"""Root conftest: anchors the repo root (for the `benchmarks` package) and
src/ (for `repro`) on sys.path, so the suite runs under bare `pytest` from
any directory, not just `PYTHONPATH=src python -m pytest` from the root."""
import pathlib
import sys

_ROOT = pathlib.Path(__file__).resolve().parent
sys.path.insert(0, str(_ROOT))
sys.path.insert(0, str(_ROOT / "src"))
