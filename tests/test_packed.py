"""Packed multi-program fleet runtime (DESIGN.md §9.8): banked-fetch
stepper parity against per-program monolithic runs, three-way
(switch/branchless/pallas) engine packed-parity with the sequential
baseline, heterogeneous per-lane step budgets, the proportional
admission scheduler, and sharded multi-device packed streaming."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.fleet import skew_fleet, skew_program
from repro.flexibench.base import get
from repro.flexibits import iss
from repro.fleet import engine
from repro.fleet.engine import PackedGroup, _apportion, run_packed
from repro.fleet.plan import FleetGroup, FleetPlan, run_plan
from repro.kernels.iss_stepper import iss_segment_banked

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _packed_pool(workloads, pids, seed=5):
    """Interleaved lane pool: lane i runs workloads[pids[i]]."""
    n = len(pids)
    mem_words = max(w.total_mem_words for w in workloads)
    mems = np.zeros((n, mem_words), np.int32)
    ms = np.zeros(n, np.int32)
    for i, p in enumerate(pids):
        w = workloads[p]
        rng = np.random.default_rng([seed, i])
        m = w.initial_memory(w.gen_inputs(rng, 1)[0])
        mems[i, :len(m)] = m
        ms[i] = w.max_steps
    lanes = iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32), pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems), halted=jnp.zeros((n,), bool),
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
        n_cycles=jnp.zeros((n,), iss.I32))
    ps = iss.PackedState(lanes=lanes, prog_id=jnp.asarray(pids, iss.I32),
                         max_steps=jnp.asarray(ms))
    refs = []
    for i, p in enumerate(pids):
        w = workloads[p]
        code = jnp.asarray(w.program.code.view(np.int32))
        refs.append(iss.run(code, jnp.asarray(
            mems[i, :w.total_mem_words]), w.max_steps))
    return ps, refs


def _assert_lanes_match_refs(st: iss.PackedState, refs, workloads, pids):
    for i, p in enumerate(pids):
        w = workloads[p]
        ref = refs[i]
        np.testing.assert_array_equal(
            np.asarray(st.lanes.n_instr)[i], np.asarray(ref.n_instr),
            err_msg=f"lane {i}")
        np.testing.assert_array_equal(
            np.asarray(st.lanes.n_two_stage)[i],
            np.asarray(ref.n_two_stage), err_msg=f"lane {i}")
        np.testing.assert_array_equal(
            np.asarray(st.lanes.mem)[i, :w.total_mem_words],
            np.asarray(ref.mem), err_msg=f"lane {i}")
        np.testing.assert_array_equal(
            np.asarray(st.lanes.regs)[i], np.asarray(ref.regs),
            err_msg=f"lane {i}")
        np.testing.assert_array_equal(
            np.asarray(st.lanes.mix)[i], np.asarray(ref.mix),
            err_msg=f"lane {i}")


def test_pack_programs_pads_and_measures():
    a = np.arange(3, dtype=np.uint32)
    b = np.arange(7, dtype=np.uint32)
    bank, clen = iss.pack_programs([a, b])
    assert bank.shape == (2, 7) and bank.dtype == np.int32
    np.testing.assert_array_equal(clen, [3, 7])
    np.testing.assert_array_equal(bank[0, 3:], 0)   # padding
    np.testing.assert_array_equal(bank[1], b.view(np.int32))


def test_fetch_banked_clamps_per_program():
    """A pc past a short program's end fetches that program's OWN last
    word (jax clamp-on-read against the row's code_len), never the
    bank's padding or another row."""
    bank, clen = iss.pack_programs(
        [np.array([10, 11], np.uint32), np.array([20, 21, 22], np.uint32)])
    bank_j, clen_j = jnp.asarray(bank), jnp.asarray(clen)
    got = jax.jit(iss.fetch_banked)(
        bank_j, clen_j, jnp.asarray([0, 0, 1], iss.I32),
        jnp.asarray([4, 8, 11 * 4], iss.I32))
    np.testing.assert_array_equal(np.asarray(got), [11, 11, 22])


@pytest.mark.parametrize("mode", ["branchless", "pallas", "switch"])
def test_banked_segments_match_per_program_monolithic(mode):
    """Interleaved lanes running two different workloads from one bank
    retire exactly what each lane's own single-program `iss.run` does —
    for all three banked segment steppers."""
    workloads = (get("WQ"), get("MC"))
    pids = [i % 2 for i in range(8)]
    ps, refs = _packed_pool(workloads, pids)
    bank_np, clen_np = iss.pack_programs(
        [w.program.code for w in workloads])
    bank, clen = jnp.asarray(bank_np), jnp.asarray(clen_np)
    sub = frozenset().union(
        *(iss.opcode_subset(w.program.code) for w in workloads))

    if mode == "branchless":
        seg = jax.jit(lambda b, c, s: iss.run_segment_lanes_banked(
            b, c, s, 64, sub))
    elif mode == "pallas":
        seg = jax.jit(lambda b, c, s: iss_segment_banked(
            b, c, s, seg_steps=64, subset=sub, lane_tile=4))
    else:
        seg = jax.jit(lambda b, c, s: iss.PackedState(
            lanes=jax.vmap(lambda p, m, l: iss.run_segment_banked(
                b, c, p, m, l, 64))(s.prog_id, s.max_steps, s.lanes),
            prog_id=s.prog_id, max_steps=s.max_steps))

    st = ps
    for _ in range(10_000):
        st = seg(bank, clen, st)
        if bool(np.asarray(st.lanes.halted).all()):
            break
    _assert_lanes_match_refs(st, refs, workloads, pids)


@pytest.mark.parametrize("stepper", ["switch", "branchless", "pallas"])
def test_packed_engine_bit_exact_with_sequential(stepper):
    """run_packed demuxes per-group results bit-exactly equal to what
    run_stream produces for each group alone — full final state, per-item
    tallies, and outputs — for all three steppers."""
    specs = (("WQ", 1, 40), ("MC", 2, 17))
    groups = []
    for key, seed, n in specs:
        w = get(key)
        groups.append(PackedGroup(
            code=w.program.code, source=engine.workload_source(w, seed),
            n_items=n, max_steps=w.max_steps,
            mem_words=w.total_mem_words, out_addr=w.out_addr))
    res, stats = run_packed(groups, chunk=16, seg_steps=128,
                            keep_state=True, stepper=stepper)
    assert stats.n_groups == 2 and stats.chunk == 16
    for (key, seed, n), r in zip(specs, res):
        w = get(key)
        ref = engine.run_workload_stream(
            w, n, seed=seed, chunk=16, seg_steps=128, keep_state=True,
            stepper=stepper)
        np.testing.assert_array_equal(r.n_instr, ref.n_instr)
        np.testing.assert_array_equal(r.n_two_stage, ref.n_two_stage)
        np.testing.assert_array_equal(r.halted, ref.halted)
        np.testing.assert_array_equal(r.out, ref.out)
        np.testing.assert_array_equal(r.mix, ref.mix)
        np.testing.assert_array_equal(r.mems, ref.mems)
        np.testing.assert_array_equal(r.regs, ref.regs)
        np.testing.assert_array_equal(r.pc, ref.pc)
        np.testing.assert_array_equal(r.mix_items, ref.mix_items)
        assert r.stepper == stepper and r.halted.all()
        # the demuxed outputs also match the functional reference
        src = engine.workload_source(w, seed)(0, n)
        np.testing.assert_array_equal(r.out, w.ref(src[:, :w.n_inputs]))


def test_packed_plan_report_matches_sequential():
    """run_plan(packed=True) reports the same per-group carbon numbers
    (to the bit — same floats) as the sequential baseline, plus packed
    whole-run stats."""
    groups = (
        FleetGroup(workload="WQ", core="SERV", n_items=40, seed=1),
        FleetGroup(workload="MC", core="HERV", n_items=24, seed=2),
    )
    rep_p = run_plan(FleetPlan(groups=groups, chunk=16, seg_steps=128))
    rep_s = run_plan(FleetPlan(groups=groups, chunk=16, seg_steps=128,
                               packed=False))
    assert rep_p.packed is not None and rep_p.packed.n_groups == 2
    assert rep_s.packed is None
    for a, b in zip(rep_p.groups, rep_s.groups):
        np.testing.assert_array_equal(a.result.n_instr, b.result.n_instr)
        np.testing.assert_array_equal(a.result.mix, b.result.mix)
        assert a.profile == b.profile
        assert a.energy_j_per_exec == b.energy_j_per_exec
        assert a.operational_kg == b.operational_kg
        assert a.embodied_kg == b.embodied_kg
        assert a.total_kg == b.total_kg
        assert a.recommended_core == b.recommended_core
    assert "packed runtime: 2 groups" in rep_p.format()


def test_packed_heterogeneous_step_budgets():
    """Groups with different max_steps in ONE pool: each budget-exhausted
    item retires with n_instr == its OWN group's budget and halted=False,
    exactly as in its group's sequential run."""
    prog = skew_program()
    mems_a = skew_fleet(prog, 12, short_iters=4, long_iters=5000,
                        long_frac=0.5, seed=2)
    mems_b = skew_fleet(prog, 12, short_iters=4, long_iters=5000,
                        long_frac=0.5, seed=3)
    groups = [
        PackedGroup(code=prog.code, source=engine.array_source(mems_a),
                    n_items=12, max_steps=200, mem_words=32, out_addr=1),
        PackedGroup(code=prog.code, source=engine.array_source(mems_b),
                    n_items=12, max_steps=350, mem_words=32, out_addr=1),
    ]
    res, _ = run_packed(groups, chunk=8, seg_steps=64)
    for r, mems, budget in ((res[0], mems_a, 200), (res[1], mems_b, 350)):
        long_items = mems[:, 0] == 5000
        assert (~r.halted[long_items]).all()
        assert r.halted[~long_items].all()
        assert (r.n_instr[long_items] == budget).all()


def test_apportion_is_proportional_and_exact():
    """The admission split is deterministic, integral, never exceeds a
    group's backlog, and hands out exactly min(slots, total) lanes."""
    cases = [
        (10, [1, 100]), (100, [2, 2, 100]), (90, [1, 1, 1, 97]),
        (5, [2, 4]), (6, [1, 5]), (3, [0, 0, 7]), (7, [3, 3]),
        (0, [4, 4]), (16, [0, 0, 0]), (128, [1024, 128, 64, 64]),
    ]
    for slots, rem in cases:
        take = _apportion(slots, rem)
        assert take.sum() == min(slots, sum(rem)), (slots, rem, take)
        assert (take <= np.asarray(rem)).all(), (slots, rem, take)
        assert (take >= 0).all()
        np.testing.assert_array_equal(take, _apportion(slots, rem))
    # proportionality: the big group gets the lion's share
    take = _apportion(128, [1024, 128, 64, 64])
    assert take[0] > take[1] > 0 and take[2] > 0 and take[3] > 0


def test_packed_scheduler_beats_sequential_drain_on_skew():
    """On 8x-skewed group sizes with within-group halt-time skew, the
    packed stream needs fewer segments and fewer lane-step slots than
    draining the groups sequentially (freed lanes are backfilled from
    other groups instead of idling through each group's tail)."""
    prog = skew_program()
    sizes = (128, 16, 16)
    groups = []
    seq_segments = 0
    seq_lane_steps = 0
    for gi, n in enumerate(sizes):
        mems = skew_fleet(prog, n, short_iters=8, long_iters=1500,
                          long_frac=0.15, seed=31 + gi)
        g = PackedGroup(code=prog.code, source=engine.array_source(mems),
                        n_items=n, max_steps=100_000, mem_words=32,
                        out_addr=1)
        groups.append(g)
        r = engine.run_stream(prog.code, engine.array_source(mems),
                              n_items=n, mem_words=32, max_steps=100_000,
                              chunk=16, seg_steps=64, out_addr=1)
        seq_segments += r.n_segments
        seq_lane_steps += r.lane_steps
    _, stats = run_packed(groups, chunk=16, seg_steps=64)
    assert stats.n_segments < seq_segments, (stats.n_segments,
                                             seq_segments)
    assert stats.lane_steps < seq_lane_steps, (stats.lane_steps,
                                               seq_lane_steps)


@pytest.mark.parametrize("stepper", ["switch", "branchless", "pallas"])
def test_packed_preserves_oob_memory_semantics_per_group(stepper):
    """Data-memory out-of-range semantics are per-GROUP, not per-pool:
    a lane of a small-memory group packed next to a larger-memory group
    still clamps reads to ITS OWN last word and drops ITS OWN
    out-of-range stores (the data-port analogue of fetch_banked's
    per-program pc clamp), so even OOB-touching programs stay bit-exact
    with their sequential baseline."""
    from repro.flexibits.asm import Asm

    a = Asm(vm_reserved=32)
    a.li(a.t0, 99)
    a.sw(a.t0, a.zero, 80)    # word 20: OOB for an 8-word memory
    a.lw(a.t1, a.zero, 80)    # OOB load
    a.sw(a.t1, a.zero, 4)     # out at word 1
    a.halt()
    prog = a.assemble()

    def source(mem_words):
        mem = np.zeros((1, mem_words), np.int32)
        mem[0, :len(prog.initial_memory(mem_words))] = \
            prog.initial_memory(mem_words)
        mem[0, 7] = 1234          # sentinel at the small memory's last word
        return engine.array_source(mem)

    groups = [
        PackedGroup(code=prog.code, source=source(8), n_items=1,
                    max_steps=100, mem_words=8, out_addr=1),
        PackedGroup(code=prog.code, source=source(32), n_items=1,
                    max_steps=100, mem_words=32, out_addr=1),
    ]
    res, _ = run_packed(groups, chunk=2, seg_steps=16, stepper=stepper)
    for g in groups:
        ref = engine.run_stream(g.code, g.source, n_items=1,
                                mem_words=g.mem_words, max_steps=100,
                                chunk=1, seg_steps=16, out_addr=1,
                                stepper=stepper)
        r = res[0] if g.mem_words == 8 else res[1]
        np.testing.assert_array_equal(r.out, ref.out)
        np.testing.assert_array_equal(r.n_instr, ref.n_instr)
    # word 20 is OOB for the 8-word group: its store DROPS and its load
    # clamps to word 7's sentinel; for the 32-word group the same
    # addresses are in range, so the stored 99 reads back
    assert res[0].out[0] == 1234
    assert res[1].out[0] == 99


def test_run_packed_rejects_bad_args():
    prog = skew_program()
    g = PackedGroup(code=prog.code,
                    source=engine.array_source(np.zeros((4, 32), np.int32)),
                    n_items=4, max_steps=100, mem_words=32)
    with pytest.raises(ValueError):
        run_packed([])
    with pytest.raises(ValueError):
        run_packed([g], seg_steps=0)
    with pytest.raises(ValueError):
        run_packed([g], stepper="vliw")


@pytest.mark.slow
def test_packed_sharded_multi_device_bit_exact():
    """Packed streaming under shard_map on 4 forced host devices stays
    bit-exact with the sequential per-group baseline, for all three
    steppers (lane fields prog_id/max_steps shard over the mesh; the
    bank replicates)."""
    script = r"""
import numpy as np, jax, json
from benchmarks.fleet import skew_fleet, skew_program
from repro.fleet import engine
from repro.fleet.engine import PackedGroup, run_packed
prog = skew_program()
mems_a = skew_fleet(prog, 40, short_iters=8, long_iters=400,
                    long_frac=0.2, seed=13)
mems_b = skew_fleet(prog, 24, short_iters=16, long_iters=300,
                    long_frac=0.3, seed=14)
groups = [
    PackedGroup(code=prog.code, source=engine.array_source(mems_a),
                n_items=40, max_steps=100_000, mem_words=32, out_addr=1),
    PackedGroup(code=prog.code, source=engine.array_source(mems_b),
                n_items=24, max_steps=100_000, mem_words=32, out_addr=1),
]
refs = [engine.run_stream(g.code, g.source, n_items=g.n_items,
                          mem_words=32, max_steps=100_000, chunk=16,
                          seg_steps=64, out_addr=1) for g in groups]
mesh = jax.make_mesh((len(jax.devices()),), ("fleet",))
for stepper in ("branchless", "pallas", "switch"):
    res, stats = run_packed(groups, chunk=16, seg_steps=64, mesh=mesh,
                            stepper=stepper)
    assert stats.n_devices == 4, stats.n_devices
    for r, ref in zip(res, refs):
        np.testing.assert_array_equal(r.n_instr, ref.n_instr)
        np.testing.assert_array_equal(r.out, ref.out)
        np.testing.assert_array_equal(r.mix, ref.mix)
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT, env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout.strip().splitlines()[-1])["ok"]
