"""FlexiLint soundness and integration tests (DESIGN.md §9.11).

The static analyzer's claims are certificates, so every one is pinned
against ground truth: decode/disasm round-trips through every ISA
entry, the CFG/dataflow/bounds passes are exercised on hand-built
programs with known defects, and the PyISS oracle cross-validates the
whole pipeline — on all 11 FlexiBench workloads and on random
instruction soups, every retired word must lie in the static reachable
set, every retired mnemonic in the static subset, and measured
steps/ticks must sit inside the [min_steps, WCET] envelope.

Engine integration: the analyzer's reachable-only opcode subsets must
leave every stepper bit-exact with the text-derived subsets, budget
validation must reject provably-insufficient `max_steps`, and the
fleet report's certified worst-case cycles must dominate the measured
means.

`hypothesis` is optional (as in test_flexibits.py): without it the
soup property test falls back to a deterministic seed sweep.
"""
import numpy as np
import pytest

from repro.core import carbon
from repro.flexibench.base import all_workloads, get
from repro.flexibits import analyze, asm, isa, iss
from repro.flexibits.asm import Asm, decode, disasm
from repro.flexibits.cycles import CORES, TICKS_PER_CYCLE, cost_row
from repro.flexibits.pyiss import PyISS
from repro.fleet.plan import BudgetError, FleetGroup, FleetPlan, run_plan

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

COST = cost_row(CORES["SERV"], dynamic=True)


# ---------------------------------------------------------------------------
# satellite 1: decode/disasm round-trip over the whole ISA

def _operand_sweep(name, rng):
    """A handful of legal operand tuples (rd, rs1, rs2, imm) for `name`."""
    out = []
    for _ in range(8):
        rd = int(rng.integers(0, 32))
        rs1 = int(rng.integers(0, 32))
        rs2 = int(rng.integers(0, 32))
        if name in isa.R_OPS:
            out.append((rd, rs1, rs2, 0))
        elif name in isa.SHIFT_OPS:
            out.append((rd, rs1, 0, int(rng.integers(0, 32))))
        elif name in isa.I_OPS:
            out.append((rd, rs1, 0, int(rng.integers(-2048, 2048))))
        elif name in isa.S_OPS:
            out.append((0, rs1, rs2, int(rng.integers(-2048, 2048))))
        elif name in isa.B_OPS:
            out.append((0, rs1, rs2, int(rng.integers(-2048, 2048)) * 2))
        elif name in ("lui", "auipc"):
            out.append((rd, 0, 0, int(rng.integers(0, 1 << 20))))
        elif name == "jal":
            out.append((rd, 0, 0, int(rng.integers(-(1 << 19),
                                                   1 << 19)) * 2))
        else:                                   # ecall / ebreak
            out.append((0, 0, 0, 0))
    return out


def test_decode_roundtrip_every_isa_entry():
    rng = np.random.default_rng(0)
    for name in isa.ALL_OPS:
        for rd, rs1, rs2, imm in _operand_sweep(name, rng):
            word = isa.encode(name, rd, rs1, rs2, imm)
            d = decode(word)
            assert d is not None, (name, hex(word))
            assert d.name == name
            assert isa.encode(d.name, d.rd, d.rs1, d.rs2, d.imm) == word
            text = disasm(word)
            assert not text.startswith(".word"), (name, text)
            assert name in text


def test_decode_rejects_garbage():
    assert decode(0) is None
    assert decode(0xFFFFFFFF) is None
    assert disasm(0).startswith(".word")
    # SYSTEM words other than the two exact halt encodings are data
    assert decode((2 << 20) | isa.OP_SYSTEM) is None


def test_disasm_spot_checks():
    assert disasm(isa.encode("addi", 10, 0, 0, 5)) == "addi a0, zero, 5"
    assert disasm(isa.encode("lw", 6, 2, 0, 8)) == "lw t1, 8(sp)"
    assert disasm(isa.encode("sw", 0, 2, 6, -4)) == "sw t1, -4(sp)"
    assert disasm(isa.encode("ecall")) == "ecall"
    b = disasm(isa.encode("beq", 0, 5, 5, -8))
    assert b.startswith("beq") and "pc-8" in b


def test_pyiss_trace_dump():
    a = Asm()
    a.li(a.t0, 7)
    a.halt()
    prog = a.assemble()
    sim = PyISS(prog.code, mem_words=16, trace_len=4)
    sim.run(max_steps=10)
    dump = sim.format_trace()
    assert "addi t0, zero, 7" in dump and "ecall" in dump


# ---------------------------------------------------------------------------
# dataflow / CFG units on hand-built programs

def _codes(a):
    return [d.code for d in a.diags]


def test_read_before_write_error():
    a = Asm()
    a.add(a.t1, a.t2, a.a0)     # t2/a0 never written
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert "read-before-write" in _codes(res)
    assert res.errors


def test_zero_init_regs_are_defined():
    # the cores zero-init the file, so reading x0 or any reg the
    # analyzer proves written is clean
    a = Asm()
    a.addi(a.t0, a.zero, 3)
    a.add(a.t1, a.t0, a.t0)
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert not res.errors


def test_dead_store_warning():
    a = Asm()
    a.li(a.t0, 1)
    a.li(a.t0, 2)               # first li is dead
    a.sw(a.t0, a.zero, 0)
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert "dead-store" in _codes(res)


def test_unreachable_code_warning():
    a = Asm()
    end = a.uniq()
    a.j(end)
    a.li(a.t0, 1)               # skipped forever
    a.label(end)
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert "unreachable-code" in _codes(res)
    assert 1 not in res.reachable


def test_unreachable_halt_error():
    a = Asm()
    loop = a.uniq()
    a.label(loop)
    a.j(loop)                   # spins forever, ecall unreachable
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert "unreachable-halt" in _codes(res)
    assert res.min_steps is None


def test_oob_store_error_and_proved_store_silent():
    a = Asm()
    a.li(a.t0, 1)
    a.sw(a.t0, a.zero, 400)     # mem is 16 words = 64 bytes
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert "oob-access" in _codes(res)

    b = Asm()
    b.li(b.t0, 1)
    b.sw(b.t0, b.zero, 8)       # provably inside
    b.halt()
    res2 = analyze.analyze_program(b.assemble(), mem_words=16)
    assert "oob-access" not in _codes(res2)
    assert "runtime-clamped" not in _codes(res2)


def test_unknown_address_is_runtime_clamped_info():
    a = Asm()
    a.lw(a.t0, a.zero, 0)       # loads unknown data
    a.lw(a.t1, a.t0, 0)         # address not affine in constants
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert "runtime-clamped" in _codes(res)
    assert not res.errors


def test_indirect_jalr_degrades_to_overapproximation():
    a = Asm()
    a.li(a.t0, 8)
    a.jalr(a.zero, a.t0, 0)     # computed jump, not a ret
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert res.degraded is not None
    assert res.reachable == frozenset(range(res.n_words))
    assert res.subset == iss.opcode_subset(res.code)
    assert res.wcet_steps is None
    # budget-only tick bound still exists
    assert res.bound_ticks(COST, max_steps=10) == \
        10 * res.max_instr_ticks(COST)


# ---------------------------------------------------------------------------
# WCET: a counted loop where the bound is exact

def _counted_loop(n):
    a = Asm()
    loop = a.uniq()
    a.li(a.t0, 0)
    a.li(a.t1, n)
    a.label(loop)
    a.addi(a.t0, a.t0, 1)
    a.blt(a.t0, a.t1, loop)
    a.halt()
    return a.assemble()


def test_counted_loop_wcet_is_exact():
    prog = _counted_loop(10)
    res = analyze.analyze_program(prog, mem_words=16)
    assert not res.errors and res.degraded is None
    # counter idiom inferred without annotation
    assert res.loop_headers and list(res.loop_headers.values()) == [10]
    sim = PyISS(prog.code, mem_words=16).run()
    assert sim.halted
    assert res.wcet_steps == sim.n_instr == 23   # 2 + 10*2 + 1
    assert res.min_steps <= sim.n_instr
    # tick bound: loose only by the final not-taken branch
    assert sim.ticks(COST) <= res.wcet_ticks(COST)


def test_loop_bound_annotation_overrides_inference():
    a = Asm()
    loop = a.uniq()
    a.li(a.t0, 0)
    a.lw(a.t1, a.zero, 0)       # data-dependent trip count
    a.loop_bound(loop, 5)
    a.label(loop)
    a.addi(a.t0, a.t0, 1)
    a.blt(a.t0, a.t1, loop)
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert res.degraded is None
    assert 5 in res.loop_headers.values()
    assert res.wcet_steps == 3 + 5 * 2    # li+lw + 5*(addi+blt) ... + ecall
    # (3 entry words include the ecall: 2 setup + 5*2 body + 1 halt)


def test_unannotated_data_loop_is_unbounded():
    a = Asm()
    loop = a.uniq()
    a.li(a.t0, 0)
    a.lw(a.t1, a.zero, 0)
    a.label(loop)
    a.addi(a.t0, a.t0, 1)
    a.blt(a.t0, a.t1, loop)
    a.halt()
    res = analyze.analyze_program(a.assemble(), mem_words=16)
    assert "unbounded-loop" in _codes(res)
    assert res.wcet_steps is None


# ---------------------------------------------------------------------------
# satellite 3: analyzer soundness vs the PyISS oracle

@pytest.mark.parametrize("w", all_workloads(), ids=lambda w: w.key)
def test_workload_soundness(w):
    a = analyze.analyze_workload(w)
    assert a.degraded is None, (w.key, a.degraded)
    assert not a.errors, [d.format(a.code) for d in a.errors]
    assert a.wcet_steps is not None and a.min_steps is not None
    wcet_t = a.wcet_ticks(COST)
    assert wcet_t is not None
    rng = np.random.default_rng(0)
    for x in w.gen_inputs(rng, 2):
        sim = PyISS(w.program.code, mem_words=w.total_mem_words,
                    init_mem=w.initial_memory(x))
        sim.run(max_steps=w.max_steps)
        assert sim.halted
        assert sim.visited <= a.reachable, \
            sorted(sim.visited - a.reachable)
        assert set(sim.mix) <= a.reachable_names
        assert a.min_steps <= sim.n_instr <= a.wcet_steps
        assert sim.ticks(COST) <= wcet_t


def test_workload_lint_is_clean_except_documented():
    """The only warning across FlexiBench is SI's known dead store at
    word 16 (`add t1,t1,s0` whose value the next iteration recomputes)
    — kept in the source as FlexiLint's demo finding (README)."""
    for w in all_workloads():
        a = analyze.analyze_workload(w)
        if w.key == "SI":
            assert [(d.code, d.word) for d in a.warnings] == \
                [("dead-store", 16)]
        else:
            assert not a.warnings, (w.key, [d.code for d in a.warnings])


def test_workload_static_subset_within_text_subset():
    for w in all_workloads():
        static = iss.opcode_subset(w.program.code, reachable_only=True)
        text = iss.opcode_subset(w.program.code)
        assert static <= text, w.key


def _soup_soundness(seed):
    """One random-soup soundness trial: build a soup of valid words,
    analyze, and check PyISS containment whenever the CFG is exact."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 24))
    names = [name for name in isa.ALL_OPS if name != "ebreak"]
    words = []
    for i in range(n):
        name = names[int(rng.integers(0, len(names)))]
        rd, rs1, rs2, imm = _operand_sweep(name, rng)[0]
        if name in isa.B_OPS or name == "jal":
            imm = int(rng.integers(-n, n)) * 4
        if name in isa.S_OPS or name in ("lw", "lh", "lb", "lhu", "lbu"):
            rs1, imm = 0, int(rng.integers(0, 64)) * 4
        words.append(isa.encode(name, rd, rs1, rs2, imm))
    words.append(isa.encode("ecall"))
    code = np.array(words, np.uint32)
    a = analyze.analyze_code(code, mem_words=64)
    if a.degraded is not None:
        # over-approximation contract: everything reachable, subset
        # falls back to the text scan
        assert a.reachable == frozenset(range(len(code)))
        assert a.subset == iss.opcode_subset(code)
        return
    sim = PyISS(code, mem_words=64)
    sim.run(max_steps=2000)
    assert sim.visited <= a.reachable, seed
    assert set(sim.mix) <= a.reachable_names, seed
    if sim.halted:
        if a.min_steps is not None:
            assert sim.n_instr >= a.min_steps, seed
        if a.wcet_steps is not None:
            assert sim.n_instr <= a.wcet_steps, seed
            assert sim.ticks(COST) <= a.wcet_ticks(COST), seed


if HAVE_HYPOTHESIS:
    @hypothesis.given(st.integers(0, 2 ** 31 - 1))
    @hypothesis.settings(max_examples=60, deadline=None)
    def test_soup_soundness(seed):
        _soup_soundness(seed)
else:
    @pytest.mark.parametrize("seed", range(60))
    def test_soup_soundness(seed):
        _soup_soundness(seed)


# ---------------------------------------------------------------------------
# satellite 2 + engine integration

def _mini_plan(**kw):
    return FleetPlan(groups=[FleetGroup("WQ", n_items=16),
                             FleetGroup("MC", n_items=16)],
                     chunk=16, seg_steps=128, **kw)


def test_static_subsets_bit_exact_with_text():
    ra = run_plan(_mini_plan(subset_source="text", timing="dynamic"))
    rb = run_plan(_mini_plan(subset_source="static", timing="dynamic"))
    for ga, gb in zip(ra.groups, rb.groups):
        np.testing.assert_array_equal(ga.result.out, gb.result.out)
        np.testing.assert_array_equal(ga.result.n_instr, gb.result.n_instr)
        np.testing.assert_array_equal(ga.result.n_cycles,
                                      gb.result.n_cycles)


def test_budget_error_names_program_and_bounds():
    plan = FleetPlan(groups=[FleetGroup("HC", n_items=8, max_steps=100)],
                     chunk=8, seg_steps=64)
    with pytest.raises(BudgetError) as ei:
        run_plan(plan)
    e = ei.value
    assert e.name == "HC" and e.budget == 100
    assert e.min_steps == analyze.analyze_workload(get("HC")).min_steps
    assert "HC" in str(e) and "100" in str(e)


def test_budget_validation_can_be_disabled():
    plan = FleetPlan(groups=[FleetGroup("WQ", n_items=8, max_steps=2)],
                     chunk=8, seg_steps=64, validate_budgets=False)
    rep = run_plan(plan)
    assert not rep.groups[0].result.halted.any()


def test_static_max_steps_budget():
    a = analyze.analyze_workload(get("MC"))
    plan = FleetPlan(groups=[FleetGroup("MC", n_items=16,
                                        max_steps="static")],
                     chunk=16, seg_steps=128)
    rep = run_plan(plan)
    g = rep.groups[0]
    assert g.result.halted.all()     # WCET budget is proved sufficient
    ref = run_plan(FleetPlan(groups=[FleetGroup("MC", n_items=16)],
                             chunk=16, seg_steps=128))
    np.testing.assert_array_equal(g.result.out, ref.groups[0].result.out)
    assert plan.groups[0].resolve_max_steps(get("MC"), a) == a.wcet_steps


def test_report_carries_certificates():
    rep = run_plan(_mini_plan(timing="dynamic"))
    for g in rep.groups:
        assert g.wcet_cycles is not None
        assert g.measured_cycles is not None
        assert g.measured_cycles <= g.wcet_cycles
        assert g.wcet_ratio >= 1.0
        assert g.certified_energy_j == pytest.approx(
            carbon.certified_energy_j(g.core, g.profile, 10_000.0,
                                      g.wcet_cycles))
        assert g.certified_energy_j >= g.energy_j_per_exec
        assert g.certified_operational_kg >= g.operational_kg
    text = rep.format()
    assert "wcet-cyc" in text and "certified (FlexiLint" in text


def test_certified_cycles_match_bound_ticks():
    w = get("WQ")
    a = analyze.analyze_workload(w)
    rep = run_plan(FleetPlan(groups=[FleetGroup("WQ", n_items=8)],
                             chunk=8, seg_steps=64))
    want = a.bound_ticks(COST, w.max_steps) / TICKS_PER_CYCLE
    assert rep.groups[0].wcet_cycles == pytest.approx(want)


def test_cli_runs_clean(capsys):
    from repro.tools.flexilint import main
    assert main(["WQ", "MC", "--measure", "1"]) == 0
    out = capsys.readouterr().out
    assert "FlexiLint: WQ" in out and "wcet-ticks" in out
    assert "flexilint: 2 program(s) analyzed, ok" in out
