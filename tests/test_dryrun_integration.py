"""Dry-run integration: the launcher really lowers/compiles production-mesh
cells (subprocess so the 512-device XLA flag doesn't leak into this
process), and the artifacts carry roofline terms."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# subprocess compiles of full dryrun cells — full tier only
pytestmark = pytest.mark.slow


@pytest.mark.parametrize("args", [
    ("whisper-tiny", "train_4k", False),
    ("whisper-tiny", "decode_32k", False),
    ("qwen2-1.5b", "train_4k", True),        # multi-pod: 512 chips
])
def test_dryrun_cell_compiles(tmp_path, args):
    arch, shape, multipod = args
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, "--out", str(tmp_path)]
    if multipod:
        cmd.append("--multi-pod")
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(cmd, capture_output=True, text=True, env=env,
                         timeout=560, cwd=REPO)
    assert out.returncode == 0, out.stderr[-2000:]
    tag = f"{arch}__{shape}__{'pod2' if multipod else 'pod1'}"
    with open(tmp_path / f"{tag}.json") as f:
        d = json.load(f)
    assert d["status"] == "ok", d
    r = d["roofline"]
    assert r["bound_step_s"] > 0
    assert d["hlo"]["flops_per_device"] > 0
    assert d["hlo"]["unknown_trip_counts"] == 0
    mesh = "2x16x16" if multipod else "16x16"
    assert d["mesh"] == mesh


def test_artifacts_cover_all_cells():
    """The shipped artifacts contain all 40 cells x both meshes."""
    art = os.path.join(REPO, "artifacts", "dryrun_opt")
    if not os.path.isdir(art):
        pytest.skip("artifacts not present")
    names = os.listdir(art)
    for pod in ("pod1", "pod2"):
        cells = [n for n in names if n.endswith(f"__{pod}.json")]
        assert len(cells) == 40, (pod, len(cells))
        ok = skip = 0
        for n in cells:
            with open(os.path.join(art, n)) as f:
                d = json.load(f)
            ok += d["status"] == "ok"
            skip += d["status"] == "skip"
        assert ok == 32 and skip == 8, (pod, ok, skip)
