"""Streaming fleet engine tests (DESIGN.md §9): bit-exact parity of
segmented early-exit execution vs the monolithic vmap(while_loop),
heterogeneous FleetPlan smoke, and cycle savings on skewed halt times."""
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.fleet import skew_fleet, skew_program
from repro.flexibench.base import get
from repro.flexibits import fleet, iss
from repro.fleet import (FleetGroup, FleetPlan, array_source, run_plan,
                         run_stream, workload_source)
from repro.launch.mesh import make_host_mesh


@pytest.mark.parametrize("key", ["WQ", "MC"])
def test_segmented_parity_with_monolithic(key):
    """Chunked + segmented execution is bit-exact with one-shot iss.run."""
    w = get(key)
    mems = fleet.fleet_inputs(w, 24, seed=0)
    mono = iss.run_fleet(jnp.asarray(w.program.code.view(np.int32)),
                         jnp.asarray(mems), w.max_steps)
    res = run_stream(w.program.code, array_source(mems), n_items=24,
                     mem_words=mems.shape[1], max_steps=w.max_steps,
                     chunk=7, seg_steps=16, out_addr=w.out_addr,
                     keep_state=True)
    np.testing.assert_array_equal(res.mems, np.asarray(mono.mem))
    np.testing.assert_array_equal(res.regs, np.asarray(mono.regs))
    np.testing.assert_array_equal(res.n_instr, np.asarray(mono.n_instr))
    np.testing.assert_array_equal(res.n_two_stage,
                                  np.asarray(mono.n_two_stage))
    np.testing.assert_array_equal(res.mix_items, np.asarray(mono.mix))
    assert res.halted.all()
    np.testing.assert_array_equal(res.mix, np.asarray(mono.mix).sum(0))
    # outputs match the functional reference too
    xs = mems[:, :w.n_inputs]
    np.testing.assert_array_equal(res.out, w.ref(xs))


def test_legacy_wrapper_bit_exact():
    """run_fleet_sharded (now a wrapper over the engine) is unchanged."""
    w = get("WQ")
    mems = fleet.fleet_inputs(w, 16, seed=3)
    mono = iss.run_fleet(jnp.asarray(w.program.code.view(np.int32)),
                         jnp.asarray(mems), w.max_steps)
    st = fleet.run_fleet_sharded(w, mems, make_host_mesh())
    np.testing.assert_array_equal(np.asarray(st.mem), np.asarray(mono.mem))
    np.testing.assert_array_equal(np.asarray(st.n_instr),
                                  np.asarray(mono.n_instr))
    assert np.asarray(st.halted).all()


def test_early_exit_beats_monolithic_on_skew():
    """On a skewed halt distribution the engine retires >=2X fewer
    simulated lane-steps than the monolithic baseline, bit-exactly."""
    prog = skew_program()
    mems = skew_fleet(prog, 64, short_iters=8, long_iters=2000,
                      long_frac=0.1, seed=1)
    mono = iss.run_fleet(jnp.asarray(prog.code.view(np.int32)),
                         jnp.asarray(mems), 100_000)
    res = run_stream(prog.code, array_source(mems), n_items=64,
                     mem_words=32, max_steps=100_000, chunk=16,
                     seg_steps=64, out_addr=1, keep_state=True)
    np.testing.assert_array_equal(res.mems, np.asarray(mono.mem))
    np.testing.assert_array_equal(res.out, mems[:, 0])
    assert res.monolithic_lane_steps >= 2 * res.lane_steps, (
        res.monolithic_lane_steps, res.lane_steps)


def test_max_steps_budget_marks_unhalted():
    """Items that exhaust max_steps are retired with halted=False, like
    the monolithic path."""
    prog = skew_program()
    mems = skew_fleet(prog, 8, short_iters=4, long_iters=5000,
                      long_frac=0.5, seed=2)
    res = run_stream(prog.code, array_source(mems), n_items=8,
                     mem_words=32, max_steps=200, chunk=4, seg_steps=32)
    long_items = mems[:, 0] == 5000
    assert (~res.halted[long_items]).all()
    assert res.halted[~long_items].all()
    assert (res.n_instr[long_items] == 200).all()


def test_workload_source_deterministic_and_o_chunk():
    """Item i is a pure function of (seed, i): identical no matter how
    refill boundaries slice the stream."""
    w = get("WQ")
    src = workload_source(w, seed=5)
    whole = src(128, 32)
    np.testing.assert_array_equal(whole, src(128, 32))
    sliced = np.concatenate([src(128, 13), src(141, 19)])
    np.testing.assert_array_equal(whole, sliced)
    assert whole.shape == (32, w.total_mem_words)


def test_heterogeneous_plan_smoke():
    """Two (workload, core) groups through one engine: per-group tallies,
    carbon totals, and engine accounting all populated."""
    plan = FleetPlan(groups=(
        FleetGroup(workload="WQ", core="SERV", n_items=40, seed=1),
        FleetGroup(workload="MC", core="HERV", n_items=24, seed=2),
    ), chunk=16, seg_steps=128)
    rep = run_plan(plan)
    assert rep.n_items == 64
    assert len(rep.groups) == 2
    for g in rep.groups:
        assert g.result.halted.all()
        assert g.total_kg > 0 and g.embodied_kg > 0
        assert g.energy_j_per_exec > 0
        assert g.recommended_core in ("SERV", "QERV", "HERV")
        # mean instruction counts reflect real executions
        assert g.profile.n_one_stage + g.profile.n_two_stage > 1
    # cross-model consistency: report totals are sums of group totals
    assert rep.total_kg == pytest.approx(
        sum(g.total_kg for g in rep.groups))
    assert rep.simulation_kg() > 0
    text = rep.format()
    assert "WQ" in text and "MC" in text and "lane-steps" in text


def test_engine_chunk_larger_than_fleet():
    """chunk > n_items pads lanes without touching results."""
    w = get("WQ")
    mems = fleet.fleet_inputs(w, 5, seed=7)
    res = run_stream(w.program.code, array_source(mems), n_items=5,
                     mem_words=mems.shape[1], max_steps=w.max_steps,
                     chunk=64, seg_steps=4096, out_addr=w.out_addr)
    assert res.halted.all()
    np.testing.assert_array_equal(res.out, w.ref(mems[:, :w.n_inputs]))
