"""Streaming fleet engine tests (DESIGN.md §9): bit-exact parity of
segmented early-exit execution vs the monolithic vmap(while_loop),
heterogeneous FleetPlan smoke, and cycle savings on skewed halt times."""
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.fleet import skew_fleet, skew_program
from repro.flexibench.base import get
from repro.flexibits import fleet, iss
from repro.fleet import (FleetGroup, FleetPlan, array_source, run_plan,
                         run_stream, workload_source)
from repro.launch.mesh import make_host_mesh


@pytest.mark.parametrize("key", ["WQ", "MC"])
def test_segmented_parity_with_monolithic(key):
    """Chunked + segmented execution is bit-exact with one-shot iss.run."""
    w = get(key)
    mems = fleet.fleet_inputs(w, 24, seed=0)
    mono = iss.run_fleet(jnp.asarray(w.program.code.view(np.int32)),
                         jnp.asarray(mems), w.max_steps)
    res = run_stream(w.program.code, array_source(mems), n_items=24,
                     mem_words=mems.shape[1], max_steps=w.max_steps,
                     chunk=7, seg_steps=16, out_addr=w.out_addr,
                     keep_state=True)
    np.testing.assert_array_equal(res.mems, np.asarray(mono.mem))
    np.testing.assert_array_equal(res.regs, np.asarray(mono.regs))
    np.testing.assert_array_equal(res.n_instr, np.asarray(mono.n_instr))
    np.testing.assert_array_equal(res.n_two_stage,
                                  np.asarray(mono.n_two_stage))
    np.testing.assert_array_equal(res.mix_items, np.asarray(mono.mix))
    assert res.halted.all()
    np.testing.assert_array_equal(res.mix, np.asarray(mono.mix).sum(0))
    # outputs match the functional reference too
    xs = mems[:, :w.n_inputs]
    np.testing.assert_array_equal(res.out, w.ref(xs))


def test_legacy_wrapper_bit_exact():
    """run_fleet_sharded (now a wrapper over the engine) is unchanged."""
    w = get("WQ")
    mems = fleet.fleet_inputs(w, 16, seed=3)
    mono = iss.run_fleet(jnp.asarray(w.program.code.view(np.int32)),
                         jnp.asarray(mems), w.max_steps)
    st = fleet.run_fleet_sharded(w, mems, make_host_mesh())
    np.testing.assert_array_equal(np.asarray(st.mem), np.asarray(mono.mem))
    np.testing.assert_array_equal(np.asarray(st.n_instr),
                                  np.asarray(mono.n_instr))
    assert np.asarray(st.halted).all()


def test_early_exit_beats_monolithic_on_skew():
    """On a skewed halt distribution the engine retires >=2X fewer
    simulated lane-steps than the monolithic baseline, bit-exactly."""
    prog = skew_program()
    mems = skew_fleet(prog, 64, short_iters=8, long_iters=2000,
                      long_frac=0.1, seed=1)
    mono = iss.run_fleet(jnp.asarray(prog.code.view(np.int32)),
                         jnp.asarray(mems), 100_000)
    res = run_stream(prog.code, array_source(mems), n_items=64,
                     mem_words=32, max_steps=100_000, chunk=16,
                     seg_steps=64, out_addr=1, keep_state=True)
    np.testing.assert_array_equal(res.mems, np.asarray(mono.mem))
    np.testing.assert_array_equal(res.out, mems[:, 0])
    assert res.monolithic_lane_steps >= 2 * res.lane_steps, (
        res.monolithic_lane_steps, res.lane_steps)


def test_max_steps_budget_marks_unhalted():
    """Items that exhaust max_steps are retired with halted=False, like
    the monolithic path."""
    prog = skew_program()
    mems = skew_fleet(prog, 8, short_iters=4, long_iters=5000,
                      long_frac=0.5, seed=2)
    res = run_stream(prog.code, array_source(mems), n_items=8,
                     mem_words=32, max_steps=200, chunk=4, seg_steps=32)
    long_items = mems[:, 0] == 5000
    assert (~res.halted[long_items]).all()
    assert res.halted[~long_items].all()
    assert (res.n_instr[long_items] == 200).all()


def test_workload_source_deterministic_and_o_chunk():
    """Item i is a pure function of (seed, i): identical no matter how
    refill boundaries slice the stream."""
    w = get("WQ")
    src = workload_source(w, seed=5)
    whole = src(128, 32)
    np.testing.assert_array_equal(whole, src(128, 32))
    sliced = np.concatenate([src(128, 13), src(141, 19)])
    np.testing.assert_array_equal(whole, sliced)
    assert whole.shape == (32, w.total_mem_words)


def test_workload_source_refill_boundary_invariance_across_blocks():
    """The batched generator quantizes on fixed ALIGNED blocks, so a
    request is invariant under ANY refill slicing — including slicings
    that straddle generation-block boundaries, land on them exactly, or
    re-read earlier items after the block cache moved on."""
    w = get("WQ")
    for gen_block in (1, 7, 64):
        src = workload_source(w, seed=9, gen_block=gen_block)
        start, count = 3 * gen_block - 2, 4 * gen_block + 5
        whole = src(start, count)
        # every contiguous partition of [start, start+count) agrees
        for cuts in ([1], [gen_block], [2, gen_block - 1, gen_block],
                     [count - 1]):
            parts, i = [], start
            k = 0
            while i < start + count:
                step = min(cuts[k % len(cuts)], start + count - i)
                parts.append(src(i, step))
                i += step
                k += 1
            np.testing.assert_array_equal(
                whole, np.concatenate(parts), err_msg=f"{gen_block}/{cuts}")
        # backward re-read (cache was evicted forward): still identical
        np.testing.assert_array_equal(whole[:5], src(start, 5))
        # separate source objects with the same (seed, gen_block) agree
        np.testing.assert_array_equal(
            whole, workload_source(w, seed=9, gen_block=gen_block)(
                start, count))


def test_workload_source_batches_generation_calls():
    """The prefetcher host hot path calls gen_inputs once per aligned
    block, not once per item."""
    w = get("WQ")
    calls = []

    def counting_gen(rng, n):
        calls.append(n)
        return w.gen_inputs(rng, n)

    import dataclasses as dc
    w2 = dc.replace(w, gen_inputs=counting_gen)
    src = workload_source(w2, seed=0, gen_block=64)
    src(0, 256)
    assert calls == [64, 64, 64, 64]
    calls.clear()
    src(256, 32)        # quarter block: still ONE vectorized call
    assert calls == [64]
    calls.clear()
    src(288, 32)        # same aligned block: served from the cache
    assert calls == []


def test_heterogeneous_plan_smoke():
    """Two (workload, core) groups through one engine: per-group tallies,
    carbon totals, and engine accounting all populated."""
    plan = FleetPlan(groups=(
        FleetGroup(workload="WQ", core="SERV", n_items=40, seed=1),
        FleetGroup(workload="MC", core="HERV", n_items=24, seed=2),
    ), chunk=16, seg_steps=128)
    rep = run_plan(plan)
    assert rep.n_items == 64
    assert len(rep.groups) == 2
    for g in rep.groups:
        assert g.result.halted.all()
        assert g.total_kg > 0 and g.embodied_kg > 0
        assert g.energy_j_per_exec > 0
        assert g.recommended_core in ("SERV", "QERV", "HERV")
        # mean instruction counts reflect real executions
        assert g.profile.n_one_stage + g.profile.n_two_stage > 1
    # cross-model consistency: report totals are sums of group totals
    assert rep.total_kg == pytest.approx(
        sum(g.total_kg for g in rep.groups))
    assert rep.simulation_kg() > 0
    text = rep.format()
    assert "WQ" in text and "MC" in text and "lane-steps" in text


def test_group_report_closed_form():
    """GroupReport's operational/embodied/energy fields pinned against
    hand-computed values from the paper's model constants (cycles.py
    Table 7 cores + Table 8 memory coefficients), not just cross-group
    sums: mean instruction counts over items, bit-serial cycle/runtime
    conversion, power x runtime energy, lifetime x frequency operational
    kg, and per-item embodied kg scaled to the group."""
    import dataclasses as dc

    from repro.core.carbon import KG_PER_MM2
    from repro.flexibits.cycles import (AREA_UNIT_MM2, CORES,
                                        LPROM_AREA_PER_KB, SRAM_AREA_BASE,
                                        SRAM_AREA_PER_KB, SRAM_MW_BASE,
                                        SRAM_MW_PER_KB)
    from repro.fleet.engine import FleetResult
    from repro.fleet.report import build_group_report

    w = get("WQ")
    core = CORES["HERV"]
    n_items, clock_hz, intensity = 4, 10_000.0, 0.5
    lifetime_s, execs_per_day = 86_400.0 * 10, 24.0
    n_instr = np.array([10, 12, 14, 16], np.int64)
    n_two = np.array([2, 3, 4, 5], np.int64)
    res = FleetResult(
        n_items=n_items, n_instr=n_instr, n_two_stage=n_two,
        halted=np.ones(n_items, bool), out=np.zeros(n_items, np.int32),
        mix=np.zeros(8, np.int64), lane_steps=64, n_segments=1, chunk=4,
        seg_steps=64, wall_s=0.1)
    rep = build_group_report(
        group=None, workload=w, core=core, result=res,
        lifetime_s=lifetime_s, execs_per_day=execs_per_day,
        intensity=intensity, clock_hz=clock_hz)

    # ---- hand computation, from first principles
    mean_one = (10 + 12 + 14 + 16 - 2 - 3 - 4 - 5) / 4     # 9.5
    mean_two = (2 + 3 + 4 + 5) / 4                         # 3.5
    assert rep.profile.n_one_stage == pytest.approx(mean_one)
    assert rep.profile.n_two_stage == pytest.approx(mean_two)
    cycles = (mean_one * (32.0 / 8 + 3.65)                 # HERV one-stage
              + mean_two * (64.0 / 8 + 6.2))               # HERV two-stage
    assert rep.cycles_per_item == pytest.approx(cycles)
    vm_kb = w.vm_kb()
    p_mw = 24.99 + max(SRAM_MW_BASE + SRAM_MW_PER_KB * vm_kb, 0.05)
    e_exec = p_mw * 1e-3 * cycles / clock_hz
    assert rep.energy_j_per_exec == pytest.approx(e_exec, rel=1e-12)
    assert rep.fleet_exec_kwh == pytest.approx(
        e_exec * n_items / 3.6e6, rel=1e-12)
    n_exec = execs_per_day * lifetime_s / 86_400.0         # 240 execs
    assert rep.operational_kg == pytest.approx(
        e_exec * n_exec / 3.6e6 * intensity * n_items, rel=1e-12)
    area = (4.50
            + max(SRAM_AREA_BASE + SRAM_AREA_PER_KB * vm_kb, 0.1)
            * AREA_UNIT_MM2
            + LPROM_AREA_PER_KB * w.nvm_kb * AREA_UNIT_MM2)
    assert rep.embodied_kg == pytest.approx(
        area * KG_PER_MM2 * n_items, rel=1e-12)
    assert rep.total_kg == pytest.approx(
        rep.operational_kg + rep.embodied_kg, rel=1e-12)
    assert rep.recommended_core in ("SERV", "QERV", "HERV")

    # n_items=0 must not divide by zero (profile means fall back to n=1)
    res0 = dc.replace(res, n_items=0, n_instr=np.zeros(0, np.int64),
                      n_two_stage=np.zeros(0, np.int64),
                      halted=np.zeros(0, bool), out=np.zeros(0, np.int32))
    rep0 = build_group_report(
        group=None, workload=w, core=core, result=res0,
        lifetime_s=lifetime_s, execs_per_day=execs_per_day,
        intensity=intensity, clock_hz=clock_hz)
    assert rep0.operational_kg == 0.0 and rep0.embodied_kg == 0.0


def test_engine_chunk_larger_than_fleet():
    """chunk > n_items pads lanes without touching results."""
    w = get("WQ")
    mems = fleet.fleet_inputs(w, 5, seed=7)
    res = run_stream(w.program.code, array_source(mems), n_items=5,
                     mem_words=mems.shape[1], max_steps=w.max_steps,
                     chunk=64, seg_steps=4096, out_addr=w.out_addr)
    assert res.halted.all()
    np.testing.assert_array_equal(res.out, w.ref(mems[:, :w.n_inputs]))
