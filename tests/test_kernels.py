"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as R
from repro.kernels.bitplane_matmul import bitplane_matmul


@pytest.mark.parametrize("bits", [1, 4, 8])
@pytest.mark.parametrize("shape", [(128, 128, 128), (256, 128, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bitplane_matmul(bits, shape, dtype):
    m, k, n = shape
    key = jax.random.key(bits + m)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (m, k), dtype)
    w = jax.random.normal(kw, (k, n), jnp.float32) * 0.1
    planes, scales, _ = R.quantize_weights(w, bits)
    got = bitplane_matmul(x, planes, scales, bits=bits, interpret=True)
    want = R.bitplane_matmul_ref(x, planes, scales, bits=bits)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_bitplane_quantization_error_shrinks_with_bits():
    key = jax.random.key(0)
    w = jax.random.normal(key, (128, 128)) * 0.2
    x = jax.random.normal(jax.random.key(1), (128, 128))
    exact = x @ w
    errs = []
    for bits in (2, 4, 8):
        planes, scales, _ = R.quantize_weights(w, bits)
        approx = R.bitplane_matmul_ref(x, planes, scales, bits=bits)
        errs.append(float(jnp.abs(approx - exact).mean()))
    assert errs[0] > errs[1] > errs[2], errs


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("shape", [(2, 256, 64), (1, 512, 128)])
def test_flash_attention(causal, shape):
    bh, l, d = shape
    keys = jax.random.split(jax.random.key(l), 3)
    q = jax.random.normal(keys[0], (bh, l, d), jnp.float32)
    k = jax.random.normal(keys[1], (bh, l, d), jnp.float32)
    v = jax.random.normal(keys[2], (bh, l, d), jnp.float32)
    from repro.kernels.flash_attention import flash_attention
    got = flash_attention(q, k, v, causal=causal, tq=128, tk=128,
                          interpret=True)
    want = R.attention_ref(q[:, None], k[:, None], v[:, None],
                           causal=causal)[:, 0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_gqa_flash_wrapper_matches_model_attention():
    from repro.models.layers import chunked_attention
    b, l, h, hkv, d = 2, 256, 8, 2, 32
    ks = jax.random.split(jax.random.key(7), 3)
    q = jax.random.normal(ks[0], (b, l, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, l, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, l, hkv, d), jnp.float32)
    got = ops.gqa_flash_attention(q, k, v, causal=True, tq=64, tk=64)
    want = chunked_attention(q, k, v, causal=True, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("shape", [(2, 4, 128, 32, 16), (1, 2, 256, 64, 32)])
def test_ssd_scan(shape):
    bt, h, l, p, n = shape
    ks = jax.random.split(jax.random.key(l), 4)
    x = jax.random.normal(ks[0], (bt, h, l, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, h, l)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bt, 1, l, n), jnp.float32) * 0.5
    C = jax.random.normal(jax.random.key(l + 1), (bt, 1, l, n),
                          jnp.float32) * 0.5
    got = ops.ssd(x, dt, A, B, C, q=64)
    Bh = jnp.broadcast_to(B, (bt, h, l, n))
    Ch = jnp.broadcast_to(C, (bt, h, l, n))
    want, _ = R.ssd_ref(x, dt, A, Bh, Ch)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_ssd_kernel_matches_model_ssd_chunked():
    """Kernel vs the model-side jnp implementation (different chunking)."""
    from repro.models.mamba import ssd_chunked
    bt, l, h, p, n = 2, 128, 4, 16, 8
    ks = jax.random.split(jax.random.key(3), 4)
    x = jax.random.normal(ks[0], (bt, l, h, p), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (bt, l, h)))
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    B = jax.random.normal(ks[3], (bt, l, 1, n), jnp.float32) * 0.5
    C = jax.random.normal(jax.random.key(9), (bt, l, 1, n),
                          jnp.float32) * 0.5
    want = ssd_chunked(x, dt, A, B, C, jnp.zeros(h), chunk=32)
    got = ops.ssd(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                  A, B.transpose(0, 2, 1, 3), C.transpose(0, 2, 1, 3),
                  q=32).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
