"""Resident packed runtime (DESIGN.md §9.9): on-device refill parity
with the PR-4 host-refill baseline (full state, three steppers), the
banked Pallas refill swap, adaptive-superstep determinism and
bit-exactness, sync-stats accounting, and the 4-device shard_map path."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from benchmarks.fleet import skew_fleet, skew_program
from repro.fleet import engine
from repro.fleet.engine import (PackedGroup, _SuperstepController,
                                run_packed)
from repro.fleet.plan import FleetGroup, FleetPlan, run_plan
from repro.flexibits import iss
from repro.kernels.iss_stepper import iss_refill

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_STATE_FIELDS = ("n_instr", "n_two_stage", "halted", "out", "mix",
                 "mems", "regs", "pc", "mix_items")


def _skew_groups(n_a=40, n_b=24, max_steps_b=100_000):
    prog = skew_program()
    mems_a = skew_fleet(prog, n_a, short_iters=8, long_iters=400,
                        long_frac=0.2, seed=13)
    mems_b = skew_fleet(prog, n_b, short_iters=16, long_iters=300,
                        long_frac=0.3, seed=14)
    return [
        PackedGroup(code=prog.code, source=engine.array_source(mems_a),
                    n_items=n_a, max_steps=100_000, mem_words=32,
                    out_addr=1),
        PackedGroup(code=prog.code, source=engine.array_source(mems_b),
                    n_items=n_b, max_steps=max_steps_b, mem_words=32,
                    out_addr=1),
    ]


@pytest.mark.parametrize("stepper", ["switch", "branchless", "pallas"])
def test_resident_bit_exact_with_host_refill(stepper):
    """Full-state parity: the resident runtime retires, demuxes, and
    keeps final state bit-exactly equal to the host-refill baseline —
    including a group whose budget, not halting, ends its items."""
    groups = _skew_groups(max_steps_b=200)
    host, _ = run_packed(groups, chunk=16, seg_steps=64, keep_state=True,
                         refill="host", stepper=stepper)
    res, stats = run_packed(groups, chunk=16, seg_steps=64,
                            keep_state=True, refill="device",
                            stepper=stepper)
    assert stats.refill == "device"
    for a, b in zip(host, res):
        for f in _STATE_FIELDS:
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                          err_msg=f)
        assert not a.halted[a.n_instr == 200].any()   # budget-exhausted


def test_resident_plan_report_matches_host_refill():
    """run_plan floats (carbon, energy, profiles) are identical between
    the resident and host-refill loops — the demux feeds the same
    report path bit-for-bit."""
    groups = (
        FleetGroup(workload="WQ", core="SERV", n_items=40, seed=1),
        FleetGroup(workload="MC", core="HERV", n_items=24, seed=2),
    )
    rep_d = run_plan(FleetPlan(groups=groups, chunk=16, seg_steps=128))
    rep_h = run_plan(FleetPlan(groups=groups, chunk=16, seg_steps=128,
                               refill="host"))
    assert rep_d.packed.refill == "device"
    assert rep_h.packed.refill == "host"
    for a, b in zip(rep_d.groups, rep_h.groups):
        np.testing.assert_array_equal(a.result.n_instr, b.result.n_instr)
        np.testing.assert_array_equal(a.result.mix, b.result.mix)
        assert a.profile == b.profile
        assert a.energy_j_per_exec == b.energy_j_per_exec
        assert a.total_kg == b.total_kg
    assert "sync stats (device-refill)" in rep_d.format()


@pytest.mark.parametrize("stepper", ["branchless", "pallas"])
def test_adaptive_supersteps_bit_exact_and_deterministic(stepper):
    """Same plan + seed: two adaptive runs produce the identical segment
    schedule and results; adaptive results are bit-exact with the fixed
    schedule; the schedule actually adapts (more than one rung used on
    a churny skewed fleet) and stays within the ladder."""
    groups = _skew_groups()
    kw = dict(chunk=16, seg_steps=64, keep_state=True, stepper=stepper)
    fixed, sf = run_packed(_skew_groups(), **kw)
    run1, s1 = run_packed(_skew_groups(), adaptive=True, **kw)
    run2, s2 = run_packed(groups, adaptive=True, **kw)
    assert s1.adaptive and s1.seg_schedule == s2.seg_schedule
    assert len(s1.seg_schedule) == s1.n_segments
    assert sf.seg_schedule == (64,) * sf.n_segments
    ladder = _SuperstepController(64, 16, True).ladder
    assert set(s1.seg_schedule) <= set(ladder)
    assert len(set(s1.seg_schedule)) > 1, "controller never adapted"
    for a, b, c in zip(fixed, run1, run2):
        for f in _STATE_FIELDS:
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                          err_msg=f)
            np.testing.assert_array_equal(getattr(b, f), getattr(c, f),
                                          err_msg=f)


def test_superstep_controller_ladder_and_policy():
    """The ladder is bounded (bounded retraces), capped at seg_steps,
    and the policy moves the right way: high observed churn shrinks the
    next segment, a quiet pool decays back to the cap."""
    c = _SuperstepController(4096, 256, True)
    assert c.ladder == (256, 512, 1024, 2048, 4096)
    assert c.next_seg() == 4096          # no signal yet -> cap
    for _ in range(4):
        c.record(n_retired=200, steps=256)   # heavy churn
    assert c.next_seg() == 256
    for _ in range(12):
        c.record(n_retired=0, steps=4096)    # long-tail quiet pool
    assert c.next_seg() == 4096
    assert c.schedule == [4096, 256, 4096]
    # disabled controller always returns the configured seg_steps
    off = _SuperstepController(4096, 256, False)
    off.record(n_retired=200, steps=256)
    assert off.next_seg() == 4096


def test_refill_take_assigns_staged_rows_in_lane_order():
    free = jnp.asarray([True, False, True, True, False, True])
    take, src = iss.refill_take(free, jnp.asarray(2, iss.I32))
    np.testing.assert_array_equal(
        np.asarray(take), [True, False, True, False, False, False])
    np.testing.assert_array_equal(np.asarray(src)[[0, 2]], [0, 1])
    # staged batch larger than the free set: every free lane takes
    take, src = iss.refill_take(free, jnp.asarray(6, iss.I32))
    np.testing.assert_array_equal(np.asarray(take), np.asarray(free))
    np.testing.assert_array_equal(np.asarray(src)[[0, 2, 3, 5]],
                                  [0, 1, 2, 3])


def test_pallas_refill_swap_matches_jnp_swap():
    """The banked Pallas compaction/scatter kernel (`iss_refill`) is
    bit-identical to the shared jnp helper (`iss.refill_lanes`) over a
    randomized pool + staged batch, including un-taken lanes."""
    rng = np.random.default_rng(7)
    n, m, s = 8, 16, 5
    lanes = iss.ISSState(
        regs=jnp.asarray(rng.integers(-9, 9, (n, 16)), iss.I32),
        pc=jnp.asarray(rng.integers(0, 64, n), iss.I32),
        mem=jnp.asarray(rng.integers(-99, 99, (n, m)), iss.I32),
        halted=jnp.asarray(rng.random(n) < 0.5),
        n_instr=jnp.asarray(rng.integers(0, 50, n), iss.I32),
        n_two_stage=jnp.asarray(rng.integers(0, 20, n), iss.I32),
        mix=jnp.asarray(rng.integers(0, 9, (n, 8)), iss.I32),
        n_cycles=jnp.asarray(rng.integers(0, 999, n), iss.I32))
    ps = iss.PackedState(
        lanes=lanes,
        prog_id=jnp.asarray(rng.integers(0, 3, n), iss.I32),
        max_steps=jnp.asarray(rng.integers(1, 99, n), iss.I32))
    free = jnp.asarray(rng.random(n) < 0.6)
    take, src = iss.refill_take(free, jnp.asarray(s, iss.I32))
    smem = jnp.asarray(rng.integers(-99, 99, (n, m)), iss.I32)
    sprog = jnp.asarray(rng.integers(0, 3, n), iss.I32)
    sms = jnp.asarray(rng.integers(1, 99, n), iss.I32)
    a = iss.refill_lanes(ps, take, src, smem, sprog, sms)
    b = jax.jit(lambda *xs: iss_refill(*xs, lane_tile=4))(
        ps, take, src, smem, sprog, sms)
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_resident_syncs_fewer_than_host_refill():
    """On a churny skewed fleet the resident loop performs strictly
    fewer blocking host syncs (one small stats read per segment + one
    drain) than the host-refill loop (done-count scalar per segment +
    O(done) harvest pulls per finishing segment), and its sync stats
    are populated sanely."""
    _, sh = run_packed(_skew_groups(), chunk=16, seg_steps=64,
                       refill="host")
    _, sd = run_packed(_skew_groups(), chunk=16, seg_steps=64,
                       refill="device")
    assert sd.host_syncs < sh.host_syncs, (sd.host_syncs, sh.host_syncs)
    # one stats read per iteration (segments + trailing) + 5 drain pulls
    assert sd.host_syncs == sd.n_segments + 1 + 5
    for s in (sh, sd):
        assert s.sync_wait_s >= 0.0 and s.refill_wall_s >= 0.0
        assert 0.0 <= s.device_busy_frac <= 1.0
        assert len(s.seg_schedule) == s.n_segments


def test_run_packed_rejects_bad_refill():
    groups = _skew_groups()
    with pytest.raises(ValueError):
        run_packed(groups, refill="telepathy")


def test_resident_falls_back_to_host_past_safety_bounds():
    """Past the int32 mix-counter bound (a group that COULD retire 2^31
    instructions) or the keep_state device-row budget, the engine runs
    the host loop instead of overflowing/allocating silently — and says
    so in PackedStats.refill."""
    prog = skew_program()
    mems = skew_fleet(prog, 4, short_iters=4, long_iters=8,
                      long_frac=0.5, seed=1)
    big_budget = PackedGroup(code=prog.code,
                             source=engine.array_source(mems), n_items=4,
                             max_steps=2**30, mem_words=32, out_addr=1)
    res, stats = run_packed([big_budget], chunk=4, seg_steps=32)
    assert stats.refill == "host"
    assert res[0].halted.all()
    # a same-shape run under the bound stays resident
    ok = PackedGroup(code=prog.code, source=engine.array_source(mems),
                     n_items=4, max_steps=100_000, mem_words=32,
                     out_addr=1)
    _, stats = run_packed([ok], chunk=4, seg_steps=32)
    assert stats.refill == "device"


def test_resident_single_group_stream_parity():
    """run_stream (the single-group special case) is bit-exact between
    the resident and host-refill loops, including keep_state."""
    prog = skew_program()
    mems = skew_fleet(prog, 50, short_iters=8, long_iters=600,
                      long_frac=0.25, seed=3)
    kw = dict(n_items=50, mem_words=32, max_steps=100_000, chunk=16,
              seg_steps=64, out_addr=1, keep_state=True)
    a = engine.run_stream(prog.code, engine.array_source(mems),
                          refill="host", **kw)
    b = engine.run_stream(prog.code, engine.array_source(mems),
                          refill="device", **kw)
    c = engine.run_stream(prog.code, engine.array_source(mems),
                          refill="device", adaptive=True, **kw)
    for f in _STATE_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
        np.testing.assert_array_equal(getattr(a, f), getattr(c, f),
                                      err_msg=f)


@pytest.mark.slow
def test_resident_adaptive_sharded_multi_device_bit_exact():
    """Resident + adaptive streaming under shard_map on 4 forced host
    devices stays bit-exact with the host-refill baseline for all three
    steppers — FULL final state (mems/regs/pc/mix_items included) and
    per-group stats, not just the scalar tallies — and the adaptive
    schedule is identical across reruns. Shard-locally (§9.12): staged
    buffers shard per-device via `stage_shardings` (each device gets
    only its own slice), lane fields shard, the retire scatter lands in
    per-shard `ResidentAcc` row blocks, and the per-shard retired
    counts must cover every item with ONE host sync per segment.
    """
    script = r"""
import numpy as np, jax, json
from benchmarks.fleet import skew_fleet, skew_program
from repro.fleet import engine
from repro.fleet.engine import PackedGroup, run_packed
prog = skew_program()
mems_a = skew_fleet(prog, 40, short_iters=8, long_iters=400,
                    long_frac=0.2, seed=13)
mems_b = skew_fleet(prog, 24, short_iters=16, long_iters=300,
                    long_frac=0.3, seed=14)
groups = [
    PackedGroup(code=prog.code, source=engine.array_source(mems_a),
                n_items=40, max_steps=100_000, mem_words=32, out_addr=1),
    PackedGroup(code=prog.code, source=engine.array_source(mems_b),
                n_items=24, max_steps=100_000, mem_words=32, out_addr=1),
]
FIELDS = ("n_instr", "n_two_stage", "halted", "out", "mix",
          "mems", "regs", "pc", "mix_items")
refs, _ = run_packed(groups, chunk=16, seg_steps=64, refill="host",
                     keep_state=True)
mesh = jax.make_mesh((len(jax.devices()),), ("fleet",))
for stepper in ("branchless", "pallas", "switch"):
    scheds = []
    for _ in range(2):
        res, stats = run_packed(groups, chunk=16, seg_steps=64,
                                mesh=mesh, stepper=stepper,
                                refill="device", adaptive=True,
                                keep_state=True)
        assert stats.n_devices == 4, stats.n_devices
        assert stats.n_shards == 4, stats.n_shards
        assert sum(stats.shard_retired) == 64, stats.shard_retired
        assert sum(stats.shard_lane_steps) == stats.lane_steps
        assert stats.host_syncs == stats.n_segments + 1 + 9, stats
        scheds.append(stats.seg_schedule)
        for r, ref in zip(res, refs):
            assert r.n_items == ref.n_items
            assert r.n_segments > 0 and r.lane_steps > 0
            for f in FIELDS:
                np.testing.assert_array_equal(getattr(r, f),
                                              getattr(ref, f),
                                              err_msg=f"{stepper}:{f}")
    assert scheds[0] == scheds[1], (stepper, scheds)
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT, env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout.strip().splitlines()[-1])["ok"]
