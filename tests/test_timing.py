"""Cycle-accurate timing differential tests (DESIGN.md §9.10).

The per-lane timing layer must not disturb architectural state, and its
tick tallies must be *exact*: every stepper (legacy lax.switch,
branchless one-hot, fused Pallas segment — including the banked packed
runtime with on-device refill) is stepped in lockstep against the PyISS
cycle oracle on random instruction soups and on all 11 FlexiBench
workloads, comparing full architectural state AND per-lane cycle
counters bit-for-bit across all three core widths.

Also pins the Table-7 paper ratios under the timing layer's base case
(satellite of the same change): base-cost event pricing is *exactly*
the two-bucket analytic model, so the 3.15x/4.93x speedup and
2.65x/3.50x energy geomeans survive by construction.

`hypothesis` is optional (as in test_flexibits.py): without it the
single-instruction property test is skipped; the deterministic
spot-check fallbacks always run.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.flexibench.base import all_workloads
from repro.flexibits import isa, iss
from repro.flexibits.asm import Asm
from repro.flexibits.cycles import (CORES, N_COST, TAKEN_IDX,
                                    TICKS_PER_CYCLE, base_ticks, cost_row,
                                    event_cycles)
from repro.flexibits.pyiss import PyISS
from repro.fleet import engine

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

CORE_NAMES = ("SERV", "QERV", "HERV")
STEPPERS = ("switch", "branchless", "pallas")
MEM_WORDS = 128
# any u32 address in [MEM_WORDS*4, 2^31) exercises clamp-on-read /
# drop-on-write; bit-31 addresses are outside the contract (iss.py)
OOB_BASE = 2 ** 31 - 8192

R_NAMES = tuple(isa.R_OPS)
I_NAMES = ("addi", "slti", "sltiu", "xori", "ori", "andi")
SH_NAMES = tuple(isa.SHIFT_OPS)
MEM_NAMES = ("lw", "lh", "lb", "lhu", "lbu", "sw", "sh", "sb")
B_NAMES = tuple(isa.B_OPS)

_step_switch = jax.jit(lambda code, s, cost: iss.step(code, s, cost=cost))
_step_bl = jax.jit(
    lambda code, s, cost: iss.step_branchless(code, s, cost=cost))


def _u32(v):
    return int(v) & 0xFFFFFFFF


def _assert_state_matches(s, py, tag=""):
    """Full architectural state + cycle tally of one JAX state vs PyISS."""
    assert int(s.n_instr) == py.n_instr, tag
    assert int(s.n_two_stage) == py.n_two_stage, tag
    assert _u32(s.pc) == _u32(py.pc), tag
    np.testing.assert_array_equal(
        np.asarray(s.regs, np.int64), np.asarray(py.regs, np.int64),
        err_msg=tag)
    np.testing.assert_array_equal(
        np.asarray(s.mem, np.int64), py.mem, err_msg=tag)
    np.testing.assert_array_equal(
        np.asarray(s.mix, np.int64), py.events[:8] + py.events[8:16],
        err_msg=tag)
    assert int(s.n_cycles) == py.n_cycles, tag


# --------------------------------------------------------------- fixed point

def test_tick_table_exact_fixed_point():
    """Integer tick costs are EXACTLY TICKS_PER_CYCLE x the analytic
    per-instruction cycle counts — the SERV 38/70 anchors and the Table-7
    geomeans are preserved by construction, not by tolerance."""
    for core in CORES.values():
        one, two = base_ticks(core)
        assert one == TICKS_PER_CYCLE * core.cycles_one_stage()
        assert two == TICKS_PER_CYCLE * core.cycles_two_stage()
        base = cost_row(core)
        assert base.shape == (N_COST,)
        assert not base[TAKEN_IDX:].any()       # base case: no dynamic terms
        dyn = cost_row(core, dynamic=True)
        assert (dyn[TAKEN_IDX:] > 0).all()
        np.testing.assert_array_equal(base[:TAKEN_IDX], dyn[:TAKEN_IDX])
    assert base_ticks(CORES["SERV"]) == (760, 1400)     # 38 / 70 cycles


# ------------------------------------------------------------ lockstep steps

def _lockstep_program():
    """One program touching every opcode class and every dynamic timing
    event: taken + fall-through branches, varied serial shift amounts,
    subword RMW, jumps, upper immediates, and OOB clamp/drop accesses."""
    a = Asm(vm_reserved=MEM_WORDS * 4)
    a.li(3, 0)                        # in-range memory base
    a.li(4, OOB_BASE)                 # OOB base (clamp/drop)
    a.li(5, -3)
    a.li(6, 100)
    a.li(7, 0x1234_5678 - (1 << 32) // 2)
    a.lui(8, 0xABCDE)
    a.emit("auipc", 9, imm=0x7)
    a.add(10, 5, 6)
    a.sub(11, 6, 5)
    a.emit("xor", 12, 7, 8)
    a.emit("or", 13, 7, 8)
    a.emit("and", 14, 7, 8)
    a.emit("slt", 15, 5, 6)
    a.emit("sltu", 15, 5, 6)
    a.emit("slli", 10, 7, imm=1)
    a.emit("slli", 10, 7, imm=31)
    a.emit("srli", 11, 7, imm=17)
    a.emit("srai", 12, 5, imm=9)
    a.li(15, 13)
    a.emit("sll", 13, 6, 15)          # reg-amount shifts
    a.emit("srl", 13, 7, 15)
    a.emit("sra", 13, 5, 15)
    a.sw(7, 3, 16)
    a.emit("sh", 0, 3, 7, 18)         # subword RMW, unaligned half
    a.emit("sb", 0, 3, 8, 21)
    a.lw(10, 3, 16)
    a.emit("lh", 11, 3, imm=18)
    a.emit("lb", 12, 3, imm=21)
    a.emit("lhu", 11, 3, imm=18)
    a.emit("lbu", 12, 3, imm=21)
    a.lw(10, 4, 4)                    # OOB: clamps to last word
    a.emit("lbu", 11, 4, imm=7)
    a.sw(7, 4, 8)                     # OOB: dropped
    a.emit("sb", 0, 4, 7, 3)
    a.beq(5, 6, "skip1")              # not taken
    a.addi(14, 14, 1)
    a.label("skip1")
    a.blt(5, 6, "skip2")              # taken
    a.addi(14, 14, 2)
    a.label("skip2")
    a.bltu(5, 6, "skip3")             # -3 unsigned is huge: not taken
    a.addi(14, 14, 4)
    a.label("skip3")
    a.li(5, 0)                        # bounded backward loop (taken x3)
    a.label("loop")
    a.addi(5, 5, 1)
    a.emit("slti", 6, 5, imm=4)
    a.bne(6, 0, "loop")
    a.jal(1, "over")
    a.addi(14, 14, 8)
    a.label("over")
    a.jalr(2, 1, 8)                   # link reg + 8 = the next instruction
    for r in range(16):
        a.sw(r, 3, 4 * r)
    a.halt()
    return a.assemble()


@pytest.mark.parametrize("core_name", CORE_NAMES)
def test_single_step_lockstep(core_name):
    """iss.step and iss.step_branchless vs the oracle after EVERY retired
    instruction — state and cycle tally, dynamic cost row."""
    prog = _lockstep_program()
    cost = cost_row(CORES[core_name], dynamic=True)
    mem0 = prog.initial_memory(MEM_WORDS)
    py = PyISS(prog.code, MEM_WORDS, mem0, cost=cost)
    code = jnp.asarray(prog.code.view(np.int32))
    costj = jnp.asarray(cost)
    s_sw = iss.init_state(jnp.asarray(mem0))
    s_bl = s_sw
    for n in range(500):
        if py.halted:
            break
        py.step()
        s_sw = _step_switch(code, s_sw, costj)
        s_bl = _step_bl(code, s_bl, costj)
        _assert_state_matches(s_sw, py, f"switch step {n}")
        _assert_state_matches(s_bl, py, f"branchless step {n}")
    assert py.halted and bool(s_sw.halted) and bool(s_bl.halted)
    assert py.events[TAKEN_IDX] >= 4          # the soup really branched
    assert py.n_cycles > 0


# --------------------------------------------------- random instruction soups

def _timing_soup(rng):
    """Random halting program over the full ISA: forward branches, a
    bounded backward loop, subword + OOB memory traffic, jumps."""
    a = Asm(vm_reserved=MEM_WORDS * 4)
    a.li(3, 0)
    a.li(4, OOB_BASE)
    for r in range(5, 16):
        a.li(r, int(rng.integers(-2 ** 31, 2 ** 31)))
    a.li(5, 0)
    a.li(6, int(rng.integers(3, 9)))
    a.label("loop")
    a.addi(5, 5, 1)
    a.blt(5, 6, "loop")
    kinds = ("r", "i", "sh", "mem", "br", "jal", "ui")
    for i in range(int(rng.integers(30, 80))):
        kind = str(rng.choice(kinds))
        rd = int(rng.integers(5, 16))
        rs1 = int(rng.integers(0, 16))
        rs2 = int(rng.integers(0, 16))
        if kind == "r":
            a.emit(str(rng.choice(R_NAMES)), rd, rs1, rs2)
        elif kind == "i":
            a.emit(str(rng.choice(I_NAMES)), rd, rs1,
                   imm=int(rng.integers(-2048, 2048)))
        elif kind == "sh":
            a.emit(str(rng.choice(SH_NAMES)), rd, rs1,
                   imm=int(rng.integers(0, 32)))
        elif kind == "mem":
            name = str(rng.choice(MEM_NAMES))
            base = 4 if rng.random() < 0.25 else 3
            off = int(rng.integers(0, MEM_WORDS * 4 - 4))
            if name[0] == "s":
                a.emit(name, 0, base, rs2, off)
            else:
                a.emit(name, rd, base, imm=off)
        elif kind == "br":
            lbl = f"fwd{i}"
            getattr(a, str(rng.choice(B_NAMES)))(rs1, rs2, lbl)
            a.emit(str(rng.choice(I_NAMES)), int(rng.integers(5, 16)), rs1,
                   imm=int(rng.integers(-2048, 2048)))
            a.label(lbl)
        elif kind == "jal":
            lbl = f"j{i}"
            a.jal(rd, lbl)
            a.addi(int(rng.integers(5, 16)), 0, 1)
            a.label(lbl)
        elif rng.random() < 0.5:
            a.lui(rd, int(rng.integers(0, 1 << 20)))
        else:
            a.emit("auipc", rd, imm=int(rng.integers(0, 1 << 20)))
    for r in range(16):
        a.sw(r, 3, 4 * r)
    a.halt()
    return a.assemble()


@functools.lru_cache(maxsize=None)
def _soup_fixture():
    """(prog, mem0, core_name, cost, oracle) per soup — cores round-robin
    so one packed run exercises per-group heterogeneous cost rows."""
    out = []
    for i in range(6):
        prog = _timing_soup(np.random.default_rng(1000 + i))
        cost = cost_row(CORES[CORE_NAMES[i % 3]], dynamic=True)
        mem0 = prog.initial_memory(MEM_WORDS)
        py = PyISS(prog.code, MEM_WORDS, mem0, cost=cost).run(4096)
        assert py.halted
        out.append((prog, mem0, cost, py))
    return out


def _check_packed_vs_oracle(results, oracles, mem_words_of):
    for g, (res, py) in enumerate(zip(results, oracles)):
        mw = mem_words_of(g)
        assert res.n_cycles is not None
        for i in range(res.n_items):
            tag = f"group {g} item {i}"
            assert bool(res.halted[i]), tag
            assert int(res.n_instr[i]) == py.n_instr, tag
            assert _u32(res.pc[i]) == _u32(py.pc), tag
            np.testing.assert_array_equal(
                np.asarray(res.regs[i], np.int64),
                np.asarray(py.regs, np.int64), err_msg=tag)
            np.testing.assert_array_equal(
                np.asarray(res.mems[i][:mw], np.int64), py.mem[:mw],
                err_msg=tag)
            assert int(res.n_cycles[i]) == py.n_cycles, tag


@pytest.mark.parametrize("stepper", STEPPERS)
def test_soup_differential(stepper):
    """Whole random programs through the packed fleet runtime (banked
    fetch, on-device refill): final state + per-lane cycle tallies equal
    the oracle for every item, heterogeneous cost rows in one bank."""
    oracles = _soup_fixture()
    groups = [engine.PackedGroup(
        code=prog.code,
        source=engine.array_source(np.broadcast_to(
            mem0, (2, MEM_WORDS)).copy()),
        n_items=2, max_steps=4096, mem_words=MEM_WORDS, cost=cost)
        for (prog, mem0, cost, _) in oracles]
    results, _ = engine.run_packed(groups, chunk=8, seg_steps=256,
                                   keep_state=True, stepper=stepper)
    _check_packed_vs_oracle(results, [py for *_, py in oracles],
                            lambda g: MEM_WORDS)


# -------------------------------------------------- all FlexiBench workloads

@functools.lru_cache(maxsize=None)
def _workload_fixture():
    """Per (workload, item) oracle runs with dynamic cost rows, cores
    round-robin across the 11 workloads; inputs are the engine's own
    stream items (workload_source) so the packed run sees identical
    memory images."""
    n = 2
    ws = all_workloads()
    fixture = []
    for i, w in enumerate(ws):
        cost = cost_row(CORES[CORE_NAMES[i % 3]], dynamic=True)
        mems = np.asarray(engine.workload_source(w, seed=0)(0, n), np.int32)
        pys = []
        for j in range(n):
            py = PyISS(w.program.code, w.total_mem_words, mems[j],
                       cost=cost).run(w.max_steps)
            assert py.halted, w.key
            pys.append(py)
        fixture.append((w, cost, mems, pys))
    return fixture


@pytest.mark.parametrize("stepper", STEPPERS)
def test_workload_differential(stepper):
    """All 11 FlexiBench workloads in ONE packed bank per stepper: out
    words, full final state, and per-lane cycle tallies all equal the
    PyISS oracle, per item."""
    fixture = _workload_fixture()
    groups = [engine.PackedGroup(
        code=w.program.code, source=engine.array_source(mems),
        n_items=len(mems), max_steps=w.max_steps,
        mem_words=w.total_mem_words, out_addr=w.out_addr, cost=cost)
        for (w, cost, mems, _) in fixture]
    results, _ = engine.run_packed(groups, chunk=16, seg_steps=128,
                                   keep_state=True, stepper=stepper)
    for res, (w, _, _, pys) in zip(results, fixture):
        for j, py in enumerate(pys):
            assert int(res.out[j]) == int(np.int32(py.mem[w.out_addr])), \
                (w.key, j)
            assert int(res.n_instr[j]) == py.n_instr, (w.key, j)
            assert int(res.n_cycles[j]) == py.n_cycles, (w.key, j)
            np.testing.assert_array_equal(
                np.asarray(res.mems[j][:w.total_mem_words], np.int64),
                py.mem, err_msg=f"{w.key} item {j}")


# -------------------------------------------- single-instruction properties

def _single_instr_check(name, rd, rs1, rs2, a_val, b_val, imm):
    """One decoded instruction on a fresh state: PyISS vs step_branchless,
    full state + tick tally on every core (dynamic rows)."""
    code = np.array([isa.encode(name, rd, rs1, rs2, imm),
                     isa.encode("ecall")], np.uint32)
    mem0 = (np.arange(MEM_WORDS, dtype=np.int64) * 2654435761) \
        .astype(np.int32)
    regs = np.zeros(16, np.int64)
    if rs2 != 0:
        regs[rs2] = np.int32(b_val)
    if rs1 != 0:
        regs[rs1] = np.int32(a_val)        # rs1 wins on alias (addressing)
    codej = jnp.asarray(code.view(np.int32))
    for cname in CORE_NAMES:
        cost = cost_row(CORES[cname], dynamic=True)
        py = PyISS(code, MEM_WORDS, mem0, cost=cost)
        py.regs = [int(v) for v in regs]
        py.step()
        s0 = iss.init_state(jnp.asarray(mem0))._replace(
            regs=jnp.asarray(regs, iss.I32))
        s1 = _step_bl(codej, s0, jnp.asarray(cost))
        _assert_state_matches(s1, py, f"{name} on {cname}")


def _draw_operands(rng, name):
    rd = int(rng.integers(0, 16))
    rs1 = int(rng.integers(0, 16))
    rs2 = int(rng.integers(0, 16))
    imm = int(rng.integers(0, 32)) if name in isa.SHIFT_OPS \
        else int(rng.integers(-2048, 2048))
    b_val = int(rng.integers(-2 ** 31, 2 ** 31))
    if name in isa.S_OPS or (name in isa.I_OPS and name[0] == "l"):
        # target address in [0, 2^31): in-range or OOB clamp/drop zone
        addr = int(rng.integers(0, MEM_WORDS * 4)) if rng.random() < 0.5 \
            else int(rng.integers(MEM_WORDS * 4, 2 ** 31 - 4096))
        a_val = (addr - imm) & 0xFFFFFFFF
        if a_val >= 1 << 31:
            a_val -= 1 << 32
        if rs1 == 0:                  # x0 base: keep the address valid
            a_val, imm = 0, int(rng.integers(0, 2048))
    else:
        a_val = int(rng.integers(-2 ** 31, 2 ** 31))
    return rd, rs1, rs2, int(a_val), b_val, imm


if HAVE_HYPOTHESIS:
    @st.composite
    def single_instr(draw):
        name = draw(st.sampled_from(isa.ALL_OPS))
        seed = draw(st.integers(0, 2 ** 31 - 1))
        return (name,) + _draw_operands(np.random.default_rng(seed), name)

    @hypothesis.settings(max_examples=40, deadline=None)
    @hypothesis.given(single_instr())
    def test_single_instruction_matches_oracle(case):
        _single_instr_check(*case)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_single_instruction_matches_oracle():
        pass


def test_single_instruction_spot_checks():
    """Deterministic fallback: every opcode (incl. ecall/ebreak, x0
    destinations, OOB addresses) through the same differential check."""
    rng = np.random.default_rng(7)
    for name in isa.ALL_OPS:
        for _ in range(3):
            _single_instr_check(name, *_draw_operands(rng, name))
    # pinned edges: x0 write, OOB load clamp, OOB store drop
    _single_instr_check("addi", 0, 5, 0, 99, 0, 123)
    _single_instr_check("lw", 6, 5, 0, OOB_BASE, 0, 16)
    _single_instr_check("sb", 0, 5, 7, OOB_BASE, -1, 5)


# --------------------------------------------------------- Table-7 ratio pins

def test_base_event_pricing_equals_analytic():
    """Base-cost event pricing is the two-bucket analytic model; dynamic
    pricing is strictly costlier (it only adds nonnegative terms)."""
    from benchmarks.common import device_profile
    for w in all_workloads():
        prof = device_profile(w.key)
        for core in CORES.values():
            want = core.cycles(prof.n_one_stage, prof.n_two_stage)
            got = event_cycles(prof.events, core, dynamic=False)
            assert got == pytest.approx(want, rel=1e-12), (w.key, core.name)
            assert event_cycles(prof.events, core, dynamic=True) > got


def test_table7_geomeans_pinned():
    """Paper Table-7/Fig-9 ratios under the timing layer's base case:
    geomean speedups 3.15x (QERV) / 4.93x (HERV), energy gains
    2.65x / 3.50x."""
    from benchmarks.paper_tables import table7_fig9_ppa
    _, derived = table7_fig9_ppa()
    paper = derived["paper"]
    assert paper == {"qerv_speedup": 3.15, "herv_speedup": 4.93,
                     "qerv_energy": 2.65, "herv_energy": 3.50}
    assert derived["qerv_speedup_geomean"] == \
        pytest.approx(paper["qerv_speedup"], rel=0.06)
    assert derived["herv_speedup_geomean"] == \
        pytest.approx(paper["herv_speedup"], rel=0.06)
    assert derived["qerv_energy_gain_geomean"] == \
        pytest.approx(paper["qerv_energy"], rel=0.06)
    assert derived["herv_energy_gain_geomean"] == \
        pytest.approx(paper["herv_energy"], rel=0.06)
