"""Carbon-model tests: monotonicity, crossovers, paper anchors.

`hypothesis` is optional (see requirements-dev.txt): without it the
property tests are skipped and the anchor/deterministic tests still run.
"""
import numpy as np
import pytest

from repro.core import carbon as C
from repro.core.scale import (breakeven_effectiveness, savings_kg, table5)
from repro.core.selection import (crossover_lifetime_s, optimal_core,
                                  selection_map)
from repro.flexibits.cycles import CORES, HERV, QERV, SERV

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

PROF = C.DeviceProfile(n_one_stage=30_000, n_two_stage=20_000, vm_kb=0.6,
                       nvm_kb=3.3)


def _check_total_carbon_monotone_in_lifetime(days, freq):
    for core in CORES.values():
        a = C.total_kg(core, PROF, lifetime_s=days * 86400,
                       execs_per_day=freq)
        b = C.total_kg(core, PROF, lifetime_s=2 * days * 86400,
                       execs_per_day=freq)
        assert b > a


def _check_savings_linear_and_breakeven_consistent(fp, eff):
    be = breakeven_effectiveness(fp)
    s = savings_kg(fp, eff)
    if eff > be * 1.01:
        assert s > 0
    if eff < be * 0.99:
        assert s < 0


if HAVE_HYPOTHESIS:
    @hypothesis.settings(max_examples=25, deadline=None)
    @hypothesis.given(st.floats(1, 2000), st.floats(0.1, 1e4))
    def test_total_carbon_monotone_in_lifetime(days, freq):
        _check_total_carbon_monotone_in_lifetime(days, freq)

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(st.floats(0.001, 3.0), st.floats(0.0, 1.0))
    def test_savings_linear_and_breakeven_consistent(fp, eff):
        _check_savings_linear_and_breakeven_consistent(fp, eff)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_total_carbon_monotone_in_lifetime():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_savings_linear_and_breakeven_consistent():
        pass


def test_total_carbon_monotone_spot_checks():
    """Deterministic fallback for the hypothesis monotonicity property."""
    for days, freq in ((1.0, 0.5), (30.0, 24.0), (1500.0, 8000.0)):
        _check_total_carbon_monotone_in_lifetime(days, freq)


def test_savings_breakeven_spot_checks():
    """Deterministic fallback for the hypothesis savings property."""
    for fp, eff in ((0.002, 0.9), (1.0, 0.1), (2.5, 0.7)):
        _check_savings_linear_and_breakeven_consistent(fp, eff)


def test_short_lifetime_prefers_serv_long_prefers_herv():
    short, _ = optimal_core(PROF, lifetime_s=86400.0, execs_per_day=1)
    long_, _ = optimal_core(PROF, lifetime_s=20 * 365 * 86400.0,
                            execs_per_day=10_000)
    assert short.name == "SERV"
    assert long_.name == "HERV"


def test_selection_map_monotone_boundaries():
    """Once the map switches away from SERV along increasing lifetime it
    never switches back (operational carbon accumulates monotonically)."""
    lifetimes = np.logspace(np.log10(86400.0), np.log10(20 * 365 * 86400),
                            60)
    freqs = np.logspace(0, 5, 20)
    m = selection_map(PROF, lifetimes, freqs)
    for col in m.T:
        assert np.all(np.diff(col) >= 0), col


def test_crossover_formula_agrees_with_grid():
    x = crossover_lifetime_s(PROF, SERV, HERV, execs_per_day=100)
    assert np.isfinite(x) and x > 0
    before, _ = optimal_core(PROF, lifetime_s=x * 0.5, execs_per_day=100,
                             cores=[SERV, HERV])
    after, _ = optimal_core(PROF, lifetime_s=x * 2.0, execs_per_day=100,
                            cores=[SERV, HERV])
    assert before.name == "SERV" and after.name == "HERV"


def test_energy_source_scaling():
    hi = C.operational_kg(SERV, PROF, lifetime_s=1e7, execs_per_day=10,
                          intensity=C.ENERGY_SOURCES["coal"])
    lo = C.operational_kg(SERV, PROF, lifetime_s=1e7, execs_per_day=10,
                          intensity=C.ENERGY_SOURCES["wind"])
    assert hi / lo == C.ENERGY_SOURCES["coal"] / C.ENERGY_SOURCES["wind"]


def test_table5_anchors():
    t = table5()
    assert abs(1 / t["flexible"]["breakeven"] - 417) < 10     # paper 1/417
    assert abs(1 / t["hybrid"]["breakeven"] - 35) < 1.5       # paper 1/35
    assert abs(100 * t["silicon"]["breakeven"] - 59.18) < 0.5
    # savings at 100% effectiveness ~ 5.3e10 kg
    assert abs(t["flexible"]["savings_kg"][1.0] - 5.3e10) < 2e9
