"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs. (Deliverable f.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.model import build_model, input_specs
from repro.configs.base import ShapeConfig


def _batch_for(cfg, b=2, l=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, l)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (b, l)),
                               jnp.int32),
        "mask": jnp.ones((b, l), jnp.float32),
    }
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_audio_frames, cfg.d_model)),
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_loss(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch_for(cfg)
    loss, metrics = jax.jit(model.loss_fn)(params, batch)
    assert np.isfinite(float(loss)), (arch, metrics)
    assert float(loss) > 0


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_no_nans(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    batch = _batch_for(cfg)

    @jax.jit
    def step(p, b):
        (loss, _), grads = jax.value_and_grad(model.loss_fn,
                                              has_aux=True)(p, b)
        return loss, grads

    loss, grads = step(params, batch)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    b, l, cap = 2, 16, 32
    batch = _batch_for(cfg, b, l)
    prompt = {k: v[:, :l] if k in ("tokens",) else v
              for k, v in batch.items() if k != "targets" and k != "mask"}
    logits, cache = jax.jit(
        lambda p, bt: model.prefill_fn(p, bt, cap))(params, prompt)
    assert logits.shape[0] == b and logits.shape[1] == 1
    assert np.all(np.isfinite(np.asarray(logits[..., :cfg.vocab],
                                         np.float32)))
    tok = jnp.argmax(logits[..., :cfg.vocab], -1).astype(jnp.int32)
    logits2, cache = jax.jit(model.decode_fn)(params, cache, tok,
                                              jnp.int32(l))
    assert logits2.shape[:2] == (b, 1)
    assert np.all(np.isfinite(np.asarray(logits2[..., :cfg.vocab],
                                         np.float32)))
