"""Loop-aware HLO analyzer: trip-count multiplication, dot FLOPs, and
roofline-term arithmetic validated on small compiled programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo, op_counts
from repro.launch.roofline import (HBM_BW, PEAK_FLOPS, roofline_terms)


def test_dot_flops_exact():
    m, k, n = 64, 128, 32

    @jax.jit
    def f(a, b):
        return a @ b

    hlo = f.lower(jnp.zeros((m, k), jnp.float32),
                  jnp.zeros((k, n), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["flops_per_device"] == 2 * m * k * n


def test_scan_multiplies_flops_by_trip_count():
    m = 32
    w = jnp.eye(m, dtype=jnp.float32)

    def one(x, _):
        return x @ w, None

    @jax.jit
    def f(x):
        y, _ = jax.lax.scan(one, x, None, length=17)
        return y

    hlo = f.lower(jnp.zeros((m, m), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["flops_per_device"] == 17 * 2 * m * m * m
    assert r["unknown_trip_counts"] == 0


def test_bytes_scale_with_loop():
    m = 128

    @jax.jit
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 1.0001, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    hlo = f.lower(jnp.zeros((m, m), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    # at least 10 x (read + write) of the (m, m) buffer
    assert r["bytes_per_device"] >= 10 * 2 * m * m * 4


# Synthetic scheduled-HLO module with hand-computable totals: pins the
# three analyzer quantities fixed in PR 2 (dot contracting-dim FLOPs from
# inline-typed operands, while-body trip-count multiplication for both
# FLOPs and bytes) to closed-form values, independent of XLA codegen.
_SYNTH_HLO = """\
HloModule pinned, is_scheduled=true

%body.1 (arg.1: (s32[], f32[8,16], f32[16,4])) -> (s32[], f32[8,16], f32[16,4]) {
  %arg.1 = (s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) parameter(0)
  %gte.0 = s32[] get-tuple-element((s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) %arg.1), index=0
  %gte.1 = f32[8,16]{1,0} get-tuple-element((s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) %arg.1), index=1
  %gte.2 = f32[16,4]{1,0} get-tuple-element((s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) %arg.1), index=2
  %dot.1 = f32[8,4]{1,0} dot(f32[8,16]{1,0} %gte.1, f32[16,4]{1,0} %gte.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %c.1 = s32[] constant(1)
  %add.1 = s32[] add(s32[] %gte.0, s32[] %c.1)
  ROOT %tuple.1 = (s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) tuple(s32[] %add.1, f32[8,16]{1,0} %gte.1, f32[16,4]{1,0} %gte.2)
}

%cond.1 (arg.2: (s32[], f32[8,16], f32[16,4])) -> pred[] {
  %arg.2 = (s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) parameter(0)
  %gte.3 = s32[] get-tuple-element((s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) %arg.2), index=0
  %c.2 = s32[] constant(5)
  ROOT %lt.1 = pred[] compare(s32[] %gte.3, s32[] %c.2), direction=LT
}

ENTRY %main.1 (p0.1: f32[8,16], p1.1: f32[16,4]) -> f32[8,4] {
  %p0.1 = f32[8,16]{1,0} parameter(0)
  %p1.1 = f32[16,4]{1,0} parameter(1)
  %c.3 = s32[] constant(0)
  %tuple.2 = (s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) tuple(s32[] %c.3, f32[8,16]{1,0} %p0.1, f32[16,4]{1,0} %p1.1)
  %while.1 = (s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) while((s32[], f32[8,16]{1,0}, f32[16,4]{1,0}) %tuple.2), condition=%cond.1, body=%body.1, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %dot.2 = f32[8,4]{1,0} dot(f32[8,16]{1,0} %p0.1, f32[16,4]{1,0} %p1.1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_pinned_dot_flops_closed_form():
    """dot FLOPs = 2*m*k*n from inline-typed operands, and while bodies
    multiply by known_trip_count: 5 body dots + 1 entry dot."""
    r = analyze_hlo(_SYNTH_HLO)
    one_dot = 2 * 8 * 16 * 4
    assert r["flops_per_device"] == (5 + 1) * one_dot
    assert r["unknown_trip_counts"] == 0


def test_pinned_loop_bytes_closed_form():
    """Loop bytes scale by trip count. Per body iteration: dot.1
    (result 8*4 + operands 8*16 + 16*4) + add.1 (3 scalars) floats;
    entry: dot.2 the same + while.1 (result tuple + operand tuple)."""
    r = analyze_hlo(_SYNTH_HLO)
    dot_bytes = 4 * (8 * 4 + 8 * 16 + 16 * 4)
    body_bytes = dot_bytes + 4 * 3
    while_state = 4 * (1 + 8 * 16 + 16 * 4)
    entry_bytes = dot_bytes + 2 * while_state    # while result + operand
    assert r["bytes_per_device"] == 5 * body_bytes + entry_bytes


def test_pinned_trip_count_from_compiled_scan():
    """End-to-end pin on a real compiled scan: flops == trip * 2*m*m*m
    exactly (the regression fixed in PR 2: operand name lookups missed
    inline-typed operands, collapsing contracting dims to 1)."""
    m, trip = 8, 13
    w = jnp.eye(m, dtype=jnp.float32)

    @jax.jit
    def f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ w, None), x, None,
                            length=trip)
        return y

    hlo = f.lower(jnp.zeros((m, m), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["flops_per_device"] == trip * 2 * m * m * m
    assert r["unknown_trip_counts"] == 0


def test_op_counts_synthetic_closed_form():
    """Structural op counts on the pinned module: parameters are not
    ops, a while is one op of its caller, and its body's count is
    reported per trip."""
    r = op_counts(_SYNTH_HLO)
    assert r["entry"] == "main.1"
    # entry: c.3, tuple.2, while.1, dot.2 (2 parameters excluded)
    assert r["entry_ops"] == 4
    # body.1: gte x3, dot.1, c.1, add.1, tuple.1 (parameter excluded)
    assert r["computations"]["body.1"] == 7
    assert r["while_body_ops"] == {"body.1": 7}
    assert r["max_while_body_ops"] == 7


def test_fused_segment_top_level_collapse():
    """DESIGN.md §9.7 acceptance: the fused pallas segment module's top
    level holds >=10x fewer ops than the branchless step-loop body x
    seg_steps it replaces (the branchless while re-dispatches its whole
    step graph once per architectural step)."""
    from benchmarks.fleet import fleet_fusion_proof
    _, fp = fleet_fusion_proof(chunk=16, seg_steps=64)
    assert fp["branchless"]["step_while_body_ops"] > 0
    assert fp["pallas"]["entry_ops"] > 0
    assert fp["top_level_ratio"] >= 10.0


def test_roofline_terms_arithmetic():
    res = {"hlo": {"flops_per_device": PEAK_FLOPS,       # 1 s compute
                   "bytes_per_device": HBM_BW / 2,       # 0.5 s memory
                   "collective_bytes_per_device": 0.0},
           "model_flops": PEAK_FLOPS * 256 * 0.25,       # 0.25 s ideal
           "kind": "train"}
    r = roofline_terms(res, 256)
    assert r["bottleneck"] == "compute_s"
    np.testing.assert_allclose(r["bound_step_s"], 1.0)
    np.testing.assert_allclose(r["roofline_fraction"], 0.25)


def test_decode_fraction_uses_memory_floor():
    res = {"hlo": {"flops_per_device": 1e6,
                   "bytes_per_device": HBM_BW,           # 1 s memory
                   "collective_bytes_per_device": 0.0},
           "model_flops": 1e6,
           "param_bytes": HBM_BW * 64,                   # 0.25 s floor
           "cache_bytes": 0,
           "kind": "decode"}
    r = roofline_terms(res, 256)
    np.testing.assert_allclose(r["roofline_fraction"], 0.25)
