"""Loop-aware HLO analyzer: trip-count multiplication, dot FLOPs, and
roofline-term arithmetic validated on small compiled programs."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import (HBM_BW, PEAK_FLOPS, roofline_terms)


def test_dot_flops_exact():
    m, k, n = 64, 128, 32

    @jax.jit
    def f(a, b):
        return a @ b

    hlo = f.lower(jnp.zeros((m, k), jnp.float32),
                  jnp.zeros((k, n), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["flops_per_device"] == 2 * m * k * n


def test_scan_multiplies_flops_by_trip_count():
    m = 32
    w = jnp.eye(m, dtype=jnp.float32)

    def one(x, _):
        return x @ w, None

    @jax.jit
    def f(x):
        y, _ = jax.lax.scan(one, x, None, length=17)
        return y

    hlo = f.lower(jnp.zeros((m, m), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["flops_per_device"] == 17 * 2 * m * m * m
    assert r["unknown_trip_counts"] == 0


def test_bytes_scale_with_loop():
    m = 128

    @jax.jit
    def f(x):
        def body(c, _):
            return jnp.tanh(c) * 1.0001, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    hlo = f.lower(jnp.zeros((m, m), jnp.float32)).compile().as_text()
    r = analyze_hlo(hlo)
    # at least 10 x (read + write) of the (m, m) buffer
    assert r["bytes_per_device"] >= 10 * 2 * m * m * 4


def test_roofline_terms_arithmetic():
    res = {"hlo": {"flops_per_device": PEAK_FLOPS,       # 1 s compute
                   "bytes_per_device": HBM_BW / 2,       # 0.5 s memory
                   "collective_bytes_per_device": 0.0},
           "model_flops": PEAK_FLOPS * 256 * 0.25,       # 0.25 s ideal
           "kind": "train"}
    r = roofline_terms(res, 256)
    assert r["bottleneck"] == "compute_s"
    np.testing.assert_allclose(r["bound_step_s"], 1.0)
    np.testing.assert_allclose(r["roofline_fraction"], 0.25)


def test_decode_fraction_uses_memory_floor():
    res = {"hlo": {"flops_per_device": 1e6,
                   "bytes_per_device": HBM_BW,           # 1 s memory
                   "collective_bytes_per_device": 0.0},
           "model_flops": 1e6,
           "param_bytes": HBM_BW * 64,                   # 0.25 s floor
           "cache_bytes": 0,
           "kind": "decode"}
    r = roofline_terms(res, 256)
    np.testing.assert_allclose(r["roofline_fraction"], 0.25)
