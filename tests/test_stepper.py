"""Lane-parallel branchless stepper and fused-segment pallas stepper
(DESIGN.md §9.5/§9.6/§9.7): bit-exactness vs the lax.switch interpreter
over a randomized instruction soup covering every opcode class,
opcode-subset specialization, segment-loop parity, engine stepper A/B
parity, the async prefetcher, and sharded multi-device streaming."""
import json
import os
import subprocess
import sys
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.flexibits import isa, iss
from repro.kernels.iss_stepper import iss_segment

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
MEM_WORDS = 64


def _random_instr(rng, name):
    rd = int(rng.integers(0, 16))
    rs1 = int(rng.integers(0, 16))
    rs2 = int(rng.integers(0, 16))
    imm = int(rng.integers(-2048, 2048))
    if name in isa.SHIFT_OPS:
        imm = int(rng.integers(0, 32))
    elif name in isa.B_OPS or name == "jal":
        imm = int(rng.integers(-64, 64)) * 2
    elif name in ("lui", "auipc"):
        imm = int(rng.integers(0, 1 << 20))
    elif name in ("lb", "lh", "lw", "lbu", "lhu", "sb", "sh", "sw"):
        imm = int(rng.integers(0, MEM_WORDS * 4 - 4))
    return isa.encode(name, rd=rd, rs1=rs1, rs2=rs2, imm=imm)


def _random_state(rng, mem_like=False):
    regs = rng.integers(-2**31, 2**31, 16).astype(np.int64)
    if mem_like:     # keep addresses near the memory (including OOB edges)
        regs = np.abs(regs) % (MEM_WORDS * 2)
    regs[0] = 0
    mem = rng.integers(-2**31, 2**31, MEM_WORDS).astype(np.int64)
    s = iss.init_state(jnp.asarray(mem.astype(np.int32)))
    return s._replace(regs=jnp.asarray(regs.astype(np.int32)))


def _assert_state_equal(a: iss.ISSState, b: iss.ISSState, ctx=""):
    for f in iss.ISSState._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, f)), np.asarray(getattr(b, f)),
            err_msg=f"{ctx}: field {f}")


def test_branchless_step_bit_exact_instruction_soup():
    """Every opcode class x random fields x random state: step_branchless
    commits exactly what the lax.switch step commits."""
    rng = np.random.default_rng(7)
    step = jax.jit(iss.step)
    step_bl = jax.jit(iss.step_branchless)
    mem_ops = ("lb", "lh", "lw", "lbu", "lhu", "sb", "sh", "sw")
    for name in isa.ALL_OPS:
        for _ in range(8):
            word = _random_instr(rng, name)
            code = jnp.asarray(np.array([word], np.uint32).view(np.int32))
            s = _random_state(rng, mem_like=name in mem_ops)
            _assert_state_equal(step(code, s), step_bl(code, s),
                                ctx=f"{name} word={word:#010x}")


def test_step_lanes_bit_exact_batched_soup():
    """step_lanes over a lane batch == vmap(step), one random instruction
    per lane drawn from the full ISA."""
    rng = np.random.default_rng(11)
    lanes = len(isa.ALL_OPS)
    words = np.array([_random_instr(rng, n) for n in isa.ALL_OPS],
                     np.uint32)
    # each lane points at its own instruction in a shared program
    states = []
    for i in range(lanes):
        s = _random_state(rng)
        states.append(s._replace(pc=jnp.asarray(4 * i, iss.I32)))
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    code = jnp.asarray(words.view(np.int32))
    ref = jax.jit(jax.vmap(lambda s: iss.step(code, s)))(batched)
    got = jax.jit(lambda st: iss.step_lanes(code, st))(batched)
    _assert_state_equal(ref, got, ctx="batched soup")


def test_opcode_subset_is_sound_and_minimal():
    from repro.flexibench.base import get
    w = get("WQ")
    sub = iss.opcode_subset(w.program.code)
    # sound: every opcode the program retires is in the subset
    assert sub <= iss.FULL_SUBSET
    ops_in_text = {int(x) & 0x7F
                   for x in w.program.code.view(np.uint32).tolist()}
    assert {o for o in ops_in_text if o in iss.FULL_SUBSET} == set(sub)


def test_subset_specialized_segment_parity():
    """run_segment_lanes with the derived opcode subset retires the exact
    sequence of the full-ISA switch interpreter on a real workload."""
    from repro.flexibench.base import get
    from repro.flexibits.fleet import fleet_inputs
    w = get("MC")
    n = 12
    mems = fleet_inputs(w, n, seed=9)
    code = jnp.asarray(w.program.code.view(np.int32))
    sub = iss.opcode_subset(w.program.code)
    mono = iss.run_fleet(code, jnp.asarray(mems), w.max_steps)

    states = iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32),
        pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems),
        halted=jnp.zeros((n,), bool),
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
        n_cycles=jnp.zeros((n,), iss.I32),
    )
    seg = jax.jit(lambda c, st: iss.run_segment_lanes(
        c, st, 64, w.max_steps, sub))
    for _ in range(10_000):
        states = seg(code, states)
        if bool(np.asarray(states.halted).all()):
            break
    _assert_state_equal(states, mono, ctx="subset segment")


def test_segment_unroll_bit_exact():
    """Unrolled segment bodies mask sub-steps past seg_steps, so any
    (seg_steps, unroll) combination retires the same sequence."""
    from repro.flexibench.base import get
    from repro.flexibits.fleet import fleet_inputs
    w = get("WQ")
    mems = fleet_inputs(w, 6, seed=1)
    code = jnp.asarray(w.program.code.view(np.int32))
    states = iss.ISSState(
        regs=jnp.zeros((6, 16), iss.I32), pc=jnp.zeros((6,), iss.I32),
        mem=jnp.asarray(mems), halted=jnp.zeros((6,), bool),
        n_instr=jnp.zeros((6,), iss.I32),
        n_two_stage=jnp.zeros((6,), iss.I32),
        mix=jnp.zeros((6, len(iss.MIX_CLASSES)), iss.I32),
        n_cycles=jnp.zeros((6,), iss.I32))
    ref = jax.jit(lambda c, s: iss.run_segment_lanes(
        c, s, 37, w.max_steps))(code, states)
    got = jax.jit(lambda c, s: iss.run_segment_lanes(
        c, s, 37, w.max_steps, None, 8))(code, states)
    _assert_state_equal(ref, got, ctx="unroll=8 vs 1, seg_steps=37")
    assert int(np.asarray(got.n_instr).max()) <= 37


def test_pallas_segment_bit_exact_instruction_soup():
    """Every opcode class x random fields x random state: the fused
    pallas segment at seg_steps=1 commits exactly what step_lanes
    commits — including clamp-on-read / drop-on-write behavior at the
    OOB memory edges the mem-op lane states are biased toward."""
    rng = np.random.default_rng(21)
    mem_ops = ("lb", "lh", "lw", "lbu", "lhu", "sb", "sh", "sw")
    lanes = len(isa.ALL_OPS)
    for trial in range(6):
        words = np.array([_random_instr(rng, n) for n in isa.ALL_OPS],
                         np.uint32)
        states = []
        for i, name in enumerate(isa.ALL_OPS):
            s = _random_state(rng, mem_like=name in mem_ops)
            states.append(s._replace(pc=jnp.asarray(4 * i, iss.I32)))
        batched = jax.tree.map(lambda *xs: jnp.stack(xs), *states)
        code = jnp.asarray(words.view(np.int32))
        ref = jax.jit(lambda st: iss.step_lanes(code, st))(batched)
        # lane_tile < lanes exercises the lane-tile grid as well
        got = iss_segment(code, batched, seg_steps=1, max_steps=1 << 30,
                          lane_tile=max(1, lanes // 3))
        _assert_state_equal(ref, got, ctx=f"pallas soup trial {trial}")


def test_pallas_subset_segment_parity():
    """Fused segments with the derived opcode subset retire the exact
    sequence of the monolithic full-ISA interpreter on a real workload,
    across many segment boundaries and a tiled lane grid."""
    from repro.flexibench.base import get
    from repro.flexibits.fleet import fleet_inputs
    w = get("MC")
    n = 12
    mems = fleet_inputs(w, n, seed=9)
    code = jnp.asarray(w.program.code.view(np.int32))
    sub = iss.opcode_subset(w.program.code)
    mono = iss.run_fleet(code, jnp.asarray(mems), w.max_steps)

    states = iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32),
        pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems),
        halted=jnp.zeros((n,), bool),
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
        n_cycles=jnp.zeros((n,), iss.I32),
    )
    seg = jax.jit(lambda c, st: iss_segment(
        c, st, seg_steps=64, max_steps=w.max_steps, subset=sub,
        lane_tile=4))
    for _ in range(10_000):
        states = seg(code, states)
        if bool(np.asarray(states.halted).all()):
            break
    _assert_state_equal(states, mono, ctx="pallas subset segment")


def test_engine_stepper_ab_parity():
    """run_stream is bit-exact across all three steppers (switch,
    branchless, pallas), including full final state and the engine's
    lane-step accounting."""
    from benchmarks.fleet import skew_fleet, skew_program
    from repro.fleet import array_source, run_stream
    prog = skew_program()
    mems = skew_fleet(prog, 48, short_iters=8, long_iters=900,
                      long_frac=0.25, seed=5)
    kw = dict(n_items=48, mem_words=32, max_steps=100_000, chunk=16,
              seg_steps=64, out_addr=1, keep_state=True)
    a = run_stream(prog.code, array_source(mems), stepper="switch", **kw)
    assert a.stepper == "switch"
    for stepper in ("branchless", "pallas"):
        b = run_stream(prog.code, array_source(mems), stepper=stepper,
                       **kw)
        np.testing.assert_array_equal(a.mems, b.mems)
        np.testing.assert_array_equal(a.regs, b.regs)
        np.testing.assert_array_equal(a.n_instr, b.n_instr)
        np.testing.assert_array_equal(a.out, b.out)
        np.testing.assert_array_equal(a.mix, b.mix)
        assert a.lane_steps == b.lane_steps
        assert b.stepper == stepper


def test_prefetcher_preserves_stream_order():
    from repro.fleet.engine import _Prefetcher

    def source(start, count):
        return np.arange(start, start + count, dtype=np.int32)[:, None]

    for background in (True, False):
        pref = _Prefetcher(source, 103, block=16, background=background)
        got = np.concatenate([pref.take(7) for _ in range(14)]
                             + [pref.take(5)])
        np.testing.assert_array_equal(got[:, 0], np.arange(103))
        pref.close()


def test_pallas_prime_chunk_rounds_to_wide_tiles():
    """A prime chunk > 128 would tile at 1 lane/kernel; the engine pads
    the pallas lane pool up to a 128-multiple instead (inert padding
    lanes), staying bit-exact with branchless."""
    from benchmarks.fleet import skew_fleet, skew_program
    from repro.fleet import array_source, run_stream
    prog = skew_program()
    mems = skew_fleet(prog, 140, short_iters=8, long_iters=200,
                      long_frac=0.2, seed=3)
    kw = dict(n_items=140, mem_words=32, max_steps=100_000, chunk=131,
              seg_steps=64, out_addr=1)
    a = run_stream(prog.code, array_source(mems), stepper="branchless",
                   **kw)
    b = run_stream(prog.code, array_source(mems), stepper="pallas", **kw)
    assert b.chunk == 256 and a.chunk == 131
    np.testing.assert_array_equal(a.out, b.out)
    np.testing.assert_array_equal(a.n_instr, b.n_instr)


def test_prefetcher_exhaustion_reports_cursor_and_counts():
    """Over-draining the stream raises a diagnostic error naming the
    cursor, the requested count, and n_items (regression: the bare
    'source stream exhausted' gave nothing to debug a plan/source
    n_items mismatch with) — in both sync and background modes."""
    from repro.fleet.engine import _Prefetcher

    def source(start, count):
        return np.zeros((count, 1), np.int32)

    for background in (True, False):
        pref = _Prefetcher(source, 10, block=4, background=background)
        pref.take(7)
        with pytest.raises(RuntimeError) as exc:
            pref.take(5)
        msg = str(exc.value)
        assert "requested 5" in msg and "cursor 7" in msg
        assert "10 item(s)" in msg and "3 item(s) remaining" in msg
        pref.take(3)          # the remainder is still deliverable
        pref.close()


def test_prefetcher_close_drains_inflight_fetch():
    """close() must cancel or drain the background fetch: a leaked
    worker thread must never still be inside the source after close()
    returns (regression: shutdown(wait=False) left it running)."""
    from repro.fleet.engine import _Prefetcher
    lock = threading.Lock()
    running = [0]
    calls = []

    def source(start, count):
        with lock:
            running[0] += 1
        calls.append(start)
        time.sleep(0.2)
        with lock:
            running[0] -= 1
        return np.zeros((count, 1), np.int32)

    pref = _Prefetcher(source, 64, block=16, background=True)
    pref.close()
    assert running[0] == 0, "source still running after close()"
    assert pref._fut is None
    n_calls = len(calls)
    time.sleep(0.3)          # a cancelled future must never fire late
    assert len(calls) == n_calls <= 1


def test_prefetcher_surfaces_background_exception_with_context():
    """A source that dies inside the worker thread must fail the NEXT
    take() (not vanish with the future) with the source, item span, and
    stream cursor in the message; every later take() keeps failing with
    the original exception chained — in both background and sync modes."""
    from repro.fleet.engine import _Prefetcher

    class Boom(ValueError):
        pass

    def source(start, count):
        if start >= 4:
            raise Boom(f"payload for [{start}:{start + count})")
        return np.zeros((count, 1), np.int32)

    for background in (True, False):
        pref = _Prefetcher(source, 64, block=4, background=background)
        pref.take(4)             # first block is healthy
        with pytest.raises(RuntimeError) as exc:
            pref.take(4)         # consumes the poisoned fetch
        msg = str(exc.value)
        assert "[4:8)" in msg and "cursor" in msg and "source" in msg
        assert isinstance(exc.value.__cause__, Boom)
        with pytest.raises(RuntimeError, match="already failed") as exc2:
            pref.take(1)         # latched: the stream stays dead
        assert isinstance(exc2.value.__cause__, Boom)
        pref.close()


def test_prefetcher_close_is_idempotent():
    """close() on every engine exit path means it can run twice (e.g.
    once in an except block, once in finally) — the second call must be
    a no-op, and take() after close() must fail loudly, not fall back
    to a synchronous fetch."""
    from repro.fleet.engine import _Prefetcher

    def source(start, count):
        return np.zeros((count, 1), np.int32)

    for background in (True, False):
        pref = _Prefetcher(source, 16, block=4, background=background)
        pref.take(2)
        pref.close()
        pref.close()             # idempotent
        with pytest.raises(RuntimeError, match="closed"):
            pref.take(1)


def test_engine_prefetch_off_matches_on():
    from repro.flexibench.base import get
    from repro.fleet import run_workload_stream
    w = get("WQ")
    a = run_workload_stream(w, 20, seed=3, chunk=8, seg_steps=128,
                            prefetch=True)
    b = run_workload_stream(w, 20, seed=3, chunk=8, seg_steps=128,
                            prefetch=False)
    np.testing.assert_array_equal(a.out, b.out)
    np.testing.assert_array_equal(a.n_instr, b.n_instr)


@pytest.mark.slow
def test_sharded_multi_device_bit_exact():
    """shard_map streaming over 4 forced host devices stays bit-exact.

    jax pins the device count at first backend init, so this runs in a
    subprocess with --xla_force_host_platform_device_count."""
    script = r"""
import numpy as np, jax, jax.numpy as jnp, json
from benchmarks.fleet import skew_fleet, skew_program
from repro.fleet import array_source, run_stream
from repro.flexibits import iss
prog = skew_program()
mems = skew_fleet(prog, 64, short_iters=8, long_iters=400,
                  long_frac=0.2, seed=13)
mono = iss.run_fleet(jnp.asarray(prog.code.view(np.int32)),
                     jnp.asarray(mems), 100_000)
mesh = jax.make_mesh((len(jax.devices()),), ("fleet",))
for stepper in ("branchless", "pallas", "switch"):
    res = run_stream(prog.code, array_source(mems), n_items=64,
                     mem_words=32, max_steps=100_000, chunk=16,
                     seg_steps=64, out_addr=1, keep_state=True,
                     mesh=mesh, stepper=stepper)
    np.testing.assert_array_equal(res.mems, np.asarray(mono.mem))
    np.testing.assert_array_equal(res.n_instr, np.asarray(mono.n_instr))
    assert res.n_devices == 4, res.n_devices
print(json.dumps({"ok": True}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT, env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert json.loads(proc.stdout.strip().splitlines()[-1])["ok"]
