"""FlexiBench: every workload's assembly (on the oracle ISS) must equal its
functional reference on random inputs; memory profiles sane; Fig-6 algo
variants equivalent."""
import numpy as np
import pytest

from repro.flexibench.base import all_workloads, get
from repro.flexibench.memory import profile_memory
from repro.flexibits.pyiss import PyISS

WKEYS = [w.key for w in all_workloads()]


@pytest.mark.parametrize("key", WKEYS)
def test_asm_matches_reference(key):
    w = get(key)
    rng = np.random.default_rng(42)
    xs = w.gen_inputs(rng, 4)
    want = w.ref(xs)
    for x, exp in zip(xs, want):
        sim = PyISS(w.program.code, w.total_mem_words,
                    w.initial_memory(x)).run(w.max_steps)
        assert sim.halted, (key, "did not halt")
        assert int(np.int32(sim.mem[w.out_addr])) == int(exp), key


def test_workloads_end_to_end_packed_engine():
    """Every workload through the packed fleet engine (one bank, one
    stream): each item's out-word must equal the functional reference
    AND the PyISS oracle."""
    from repro.fleet import engine
    n = 2
    ws = all_workloads()
    groups, want = [], []
    for w in ws:
        rng = np.random.default_rng(42)
        xs = w.gen_inputs(rng, n)
        mems = np.stack([w.initial_memory(x) for x in xs]).astype(np.int32)
        refs = np.asarray(w.ref(xs), np.int64)
        oracle = []
        for m in mems:
            sim = PyISS(w.program.code, w.total_mem_words, m).run(w.max_steps)
            assert sim.halted, w.key
            oracle.append(int(np.int32(sim.mem[w.out_addr])))
        groups.append(engine.PackedGroup(
            code=w.program.code, source=engine.array_source(mems),
            n_items=n, max_steps=w.max_steps, mem_words=w.total_mem_words,
            out_addr=w.out_addr))
        want.append((w.key, refs, np.asarray(oracle, np.int64)))
    results, _ = engine.run_packed(groups, chunk=16, seg_steps=256)
    for res, (key, refs, oracle) in zip(results, want):
        assert res.halted.all(), key
        np.testing.assert_array_equal(res.out.astype(np.int64), refs,
                                      err_msg=key)
        np.testing.assert_array_equal(res.out.astype(np.int64), oracle,
                                      err_msg=key)


def test_eleven_workloads_ten_sdgs():
    ws = all_workloads()
    assert len(ws) == 11
    assert len({w.sdg for w in ws}) >= 10


def test_lifetime_heterogeneity_three_orders():
    ws = all_workloads()
    lts = [w.lifetime_s for w in ws]
    assert max(lts) / min(lts) >= 1000     # the paper's 1000x claim


def test_memory_profile_sane():
    w = get("HC")                           # NVM-heavy (tree tables)
    m = profile_memory(w)
    assert m["nvm_kb"] > 10
    assert 0 < m["vm_kb"] < 2
    wq = profile_memory(get("WQ"))
    assert wq["nvm_kb"] < 0.2               # threshold check is tiny


@pytest.mark.parametrize("name", ["LR", "DT-Small", "KNN-Small", "MLP"])
def test_spoilage_algo_asm_equivalence(name):
    from repro.flexibench.spoilage_algos import all_algos, gen_dataset
    algo = next(a for a in all_algos() if a.name == name)
    rng = np.random.default_rng(7)
    xs, _ = gen_dataset(rng, 3)
    mem_words = (algo.program.ro_base // 4 + len(algo.program.ro_words)
                 + max(algo.mem_words, 64))
    for x in xs:
        mem = algo.program.initial_memory(mem_words).copy()
        mem[:len(x)] = x
        sim = PyISS(algo.program.code, mem_words, mem).run(algo.max_steps)
        assert sim.halted
        assert int(np.int32(sim.mem[algo.out_addr])) == \
            int(algo.ref(x[None])[0])
