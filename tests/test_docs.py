"""DESIGN.md citation integrity: every `DESIGN.md §N[.M]` reference in
the codebase must resolve to a real section heading."""
import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent
CITE_RE = re.compile(r"DESIGN\.md\s*§(\d+(?:\.\d+)*)")
HEADING_RE = re.compile(r"^#+\s*§(\d+(?:\.\d+)*)\b", re.MULTILINE)
SCAN_DIRS = ("src", "tests", "benchmarks", "examples")


def _sections():
    text = (ROOT / "DESIGN.md").read_text()
    return set(HEADING_RE.findall(text))


def _citations():
    cites = []
    for d in SCAN_DIRS:
        for path in (ROOT / d).rglob("*.py"):
            for num in CITE_RE.findall(path.read_text()):
                cites.append((path.relative_to(ROOT), num))
    return cites


def test_design_md_exists_with_required_anchors():
    secs = _sections()
    # sections the codebase has historically cited + the fleet engine
    for anchor in ("2.1", "3", "4", "5", "8.2", "8.4", "8.5", "9"):
        assert anchor in secs, f"DESIGN.md is missing §{anchor}"


def test_every_design_citation_resolves():
    secs = _sections()
    cites = _citations()
    assert cites, "expected DESIGN.md citations in the codebase"
    missing = [(str(p), n) for p, n in cites if n not in secs]
    assert not missing, f"dangling DESIGN.md citations: {missing}"
