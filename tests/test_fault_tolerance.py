"""Fault-tolerance integration tests: checkpoint/restore exactness,
simulated-preemption resume, elastic re-mesh, data determinism, straggler
watchdog, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, host_batch
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import (compressed_allreduce,
                                           init_residuals)
from repro.launch.train import StragglerWatchdog, train_loop
from repro.models.model import build_model


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.int32), "d": jnp.zeros(())}}
    ckpt.save(str(tmp_path), 3, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in range(1, 6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert sorted(ckpt.all_steps(str(tmp_path))) == [4, 5]


@pytest.mark.slow
def test_preemption_resume_exact(tmp_path):
    """Train 6 steps straight vs 3 steps -> 'preempt' -> resume 3 more;
    final losses must match exactly (deterministic data + donated state)."""
    cfg = get_smoke_config("qwen2-1.5b")
    d1 = str(tmp_path / "a")
    d2 = str(tmp_path / "b")
    full = train_loop(cfg=cfg, steps=6, batch=4, seq=32, ckpt_dir=d1,
                      ckpt_every=3, log=lambda *a: None)
    train_loop(cfg=cfg, steps=3, batch=4, seq=32, ckpt_dir=d2,
               ckpt_every=3, log=lambda *a: None)
    resumed = train_loop(cfg=cfg, steps=6, batch=4, seq=32, ckpt_dir=d2,
                         ckpt_every=3, log=lambda *a: None)
    np.testing.assert_allclose(full["losses"][3:], resumed["losses"],
                               rtol=1e-5)


def test_elastic_restart_different_mesh(tmp_path):
    """Checkpoint from mesh A restores onto a differently-shaped mesh."""
    from repro.distributed.elastic import resume_elastic
    from repro.launch.steps import make_train_step
    cfg = get_smoke_config("minitron-8b")
    model = build_model(cfg)
    opt_init, _ = make_train_step(model)
    params = model.init_params(jax.random.key(0))
    opt = opt_init(params)
    ckpt.save(str(tmp_path), 7, {"params": params, "opt": opt})

    mesh_b = jax.make_mesh((1, 1), ("data", "model"))
    p2, o2, step = resume_elastic(str(tmp_path), model, opt_init, mesh_b)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_determinism_across_topologies():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8)
    whole = host_batch(cfg, step=5, host_id=0, n_hosts=1)
    parts = [host_batch(cfg, step=5, host_id=h, n_hosts=4)
             for h in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(whole["tokens"], glued)
    # and distinct across steps
    other = host_batch(cfg, step=6)
    assert not np.array_equal(whole["tokens"], other["tokens"])


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=2.0, warmup=3)
    for i in range(5):
        assert not w.observe(i, 1.0)
    assert w.observe(5, 3.5)
    assert w.flagged == [(5, 3.5)]


def test_compressed_allreduce_error_feedback():
    """EF-int8 all-reduce: single-step error bounded; residual carries the
    exact quantization error so the bias vanishes across steps."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(64, 64)).astype(np.float32))}
    r = init_residuals(g)
    mean, r2 = compressed_allreduce(g, r, mesh, axis="data")
    # n=1: mean should equal dequantized(g), residual the rounding error
    err = np.abs(np.asarray(mean["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert err.max() <= scale * 0.51 + 1e-6
    np.testing.assert_allclose(np.asarray(r2["w"]),
                               np.asarray(g["w"] - mean["w"]), atol=1e-6)
    # feeding back the residual recovers the lost mass
    mean2, _ = compressed_allreduce(
        jax.tree.map(jnp.zeros_like, g), r2, mesh, axis="data")
    recovered = np.asarray(mean["w"]) + np.asarray(mean2["w"])
    err2 = np.abs(recovered - np.asarray(g["w"]))
    assert err2.max() < err.max() + 1e-6
