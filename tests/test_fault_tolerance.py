"""Fault-tolerance integration tests: checkpoint/restore exactness,
simulated-preemption resume, elastic re-mesh, data determinism, straggler
watchdog, gradient compression, and the resident fleet stream's
checkpointable state (DESIGN.md §9.12): kill-and-resume bit-exactness,
including resume onto a differently-shaped mesh."""
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_smoke_config
from repro.data.pipeline import DataConfig, host_batch
from repro.distributed import checkpoint as ckpt
from repro.distributed.compression import (compressed_allreduce,
                                           init_residuals)
from repro.launch.train import StragglerWatchdog, train_loop
from repro.models.model import build_model


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones(5, jnp.int32), "d": jnp.zeros(())}}
    ckpt.save(str(tmp_path), 3, tree)
    restored, step = ckpt.restore(str(tmp_path), tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_keep_n(tmp_path):
    tree = {"x": jnp.zeros(2)}
    for s in range(1, 6):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    assert sorted(ckpt.all_steps(str(tmp_path))) == [4, 5]


def _flip_byte(path, offset):
    with open(path, "r+b") as f:
        f.seek(offset)
        b = f.read(1)
        f.seek(offset)
        f.write(bytes([b[0] ^ 0xFF]))


def test_checkpoint_crc_detects_bit_flip(tmp_path):
    """npz members are STORED (uncompressed): a flipped payload byte
    loads cleanly and only the per-leaf CRC32 catches it — the error
    names both the file and the damaged leaf (DESIGN.md §9.14)."""
    tree = {"a": jnp.arange(1024, dtype=jnp.float32)}
    ckpt.save(str(tmp_path), 3, tree)
    npz = str(tmp_path / "step_3" / "arrays.npz")
    _flip_byte(npz, 300)    # inside the first member's array payload
    with pytest.raises(ckpt.CheckpointCorrupt) as ei:
        ckpt.restore(str(tmp_path), tree, step=3)
    assert ei.value.leaf == "a"
    assert "arrays.npz" in str(ei.value)


def test_checkpoint_truncation_detected(tmp_path):
    tree = {"a": jnp.arange(256, dtype=jnp.int32)}
    ckpt.save(str(tmp_path), 1, tree)
    npz = str(tmp_path / "step_1" / "arrays.npz")
    with open(npz, "r+b") as f:
        f.truncate(os.path.getsize(npz) // 2)
    with pytest.raises(ckpt.CheckpointCorrupt, match="arrays.npz"):
        ckpt.restore(str(tmp_path), tree, step=1)


def test_auto_resume_falls_back_to_newest_intact(tmp_path):
    """step=None restores the newest checkpoint that verifies; only
    when every step is damaged does the corruption surface."""
    tree1 = {"x": jnp.full(64, 1, jnp.int32)}
    tree2 = {"x": jnp.full(64, 2, jnp.int32)}
    ckpt.save(str(tmp_path), 1, tree1)
    ckpt.save(str(tmp_path), 2, tree2)
    npz2 = str(tmp_path / "step_2" / "arrays.npz")
    with open(npz2, "r+b") as f:
        f.truncate(10)
    restored, step = ckpt.restore(str(tmp_path), tree1)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["x"]),
                                  np.asarray(tree1["x"]))
    npz1 = str(tmp_path / "step_1" / "arrays.npz")
    _flip_byte(npz1, 250)
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.restore(str(tmp_path), tree1)


@pytest.mark.slow
def test_preemption_resume_exact(tmp_path):
    """Train 6 steps straight vs 3 steps -> 'preempt' -> resume 3 more;
    final losses must match exactly (deterministic data + donated state)."""
    cfg = get_smoke_config("qwen2-1.5b")
    d1 = str(tmp_path / "a")
    d2 = str(tmp_path / "b")
    full = train_loop(cfg=cfg, steps=6, batch=4, seq=32, ckpt_dir=d1,
                      ckpt_every=3, log=lambda *a: None)
    train_loop(cfg=cfg, steps=3, batch=4, seq=32, ckpt_dir=d2,
               ckpt_every=3, log=lambda *a: None)
    resumed = train_loop(cfg=cfg, steps=6, batch=4, seq=32, ckpt_dir=d2,
                         ckpt_every=3, log=lambda *a: None)
    np.testing.assert_allclose(full["losses"][3:], resumed["losses"],
                               rtol=1e-5)


def test_elastic_restart_different_mesh(tmp_path):
    """Checkpoint from mesh A restores onto a differently-shaped mesh."""
    from repro.distributed.elastic import resume_elastic
    from repro.launch.steps import make_train_step
    cfg = get_smoke_config("minitron-8b")
    model = build_model(cfg)
    opt_init, _ = make_train_step(model)
    params = model.init_params(jax.random.key(0))
    opt = opt_init(params)
    ckpt.save(str(tmp_path), 7, {"params": params, "opt": opt})

    mesh_b = jax.make_mesh((1, 1), ("data", "model"))
    p2, o2, step = resume_elastic(str(tmp_path), model, opt_init, mesh_b)
    assert step == 7
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_data_determinism_across_topologies():
    cfg = DataConfig(vocab=101, seq_len=16, global_batch=8)
    whole = host_batch(cfg, step=5, host_id=0, n_hosts=1)
    parts = [host_batch(cfg, step=5, host_id=h, n_hosts=4)
             for h in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(whole["tokens"], glued)
    # and distinct across steps
    other = host_batch(cfg, step=6)
    assert not np.array_equal(whole["tokens"], other["tokens"])


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=2.0, warmup=3)
    for i in range(5):
        assert not w.observe(i, 1.0)
    assert w.observe(5, 3.5)
    assert w.flagged == [(5, 3.5)]


_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

_FLEET_STATE_FIELDS = ("n_instr", "n_two_stage", "halted", "out", "mix",
                       "mems", "regs", "pc", "mix_items")


def _fleet_groups():
    from benchmarks.fleet import skew_fleet, skew_program
    from repro.fleet import engine
    prog = skew_program()
    mems_a = skew_fleet(prog, 40, short_iters=8, long_iters=400,
                        long_frac=0.2, seed=13)
    mems_b = skew_fleet(prog, 24, short_iters=16, long_iters=300,
                        long_frac=0.3, seed=14)
    return [
        engine.PackedGroup(code=prog.code,
                           source=engine.array_source(mems_a),
                           n_items=40, max_steps=100_000, mem_words=32,
                           out_addr=1),
        engine.PackedGroup(code=prog.code,
                           source=engine.array_source(mems_b),
                           n_items=24, max_steps=100_000, mem_words=32,
                           out_addr=1),
    ]


def test_resident_stream_kill_and_resume_bit_exact(tmp_path):
    """Kill the resident stream mid-flight (InjectedFault at a segment
    boundary) and rerun against the same checkpoint dir: the stream
    auto-resumes from its last snapshot, drains bit-exactly equal to an
    uninterrupted run (full state + per-group mix), and the resumed
    run's total segment count matches — deterministic re-execution from
    the checkpoint, not approximate recovery (DESIGN.md §9.12)."""
    from repro.fleet import engine
    kw = dict(chunk=16, seg_steps=64, keep_state=True)
    ref, ref_stats = engine.run_packed(_fleet_groups(), **kw)
    cdir = str(tmp_path / "fleet-ck")
    with pytest.raises(engine.InjectedFault):
        engine.run_packed(_fleet_groups(), checkpoint_dir=cdir,
                          checkpoint_every=4, _crash_after_segments=10,
                          **kw)
    crashed_at = ckpt.latest_step(cdir)
    assert crashed_at is not None and crashed_at <= 10
    res, stats = engine.run_packed(_fleet_groups(), checkpoint_dir=cdir,
                                   checkpoint_every=4, **kw)
    assert stats.n_segments == ref_stats.n_segments
    for a, b in zip(ref, res):
        for f in _FLEET_STATE_FIELDS:
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                          err_msg=f)


def test_resident_stream_resume_skips_corrupt_newest(tmp_path):
    """Kill the stream, then damage its newest on-disk snapshot (bit
    flip) — auto-resume must fall back to the next-older intact
    checkpoint and still drain bit-exactly equal to an uninterrupted
    run (§9.14: one torn write never strands the stream)."""
    from repro.fleet import engine
    kw = dict(chunk=16, seg_steps=64, keep_state=True)
    ref, ref_stats = engine.run_packed(_fleet_groups(), **kw)
    cdir = str(tmp_path / "fleet-ck")
    with pytest.raises(engine.InjectedFault):
        engine.run_packed(_fleet_groups(), checkpoint_dir=cdir,
                          checkpoint_every=3, _crash_after_segments=10,
                          **kw)
    steps = sorted(ckpt.all_steps(cdir))
    assert len(steps) >= 2      # need an older one to fall back to
    newest = steps[-1]
    _flip_byte(os.path.join(cdir, f"step_{newest}", "arrays.npz"), 400)
    with pytest.raises(ckpt.CheckpointCorrupt):
        ckpt.verify(cdir, newest)
    res, stats = engine.run_packed(_fleet_groups(), checkpoint_dir=cdir,
                                   checkpoint_every=3, **kw)
    assert stats.n_segments == ref_stats.n_segments
    for a, b in zip(ref, res):
        for f in _FLEET_STATE_FIELDS:
            np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                          err_msg=f)


def test_resident_checkpoint_requires_packed_plan(tmp_path):
    from repro.fleet.plan import FleetGroup, FleetPlan, run_plan
    plan = FleetPlan(groups=(FleetGroup(workload="WQ", n_items=4),),
                     packed=False)
    with pytest.raises(ValueError, match="packed"):
        run_plan(plan, checkpoint_dir=str(tmp_path))


@pytest.mark.slow
def test_resident_stream_elastic_resume_across_mesh_shapes(tmp_path):
    """The resident checkpoint is mesh-independent: crash a 4-device
    sharded stream, resume it on 2 devices, and the drained results are
    bit-exact with an uninterrupted single-device run — surviving lanes
    and pending spans are re-dealt to the new mesh's shards (§9.12)."""
    cdir = str(tmp_path / "elastic-ck")
    crash = r"""
import json
from repro.fleet import engine
from test_fault_tolerance import _fleet_groups
import jax
mesh = jax.make_mesh((4,), ("fleet",))
try:
    engine.run_packed(_fleet_groups(), chunk=16, seg_steps=64,
                      keep_state=True, mesh=mesh,
                      checkpoint_dir=%(cdir)r, checkpoint_every=3,
                      _crash_after_segments=8)
    raise SystemExit("expected InjectedFault")
except engine.InjectedFault:
    pass
print(json.dumps({"ok": True}))
""" % {"cdir": cdir}
    resume = r"""
import json
import numpy as np
import jax
from repro.fleet import engine
from test_fault_tolerance import _FLEET_STATE_FIELDS, _fleet_groups
ref, ref_stats = engine.run_packed(_fleet_groups(), chunk=16,
                                   seg_steps=64, keep_state=True)
mesh = jax.make_mesh((2,), ("fleet",))
res, stats = engine.run_packed(_fleet_groups(), chunk=16, seg_steps=64,
                               keep_state=True, mesh=mesh,
                               checkpoint_dir=%(cdir)r,
                               checkpoint_every=3)
assert stats.n_shards == 2, stats.n_shards
# n_segments is NOT asserted across mesh shapes: per-shard lane
# occupancy (and so drain cadence) legitimately differs; bit-exact
# per-item results are the invariant
for a, b in zip(ref, res):
    for f in _FLEET_STATE_FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
print(json.dumps({"ok": True}))
""" % {"cdir": cdir}
    for n_dev, script in ((4, crash), (2, resume)):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            env.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={n_dev}")
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(_ROOT, "src"), _ROOT,
             os.path.join(_ROOT, "tests"), env.get("PYTHONPATH", "")])
        proc = subprocess.run([sys.executable, "-c", script], env=env,
                              capture_output=True, text=True,
                              timeout=600)
        assert proc.returncode == 0, (n_dev, proc.stderr[-2000:])
        assert json.loads(proc.stdout.strip().splitlines()[-1])["ok"]
    assert ckpt.latest_step(cdir) is not None


def test_compressed_allreduce_error_feedback():
    """EF-int8 all-reduce: single-step error bounded; residual carries the
    exact quantization error so the bias vanishes across steps."""
    mesh = jax.make_mesh((1,), ("data",))
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(64, 64)).astype(np.float32))}
    r = init_residuals(g)
    mean, r2 = compressed_allreduce(g, r, mesh, axis="data")
    # n=1: mean should equal dequantized(g), residual the rounding error
    err = np.abs(np.asarray(mean["w"]) - np.asarray(g["w"]))
    scale = np.abs(np.asarray(g["w"])).max() / 127
    assert err.max() <= scale * 0.51 + 1e-6
    np.testing.assert_allclose(np.asarray(r2["w"]),
                               np.asarray(g["w"] - mean["w"]), atol=1e-6)
    # feeding back the residual recovers the lost mass
    mean2, _ = compressed_allreduce(
        jax.tree.map(jnp.zeros_like, g), r2, mesh, axis="data")
    recovered = np.asarray(mean["w"]) + np.asarray(mean2["w"])
    err2 = np.abs(recovered - np.asarray(g["w"]))
    assert err2.max() < err.max() + 1e-6
