"""FlexiFault tests (DESIGN.md §9.14): deterministic counter-based fault
injection bit-identical across all three steppers and the PyISS
FaultOracle, rate-0 / faults=None bit-exactness with the fault-free
engine, DMR detect/rollback/quarantine recovery end-to-end, the
consecutive-retry quarantine semantics, golden-vs-faulty rate
measurement, redundancy-aware planner reproduction at rate 0, and the
FleetPlan wiring + resilience pricing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax

from benchmarks.fleet import skew_fleet, skew_program
from repro.flexibits import faults, iss, pyiss
from repro.fleet import engine
from repro.kernels import iss_stepper as ks

_STATE_FIELDS = ("regs", "pc", "mem", "halted", "n_instr")
_RESULT_FIELDS = ("n_instr", "halted", "out", "mems", "regs", "pc")


def _fleet(n=8, seed=0):
    prog = skew_program()
    code = np.asarray(prog.code, np.uint32)
    mems = np.tile(prog.initial_memory(32), (n, 1))
    mems[:, 0] = np.random.default_rng(seed).integers(5, 60, size=n)
    return code, mems


def _group(code, mems, max_steps=400):
    return engine.PackedGroup(code=code, source=engine.array_source(mems),
                              n_items=len(mems), max_steps=max_steps,
                              mem_words=mems.shape[1], out_addr=1)


# ---- stepper-level identity -------------------------------------------


def test_faulty_trajectories_bit_identical_and_match_oracle():
    """A nonzero schedule produces BIT-IDENTICAL faulty trajectories on
    the branchless, lax.switch, and Pallas steppers, and each lane
    matches the PyISS FaultOracle exactly — the §9.13 counter-seeding
    discipline applied to corruption."""
    code, mems = _fleet(8)
    MAX = 400
    spec = faults.FaultSpec(rate=0.05, seed=3,
                            targets=("regs", "mem", "pc"))
    keys = faults.lane_keys(spec.seed, len(mems))
    kj, ej = jnp.asarray(keys), jnp.zeros(len(mems), jnp.int32)
    codej = jnp.asarray(code.view(np.int32))
    states = jax.vmap(lambda m: iss.init_state(m))(jnp.asarray(mems))

    out_b = iss.run_segment_lanes(codej, states, seg_steps=MAX,
                                  max_steps=MAX, faults=spec,
                                  lane_key=kj, epoch=ej)

    def run_switch(mem, k, e):
        def body(st):
            return iss.step(codej, st, faults=spec, lane_key=k, epoch=e)
        return lax.while_loop(
            lambda st: (~st.halted) & (st.n_instr < MAX), body,
            iss.init_state(mem))

    out_s = jax.vmap(run_switch)(jnp.asarray(mems), kj, ej)
    out_p = ks.iss_segment(codej, states, seg_steps=MAX, max_steps=MAX,
                           faults=spec, lane_key=kj, epoch=ej)
    for name, out in (("switch", out_s), ("pallas", out_p)):
        for f in _STATE_FIELDS:
            np.testing.assert_array_equal(
                np.asarray(getattr(out_b, f)),
                np.asarray(getattr(out, f)), err_msg=f"{name}.{f}")

    fired = 0
    for i in range(len(mems)):
        p = pyiss.PyISS(code, mems.shape[1], init_mem=mems[i])
        o = faults.FaultOracle(spec, int(keys[i]))
        p.post_commit = o
        p.run(MAX)
        fired += o.fired
        np.testing.assert_array_equal(
            np.asarray(out_b.regs[i]),
            np.array([np.int32(r) for r in p.regs]), err_msg=f"lane {i}")
        assert int(out_b.pc[i]) == np.int32(p.pc & 0xFFFFFFFF), i
        np.testing.assert_array_equal(
            np.asarray(out_b.mem[i], np.int64),
            np.asarray(p.mem, np.int64), err_msg=f"lane {i}")
        assert int(out_b.n_instr[i]) == p.n_instr, i
    assert fired > 0, "schedule never fired — the test proved nothing"


def test_rate_zero_bit_exact_with_faults_off():
    """rate=0 keeps the injection graph compiled in but must remain
    bit-exact with `faults=None` (every mask is all-false)."""
    code, mems = _fleet(8)
    codej = jnp.asarray(code.view(np.int32))
    states = jax.vmap(lambda m: iss.init_state(m))(jnp.asarray(mems))
    kw = dict(seg_steps=400, max_steps=400)
    off = iss.run_segment_lanes(codej, states, **kw)
    zero = iss.run_segment_lanes(
        codej, states, faults=faults.FaultSpec(rate=0.0),
        lane_key=jnp.asarray(faults.lane_keys(0, len(mems))),
        epoch=jnp.zeros(len(mems), jnp.int32), **kw)
    for f in _STATE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(off, f)),
                                      np.asarray(getattr(zero, f)),
                                      err_msg=f)


@pytest.mark.parametrize("mode", ["stuck", "dead"])
def test_defect_modes_bit_identical(mode):
    """stuck-at and dead-lane defects recur by construction (keyed
    below the epoch) and stay stepper- and oracle-identical."""
    code, mems = _fleet(8)
    sp = faults.FaultSpec(rate=1.0, seed=1, mode=mode)
    keys = faults.lane_keys(sp.seed, len(mems))
    kj, ej = jnp.asarray(keys), jnp.zeros(len(mems), jnp.int32)
    codej = jnp.asarray(code.view(np.int32))
    states = jax.vmap(lambda m: iss.init_state(m))(jnp.asarray(mems))
    kw = dict(seg_steps=400, max_steps=400, faults=sp, lane_key=kj,
              epoch=ej)
    ob = iss.run_segment_lanes(codej, states, **kw)
    op = ks.iss_segment(codej, states, **kw)
    for f in _STATE_FIELDS:
        np.testing.assert_array_equal(np.asarray(getattr(ob, f)),
                                      np.asarray(getattr(op, f)),
                                      err_msg=f)
    p = pyiss.PyISS(code, mems.shape[1], init_mem=mems[0])
    p.post_commit = faults.FaultOracle(sp, int(keys[0]))
    p.run(400)
    np.testing.assert_array_equal(
        np.asarray(ob.regs[0]), np.array([np.int32(r) for r in p.regs]))
    assert int(ob.n_instr[0]) == p.n_instr


# ---- packed engine ----------------------------------------------------


def test_packed_rate_zero_bit_exact_with_pre_fault_engine():
    code, mems = _fleet(40)
    gold, _ = engine.run_packed([_group(code, mems)], chunk=16,
                                seg_steps=64, keep_state=True)
    z, _ = engine.run_packed([_group(code, mems)], chunk=16, seg_steps=64,
                             keep_state=True,
                             faults=faults.FaultSpec(rate=0.0))
    for f in _RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(gold[0], f),
                                      getattr(z[0], f), err_msg=f)


def test_packed_faulty_run_deterministic_and_stepper_identical():
    """A nonzero schedule is (1) reproducible run-to-run, (2) actually
    corrupting, and (3) bit-identical across the three steppers at the
    same (chunk, seg_steps) — faults are a function of the schedule,
    not of the execution strategy."""
    code, mems = _fleet(40)
    spec = faults.FaultSpec(rate=0.02, seed=5,
                            targets=("regs", "mem", "pc"))
    kw = dict(chunk=16, seg_steps=64, keep_state=True, faults=spec)
    gold, _ = engine.run_packed([_group(code, mems)], chunk=16,
                                seg_steps=64, keep_state=True)
    fb, _ = engine.run_packed([_group(code, mems)], **kw)
    fb2, _ = engine.run_packed([_group(code, mems)], **kw)
    for f in _RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(fb[0], f),
                                      getattr(fb2[0], f), err_msg=f)
    assert not np.array_equal(fb[0].mems, gold[0].mems), \
        "schedule never corrupted anything"
    for st in ("pallas", "switch"):
        fs, _ = engine.run_packed([_group(code, mems)], stepper=st, **kw)
        for f in _RESULT_FIELDS:
            np.testing.assert_array_equal(getattr(fb[0], f),
                                          getattr(fs[0], f),
                                          err_msg=f"{st}.{f}")


@pytest.mark.parametrize("stepper", ["branchless", "pallas", "switch"])
def test_dmr_recovers_golden_results(stepper):
    """DMR + retry recovers every detectable fault end-to-end: the
    drained results are bit-exact with the fault-free run."""
    code, mems = _fleet(40)
    gold, _ = engine.run_packed([_group(code, mems)], chunk=16,
                                seg_steps=64, keep_state=True)
    mild = faults.FaultSpec(rate=0.0008, seed=5,
                            targets=("regs", "mem", "pc"))
    dm, ds = engine.run_packed([_group(code, mems)], chunk=32,
                               seg_steps=64, keep_state=True,
                               faults=mild, redundancy="dmr",
                               max_retries=6, stepper=stepper)
    for f in _RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(gold[0], f),
                                      getattr(dm[0], f), err_msg=f)
    assert ds.detected > 0 and ds.corrected > 0
    assert ds.corrected <= ds.detected


def test_dmr_fault_free_is_pure_overhead():
    code, mems = _fleet(40)
    gold, _ = engine.run_packed([_group(code, mems)], chunk=16,
                                seg_steps=64, keep_state=True)
    d0, d0s = engine.run_packed([_group(code, mems)], chunk=32,
                                seg_steps=64, keep_state=True,
                                redundancy="dmr")
    for f in _RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(gold[0], f),
                                      getattr(d0[0], f), err_msg=f)
    assert d0s.detected == 0 and d0s.corrected == 0
    assert d0s.quarantined == 0


def test_dmr_dead_lanes_quarantine_and_backfill():
    """Dead-lane defects recur on retry, so the pair quarantines and
    its item is re-admitted on a healthy pair — results still golden."""
    code, mems = _fleet(40)
    gold, _ = engine.run_packed([_group(code, mems)], chunk=16,
                                seg_steps=64, keep_state=True)
    dead = faults.FaultSpec(rate=0.3, seed=5, mode="dead")
    dq, dqs = engine.run_packed([_group(code, mems)], chunk=32,
                                seg_steps=64, keep_state=True,
                                faults=dead, redundancy="dmr",
                                max_retries=1)
    for f in _RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(gold[0], f),
                                      getattr(dq[0], f), err_msg=f)
    assert dqs.quarantined > 0


def test_dmr_long_items_accrue_transients_without_quarantine():
    """Regression: the retry counter must count CONSECUTIVE mismatching
    boundaries, resetting on every clean one. An item spanning ~100+
    segments legitimately accrues many independent transients over its
    lifetime; a lifetime-cumulative counter quarantined every pair and
    starved the pool (the bug showed up first on the CT workload's
    ~51k-instruction items)."""
    prog = skew_program()
    mems = skew_fleet(prog, 16, short_iters=64, long_iters=1500,
                      long_frac=0.5, seed=7)
    g = engine.PackedGroup(code=prog.code,
                           source=engine.array_source(mems), n_items=16,
                           max_steps=100_000, mem_words=32, out_addr=1)
    gold, _ = engine.run_packed([g], chunk=16, seg_steps=64,
                                keep_state=True)
    g2 = engine.PackedGroup(code=prog.code,
                            source=engine.array_source(mems), n_items=16,
                            max_steps=100_000, mem_words=32, out_addr=1)
    mild = faults.FaultSpec(rate=0.0008, seed=5,
                            targets=("regs", "mem", "pc"))
    dm, ds = engine.run_packed([g2], chunk=16, seg_steps=64,
                               keep_state=True, faults=mild,
                               redundancy="dmr", max_retries=6)
    # many independent detections, zero quarantines, golden results
    assert ds.detected > 10, ds.detected
    assert ds.quarantined == 0, ds.quarantined
    for f in _RESULT_FIELDS:
        np.testing.assert_array_equal(getattr(gold[0], f),
                                      getattr(dm[0], f), err_msg=f)


def test_resilience_requires_resident_loop():
    code, mems = _fleet(8)
    spec = faults.FaultSpec(rate=0.02, seed=5)
    with pytest.raises(ValueError, match="resident"):
        engine.run_packed([_group(code, mems)], refill="host",
                          faults=spec)
    with pytest.raises(ValueError, match="checkpoint"):
        engine.run_packed([_group(code, mems)], checkpoint_dir="/tmp/x",
                          faults=spec)


# ---- measurement and pricing ------------------------------------------


def test_measure_rates_classification():
    code, mems = _fleet(8, seed=2)
    spec = faults.FaultSpec(rate=0.05, seed=3,
                            targets=("regs", "mem", "pc"))
    rep = faults.measure_rates(code, mems, max_steps=400, spec=spec)
    assert rep.n_trials == 8
    assert rep.exposed > 0
    assert rep.masked + rep.derated + rep.sdc == rep.exposed
    assert rep.live_regs and all(0 <= r < 16 for r in rep.live_regs)
    quiet = faults.measure_rates(code, mems, max_steps=400,
                                 spec=faults.FaultSpec(rate=0.0))
    assert quiet.exposed == 0


def test_redundancy_selection_rate_zero_reproduces_selection_map():
    """The joint (redundancy x core) argmin at fault rate 0 must pick
    redundancy 'none' everywhere and reproduce `selection_map` exactly
    — spare copies only cost, never pay."""
    from repro.core import carbon
    from repro.core.selection import (redundancy_selection_map,
                                      selection_map)
    from repro.flexibench.base import get

    w = get("WQ")
    prof = carbon.DeviceProfile(n_one_stage=400.0, n_two_stage=130.0,
                                vm_kb=w.vm_kb(), nvm_kb=w.nvm_kb)
    L = np.logspace(np.log10(86_400.0 * 3), np.log10(86_400.0 * 1000), 9)
    F = np.array([1.0, 24.0, 960.0])
    r_idx, c_idx = redundancy_selection_map(prof, L, F, fault_rate=0.0)
    assert (r_idx == 0).all()
    np.testing.assert_array_equal(c_idx, selection_map(prof, L, F))
    # at a printing-grade rate the axis is live: protection wins cells
    r_hi, _ = redundancy_selection_map(prof, L, F, fault_rate=1e-3)
    assert (r_hi > 0).any()


def test_plan_wiring_prices_resilience():
    """FleetPlan(faults=..., redundancy='dmr') drains bit-exactly equal
    to the fault-free plan, prices strictly more carbon (spare area +
    re-execution), and the report prints the §9.14 resilience line."""
    from repro.fleet.plan import FleetGroup, FleetPlan, run_plan

    base = dict(groups=[FleetGroup("WQ", n_items=8)], chunk=16,
                seg_steps=128)
    r0 = run_plan(FleetPlan(**base))
    mild = faults.FaultSpec(rate=2e-4, seed=5,
                            targets=("regs", "mem", "pc"))
    r1 = run_plan(FleetPlan(**base, faults=mild, redundancy="dmr",
                            max_retries=6))
    for g0, g1 in zip(r0.groups, r1.groups):
        np.testing.assert_array_equal(g0.result.out, g1.result.out)
        np.testing.assert_array_equal(g0.result.n_instr,
                                      g1.result.n_instr)
        assert g1.total_kg > g0.total_kg
    assert r1.packed.redundancy == "dmr"
    assert "resilience (FlexiFault §9.14, dmr)" in r1.format()
    assert "resilience" not in r0.format()
