"""Cross-path consistency: prefill+decode must reproduce the training
forward's next-token logits; MoE dispatch modes agree; sharding rules are
divisibility-safe."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.models.model import build_model

DECODE_MATCH_ARCHS = ["minitron-8b", "qwen2-1.5b", "gemma3-12b",
                      "qwen2-moe-a2.7b", "deepseek-v3-671b", "mamba2-1.3b",
                      "zamba2-7b"]


@pytest.mark.slow
@pytest.mark.parametrize("arch", DECODE_MATCH_ARCHS)
def test_decode_matches_teacher_forcing(arch):
    """Greedy decode logits at position t == full-forward logits at t.

    MoE capacity is raised so no token drops (capacity dropping makes the
    paths legitimately diverge); tolerances cover bf16 reassociation
    (absorbed-MLA decode, conv-state decode paths)."""
    import dataclasses
    cfg = get_smoke_config(arch).replace(remat=False)
    if cfg.moe:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe,
                                                  capacity_factor=8.0))
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    b, l = 2, 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, l)), jnp.int32)

    # full forward logits (training path)
    if cfg.family in ("dense", "moe", "vlm"):
        from repro.models.transformer import decoder_forward, logits_fn
        h, _ = decoder_forward(params, cfg, toks)
        full = logits_fn(params, cfg, h)
    elif cfg.family == "hybrid":
        from repro.models.hybrid import hybrid_forward
        from repro.models.transformer import logits_fn
        full = logits_fn(params, cfg, hybrid_forward(params, cfg, toks))
    else:
        from repro.models.ssm import ssm_forward
        from repro.models.transformer import logits_fn
        full = logits_fn(params, cfg, ssm_forward(params, cfg, toks))

    # prefill on the first l-1 tokens, then decode token l-1
    cap = l + 4
    logits_p, cache = model.prefill_fn(params, {"tokens": toks[:, :l - 1]},
                                       cap)
    logits_d, _ = model.decode_fn(params, cache, toks[:, l - 1:l],
                                  jnp.int32(l - 1))
    v = cfg.vocab
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0, :v], np.float32),
        np.asarray(full[:, l - 2, :v], np.float32), rtol=6e-2, atol=8e-2)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0, :v], np.float32),
        np.asarray(full[:, l - 1, :v], np.float32), rtol=6e-2, atol=8e-2)


def test_moe_hierarchical_matches_flat():
    """On a 1-shard mesh the hierarchical dispatch must equal the flat
    path exactly (same capacity, same order)."""
    import dataclasses
    from repro.models import moe as MOE
    cfg = get_smoke_config("qwen2-moe-a2.7b")
    m_flat = cfg.moe
    m_hier = dataclasses.replace(cfg.moe, dispatch="hierarchical")
    p = MOE.init_moe(jax.random.key(1), cfg.d_model, m_flat, jnp.float32)
    x = jax.random.normal(jax.random.key(2), (2, 16, cfg.d_model),
                          jnp.float32)
    y1, a1 = MOE.moe_ffn(p, x, m_flat)
    from repro.distributed.meshctx import mesh_context
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh_context(mesh):
        y2, a2 = jax.jit(lambda p, x: MOE.moe_ffn(p, x, m_hier))(p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_expert_padding_masks_padded_experts():
    import dataclasses
    from repro.models import moe as MOE
    m = dataclasses.replace(get_smoke_config("qwen2-moe-a2.7b").moe,
                            n_experts=6, n_experts_padded=8, top_k=2)
    logits = jax.random.normal(jax.random.key(0), (64, 8), jnp.float32)
    probs, idx, aux = MOE.router_topk(logits, m)
    assert int(jnp.max(idx)) < 6          # never routes to padded experts


def test_sharding_rules_divisibility():
    """No parameter ever gets a spec whose dim doesn't divide the mesh."""
    from repro.distributed.sharding import abstract_mesh, param_shardings
    mesh = abstract_mesh(("data", "model"), (1, 2))
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        model = build_model(cfg)
        abs_p = model.abstract_params()
        shardings = param_shardings(abs_p, mesh)
        for leaf, sh in zip(jax.tree.leaves(abs_p),
                            jax.tree.leaves(shardings)):
            spec = sh.spec
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                size = int(np.prod([mesh.shape[a] for a in axes]))
                assert leaf.shape[dim] % size == 0, (arch, leaf.shape, spec)


def test_grad_accum_matches_single_batch():
    """grad_accum=2 over a batch == one step over the same batch."""
    from repro.launch.steps import make_train_step
    cfg = get_smoke_config("qwen2-1.5b")
    model = build_model(cfg)
    params = model.init_params(jax.random.key(0))
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                              jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)),
                               jnp.int32),
        "mask": jnp.ones((4, 16), jnp.float32),
    }
    for ga in (1, 2):
        opt_init, step = make_train_step(model, grad_accum=ga)
        p2, _, m = jax.jit(step)(params, opt_init(params), batch,
                                 jnp.int32(0))
        if ga == 1:
            base = m["loss"]
        else:
            np.testing.assert_allclose(float(m["loss"]), float(base),
                                       rtol=2e-2)
