"""FlexiBits property tests: JAX ISS == Python oracle on random programs
(hypothesis), assembler round-trips, cycle-model invariants.

`hypothesis` is optional (see requirements-dev.txt): without it the
property tests are skipped; deterministic tests still run.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.flexibits import iss
from repro.flexibits.asm import Asm
from repro.flexibits.cycles import CORES, HERV, QERV, SERV
from repro.flexibits.pyiss import PyISS

try:
    import hypothesis
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

R_OPS = ["add", "sub", "sll", "slt", "sltu", "xor", "srl", "sra", "or",
         "and"]
I_OPS = ["addi", "slti", "sltiu", "xori", "ori", "andi"]
SH_OPS = ["slli", "srli", "srai"]


def _check_iss_matches_oracle(prog):
    mem0 = prog.initial_memory(128)
    py = PyISS(prog.code, 128, mem0).run(100_000)
    jx = iss.run(jnp.asarray(prog.code.view(np.int32)),
                 jnp.asarray(mem0), 100_000)
    assert py.halted and bool(jx.halted)
    np.testing.assert_array_equal(np.asarray(jx.mem[:16], np.int64),
                                  py.mem[:16])
    assert int(jx.n_instr) == py.n_instr
    assert int(jx.n_two_stage) == py.n_two_stage


def _check_software_mul_wraps_like_int32(x, y):
    a = Asm(vm_reserved=64)
    a.li(a.a0, x)
    a.li(a.a1, y)
    a.call("__mul")
    a.sw(a.a0, a.zero, 0)
    a.halt()
    a.emit_mul_routine()
    prog = a.assemble()
    py = PyISS(prog.code, 64, prog.initial_memory(64)).run(100_000)
    want = np.asarray([(x * y) & 0xFFFFFFFF], np.int64).astype(np.uint32) \
        .astype(np.int32)[0]
    assert np.int32(py.mem[0]) == want


if HAVE_HYPOTHESIS:
    @st.composite
    def random_program(draw):
        """Straight-line arithmetic program + a store of every register."""
        a = Asm(vm_reserved=128)
        n = draw(st.integers(5, 40))
        # seed registers
        for r in range(5, 16):
            a.li(r, draw(st.integers(-2048, 2047)))
        for _ in range(n):
            kind = draw(st.sampled_from(["r", "i", "sh"]))
            rd = draw(st.integers(5, 15))
            rs1 = draw(st.integers(0, 15))
            if kind == "r":
                op = draw(st.sampled_from(R_OPS))
                rs2 = draw(st.integers(0, 15))
                a.emit(op, rd, rs1, rs2)
            elif kind == "i":
                op = draw(st.sampled_from(I_OPS))
                a.emit(op, rd, rs1, imm=draw(st.integers(-2048, 2047)))
            else:
                op = draw(st.sampled_from(SH_OPS))
                a.emit(op, rd, rs1, imm=draw(st.integers(0, 31)))
        for r in range(16):
            a.sw(r, 0, 4 * r)
        a.halt()
        return a.assemble()

    @hypothesis.settings(max_examples=30, deadline=None)
    @hypothesis.given(random_program())
    def test_iss_matches_oracle(prog):
        _check_iss_matches_oracle(prog)

    @hypothesis.settings(max_examples=20, deadline=None)
    @hypothesis.given(st.integers(-2 ** 31, 2 ** 31 - 1),
                      st.integers(-2 ** 31, 2 ** 31 - 1))
    def test_software_mul_wraps_like_int32(x, y):
        _check_software_mul_wraps_like_int32(x, y)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_iss_matches_oracle():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_software_mul_wraps_like_int32():
        pass


def test_software_mul_spot_checks():
    """Deterministic fallback for the hypothesis mul property."""
    for x, y in ((0, 0), (3, 7), (-5, 123456), (2 ** 31 - 1, -2),
                 (-2 ** 31, 3)):
        _check_software_mul_wraps_like_int32(x, y)


def _np_random_program(rng):
    """Deterministic analogue of the hypothesis `random_program` strategy."""
    a = Asm(vm_reserved=128)
    for r in range(5, 16):
        a.li(r, int(rng.integers(-2048, 2048)))
    for _ in range(int(rng.integers(5, 41))):
        kind = rng.choice(["r", "i", "sh"])
        rd = int(rng.integers(5, 16))
        rs1 = int(rng.integers(0, 16))
        if kind == "r":
            a.emit(str(rng.choice(R_OPS)), rd, rs1,
                   int(rng.integers(0, 16)))
        elif kind == "i":
            a.emit(str(rng.choice(I_OPS)), rd, rs1,
                   imm=int(rng.integers(-2048, 2048)))
        else:
            a.emit(str(rng.choice(SH_OPS)), rd, rs1,
                   imm=int(rng.integers(0, 32)))
    for r in range(16):
        a.sw(r, 0, 4 * r)
    a.halt()
    return a.assemble()


def test_iss_oracle_spot_checks():
    """Deterministic fallback for the hypothesis ISS-vs-oracle property:
    fixed-seed random programs through the same parity check."""
    for seed in range(5):
        _check_iss_matches_oracle(
            _np_random_program(np.random.default_rng(seed)))


def test_branch_and_memory_ops():
    a = Asm(vm_reserved=64)
    # sum 1..10 via loop; store bytes/halfwords too
    a.li(a.t0, 0)
    a.li(a.t1, 1)
    a.label("loop")
    a.add(a.t0, a.t0, a.t1)
    a.addi(a.t1, a.t1, 1)
    a.li(a.t2, 10)
    a.bge(a.t2, a.t1, "loop")
    a.sw(a.t0, a.zero, 0)
    a.emit("sh", 0, 0, a.t0, 4)
    a.emit("sb", 0, 0, a.t0, 8)
    a.emit("lb", a.a0, 0, imm=8)
    a.sw(a.a0, a.zero, 12)
    a.halt()
    prog = a.assemble()
    mem0 = prog.initial_memory(64)
    py = PyISS(prog.code, 64, mem0).run()
    jx = iss.run(jnp.asarray(prog.code.view(np.int32)), jnp.asarray(mem0),
                 10_000)
    assert py.mem[0] == 55 and int(jx.mem[0]) == 55
    assert py.mem[3] == 55 and int(jx.mem[3]) == 55
    np.testing.assert_array_equal(np.asarray(jx.mem[:16], np.int64),
                                  py.mem[:16])


def test_cycle_model_matches_paper_anchors():
    assert SERV.cycles_one_stage() == 38.0          # 32 + 6
    assert SERV.cycles_two_stage() == 70.0          # 64 + 6 (paper §4.2)
    # area/power straight from Table 7
    assert SERV.area_mm2 == 2.93 and HERV.power_mw == 24.99
    # wider datapaths strictly faster per instruction
    for one in (True, False):
        f = (lambda c: c.cycles_one_stage()) if one else \
            (lambda c: c.cycles_two_stage())
        assert f(SERV) > f(QERV) > f(HERV)


def test_vmap_fleet_agrees_with_single_runs():
    from repro.flexibench.base import get
    w = get("WQ")
    rng = np.random.default_rng(0)
    xs = w.gen_inputs(rng, 8)
    mems = np.stack([w.initial_memory(x) for x in xs])
    state = iss.run_fleet(jnp.asarray(w.program.code.view(np.int32)),
                          jnp.asarray(mems), w.max_steps)
    outs = np.asarray(state.mem[:, w.out_addr])
    np.testing.assert_array_equal(outs, w.ref(xs))
