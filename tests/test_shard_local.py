"""Shard-local resident fleet (DESIGN.md §9.12): the static item->shard
partition and span helpers, 2-device subprocess parity with the
single-device stream (full state + per-shard stats), and the HLO audit
pinning the sharded segment loop collective-free — the compiled refill
and segment modules the engine actually runs must contain zero
cross-device collective ops."""
import json
import os
import subprocess
import sys

import numpy as np

from repro.fleet import engine

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------- units

def test_shard_partition_contiguous_balanced():
    spans = engine.shard_partition((10, 7), 4)
    for g, total in zip(range(2), (10, 7)):
        per = [engine._span_items(spans[g][s]) for s in range(4)]
        np.testing.assert_array_equal(np.concatenate(per),
                                      np.arange(total))
        sizes = [p.size for p in per]
        assert max(sizes) - min(sizes) <= 1
        for p in per:                     # each shard's slice contiguous
            assert p.size == 0 or (np.diff(p) == 1).all()


def test_shard_partition_single_shard_is_identity():
    spans = engine.shard_partition((5,), 1)
    assert spans == [[[(0, 5)]]]


def test_shard_partition_more_shards_than_items():
    spans = engine.shard_partition((2,), 4)
    sizes = [engine._span_items(spans[0][s]).size for s in range(4)]
    assert sizes == [1, 1, 0, 0]


def test_split_spans_elastic_redeal():
    """_split_spans re-deals a restored pending span list over a new
    shard count, preserving item order and balance (elastic resume)."""
    spans = [(3, 9), (20, 24)]            # 10 pending items
    out = engine._split_spans(spans, 3)
    per = [engine._span_items(s) for s in out]
    np.testing.assert_array_equal(
        np.concatenate(per), engine._span_items(spans))
    assert [p.size for p in per] == [4, 3, 3]
    # and a round-trip through _items_to_spans is lossless
    for s, p in zip(out, per):
        assert engine._items_to_spans(p) == s


def test_span_source_fetches_contiguous_runs():
    base = np.arange(40, dtype=np.int32).reshape(20, 2)
    calls = []

    def src(start, count):
        calls.append((start, count))
        return base[start:start + count]

    view = engine._span_source(src, [(2, 5), (9, 11)])
    np.testing.assert_array_equal(view(0, 5), base[[2, 3, 4, 9, 10]])
    assert calls == [(2, 3), (9, 2)]      # one fetch per contiguous run
    calls.clear()
    np.testing.assert_array_equal(view(1, 3), base[[3, 4, 9]])
    assert calls == [(3, 2), (9, 1)]
    assert view(5, 0).size == 0


# ------------------------------------------- 2-device parity + HLO audit

_SMOKE = r"""
import json
import jax
import numpy as np
import repro.fleet.engine as eng
from benchmarks.fleet import skew_fleet, skew_program
from repro.launch.hlo_analysis import analyze_hlo

# Capture the exact compiled modules the sharded run executes: wrap the
# runner factories so the first call lowers/compiles the same jitted fn
# (AOT) before running it.
texts = {}
_orig_refill = eng._resident_refill_runner
_orig_seg = eng._packed_segment_runner


def _wrap(name, orig):
    def factory(*a):
        jfn = orig(*a)
        if not any(isinstance(x, jax.sharding.Mesh) for x in a):
            return jfn

        def run(*args):
            if name not in texts:
                texts[name] = jfn.lower(*args).compile().as_text()
            return jfn(*args)
        return run
    return factory


eng._resident_refill_runner = _wrap("refill", _orig_refill)
eng._packed_segment_runner = _wrap("segment", _orig_seg)

prog = skew_program()
mems_a = skew_fleet(prog, 40, short_iters=8, long_iters=400,
                    long_frac=0.2, seed=13)
mems_b = skew_fleet(prog, 24, short_iters=16, long_iters=300,
                    long_frac=0.3, seed=14)


def groups():
    return [
        eng.PackedGroup(code=prog.code, source=eng.array_source(mems_a),
                        n_items=40, max_steps=100_000, mem_words=32,
                        out_addr=1),
        eng.PackedGroup(code=prog.code, source=eng.array_source(mems_b),
                        n_items=24, max_steps=100_000, mem_words=32,
                        out_addr=1),
    ]


ref, _ = eng.run_packed(groups(), chunk=16, seg_steps=64,
                        keep_state=True)
mesh = jax.make_mesh((2,), ("fleet",))
res, stats = eng.run_packed(groups(), chunk=16, seg_steps=64,
                            keep_state=True, mesh=mesh)
assert stats.n_shards == 2, stats.n_shards
# one stats read per iteration (segments + trailing) + 9 drain pulls
# (5 scalar-ish leaves + 4 keep_state leaves) — NOT multiplied by shards
assert stats.host_syncs == stats.n_segments + 1 + 9, stats
assert sum(stats.shard_retired) == 64, stats.shard_retired
assert sum(stats.shard_lane_steps) == stats.lane_steps, stats
fields = ("n_instr", "n_two_stage", "halted", "out", "mix",
          "mems", "regs", "pc", "mix_items")
for a, b in zip(ref, res):
    for f in fields:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f),
                                      err_msg=f)
assert set(texts) == {"refill", "segment"}, sorted(texts)
audit = {name: {"counts": analyze_hlo(t)["collective_counts"],
                "bytes": analyze_hlo(t)["collective_bytes_per_device"]}
         for name, t in texts.items()}
print(json.dumps({"ok": True, "audit": audit,
                  "shard_retired": list(stats.shard_retired)}))
"""


def test_two_device_shard_local_parity_and_collective_free_hlo():
    """On 2 forced host devices the shard-local resident stream is
    bit-exact with the single-device run (full final state, both
    groups), keeps ONE host sync per segment (not one per shard), and
    the compiled segment + refill modules it executed contain ZERO
    cross-device collective ops — the §9.12 claim, pinned on the real
    lowered HLO rather than on source inspection."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), _ROOT, env.get("PYTHONPATH", "")])
    proc = subprocess.run([sys.executable, "-c", _SMOKE], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"]
    assert sorted(out["shard_retired"]) == [32, 32]
    for name, a in out["audit"].items():
        assert a["bytes"] == 0, (name, a)
        assert all(v == 0 for v in a["counts"].values()), (name, a)
