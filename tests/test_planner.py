"""Serving-fleet planner: embodied-vs-operational crossover properties."""
import numpy as np

from repro.core.planner import VARIANTS, plan_grid, tokens_per_s_per_chip


def _plan(lifetimes, qps):
    kv = 32 * 8 * 128 * 2 * 2
    return plan_grid(n_params=8e9, kv_bytes_per_token=kv,
                     lifetimes_days=np.asarray(lifetimes, float),
                     qps_grid=np.asarray(qps, float))


def test_throughput_scales_with_fewer_bits():
    kv = 32 * 8 * 128 * 2 * 2
    t16 = tokens_per_s_per_chip(8e9, 16, kv, 16)
    t8 = tokens_per_s_per_chip(8e9, 8, kv, 16)
    t4 = tokens_per_s_per_chip(8e9, 4, kv, 16)
    assert t4 > t8 > t16
    assert t8 / t16 > 1.5          # weight-read-dominated regime


def test_longer_lifetime_never_decreases_w4_adoption():
    plan = _plan([7, 90, 3 * 365], np.logspace(2, 6, 9))
    w4 = [(plan["variant_idx"][i] == 2).sum() for i in range(3)]
    assert w4[0] <= w4[1] <= w4[2]
    assert w4[2] > w4[0]           # the crossover exists


def test_infeasible_qps_marked():
    plan = _plan([365], [1e12])
    assert plan["variant_idx"][0, 0] == -1


def test_total_carbon_monotone_in_qps():
    plan = _plan([365], np.logspace(2, 6, 9))
    kg = plan["total_kg"][0]
    assert np.all(np.diff(kg) > 0)
