"""Serving-fleet planner: embodied-vs-operational crossover properties,
and exact equality of the broadcast `plan_grid` with the scalar loop
formulation it vectorized."""
import numpy as np

from repro.core.planner import (CHIP_POWER_W, PUE, TPU_EMBODIED_KG,
                                VARIANTS, plan_grid,
                                tokens_per_s_per_chip)


def _plan(lifetimes, qps):
    kv = 32 * 8 * 128 * 2 * 2
    return plan_grid(n_params=8e9, kv_bytes_per_token=kv,
                     lifetimes_days=np.asarray(lifetimes, float),
                     qps_grid=np.asarray(qps, float))


def test_throughput_scales_with_fewer_bits():
    kv = 32 * 8 * 128 * 2 * 2
    t16 = tokens_per_s_per_chip(8e9, 16, kv, 16)
    t8 = tokens_per_s_per_chip(8e9, 8, kv, 16)
    t4 = tokens_per_s_per_chip(8e9, 4, kv, 16)
    assert t4 > t8 > t16
    assert t8 / t16 > 1.5          # weight-read-dominated regime


def test_longer_lifetime_never_decreases_w4_adoption():
    plan = _plan([7, 90, 3 * 365], np.logspace(2, 6, 9))
    w4 = [(plan["variant_idx"][i] == 2).sum() for i in range(3)]
    assert w4[0] <= w4[1] <= w4[2]
    assert w4[2] > w4[0]           # the crossover exists


def test_infeasible_qps_marked():
    plan = _plan([365], [1e12])
    assert plan["variant_idx"][0, 0] == -1


def test_total_carbon_monotone_in_qps():
    plan = _plan([365], np.logspace(2, 6, 9))
    kg = plan["total_kg"][0]
    assert np.all(np.diff(kg) > 0)


def _plan_grid_loop(*, n_params, kv_bytes_per_token, lifetimes_days,
                    qps_grid, chips_options=(8, 16, 32, 64, 128, 256),
                    intensity=0.367, variants=VARIANTS):
    """Scalar reference: the triple-nested loop `plan_grid` replaced
    with one broadcast — kept here verbatim as the equality oracle."""
    nl, nq = len(lifetimes_days), len(qps_grid)
    best = np.full((nl, nq), -1, np.int32)
    best_chips = np.zeros((nl, nq), np.int32)
    best_kg = np.full((nl, nq), np.inf)
    options = []
    for vi, v in enumerate(variants):
        for chips in chips_options:
            tps = tokens_per_s_per_chip(n_params, v.weight_bits,
                                        kv_bytes_per_token, chips) * chips
            options.append((vi, chips, tps))
    for li, days in enumerate(lifetimes_days):
        for qi, qps in enumerate(qps_grid):
            for vi, chips, tps in options:
                if tps < qps:
                    continue
                emb = chips * TPU_EMBODIED_KG * \
                    min(days / (3 * 365.0), 1.0)
                util = qps / tps
                kwh = chips * CHIP_POWER_W * PUE * util \
                    * days * 24.0 / 1000.0
                op = kwh * intensity
                total = variants[vi].prep_kg + emb + op
                if total < best_kg[li, qi]:
                    best_kg[li, qi] = total
                    best[li, qi] = vi
                    best_chips[li, qi] = chips
    return {"variant_idx": best, "chips": best_chips,
            "total_kg": best_kg}


def test_plan_grid_broadcast_equals_loop_exactly():
    """The vectorized `plan_grid` is closed-form equal to the scalar
    loop — same floats (identical op order), same argmin tie-breaks
    (first strict minimum), same infeasible markers — across a grid
    that exercises feasible, infeasible, and tied regions."""
    kv = 32 * 8 * 128 * 2 * 2
    kw = dict(n_params=8e9, kv_bytes_per_token=kv,
              lifetimes_days=np.array([1.0, 7.0, 90.0, 3 * 365.0,
                                       10 * 365.0]),
              qps_grid=np.logspace(1, 12, 23))
    got = plan_grid(**kw)
    ref = _plan_grid_loop(**kw)
    np.testing.assert_array_equal(got["variant_idx"],
                                  ref["variant_idx"])
    np.testing.assert_array_equal(got["chips"], ref["chips"])
    np.testing.assert_array_equal(got["total_kg"], ref["total_kg"])
    assert got["variant_idx"].dtype == ref["variant_idx"].dtype
    assert got["chips"].dtype == ref["chips"].dtype
    assert (got["variant_idx"] == -1).any()        # infeasible cells hit
