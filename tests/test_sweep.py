"""Monte Carlo carbon-planner sweep: determinism and oracle parity
(DESIGN.md §9.13).

The contracts pinned here, all as exact array equality:

- same seed + different tile sizes -> bit-identical reductions (the
  counter-based per-cell seeding plus associative accumulators);
- Pallas vs jnp paths bit-exact, at any row-tile size;
- on point-mass lifetime distributions the device sweep equals the
  numpy `selection.total_grid` / `selection_map` oracles bit-for-bit
  (float64 under `enable_x64`), and Monte Carlo percentiles collapse
  to the closed-form point estimate;
- `serving_plan_jnp` equals the numpy `planner.plan_grid` oracle on
  shared grid points.
"""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.carbon import DeviceProfile
from repro.core.planner import plan_grid
from repro.core.selection import (crossover_lifetime_s,
                                  crossover_lifetimes, selection_map,
                                  total_grid)
from repro.core.sweep import (LifetimeDist, SweepSpec, run_sweep,
                              serving_plan_jnp)
from repro.flexibits.cycles import CORES
from repro.kernels import carbon_sweep as csk

PROF = DeviceProfile(n_one_stage=600, n_two_stage=400, vm_kb=0.4,
                     nvm_kb=1.0)
DAY = 86_400.0
FIELDS = ("mean", "p50", "p90", "p99", "min", "max", "mean_emb",
          "mean_op", "fleet_mean", "counts", "hist")


def _mixture_spec(draws=32, seed=7):
    mix = LifetimeDist.mixture(
        [(LifetimeDist.lognormal(DAY * 30, 1.8), 0.7),
         (LifetimeDist.weibull(DAY * 300, 0.8), 0.3)])
    return SweepSpec(
        workloads=("w0", "w1"), profiles=(PROF, PROF),
        dists=(mix, LifetimeDist.point(DAY * 100)),
        execs_per_day=(1.0, 24.0, 96.0),
        intensities=(0.028, 0.367), volumes=(1.0, 1e9),
        draws=draws, seed=seed)


def _point_spec(draws=8, seed=3):
    lifes = [DAY * d for d in (1, 10, 100, 1000)]
    return SweepSpec(
        workloads=("w0",), profiles=(PROF,),
        dists=tuple(LifetimeDist.point(L) for L in lifes),
        execs_per_day=(1.0, 24.0, 96.0), intensities=(0.367,),
        volumes=(1e6,), draws=draws, seed=seed), lifes


def _assert_results_equal(a, b):
    for f in FIELDS:
        np.testing.assert_array_equal(getattr(a, f), getattr(b, f), f)
    for k in a.pareto:
        np.testing.assert_array_equal(a.pareto[k], b.pareto[k], k)


# ------------------------------------------------------ determinism
def test_tile_sizes_bit_identical():
    spec = _mixture_spec()
    runs = [run_sweep(spec, path="jnp", tile_cells=t)
            for t in (3, 7, 48, spec.n_cells)]
    for other in runs[1:]:
        _assert_results_equal(runs[0], other)


def test_flush_cadence_bit_identical():
    spec = _mixture_spec()
    a = run_sweep(spec, path="jnp", tile_cells=7)
    b = run_sweep(spec, path="jnp", tile_cells=7, flush_limit=1)
    _assert_results_equal(a, b)


def test_same_seed_reproduces_different_seed_differs():
    spec = _mixture_spec()
    a = run_sweep(spec, path="jnp", tile_cells=16)
    b = run_sweep(spec, path="jnp", tile_cells=16)
    _assert_results_equal(a, b)
    c = run_sweep(dataclasses.replace(spec, seed=spec.seed + 1),
                  path="jnp", tile_cells=16)
    assert not np.array_equal(a.mean, c.mean)


# --------------------------------------------------- pallas A/B parity
def test_pallas_vs_jnp_bit_exact():
    spec = _mixture_spec()
    a = run_sweep(spec, path="jnp", tile_cells=48)
    b = run_sweep(spec, path="pallas", tile_cells=48)
    _assert_results_equal(a, b)


def test_pallas_row_tiles_bit_exact():
    rng = np.random.default_rng(0)
    n_cells, n_draws, n_cores = 12, 8, 3
    emb = jnp.asarray(rng.uniform(1e-4, 1e-2, (n_cells, n_cores)),
                      jnp.float32)
    kwh = jnp.asarray(rng.uniform(1e-9, 1e-6, (n_cells, n_cores)),
                      jnp.float32)
    inten = jnp.asarray(rng.uniform(0.01, 1.1, n_cells), jnp.float32)
    freq = jnp.asarray(rng.uniform(0.5, 100, n_cells), jnp.float32)
    life = jnp.asarray(rng.uniform(1, 4000, (n_cells, n_draws)),
                       jnp.float32)  # days — pre-divided like the engine
    valid = jnp.asarray(rng.random(n_cells) < 0.8)
    cell = jnp.arange(n_cells, dtype=jnp.int32)
    kw = dict(hist_lo=-4.0, hist_inv=12.8, par_lo=-4.0, par_inv=6.4)
    acc = csk.init_acc(64, 32, jnp.float32)
    ref_out, ref_acc = csk.sweep_tile(emb, kwh, inten, freq, life,
                                      valid, cell, acc, path="jnp", **kw)
    for rt in (1, 3, 4, 12, None):
        out, pacc = csk.sweep_tile(emb, kwh, inten, freq, life, valid,
                                   cell, acc, path="pallas",
                                   row_tile=rt, **kw)
        for a, b in zip(ref_out, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(ref_acc, pacc):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_unknown_path_raises():
    spec = _mixture_spec()
    with pytest.raises(ValueError, match="unknown sweep path"):
        run_sweep(spec, path="fused")


# ------------------------------------------------- numpy oracle parity
def test_point_mass_equals_total_grid_bitwise():
    """On point-mass lifetime grids, float64 device totals ARE the numpy
    `total_grid` floats and the modal core IS `selection_map`."""
    spec, lifes = _point_spec(draws=8)
    cores = list(CORES.values())
    tg = total_grid(cores, PROF, np.asarray(lifes),
                    np.asarray(spec.execs_per_day))
    best = tg.min(axis=0)
    smap = selection_map(PROF, np.asarray(lifes),
                         np.asarray(spec.execs_per_day))
    with jax.experimental.enable_x64():
        res = run_sweep(spec, path="jnp", tile_cells=5,
                        dtype=np.float64)
        res1 = run_sweep(dataclasses.replace(spec, draws=1),
                         path="jnp", dtype=np.float64)
        resp = run_sweep(spec, path="pallas", tile_cells=12,
                         dtype=np.float64)
    sq = np.s_[:, :, 0, 0, 0, 0, 0]
    np.testing.assert_array_equal(res.p50[sq], best)
    np.testing.assert_array_equal(res.min[sq], best)
    np.testing.assert_array_equal(res.max[sq], best)
    np.testing.assert_array_equal(res1.mean[sq], best)
    np.testing.assert_array_equal(res.best_core[sq], smap)
    _assert_results_equal(res, resp)           # A/B holds in f64 too


def test_point_mass_percentiles_collapse_to_point_estimate():
    """MC percentiles in the point-mass limit are the closed-form point
    estimate: every order statistic equals every other, bit-for-bit."""
    spec, _ = _point_spec(draws=16)
    res = run_sweep(spec, path="jnp", tile_cells=6)
    for f in ("p50", "p90", "p99", "min", "max"):
        np.testing.assert_array_equal(getattr(res, f), res.min, f)
    assert res.hist.sum() == res.n_scenarios


def test_fleet_mean_scales_with_volume():
    spec = _mixture_spec()
    res = run_sweep(spec, path="jnp", tile_cells=16)
    v = np.asarray(spec.volumes)
    np.testing.assert_array_equal(
        res.fleet_mean,
        (res.mean.astype(np.float64)
         * v[None, None, None, :, None, None, None]).astype(np.float32))


def test_serving_plan_jnp_equals_plan_grid_bitwise():
    kv = 32 * 8 * 128 * 2 * 2
    kw = dict(n_params=8e9, kv_bytes_per_token=kv,
              lifetimes_days=np.array([1.0, 30.0, 365.0, 3 * 365.0]),
              qps_grid=np.logspace(1, 12, 12))
    ref = plan_grid(**kw)
    with jax.experimental.enable_x64():
        got = serving_plan_jnp(**kw)
    for k in ("variant_idx", "chips", "total_kg"):
        np.testing.assert_array_equal(np.asarray(got[k]), ref[k], k)


# ------------------------------------------------------- timing modes
def test_timing_axis_orders_base_dynamic_wcet():
    """One sweep prices base, dynamic, and certified-worst-case timing;
    with measured event vectors the dynamic price is >= base and the
    WCET certificate bounds both (it is priced from the dynamic cost
    row's static ceiling)."""
    events = [0.0] * 19
    events[0], events[1], events[2] = 600.0, 400.0, 120.0
    # dynamic-only events (taken branches / serial shifts / subword RMW):
    # priced 0 by the base cost row, so dynamic > base strictly.
    events[16], events[17], events[18] = 50.0, 200.0, 30.0
    prof = dataclasses.replace(PROF, events=tuple(events))
    # SERV/QERV/HERV dynamic event cycles are ~44.8k/14.0k/8.9k; the
    # certificate must sit above each core's dynamic-priced measurement
    # to bound it.
    wcet = ((60_000.0, 20_000.0, 12_000.0),)
    spec = SweepSpec(
        workloads=("w0",), profiles=(prof,),
        dists=(LifetimeDist.point(DAY * 100),),
        execs_per_day=(24.0,), intensities=(0.367,),
        timing=("base", "dynamic", "wcet"), wcet_cycles=wcet,
        draws=4, seed=0)
    res = run_sweep(spec, path="jnp")
    base, dyn, wc = (res.mean_op[0, 0, 0, 0, 0, t, 0] for t in range(3))
    assert base < dyn < wc


def test_spec_validation_errors():
    spec = _mixture_spec()
    with pytest.raises(ValueError, match="dists is empty"):
        run_sweep(dataclasses.replace(spec, dists=()))
    with pytest.raises(ValueError, match="draws"):
        run_sweep(dataclasses.replace(spec, draws=0))
    with pytest.raises(ValueError, match="unknown timing"):
        run_sweep(dataclasses.replace(spec, timing=("typical",)))
    with pytest.raises(ValueError, match="unknown redundancy"):
        run_sweep(dataclasses.replace(spec, redundancies=("quad",)))
    with pytest.raises(ValueError, match="fault rates"):
        run_sweep(dataclasses.replace(spec, fault_rates=(-1.0,)))
    with pytest.raises(ValueError, match="wcet"):
        run_sweep(dataclasses.replace(spec, timing=("wcet",)))
    with pytest.raises(ValueError, match="enable_x64"):
        run_sweep(spec, dtype=np.float64)


def test_plan_grid_empty_options_raise():
    kw = dict(n_params=8e9, kv_bytes_per_token=1e5,
              lifetimes_days=np.array([365.0]),
              qps_grid=np.array([100.0]))
    with pytest.raises(ValueError, match="chips_options is empty"):
        plan_grid(chips_options=(), **kw)
    with pytest.raises(ValueError, match="variants is empty"):
        plan_grid(variants=(), **kw)
    with pytest.raises(ValueError, match="chips_options is empty"):
        serving_plan_jnp(chips_options=(), **kw)


def test_plan_grid_no_warnings_on_infeasible():
    """inf/extreme qps demands must not raise numpy warnings: the util
    divide is masked to feasible options."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan = plan_grid(n_params=8e9, kv_bytes_per_token=1e5,
                         lifetimes_days=np.array([365.0]),
                         qps_grid=np.array([100.0, 1e15, np.inf]))
    assert (plan["variant_idx"][0, 1:] == -1).all()


# ---------------------------------------------- redundancy axis (§9.14)
def test_redundancy_rate_zero_reproduces_selection():
    """At fault rate 0 spare copies only cost, never pay: the joint
    (core, redundancy) argmin picks 'none' everywhere and its core half
    IS `selection_map` — the redundancy-aware planner reproduces
    today's selections exactly."""
    spec, lifes = _point_spec(draws=4)
    spec = dataclasses.replace(spec, fault_rates=(0.0, 1e-4),
                               redundancies=("none", "dmr", "tmr"))
    smap = selection_map(PROF, np.asarray(lifes),
                         np.asarray(spec.execs_per_day))
    with jax.experimental.enable_x64():
        res = run_sweep(spec, path="jnp", tile_cells=5, dtype=np.float64)
    sq0 = np.s_[:, :, 0, 0, 0, 0, 0]              # fault-rate-0 slice
    np.testing.assert_array_equal(res.best_redundancy[sq0], 0)
    np.testing.assert_array_equal(res.best_core[sq0], smap)


def test_redundancy_expanded_paths_bit_exact():
    """jnp and Pallas reductions stay bit-exact with the candidate axis
    expanded to core x redundancy and a nonzero fault-rate axis."""
    spec = dataclasses.replace(_mixture_spec(draws=16),
                               fault_rates=(0.0, 1e-3),
                               redundancies=("none", "dmr"))
    a = run_sweep(spec, path="jnp", tile_cells=13)
    b = run_sweep(spec, path="pallas", tile_cells=48)
    _assert_results_equal(a, b)
    assert a.counts.shape[-1] == spec.n_candidates


# ------------------------------------------------ crossover vectorized
def test_crossover_matrix_matches_scalar():
    cores = list(CORES.values())
    mat = crossover_lifetimes(PROF, execs_per_day=24.0)
    assert mat.shape == (len(cores), len(cores))
    assert np.isinf(np.diag(mat)).all()
    for a, ca in enumerate(cores):
        for b, cb in enumerate(cores):
            s = crossover_lifetime_s(PROF, ca, cb, execs_per_day=24.0)
            assert mat[a, b] == s, (ca.name, cb.name)
    # a pair crosses in at most one direction
    finite = np.isfinite(mat)
    assert not (finite & finite.T & ~np.eye(len(cores), dtype=bool)).any()


# ------------------------------------------------- frontier extraction
def test_frontier_is_nondominated_and_annotated():
    spec = _mixture_spec(draws=64)
    res = run_sweep(spec, path="jnp", tile_cells=32)
    rows = res.frontier()
    assert rows, "frontier should not be empty"
    embs = [r["embodied_kg"] for r in rows]
    ops = [r["operational_kg"] for r in rows]
    assert embs == sorted(embs)
    assert ops == sorted(ops, reverse=True)       # strictly improving
    for r in rows:
        assert r["workload"] in spec.workloads
        assert r["core"] in [c.name for c in spec.cores]
        di, fi, ii, vi, wi, ti, fri = spec.decode_cell(r["cell"])
        assert spec.workloads[wi] == r["workload"]
        assert spec.dists[di].name == r["dist"]
        assert spec.fault_rates[fri] == r["fault_rate"]


def test_mixture_of_points_hits_both_components():
    """A 50/50 two-point mixture with 64 draws hits both components
    (P[miss] = 2^-63): min/max bracket exactly the two closed-form
    totals of the best core."""
    d1, d2 = DAY * 1.0, DAY * 2000.0
    mix = LifetimeDist.mixture([(LifetimeDist.point(d1), 0.5),
                                (LifetimeDist.point(d2), 0.5)])
    spec = SweepSpec(workloads=("w0",), profiles=(PROF,), dists=(mix,),
                     execs_per_day=(24.0,), intensities=(0.367,),
                     draws=64, seed=1)
    cores = list(CORES.values())
    tg = total_grid(cores, PROF, np.array([d1, d2]), np.array([24.0]))
    lo, hi = tg[:, 0, 0].min(), tg[:, 1, 0].min()
    with jax.experimental.enable_x64():
        res = run_sweep(spec, path="jnp", dtype=np.float64)
    assert res.min.ravel()[0] == lo
    assert res.max.ravel()[0] == hi
