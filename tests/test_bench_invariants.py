"""Fast-tier gate over the committed BENCH_fleet.json artifact.

The fleet benchmark itself runs on main only (CI full tier), which used
to mean a regression in a recorded perf invariant — the §9.7 fusion
proof, the §9.8 packed-runtime win — only surfaced after merge, as an
artifact nobody opened. This gate validates the *committed* numbers on
every push: whoever regenerates BENCH_fleet.json with a regressed
tentpole metric fails fast-tier CI right in their PR. (Wall-clock rows
are machine-dependent; the gates below are exactly the invariants the
benchmark itself enforces on exit, evaluated on the recorded run.)
"""
import json
import os

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
_BENCH = os.path.join(_ROOT, "BENCH_fleet.json")


@pytest.fixture(scope="module")
def bench():
    assert os.path.exists(_BENCH), (
        "BENCH_fleet.json missing at the repo root — regenerate with "
        "PYTHONPATH=src python benchmarks/fleet.py")
    with open(_BENCH) as f:
        return json.load(f)


def test_bench_has_all_studies(bench):
    for key in ("streaming_vs_monolithic", "stepper_ab", "fusion_proof",
                "packed_vs_sequential", "resident_vs_host_refill",
                "timing_overhead", "fault_overhead", "planner_sweep",
                "flexilint", "device_scaling"):
        assert key in bench, f"BENCH_fleet.json lost the {key} study"


def test_fusion_proof_invariant(bench):
    """§9.7: the fused-segment module's top level must stay >=10x
    smaller than the branchless step body x seg_steps it replaces."""
    fp = bench["fusion_proof"]
    assert float(fp["top_level_ratio"]) >= 10.0, fp["top_level_ratio"]
    assert int(fp["pallas"]["entry_ops"]) < \
        int(fp["branchless"]["dispatched_ops_per_segment"])


def test_packed_runtime_invariant(bench):
    """§9.8: on the skewed-group-size plan the packed stream must not be
    slower than the sequential group drain, must be bit-exact, and must
    retire strictly fewer segments and lane-step slots."""
    pk = bench["packed_vs_sequential"]
    assert pk["bit_exact"] is True
    assert float(pk["packed_wall_s"]) <= float(pk["sequential_wall_s"]), (
        pk["packed_wall_s"], pk["sequential_wall_s"])
    assert int(pk["packed_segments"]) < int(pk["sequential_segments"])
    assert int(pk["packed_lane_steps"]) < int(pk["sequential_lane_steps"])


def test_stepper_ab_invariant(bench):
    """§9.5: the branchless stepper must stay ahead of the legacy
    lax.switch interpreter per retired instruction."""
    assert float(bench["stepper_ab"]["stepper_speedup"]) > 1.0


def test_timing_overhead_invariant(bench):
    """§9.10: the per-lane cycle layer must be architecturally invisible
    (bit-exact on vs off) and cheap — cycles-on segment wall within
    1.5x of cycles-off even with full dynamic cost rows."""
    to = bench["timing_overhead"]
    assert to["bit_exact"] is True
    assert float(to["overhead_ratio"]) <= 1.5, to["overhead_ratio"]
    assert float(to["mean_cycles_per_item"]) > 0


def test_fault_overhead_invariant(bench):
    """§9.14: a rate-0 fault schedule must be bit-exact with faults-off
    (injection graph architecturally invisible), DMR must recover the
    fault-free outputs exactly under a nonzero schedule, and the DMR
    wall clock must stay within 2.5x of faults-off (two copies per
    item plus rollback re-execution). The recorded unprotected run must
    show a nonzero SDC rate — that silent corruption is the carbon
    model's whole case for pricing redundancy."""
    fo = bench["fault_overhead"]
    assert fo["bit_exact"] is True
    assert fo["dmr_recovered"] is True
    assert float(fo["dmr_overhead_ratio"]) <= 2.5, (
        fo["dmr_overhead_ratio"])
    assert 0.0 < float(fo["sdc_rate"]) <= 1.0, fo["sdc_rate"]
    assert int(fo["detected"]) > 0
    assert int(fo["corrected"]) > 0
    assert int(fo["corrupted_items"]) > 0


def test_planner_sweep_invariant(bench):
    """§9.13: the fused device sweep must price >=1e6 scenarios/s on
    CPU and hold a >=100x margin over the per-scenario python loop,
    with the Pallas A/B bit-exact and the float64 point-mass run pinned
    exactly to the numpy total_grid/selection_map oracles."""
    ps = bench["planner_sweep"]
    assert float(ps["scenarios_per_s"]) >= 1e6, ps["scenarios_per_s"]
    assert float(ps["python_loop_speedup"]) >= 100.0, (
        ps["python_loop_speedup"])
    assert ps["bit_exact"] is True
    assert ps["oracle_exact"] is True
    assert int(ps["n_scenarios"]) >= 100_000
    assert int(ps["n_cells"]) * int(ps["draws"]) == int(ps["n_scenarios"])


def test_flexilint_invariant(bench):
    """§9.11: every FlexiBench workload must analyze with zero lint
    errors and a finite WCET, and the recorded certificate must
    dominate the PyISS-measured ticks (WCET/measured >= 1 — below 1 is
    a soundness bug, not a perf regression)."""
    fl = bench["flexilint"]
    per = fl["per_workload"]
    assert len(per) == 11, sorted(per)
    assert int(fl["total_errors"]) == 0
    assert fl["all_bounded"] is True
    for key, p in per.items():
        assert float(p["analysis_wall_ms"]) > 0, key
        assert p["wcet_ticks"] is not None, key
        assert int(p["measured_max_ticks"]) > 0, key
        assert float(p["wcet_over_measured"]) >= 1.0, (
            key, p["wcet_over_measured"])
        assert int(p["min_steps"]) <= int(p["wcet_steps"]), key


def test_resident_runtime_invariant(bench):
    """§9.9: on the 16x-skewed churny plan the resident runtime must be
    bit-exact with the host-refill baseline, no slower on wall-clock,
    and must perform strictly fewer blocking host syncs."""
    rh = bench["resident_vs_host_refill"]
    assert rh["bit_exact"] is True
    assert float(rh["resident_wall_s"]) <= \
        float(rh["host_refill_wall_s"]), (
        rh["resident_wall_s"], rh["host_refill_wall_s"])
    assert int(rh["resident_syncs"]) < int(rh["host_refill_syncs"]), (
        rh["resident_syncs"], rh["host_refill_syncs"])


def test_device_scaling_invariant(bench):
    """§9.12: the shard-local resident engine's weak-scaling curve must
    be monotonically increasing with >=2.5x at 4 devices (replay basis:
    per-shard dedicated-device wall — the legitimate node throughput of
    a collective-free loop), every shard replay must be bit-exact with
    the sharded run, the oversubscribed wall-clock must hold the >=0.6
    efficiency floor, and each recorded point must carry the sync
    accounting (host_syncs/sync_wait_s/device_busy_frac)."""
    sc = bench["device_scaling"]
    assert sc["bit_exact"] is True
    sp = [float(s) for s in sc["speedup_vs_1dev"]]
    devs = [int(p["n_devices"]) for p in sc["points"]]
    assert devs == sorted(devs) and len(devs) >= 3
    assert all(b > a for a, b in zip(sp, sp[1:])), sp
    assert 4 in devs
    assert sp[devs.index(4)] >= 2.5, sp
    assert float(sc["min_oversubscribed_efficiency"]) >= 0.6
    for p in sc["points"]:
        assert int(p["host_syncs"]) > 0
        assert float(p["sync_wait_s"]) >= 0.0
        assert 0.0 <= float(p["device_busy_frac"]) <= 1.0
        assert int(p["n_shards"]) == int(p["n_devices"])
        assert float(p["shard_wall_s"]) > 0.0
        assert float(p["speedup_vs_1dev"]) > 0.0
