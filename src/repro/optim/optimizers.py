"""Optimizers as pure pytree functions: AdamW and Adafactor.

AdamW keeps fp32 m/v (and updates the bf16 params directly — master weights
in fp32 are the `master=True` option). Adafactor stores factored second
moments (row/col) for matrices — the memory-viable choice for the 671B MoE
(see configs/deepseek_v3_671b.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_by_norm(grads, max_norm: float):
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gnorm


# ----------------------------------------------------------------- adamw

def adamw_init(params, master: bool = False):
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
    }
    if master:
        state["master"] = jax.tree.map(
            lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params, grads, state, lr, *, b1=0.9, b2=0.95, eps=1e-8,
                 wd=0.1):
    step = state["step"] + 1
    t = step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        mhat = m2 / (1 - b1 ** t)
        vhat = v2 / (1 - b2 ** t)
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"step": step, "m": new_m, "v": new_v}


# -------------------------------------------------------------- adafactor

def _factored(shape) -> bool:
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params):
    def one(p):
        if _factored(p.shape):
            return {
                "r": jnp.zeros(p.shape[:-1], jnp.float32),        # row
                "c": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                               jnp.float32),                      # col
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}
    return {"step": jnp.zeros((), jnp.int32),
            "vs": jax.tree.map(one, params,
                               is_leaf=lambda x: hasattr(x, "shape"))}


def adafactor_update(params, grads, state, lr, *, decay=0.8, eps=1e-30,
                     clip_thresh=1.0, wd=0.0):
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    beta = 1.0 - t ** -decay

    def upd(p, g, v):
        g = g.astype(jnp.float32)
        g2 = g * g + eps
        if _factored(p.shape):
            r = beta * v["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
            c = beta * v["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
            rmean = jnp.mean(r, axis=-1, keepdims=True)
            vhat = (r / jnp.maximum(rmean, eps))[..., None] * c[..., None, :]
            newv = {"r": r, "c": c}
        else:
            vhat = beta * v["v"] + (1 - beta) * g2
            newv = {"v": vhat}
        u = g * jax.lax.rsqrt(jnp.maximum(vhat, eps))
        # update clipping (Adafactor's RMS clip)
        rms = jnp.sqrt(jnp.mean(u * u) + eps)
        u = u / jnp.maximum(1.0, rms / clip_thresh)
        newp = (p.astype(jnp.float32) - lr * (u + wd * p.astype(jnp.float32))
                ).astype(p.dtype)
        return newp, newv

    leaves_p, tdef = jax.tree.flatten(params)
    leaves_g = jax.tree.leaves(grads)
    vs_leaves = tdef.flatten_up_to(state["vs"])
    out = [upd(p, g, v) for p, g, v in zip(leaves_p, leaves_g, vs_leaves)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_vs = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_p, {"step": step, "vs": new_vs}


# ----------------------------------------------------------------- facade

def make_optimizer(name: str):
    if name == "adamw":
        return adamw_init, adamw_update
    if name == "adafactor":
        return adafactor_init, adafactor_update
    raise ValueError(name)
