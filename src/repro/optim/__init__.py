from repro.optim.optimizers import (adafactor_init, adafactor_update,
                                    adamw_init, adamw_update, clip_by_norm,
                                    make_optimizer)
from repro.optim.schedule import cosine_schedule
