"""Batched serving loop: prefill + decode with a KV cache, greedy sampling.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --smoke \
      --batch 4 --prompt-len 16 --gen 16
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.distributed.meshctx import mesh_context
from repro.launch.mesh import make_host_mesh
from repro.models.model import build_model


def generate(cfg, *, batch: int, prompt_len: int, gen: int, mesh=None,
             seed: int = 0, params=None, log=print):
    mesh = mesh or make_host_mesh()
    model = build_model(cfg)
    cap = prompt_len + gen
    rng = np.random.default_rng(seed)

    with mesh_context(mesh):
        if params is None:
            params = model.init_params(jax.random.key(0))
        prompt = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab, (batch, prompt_len)), jnp.int32)}
        if cfg.family == "vlm":
            prompt["patches"] = jnp.asarray(
                rng.normal(size=(batch, cfg.n_patches, cfg.d_model)),
                jnp.bfloat16)
        if cfg.family == "audio":
            prompt["frames"] = jnp.asarray(
                rng.normal(size=(batch, cfg.n_audio_frames, cfg.d_model)),
                jnp.bfloat16)

        t0 = time.perf_counter()
        prefill = jax.jit(lambda p, b: model.prefill_fn(p, b, cap))
        logits, cache = prefill(params, prompt)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        decode = jax.jit(model.decode_fn, donate_argnums=(1,))
        tok = jnp.argmax(logits[..., :cfg.vocab], -1).astype(jnp.int32)
        out_tokens = [tok]
        t1 = time.perf_counter()
        for i in range(gen - 1):
            pos = jnp.int32(prompt_len + i)
            logits, cache = decode(params, cache, tok, pos)
            tok = jnp.argmax(logits[..., :cfg.vocab], -1).astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.perf_counter() - t1
        toks = jnp.concatenate(out_tokens, axis=1)
        log(f"[serve] prefill {t_prefill * 1e3:.0f}ms, "
            f"{gen - 1} decode steps {t_decode * 1e3:.0f}ms "
            f"({(gen - 1) * batch / max(t_decode, 1e-9):.1f} tok/s)")
        return np.asarray(toks), {"prefill_s": t_prefill,
                                  "decode_s": t_decode}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    toks, stats = generate(cfg, batch=args.batch,
                           prompt_len=args.prompt_len, gen=args.gen)
    print(json.dumps({"shape": list(toks.shape), **stats}))


if __name__ == "__main__":
    main()
