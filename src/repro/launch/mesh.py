"""Production meshes. Functions only — importing this module never touches
jax device state (jax locks the device count on first backend init)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod (data, model); 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host offers, as a (data, model) mesh for tests."""
    n = len(jax.devices())
    model = 1
    for m in (4, 2, 1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))
