"""Loop-aware analysis of post-SPMD optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (trip counts are
not folded in), which under-counts scanned-layer models by ~n_layers x.
This module parses the HLO text instead:

  * builds the computation call graph (fusion `calls=`, `to_apply=`,
    while `body=`/`condition=`, `branch_computations=`),
  * multiplies while bodies by XLA's `known_trip_count` annotation,
  * counts dot FLOPs as 2 * numel(result) * prod(lhs contracting dims),
  * sums collective traffic bytes with ring-algorithm factors:
      all-gather:          result_bytes            (receives N-1 shards)
      all-reduce:        2*result_bytes            (reduce-scatter+gather)
      reduce-scatter:      result_bytes * group    (full tensor traffic)
      all-to-all:          sum(result bytes)
      collective-permute:  result_bytes

All numbers are PER DEVICE (post-SPMD shapes are shard shapes).
"""
from __future__ import annotations

import re
from typing import Dict, List, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_INSTR_RE = re.compile(r"^\s+(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*?)\)\s*->")


def _array_shapes(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _ARRAY_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _numel(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


def _bytes_of(type_str: str) -> int:
    return sum(_numel(d) * _DTYPE_BYTES[dt]
               for dt, d in _array_shapes(type_str))


def _split_operands(text: str) -> List[str]:
    """Split an operand list on top-level commas only (shape dims like
    `f32[64,128]` and tuple types nest commas inside []/{}/())."""
    out: List[str] = []
    depth = 0
    cur: List[str] = []
    for ch in text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    out.append("".join(cur))
    return [o.strip() for o in out if o.strip()]


def _operand_span(tail: str, start: int) -> str:
    """Text between the opcode's '(' (at `start`) and its matching ')'."""
    depth = 1
    j = start
    while j < len(tail) and depth:
        if tail[j] in "([{":
            depth += 1
        elif tail[j] in ")]}":
            depth -= 1
        j += 1
    return tail[start:j - 1] if depth == 0 else tail[start:]


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


def _collective_base(opcode: str) -> str:
    """Strip async -start/-done SUFFIXES (str.rstrip strips a char set,
    which would mangle e.g. 'all-gather-start' -> 'all-gathe')."""
    for suf in ("-start", "-done"):
        if opcode.endswith(suf):
            return opcode[:-len(suf)]
    return opcode


_NO_BYTES_OPS = {"tuple", "get-tuple-element", "parameter", "constant",
                 "bitcast", "after-all", "partition-id", "replica-id",
                 "iota"}


class HloStats:
    def __init__(self):
        self.flops = 0.0
        self.bytes = 0.0                           # HBM traffic proxy
        self.coll_bytes = {k: 0.0 for k in _COLLECTIVES}
        self.coll_counts = {k: 0 for k in _COLLECTIVES}
        # (callee, flop_multiplier, bytes_multiplier)
        self.calls: List[Tuple[str, float, float]] = []
        self.unknown_trip = 0


def _parse(hlo: str):
    comps: Dict[str, HloStats] = {}
    shapes: Dict[str, Dict[str, List[int]]] = {}   # comp -> name -> dims
    entry = None
    cur = None

    for raw in hlo.splitlines():
        mc = _COMP_RE.match(raw)
        if mc and not raw.startswith(" "):
            cur = mc.group(2)
            comps[cur] = HloStats()
            shapes[cur] = {}
            if mc.group(1):
                entry = cur
            # header params with simple array types
            for pm in re.finditer(r"([\w\.\-]+):\s*([a-z0-9]+\[[\d,]*\])",
                                  mc.group(3)):
                arrs = _array_shapes(pm.group(2))
                if arrs:
                    shapes[cur][pm.group(1)] = arrs[0]
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(raw)
        if not mi:
            continue
        name, rest = mi.group(1), mi.group(2)
        st = comps[cur]

        # result type = prefix of `rest` up to the opcode token. Tuple types
        # contain '/*index=N*/' comments, so scan parens by depth instead of
        # regexing.
        if rest.startswith("("):
            depth = 0
            end = -1
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            if end < 0:
                continue
            type_str = rest[:end + 1]
            tail = rest[end + 1:]
        else:
            mt_ = re.match(r"([a-z0-9]+\[[\d,]*\](?:\{[\d,*TS()]*\})?)", rest)
            if not mt_:
                continue
            type_str = mt_.group(1)
            tail = rest[mt_.end():]
        mop = re.match(r"\s+([\w\-]+)\(", tail)
        if not mop:
            continue
        opcode = mop.group(1)
        arrs = _array_shapes(type_str)
        if arrs:
            shapes[cur][name] = arrs[0]

        # Operand shapes: scheduled HLO writes operands inline-typed
        # (`dot(f32[64,128]{1,0} %Arg_0.1, ...)`), so parse the type off
        # the operand text itself and only fall back to the name table for
        # bare `%name` references (pre-scheduling dumps).
        operand_strs = _split_operands(_operand_span(tail, mop.end()))

        def _operand_arrays(o: str) -> List[Tuple[str, List[int]]]:
            found = _array_shapes(o)
            if found:
                return found
            toks = o.split()
            ent = shapes[cur].get(toks[-1].lstrip("%")) if toks else None
            return [ent] if ent is not None else []

        # HBM-bytes proxy with op-specific rules. In-place/slicing ops move
        # only the slice, NOT the full buffer (XLA aliases the rest);
        # counting their full operands would overcount carried scan stashes
        # by ~n_layers x. Fused computations' internals never touch HBM
        # (bytes edges skip `calls=`, see below).
        def _operand_bytes_list():
            return [sum(_numel(d) * _DTYPE_BYTES[dt]
                        for dt, d in _operand_arrays(o))
                    for o in operand_strs]

        def _operand_bytes(idx=None):
            lst = _operand_bytes_list()
            if idx is not None:
                lst = lst[idx:idx + 1]
            return sum(lst)

        if opcode in _NO_BYTES_OPS or opcode in ("reshape",):
            pass
        elif opcode == "dynamic-update-slice":
            st.bytes += 2.0 * _operand_bytes(1)     # r/w the updated window
        elif opcode == "fusion" and "dynamic-update-slice" in name:
            # XLA aliases the big buffer through DUS fusions (in-place);
            # traffic = the non-aliased (small) operands, r/w
            res = _bytes_of(type_str)
            small = sum(b for b in _operand_bytes_list() if b != res)
            st.bytes += 2.0 * small
        elif opcode == "fusion" and "dynamic-slice" in name:
            st.bytes += 2.0 * _bytes_of(type_str)   # read slice + write
        elif opcode in ("dynamic-slice", "slice", "transpose", "copy",
                        "concatenate", "convert", "reverse", "pad",
                        "gather", "scatter"):
            st.bytes += 2.0 * _bytes_of(type_str)   # read + write ~ result
        elif opcode in ("broadcast",):
            st.bytes += float(_bytes_of(type_str))  # write-only
        else:
            st.bytes += float(_bytes_of(type_str) + _operand_bytes())

        if opcode == "dot":
            lhs_ents = _operand_arrays(operand_strs[0]) if operand_strs \
                else []
            lhs_shape = lhs_ents[0][1] if lhs_ents else None
            cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rest)
            contract = 1
            if lhs_shape is not None and cdims:
                for d in cdims.group(1).split(","):
                    if d:
                        contract *= lhs_shape[int(d)]
            result_numel = sum(_numel(d) for _, d in arrs)
            st.flops += 2.0 * result_numel * contract
        elif opcode in ("convolution",):
            # conservative: treat like a dot over the kernel volume
            result_numel = sum(_numel(d) for _, d in arrs)
            st.flops += 2.0 * result_numel
        elif _collective_base(opcode) in _COLLECTIVES:
            base = _collective_base(opcode)
            if not opcode.endswith("-done"):
                nbytes = _bytes_of(type_str)
                g = _group_size(rest)
                if base == "all-reduce":
                    traffic = 2.0 * nbytes
                elif base == "reduce-scatter":
                    traffic = float(nbytes) * g
                else:
                    traffic = float(nbytes)
                st.coll_bytes[base] += traffic
                st.coll_counts[base] += 1

        # call edges: (callee, flop_mult, bytes_mult). Fusion bodies don't
        # touch HBM (bytes_mult 0); while bodies run `trip` times for both.
        if opcode == "fusion":
            m = re.search(r"calls=%?([\w\.\-]+)", rest)
            if m:
                st.calls.append((m.group(1), 1.0, 0.0))
        elif opcode == "call":
            m = re.search(r"to_apply=%?([\w\.\-]+)", rest)
            if m:
                st.calls.append((m.group(1), 1.0, 1.0))
        elif opcode == "while":
            mw = re.search(r"body=%?([\w\.\-]+)", rest)
            trip = 1.0
            mt = re.search(r'known_trip_count["\']?:\s*\{"n":"(\d+)"', rest)
            if not mt:
                mt = re.search(r"trip_count=(\d+)", rest)
            if mt:
                trip = float(mt.group(1))
            else:
                st.unknown_trip += 1
            if mw:
                st.calls.append((mw.group(1), trip, trip))
            mcnd = re.search(r"condition=%?([\w\.\-]+)", rest)
            if mcnd:
                st.calls.append((mcnd.group(1), trip, 0.0))
        elif opcode == "conditional":
            mb = re.search(r"branch_computations=\{([^}]*)\}", rest)
            if mb:
                for b in mb.group(1).split(","):
                    st.calls.append((b.strip().lstrip("%"), 1.0, 1.0))
        else:
            # reduce/sort/map/scatter apply tiny computations; flops only
            for m in re.finditer(r"to_apply=%?([\w\.\-]+)", rest):
                st.calls.append((m.group(1), 1.0, 0.0))

    return comps, entry


def op_counts(hlo: str) -> Dict:
    """Structural op counts of a compiled HLO module.

    Counts the instructions each computation dispatches *itself*: a
    fusion, call, or while is ONE op of the computation that contains it
    (its internals belong to the callee computation); `parameter`
    declarations are not ops. Returns the per-computation counts, the
    entry-computation count, and the op counts of every while-loop body.

    This is the structural complement to `analyze_hlo`'s flop/byte
    totals: a while body's op count is the size of the HLO graph XLA
    re-dispatches on every loop trip, while `entry_ops` is what the
    module dispatches once per call. The fleet benchmark's fused-segment
    proof (benchmarks/fleet.py, DESIGN.md §9.7) compares the two: the
    XLA segment stepper re-dispatches its whole step graph once per
    architectural step, the fused Pallas segment dispatches a single
    kernel unit per segment.
    """
    counts: Dict[str, int] = {}
    entry = None
    cur = None
    body_names = []
    for raw in hlo.splitlines():
        mc = _COMP_RE.match(raw)
        if mc and not raw.startswith(" "):
            cur = mc.group(2)
            counts[cur] = 0
            if mc.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(raw)
        if not mi:
            continue
        rest = mi.group(2)
        if re.search(r"\bparameter\(", rest):
            continue
        counts[cur] += 1
        if re.search(r"\bwhile\(", rest):
            mb = re.search(r"body=%?([\w\.\-]+)", rest)
            if mb:
                body_names.append(mb.group(1))
    if entry is None:
        raise ValueError("no ENTRY computation found")
    body_ops = {b: counts.get(b, 0) for b in body_names}
    return {
        "entry": entry,
        "entry_ops": counts[entry],
        "computations": counts,
        "while_body_ops": body_ops,
        "max_while_body_ops": max(body_ops.values(), default=0),
    }


def analyze_hlo(hlo: str) -> Dict:
    """Loop-aware totals per device: flops, collective bytes, counts."""
    comps, entry = _parse(hlo)
    memo: Dict[str, Dict] = {}

    def zero():
        return {"flops": 0.0, "bytes": 0.0,
                "coll": {k: 0.0 for k in _COLLECTIVES},
                "counts": {k: 0 for k in _COLLECTIVES},
                "unknown_trip": 0}

    def visit(name: str) -> Dict:
        if name in memo:
            return memo[name]
        st = comps.get(name)
        if st is None:
            return zero()
        memo[name] = zero()  # break cycles defensively
        total = {"flops": st.flops, "bytes": st.bytes,
                 "coll": dict(st.coll_bytes),
                 "counts": dict(st.coll_counts),
                 "unknown_trip": st.unknown_trip}
        for callee, fmult, bmult in st.calls:
            sub = visit(callee)
            total["flops"] += fmult * sub["flops"]
            total["bytes"] += bmult * sub["bytes"]
            for k in _COLLECTIVES:
                total["coll"][k] += fmult * sub["coll"][k]
                total["counts"][k] += sub["counts"][k]
            total["unknown_trip"] += sub["unknown_trip"]
        memo[name] = total
        return total

    if entry is None:
        raise ValueError("no ENTRY computation found")
    t = visit(entry)
    return {
        "flops_per_device": t["flops"],
        "bytes_per_device": t["bytes"],
        "collective_bytes_per_device": sum(t["coll"].values()),
        "collective_per_op": t["coll"],
        "collective_counts": t["counts"],
        "unknown_trip_counts": t["unknown_trip"],
    }
