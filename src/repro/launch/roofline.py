"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs / (chips * peak_FLOPs)
  memory term     = HLO_bytes / (chips * HBM_bw)
  collective term = collective_bytes / (chips * link_bw)

Hardware constants (TPU v5e target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (assignment spec).

FLOPs / bytes / collective bytes come from `hlo_analysis.analyze_hlo` on the
post-SPMD optimized HLO (loop-aware: while bodies x trip counts), because
``compiled.cost_analysis()`` counts scan bodies once. The analyzer returns
PER-DEVICE quantities; HLO_FLOPs(global) = per_device * chips, so the
chips-normalized terms below use per-device values directly.
"""
from __future__ import annotations

from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

# kept for backward compat in dryrun artifacts
from repro.launch.hlo_analysis import analyze_hlo  # noqa: F401,E402


def roofline_terms(result: Dict, n_chips: int) -> Dict:
    """Three terms (seconds) + bottleneck + usefulness ratio.

    `result` must contain 'hlo' (analyze_hlo output) and 'model_flops'.
    """
    h = result.get("hlo", {})
    flops_dev = float(h.get("flops_per_device", 0.0))
    bytes_dev = float(h.get("bytes_per_device", 0.0))
    coll_dev = float(h.get("collective_bytes_per_device", 0.0))

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    mf = float(result.get("model_flops", 0.0))
    flops_global = flops_dev * n_chips
    bound = max(max(terms.values()), 1e-30)
    # roofline fraction = the kind-appropriate *ideal* step time over the
    # bound step time. Train/prefill are compute-ideal (MFU-style); decode
    # is memory-ideal: every step must at least stream the weights + the
    # batch's decode state from HBM.
    ideal_compute_s = mf / (n_chips * PEAK_FLOPS)
    ideal_s = ideal_compute_s
    if result.get("kind") == "decode":
        floor_bytes = (float(result.get("param_bytes", 0))
                       + float(result.get("cache_bytes", 0))) / n_chips
        ideal_s = max(ideal_compute_s, floor_bytes / HBM_BW)
    return {
        **terms,
        "bottleneck": dom,
        "model_flops": mf,
        "hlo_flops": flops_global,
        "useful_ratio": (mf / flops_global) if flops_global else 0.0,
        "bound_step_s": bound,
        "ideal_step_s": ideal_s,
        "roofline_fraction": ideal_s / bound,
    }
