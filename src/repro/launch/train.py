"""Production train loop: auto-resume, atomic checkpoints, straggler
watchdog, optional gradient accumulation. Runs the real thing on whatever
devices exist (CPU smoke = 1 device; pods = the production mesh).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 20 --ckpt-dir /tmp/ckpt [--batch 8 --seq 128]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, host_batch
from repro.distributed import checkpoint as ckpt
from repro.distributed.meshctx import mesh_context
from repro.distributed.sharding import (batch_shardings, opt_shardings,
                                        param_shardings)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models.model import build_model


class StragglerWatchdog:
    """Flags steps slower than `factor` x the running median. On real pods
    this feeds the rescheduling hook; here it logs (and is unit-tested)."""

    def __init__(self, factor: float = 2.0, warmup: int = 3):
        self.times = []
        self.factor = factor
        self.warmup = warmup
        self.flagged = []

    def observe(self, step: int, dt: float) -> bool:
        slow = (len(self.times) >= self.warmup
                and dt > self.factor * float(np.median(self.times)))
        self.times.append(dt)
        if slow:
            self.flagged.append((step, dt))
        return slow


def train_loop(*, cfg, steps: int, batch: int, seq: int, ckpt_dir: str,
               mesh=None, ckpt_every: int = 10, grad_accum: int = 1,
               lr_kwargs=None, log=print):
    mesh = mesh or make_host_mesh()
    model = build_model(cfg)
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    opt_init, train_step = make_train_step(model, grad_accum=grad_accum,
                                           lr_kwargs=lr_kwargs)

    with mesh_context(mesh):
        params_abs = model.abstract_params()
        p_sh = param_shardings(params_abs, mesh)
        opt_abs = jax.eval_shape(opt_init, params_abs)
        o_sh = opt_shardings(opt_abs, mesh, zero1=cfg.zero1)

        start = ckpt.latest_step(ckpt_dir) if ckpt_dir else None
        if start is not None:
            state = {"params": params_abs, "opt": opt_abs}
            restored, _ = ckpt.restore(
                ckpt_dir, state, shardings={"params": p_sh, "opt": o_sh})
            params, opt_state = restored["params"], restored["opt"]
            start_step = start
            log(f"[train] resumed from step {start}")
        else:
            params = jax.jit(model.init_params, out_shardings=p_sh)(
                jax.random.key(0))
            opt_state = jax.jit(opt_init, out_shardings=o_sh)(params)
            start_step = 0

        sample = host_batch(dcfg, 0)
        b_sh = batch_shardings(
            jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                         sample), mesh)
        jstep = jax.jit(train_step,
                        in_shardings=(p_sh, o_sh, b_sh, None),
                        out_shardings=(p_sh, o_sh, None),
                        donate_argnums=(0, 1))

        watchdog = StragglerWatchdog()
        losses = []
        for step in range(start_step, steps):
            bt = host_batch(dcfg, step)
            bt = jax.tree.map(
                lambda x, s: jax.device_put(x, s), bt, b_sh)
            t0 = time.perf_counter()
            params, opt_state, metrics = jstep(params, opt_state, bt,
                                               jnp.int32(step))
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            slow = watchdog.observe(step, dt)
            losses.append(loss)
            log(f"[train] step={step} loss={loss:.4f} dt={dt * 1e3:.0f}ms"
                + (" SLOW" if slow else ""))
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                ckpt.save(ckpt_dir, step + 1,
                          {"params": params, "opt": opt_state})
        if ckpt_dir:
            ckpt.save(ckpt_dir, steps, {"params": params, "opt": opt_state})
        return {"losses": losses, "flagged": watchdog.flagged,
                "params": params}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--production-mesh", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(
        args.arch)
    mesh = make_production_mesh() if args.production_mesh else None
    out = train_loop(cfg=cfg, steps=args.steps, batch=args.batch,
                     seq=args.seq, ckpt_dir=args.ckpt_dir, mesh=mesh,
                     grad_accum=args.grad_accum)
    print(json.dumps({"first_loss": out["losses"][0],
                      "last_loss": out["losses"][-1],
                      "n_flagged": len(out["flagged"])}))


if __name__ == "__main__":
    main()
