"""jit-able train/prefill/decode step builders shared by train.py, serve.py
and the dry-run."""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.model import Model
from repro.optim import (clip_by_norm, cosine_schedule, make_optimizer)


def make_train_step(model: Model, *, grad_accum: int = 1,
                    max_grad_norm: float = 1.0, lr_kwargs=None):
    """Returns (init_opt_state, train_step).

    train_step(params, opt_state, batch, step) ->
        (params, opt_state, metrics)
    """
    cfg = model.cfg
    opt_init, opt_update = make_optimizer(cfg.optimizer)
    lr_kwargs = lr_kwargs or {}

    def loss_for_grad(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_for_grad, has_aux=True)

    def train_step(params, opt_state, batch, step):
        if grad_accum > 1:
            def micro(carry, mb):
                gsum, msum = carry
                (loss, metrics), grads = grad_fn(params, mb)
                gsum = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / grad_accum,
                    gsum, grads)
                return (gsum, msum + loss / grad_accum), None

            def split(x):
                return x.reshape((grad_accum, x.shape[0] // grad_accum)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), _ = jax.lax.scan(micro, (g0, 0.0), mbs)
            metrics = {"xent": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        grads, gnorm = clip_by_norm(grads, max_grad_norm)
        lr = cosine_schedule(step, **lr_kwargs)
        params, opt_state = opt_update(params, grads, opt_state, lr)
        metrics = dict(metrics, gnorm=gnorm, lr=lr,
                       loss=metrics.get("xent", 0.0))
        return params, opt_state, metrics

    return opt_init, train_step


def make_prefill_step(model: Model, seq_len: int):
    def prefill_step(params, batch):
        return model.prefill_fn(params, batch, seq_len)
    return prefill_step


def make_decode_step(model: Model):
    def decode_step(params, cache, tokens, pos):
        return model.decode_fn(params, cache, tokens, pos)
    return decode_step
