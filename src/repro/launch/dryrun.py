import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first
# backend initialization. 512 placeholder host devices let jax.make_mesh
# build the production meshes; nothing is ever allocated (AOT lower/compile
# over ShapeDtypeStructs only).
# Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
# cell, print memory/cost analysis, and dump roofline raw terms to JSON.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b \
#       --shape train_4k [--multi-pod] [--out artifacts/dryrun]
#   PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import SHAPES_BY_NAME, shape_applicable
from repro.configs.registry import ARCH_IDS, get_config
from repro.distributed.meshctx import mesh_context
from repro.distributed.sharding import (batch_shardings, cache_shardings,
                                        opt_shardings, param_shardings)
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.roofline import roofline_terms
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models.model import (build_model, count_params_abstract,
                                input_specs, model_flops)


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               compile_: bool = True, config_overrides=None):
    """Lower (and compile) one dry-run cell. Returns a result dict."""
    cfg = get_config(arch)
    if config_overrides:
        cfg = cfg.replace(**config_overrides)
    shape = SHAPES_BY_NAME[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    model = build_model(cfg)
    t0 = time.time()

    with mesh_context(mesh):
        params_abs = model.abstract_params()
        p_sh = param_shardings(params_abs, mesh)
        specs = input_specs(cfg, shape)
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "x".join(str(s) for s in mesh.devices.shape),
                  "multi_pod": multi_pod, "status": "ok",
                  "n_params": count_params_abstract(model)}

        if shape.kind == "train":
            opt_init, train_step = make_train_step(model)
            opt_abs = _abstract(opt_init, params_abs)
            o_sh = opt_shardings(opt_abs, mesh, zero1=cfg.zero1)
            b_sh = batch_shardings(specs, mesh)
            step_abs = jax.ShapeDtypeStruct((), jnp.int32)
            jitted = jax.jit(
                train_step,
                in_shardings=(p_sh, o_sh, b_sh, None),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1))
            lowered = jitted.lower(params_abs, opt_abs, specs, step_abs)
        elif shape.kind == "prefill":
            prefill_step = make_prefill_step(model, shape.seq_len)
            b_sh = batch_shardings(specs, mesh)
            cache_abs = _abstract(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_sh = cache_shardings(cache_abs, mesh)
            jitted = jax.jit(prefill_step, in_shardings=(p_sh, b_sh),
                             out_shardings=(None, c_sh))
            lowered = jitted.lower(params_abs, specs)
        else:  # decode
            decode_step = make_decode_step(model)
            cache_abs = _abstract(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            c_sh = cache_shardings(cache_abs, mesh)
            tok_sh = batch_shardings(
                {"tokens": specs["tokens"]}, mesh)["tokens"]
            jitted = jax.jit(decode_step,
                             in_shardings=(p_sh, c_sh, tok_sh, None),
                             out_shardings=(None, c_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_abs, cache_abs, specs["tokens"],
                                   specs["pos"])

        result["lower_s"] = round(time.time() - t0, 1)
        if not compile_:
            return result

        t1 = time.time()
        compiled = lowered.compile()
        result["compile_s"] = round(time.time() - t1, 1)

        # ---- memory analysis
        try:
            ma = compiled.memory_analysis()
            result["memory"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not provide it
            result["memory"] = {"error": str(e)}

        # ---- cost analysis
        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, (list, tuple)):
                ca = ca[0]
            result["cost"] = {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))
                              and k in ("flops", "bytes accessed",
                                        "transcendentals")}
        except Exception as e:
            result["cost"] = {"error": str(e)}

        # ---- loop-aware FLOPs/bytes/collectives from the post-SPMD HLO
        try:
            hlo = compiled.as_text()
            result["hlo"] = analyze_hlo(hlo)
        except Exception as e:
            result["hlo"] = {"error": str(e)}

        result["model_flops"] = model_flops(cfg, shape, result["n_params"])
        # ideal-traffic floor (decode roofline): weights + decode state
        param_bytes = sum(
            int(jnp.dtype(l.dtype).itemsize) * int(jnp.prod(
                jnp.asarray(l.shape))) if l.shape else
            jnp.dtype(l.dtype).itemsize
            for l in jax.tree.leaves(params_abs))
        result["param_bytes"] = int(param_bytes)
        if shape.kind == "decode":
            cache_bytes = sum(
                int(jnp.dtype(l.dtype).itemsize) * int(jnp.prod(
                    jnp.asarray(l.shape))) if l.shape else 0
                for l in jax.tree.leaves(cache_abs))
            result["cache_bytes"] = int(cache_bytes)
        result["kind"] = shape.kind
        n_chips = int(mesh.devices.size)
        result["roofline"] = roofline_terms(result, n_chips)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES_BY_NAME))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCH_IDS:
            for shape_name in SHAPES_BY_NAME:
                cells.append((arch, shape_name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]

    os.makedirs(args.out, exist_ok=True)
    for arch, shape_name in cells:
        tag = f"{arch}__{shape_name}__{'pod2' if args.multi_pod else 'pod1'}"
        path = os.path.join(args.out, tag + ".json")
        try:
            res = lower_cell(arch, shape_name, multi_pod=args.multi_pod,
                             compile_=not args.no_compile)
        except Exception as e:
            res = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}",
                   "traceback": traceback.format_exc()[-2000:]}
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = res["status"]
        extra = ""
        if status == "ok":
            rf = res.get("roofline", {})
            extra = (f" flops/dev={res['hlo'].get('flops_per_device', 0):.3e}"
                     f" bottleneck={rf.get('bottleneck')}"
                     f" frac={rf.get('roofline_fraction', 0):.3f}"
                     f" compile={res.get('compile_s')}s")
        elif status == "error":
            extra = " " + res["error"][:200]
        print(f"[dryrun] {tag}: {status}{extra}", flush=True)


if __name__ == "__main__":
    main()
