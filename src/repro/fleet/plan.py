"""Heterogeneous fleet plans: (workload, core) sub-fleets in one run.

The paper's fleet is not uniform — items differ in workload, datapath
width, deployment lifetime, and task frequency (1000X lifetime variation,
Table 2). A `FleetPlan` expresses that: each `FleetGroup` pins a
FlexiBench workload to a FLEXIBITS core and a deployment profile, and
`run_plan` drives every group through the same streaming engine
(DESIGN.md §9.3), collecting per-group cycle/energy tallies for the
carbon report.

Plans are statically checked before anything runs (DESIGN.md §9.11):
FlexiLint's shortest-path-to-HALT bound rejects `max_steps` budgets
that provably cannot reach the ecall (`BudgetError`), `max_steps=
"static"` derives the budget from the program's WCET instead of a
hand-picked number, and `subset_source="static"` specializes the
steppers with the analyzer's reachable-only opcode subset. Each group
also carries a certified worst-case cycle bound into the report so
the carbon table prints proved ceilings next to measured means.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

from jax.sharding import Mesh

from repro.flexibench import base as fb
from repro.flexibits import analyze
from repro.flexibits.cycles import CORES, TICKS_PER_CYCLE, Core, cost_row
from repro.flexibits.faults import FaultSpec
from repro.fleet import engine
from repro.fleet.report import FleetReport, build_group_report


class BudgetError(ValueError):
    """A group's `max_steps` budget is statically proved insufficient:
    FlexiLint's shortest path to HALT (`Analysis.min_steps`, a sound
    lower bound on retirements) already exceeds the budget, so every
    lane would be cut off before the ecall."""

    def __init__(self, name: str, budget: int, min_steps: int):
        self.name = name
        self.budget = budget
        self.min_steps = min_steps
        super().__init__(
            f"workload {name!r}: max_steps budget {budget} cannot reach "
            f"HALT — the statically shortest path to the ecall retires "
            f"{min_steps} instructions (FlexiLint min_steps, §9.11)")


@dataclasses.dataclass(frozen=True)
class FleetGroup:
    """One homogeneous sub-fleet: n_items of one workload on one core.

    `max_steps` is the per-item retirement budget: None takes the
    workload's hand-set value, an int overrides it, and the string
    "static" derives it from FlexiLint's WCET instruction bound —
    a budget *proved* sufficient for every input (errors out if the
    program has no finite static bound)."""
    workload: str                         # FlexiBench key (WQ, MC, ...)
    core: str = "SERV"                    # FLEXIBITS core name
    n_items: int = 1024
    seed: int = 0
    lifetime_s: Optional[float] = None    # default: workload Table-2 value
    execs_per_day: Optional[float] = None
    max_steps: Union[int, str, None] = None   # int | "static" | None

    def resolve(self) -> Tuple[fb.Workload, Core, float, float]:
        w = fb.get(self.workload)
        core = CORES[self.core]
        life = self.lifetime_s if self.lifetime_s is not None \
            else w.lifetime_s
        freq = self.execs_per_day if self.execs_per_day is not None \
            else w.execs_per_day
        return w, core, life, freq

    def resolve_max_steps(self, w: fb.Workload,
                          analysis: analyze.Analysis) -> int:
        """The group's effective per-item step budget (see class doc)."""
        if self.max_steps == "static":
            if analysis.wcet_steps is None:
                raise ValueError(
                    f"workload {w.key!r}: max_steps='static' needs a "
                    f"finite FlexiLint WCET, but the analysis has none "
                    f"(degraded: {analysis.degraded!r})")
            return analysis.wcet_steps
        if self.max_steps is not None:
            return int(self.max_steps)
        return w.max_steps


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """A full heterogeneous fleet plus engine tuning knobs.

    `stepper` selects the segment interpreter: "branchless" (lane-
    parallel stepper with per-workload opcode-subset specialization,
    DESIGN.md §9.5), "pallas" (fused-segment kernel, §9.7), or the
    legacy "switch" interpreter for A/B runs; `prefetch` enables
    double-buffered async host refill (§9.6); `packed` (the default)
    executes ALL groups in one packed multi-program stream — program
    bank + per-lane prog_id, freed lanes backfilled from any pending
    group (§9.8) — instead of draining groups sequentially. Per-group
    results are bit-exact either way (pinned by tests/test_packed.py);
    `packed=False` keeps the sequential path as the A/B baseline.

    `refill` picks the stream loop (§9.9): "device" (default) is the
    resident runtime — on-device retire/refill, one small async stats
    read per segment — and "host" the PR-4 blocking host-refill loop,
    kept for A/B runs; results are bit-exact either way
    (tests/test_resident.py). `adaptive` turns on the superstep
    controller: each segment's step bound is picked from a bounded
    ladder under `seg_steps` by the observed halt cadence
    (deterministic for a given plan, bit-exact with fixed
    segmentation).

    `timing` turns on the per-lane cycle layer (DESIGN.md §9.10): each
    group's lanes accumulate ticks from its core's cost row
    (`cycles.cost_row`) and the carbon report prices the group from the
    *measured* mean cycles instead of the two-bucket analytic model.
    "base" prices only the per-(stage, class) table — numerically
    identical to the analytic model, an end-to-end consistency mode —
    while "dynamic" additionally prices taken-branch refetch, serial
    shift amount, and subword read-modify-write. None (default) keeps
    the cycles-off graphs and analytic pricing.

    `validate_budgets` (default on) runs FlexiLint over every group
    before launch and raises `BudgetError` when a `max_steps` budget is
    statically proved unable to reach HALT; `subset_source` picks the
    steppers' opcode-subset oracle — "text" (default) scans the encoded
    words as data (`iss.opcode_subset`), "static" uses the analyzer's
    reachable-only subset (DESIGN.md §9.11), which can be strictly
    smaller when dead code carries opcode classes the program never
    retires. Results are bit-exact either way (tests/test_flexilint.py
    pins it).

    `faults`/`redundancy`/`max_retries` turn on the FlexiFault
    resilience layer (DESIGN.md §9.14): a deterministic counter-based
    fault schedule injected into every lane, and — with
    `redundancy="dmr"` — shadow-lane detection with segment-granular
    re-execution and quarantine. Resilient plans require the resident
    refill loop; `faults=None` with `redundancy="none"` (the default)
    keeps the fault-free graphs bit-exact. The report prices each group
    under the plan's redundancy mode (`carbon.redundant_*`), so DMR
    plans show the spare-area + re-execution carbon they'd pay in
    deployment."""
    groups: Sequence[FleetGroup]
    chunk: int = 256
    seg_steps: int = 4096
    intensity: float = 0.367              # kg CO2e/kWh (US grid)
    clock_hz: float = 10_000.0
    stepper: str = "branchless"
    prefetch: bool = True
    packed: bool = True
    refill: str = "device"
    adaptive: bool = False
    timing: Optional[str] = None          # None | "base" | "dynamic"
    validate_budgets: bool = True         # FlexiLint min-steps gate
    subset_source: str = "text"           # "text" | "static"
    faults: Optional[FaultSpec] = None    # FlexiFault schedule (§9.14)
    redundancy: str = "none"              # "none" | "dmr"
    max_retries: int = 2                  # DMR rollbacks before quarantine

    @property
    def n_items(self) -> int:
        return sum(g.n_items for g in self.groups)


def _group_cost(plan: FleetPlan, core: Core):
    """The group's engine cost row under the plan's timing mode."""
    if plan.timing is None:
        return None
    if plan.timing not in ("base", "dynamic"):
        raise ValueError('timing must be None, "base", or "dynamic"')
    return cost_row(core, dynamic=plan.timing == "dynamic")


def _static_pass(plan: FleetPlan, g: FleetGroup, w: fb.Workload,
                 core: Core):
    """FlexiLint pre-flight for one group (DESIGN.md §9.11): resolve the
    step budget (possibly WCET-derived), reject provably-insufficient
    budgets, pick the stepper subset, and price the certified
    worst-case cycle bound for the report.

    The certificate always uses the *dynamic* cost row — the bound must
    hold on real hardware, where taken-branch refetch, serial shifts,
    and subword RMW all cost ticks — so a "base"-timing run's measured
    mean sits under it a fortiori."""
    if plan.subset_source not in ("text", "static"):
        raise ValueError('subset_source must be "text" or "static"')
    analysis = analyze.analyze_workload(w)
    max_steps = g.resolve_max_steps(w, analysis)
    if plan.validate_budgets and analysis.min_steps is not None \
            and max_steps < analysis.min_steps:
        raise BudgetError(w.key, max_steps, analysis.min_steps)
    subset = analysis.subset if plan.subset_source == "static" else None
    wcet_ticks = analysis.bound_ticks(cost_row(core, dynamic=True),
                                      max_steps)
    wcet_cycles = None if wcet_ticks is None \
        else wcet_ticks / TICKS_PER_CYCLE
    return max_steps, subset, wcet_cycles


def _packed_groups(plan: FleetPlan):
    """Lower FleetGroups to engine-level PackedGroups (one bank row per
    group — two groups sharing a workload still get their own rows, so
    prog_id doubles as the group id for accounting)."""
    lowered = []
    resolved = []
    for g in plan.groups:
        w, core, lifetime_s, execs_per_day = g.resolve()
        max_steps, subset, wcet_cycles = _static_pass(plan, g, w, core)
        resolved.append((w, core, lifetime_s, execs_per_day, wcet_cycles))
        lowered.append(engine.PackedGroup(
            code=w.program.code, source=engine.workload_source(w, g.seed),
            n_items=g.n_items, max_steps=max_steps,
            mem_words=w.total_mem_words, out_addr=w.out_addr,
            cost=_group_cost(plan, core), subset=subset))
    return lowered, resolved


def run_plan(plan: FleetPlan, mesh: Optional[Mesh] = None,
             keep_state: bool = False,
             checkpoint_dir: Optional[str] = None,
             checkpoint_every: int = 0) -> FleetReport:
    """Execute the plan and price it through the carbon report.

    With `plan.packed` (the default) every group runs in ONE packed
    stream (engine.run_packed) and `fleet/report.py` demuxes the
    per-lane tallies back into per-group `GroupReport`s; with
    `packed=False` groups drain sequentially through `run_stream`, one
    stream each — the A/B baseline the packed runtime is benchmarked
    (and pinned bit-exact) against. Under a mesh the resident stream is
    shard-local (DESIGN.md §9.12) and the returned
    `FleetReport.packed` carries per-shard retirement/lane-step stats;
    `checkpoint_dir`/`checkpoint_every` make the packed resident stream
    durable (mid-flight checkpoint + bit-exact auto-resume — packed
    plans only).
    """
    if checkpoint_dir is not None and not (plan.packed and plan.groups):
        raise ValueError("checkpointing requires a packed plan")
    if plan.packed and plan.groups:
        lowered, resolved = _packed_groups(plan)
        results, stats = engine.run_packed(
            lowered, chunk=plan.chunk, seg_steps=plan.seg_steps,
            keep_state=keep_state, mesh=mesh, stepper=plan.stepper,
            prefetch=plan.prefetch, refill=plan.refill,
            adaptive=plan.adaptive, checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every, faults=plan.faults,
            redundancy=plan.redundancy, max_retries=plan.max_retries)
        group_reports = [
            build_group_report(
                group=g, workload=w, core=core, result=res,
                lifetime_s=lifetime_s, execs_per_day=execs_per_day,
                intensity=plan.intensity, clock_hz=plan.clock_hz,
                wcet_cycles=wcet_cycles, redundancy=plan.redundancy,
                fault_rate=0.0 if plan.faults is None
                else plan.faults.rate)
            for g, (w, core, lifetime_s, execs_per_day, wcet_cycles), res
            in zip(plan.groups, resolved, results)]
        return FleetReport(groups=group_reports, intensity=plan.intensity,
                           packed=stats)

    group_reports = []
    for g in plan.groups:
        w, core, lifetime_s, execs_per_day = g.resolve()
        max_steps, subset, wcet_cycles = _static_pass(plan, g, w, core)
        res = engine.run_workload_stream(
            w, g.n_items, seed=g.seed, chunk=plan.chunk,
            seg_steps=plan.seg_steps, max_steps=max_steps,
            keep_state=keep_state, mesh=mesh, stepper=plan.stepper,
            prefetch=plan.prefetch, refill=plan.refill,
            adaptive=plan.adaptive, cost=_group_cost(plan, core),
            subset=subset, faults=plan.faults,
            redundancy=plan.redundancy, max_retries=plan.max_retries)
        group_reports.append(build_group_report(
            group=g, workload=w, core=core, result=res,
            lifetime_s=lifetime_s, execs_per_day=execs_per_day,
            intensity=plan.intensity, clock_hz=plan.clock_hz,
            wcet_cycles=wcet_cycles, redundancy=plan.redundancy,
            fault_rate=0.0 if plan.faults is None else plan.faults.rate))
    return FleetReport(groups=group_reports, intensity=plan.intensity)
