"""Heterogeneous fleet plans: (workload, core) sub-fleets in one run.

The paper's fleet is not uniform — items differ in workload, datapath
width, deployment lifetime, and task frequency (1000X lifetime variation,
Table 2). A `FleetPlan` expresses that: each `FleetGroup` pins a
FlexiBench workload to a FLEXIBITS core and a deployment profile, and
`run_plan` drives every group through the same streaming engine
(DESIGN.md §9.3), collecting per-group cycle/energy tallies for the
carbon report.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

from jax.sharding import Mesh

from repro.flexibench import base as fb
from repro.flexibits.cycles import CORES, Core, cost_row
from repro.fleet import engine
from repro.fleet.report import FleetReport, build_group_report


@dataclasses.dataclass(frozen=True)
class FleetGroup:
    """One homogeneous sub-fleet: n_items of one workload on one core."""
    workload: str                         # FlexiBench key (WQ, MC, ...)
    core: str = "SERV"                    # FLEXIBITS core name
    n_items: int = 1024
    seed: int = 0
    lifetime_s: Optional[float] = None    # default: workload Table-2 value
    execs_per_day: Optional[float] = None
    max_steps: Optional[int] = None

    def resolve(self) -> Tuple[fb.Workload, Core, float, float]:
        w = fb.get(self.workload)
        core = CORES[self.core]
        life = self.lifetime_s if self.lifetime_s is not None \
            else w.lifetime_s
        freq = self.execs_per_day if self.execs_per_day is not None \
            else w.execs_per_day
        return w, core, life, freq


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """A full heterogeneous fleet plus engine tuning knobs.

    `stepper` selects the segment interpreter: "branchless" (lane-
    parallel stepper with per-workload opcode-subset specialization,
    DESIGN.md §9.5), "pallas" (fused-segment kernel, §9.7), or the
    legacy "switch" interpreter for A/B runs; `prefetch` enables
    double-buffered async host refill (§9.6); `packed` (the default)
    executes ALL groups in one packed multi-program stream — program
    bank + per-lane prog_id, freed lanes backfilled from any pending
    group (§9.8) — instead of draining groups sequentially. Per-group
    results are bit-exact either way (pinned by tests/test_packed.py);
    `packed=False` keeps the sequential path as the A/B baseline.

    `refill` picks the stream loop (§9.9): "device" (default) is the
    resident runtime — on-device retire/refill, one small async stats
    read per segment — and "host" the PR-4 blocking host-refill loop,
    kept for A/B runs; results are bit-exact either way
    (tests/test_resident.py). `adaptive` turns on the superstep
    controller: each segment's step bound is picked from a bounded
    ladder under `seg_steps` by the observed halt cadence
    (deterministic for a given plan, bit-exact with fixed
    segmentation).

    `timing` turns on the per-lane cycle layer (DESIGN.md §9.10): each
    group's lanes accumulate ticks from its core's cost row
    (`cycles.cost_row`) and the carbon report prices the group from the
    *measured* mean cycles instead of the two-bucket analytic model.
    "base" prices only the per-(stage, class) table — numerically
    identical to the analytic model, an end-to-end consistency mode —
    while "dynamic" additionally prices taken-branch refetch, serial
    shift amount, and subword read-modify-write. None (default) keeps
    the cycles-off graphs and analytic pricing."""
    groups: Sequence[FleetGroup]
    chunk: int = 256
    seg_steps: int = 4096
    intensity: float = 0.367              # kg CO2e/kWh (US grid)
    clock_hz: float = 10_000.0
    stepper: str = "branchless"
    prefetch: bool = True
    packed: bool = True
    refill: str = "device"
    adaptive: bool = False
    timing: Optional[str] = None          # None | "base" | "dynamic"

    @property
    def n_items(self) -> int:
        return sum(g.n_items for g in self.groups)


def _group_cost(plan: FleetPlan, core: Core):
    """The group's engine cost row under the plan's timing mode."""
    if plan.timing is None:
        return None
    if plan.timing not in ("base", "dynamic"):
        raise ValueError('timing must be None, "base", or "dynamic"')
    return cost_row(core, dynamic=plan.timing == "dynamic")


def _packed_groups(plan: FleetPlan):
    """Lower FleetGroups to engine-level PackedGroups (one bank row per
    group — two groups sharing a workload still get their own rows, so
    prog_id doubles as the group id for accounting)."""
    lowered = []
    resolved = []
    for g in plan.groups:
        w, core, lifetime_s, execs_per_day = g.resolve()
        resolved.append((w, core, lifetime_s, execs_per_day))
        lowered.append(engine.PackedGroup(
            code=w.program.code, source=engine.workload_source(w, g.seed),
            n_items=g.n_items,
            max_steps=g.max_steps if g.max_steps is not None
            else w.max_steps,
            mem_words=w.total_mem_words, out_addr=w.out_addr,
            cost=_group_cost(plan, core)))
    return lowered, resolved


def run_plan(plan: FleetPlan, mesh: Optional[Mesh] = None,
             keep_state: bool = False) -> FleetReport:
    """Execute the plan and price it through the carbon report.

    With `plan.packed` (the default) every group runs in ONE packed
    stream (engine.run_packed) and `fleet/report.py` demuxes the
    per-lane tallies back into per-group `GroupReport`s; with
    `packed=False` groups drain sequentially through `run_stream`, one
    stream each — the A/B baseline the packed runtime is benchmarked
    (and pinned bit-exact) against.
    """
    if plan.packed and plan.groups:
        lowered, resolved = _packed_groups(plan)
        results, stats = engine.run_packed(
            lowered, chunk=plan.chunk, seg_steps=plan.seg_steps,
            keep_state=keep_state, mesh=mesh, stepper=plan.stepper,
            prefetch=plan.prefetch, refill=plan.refill,
            adaptive=plan.adaptive)
        group_reports = [
            build_group_report(
                group=g, workload=w, core=core, result=res,
                lifetime_s=lifetime_s, execs_per_day=execs_per_day,
                intensity=plan.intensity, clock_hz=plan.clock_hz)
            for g, (w, core, lifetime_s, execs_per_day), res
            in zip(plan.groups, resolved, results)]
        return FleetReport(groups=group_reports, intensity=plan.intensity,
                           packed=stats)

    group_reports = []
    for g in plan.groups:
        w, core, lifetime_s, execs_per_day = g.resolve()
        res = engine.run_workload_stream(
            w, g.n_items, seed=g.seed, chunk=plan.chunk,
            seg_steps=plan.seg_steps, max_steps=g.max_steps,
            keep_state=keep_state, mesh=mesh, stepper=plan.stepper,
            prefetch=plan.prefetch, refill=plan.refill,
            adaptive=plan.adaptive, cost=_group_cost(plan, core))
        group_reports.append(build_group_report(
            group=g, workload=w, core=core, result=res,
            lifetime_s=lifetime_s, execs_per_day=execs_per_day,
            intensity=plan.intensity, clock_hz=plan.clock_hz))
    return FleetReport(groups=group_reports, intensity=plan.intensity)
