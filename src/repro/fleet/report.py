"""Fleet-level carbon report: engine tallies priced through the paper's
models.

Each group's measured cycle tallies become a `DeviceProfile` for
core/carbon.py (operational + embodied kg over the group's deployment
lifetime), core/selection.py supplies the carbon-optimal core for the
group's (lifetime, frequency) point, and core/planner.py's datacenter
constants price the *simulation itself* — the TPU-side footprint of
running the fleet through the ISS (DESIGN.md §9.4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional

from repro.core import carbon
from repro.core.planner import CHIP_POWER_W, PUE
from repro.core.selection import optimal_core
from repro.flexibench.base import Workload
from repro.flexibits.cycles import TICKS_PER_CYCLE, Core
from repro.fleet.engine import FleetResult, PackedStats


@dataclasses.dataclass(frozen=True)
class GroupReport:
    group: Any                    # the FleetGroup that produced this row
    workload: Workload
    core: Core
    result: FleetResult
    lifetime_s: float
    execs_per_day: float
    profile: carbon.DeviceProfile      # measured mean instruction counts
    energy_j_per_exec: float           # one execution, one item
    fleet_exec_kwh: float              # one execution of every item
    operational_kg: float              # whole group over its lifetime
    embodied_kg: float                 # whole group (SoC only)
    total_kg: float
    recommended_core: str              # carbon-argmin core for this point
    # mean measured cycles/execution from the engine's per-lane n_cycles
    # tallies (§9.10); None when the group ran cycles-off
    measured_cycles: Optional[float] = None
    # FlexiLint certificate (§9.11): statically proved worst-case
    # cycles/execution (dynamic cost row), and that ceiling priced as
    # energy and lifetime operational carbon. None when the plan ran
    # without the static pass (run_plan always supplies it).
    wcet_cycles: Optional[float] = None
    certified_energy_j: Optional[float] = None
    certified_operational_kg: Optional[float] = None

    @property
    def cycles_per_item(self) -> float:
        """Measured mean cycles when the run carried the timing layer,
        the two-bucket analytic number otherwise."""
        if self.measured_cycles is not None:
            return self.measured_cycles
        return self.core.cycles(self.profile.n_one_stage,
                                self.profile.n_two_stage)

    @property
    def wcet_ratio(self) -> Optional[float]:
        """Certified worst-case cycles / measured-or-analytic mean —
        the looseness of the certificate (>= 1 whenever the mean is a
        dynamic-cost measurement; see tests/test_flexilint.py)."""
        if self.wcet_cycles is None:
            return None
        return self.wcet_cycles / max(self.cycles_per_item, 1e-12)


def build_group_report(*, group: Any, workload: Workload, core: Core,
                       result: FleetResult, lifetime_s: float,
                       execs_per_day: float, intensity: float,
                       clock_hz: float,
                       wcet_cycles: Optional[float] = None,
                       redundancy: str = "none",
                       fault_rate: float = 0.0) -> GroupReport:
    n = max(result.n_items, 1)
    mean_one = float((result.n_instr - result.n_two_stage).sum()) / n
    mean_two = float(result.n_two_stage.sum()) / n
    vm_kb = workload.vm_kb()
    prof = carbon.DeviceProfile(n_one_stage=mean_one, n_two_stage=mean_two,
                                vm_kb=vm_kb, nvm_kb=workload.nvm_kb)
    # timing layer on -> price the group from its accumulated per-lane
    # tick tallies instead of the two-bucket model ("base" cost rows
    # reproduce the analytic number exactly; "dynamic" adds the terms
    # the two-bucket model cannot see, §9.10)
    cycles = None
    if result.n_cycles is not None:
        cycles = float(result.n_cycles.sum()) / n / TICKS_PER_CYCLE
    e_exec = carbon.energy_per_exec_j(core, prof, clock_hz, cycles)
    # resilience pricing (§9.14): spare-area embodied + re-execution
    # operational x SDC derating; "none" at rate 0 (the default) is
    # bitwise the unprotected numbers (factors exactly 1.0/area 0)
    derate = carbon.sdc_derating(
        redundancy, fault_rate=fault_rate,
        n_instr=mean_one + mean_two, width=core.width)
    op_kg = carbon.redundant_operational_kg(
        core, prof, lifetime_s=lifetime_s, execs_per_day=execs_per_day,
        redundancy=redundancy, fault_rate=fault_rate,
        intensity=intensity, clock_hz=clock_hz,
        cycles=cycles) * derate * result.n_items
    emb_kg = carbon.redundant_embodied_kg(core, prof, redundancy) \
        * derate * result.n_items
    best, _ = optimal_core(prof, lifetime_s=lifetime_s,
                           execs_per_day=execs_per_day, intensity=intensity)
    # FlexiLint certificate (§9.11): price the proved worst-case cycle
    # ceiling through the same carbon model as the measured mean
    cert_e = cert_op = None
    if wcet_cycles is not None:
        # the measured op_kg above carries the redundancy energy factor
        # and SDC derating; the certificate must dominate under the SAME
        # provisioning, so scale it by the same multipliers (both are
        # exactly 1.0 at the unprotected defaults)
        res_mult = carbon.redundancy_energy_factor(
            redundancy, fault_rate=fault_rate,
            n_instr=mean_one + mean_two, width=core.width) * derate
        cert_e = carbon.certified_energy_j(core, prof, clock_hz,
                                           wcet_cycles) * res_mult
        cert_op = carbon.certified_operational_kg(
            core, prof, lifetime_s=lifetime_s, execs_per_day=execs_per_day,
            intensity=intensity, clock_hz=clock_hz,
            wcet_cycles=wcet_cycles) * res_mult * result.n_items
    return GroupReport(
        group=group, workload=workload, core=core, result=result,
        lifetime_s=lifetime_s, execs_per_day=execs_per_day, profile=prof,
        energy_j_per_exec=e_exec,
        fleet_exec_kwh=e_exec * result.n_items / 3.6e6,
        operational_kg=op_kg, embodied_kg=emb_kg,
        total_kg=op_kg + emb_kg, recommended_core=best.name,
        measured_cycles=cycles, wcet_cycles=wcet_cycles,
        certified_energy_j=cert_e, certified_operational_kg=cert_op)


def simulation_footprint_kg(wall_s: float, n_chips: int = 1,
                            intensity: float = 0.367) -> float:
    """Carbon of running the simulation itself, using the serving planner's
    datacenter chip model (core/planner.py): chip power x PUE x wall time."""
    kwh = n_chips * CHIP_POWER_W * PUE * wall_s / 3600.0 / 1000.0
    return kwh * intensity


@dataclasses.dataclass(frozen=True)
class FleetReport:
    """Fleet-wide pricing + engine accounting.

    From a packed run (DESIGN.md §9.8) the per-group `GroupReport`s are
    the *demux* of one multiplexed stream: each group's per-item
    instruction/timing/mix tallies — and therefore every carbon number —
    are bit-exact with a sequential per-group run, while `packed` holds
    the whole-run `PackedStats` (total lane-step slots including idle
    lanes, segment count, wall clock for the single stream).
    """
    groups: List[GroupReport]
    intensity: float
    packed: Optional[PackedStats] = None

    @property
    def n_items(self) -> int:
        return sum(g.result.n_items for g in self.groups)

    @property
    def lane_steps(self) -> int:
        """Lane-step slots attributed to groups' active lanes. For a
        packed run, `packed.lane_steps` additionally counts idle/padding
        slots, which belong to the shared stream rather than a group."""
        return sum(g.result.lane_steps for g in self.groups)

    @property
    def monolithic_lane_steps(self) -> int:
        return sum(g.result.monolithic_lane_steps for g in self.groups)

    @property
    def busy_steps(self) -> int:
        return sum(g.result.busy_steps for g in self.groups)

    @property
    def wall_s(self) -> float:
        if self.packed is not None:
            return self.packed.wall_s      # one stream, measured once
        return sum(g.result.wall_s for g in self.groups)

    @property
    def total_kg(self) -> float:
        return sum(g.total_kg for g in self.groups)

    @property
    def cycles_saved_ratio(self) -> float:
        """Monolithic lane-steps / streaming lane-steps (higher = better)."""
        return self.monolithic_lane_steps / max(self.lane_steps, 1)

    def simulation_kg(self, n_chips: int = 1) -> float:
        return simulation_footprint_kg(self.wall_s, n_chips, self.intensity)

    def format(self) -> str:
        # WCET column only when at least one group carries a §9.11
        # certificate (run_plan always attaches one)
        certified = any(g.wcet_cycles is not None for g in self.groups)
        head = (f"{'group':<22} {'core':<5} {'items':>8} {'instr/item':>11} "
                f"{'cyc/item':>10} "
                + (f"{'wcet-cyc':>10} " if certified else "")
                + f"{'mWh/fleet-exec':>14} "
                f"{'kg CO2e (op+emb)':>17} {'best':>5}")
        lines = [head, "-" * len(head)]
        for g in self.groups:
            mean_instr = (g.profile.n_one_stage + g.profile.n_two_stage)
            wcet = ""
            if certified:
                wcet = f"{'-':>10} " if g.wcet_cycles is None \
                    else f"{g.wcet_cycles:>10.0f} "
            lines.append(
                f"{g.workload.key + ' ' + g.workload.algorithm:<22.22} "
                f"{g.core.name:<5} {g.result.n_items:>8} "
                f"{mean_instr:>11.1f} {g.cycles_per_item:>10.1f} "
                + wcet +
                f"{g.fleet_exec_kwh * 1e6:>14.3f} "
                f"{g.operational_kg:>8.3g}+{g.embodied_kg:<8.3g} "
                f"{g.recommended_core:>5}")
        lines.append("-" * len(head))
        eff = 100.0 * self.busy_steps / max(self.lane_steps, 1)
        steppers = sorted({g.result.stepper for g in self.groups})
        n_dev = max((g.result.n_devices for g in self.groups), default=1)
        lines.append(
            f"fleet: {self.n_items} items, {self.total_kg:.4g} kg CO2e; "
            f"engine: {self.lane_steps:,} lane-steps "
            f"({eff:.1f}% busy) vs {self.monolithic_lane_steps:,} "
            f"monolithic ({self.cycles_saved_ratio:.2f}x saved); "
            f"stepper {'/'.join(steppers)} x{n_dev} dev; "
            f"sim footprint {self.simulation_kg() * 1e3:.3g} g CO2e "
            f"({self.wall_s:.2f}s wall)")
        if certified:
            cert = [g for g in self.groups if g.wcet_cycles is not None]
            cert_op = sum(g.certified_operational_kg for g in cert)
            meas_op = sum(g.operational_kg for g in cert)
            lines.append(
                f"certified (FlexiLint §9.11): worst-case operational "
                f"{cert_op:.4g} kg CO2e vs {meas_op:.4g} measured/analytic "
                f"({cert_op / max(meas_op, 1e-30):.2f}x headroom, "
                f"{len(cert)}/{len(self.groups)} groups certified)")
        if self.packed is not None:
            p = self.packed
            lines.append(
                f"packed runtime: {p.n_groups} groups in one stream "
                f"(bank {p.n_progs}x{p.bank_width} words), "
                f"{p.n_segments} segments, {p.lane_steps:,} lane-step "
                f"slots incl. idle, chunk {p.chunk}")
            mode = f"{p.refill}-refill" \
                + (", adaptive supersteps" if p.adaptive else "")
            lines.append(
                f"sync stats ({mode}): {p.host_syncs} blocking host "
                f"syncs ({p.sync_wait_s:.3f}s waited), refill host work "
                f"{p.refill_wall_s:.3f}s, device busy "
                f"{100.0 * p.device_busy_frac:.1f}%")
            if p.redundancy != "none" or p.detected or p.quarantined:
                lines.append(
                    f"resilience (FlexiFault §9.14, {p.redundancy}): "
                    f"{p.detected} divergences detected, {p.corrected} "
                    f"corrected by segment re-execution, "
                    f"{p.quarantined} lane pairs quarantined")
            if p.n_shards > 1 and p.shard_retired:
                lines.append(
                    f"shard-local (§9.12): {p.n_shards} shards, "
                    f"retired/shard {list(p.shard_retired)}, "
                    f"lane-steps/shard {list(p.shard_lane_steps)} — "
                    f"collective-free segment loop, "
                    f"{p.host_syncs} host syncs total (not x shards)")
        return "\n".join(lines)
