"""Streaming fleet-execution engine (DESIGN.md §9).

Replaces the one-shot `flexibits.fleet.run_fleet_sharded` hot path with a
chunked, segment-early-exit, heterogeneity-aware engine:

- `engine.run_stream`   — chunked streaming executor (host memory O(chunk))
- `plan.FleetPlan`      — heterogeneous (workload, core) sub-fleets
- `plan.run_plan`       — drive a plan through the engine
- `report.FleetReport`  — per-group cycle/energy tallies priced through
                          core/carbon.py and core/planner.py
"""
from repro.fleet.engine import (STEPPERS, FleetResult, array_source,
                                run_stream, run_workload_stream,
                                workload_source)
from repro.fleet.plan import FleetGroup, FleetPlan, run_plan
from repro.fleet.report import FleetReport, GroupReport

__all__ = [
    "STEPPERS", "FleetResult", "array_source", "run_stream",
    "run_workload_stream", "workload_source",
    "FleetGroup", "FleetPlan", "run_plan", "FleetReport", "GroupReport",
]
