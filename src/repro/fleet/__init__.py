"""Streaming fleet-execution engine (DESIGN.md §9).

Replaces the one-shot `flexibits.fleet.run_fleet_sharded` hot path with a
chunked, segment-early-exit, heterogeneity-aware engine:

- `engine.run_stream`   — chunked streaming executor (host memory O(chunk))
- `engine.run_packed`   — packed multi-program runtime: every group of a
                          heterogeneous plan in ONE stream (program bank,
                          per-lane prog_id, admission scheduler, §9.8);
                          device-resident by default (`refill="device"`,
                          on-device retire/refill + async sync, optional
                          adaptive supersteps, §9.9)
- `plan.FleetPlan`      — heterogeneous (workload, core) sub-fleets;
                          `run_plan` routes through the packed runtime by
                          default (`packed=False` = sequential baseline)
- `report.FleetReport`  — per-group cycle/energy tallies priced through
                          core/carbon.py and core/planner.py, with packed
                          whole-run stats when the plan ran packed
"""
from repro.fleet.engine import (REFILLS, STEPPERS, FleetResult,
                                PackedGroup, PackedStats, array_source,
                                run_packed, run_stream,
                                run_workload_stream, workload_source)
from repro.fleet.plan import FleetGroup, FleetPlan, run_plan
from repro.fleet.report import FleetReport, GroupReport

__all__ = [
    "REFILLS", "STEPPERS", "FleetResult", "PackedGroup", "PackedStats",
    "array_source", "run_packed", "run_stream", "run_workload_stream",
    "workload_source",
    "FleetGroup", "FleetPlan", "run_plan", "FleetReport", "GroupReport",
]
