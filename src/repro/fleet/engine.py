"""Chunked streaming fleet executor with early-exit segmentation.

The monolithic path (`flexibits.fleet.run_fleet_sharded`) vmaps one
while_loop over the whole fleet: every SIMD lane is occupied until the
*slowest* item halts, and the host materializes all item memories at once.
This engine fixes both (DESIGN.md §9):

- **Chunked streaming.** Items flow through a fixed pool of `chunk` lanes;
  the host only ever holds O(chunk) memory images (the per-item *scalar*
  results — counts, halt flags, output words — are O(fleet), which is what
  makes 10M+ item runs feasible). Lane buffers are donated back to XLA
  between segments, so device memory is a single chunk-sized allocation.

- **Early-exit segmentation.** The interpreter runs in bounded cycle
  segments (default 4096). Between segments, halted lanes are harvested,
  compacted out, and refilled from the stream, so aggregate simulated
  lane-steps track the fleet's *actual* halt distribution instead of the
  worst case. Segmented execution retires the exact instruction sequence
  of `iss.run`, so final memories are bit-exact with the monolithic path.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as dsharding
from repro.flexibench.base import Workload
from repro.flexibits import iss
from repro.kernels import iss_stepper

STEPPERS = ("branchless", "pallas", "switch")

# source protocol: source(start, count) -> (count, mem_words) int32
Source = Callable[[int, int], np.ndarray]


def array_source(mems: np.ndarray) -> Source:
    """Stream an in-memory (n_items, M) array (parity tests, small fleets)."""
    mems = np.asarray(mems, np.int32)

    def src(start: int, count: int) -> np.ndarray:
        return mems[start:start + count]

    return src


def workload_source(w: Workload, seed: int = 0) -> Source:
    """O(chunk) on-demand input generation for one workload.

    Item i is seeded by (seed, i), so every item's inputs are a pure
    function of its index — the fleet is identical no matter how the
    engine's refill boundaries slice the stream (chunk/seg_steps are
    pure performance knobs).
    """
    base = w.initial_memory(np.zeros(w.n_inputs, np.int32))

    def src(start: int, count: int) -> np.ndarray:
        xs = np.stack([
            w.gen_inputs(np.random.default_rng([seed, i]), 1)[0]
            for i in range(start, start + count)])
        mems = np.tile(base, (count, 1))
        mems[:, :xs.shape[1]] = xs
        return mems

    return src


class _Prefetcher:
    """Double-buffered async host refill (DESIGN.md §9.6).

    Source generation is host work (per-item RNG, memory-image assembly);
    segment execution is device work. A one-worker executor keeps exactly
    one `block`-sized fetch in flight, so generating the next chunk of
    items overlaps the device segment instead of serializing after it.
    The engine consumes items strictly in stream order, so a single
    pending future is a full double buffer. `background=False` degrades
    to synchronous fetches (for sources that aren't thread-safe).
    """

    def __init__(self, source: Source, n_items: int, block: int,
                 background: bool = True):
        self._source = source
        self._n = n_items
        self._block = max(1, block)
        self._cursor = 0          # next un-requested item
        self._buf: Optional[np.ndarray] = None
        self._off = 0
        self._fut = None
        self._ex = concurrent.futures.ThreadPoolExecutor(max_workers=1) \
            if background else None
        if self._ex is not None:
            self._submit()

    def _submit(self):
        count = min(self._block, self._n - self._cursor)
        if count > 0:
            start = self._cursor
            self._cursor += count
            self._fut = self._ex.submit(self._source, start, count)
        else:
            self._fut = None

    def take(self, count: int) -> np.ndarray:
        """Next `count` item memories, in stream order."""
        if self._ex is None:
            start = self._cursor
            self._cursor += count
            return np.asarray(self._source(start, count), np.int32)
        parts = []
        while count > 0:
            if self._buf is None or self._off >= len(self._buf):
                if self._fut is None:
                    raise RuntimeError("source stream exhausted")
                self._buf = np.asarray(self._fut.result(), np.int32)
                self._off = 0
                self._submit()          # refill the second buffer now
            k = min(count, len(self._buf) - self._off)
            parts.append(self._buf[self._off:self._off + k])
            self._off += k
            count -= k
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def close(self):
        """Cancel/drain the in-flight fetch and join the worker.

        `shutdown(wait=False)` would leave a running background fetch
        alive past close — a leaked non-daemon thread still calling the
        source after the engine returned (or raised). Cancel the pending
        future if it has not started; if it is already running, drain it
        (`wait=True`) so the source is never invoked after close().
        """
        if self._ex is not None:
            self._ex.shutdown(wait=True, cancel_futures=True)
            self._fut = None


@dataclasses.dataclass
class FleetResult:
    """Per-item scalars plus engine-level accounting for one stream run."""
    n_items: int
    n_instr: np.ndarray          # (n,) retired instructions per item
    n_two_stage: np.ndarray      # (n,)
    halted: np.ndarray           # (n,) bool (False = max_steps exhausted)
    out: np.ndarray              # (n,) word at out_addr (0 if no out_addr)
    mix: np.ndarray              # (8,) retired-instruction mix, fleet total
    lane_steps: int              # SIMD lane-step slots the engine executed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    stepper: str = "branchless"
    n_devices: int = 1
    # full final state, only populated with keep_state=True (O(fleet) host
    # memory — for parity tests and the legacy ISSState wrapper)
    mems: Optional[np.ndarray] = None    # (n, M)
    regs: Optional[np.ndarray] = None    # (n, 16)
    pc: Optional[np.ndarray] = None      # (n,)
    mix_items: Optional[np.ndarray] = None  # (n, 8)

    @property
    def busy_steps(self) -> int:
        """Lane-steps that retired a real instruction (useful work)."""
        return int(self.n_instr.sum())

    @property
    def monolithic_lane_steps(self) -> int:
        """Cost of the one-shot vmap(while_loop) on the same fleet: every
        lane runs (masked) until the slowest item halts."""
        if self.n_items == 0:
            return 0
        return int(self.n_items) * int(self.n_instr.max())

    @property
    def items_per_s(self) -> float:
        return self.n_items / self.wall_s if self.wall_s > 0 else float("inf")


def _lane_state_specs(mesh: Mesh, mem_words: int):
    """Shard specs for a chunk ISSState, derived from the real state
    constructor (via eval_shape) so field set and ranks can never drift
    from what run_stream actually passes in."""
    abstract = jax.eval_shape(
        lambda: _fresh_chunk(np.zeros((1, mem_words), np.int32),
                             np.ones(1, bool)))
    return dsharding.lane_specs(mesh, abstract)


@functools.lru_cache(maxsize=None)
def _segment_runner(stepper: str, chunk: int, seg_steps: int,
                    max_steps: int, mem_words: int,
                    mesh: Optional[Mesh], subset):
    """Compiled segment runner, cached per engine configuration.

    One factory for every (stepper, mesh) combination so heterogeneous
    `FleetPlan` runs stop retracing per group: two groups that share
    (stepper, chunk, seg_steps, max_steps, mem_words, mesh, opcode
    subset) reuse the exact same jitted callable, and the jit cache
    inside it never sees a new python closure per `run_stream` call.
    `chunk` and `mem_words` only describe the lane-pool shape (the body
    never reads them — jit specializes on the traced state shapes), but
    keying on them keeps one compiled trace per callable.

    Steppers: "branchless" — lane-parallel masked-select while_loop
    (DESIGN.md §9.5); "pallas" — fused-segment kernel holding lane state
    resident for the whole segment (§9.7); "switch" — the legacy vmapped
    lax.switch interpreter. With a mesh the runner is shard_map'd: each
    device owns chunk/n_devices lanes and runs its own segment, so a
    device whose lanes all halt exits immediately instead of being
    dragged along by a global (all-reduced) loop condition, which is
    what the GSPMD lowering of the same code does (§9.6). No collectives
    are needed: the engine is pure data parallelism over items.
    """
    def seg(code, state):
        if stepper == "switch":
            return jax.vmap(lambda s: iss.run_segment(
                code, s, seg_steps, max_steps))(state)
        if stepper == "pallas":
            return iss_stepper.iss_segment(
                code, state, seg_steps=seg_steps, max_steps=max_steps,
                subset=subset)
        return iss.run_segment_lanes(code, state, seg_steps, max_steps,
                                     subset)

    if mesh is None:
        return jax.jit(seg, donate_argnums=(1,))
    specs = _lane_state_specs(mesh, mem_words)
    fn = shard_map(seg, mesh=mesh, in_specs=(P(), specs),
                   out_specs=specs, check_rep=False)
    return jax.jit(fn, donate_argnums=(1,))


@functools.partial(jax.jit, static_argnames=("max_steps",))
def _done_count(state: iss.ISSState, *, max_steps: int):
    """Scalar count of done lanes (halted or step-budget exhausted).

    The engine's per-segment host sync: comparing this single int32
    against the host-known value tells whether any lane finished this
    segment — only then is the O(chunk) halted/n_instr harvest pulled.
    """
    return (state.halted | (state.n_instr >= max_steps)).sum()


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill(state: iss.ISSState, replace, new_mems) -> iss.ISSState:
    """Reset `replace` lanes to a fresh item (mem from new_mems)."""
    rep1 = replace[:, None]
    return iss.ISSState(
        regs=jnp.where(rep1, 0, state.regs),
        pc=jnp.where(replace, 0, state.pc),
        mem=jnp.where(rep1, new_mems, state.mem),
        halted=jnp.where(replace, False, state.halted),
        n_instr=jnp.where(replace, 0, state.n_instr),
        n_two_stage=jnp.where(replace, 0, state.n_two_stage),
        mix=jnp.where(rep1, 0, state.mix),
    )


def _fresh_chunk(mems: np.ndarray, active: np.ndarray) -> iss.ISSState:
    n, _ = mems.shape
    return iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32),
        pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems, iss.I32),
        halted=jnp.asarray(~active),   # padding lanes never step
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
    )


def _shard_state(state: iss.ISSState, mesh: Mesh) -> iss.ISSState:
    """Lay the lane axis out over every mesh axis (pure data parallelism),
    per the fleet-lane rule in distributed/sharding.py."""
    return jax.tree.map(jax.device_put, state,
                        dsharding.lane_shardings(mesh, state))


def run_stream(code: np.ndarray, source: Source, *, n_items: int,
               mem_words: int, max_steps: int, chunk: int = 256,
               seg_steps: int = 4096, out_addr: Optional[int] = None,
               keep_state: bool = False,
               mesh: Optional[Mesh] = None,
               stepper: str = "branchless",
               subset: Optional[frozenset] = None,
               prefetch: bool = True) -> FleetResult:
    """Stream `n_items` memory images from `source` through `chunk` lanes.

    Returns per-item scalars in item order. With `keep_state=True` the
    full final state (memories, registers, pc) is also collected — O(fleet)
    host memory, so only use it for parity checks or small fleets.

    `stepper` picks the segment interpreter: "branchless" (lane-parallel
    masked-select stepper, DESIGN.md §9.5), "pallas" (fused-segment
    kernel — the whole segment of a lane tile runs inside one kernel
    invocation with state resident, §9.7), or "switch" (the legacy
    vmapped lax.switch interpreter). `subset` optionally pins the static
    opcode subset for the branchless/pallas steppers; by default it is
    derived from the program text (`iss.opcode_subset`), letting the
    compiler drop opcode classes the workload can never retire. With a
    `mesh`, lanes are sharded over every mesh axis and each device steps
    its shard independently via shard_map (DESIGN.md §9.6). `prefetch`
    overlaps host-side source generation with device segments (double
    buffering).

    Host<->device sync per segment is one scalar: the done-lane count.
    The O(chunk) halted/n_instr/mem harvest only happens on segments
    where that count says some lane actually finished.
    """
    if seg_steps < 1:
        raise ValueError("seg_steps must be >= 1")
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    if stepper not in STEPPERS:
        raise ValueError(f"stepper must be one of {STEPPERS}")
    chunk = min(chunk, max(n_items, 1))
    n_dev = 1
    if mesh is not None:
        n_dev = int(np.prod(list(mesh.shape.values())))
    round_to = n_dev
    if stepper == "pallas" and chunk > 128:
        # keep the pallas lane-tile grid wide: a prime-ish chunk would
        # tile at its largest small divisor (worst case 1 lane/kernel).
        # Rounding the pool up to a 128-lane multiple (lcm'd with the
        # mesh) costs only inert padding lanes, which never step.
        round_to = int(128 * n_dev // np.gcd(128, n_dev))
    if round_to > 1:
        chunk = -(-chunk // round_to) * round_to

    code_np = np.asarray(code)
    if stepper in ("branchless", "pallas") and subset is None:
        subset = iss.opcode_subset(code_np)
    code = jnp.asarray(code_np.view(np.int32))

    seg_fn = _segment_runner(stepper, chunk, seg_steps, max_steps,
                             mem_words, mesh, subset)

    # per-item result collectors (scalars: O(fleet))
    r_instr = np.zeros(n_items, np.int64)
    r_two = np.zeros(n_items, np.int64)
    r_halt = np.zeros(n_items, bool)
    r_out = np.zeros(n_items, np.int32)
    r_mix = np.zeros(len(iss.MIX_CLASSES), np.int64)
    if keep_state:
        r_mem = np.zeros((n_items, mem_words), np.int32)
        r_regs = np.zeros((n_items, 16), np.int32)
        r_pc = np.zeros(n_items, np.int32)
        r_mix_items = np.zeros((n_items, len(iss.MIX_CLASSES)), np.int32)

    t0 = time.perf_counter()

    # close the prefetch worker even when a segment raises (XLA OOM, bad
    # source shapes): a leaked non-daemon thread outlives the call
    pref = _Prefetcher(source, n_items, block=chunk, background=prefetch)
    try:
        # initial fill
        cursor = min(chunk, n_items)
        first = np.zeros((chunk, mem_words), np.int32)
        if cursor:
            first[:cursor] = pref.take(cursor)
        ids = np.full(chunk, -1, np.int64)
        ids[:cursor] = np.arange(cursor)
        state = _fresh_chunk(first, ids >= 0)
        if mesh is not None:
            state = _shard_state(state, mesh)

        prev_instr = np.zeros(chunk, np.int64)
        lane_steps = 0
        n_segments = 0
        # host-known done-lane count: padding + retired-but-not-refilled
        # lanes stay halted on device, so done == chunk - #active always
        # holds right after a harvest
        expected_done = chunk - int((ids >= 0).sum())

        while (ids >= 0).any():
            state = seg_fn(code, state)
            n_segments += 1

            # single-scalar sync: if no lane finished this segment, every
            # active lane ran exactly seg_steps (the segment loop only
            # stops early when lanes halt or exhaust max_steps — both
            # would raise the done count), so the O(chunk) harvest pulls
            # are skipped entirely
            if int(_done_count(state, max_steps=max_steps)) == expected_done:
                lane_steps += chunk * seg_steps
                prev_instr[ids >= 0] += seg_steps
                continue

            halted = np.asarray(state.halted)
            n_instr = np.asarray(state.n_instr, np.int64)
            # SIMD cost: all lanes are occupied for the longest path this
            # segment took on any lane
            lane_steps += chunk * int((n_instr - prev_instr).max(initial=0))
            prev_instr = n_instr

            active = ids >= 0
            done = active & (halted | (n_instr >= max_steps))
            idx = np.nonzero(done)[0]
            if idx.size:
                items = ids[idx]
                r_instr[items] = n_instr[idx]
                r_two[items] = np.asarray(state.n_two_stage, np.int64)[idx]
                r_halt[items] = halted[idx]
                mix_rows = np.asarray(state.mix[jnp.asarray(idx)], np.int64)
                r_mix += mix_rows.sum(0)
                if out_addr is not None:
                    r_out[items] = np.asarray(state.mem[:, out_addr])[idx]
                if keep_state:
                    jidx = jnp.asarray(idx)
                    r_mem[items] = np.asarray(state.mem[jidx])
                    r_regs[items] = np.asarray(state.regs[jidx])
                    r_pc[items] = np.asarray(state.pc)[idx]
                    r_mix_items[items] = mix_rows

                # compact: retire done lanes, refill from the stream
                n_new = min(idx.size, n_items - cursor)
                ids[idx] = -1
                if n_new:
                    lanes = idx[:n_new]
                    new_mems = np.zeros((chunk, mem_words), np.int32)
                    new_mems[lanes] = pref.take(n_new)
                    replace = np.zeros(chunk, bool)
                    replace[lanes] = True
                    ids[lanes] = np.arange(cursor, cursor + n_new)
                    cursor += n_new
                    prev_instr[lanes] = 0
                    state = _refill(state, jnp.asarray(replace),
                                    jnp.asarray(new_mems))
            expected_done = chunk - int((ids >= 0).sum())
    finally:
        pref.close()

    wall_s = time.perf_counter() - t0
    return FleetResult(
        n_items=n_items, n_instr=r_instr, n_two_stage=r_two, halted=r_halt,
        out=r_out, mix=r_mix, lane_steps=lane_steps, n_segments=n_segments,
        chunk=chunk, seg_steps=seg_steps, wall_s=wall_s,
        stepper=stepper, n_devices=n_dev,
        mems=r_mem if keep_state else None,
        regs=r_regs if keep_state else None,
        pc=r_pc if keep_state else None,
        mix_items=r_mix_items if keep_state else None,
    )


def run_workload_stream(w: Workload, n_items: int, *, seed: int = 0,
                        chunk: int = 256, seg_steps: int = 4096,
                        max_steps: Optional[int] = None,
                        keep_state: bool = False,
                        mesh: Optional[Mesh] = None,
                        stepper: str = "branchless",
                        prefetch: bool = True) -> FleetResult:
    """Convenience wrapper: stream a FlexiBench workload end to end.

    The branchless/pallas steppers' opcode subset is derived from the
    workload's program text, so the compiled segment contains only the
    ISA subset this workload retires (the RISP specialization knob
    applied to the simulator)."""
    return run_stream(
        w.program.code, workload_source(w, seed), n_items=n_items,
        mem_words=w.total_mem_words,
        max_steps=max_steps or w.max_steps, chunk=chunk,
        seg_steps=seg_steps, out_addr=w.out_addr, keep_state=keep_state,
        mesh=mesh, stepper=stepper, prefetch=prefetch)
