"""Chunked streaming fleet executor with early-exit segmentation.

The monolithic path (`flexibits.fleet.run_fleet_sharded`) vmaps one
while_loop over the whole fleet: every SIMD lane is occupied until the
*slowest* item halts, and the host materializes all item memories at once.
This engine fixes both (DESIGN.md §9):

- **Chunked streaming.** Items flow through a fixed pool of `chunk` lanes;
  the host only ever holds O(chunk) memory images (the per-item *scalar*
  results — counts, halt flags, output words — are O(fleet), which is what
  makes 10M+ item runs feasible). Lane buffers are donated back to XLA
  between segments, so device memory is a single chunk-sized allocation.

- **Early-exit segmentation.** The interpreter runs in bounded cycle
  segments (default 4096). Between segments, halted lanes are harvested,
  compacted out, and refilled from the stream, so aggregate simulated
  lane-steps track the fleet's *actual* halt distribution instead of the
  worst case. Segmented execution retires the exact instruction sequence
  of `iss.run`, so final memories are bit-exact with the monolithic path.

- **Packed multi-program runtime** (`run_packed`, DESIGN.md §9.8). A
  heterogeneous `FleetPlan` no longer drains group by group: programs
  are padded into a bank, every lane carries its program row + step
  budget, and freed lanes are backfilled with items from ANY pending
  group, so one group's halt-time tail hides behind the others' backlog
  and the whole plan runs as one stream.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.distributed import sharding as dsharding
from repro.flexibench.base import Workload
from repro.flexibits import iss
from repro.kernels import iss_stepper

STEPPERS = ("branchless", "pallas", "switch")

# source protocol: source(start, count) -> (count, mem_words) int32
Source = Callable[[int, int], np.ndarray]


def array_source(mems: np.ndarray) -> Source:
    """Stream an in-memory (n_items, M) array (parity tests, small fleets)."""
    mems = np.asarray(mems, np.int32)

    def src(start: int, count: int) -> np.ndarray:
        return mems[start:start + count]

    return src


def workload_source(w: Workload, seed: int = 0,
                    gen_block: int = 256) -> Source:
    """O(chunk) on-demand input generation for one workload.

    Generation is batched over fixed *aligned* blocks of `gen_block`
    items: item i's inputs are row `i % gen_block` of
    `w.gen_inputs(default_rng([seed, i // gen_block]), gen_block)`. The
    aligned block an item falls in is a pure function of its index, so
    the fleet is identical no matter how the engine's refill boundaries
    slice the stream (chunk/seg_steps are pure performance knobs) —
    while the host hot path pays one Generator construction and one
    vectorized `gen_inputs` call per block instead of per item.
    `gen_block` is part of the stream's identity (a different block size
    is a different — equally valid — fleet), not an engine tuning knob.

    The last generated block is cached: the engine consumes items in
    stream order, so a request straddling a block boundary reuses the
    cached block instead of regenerating it.
    """
    base = w.initial_memory(np.zeros(w.n_inputs, np.int32))
    gen_block = max(1, gen_block)
    cache = {"blk": -1, "xs": None}

    def block(blk: int) -> np.ndarray:
        if cache["blk"] != blk:
            rng = np.random.default_rng([seed, blk])
            cache["xs"] = np.asarray(w.gen_inputs(rng, gen_block), np.int32)
            cache["blk"] = blk
        return cache["xs"]

    def src(start: int, count: int) -> np.ndarray:
        if count <= 0:
            return np.zeros((0, base.size), np.int32)
        parts = []
        i = start
        while i < start + count:
            blk, off = divmod(i, gen_block)
            k = min(gen_block - off, start + count - i)
            parts.append(block(blk)[off:off + k])
            i += k
        xs = parts[0] if len(parts) == 1 else np.concatenate(parts)
        mems = np.tile(base, (count, 1))
        mems[:, :xs.shape[1]] = xs
        return mems

    return src


class _Prefetcher:
    """Double-buffered async host refill (DESIGN.md §9.6).

    Source generation is host work (per-item RNG, memory-image assembly);
    segment execution is device work. A one-worker executor keeps exactly
    one `block`-sized fetch in flight, so generating the next chunk of
    items overlaps the device segment instead of serializing after it.
    The engine consumes items strictly in stream order, so a single
    pending future is a full double buffer. `background=False` degrades
    to synchronous fetches (for sources that aren't thread-safe).
    """

    def __init__(self, source: Source, n_items: int, block: int,
                 background: bool = True):
        self._source = source
        self._n = n_items
        self._block = max(1, block)
        self._cursor = 0          # next un-requested item
        self._taken = 0           # items handed to the engine so far
        self._buf: Optional[np.ndarray] = None
        self._off = 0
        self._fut = None
        self._ex = concurrent.futures.ThreadPoolExecutor(max_workers=1) \
            if background else None
        if self._ex is not None:
            self._submit()

    def _submit(self):
        count = min(self._block, self._n - self._cursor)
        if count > 0:
            start = self._cursor
            self._cursor += count
            self._fut = self._ex.submit(self._source, start, count)
        else:
            self._fut = None

    def take(self, count: int) -> np.ndarray:
        """Next `count` item memories, in stream order.

        Requests past the declared stream length fail loudly with the
        full cursor state — "exhausted" alone is undebuggable when a
        plan/group/source disagrees with the engine about `n_items`.
        """
        if self._taken + count > self._n:
            raise RuntimeError(
                f"source stream exhausted: requested {count} item(s) at "
                f"stream cursor {self._taken}, but the source holds only "
                f"{self._n} item(s) "
                f"({self._n - self._taken} item(s) remaining)")
        self._taken += count
        if self._ex is None:
            start = self._cursor
            self._cursor += count
            return np.asarray(self._source(start, count), np.int32)
        parts = []
        while count > 0:
            if self._buf is None or self._off >= len(self._buf):
                if self._fut is None:
                    raise RuntimeError(
                        f"source stream exhausted: no fetch in flight at "
                        f"stream cursor {self._taken}, request cursor "
                        f"{self._cursor}, n_items={self._n}")
                self._buf = np.asarray(self._fut.result(), np.int32)
                self._off = 0
                self._submit()          # refill the second buffer now
            k = min(count, len(self._buf) - self._off)
            parts.append(self._buf[self._off:self._off + k])
            self._off += k
            count -= k
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def close(self):
        """Cancel/drain the in-flight fetch and join the worker.

        `shutdown(wait=False)` would leave a running background fetch
        alive past close — a leaked non-daemon thread still calling the
        source after the engine returned (or raised). Cancel the pending
        future if it has not started; if it is already running, drain it
        (`wait=True`) so the source is never invoked after close().
        """
        if self._ex is not None:
            self._ex.shutdown(wait=True, cancel_futures=True)
            self._fut = None


@dataclasses.dataclass
class FleetResult:
    """Per-item scalars plus engine-level accounting for one stream run."""
    n_items: int
    n_instr: np.ndarray          # (n,) retired instructions per item
    n_two_stage: np.ndarray      # (n,)
    halted: np.ndarray           # (n,) bool (False = max_steps exhausted)
    out: np.ndarray              # (n,) word at out_addr (0 if no out_addr)
    mix: np.ndarray              # (8,) retired-instruction mix, fleet total
    lane_steps: int              # SIMD lane-step slots the engine executed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    stepper: str = "branchless"
    n_devices: int = 1
    # full final state, only populated with keep_state=True (O(fleet) host
    # memory — for parity tests and the legacy ISSState wrapper)
    mems: Optional[np.ndarray] = None    # (n, M)
    regs: Optional[np.ndarray] = None    # (n, 16)
    pc: Optional[np.ndarray] = None      # (n,)
    mix_items: Optional[np.ndarray] = None  # (n, 8)

    @property
    def busy_steps(self) -> int:
        """Lane-steps that retired a real instruction (useful work)."""
        return int(self.n_instr.sum())

    @property
    def monolithic_lane_steps(self) -> int:
        """Cost of the one-shot vmap(while_loop) on the same fleet: every
        lane runs (masked) until the slowest item halts."""
        if self.n_items == 0:
            return 0
        return int(self.n_items) * int(self.n_instr.max())

    @property
    def items_per_s(self) -> float:
        return self.n_items / self.wall_s if self.wall_s > 0 else float("inf")


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill(state: iss.ISSState, replace, new_mems) -> iss.ISSState:
    """Reset `replace` lanes to a fresh item (mem from new_mems)."""
    rep1 = replace[:, None]
    return iss.ISSState(
        regs=jnp.where(rep1, 0, state.regs),
        pc=jnp.where(replace, 0, state.pc),
        mem=jnp.where(rep1, new_mems, state.mem),
        halted=jnp.where(replace, False, state.halted),
        n_instr=jnp.where(replace, 0, state.n_instr),
        n_two_stage=jnp.where(replace, 0, state.n_two_stage),
        mix=jnp.where(rep1, 0, state.mix),
    )


def _fresh_chunk(mems: np.ndarray, active: np.ndarray) -> iss.ISSState:
    n, _ = mems.shape
    return iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32),
        pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems, iss.I32),
        halted=jnp.asarray(~active),   # padding lanes never step
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
    )


def run_stream(code: np.ndarray, source: Source, *, n_items: int,
               mem_words: int, max_steps: int, chunk: int = 256,
               seg_steps: int = 4096, out_addr: Optional[int] = None,
               keep_state: bool = False,
               mesh: Optional[Mesh] = None,
               stepper: str = "branchless",
               subset: Optional[frozenset] = None,
               prefetch: bool = True) -> FleetResult:
    """Stream `n_items` memory images from `source` through `chunk` lanes.

    Returns per-item scalars in item order. With `keep_state=True` the
    full final state (memories, registers, pc) is also collected — O(fleet)
    host memory, so only use it for parity checks or small fleets.

    `stepper` picks the segment interpreter: "branchless" (lane-parallel
    masked-select stepper, DESIGN.md §9.5), "pallas" (fused-segment
    kernel — the whole segment of a lane tile runs inside one kernel
    invocation with state resident, §9.7), or "switch" (the legacy
    vmapped lax.switch interpreter). `subset` optionally pins the static
    opcode subset for the branchless/pallas steppers; by default it is
    derived from the program text (`iss.opcode_subset`), letting the
    compiler drop opcode classes the workload can never retire. With a
    `mesh`, lanes are sharded over every mesh axis and each device steps
    its shard independently via shard_map (DESIGN.md §9.6). `prefetch`
    overlaps host-side source generation with device segments (double
    buffering).

    Implemented as the single-group special case of the packed
    multi-program runtime (`run_packed`, DESIGN.md §9.8) — one stream
    loop serves both, so the sync/harvest/refill subtleties exist in
    exactly one place — with the run's whole-pool accounting (lane-step
    slots including padding lanes, segment count, measured wall clock)
    folded back into the returned `FleetResult`. Host<->device sync per
    segment stays one scalar: the done-lane count.
    """
    results, stats = run_packed(
        [PackedGroup(code=code, source=source, n_items=n_items,
                     max_steps=max_steps, mem_words=mem_words,
                     out_addr=out_addr)],
        chunk=chunk, seg_steps=seg_steps, keep_state=keep_state,
        mesh=mesh, stepper=stepper, subset=subset, prefetch=prefetch)
    return dataclasses.replace(
        results[0], lane_steps=stats.lane_steps,
        n_segments=stats.n_segments, chunk=stats.chunk,
        wall_s=stats.wall_s)


# ---------------------------------------------------------------------------
# Packed multi-program fleet runtime (DESIGN.md §9.8)
#
# `run_stream` executes ONE program; a heterogeneous FleetPlan run group
# by group pays each group's tail idle (the last segments where only a
# few long-running items hold the whole lane pool), its own retrace, and
# its own host<->device round-trips. The packed runtime multiplexes every
# group through one stream: programs live in a padded program bank, each
# lane carries the bank row it is executing (`iss.PackedState.prog_id`)
# plus its own step budget, and the admission scheduler backfills every
# freed lane with an item from ANY pending group — proportional to the
# groups' remaining backlogs, so all groups drain together and the tail
# of one group is hidden behind the backlog of the others.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedGroup:
    """One group's inputs to the packed runtime (engine-level: program +
    item source; fleet/plan.py builds these from a FleetPlan)."""
    code: np.ndarray                  # program words (uint32 or int32)
    source: Source
    n_items: int
    max_steps: int
    mem_words: int
    out_addr: Optional[int] = None


@dataclasses.dataclass
class PackedStats:
    """Whole-run accounting of one packed stream (the per-group
    `FleetResult`s carry only the lane-step slots attributable to their
    own active lanes; idle/padding slots belong to the run)."""
    n_groups: int
    n_progs: int
    bank_width: int
    lane_steps: int               # chunk x max-step-delta, summed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    stepper: str
    n_devices: int


def _apportion(slots: int, remaining) -> np.ndarray:
    """Admission policy: split `slots` free lanes over groups
    proportionally to their remaining backlogs (largest-remainder
    rounding, ties to the lower group index — deterministic).

    Proportional shares keep every pending group flowing and drain all
    groups at roughly the same time, so no group is left to run its tail
    alone at the end of the stream. Per-group results do not depend on
    the policy at all (item i of group g is a pure function of the
    group's source), only wall-clock does.
    """
    remaining = np.asarray(remaining, np.int64)
    total = int(remaining.sum())
    slots = min(int(slots), total)
    take = np.zeros(len(remaining), np.int64)
    if slots <= 0:
        return take
    quota = slots * remaining / total
    take = np.minimum(np.floor(quota).astype(np.int64), remaining)
    left = slots - int(take.sum())
    if left > 0:
        frac = np.where(remaining > take, quota - take, -1.0)
        for g in np.argsort(-frac, kind="stable")[:left]:
            take[g] += 1
    return take


def _fresh_packed(mems: np.ndarray, active: np.ndarray,
                  prog_id: np.ndarray,
                  max_steps: np.ndarray) -> iss.PackedState:
    return iss.PackedState(
        lanes=_fresh_chunk(mems, active),
        prog_id=jnp.asarray(prog_id, iss.I32),
        max_steps=jnp.asarray(max_steps, iss.I32))


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill_packed(state: iss.PackedState, replace, new_mems, new_prog,
                   new_ms) -> iss.PackedState:
    """Reset `replace` lanes to a fresh item of (possibly) another group:
    new memory image, bank row, and step budget."""
    return iss.PackedState(
        lanes=_refill(state.lanes, replace, new_mems),
        prog_id=jnp.where(replace, new_prog, state.prog_id),
        max_steps=jnp.where(replace, new_ms, state.max_steps))


@jax.jit
def _done_count_packed(state: iss.PackedState):
    """Scalar count of done lanes (halted or own step budget exhausted;
    padding lanes carry budget 0 and count as done).

    The engine's per-segment host sync: comparing this single int32
    against the host-known value tells whether any lane finished this
    segment — only then is the O(chunk) harvest pulled."""
    return (state.lanes.halted
            | (state.lanes.n_instr >= state.max_steps)).sum()


def _packed_state_specs(mesh: Mesh, mem_words: int):
    """Shard specs for a packed lane pool, derived from the real state
    constructor (via eval_shape) so the new lane fields (prog_id,
    max_steps) can never drift from what run_packed actually passes."""
    abstract = jax.eval_shape(
        lambda: _fresh_packed(np.zeros((1, mem_words), np.int32),
                              np.ones(1, bool), np.zeros(1, np.int32),
                              np.ones(1, np.int32)))
    return dsharding.lane_specs(mesh, abstract)


@functools.lru_cache(maxsize=None)
def _packed_segment_runner(stepper: str, chunk: int, seg_steps: int,
                           mem_words: int, n_progs: int, bank_width: int,
                           mesh: Optional[Mesh], subset):
    """Compiled packed segment runner, cached per engine configuration.

    The bank, per-program code lengths, and per-program memory bounds
    are traced *inputs* (not closure constants), so two plans that share
    shapes and opcode subset reuse one compiled callable even with
    different programs. Per-lane `max_steps` lives in the state, so the
    budget never appears in the cache key at all — one compiled runner
    serves every heterogeneous budget mix.
    """
    def seg(bank, code_len, mem_len, state):
        if stepper == "switch":
            lanes = jax.vmap(
                lambda p, m, l: iss.run_segment_banked(
                    bank, code_len, p, m, l, seg_steps, mem_len)
            )(state.prog_id, state.max_steps, state.lanes)
            return iss.PackedState(lanes=lanes, prog_id=state.prog_id,
                                   max_steps=state.max_steps)
        if stepper == "pallas":
            return iss_stepper.iss_segment_banked(
                bank, code_len, state, seg_steps=seg_steps, subset=subset,
                mem_len=mem_len)
        return iss.run_segment_lanes_banked(bank, code_len, state,
                                            seg_steps, subset, mem_len)

    if mesh is None:
        return jax.jit(seg, donate_argnums=(3,))
    specs = _packed_state_specs(mesh, mem_words)
    bspecs = dsharding.bank_specs(mesh, (0, 0, 0))
    fn = shard_map(seg, mesh=mesh, in_specs=(*bspecs, specs),
                   out_specs=specs, check_rep=False)
    return jax.jit(fn, donate_argnums=(3,))


def run_packed(groups, *, chunk: int = 256, seg_steps: int = 4096,
               keep_state: bool = False, mesh: Optional[Mesh] = None,
               stepper: str = "branchless",
               subset: Optional[frozenset] = None,
               prefetch: bool = True):
    """Execute every `PackedGroup` through ONE packed stream.

    Returns `(results, stats)`: `results[g]` is a per-group `FleetResult`
    bit-exact with what `run_stream` would produce for group g alone —
    identical per-item instruction/timing/mix tallies and final state
    (`tests/test_packed.py` pins this three ways) — and `stats` is the
    whole-run `PackedStats`.

    The program bank holds one padded row per group; every stepper
    fetches through the per-program clamp (`iss.fetch_banked`), bounds
    each lane's data-memory ports at its group's own `mem_words` (so
    clamp-on-read / drop-on-write happen at the program's boundary even
    though the pool memory is padded to the largest group's), and the
    branchless/pallas steppers compile ONE graph specialized to the
    *union* opcode subset of the bank (a superset of every row's subset,
    so per-group bit-exactness is preserved). Lane admission backfills
    freed lanes from any pending group (`_apportion`); per-group sources
    prefetch concurrently, each double-buffered as in `run_stream`.

    Per-group accounting: `lane_steps`/`n_segments` count only segments
    slots where the group had active lanes; `wall_s` splits the measured
    whole-run wall clock proportionally to retired instructions (the
    sums over groups match the run, up to idle-lane slots, which belong
    to `stats`).
    """
    groups = list(groups)
    if not groups:
        raise ValueError("run_packed needs at least one group")
    if seg_steps < 1:
        raise ValueError("seg_steps must be >= 1")
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    if stepper not in STEPPERS:
        raise ValueError(f"stepper must be one of {STEPPERS}")

    n_groups = len(groups)
    counts = np.array([g.n_items for g in groups], np.int64)
    total_items = int(counts.sum())
    if total_items == 0:
        empty = [FleetResult(
            n_items=0, n_instr=np.zeros(0, np.int64),
            n_two_stage=np.zeros(0, np.int64), halted=np.zeros(0, bool),
            out=np.zeros(0, np.int32),
            mix=np.zeros(len(iss.MIX_CLASSES), np.int64), lane_steps=0,
            n_segments=0, chunk=0, seg_steps=seg_steps, wall_s=0.0,
            stepper=stepper) for _ in groups]
        return empty, PackedStats(
            n_groups=n_groups, n_progs=n_groups, bank_width=0,
            lane_steps=0, n_segments=0, chunk=0, seg_steps=seg_steps,
            wall_s=0.0, stepper=stepper, n_devices=1)
    mem_words = max(g.mem_words for g in groups)
    bank_np, code_len_np = iss.pack_programs([g.code for g in groups])
    if subset is None:
        subset = frozenset().union(
            *(iss.opcode_subset(g.code) for g in groups))
    bank = jnp.asarray(bank_np)
    code_len = jnp.asarray(code_len_np)
    # per-program memory bounds: lanes of a small-memory group keep
    # clamp-on-read / drop-on-write at their OWN word count even though
    # the pool memory is padded to the largest group's
    mem_len = jnp.asarray([g.mem_words for g in groups], iss.I32)
    ms_of = np.array([g.max_steps for g in groups], np.int64)

    chunk = min(chunk, max(total_items, 1))
    n_dev = 1
    if mesh is not None:
        n_dev = int(np.prod(list(mesh.shape.values())))
    round_to = n_dev
    if stepper == "pallas" and chunk > 128:
        # same wide-lane-tile rule as run_stream: pad the pool to a
        # 128-multiple (lcm'd with the mesh) instead of tiling at a
        # prime-ish chunk's largest small divisor
        round_to = int(128 * n_dev // np.gcd(128, n_dev))
    if round_to > 1:
        chunk = -(-chunk // round_to) * round_to

    seg_fn = _packed_segment_runner(stepper, chunk, seg_steps, mem_words,
                                    n_groups, bank_np.shape[1], mesh,
                                    subset)

    # per-group per-item collectors (scalars: O(fleet))
    r_instr = [np.zeros(n, np.int64) for n in counts]
    r_two = [np.zeros(n, np.int64) for n in counts]
    r_halt = [np.zeros(n, bool) for n in counts]
    r_out = [np.zeros(n, np.int32) for n in counts]
    r_mix = [np.zeros(len(iss.MIX_CLASSES), np.int64) for _ in groups]
    g_lane_steps = np.zeros(n_groups, np.int64)
    g_segments = np.zeros(n_groups, np.int64)
    if keep_state:
        r_mem = [np.zeros((n, g.mem_words), np.int32)
                 for n, g in zip(counts, groups)]
        r_regs = [np.zeros((n, 16), np.int32) for n in counts]
        r_pc = [np.zeros(n, np.int32) for n in counts]
        r_mix_items = [np.zeros((n, len(iss.MIX_CLASSES)), np.int32)
                       for n in counts]

    t0 = time.perf_counter()
    prefs = [_Prefetcher(g.source, g.n_items,
                         block=max(1, min(chunk, g.n_items)),
                         background=prefetch)
             for g in groups]
    try:
        cursor = np.zeros(n_groups, np.int64)   # next item per group
        ids = np.full(chunk, -1, np.int64)      # item index within group
        lane_group = np.full(chunk, -1, np.int64)
        lane_ms = np.zeros(chunk, np.int64)     # host copy of budgets

        def admit(state, free_lanes):
            """Backfill `free_lanes` with items from any pending group."""
            take = _apportion(len(free_lanes), counts - cursor)
            n_new = int(take.sum())
            if n_new == 0:
                return state, 0
            new_mems = np.zeros((chunk, mem_words), np.int32)
            new_prog = np.zeros(chunk, np.int32)
            new_ms = np.zeros(chunk, np.int32)
            replace = np.zeros(chunk, bool)
            off = 0
            for g in np.nonzero(take)[0]:
                k = int(take[g])
                lanes = free_lanes[off:off + k]
                off += k
                new_mems[lanes, :groups[g].mem_words] = prefs[g].take(k)
                new_prog[lanes] = g
                new_ms[lanes] = ms_of[g]
                replace[lanes] = True
                ids[lanes] = np.arange(cursor[g], cursor[g] + k)
                lane_group[lanes] = g
                lane_ms[lanes] = ms_of[g]
                cursor[g] += k
            if state is None:
                return (new_mems, replace, new_prog, new_ms), n_new
            return _refill_packed(state, jnp.asarray(replace),
                                  jnp.asarray(new_mems),
                                  jnp.asarray(new_prog),
                                  jnp.asarray(new_ms)), n_new

        # initial fill (admit into a fresh pool; padding lanes carry
        # budget 0 and stay parked forever)
        (first, active0, prog0, ms0), _ = admit(None, np.arange(chunk))
        state = _fresh_packed(first, active0, prog0, ms0)
        if mesh is not None:
            state = jax.tree.map(jax.device_put, state,
                                 dsharding.lane_shardings(mesh, state))

        prev_instr = np.zeros(chunk, np.int64)
        lane_steps = 0
        n_segments = 0
        expected_done = chunk - int((ids >= 0).sum())

        while (ids >= 0).any():
            state = seg_fn(bank, code_len, mem_len, state)
            n_segments += 1
            active = ids >= 0
            act_per_group = np.bincount(lane_group[active],
                                        minlength=n_groups)
            g_segments += act_per_group > 0

            # single-scalar sync, as in run_stream: if no lane finished,
            # every active lane ran exactly seg_steps
            if int(_done_count_packed(state)) == expected_done:
                lane_steps += chunk * seg_steps
                g_lane_steps += act_per_group * seg_steps
                prev_instr[active] += seg_steps
                continue

            halted = np.asarray(state.lanes.halted)
            n_instr = np.asarray(state.lanes.n_instr, np.int64)
            delta = int((n_instr - prev_instr).max(initial=0))
            lane_steps += chunk * delta
            g_lane_steps += act_per_group * delta
            prev_instr = n_instr

            done = active & (halted | (n_instr >= lane_ms))
            idx = np.nonzero(done)[0]
            if idx.size:
                jidx = jnp.asarray(idx)
                two = np.asarray(state.lanes.n_two_stage, np.int64)
                mix_rows = np.asarray(state.lanes.mix[jidx], np.int64)
                # one O(done x mem_words) row gather serves every
                # group's out-word read (and the keep_state memories) —
                # not a full O(chunk) column pull per group
                need_mem = keep_state or any(
                    g.out_addr is not None for g in groups)
                if need_mem:
                    mem_rows = np.asarray(state.lanes.mem[jidx])
                if keep_state:
                    regs_rows = np.asarray(state.lanes.regs[jidx])
                    pc_rows = np.asarray(state.lanes.pc)[idx]
                for g in np.unique(lane_group[idx]):
                    sel = lane_group[idx] == g
                    lg = idx[sel]
                    items = ids[lg]
                    r_instr[g][items] = n_instr[lg]
                    r_two[g][items] = two[lg]
                    r_halt[g][items] = halted[lg]
                    r_mix[g] += mix_rows[sel].sum(0)
                    if groups[g].out_addr is not None:
                        r_out[g][items] = \
                            mem_rows[sel][:, groups[g].out_addr]
                    if keep_state:
                        r_mem[g][items] = \
                            mem_rows[sel][:, :groups[g].mem_words]
                        r_regs[g][items] = regs_rows[sel]
                        r_pc[g][items] = pc_rows[sel]
                        r_mix_items[g][items] = mix_rows[sel]

                # retire done lanes, then backfill from any pending group
                ids[idx] = -1
                lane_group[idx] = -1
                lane_ms[idx] = 0
                state, _ = admit(state, idx)
                # refilled lanes restart at n_instr=0; retired-but-empty
                # lanes keep their frozen device counters
                prev_instr[idx] = np.where(ids[idx] >= 0, 0,
                                           prev_instr[idx])
            expected_done = chunk - int((ids >= 0).sum())
    finally:
        for p in prefs:
            p.close()

    wall_s = time.perf_counter() - t0
    busy = np.array([r.sum() for r in r_instr], np.float64)
    busy_share = busy / max(busy.sum(), 1.0)
    results = []
    for g, grp in enumerate(groups):
        results.append(FleetResult(
            n_items=grp.n_items, n_instr=r_instr[g], n_two_stage=r_two[g],
            halted=r_halt[g], out=r_out[g], mix=r_mix[g],
            lane_steps=int(g_lane_steps[g]), n_segments=int(g_segments[g]),
            chunk=chunk, seg_steps=seg_steps,
            wall_s=wall_s * float(busy_share[g]),
            stepper=stepper, n_devices=n_dev,
            mems=r_mem[g] if keep_state else None,
            regs=r_regs[g] if keep_state else None,
            pc=r_pc[g] if keep_state else None,
            mix_items=r_mix_items[g] if keep_state else None,
        ))
    stats = PackedStats(
        n_groups=n_groups, n_progs=bank_np.shape[0],
        bank_width=bank_np.shape[1], lane_steps=lane_steps,
        n_segments=n_segments, chunk=chunk, seg_steps=seg_steps,
        wall_s=wall_s, stepper=stepper, n_devices=n_dev)
    return results, stats


def run_workload_stream(w: Workload, n_items: int, *, seed: int = 0,
                        chunk: int = 256, seg_steps: int = 4096,
                        max_steps: Optional[int] = None,
                        keep_state: bool = False,
                        mesh: Optional[Mesh] = None,
                        stepper: str = "branchless",
                        prefetch: bool = True) -> FleetResult:
    """Convenience wrapper: stream a FlexiBench workload end to end.

    The branchless/pallas steppers' opcode subset is derived from the
    workload's program text, so the compiled segment contains only the
    ISA subset this workload retires (the RISP specialization knob
    applied to the simulator)."""
    return run_stream(
        w.program.code, workload_source(w, seed), n_items=n_items,
        mem_words=w.total_mem_words,
        max_steps=w.max_steps if max_steps is None else max_steps,
        chunk=chunk,
        seg_steps=seg_steps, out_addr=w.out_addr, keep_state=keep_state,
        mesh=mesh, stepper=stepper, prefetch=prefetch)
