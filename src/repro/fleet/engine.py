"""Chunked streaming fleet executor with early-exit segmentation.

The monolithic path (`flexibits.fleet.run_fleet_sharded`) vmaps one
while_loop over the whole fleet: every SIMD lane is occupied until the
*slowest* item halts, and the host materializes all item memories at once.
This engine fixes both (DESIGN.md §9):

- **Chunked streaming.** Items flow through a fixed pool of `chunk` lanes;
  the host only ever holds O(chunk) memory images (the per-item *scalar*
  results — counts, halt flags, output words — are O(fleet), which is what
  makes 10M+ item runs feasible). Lane buffers are donated back to XLA
  between segments, so device memory is a single chunk-sized allocation.

- **Early-exit segmentation.** The interpreter runs in bounded cycle
  segments (default 4096). Between segments, halted lanes are harvested,
  compacted out, and refilled from the stream, so aggregate simulated
  lane-steps track the fleet's *actual* halt distribution instead of the
  worst case. Segmented execution retires the exact instruction sequence
  of `iss.run`, so final memories are bit-exact with the monolithic path.

- **Packed multi-program runtime** (`run_packed`, DESIGN.md §9.8). A
  heterogeneous `FleetPlan` no longer drains group by group: programs
  are padded into a bank, every lane carries its program row + step
  budget, and freed lanes are backfilled with items from ANY pending
  group, so one group's halt-time tail hides behind the others' backlog
  and the whole plan runs as one stream.

- **Resident runtime** (`refill="device"`, the default; DESIGN.md §9.9).
  Retire/refill runs as one donated on-device op against an
  asynchronously staged batch, the per-segment host sync collapses to
  one small stats read overlapped with the next segment's execution,
  and an optional superstep controller (`adaptive=True`) adapts each
  segment's step bound to the observed halt cadence. The PR-4
  host-refill loop survives as `refill="host"` for A/B runs — results
  are bit-exact either way.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh

from repro.distributed import sharding as dsharding
from repro.flexibench.base import Workload
from repro.flexibits import iss
from repro.flexibits.cycles import N_COST
from repro.kernels import iss_stepper

STEPPERS = ("branchless", "pallas", "switch")
REFILLS = ("device", "host")   # resident on-device refill (§9.9) vs A/B

# resident-runtime safety bounds (see run_packed): past either, the
# engine falls back to the host-refill loop rather than risking int32
# mix-counter overflow or an O(fleet) keep_state device allocation
_RESIDENT_MIX_LIMIT = 2**31 - 1
_RESIDENT_KEEP_STATE_WORDS = 1 << 27   # ~512 MB of int32 device rows

# source protocol: source(start, count) -> (count, mem_words) int32
Source = Callable[[int, int], np.ndarray]


def array_source(mems: np.ndarray) -> Source:
    """Stream an in-memory (n_items, M) array (parity tests, small fleets)."""
    mems = np.asarray(mems, np.int32)

    def src(start: int, count: int) -> np.ndarray:
        return mems[start:start + count]

    return src


def workload_source(w: Workload, seed: int = 0,
                    gen_block: int = 256) -> Source:
    """O(chunk) on-demand input generation for one workload.

    Generation is batched over fixed *aligned* blocks of `gen_block`
    items: item i's inputs are row `i % gen_block` of
    `w.gen_inputs(default_rng([seed, i // gen_block]), gen_block)`. The
    aligned block an item falls in is a pure function of its index, so
    the fleet is identical no matter how the engine's refill boundaries
    slice the stream (chunk/seg_steps are pure performance knobs) —
    while the host hot path pays one Generator construction and one
    vectorized `gen_inputs` call per block instead of per item.
    `gen_block` is part of the stream's identity (a different block size
    is a different — equally valid — fleet), not an engine tuning knob.

    The last generated block is cached: the engine consumes items in
    stream order, so a request straddling a block boundary reuses the
    cached block instead of regenerating it.
    """
    base = w.initial_memory(np.zeros(w.n_inputs, np.int32))
    gen_block = max(1, gen_block)
    cache = {"blk": -1, "xs": None}

    def block(blk: int) -> np.ndarray:
        if cache["blk"] != blk:
            rng = np.random.default_rng([seed, blk])
            cache["xs"] = np.asarray(w.gen_inputs(rng, gen_block), np.int32)
            cache["blk"] = blk
        return cache["xs"]

    def src(start: int, count: int) -> np.ndarray:
        if count <= 0:
            return np.zeros((0, base.size), np.int32)
        parts = []
        i = start
        while i < start + count:
            blk, off = divmod(i, gen_block)
            k = min(gen_block - off, start + count - i)
            parts.append(block(blk)[off:off + k])
            i += k
        xs = parts[0] if len(parts) == 1 else np.concatenate(parts)
        mems = np.tile(base, (count, 1))
        mems[:, :xs.shape[1]] = xs
        return mems

    return src


class _Prefetcher:
    """Double-buffered async host refill (DESIGN.md §9.6).

    Source generation is host work (per-item RNG, memory-image assembly);
    segment execution is device work. A one-worker executor keeps exactly
    one `block`-sized fetch in flight, so generating the next chunk of
    items overlaps the device segment instead of serializing after it.
    The engine consumes items strictly in stream order, so a single
    pending future is a full double buffer. `background=False` degrades
    to synchronous fetches (for sources that aren't thread-safe).
    """

    def __init__(self, source: Source, n_items: int, block: int,
                 background: bool = True):
        self._source = source
        self._n = n_items
        self._block = max(1, block)
        self._cursor = 0          # next un-requested item
        self._taken = 0           # items handed to the engine so far
        self._buf: Optional[np.ndarray] = None
        self._off = 0
        self._fut = None
        self._ex = concurrent.futures.ThreadPoolExecutor(max_workers=1) \
            if background else None
        if self._ex is not None:
            self._submit()

    def _submit(self):
        count = min(self._block, self._n - self._cursor)
        if count > 0:
            start = self._cursor
            self._cursor += count
            self._fut = self._ex.submit(self._source, start, count)
        else:
            self._fut = None

    def take(self, count: int) -> np.ndarray:
        """Next `count` item memories, in stream order.

        Requests past the declared stream length fail loudly with the
        full cursor state — "exhausted" alone is undebuggable when a
        plan/group/source disagrees with the engine about `n_items`.
        """
        if self._taken + count > self._n:
            raise RuntimeError(
                f"source stream exhausted: requested {count} item(s) at "
                f"stream cursor {self._taken}, but the source holds only "
                f"{self._n} item(s) "
                f"({self._n - self._taken} item(s) remaining)")
        self._taken += count
        if self._ex is None:
            start = self._cursor
            self._cursor += count
            return np.asarray(self._source(start, count), np.int32)
        parts = []
        while count > 0:
            if self._buf is None or self._off >= len(self._buf):
                if self._fut is None:
                    raise RuntimeError(
                        f"source stream exhausted: no fetch in flight at "
                        f"stream cursor {self._taken}, request cursor "
                        f"{self._cursor}, n_items={self._n}")
                self._buf = np.asarray(self._fut.result(), np.int32)
                self._off = 0
                self._submit()          # refill the second buffer now
            k = min(count, len(self._buf) - self._off)
            parts.append(self._buf[self._off:self._off + k])
            self._off += k
            count -= k
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def close(self):
        """Cancel/drain the in-flight fetch and join the worker.

        `shutdown(wait=False)` would leave a running background fetch
        alive past close — a leaked non-daemon thread still calling the
        source after the engine returned (or raised). Cancel the pending
        future if it has not started; if it is already running, drain it
        (`wait=True`) so the source is never invoked after close().
        """
        if self._ex is not None:
            self._ex.shutdown(wait=True, cancel_futures=True)
            self._fut = None


@dataclasses.dataclass
class FleetResult:
    """Per-item scalars plus engine-level accounting for one stream run."""
    n_items: int
    n_instr: np.ndarray          # (n,) retired instructions per item
    n_two_stage: np.ndarray      # (n,)
    halted: np.ndarray           # (n,) bool (False = max_steps exhausted)
    out: np.ndarray              # (n,) word at out_addr (0 if no out_addr)
    mix: np.ndarray              # (8,) retired-instruction mix, fleet total
    lane_steps: int              # SIMD lane-step slots the engine executed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    stepper: str = "branchless"
    n_devices: int = 1
    # full final state, only populated with keep_state=True (O(fleet) host
    # memory — for parity tests and the legacy ISSState wrapper)
    mems: Optional[np.ndarray] = None    # (n, M)
    regs: Optional[np.ndarray] = None    # (n, 16)
    pc: Optional[np.ndarray] = None      # (n,)
    mix_items: Optional[np.ndarray] = None  # (n, 8)
    # per-item accumulated timing ticks (§9.10) — populated when the
    # group ran with a cycle-cost row, None for cycles-off runs
    n_cycles: Optional[np.ndarray] = None   # (n,)

    @property
    def busy_steps(self) -> int:
        """Lane-steps that retired a real instruction (useful work)."""
        return int(self.n_instr.sum())

    @property
    def monolithic_lane_steps(self) -> int:
        """Cost of the one-shot vmap(while_loop) on the same fleet: every
        lane runs (masked) until the slowest item halts."""
        if self.n_items == 0:
            return 0
        return int(self.n_items) * int(self.n_instr.max())

    @property
    def items_per_s(self) -> float:
        return self.n_items / self.wall_s if self.wall_s > 0 else float("inf")


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill(state: iss.ISSState, replace, new_mems) -> iss.ISSState:
    """Reset `replace` lanes to a fresh item (mem from new_mems)."""
    rep1 = replace[:, None]
    return iss.ISSState(
        regs=jnp.where(rep1, 0, state.regs),
        pc=jnp.where(replace, 0, state.pc),
        mem=jnp.where(rep1, new_mems, state.mem),
        halted=jnp.where(replace, False, state.halted),
        n_instr=jnp.where(replace, 0, state.n_instr),
        n_two_stage=jnp.where(replace, 0, state.n_two_stage),
        mix=jnp.where(rep1, 0, state.mix),
        n_cycles=jnp.where(replace, 0, state.n_cycles),
    )


def _fresh_chunk(mems: np.ndarray, active: np.ndarray) -> iss.ISSState:
    n, _ = mems.shape
    return iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32),
        pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems, iss.I32),
        halted=jnp.asarray(~active),   # padding lanes never step
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
        n_cycles=jnp.zeros((n,), iss.I32),
    )


def run_stream(code: np.ndarray, source: Source, *, n_items: int,
               mem_words: int, max_steps: int, chunk: int = 256,
               seg_steps: int = 4096, out_addr: Optional[int] = None,
               keep_state: bool = False,
               mesh: Optional[Mesh] = None,
               stepper: str = "branchless",
               subset: Optional[frozenset] = None,
               prefetch: bool = True, refill: str = "device",
               adaptive: bool = False,
               cost: Optional[np.ndarray] = None) -> FleetResult:
    """Stream `n_items` memory images from `source` through `chunk` lanes.

    Returns per-item scalars in item order. With `keep_state=True` the
    full final state (memories, registers, pc) is also collected — O(fleet)
    host memory, so only use it for parity checks or small fleets.

    `stepper` picks the segment interpreter: "branchless" (lane-parallel
    masked-select stepper, DESIGN.md §9.5), "pallas" (fused-segment
    kernel — the whole segment of a lane tile runs inside one kernel
    invocation with state resident, §9.7), or "switch" (the legacy
    vmapped lax.switch interpreter). `subset` optionally pins the static
    opcode subset for the branchless/pallas steppers; by default it is
    derived from the program text (`iss.opcode_subset`), letting the
    compiler drop opcode classes the workload can never retire. With a
    `mesh`, lanes are sharded over every mesh axis and each device steps
    its shard independently via shard_map (DESIGN.md §9.6). `prefetch`
    overlaps host-side source generation with device segments (double
    buffering).

    Implemented as the single-group special case of the packed
    multi-program runtime (`run_packed`, DESIGN.md §9.8) — one stream
    loop serves both, so the sync/harvest/refill subtleties exist in
    exactly one place — with the run's whole-pool accounting (lane-step
    slots including padding lanes, segment count, measured wall clock)
    folded back into the returned `FleetResult`. `refill`/`adaptive`
    pick the resident runtime and superstep controller exactly as in
    `run_packed` (DESIGN.md §9.9); with the default resident loop the
    per-segment host sync is one small async stats read, with
    `refill="host"` it is the PR-4 blocking done-count scalar.

    `cost` optionally turns on the per-lane timing layer (DESIGN.md
    §9.10): an (N_COST,) int32 cycle-cost row (`cycles.cost_row`) priced
    per retired instruction into each item's `n_cycles` tally.
    Architectural results are bit-identical with and without it.
    """
    results, stats = run_packed(
        [PackedGroup(code=code, source=source, n_items=n_items,
                     max_steps=max_steps, mem_words=mem_words,
                     out_addr=out_addr, cost=cost)],
        chunk=chunk, seg_steps=seg_steps, keep_state=keep_state,
        mesh=mesh, stepper=stepper, subset=subset, prefetch=prefetch,
        refill=refill, adaptive=adaptive)
    return dataclasses.replace(
        results[0], lane_steps=stats.lane_steps,
        n_segments=stats.n_segments, chunk=stats.chunk,
        wall_s=stats.wall_s)


# ---------------------------------------------------------------------------
# Packed multi-program fleet runtime (DESIGN.md §9.8)
#
# `run_stream` executes ONE program; a heterogeneous FleetPlan run group
# by group pays each group's tail idle (the last segments where only a
# few long-running items hold the whole lane pool), its own retrace, and
# its own host<->device round-trips. The packed runtime multiplexes every
# group through one stream: programs live in a padded program bank, each
# lane carries the bank row it is executing (`iss.PackedState.prog_id`)
# plus its own step budget, and the admission scheduler backfills every
# freed lane with an item from ANY pending group — proportional to the
# groups' remaining backlogs, so all groups drain together and the tail
# of one group is hidden behind the backlog of the others.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedGroup:
    """One group's inputs to the packed runtime (engine-level: program +
    item source; fleet/plan.py builds these from a FleetPlan)."""
    code: np.ndarray                  # program words (uint32 or int32)
    source: Source
    n_items: int
    max_steps: int
    mem_words: int
    out_addr: Optional[int] = None
    # optional (N_COST,) int32 cycle-cost row (cycles.cost_row) — turns
    # on per-lane n_cycles accounting for this group's items (§9.10)
    cost: Optional[np.ndarray] = None
    # optional static opcode subset for this program (e.g. FlexiLint's
    # reachable-only subset, DESIGN.md §9.11). The packed bank shares
    # one traced graph, so the run uses the union over groups; None
    # falls back to the text-derived `iss.opcode_subset(code)`.
    subset: Optional[frozenset] = None


@dataclasses.dataclass
class PackedStats:
    """Whole-run accounting of one packed stream (the per-group
    `FleetResult`s carry only the lane-step slots attributable to their
    own active lanes; idle/padding slots belong to the run).

    The sync-stats fields (DESIGN.md §9.9) make the host<->device
    cadence a first-class output: `host_syncs` counts every blocking
    device->host read the run performed, `sync_wait_s` the host time
    spent inside them, `refill_wall_s` the host time spent assembling/
    staging refills, and `device_busy_frac` estimates the fraction of
    the wall clock during which the device had work in flight (1 minus
    the host-only intervals where the device queue was observed empty).
    `seg_schedule` records the seg_steps actually used per segment —
    constant for a fixed run, the controller's trace for an adaptive
    one (pinned deterministic by tests/test_resident.py)."""
    n_groups: int
    n_progs: int
    bank_width: int
    lane_steps: int               # chunk x max-step-delta, summed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    stepper: str
    n_devices: int
    refill: str = "host"          # "device" (resident, §9.9) or "host"
    adaptive: bool = False
    host_syncs: int = 0           # blocking device->host reads
    sync_wait_s: float = 0.0      # host time blocked in those reads
    refill_wall_s: float = 0.0    # host time assembling/staging refills
    device_busy_frac: float = 1.0
    seg_schedule: tuple = ()      # seg_steps used, one entry per segment


class _SyncClock:
    """Counts/times every blocking device->host read plus the host-side
    refill work, and accumulates device-idle intervals for the
    `device_busy_frac` estimate (DESIGN.md §9.9)."""

    def __init__(self):
        self.host_syncs = 0
        self.sync_wait_s = 0.0
        self.refill_wall_s = 0.0
        self.idle_s = 0.0

    def fetch(self, x) -> np.ndarray:
        t0 = time.perf_counter()
        out = np.asarray(x)
        self.sync_wait_s += time.perf_counter() - t0
        self.host_syncs += 1
        return out

    def busy_frac(self, wall_s: float) -> float:
        if wall_s <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.idle_s / wall_s)


class _SuperstepController:
    """Adaptive superstep sizing (DESIGN.md §9.9).

    Tracks an EMA of the pool's finish hazard (retirements per executed
    pool-step) and picks the next segment length from a small
    power-of-two ladder below the configured `seg_steps`: when churn is
    high, shorter segments return finished lanes to the admission
    scheduler sooner (a lane that halts early in a long segment sits
    frozen — wasted occupancy — until the segment ends); when the pool
    is all long-lived tails the hazard decays and segments grow back to
    the cap, keeping the sync count low. The ladder is bounded (<= 6
    values), so the lru-cached segment runners stay bounded too — one
    compile per ladder rung, ever. Decisions are a pure function of the
    observed (retired, steps) sequence, so a plan+seed reruns to an
    identical segment schedule.
    """

    LADDER_SPAN = 16       # smallest rung = seg_steps / 16
    TARGET_FRAC = 0.25     # aim for ~chunk/4 retirements per segment
    EMA = 0.5

    def __init__(self, seg_steps: int, chunk: int, enabled: bool):
        base = max(1, seg_steps)
        rungs = {base}
        v = base
        while v > max(1, base // self.LADDER_SPAN):
            v = max(1, v // 2)
            rungs.add(v)
        self.ladder = tuple(sorted(rungs))
        self.base = base
        self.enabled = enabled
        self.target = max(1.0, self.TARGET_FRAC * chunk)
        self.rate = 0.0            # EMA of retirements per pool-step
        self.schedule = []

    def record(self, n_retired: int, steps: int):
        if steps > 0:
            self.rate = (self.EMA * (n_retired / steps)
                         + (1.0 - self.EMA) * self.rate)

    def next_seg(self) -> int:
        seg = self.base
        if self.enabled:
            for s in self.ladder:  # smallest rung meeting the target
                if self.rate * s >= self.target:
                    seg = s
                    break
        self.schedule.append(seg)
        return seg


def _apportion(slots: int, remaining) -> np.ndarray:
    """Admission policy: split `slots` free lanes over groups
    proportionally to their remaining backlogs (largest-remainder
    rounding, ties to the lower group index — deterministic).

    Proportional shares keep every pending group flowing and drain all
    groups at roughly the same time, so no group is left to run its tail
    alone at the end of the stream. Per-group results do not depend on
    the policy at all (item i of group g is a pure function of the
    group's source), only wall-clock does.
    """
    remaining = np.asarray(remaining, np.int64)
    total = int(remaining.sum())
    slots = min(int(slots), total)
    take = np.zeros(len(remaining), np.int64)
    if slots <= 0:
        return take
    quota = slots * remaining / total
    take = np.minimum(np.floor(quota).astype(np.int64), remaining)
    left = slots - int(take.sum())
    if left > 0:
        frac = np.where(remaining > take, quota - take, -1.0)
        for g in np.argsort(-frac, kind="stable")[:left]:
            take[g] += 1
    return take


def _fresh_packed(mems: np.ndarray, active: np.ndarray,
                  prog_id: np.ndarray,
                  max_steps: np.ndarray) -> iss.PackedState:
    return iss.PackedState(
        lanes=_fresh_chunk(mems, active),
        prog_id=jnp.asarray(prog_id, iss.I32),
        max_steps=jnp.asarray(max_steps, iss.I32))


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill_packed(state: iss.PackedState, replace, new_mems, new_prog,
                   new_ms) -> iss.PackedState:
    """Reset `replace` lanes to a fresh item of (possibly) another group:
    new memory image, bank row, and step budget."""
    return iss.PackedState(
        lanes=_refill(state.lanes, replace, new_mems),
        prog_id=jnp.where(replace, new_prog, state.prog_id),
        max_steps=jnp.where(replace, new_ms, state.max_steps))


@jax.jit
def _done_count_packed(state: iss.PackedState):
    """Scalar count of done lanes (halted or own step budget exhausted;
    padding lanes carry budget 0 and count as done).

    The engine's per-segment host sync: comparing this single int32
    against the host-known value tells whether any lane finished this
    segment — only then is the O(chunk) harvest pulled."""
    return (state.lanes.halted
            | (state.lanes.n_instr >= state.max_steps)).sum()


def _packed_state_specs(mesh: Mesh, mem_words: int):
    """Shard specs for a packed lane pool, derived from the real state
    constructor (via eval_shape) so the new lane fields (prog_id,
    max_steps) can never drift from what run_packed actually passes."""
    abstract = jax.eval_shape(
        lambda: _fresh_packed(np.zeros((1, mem_words), np.int32),
                              np.ones(1, bool), np.zeros(1, np.int32),
                              np.ones(1, np.int32)))
    return dsharding.lane_specs(mesh, abstract)


@functools.lru_cache(maxsize=None)
def _packed_segment_runner(stepper: str, chunk: int, seg_steps: int,
                           mem_words: int, n_progs: int, bank_width: int,
                           mesh: Optional[Mesh], subset, timing: bool):
    """Compiled packed segment runner, cached per engine configuration.

    The bank, per-program code lengths, per-program memory bounds, and
    per-program cycle-cost rows are traced *inputs* (not closure
    constants), so two plans that share shapes and opcode subset reuse
    one compiled callable even with different programs. Per-lane
    `max_steps` lives in the state, so the budget never appears in the
    cache key at all — one compiled runner serves every heterogeneous
    budget mix. `timing` is static: with it off the cost operand is a
    dead argument and the compiled segment is the cycles-off graph.
    """
    def seg(bank, code_len, mem_len, cost, state):
        cr = cost if timing else None
        if stepper == "switch":
            lanes = jax.vmap(
                lambda p, m, l: iss.run_segment_banked(
                    bank, code_len, p, m, l, seg_steps, mem_len, cr)
            )(state.prog_id, state.max_steps, state.lanes)
            return iss.PackedState(lanes=lanes, prog_id=state.prog_id,
                                   max_steps=state.max_steps)
        if stepper == "pallas":
            return iss_stepper.iss_segment_banked(
                bank, code_len, state, seg_steps=seg_steps, subset=subset,
                mem_len=mem_len, cost=cr)
        return iss.run_segment_lanes_banked(bank, code_len, state,
                                            seg_steps, subset, mem_len,
                                            cr)

    if mesh is None:
        return jax.jit(seg, donate_argnums=(4,))
    specs = _packed_state_specs(mesh, mem_words)
    bspecs = dsharding.bank_specs(mesh, (0, 0, 0, 0))
    fn = shard_map(seg, mesh=mesh, in_specs=(*bspecs, specs),
                   out_specs=specs, check_rep=False)
    return jax.jit(fn, donate_argnums=(4,))


class ResidentAcc(NamedTuple):
    """On-device result accumulators of the resident runtime (§9.9).

    Per-ITEM scalars are indexed by the item's global result row
    (`slot_base[group] + item index`), scattered once when the item's
    lane retires and fetched once at drain — per-item scalar results
    stay O(fleet) exactly as the host collectors did, they just live on
    the device until the stream ends. Per-GROUP mix totals accumulate
    in int32 (sound below 2^31 retired instructions per group per mix
    class; past that bound — or past the keep_state device-row budget —
    `run_packed` falls back to the host loop, whose collectors are
    int64 in host RAM). `prev_instr` is the per-lane retired-count
    snapshot at the
    last refill — the device-side form of the host path's `prev_instr`
    array, from which each segment's max step delta is measured. The
    keep_state leaves are None unless full final state was requested.
    """
    n_instr: jax.Array             # (total_items,) i32
    n_two: jax.Array               # (total_items,) i32
    n_cycles: jax.Array            # (total_items,) i32 timing ticks
    halted: jax.Array              # (total_items,) bool
    out: jax.Array                 # (total_items,) i32
    mix_g: jax.Array               # (n_groups, 8) i32
    prev_instr: jax.Array          # (chunk,) i32
    mems: Optional[jax.Array]      # (total_items, mem_words) i32
    regs: Optional[jax.Array]      # (total_items, 16) i32
    pc: Optional[jax.Array]        # (total_items,) i32
    mix_items: Optional[jax.Array]  # (total_items, 8) i32


@functools.partial(jax.jit, static_argnames=("use_pallas",),
                   donate_argnums=(0, 1, 2))
def _refill_resident(state: iss.PackedState, item_slot, acc: ResidentAcc,
                     staged_mems, staged_prog, staged_ms, staged_slot,
                     n_staged, out_addr, *, use_pallas: bool):
    """Retire + refill, entirely on device (DESIGN.md §9.9).

    One donated op replaces the host path's demux->rebuild->device_put
    cycle: finished lanes are detected against their own budgets
    (`iss.retire_mask`), their tallies scattered into the `ResidentAcc`
    rows of the items they carried (dropped-out-of-range scatter — only
    retiring lanes write), and fresh items swapped in from the staged
    batch in lane-rank order (`iss.refill_take` + `iss.refill_lanes`,
    or the banked Pallas swap `iss_stepper.iss_refill` when the fused
    stepper runs single-device). The lane state never leaves the
    device.

    Returns the refreshed (state, item_slot, acc) plus a small int32
    stats vector — [n_retired, n_consumed, max step delta,
    active-lanes-per-group...] — describing the segment that just ran;
    that vector is the ONLY thing the host reads per segment, fetched
    asynchronously while the next segment executes.
    """
    lanes = state.lanes
    n_groups = out_addr.shape[0]
    active = item_slot >= 0
    retired = iss.retire_mask(state, item_slot)

    # ---- accounting of the segment that just ran (host-free)
    delta = jnp.max(lanes.n_instr - acc.prev_instr, initial=0)
    act_g = jnp.zeros((n_groups,), iss.I32).at[state.prog_id].add(
        active.astype(iss.I32))

    # ---- retire: scatter finished lanes' tallies at their item rows
    n_total = acc.n_instr.shape[0]
    slot = jnp.where(retired, item_slot, n_total)   # OOB rows drop

    def put(buf, val):
        return None if buf is None else buf.at[slot].set(val, mode="drop")

    col = out_addr[state.prog_id]
    out_val = jnp.take_along_axis(
        lanes.mem, jnp.clip(col, 0, lanes.mem.shape[1] - 1)[:, None],
        axis=1)[:, 0]
    out_val = jnp.where(col >= 0, out_val, 0)
    acc = acc._replace(
        n_instr=put(acc.n_instr, lanes.n_instr),
        n_two=put(acc.n_two, lanes.n_two_stage),
        n_cycles=put(acc.n_cycles, lanes.n_cycles),
        halted=put(acc.halted, lanes.halted),
        out=put(acc.out, out_val),
        mix_g=acc.mix_g.at[state.prog_id].add(
            jnp.where(retired[:, None], lanes.mix, 0)),
        mems=put(acc.mems, lanes.mem),
        regs=put(acc.regs, lanes.regs),
        pc=put(acc.pc, lanes.pc),
        mix_items=put(acc.mix_items, lanes.mix))

    # ---- refill freed lanes from the staged batch, in lane-rank order
    free = retired | ~active
    take, src = iss.refill_take(free, n_staged)
    swap = iss_stepper.iss_refill if use_pallas else iss.refill_lanes
    new_state = swap(state, take, src, staged_mems, staged_prog,
                     staged_ms)
    new_slot = jnp.where(take, staged_slot[src],
                         jnp.where(retired, -1, item_slot))
    acc = acc._replace(prev_instr=jnp.where(take, 0, lanes.n_instr))
    stats = jnp.concatenate([
        jnp.stack([retired.sum().astype(iss.I32),
                   take.sum().astype(iss.I32), delta.astype(iss.I32)]),
        act_g])
    return new_state, new_slot, acc, stats


def run_packed(groups, *, chunk: int = 256, seg_steps: int = 4096,
               keep_state: bool = False, mesh: Optional[Mesh] = None,
               stepper: str = "branchless",
               subset: Optional[frozenset] = None,
               prefetch: bool = True, refill: str = "device",
               adaptive: bool = False):
    """Execute every `PackedGroup` through ONE packed stream.

    Returns `(results, stats)`: `results[g]` is a per-group `FleetResult`
    bit-exact with what `run_stream` would produce for group g alone —
    identical per-item instruction/timing/mix tallies and final state
    (`tests/test_packed.py` pins this three ways) — and `stats` is the
    whole-run `PackedStats`.

    The program bank holds one padded row per group; every stepper
    fetches through the per-program clamp (`iss.fetch_banked`), bounds
    each lane's data-memory ports at its group's own `mem_words` (so
    clamp-on-read / drop-on-write happen at the program's boundary even
    though the pool memory is padded to the largest group's), and the
    branchless/pallas steppers compile ONE graph specialized to the
    *union* opcode subset of the bank (a superset of every row's subset,
    so per-group bit-exactness is preserved). Lane admission backfills
    freed lanes from any pending group (`_apportion`); per-group sources
    prefetch concurrently, each double-buffered as in `run_stream`.

    Per-group accounting: `lane_steps`/`n_segments` count only segments
    slots where the group had active lanes; `wall_s` splits the measured
    whole-run wall clock proportionally to retired instructions (the
    sums over groups match the run, up to idle-lane slots, which belong
    to `stats`).

    `refill` picks the stream loop (DESIGN.md §9.9): "device" (the
    default) is the *resident* runtime — retire/refill happens in one
    donated on-device op against a staged batch that was uploaded
    asynchronously while the previous segment ran, and the only
    per-segment host read is one small stats vector fetched while the
    NEXT segment executes — while "host" keeps the PR-4 loop (blocking
    done-count read, host demux/rebuild, device_put) as the A/B
    baseline. Per-group results are bit-exact either way
    (tests/test_resident.py pins full-state parity). `adaptive` turns
    on the superstep controller (§9.9): each segment's step bound is
    picked from a bounded power-of-two ladder under `seg_steps` by the
    observed halt cadence — deterministic for a given plan, bit-exact
    with any fixed schedule.
    """
    groups = list(groups)
    if not groups:
        raise ValueError("run_packed needs at least one group")
    if seg_steps < 1:
        raise ValueError("seg_steps must be >= 1")
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    if stepper not in STEPPERS:
        raise ValueError(f"stepper must be one of {STEPPERS}")
    if refill not in REFILLS:
        raise ValueError(f"refill must be one of {REFILLS}")

    n_groups = len(groups)
    counts = np.array([g.n_items for g in groups], np.int64)
    total_items = int(counts.sum())
    if refill == "device" and groups:
        # resident-safety fallback: the on-device per-group mix
        # counters are int32 (a group's per-class retired count is
        # bounded by n_items x max_steps), and keep_state scatters full
        # final state into O(fleet) device rows — past either bound the
        # host loop (int64 collectors, host-RAM state) is the correct
        # runtime, so fall back rather than overflow/OOM silently; the
        # returned PackedStats.refill reports what actually ran.
        mix_bound = max(int(g.n_items) * int(g.max_steps)
                        for g in groups)
        ks_words = 0
        if keep_state:
            ks_words = total_items * (
                max(g.mem_words for g in groups) + 16 + 1
                + len(iss.MIX_CLASSES))
        if mix_bound > _RESIDENT_MIX_LIMIT \
                or ks_words > _RESIDENT_KEEP_STATE_WORDS:
            refill = "host"
    if total_items == 0:
        empty = [FleetResult(
            n_items=0, n_instr=np.zeros(0, np.int64),
            n_two_stage=np.zeros(0, np.int64), halted=np.zeros(0, bool),
            out=np.zeros(0, np.int32),
            mix=np.zeros(len(iss.MIX_CLASSES), np.int64), lane_steps=0,
            n_segments=0, chunk=0, seg_steps=seg_steps, wall_s=0.0,
            stepper=stepper,
            n_cycles=None if g.cost is None else np.zeros(0, np.int64))
            for g in groups]
        return empty, PackedStats(
            n_groups=n_groups, n_progs=n_groups, bank_width=0,
            lane_steps=0, n_segments=0, chunk=0, seg_steps=seg_steps,
            wall_s=0.0, stepper=stepper, n_devices=1, refill=refill,
            adaptive=adaptive)
    mem_words = max(g.mem_words for g in groups)
    bank_np, code_len_np = iss.pack_programs([g.code for g in groups])
    if subset is None:
        subset = frozenset().union(
            *(g.subset if g.subset is not None
              else iss.opcode_subset(g.code) for g in groups))
    bank = jnp.asarray(bank_np)
    code_len = jnp.asarray(code_len_np)
    # per-program memory bounds: lanes of a small-memory group keep
    # clamp-on-read / drop-on-write at their OWN word count even though
    # the pool memory is padded to the largest group's
    mem_len = jnp.asarray([g.mem_words for g in groups], iss.I32)
    ms_of = np.array([g.max_steps for g in groups], np.int64)
    # per-program cycle-cost rows (§9.10): the timing layer is ON iff
    # any group carries a cost row. Cost-less groups in a mixed plan get
    # a zero row — their lanes share the timing-on graph but tally 0.
    timing = any(g.cost is not None for g in groups)
    cost_np = np.zeros((n_groups, N_COST), np.int32)
    for i, g in enumerate(groups):
        if g.cost is not None:
            cost_np[i] = np.asarray(g.cost, np.int32)
    cost = jnp.asarray(cost_np)

    chunk = min(chunk, max(total_items, 1))
    n_dev = 1
    if mesh is not None:
        n_dev = int(np.prod(list(mesh.shape.values())))
    round_to = n_dev
    if stepper == "pallas" and chunk > 128:
        # same wide-lane-tile rule as run_stream: pad the pool to a
        # 128-multiple (lcm'd with the mesh) instead of tiling at a
        # prime-ish chunk's largest small divisor
        round_to = int(128 * n_dev // np.gcd(128, n_dev))
    if round_to > 1:
        chunk = -(-chunk // round_to) * round_to

    clock = _SyncClock()
    controller = _SuperstepController(seg_steps, chunk, adaptive)
    loop = _stream_resident if refill == "device" else _stream_host
    t0 = time.perf_counter()
    prefs = [_Prefetcher(g.source, g.n_items,
                         block=max(1, min(chunk, g.n_items)),
                         background=prefetch)
             for g in groups]
    try:
        out = loop(groups, prefs, counts, ms_of, bank, code_len, mem_len,
                   cost, timing, bank_np, chunk, keep_state, mesh,
                   stepper, subset, mem_words, controller, clock)
    finally:
        for p in prefs:
            p.close()

    wall_s = time.perf_counter() - t0
    busy = np.array([r.sum() for r in out["r_instr"]], np.float64)
    busy_share = busy / max(busy.sum(), 1.0)
    results = []
    for g, grp in enumerate(groups):
        results.append(FleetResult(
            n_items=grp.n_items, n_instr=out["r_instr"][g],
            n_two_stage=out["r_two"][g],
            halted=out["r_halt"][g], out=out["r_out"][g],
            mix=out["r_mix"][g],
            lane_steps=int(out["g_lane_steps"][g]),
            n_segments=int(out["g_segments"][g]),
            chunk=chunk, seg_steps=seg_steps,
            wall_s=wall_s * float(busy_share[g]),
            stepper=stepper, n_devices=n_dev,
            mems=out["r_mem"][g] if keep_state else None,
            regs=out["r_regs"][g] if keep_state else None,
            pc=out["r_pc"][g] if keep_state else None,
            mix_items=out["r_mix_items"][g] if keep_state else None,
            n_cycles=out["r_cycles"][g] if grp.cost is not None else None,
        ))
    stats = PackedStats(
        n_groups=n_groups, n_progs=bank_np.shape[0],
        bank_width=bank_np.shape[1], lane_steps=out["lane_steps"],
        n_segments=out["n_segments"], chunk=chunk, seg_steps=seg_steps,
        wall_s=wall_s, stepper=stepper, n_devices=n_dev, refill=refill,
        adaptive=adaptive, host_syncs=clock.host_syncs,
        sync_wait_s=clock.sync_wait_s, refill_wall_s=clock.refill_wall_s,
        device_busy_frac=clock.busy_frac(wall_s),
        seg_schedule=tuple(controller.schedule[:out["n_segments"]]))
    return results, stats


def _stream_host(groups, prefs, counts, ms_of, bank, code_len, mem_len,
                 cost, timing, bank_np, chunk, keep_state, mesh, stepper,
                 subset, mem_words, controller: _SuperstepController,
                 clock: _SyncClock):
    """The PR-4 host-refill stream loop (the `refill="host"` A/B path):
    blocking single-scalar done-count sync per segment, host-side
    demux + refill rebuild + device_put on finishing segments."""
    n_groups = len(groups)
    r_instr = [np.zeros(n, np.int64) for n in counts]
    r_two = [np.zeros(n, np.int64) for n in counts]
    r_cycles = [np.zeros(n, np.int64) for n in counts]
    r_halt = [np.zeros(n, bool) for n in counts]
    r_out = [np.zeros(n, np.int32) for n in counts]
    r_mix = [np.zeros(len(iss.MIX_CLASSES), np.int64) for _ in groups]
    g_lane_steps = np.zeros(n_groups, np.int64)
    g_segments = np.zeros(n_groups, np.int64)
    r_mem = r_regs = r_pc = r_mix_items = None
    if keep_state:
        r_mem = [np.zeros((n, g.mem_words), np.int32)
                 for n, g in zip(counts, groups)]
        r_regs = [np.zeros((n, 16), np.int32) for n in counts]
        r_pc = [np.zeros(n, np.int32) for n in counts]
        r_mix_items = [np.zeros((n, len(iss.MIX_CLASSES)), np.int32)
                       for n in counts]

    cursor = np.zeros(n_groups, np.int64)   # next item per group
    ids = np.full(chunk, -1, np.int64)      # item index within group
    lane_group = np.full(chunk, -1, np.int64)
    lane_ms = np.zeros(chunk, np.int64)     # host copy of budgets

    def admit(state, free_lanes):
        """Backfill `free_lanes` with items from any pending group."""
        take = _apportion(len(free_lanes), counts - cursor)
        n_new = int(take.sum())
        if n_new == 0:
            return state, 0
        new_mems = np.zeros((chunk, mem_words), np.int32)
        new_prog = np.zeros(chunk, np.int32)
        new_ms = np.zeros(chunk, np.int32)
        replace = np.zeros(chunk, bool)
        off = 0
        for g in np.nonzero(take)[0]:
            k = int(take[g])
            lanes = free_lanes[off:off + k]
            off += k
            new_mems[lanes, :groups[g].mem_words] = prefs[g].take(k)
            new_prog[lanes] = g
            new_ms[lanes] = ms_of[g]
            replace[lanes] = True
            ids[lanes] = np.arange(cursor[g], cursor[g] + k)
            lane_group[lanes] = g
            lane_ms[lanes] = ms_of[g]
            cursor[g] += k
        if state is None:
            return (new_mems, replace, new_prog, new_ms), n_new
        return _refill_packed(state, jnp.asarray(replace),
                              jnp.asarray(new_mems),
                              jnp.asarray(new_prog),
                              jnp.asarray(new_ms)), n_new

    # initial fill (admit into a fresh pool; padding lanes carry
    # budget 0 and stay parked forever)
    (first, active0, prog0, ms0), _ = admit(None, np.arange(chunk))
    state = _fresh_packed(first, active0, prog0, ms0)
    if mesh is not None:
        state = jax.tree.map(jax.device_put, state,
                             dsharding.lane_shardings(mesh, state))

    prev_instr = np.zeros(chunk, np.int64)
    lane_steps = 0
    n_segments = 0
    expected_done = chunk - int((ids >= 0).sum())

    while (ids >= 0).any():
        seg_steps = controller.next_seg()
        seg_fn = _packed_segment_runner(stepper, chunk, seg_steps,
                                        mem_words, n_groups,
                                        bank_np.shape[1], mesh, subset,
                                        timing)
        state = seg_fn(bank, code_len, mem_len, cost, state)
        n_segments += 1
        active = ids >= 0
        act_per_group = np.bincount(lane_group[active],
                                    minlength=n_groups)
        g_segments += act_per_group > 0

        # single-scalar sync, as in run_stream: if no lane finished,
        # every active lane ran exactly seg_steps
        if int(clock.fetch(_done_count_packed(state))) == expected_done:
            lane_steps += chunk * seg_steps
            g_lane_steps += act_per_group * seg_steps
            prev_instr[active] += seg_steps
            controller.record(0, seg_steps)
            continue

        t_harvest = time.perf_counter()
        wait_before = clock.sync_wait_s
        halted = clock.fetch(state.lanes.halted)
        n_instr = clock.fetch(state.lanes.n_instr).astype(np.int64)
        delta = int((n_instr - prev_instr).max(initial=0))
        lane_steps += chunk * delta
        g_lane_steps += act_per_group * delta
        prev_instr = n_instr

        done = active & (halted | (n_instr >= lane_ms))
        idx = np.nonzero(done)[0]
        if idx.size:
            jidx = jnp.asarray(idx)
            two = clock.fetch(state.lanes.n_two_stage).astype(np.int64)
            mix_rows = clock.fetch(state.lanes.mix[jidx]).astype(np.int64)
            if timing:   # one extra pull, only when the layer is on
                cyc = clock.fetch(state.lanes.n_cycles).astype(np.int64)
            # one O(done x mem_words) row gather serves every
            # group's out-word read (and the keep_state memories) —
            # not a full O(chunk) column pull per group
            need_mem = keep_state or any(
                g.out_addr is not None for g in groups)
            if need_mem:
                mem_rows = clock.fetch(state.lanes.mem[jidx])
            if keep_state:
                regs_rows = clock.fetch(state.lanes.regs[jidx])
                pc_rows = clock.fetch(state.lanes.pc)[idx]
            for g in np.unique(lane_group[idx]):
                sel = lane_group[idx] == g
                lg = idx[sel]
                items = ids[lg]
                r_instr[g][items] = n_instr[lg]
                r_two[g][items] = two[lg]
                if timing:
                    r_cycles[g][items] = cyc[lg]
                r_halt[g][items] = halted[lg]
                r_mix[g] += mix_rows[sel].sum(0)
                if groups[g].out_addr is not None:
                    r_out[g][items] = \
                        mem_rows[sel][:, groups[g].out_addr]
                if keep_state:
                    r_mem[g][items] = \
                        mem_rows[sel][:, :groups[g].mem_words]
                    r_regs[g][items] = regs_rows[sel]
                    r_pc[g][items] = pc_rows[sel]
                    r_mix_items[g][items] = mix_rows[sel]

            # retire done lanes, then backfill from any pending group
            ids[idx] = -1
            lane_group[idx] = -1
            lane_ms[idx] = 0
            state, _ = admit(state, idx)
            # refilled lanes restart at n_instr=0; retired-but-empty
            # lanes keep their frozen device counters
            prev_instr[idx] = np.where(ids[idx] >= 0, 0,
                                       prev_instr[idx])
        controller.record(int(idx.size), seg_steps)
        # the whole harvest+rebuild runs with the segment finished and
        # nothing dispatched: device-idle host work, minus the transfer
        # time already booked as sync wait
        dt = time.perf_counter() - t_harvest
        clock.refill_wall_s += dt
        clock.idle_s += max(0.0, dt - (clock.sync_wait_s - wait_before))
        expected_done = chunk - int((ids >= 0).sum())

    return {"r_instr": r_instr, "r_two": r_two, "r_halt": r_halt,
            "r_out": r_out, "r_mix": r_mix, "r_mem": r_mem,
            "r_regs": r_regs, "r_pc": r_pc, "r_mix_items": r_mix_items,
            "r_cycles": r_cycles,
            "g_lane_steps": g_lane_steps, "g_segments": g_segments,
            "lane_steps": lane_steps, "n_segments": n_segments}


def _stream_resident(groups, prefs, counts, ms_of, bank, code_len,
                     mem_len, cost, timing, bank_np, chunk, keep_state,
                     mesh, stepper, subset, mem_words,
                     controller: _SuperstepController,
                     clock: _SyncClock):
    """The resident stream loop (DESIGN.md §9.9, `refill="device"`).

    Pipeline per iteration, in device-queue order:

        refill_i  — donated on-device op: retire finished lanes into
                    the `ResidentAcc` rows, swap in staged items
        seg_i     — the segment, at the controller's step bound
        (host)    — async-fetch refill_i's stats vector, which blocks
                    only until refill_i is done — seg_i is already
                    executing behind it; then restock the staged batch
                    for refill_{i+1} (prefetcher take + async
                    device_put), all overlapped with seg_i

    The host therefore performs exactly ONE small read per segment and
    the device queue never drains while the stream has backlog. The
    loop exits after the refill that retires the last item; the final
    trailing segment dispatch sees an all-parked pool and its
    while_loop exits without stepping. Per-item results and final
    state are fetched ONCE, at drain.
    """
    n_groups = len(groups)
    total = int(counts.sum())
    slot_base = np.zeros(n_groups, np.int64)
    np.cumsum(counts[:-1], out=slot_base[1:])
    out_addr_np = np.asarray(
        [-1 if g.out_addr is None else g.out_addr for g in groups],
        np.int32)
    # the banked Pallas swap is the single-device fused-stepper path;
    # under a mesh the (bit-identical) jnp swap partitions with GSPMD
    use_pallas = stepper == "pallas" and mesh is None

    # ---- host mirror of the staged batch (stream order, FIFO)
    st_mems = np.zeros((chunk, mem_words), np.int32)
    st_prog = np.zeros(chunk, np.int32)
    st_ms = np.zeros(chunk, np.int32)
    st_slot = np.zeros(chunk, np.int32)
    staged = {"n": 0, "dirty": True, "dev": None}
    staged_cursor = np.zeros(n_groups, np.int64)
    stage_sh = None
    if mesh is not None:
        stage_sh = dsharding.stage_shardings(
            mesh, (st_mems, st_prog, st_ms, st_slot))

    def restock():
        take = _apportion(chunk - staged["n"], counts - staged_cursor)
        off = staged["n"]
        for g in np.nonzero(take)[0]:
            k = int(take[g])
            st_mems[off:off + k] = 0
            st_mems[off:off + k, :groups[g].mem_words] = prefs[g].take(k)
            st_prog[off:off + k] = g
            st_ms[off:off + k] = ms_of[g]
            st_slot[off:off + k] = slot_base[g] + np.arange(
                staged_cursor[g], staged_cursor[g] + k)
            staged_cursor[g] += k
            off += k
        if off != staged["n"]:
            staged["n"] = off
            staged["dirty"] = True

    def consume(k):
        if k <= 0:
            return
        keep = staged["n"] - k
        for buf in (st_mems, st_prog, st_ms, st_slot):
            buf[:keep] = buf[k:staged["n"]].copy()
        staged["n"] = keep
        staged["dirty"] = True

    def upload():
        """Async-stage the batch to device (device_put returns before
        the transfer completes, so this overlaps the running segment)."""
        if not staged["dirty"] and staged["dev"] is not None:
            return
        arrs = (st_mems.copy(), st_prog.copy(), st_ms.copy(),
                st_slot.copy())
        if mesh is None:
            staged["dev"] = tuple(jax.device_put(a) for a in arrs)
        else:
            staged["dev"] = tuple(jax.device_put(a, s)
                                  for a, s in zip(arrs, stage_sh))
        staged["dirty"] = False

    # ---- device state: an all-parked pool + result accumulators
    state = _fresh_packed(np.zeros((chunk, mem_words), np.int32),
                          np.zeros(chunk, bool),
                          np.zeros(chunk, np.int32),
                          np.zeros(chunk, np.int32))
    item_slot = jnp.full((chunk,), -1, iss.I32)
    if mesh is not None:
        state = jax.tree.map(jax.device_put, state,
                             dsharding.lane_shardings(mesh, state))
        item_slot = jax.device_put(
            item_slot, dsharding.lane_shardings(mesh, item_slot))
    n_mix = len(iss.MIX_CLASSES)
    acc = ResidentAcc(
        n_instr=jnp.zeros(total, iss.I32),
        n_two=jnp.zeros(total, iss.I32),
        n_cycles=jnp.zeros(total, iss.I32),
        halted=jnp.zeros(total, bool),
        out=jnp.zeros(total, iss.I32),
        mix_g=jnp.zeros((n_groups, n_mix), iss.I32),
        prev_instr=jnp.zeros(chunk, iss.I32),
        mems=jnp.zeros((total, mem_words), iss.I32) if keep_state
        else None,
        regs=jnp.zeros((total, 16), iss.I32) if keep_state else None,
        pc=jnp.zeros(total, iss.I32) if keep_state else None,
        mix_items=jnp.zeros((total, n_mix), iss.I32) if keep_state
        else None)
    out_addr_dev = jnp.asarray(out_addr_np)

    g_lane_steps = np.zeros(n_groups, np.int64)
    g_segments = np.zeros(n_groups, np.int64)
    lane_steps = 0
    n_segments = 0
    retired = 0
    prev_seg = 0

    restock()
    while retired < total:
        upload()
        state, item_slot, acc, stats = _refill_resident(
            state, item_slot, acc, *staged["dev"],
            jnp.asarray(staged["n"], iss.I32), out_addr_dev,
            use_pallas=use_pallas)
        seg_steps = controller.next_seg()
        seg_fn = _packed_segment_runner(stepper, chunk, seg_steps,
                                        mem_words, n_groups,
                                        bank_np.shape[1], mesh, subset,
                                        timing)
        state = seg_fn(bank, code_len, mem_len, cost, state)
        if hasattr(stats, "copy_to_host_async"):
            stats.copy_to_host_async()
        # blocks until refill_i only — seg_i is already running
        sv = clock.fetch(stats)
        n_ret, n_con, delta = int(sv[0]), int(sv[1]), int(sv[2])
        act = sv[3:].astype(np.int64)
        if (act > 0).any():
            n_segments += 1
            g_segments += act > 0
            g_lane_steps += act * delta
            lane_steps += chunk * delta
        controller.record(n_ret, prev_seg)
        prev_seg = seg_steps
        retired += n_ret
        t_refill = time.perf_counter()
        consume(n_con)
        restock()
        dt = time.perf_counter() - t_refill
        clock.refill_wall_s += dt
        try:
            if state.lanes.regs.is_ready():   # segment already done:
                clock.idle_s += dt            # restock was device-idle
        except AttributeError:
            pass

    # ---- drain: ONE demux of the on-device accumulators
    res_instr = clock.fetch(acc.n_instr).astype(np.int64)
    res_two = clock.fetch(acc.n_two).astype(np.int64)
    res_cycles = clock.fetch(acc.n_cycles).astype(np.int64) if timing \
        else np.zeros(total, np.int64)
    res_halt = clock.fetch(acc.halted)
    res_out = clock.fetch(acc.out)
    res_mix_g = clock.fetch(acc.mix_g).astype(np.int64)
    if keep_state:
        res_mems = clock.fetch(acc.mems)
        res_regs = clock.fetch(acc.regs)
        res_pc = clock.fetch(acc.pc)
        res_mix_items = clock.fetch(acc.mix_items)

    r_instr, r_two, r_halt, r_out, r_mix = [], [], [], [], []
    r_cycles = []
    r_mem = r_regs = r_pc = r_mix_items = None
    if keep_state:
        r_mem, r_regs, r_pc, r_mix_items = [], [], [], []
    for g, grp in enumerate(groups):
        sl = slice(int(slot_base[g]), int(slot_base[g] + counts[g]))
        r_instr.append(res_instr[sl])
        r_two.append(res_two[sl])
        r_cycles.append(res_cycles[sl])
        r_halt.append(res_halt[sl])
        r_out.append(res_out[sl])
        r_mix.append(res_mix_g[g])
        if keep_state:
            r_mem.append(res_mems[sl, :grp.mem_words].copy())
            r_regs.append(res_regs[sl])
            r_pc.append(res_pc[sl])
            r_mix_items.append(res_mix_items[sl])

    return {"r_instr": r_instr, "r_two": r_two, "r_halt": r_halt,
            "r_out": r_out, "r_mix": r_mix, "r_mem": r_mem,
            "r_regs": r_regs, "r_pc": r_pc, "r_mix_items": r_mix_items,
            "r_cycles": r_cycles,
            "g_lane_steps": g_lane_steps, "g_segments": g_segments,
            "lane_steps": lane_steps, "n_segments": n_segments}


def run_workload_stream(w: Workload, n_items: int, *, seed: int = 0,
                        chunk: int = 256, seg_steps: int = 4096,
                        max_steps: Optional[int] = None,
                        keep_state: bool = False,
                        mesh: Optional[Mesh] = None,
                        stepper: str = "branchless",
                        prefetch: bool = True, refill: str = "device",
                        adaptive: bool = False,
                        cost: Optional[np.ndarray] = None,
                        subset: Optional[frozenset] = None) -> FleetResult:
    """Convenience wrapper: stream a FlexiBench workload end to end.

    The branchless/pallas steppers' opcode subset is derived from the
    workload's program text, so the compiled segment contains only the
    ISA subset this workload retires (the RISP specialization knob
    applied to the simulator). `subset` pins it explicitly instead —
    e.g. FlexiLint's reachable-only subset (DESIGN.md §9.11)."""
    return run_stream(
        w.program.code, workload_source(w, seed), n_items=n_items,
        mem_words=w.total_mem_words,
        max_steps=w.max_steps if max_steps is None else max_steps,
        chunk=chunk, subset=subset,
        seg_steps=seg_steps, out_addr=w.out_addr, keep_state=keep_state,
        mesh=mesh, stepper=stepper, prefetch=prefetch, refill=refill,
        adaptive=adaptive, cost=cost)
