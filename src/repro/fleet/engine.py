"""Chunked streaming fleet executor with early-exit segmentation.

The monolithic path (`flexibits.fleet.run_fleet_sharded`) vmaps one
while_loop over the whole fleet: every SIMD lane is occupied until the
*slowest* item halts, and the host materializes all item memories at once.
This engine fixes both (DESIGN.md §9):

- **Chunked streaming.** Items flow through a fixed pool of `chunk` lanes;
  the host only ever holds O(chunk) memory images (the per-item *scalar*
  results — counts, halt flags, output words — are O(fleet), which is what
  makes 10M+ item runs feasible). Lane buffers are donated back to XLA
  between segments, so device memory is a single chunk-sized allocation.

- **Early-exit segmentation.** The interpreter runs in bounded cycle
  segments (default 4096). Between segments, halted lanes are harvested,
  compacted out, and refilled from the stream, so aggregate simulated
  lane-steps track the fleet's *actual* halt distribution instead of the
  worst case. Segmented execution retires the exact instruction sequence
  of `iss.run`, so final memories are bit-exact with the monolithic path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.flexibench.base import Workload
from repro.flexibits import iss

# source protocol: source(start, count) -> (count, mem_words) int32
Source = Callable[[int, int], np.ndarray]


def array_source(mems: np.ndarray) -> Source:
    """Stream an in-memory (n_items, M) array (parity tests, small fleets)."""
    mems = np.asarray(mems, np.int32)

    def src(start: int, count: int) -> np.ndarray:
        return mems[start:start + count]

    return src


def workload_source(w: Workload, seed: int = 0) -> Source:
    """O(chunk) on-demand input generation for one workload.

    Item i is seeded by (seed, i), so every item's inputs are a pure
    function of its index — the fleet is identical no matter how the
    engine's refill boundaries slice the stream (chunk/seg_steps are
    pure performance knobs).
    """
    base = w.initial_memory(np.zeros(w.n_inputs, np.int32))

    def src(start: int, count: int) -> np.ndarray:
        xs = np.stack([
            w.gen_inputs(np.random.default_rng([seed, i]), 1)[0]
            for i in range(start, start + count)])
        mems = np.tile(base, (count, 1))
        mems[:, :xs.shape[1]] = xs
        return mems

    return src


@dataclasses.dataclass
class FleetResult:
    """Per-item scalars plus engine-level accounting for one stream run."""
    n_items: int
    n_instr: np.ndarray          # (n,) retired instructions per item
    n_two_stage: np.ndarray      # (n,)
    halted: np.ndarray           # (n,) bool (False = max_steps exhausted)
    out: np.ndarray              # (n,) word at out_addr (0 if no out_addr)
    mix: np.ndarray              # (8,) retired-instruction mix, fleet total
    lane_steps: int              # SIMD lane-step slots the engine executed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    # full final state, only populated with keep_state=True (O(fleet) host
    # memory — for parity tests and the legacy ISSState wrapper)
    mems: Optional[np.ndarray] = None    # (n, M)
    regs: Optional[np.ndarray] = None    # (n, 16)
    pc: Optional[np.ndarray] = None      # (n,)
    mix_items: Optional[np.ndarray] = None  # (n, 8)

    @property
    def busy_steps(self) -> int:
        """Lane-steps that retired a real instruction (useful work)."""
        return int(self.n_instr.sum())

    @property
    def monolithic_lane_steps(self) -> int:
        """Cost of the one-shot vmap(while_loop) on the same fleet: every
        lane runs (masked) until the slowest item halts."""
        if self.n_items == 0:
            return 0
        return int(self.n_items) * int(self.n_instr.max())

    @property
    def items_per_s(self) -> float:
        return self.n_items / self.wall_s if self.wall_s > 0 else float("inf")


@functools.partial(jax.jit, donate_argnums=(1,),
                   static_argnames=("seg_steps", "max_steps"))
def _run_seg(code, state, *, seg_steps: int, max_steps: int):
    return jax.vmap(
        lambda s: iss.run_segment(code, s, seg_steps, max_steps))(state)


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill(state: iss.ISSState, replace, new_mems) -> iss.ISSState:
    """Reset `replace` lanes to a fresh item (mem from new_mems)."""
    rep1 = replace[:, None]
    return iss.ISSState(
        regs=jnp.where(rep1, 0, state.regs),
        pc=jnp.where(replace, 0, state.pc),
        mem=jnp.where(rep1, new_mems, state.mem),
        halted=jnp.where(replace, False, state.halted),
        n_instr=jnp.where(replace, 0, state.n_instr),
        n_two_stage=jnp.where(replace, 0, state.n_two_stage),
        mix=jnp.where(rep1, 0, state.mix),
    )


def _fresh_chunk(mems: np.ndarray, active: np.ndarray) -> iss.ISSState:
    n, _ = mems.shape
    return iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32),
        pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems, iss.I32),
        halted=jnp.asarray(~active),   # padding lanes never step
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
    )


def _shard_state(state: iss.ISSState, mesh: Mesh) -> iss.ISSState:
    """Lay the lane axis out over every mesh axis (pure data parallelism)."""
    axes = tuple(mesh.axis_names)
    lane = NamedSharding(mesh, P(axes))
    lane2d = NamedSharding(mesh, P(axes, None))
    return iss.ISSState(
        regs=jax.device_put(state.regs, lane2d),
        pc=jax.device_put(state.pc, lane),
        mem=jax.device_put(state.mem, lane2d),
        halted=jax.device_put(state.halted, lane),
        n_instr=jax.device_put(state.n_instr, lane),
        n_two_stage=jax.device_put(state.n_two_stage, lane),
        mix=jax.device_put(state.mix, lane2d),
    )


def run_stream(code: np.ndarray, source: Source, *, n_items: int,
               mem_words: int, max_steps: int, chunk: int = 256,
               seg_steps: int = 4096, out_addr: Optional[int] = None,
               keep_state: bool = False,
               mesh: Optional[Mesh] = None) -> FleetResult:
    """Stream `n_items` memory images from `source` through `chunk` lanes.

    Returns per-item scalars in item order. With `keep_state=True` the
    full final state (memories, registers, pc) is also collected — O(fleet)
    host memory, so only use it for parity checks or small fleets.
    """
    if seg_steps < 1:
        raise ValueError("seg_steps must be >= 1")
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    chunk = min(chunk, max(n_items, 1))
    if mesh is not None:
        n_dev = int(np.prod(list(mesh.shape.values())))
        chunk = -(-chunk // n_dev) * n_dev   # round up to mesh divisibility

    code = jnp.asarray(np.asarray(code).view(np.int32))

    # per-item result collectors (scalars: O(fleet))
    r_instr = np.zeros(n_items, np.int64)
    r_two = np.zeros(n_items, np.int64)
    r_halt = np.zeros(n_items, bool)
    r_out = np.zeros(n_items, np.int32)
    r_mix = np.zeros(len(iss.MIX_CLASSES), np.int64)
    if keep_state:
        r_mem = np.zeros((n_items, mem_words), np.int32)
        r_regs = np.zeros((n_items, 16), np.int32)
        r_pc = np.zeros(n_items, np.int32)
        r_mix_items = np.zeros((n_items, len(iss.MIX_CLASSES)), np.int32)

    t0 = time.perf_counter()

    # initial fill
    cursor = min(chunk, n_items)
    first = np.zeros((chunk, mem_words), np.int32)
    if cursor:
        first[:cursor] = source(0, cursor)
    ids = np.full(chunk, -1, np.int64)
    ids[:cursor] = np.arange(cursor)
    state = _fresh_chunk(first, ids >= 0)
    if mesh is not None:
        state = _shard_state(state, mesh)

    prev_instr = np.zeros(chunk, np.int64)
    lane_steps = 0
    n_segments = 0

    while (ids >= 0).any():
        state = _run_seg(code, state, seg_steps=seg_steps,
                         max_steps=max_steps)
        n_segments += 1

        halted = np.asarray(state.halted)
        n_instr = np.asarray(state.n_instr, np.int64)
        # SIMD cost: all lanes are occupied for the longest path this
        # segment took on any lane
        lane_steps += chunk * int((n_instr - prev_instr).max(initial=0))
        prev_instr = n_instr

        active = ids >= 0
        done = active & (halted | (n_instr >= max_steps))
        idx = np.nonzero(done)[0]
        if idx.size:
            items = ids[idx]
            r_instr[items] = n_instr[idx]
            r_two[items] = np.asarray(state.n_two_stage, np.int64)[idx]
            r_halt[items] = halted[idx]
            mix_rows = np.asarray(state.mix[jnp.asarray(idx)], np.int64)
            r_mix += mix_rows.sum(0)
            if out_addr is not None:
                r_out[items] = np.asarray(state.mem[:, out_addr])[idx]
            if keep_state:
                jidx = jnp.asarray(idx)
                r_mem[items] = np.asarray(state.mem[jidx])
                r_regs[items] = np.asarray(state.regs[jidx])
                r_pc[items] = np.asarray(state.pc)[idx]
                r_mix_items[items] = mix_rows

            # compact: retire done lanes, refill from the stream
            n_new = min(idx.size, n_items - cursor)
            ids[idx] = -1
            if n_new:
                lanes = idx[:n_new]
                new_mems = np.zeros((chunk, mem_words), np.int32)
                new_mems[lanes] = source(cursor, n_new)
                replace = np.zeros(chunk, bool)
                replace[lanes] = True
                ids[lanes] = np.arange(cursor, cursor + n_new)
                cursor += n_new
                prev_instr[lanes] = 0
                state = _refill(state, jnp.asarray(replace),
                                jnp.asarray(new_mems))

    wall_s = time.perf_counter() - t0
    return FleetResult(
        n_items=n_items, n_instr=r_instr, n_two_stage=r_two, halted=r_halt,
        out=r_out, mix=r_mix, lane_steps=lane_steps, n_segments=n_segments,
        chunk=chunk, seg_steps=seg_steps, wall_s=wall_s,
        mems=r_mem if keep_state else None,
        regs=r_regs if keep_state else None,
        pc=r_pc if keep_state else None,
        mix_items=r_mix_items if keep_state else None,
    )


def run_workload_stream(w: Workload, n_items: int, *, seed: int = 0,
                        chunk: int = 256, seg_steps: int = 4096,
                        max_steps: Optional[int] = None,
                        keep_state: bool = False,
                        mesh: Optional[Mesh] = None) -> FleetResult:
    """Convenience wrapper: stream a FlexiBench workload end to end."""
    return run_stream(
        w.program.code, workload_source(w, seed), n_items=n_items,
        mem_words=w.total_mem_words,
        max_steps=max_steps or w.max_steps, chunk=chunk,
        seg_steps=seg_steps, out_addr=w.out_addr, keep_state=keep_state,
        mesh=mesh)
