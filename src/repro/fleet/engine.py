"""Chunked streaming fleet executor with early-exit segmentation.

The monolithic path (`flexibits.fleet.run_fleet_sharded`) vmaps one
while_loop over the whole fleet: every SIMD lane is occupied until the
*slowest* item halts, and the host materializes all item memories at once.
This engine fixes both (DESIGN.md §9):

- **Chunked streaming.** Items flow through a fixed pool of `chunk` lanes;
  the host only ever holds O(chunk) memory images (the per-item *scalar*
  results — counts, halt flags, output words — are O(fleet), which is what
  makes 10M+ item runs feasible). Lane buffers are donated back to XLA
  between segments, so device memory is a single chunk-sized allocation.

- **Early-exit segmentation.** The interpreter runs in bounded cycle
  segments (default 4096). Between segments, halted lanes are harvested,
  compacted out, and refilled from the stream, so aggregate simulated
  lane-steps track the fleet's *actual* halt distribution instead of the
  worst case. Segmented execution retires the exact instruction sequence
  of `iss.run`, so final memories are bit-exact with the monolithic path.

- **Packed multi-program runtime** (`run_packed`, DESIGN.md §9.8). A
  heterogeneous `FleetPlan` no longer drains group by group: programs
  are padded into a bank, every lane carries its program row + step
  budget, and freed lanes are backfilled with items from ANY pending
  group, so one group's halt-time tail hides behind the others' backlog
  and the whole plan runs as one stream.

- **Resident runtime** (`refill="device"`, the default; DESIGN.md §9.9).
  Retire/refill runs as one donated on-device op against an
  asynchronously staged batch, the per-segment host sync collapses to
  one small stats read overlapped with the next segment's execution,
  and an optional superstep controller (`adaptive=True`) adapts each
  segment's step bound to the observed halt cadence. The PR-4
  host-refill loop survives as `refill="host"` for A/B runs — results
  are bit-exact either way.

- **Shard-local multi-device streaming** (DESIGN.md §9.12). Under a
  mesh, every device shard owns its lanes, its slice of the staged
  refill batch, its admission/prefetch cursors, and its own block of
  `ResidentAcc` rows; retire/refill runs as a per-shard `shard_map`
  body and the per-segment host read is ONE stacked (n_shards, 3+G)
  stats vector — the segment loop contains zero cross-device
  collectives and per-item results are demuxed exactly once at drain.
  The single-device path is literally the 1-shard special case of the
  same code. Resident state (lane pool + accumulators + staging
  cursors) checkpoints mid-flight through `distributed/checkpoint.py`
  (`checkpoint_dir=`/`checkpoint_every=`) and resumes bit-exactly,
  including onto a different mesh shape.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import time
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import checkpoint as dckpt
from repro.distributed import sharding as dsharding
from repro.flexibench.base import Workload
from repro.flexibits import faults as flexifault
from repro.flexibits import iss
from repro.flexibits.cycles import N_COST
from repro.kernels import iss_stepper

STEPPERS = ("branchless", "pallas", "switch")
REFILLS = ("device", "host")   # resident on-device refill (§9.9) vs A/B
REDUNDANCY = ("none", "dmr")   # executed redundancy modes (§9.14; the
                               # carbon planner additionally PRICES tmr)

# resident-runtime safety bounds (see run_packed): past either, the
# engine falls back to the host-refill loop rather than risking int32
# mix-counter overflow or an O(fleet) keep_state device allocation
_RESIDENT_MIX_LIMIT = 2**31 - 1
_RESIDENT_KEEP_STATE_WORDS = 1 << 27   # ~512 MB of int32 device rows

# source protocol: source(start, count) -> (count, mem_words) int32
Source = Callable[[int, int], np.ndarray]


def array_source(mems: np.ndarray) -> Source:
    """Stream an in-memory (n_items, M) array (parity tests, small fleets)."""
    mems = np.asarray(mems, np.int32)

    def src(start: int, count: int) -> np.ndarray:
        return mems[start:start + count]

    return src


def workload_source(w: Workload, seed: int = 0,
                    gen_block: int = 256) -> Source:
    """O(chunk) on-demand input generation for one workload.

    Generation is batched over fixed *aligned* blocks of `gen_block`
    items: item i's inputs are row `i % gen_block` of
    `w.gen_inputs(default_rng([seed, i // gen_block]), gen_block)`. The
    aligned block an item falls in is a pure function of its index, so
    the fleet is identical no matter how the engine's refill boundaries
    slice the stream (chunk/seg_steps are pure performance knobs) —
    while the host hot path pays one Generator construction and one
    vectorized `gen_inputs` call per block instead of per item.
    `gen_block` is part of the stream's identity (a different block size
    is a different — equally valid — fleet), not an engine tuning knob.

    The last generated block is cached: the engine consumes items in
    stream order, so a request straddling a block boundary reuses the
    cached block instead of regenerating it.
    """
    base = w.initial_memory(np.zeros(w.n_inputs, np.int32))
    gen_block = max(1, gen_block)
    cache = {"blk": -1, "xs": None}

    def block(blk: int) -> np.ndarray:
        if cache["blk"] != blk:
            rng = np.random.default_rng([seed, blk])
            cache["xs"] = np.asarray(w.gen_inputs(rng, gen_block), np.int32)
            cache["blk"] = blk
        return cache["xs"]

    def src(start: int, count: int) -> np.ndarray:
        if count <= 0:
            return np.zeros((0, base.size), np.int32)
        parts = []
        i = start
        while i < start + count:
            blk, off = divmod(i, gen_block)
            k = min(gen_block - off, start + count - i)
            parts.append(block(blk)[off:off + k])
            i += k
        xs = parts[0] if len(parts) == 1 else np.concatenate(parts)
        mems = np.tile(base, (count, 1))
        mems[:, :xs.shape[1]] = xs
        return mems

    return src


class _Prefetcher:
    """Double-buffered async host refill (DESIGN.md §9.6).

    Source generation is host work (per-item RNG, memory-image assembly);
    segment execution is device work. A one-worker executor keeps exactly
    one `block`-sized fetch in flight, so generating the next chunk of
    items overlaps the device segment instead of serializing after it.
    The engine consumes items strictly in stream order, so a single
    pending future is a full double buffer. `background=False` degrades
    to synchronous fetches (for sources that aren't thread-safe).
    """

    def __init__(self, source: Source, n_items: int, block: int,
                 background: bool = True):
        self._source = source
        self._n = n_items
        self._block = max(1, block)
        self._cursor = 0          # next un-requested item
        self._taken = 0           # items handed to the engine so far
        self._buf: Optional[np.ndarray] = None
        self._off = 0
        self._fut = None
        self._fut_span = (0, 0)   # [start, start+count) of the fetch
        self._err: Optional[BaseException] = None
        self._closed = False
        self._ex = concurrent.futures.ThreadPoolExecutor(max_workers=1) \
            if background else None
        if self._ex is not None:
            self._submit()

    def _submit(self):
        count = min(self._block, self._n - self._cursor)
        if count > 0:
            start = self._cursor
            self._cursor += count
            self._fut_span = (start, count)
            self._fut = self._ex.submit(self._source, start, count)
        else:
            self._fut = None

    def _fetch_failed(self, exc: BaseException, start: int,
                      count: int) -> RuntimeError:
        """Wrap a source exception with the stream context the bare
        traceback lacks (which source, which item span, where the
        engine's cursor was) and latch it: the background worker's
        error must surface on the *next* take(), never vanish with
        the future, and every later take() must keep failing."""
        self._err = exc
        self._fut = None
        return RuntimeError(
            f"prefetch source {self._source!r} raised while fetching "
            f"items [{start}:{start + count}) of {self._n} (stream "
            f"cursor {self._taken}): {exc!r}")

    def take(self, count: int) -> np.ndarray:
        """Next `count` item memories, in stream order.

        Requests past the declared stream length fail loudly with the
        full cursor state — "exhausted" alone is undebuggable when a
        plan/group/source disagrees with the engine about `n_items`.
        """
        if self._closed:
            raise RuntimeError("prefetcher is closed: take() after "
                               "close() at stream cursor "
                               f"{self._taken}, n_items={self._n}")
        if self._err is not None:
            raise RuntimeError(
                f"prefetch source {self._source!r} already failed "
                f"(stream cursor {self._taken}, n_items={self._n}); "
                f"the stream cannot continue") from self._err
        if self._taken + count > self._n:
            raise RuntimeError(
                f"source stream exhausted: requested {count} item(s) at "
                f"stream cursor {self._taken}, but the source holds only "
                f"{self._n} item(s) "
                f"({self._n - self._taken} item(s) remaining)")
        self._taken += count
        if self._ex is None:
            start = self._cursor
            self._cursor += count
            try:
                return np.asarray(self._source(start, count), np.int32)
            except Exception as e:
                raise self._fetch_failed(e, start, count) from e
        parts = []
        while count > 0:
            if self._buf is None or self._off >= len(self._buf):
                if self._fut is None:
                    raise RuntimeError(
                        f"source stream exhausted: no fetch in flight at "
                        f"stream cursor {self._taken}, request cursor "
                        f"{self._cursor}, n_items={self._n}")
                try:
                    self._buf = np.asarray(self._fut.result(), np.int32)
                except Exception as e:
                    raise self._fetch_failed(e, *self._fut_span) from e
                self._off = 0
                self._submit()          # refill the second buffer now
            k = min(count, len(self._buf) - self._off)
            parts.append(self._buf[self._off:self._off + k])
            self._off += k
            count -= k
        return parts[0] if len(parts) == 1 else np.concatenate(parts)

    def close(self):
        """Cancel/drain the in-flight fetch and join the worker.
        Idempotent — the engine closes on every exit path (including
        unwinding from an exception that may itself have closed).

        `shutdown(wait=False)` would leave a running background fetch
        alive past close — a leaked non-daemon thread still calling the
        source after the engine returned (or raised). Cancel the pending
        future if it has not started; if it is already running, drain it
        (`wait=True`) so the source is never invoked after close().
        """
        if self._closed:
            return
        self._closed = True
        if self._ex is not None:
            self._ex.shutdown(wait=True, cancel_futures=True)
            self._fut = None


@dataclasses.dataclass
class FleetResult:
    """Per-item scalars plus engine-level accounting for one stream run."""
    n_items: int
    n_instr: np.ndarray          # (n,) retired instructions per item
    n_two_stage: np.ndarray      # (n,)
    halted: np.ndarray           # (n,) bool (False = max_steps exhausted)
    out: np.ndarray              # (n,) word at out_addr (0 if no out_addr)
    mix: np.ndarray              # (8,) retired-instruction mix, fleet total
    lane_steps: int              # SIMD lane-step slots the engine executed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    stepper: str = "branchless"
    n_devices: int = 1
    # full final state, only populated with keep_state=True (O(fleet) host
    # memory — for parity tests and the legacy ISSState wrapper)
    mems: Optional[np.ndarray] = None    # (n, M)
    regs: Optional[np.ndarray] = None    # (n, 16)
    pc: Optional[np.ndarray] = None      # (n,)
    mix_items: Optional[np.ndarray] = None  # (n, 8)
    # per-item accumulated timing ticks (§9.10) — populated when the
    # group ran with a cycle-cost row, None for cycles-off runs
    n_cycles: Optional[np.ndarray] = None   # (n,)

    @property
    def busy_steps(self) -> int:
        """Lane-steps that retired a real instruction (useful work)."""
        return int(self.n_instr.sum())

    @property
    def monolithic_lane_steps(self) -> int:
        """Cost of the one-shot vmap(while_loop) on the same fleet: every
        lane runs (masked) until the slowest item halts."""
        if self.n_items == 0:
            return 0
        return int(self.n_items) * int(self.n_instr.max())

    @property
    def items_per_s(self) -> float:
        return self.n_items / self.wall_s if self.wall_s > 0 else float("inf")


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill(state: iss.ISSState, replace, new_mems) -> iss.ISSState:
    """Reset `replace` lanes to a fresh item (mem from new_mems)."""
    rep1 = replace[:, None]
    return iss.ISSState(
        regs=jnp.where(rep1, 0, state.regs),
        pc=jnp.where(replace, 0, state.pc),
        mem=jnp.where(rep1, new_mems, state.mem),
        halted=jnp.where(replace, False, state.halted),
        n_instr=jnp.where(replace, 0, state.n_instr),
        n_two_stage=jnp.where(replace, 0, state.n_two_stage),
        mix=jnp.where(rep1, 0, state.mix),
        n_cycles=jnp.where(replace, 0, state.n_cycles),
    )


def _fresh_chunk(mems: np.ndarray, active: np.ndarray) -> iss.ISSState:
    n, _ = mems.shape
    return iss.ISSState(
        regs=jnp.zeros((n, 16), iss.I32),
        pc=jnp.zeros((n,), iss.I32),
        mem=jnp.asarray(mems, iss.I32),
        halted=jnp.asarray(~active),   # padding lanes never step
        n_instr=jnp.zeros((n,), iss.I32),
        n_two_stage=jnp.zeros((n,), iss.I32),
        mix=jnp.zeros((n, len(iss.MIX_CLASSES)), iss.I32),
        n_cycles=jnp.zeros((n,), iss.I32),
    )


def run_stream(code: np.ndarray, source: Source, *, n_items: int,
               mem_words: int, max_steps: int, chunk: int = 256,
               seg_steps: int = 4096, out_addr: Optional[int] = None,
               keep_state: bool = False,
               mesh: Optional[Mesh] = None,
               stepper: str = "branchless",
               subset: Optional[frozenset] = None,
               prefetch: bool = True, refill: str = "device",
               adaptive: bool = False,
               cost: Optional[np.ndarray] = None,
               faults: Optional[flexifault.FaultSpec] = None,
               redundancy: str = "none",
               max_retries: int = 2) -> FleetResult:
    """Stream `n_items` memory images from `source` through `chunk` lanes.

    Returns per-item scalars in item order. With `keep_state=True` the
    full final state (memories, registers, pc) is also collected — O(fleet)
    host memory, so only use it for parity checks or small fleets.

    `stepper` picks the segment interpreter: "branchless" (lane-parallel
    masked-select stepper, DESIGN.md §9.5), "pallas" (fused-segment
    kernel — the whole segment of a lane tile runs inside one kernel
    invocation with state resident, §9.7), or "switch" (the legacy
    vmapped lax.switch interpreter). `subset` optionally pins the static
    opcode subset for the branchless/pallas steppers; by default it is
    derived from the program text (`iss.opcode_subset`), letting the
    compiler drop opcode classes the workload can never retire. With a
    `mesh`, lanes are sharded over every mesh axis and each device steps
    its shard independently via shard_map (DESIGN.md §9.6). `prefetch`
    overlaps host-side source generation with device segments (double
    buffering).

    Implemented as the single-group special case of the packed
    multi-program runtime (`run_packed`, DESIGN.md §9.8) — one stream
    loop serves both, so the sync/harvest/refill subtleties exist in
    exactly one place — with the run's whole-pool accounting (lane-step
    slots including padding lanes, segment count, measured wall clock)
    folded back into the returned `FleetResult`. `refill`/`adaptive`
    pick the resident runtime and superstep controller exactly as in
    `run_packed` (DESIGN.md §9.9); with the default resident loop the
    per-segment host sync is one small async stats read, with
    `refill="host"` it is the PR-4 blocking done-count scalar.

    `cost` optionally turns on the per-lane timing layer (DESIGN.md
    §9.10): an (N_COST,) int32 cycle-cost row (`cycles.cost_row`) priced
    per retired instruction into each item's `n_cycles` tally.
    Architectural results are bit-identical with and without it.
    """
    results, stats = run_packed(
        [PackedGroup(code=code, source=source, n_items=n_items,
                     max_steps=max_steps, mem_words=mem_words,
                     out_addr=out_addr, cost=cost)],
        chunk=chunk, seg_steps=seg_steps, keep_state=keep_state,
        mesh=mesh, stepper=stepper, subset=subset, prefetch=prefetch,
        refill=refill, adaptive=adaptive, faults=faults,
        redundancy=redundancy, max_retries=max_retries)
    return dataclasses.replace(
        results[0], lane_steps=stats.lane_steps,
        n_segments=stats.n_segments, chunk=stats.chunk,
        wall_s=stats.wall_s)


# ---------------------------------------------------------------------------
# Packed multi-program fleet runtime (DESIGN.md §9.8)
#
# `run_stream` executes ONE program; a heterogeneous FleetPlan run group
# by group pays each group's tail idle (the last segments where only a
# few long-running items hold the whole lane pool), its own retrace, and
# its own host<->device round-trips. The packed runtime multiplexes every
# group through one stream: programs live in a padded program bank, each
# lane carries the bank row it is executing (`iss.PackedState.prog_id`)
# plus its own step budget, and the admission scheduler backfills every
# freed lane with an item from ANY pending group — proportional to the
# groups' remaining backlogs, so all groups drain together and the tail
# of one group is hidden behind the backlog of the others.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PackedGroup:
    """One group's inputs to the packed runtime (engine-level: program +
    item source; fleet/plan.py builds these from a FleetPlan)."""
    code: np.ndarray                  # program words (uint32 or int32)
    source: Source
    n_items: int
    max_steps: int
    mem_words: int
    out_addr: Optional[int] = None
    # optional (N_COST,) int32 cycle-cost row (cycles.cost_row) — turns
    # on per-lane n_cycles accounting for this group's items (§9.10)
    cost: Optional[np.ndarray] = None
    # optional static opcode subset for this program (e.g. FlexiLint's
    # reachable-only subset, DESIGN.md §9.11). The packed bank shares
    # one traced graph, so the run uses the union over groups; None
    # falls back to the text-derived `iss.opcode_subset(code)`.
    subset: Optional[frozenset] = None


@dataclasses.dataclass
class PackedStats:
    """Whole-run accounting of one packed stream (the per-group
    `FleetResult`s carry only the lane-step slots attributable to their
    own active lanes; idle/padding slots belong to the run).

    The sync-stats fields (DESIGN.md §9.9) make the host<->device
    cadence a first-class output: `host_syncs` counts every blocking
    device->host read the run performed, `sync_wait_s` the host time
    spent inside them, `refill_wall_s` the host time spent assembling/
    staging refills, and `device_busy_frac` estimates the fraction of
    the wall clock during which the device had work in flight (1 minus
    the host-only intervals where the device queue was observed empty).
    `seg_schedule` records the seg_steps actually used per segment —
    constant for a fixed run, the controller's trace for an adaptive
    one (pinned deterministic by tests/test_resident.py).

    The shard-local fields (DESIGN.md §9.12) attribute the run to the
    mesh: `n_shards` is the lane-pool shard count (1 single-device),
    and for the resident loop `shard_retired`/`shard_lane_steps` break
    items retired and lane-step slots down per shard, so a scaling
    regression is attributable from the stats alone."""
    n_groups: int
    n_progs: int
    bank_width: int
    lane_steps: int               # chunk x max-step-delta, summed
    n_segments: int
    chunk: int
    seg_steps: int
    wall_s: float
    stepper: str
    n_devices: int
    refill: str = "host"          # "device" (resident, §9.9) or "host"
    adaptive: bool = False
    host_syncs: int = 0           # blocking device->host reads
    sync_wait_s: float = 0.0      # host time blocked in those reads
    refill_wall_s: float = 0.0    # host time assembling/staging refills
    device_busy_frac: float = 1.0
    seg_schedule: tuple = ()      # seg_steps used, one entry per segment
    n_shards: int = 1             # lane-pool shards (§9.12)
    shard_retired: tuple = ()     # items retired per shard (resident)
    shard_lane_steps: tuple = ()  # lane-step slots per shard (resident)
    # resilience counters (§9.14) — populated by fault-injection / DMR
    # runs. `sdc` (silent data corruption) is structurally zero here:
    # only a golden fault-free cross-check can count corruptions the
    # detector missed (that measurement lives in
    # `flexibits.faults.measure_rates`); the field exists so callers
    # that DO hold a golden run can fill in one complete record.
    redundancy: str = "none"
    detected: int = 0             # DMR digest mismatches observed
    corrected: int = 0            # pair rollbacks that re-executed
    quarantined: int = 0          # pairs permanently retired from pool
    sdc: int = 0


class _SyncClock:
    """Counts/times every blocking device->host read plus the host-side
    refill work, and accumulates device-idle intervals for the
    `device_busy_frac` estimate (DESIGN.md §9.9)."""

    def __init__(self):
        self.host_syncs = 0
        self.sync_wait_s = 0.0
        self.refill_wall_s = 0.0
        self.idle_s = 0.0

    def fetch(self, x) -> np.ndarray:
        t0 = time.perf_counter()
        out = np.asarray(x)
        self.sync_wait_s += time.perf_counter() - t0
        self.host_syncs += 1
        return out

    def busy_frac(self, wall_s: float) -> float:
        if wall_s <= 0.0:
            return 1.0
        return max(0.0, 1.0 - self.idle_s / wall_s)


class _SuperstepController:
    """Adaptive superstep sizing (DESIGN.md §9.9).

    Tracks an EMA of the pool's finish hazard (retirements per executed
    pool-step) and picks the next segment length from a small
    power-of-two ladder below the configured `seg_steps`: when churn is
    high, shorter segments return finished lanes to the admission
    scheduler sooner (a lane that halts early in a long segment sits
    frozen — wasted occupancy — until the segment ends); when the pool
    is all long-lived tails the hazard decays and segments grow back to
    the cap, keeping the sync count low. The ladder is bounded (<= 6
    values), so the lru-cached segment runners stay bounded too — one
    compile per ladder rung, ever. Decisions are a pure function of the
    observed (retired, steps) sequence, so a plan+seed reruns to an
    identical segment schedule.
    """

    LADDER_SPAN = 16       # smallest rung = seg_steps / 16
    TARGET_FRAC = 0.25     # aim for ~chunk/4 retirements per segment
    EMA = 0.5

    def __init__(self, seg_steps: int, chunk: int, enabled: bool):
        base = max(1, seg_steps)
        rungs = {base}
        v = base
        while v > max(1, base // self.LADDER_SPAN):
            v = max(1, v // 2)
            rungs.add(v)
        self.ladder = tuple(sorted(rungs))
        self.base = base
        self.enabled = enabled
        self.target = max(1.0, self.TARGET_FRAC * chunk)
        self.rate = 0.0            # EMA of retirements per pool-step
        self.schedule = []

    def record(self, n_retired: int, steps: int):
        if steps > 0:
            self.rate = (self.EMA * (n_retired / steps)
                         + (1.0 - self.EMA) * self.rate)

    def next_seg(self) -> int:
        seg = self.base
        if self.enabled:
            for s in self.ladder:  # smallest rung meeting the target
                if self.rate * s >= self.target:
                    seg = s
                    break
        self.schedule.append(seg)
        return seg


def _apportion(slots: int, remaining) -> np.ndarray:
    """Admission policy: split `slots` free lanes over groups
    proportionally to their remaining backlogs (largest-remainder
    rounding, ties to the lower group index — deterministic).

    Proportional shares keep every pending group flowing and drain all
    groups at roughly the same time, so no group is left to run its tail
    alone at the end of the stream. Per-group results do not depend on
    the policy at all (item i of group g is a pure function of the
    group's source), only wall-clock does.
    """
    remaining = np.asarray(remaining, np.int64)
    total = int(remaining.sum())
    slots = min(int(slots), total)
    take = np.zeros(len(remaining), np.int64)
    if slots <= 0:
        return take
    quota = slots * remaining / total
    take = np.minimum(np.floor(quota).astype(np.int64), remaining)
    left = slots - int(take.sum())
    if left > 0:
        frac = np.where(remaining > take, quota - take, -1.0)
        for g in np.argsort(-frac, kind="stable")[:left]:
            take[g] += 1
    return take


def _fresh_packed(mems: np.ndarray, active: np.ndarray,
                  prog_id: np.ndarray,
                  max_steps: np.ndarray) -> iss.PackedState:
    return iss.PackedState(
        lanes=_fresh_chunk(mems, active),
        prog_id=jnp.asarray(prog_id, iss.I32),
        max_steps=jnp.asarray(max_steps, iss.I32))


@functools.partial(jax.jit, donate_argnums=(0,))
def _refill_packed(state: iss.PackedState, replace, new_mems, new_prog,
                   new_ms) -> iss.PackedState:
    """Reset `replace` lanes to a fresh item of (possibly) another group:
    new memory image, bank row, and step budget."""
    return iss.PackedState(
        lanes=_refill(state.lanes, replace, new_mems),
        prog_id=jnp.where(replace, new_prog, state.prog_id),
        max_steps=jnp.where(replace, new_ms, state.max_steps))


@jax.jit
def _done_count_packed(state: iss.PackedState):
    """Scalar count of done lanes (halted or own step budget exhausted;
    padding lanes carry budget 0 and count as done).

    The engine's per-segment host sync: comparing this single int32
    against the host-known value tells whether any lane finished this
    segment — only then is the O(chunk) harvest pulled."""
    return (state.lanes.halted
            | (state.lanes.n_instr >= state.max_steps)).sum()


def _packed_state_specs(mesh: Mesh, mem_words: int):
    """Shard specs for a packed lane pool, derived from the real state
    constructor (via eval_shape) so the new lane fields (prog_id,
    max_steps) can never drift from what run_packed actually passes."""
    abstract = jax.eval_shape(
        lambda: _fresh_packed(np.zeros((1, mem_words), np.int32),
                              np.ones(1, bool), np.zeros(1, np.int32),
                              np.ones(1, np.int32)))
    return dsharding.lane_specs(mesh, abstract)


@functools.lru_cache(maxsize=None)
def _packed_segment_runner(stepper: str, chunk: int, seg_steps: int,
                           mem_words: int, n_progs: int, bank_width: int,
                           mesh: Optional[Mesh], subset, timing: bool,
                           faults: Optional[flexifault.FaultSpec] = None,
                           donate_state: bool = True):
    """Compiled packed segment runner, cached per engine configuration.

    The bank, per-program code lengths, per-program memory bounds, and
    per-program cycle-cost rows are traced *inputs* (not closure
    constants), so two plans that share shapes and opcode subset reuse
    one compiled callable even with different programs. Per-lane
    `max_steps` lives in the state, so the budget never appears in the
    cache key at all — one compiled runner serves every heterogeneous
    budget mix. `timing` is static: with it off the cost operand is a
    dead argument and the compiled segment is the cycles-off graph.
    `faults` (§9.14) is static too — with it None the runner keeps the
    pre-FlexiFault signature and graph; with a schedule on, the runner
    takes the per-lane `lane_key`/`epoch` arrays as two extra traced
    inputs ahead of the donated state.
    """
    def seg_body(bank, code_len, mem_len, cost, state,
                 lane_key=None, epoch=None):
        cr = cost if timing else None
        if stepper == "switch":
            if faults is None:
                lanes = jax.vmap(
                    lambda p, m, l: iss.run_segment_banked(
                        bank, code_len, p, m, l, seg_steps, mem_len, cr)
                )(state.prog_id, state.max_steps, state.lanes)
            else:
                lanes = jax.vmap(
                    lambda p, m, k, e, l: iss.run_segment_banked(
                        bank, code_len, p, m, l, seg_steps, mem_len, cr,
                        faults=faults, lane_key=k, epoch=e)
                )(state.prog_id, state.max_steps, lane_key, epoch,
                  state.lanes)
            return iss.PackedState(lanes=lanes, prog_id=state.prog_id,
                                   max_steps=state.max_steps)
        if stepper == "pallas":
            return iss_stepper.iss_segment_banked(
                bank, code_len, state, seg_steps=seg_steps, subset=subset,
                mem_len=mem_len, cost=cr, faults=faults,
                lane_key=lane_key, epoch=epoch)
        return iss.run_segment_lanes_banked(bank, code_len, state,
                                            seg_steps, subset, mem_len,
                                            cr, faults=faults,
                                            lane_key=lane_key,
                                            epoch=epoch)

    if faults is None:
        def seg(bank, code_len, mem_len, cost, state):
            return seg_body(bank, code_len, mem_len, cost, state)
        donate = (4,)
        extra_specs = ()
    else:
        def seg(bank, code_len, mem_len, cost, lane_key, epoch, state):
            return seg_body(bank, code_len, mem_len, cost, state,
                            lane_key=lane_key, epoch=epoch)
        donate = (6,)
        extra_specs = None  # filled below (needs the mesh axes)
    if not donate_state:
        # DMR holds the boundary state as its rollback snapshot while
        # the segment runs — the input pool must survive the call
        donate = ()

    if mesh is None:
        return jax.jit(seg, donate_argnums=donate)
    specs = _packed_state_specs(mesh, mem_words)
    bspecs = dsharding.bank_specs(mesh, (0, 0, 0, 0))
    if faults is not None:
        lane = P(tuple(mesh.axis_names))
        extra_specs = (lane, lane)
    fn = shard_map(seg, mesh=mesh, in_specs=(*bspecs, *extra_specs, specs),
                   out_specs=specs, check_rep=False)
    return jax.jit(fn, donate_argnums=donate)


class ResidentAcc(NamedTuple):
    """On-device result accumulators of the resident runtime (§9.9),
    laid out shard-locally (§9.12).

    Per-ITEM leaves hold `n_shards * cap` rows sharded on dim 0: shard
    s owns the block `[s*cap, (s+1)*cap)` and scatters ONLY the items
    it admitted (the host keeps the item->row table, `rowmap`), so the
    retire scatter never crosses a shard boundary. Rows are scattered
    once when the item's lane retires and fetched once at drain —
    per-item scalar results stay O(fleet) exactly as the host
    collectors did, they just live on the device until the stream ends.
    Single-device, `cap == total_items` and the row table is the
    identity — the old layout, unchanged. Per-GROUP mix totals
    accumulate in int32 per shard (summed over shards on the host at
    drain; sound below 2^31 retired instructions per group per mix
    class; past that bound — or past the keep_state device-row budget —
    `run_packed` falls back to the host loop, whose collectors are
    int64 in host RAM). `prev_instr` is the per-lane retired-count
    snapshot at the last refill — the device-side form of the host
    path's `prev_instr` array, from which each segment's max step delta
    is measured. The keep_state leaves are None unless full final state
    was requested.
    """
    n_instr: jax.Array             # (n_shards*cap,) i32
    n_two: jax.Array               # (n_shards*cap,) i32
    n_cycles: jax.Array            # (n_shards*cap,) i32 timing ticks
    halted: jax.Array              # (n_shards*cap,) bool
    out: jax.Array                 # (n_shards*cap,) i32
    mix_g: jax.Array               # (n_shards, n_groups, 8) i32
    prev_instr: jax.Array          # (chunk,) i32
    mems: Optional[jax.Array]      # (n_shards*cap, mem_words) i32
    regs: Optional[jax.Array]      # (n_shards*cap, 16) i32
    pc: Optional[jax.Array]        # (n_shards*cap,) i32
    mix_items: Optional[jax.Array]  # (n_shards*cap, 8) i32


class InjectedFault(RuntimeError):
    """Raised by the resident loop's fault-injection knob
    (`run_packed(..., _crash_after_segments=n)`): the stream dies at
    the top of a loop iteration, so fault-tolerance tests can kill a
    run mid-flight at a segment boundary and resume it from its last
    checkpoint (DESIGN.md §9.12)."""


def shard_partition(counts, n_shards: int):
    """Static item->shard partition of the packed stream (§9.12).

    Returns `spans[g][s]`: a list of `(lo, hi)` half-open item-index
    ranges of group g owned by shard s — a contiguous balanced split
    (shard item counts differ by at most one). Each shard admits,
    stages, and retires ONLY its own items, which is what keeps the
    resident segment loop collective-free. Per-item results are pure
    functions of (group, item index), so ANY partition is bit-exact
    with the single-device stream, and `n_shards=1` degenerates to
    exactly the old global admission order.
    """
    spans = []
    for c in np.asarray(counts, np.int64):
        c = int(c)
        base, rem = divmod(c, n_shards)
        row, lo = [], 0
        for s in range(n_shards):
            k = base + (1 if s < rem else 0)
            row.append([(lo, lo + k)] if k else [])
            lo += k
        spans.append(row)
    return spans


def _span_items(spans) -> np.ndarray:
    """Flat item-index vector of a span list."""
    if not spans:
        return np.zeros(0, np.int64)
    return np.concatenate([np.arange(lo, hi, dtype=np.int64)
                           for lo, hi in spans])


def _items_to_spans(items):
    """Compress a sorted item-index vector back into (lo, hi) spans."""
    items = np.asarray(items, np.int64)
    if items.size == 0:
        return []
    brk = np.nonzero(np.diff(items) != 1)[0]
    starts = np.concatenate([[0], brk + 1])
    ends = np.concatenate([brk, [items.size - 1]])
    return [(int(items[a]), int(items[b]) + 1)
            for a, b in zip(starts, ends)]


def _split_spans(spans, n_shards: int):
    """Contiguous balanced split of a span list over `n_shards` — the
    elastic-resume generalization of `shard_partition` (the pending
    items of a restored stream are re-dealt to the new mesh's shards).
    """
    items = _span_items(spans)
    base, rem = divmod(items.size, n_shards)
    out, lo = [], 0
    for s in range(n_shards):
        k = base + (1 if s < rem else 0)
        out.append(_items_to_spans(items[lo:lo + k]))
        lo += k
    return out


def _span_source(source: Source, spans) -> Source:
    """View of `source` restricted to a span list: linear index i maps
    to the i-th item of the concatenated spans, fetched from the
    underlying source in contiguous runs (so per-shard prefetch keeps
    issuing block-sized reads against block-aligned sources)."""
    lens = np.array([hi - lo for lo, hi in spans], np.int64)
    offs = np.concatenate([np.zeros(1, np.int64), np.cumsum(lens)])

    def src(start: int, count: int) -> np.ndarray:
        parts = []
        i, end = int(start), int(start) + int(count)
        while i < end:
            k = int(np.searchsorted(offs, i, side="right")) - 1
            take = min(end - i, int(offs[k + 1]) - i)
            a = spans[k][0] + (i - int(offs[k]))
            parts.append(np.asarray(source(a, take), np.int32))
            i += take
        if not parts:
            return np.zeros((0, 0), np.int32)
        return parts[0] if len(parts) == 1 else np.concatenate(parts)
    return src


def _abstract_acc(keep_state: bool) -> ResidentAcc:
    """Rank-only ResidentAcc skeleton (leaf sizes are irrelevant:
    `lane_specs` maps each leaf by ndim only)."""
    def z(*shape):
        return jax.ShapeDtypeStruct(shape, np.int32)
    return ResidentAcc(
        n_instr=z(1), n_two=z(1), n_cycles=z(1),
        halted=jax.ShapeDtypeStruct((1,), np.bool_), out=z(1),
        mix_g=z(1, 1, 1), prev_instr=z(1),
        mems=z(1, 1) if keep_state else None,
        regs=z(1, 1) if keep_state else None,
        pc=z(1) if keep_state else None,
        mix_items=z(1, 1) if keep_state else None)


@functools.lru_cache(maxsize=None)
def _resident_refill_runner(mesh: Optional[Mesh], mem_words: int,
                            n_groups: int, keep_state: bool,
                            use_pallas: bool, faults_on: bool = False,
                            dmr: bool = False, max_retries: int = 0):
    """Compiled retire+refill op, shard-local end to end (§9.9/§9.12).

    One donated op replaces the host path's demux->rebuild->device_put
    cycle: finished lanes are detected against their own budgets
    (`iss.retire_mask`), their tallies scattered into the `ResidentAcc`
    rows of the items they carried (dropped-out-of-range scatter — only
    retiring lanes write), and fresh items swapped in from the staged
    batch in lane-rank order (`iss.refill_take` + `iss.refill_lanes`,
    or the banked Pallas swap `iss_stepper.iss_refill` when the fused
    stepper runs single-device). The lane state never leaves the
    device.

    The body is written per-shard: staged leaves arrive with a leading
    shard dim — `(n_shards, spc, ...)` globally, `(1, spc, ...)` inside
    the shard — `n_staged` is a per-shard `(n_shards,)` vector, and
    `item_slot`/`staged_slot` carry shard-LOCAL accumulator rows, so
    the `refill_take` cumsum rank, the retire scatter, and the staged
    swap all stay inside the shard. Under a mesh the body runs through
    `shard_map` and the lowered module contains zero cross-device
    collectives (pinned by tests/test_shard_local.py); single-device it
    is jitted directly — the identical code at n_shards=1.

    Returns the refreshed (state, item_slot, acc) plus an int32
    `(n_shards, 3 + n_groups)` stats block — per shard: [n_retired,
    n_consumed, max step delta, active-lanes-per-group...] — describing
    the segment that just ran; that ONE stacked vector is all the host
    reads per segment, fetched asynchronously while the next segment
    executes.
    """
    def scatter_retired(state, item_slot, acc, out_addr, retired):
        """Scatter finished lanes' tallies at their (shard-local) item
        rows (shared by all three loop variants)."""
        lanes = state.lanes
        cap = acc.n_instr.shape[0]
        slot = jnp.where(retired, item_slot, cap)   # OOB rows drop

        def put(buf, val):
            return None if buf is None \
                else buf.at[slot].set(val, mode="drop")

        col = out_addr[state.prog_id]
        out_val = jnp.take_along_axis(
            lanes.mem, jnp.clip(col, 0, lanes.mem.shape[1] - 1)[:, None],
            axis=1)[:, 0]
        out_val = jnp.where(col >= 0, out_val, 0)
        return acc._replace(
            n_instr=put(acc.n_instr, lanes.n_instr),
            n_two=put(acc.n_two, lanes.n_two_stage),
            n_cycles=put(acc.n_cycles, lanes.n_cycles),
            halted=put(acc.halted, lanes.halted),
            out=put(acc.out, out_val),
            mix_g=acc.mix_g[0].at[state.prog_id].add(
                jnp.where(retired[:, None], lanes.mix, 0))[None],
            mems=put(acc.mems, lanes.mem),
            regs=put(acc.regs, lanes.regs),
            pc=put(acc.pc, lanes.pc),
            mix_items=put(acc.mix_items, lanes.mix))

    def refill(state, item_slot, acc, staged_mems, staged_prog,
               staged_ms, staged_slot, n_staged, out_addr):
        lanes = state.lanes
        active = item_slot >= 0
        retired = iss.retire_mask(state, item_slot)

        # ---- accounting of the segment that just ran (host-free)
        delta = jnp.max(lanes.n_instr - acc.prev_instr, initial=0)
        act_g = jnp.zeros((n_groups,), iss.I32).at[state.prog_id].add(
            active.astype(iss.I32))

        acc = scatter_retired(state, item_slot, acc, out_addr, retired)

        # ---- refill freed lanes from this shard's staged batch, in
        # lane-rank order
        free = retired | ~active
        take, src = iss.refill_take(free, n_staged[0])
        swap = iss_stepper.iss_refill if use_pallas else iss.refill_lanes
        new_state = swap(state, take, src, staged_mems[0], staged_prog[0],
                         staged_ms[0])
        new_slot = jnp.where(take, staged_slot[0][src],
                             jnp.where(retired, -1, item_slot))
        acc = acc._replace(prev_instr=jnp.where(take, 0, lanes.n_instr))
        stats = jnp.concatenate([
            jnp.stack([retired.sum().astype(iss.I32),
                       take.sum().astype(iss.I32),
                       delta.astype(iss.I32)]), act_g])[None]
        return new_state, new_slot, acc, stats

    def refill_faults(state, item_slot, epoch, acc, staged_mems,
                      staged_prog, staged_ms, staged_slot, n_staged,
                      out_addr):
        """The base loop plus the per-lane fault `epoch` (§9.14): a
        lane taking a fresh item bumps its epoch so the new item draws
        a fresh schedule instead of replaying the last item's (draws
        key on (lane, epoch, n_instr) and n_instr restarts at 0)."""
        new_state, new_slot, acc, stats = refill(
            state, item_slot, acc, staged_mems, staged_prog, staged_ms,
            staged_slot, n_staged, out_addr)
        took = (new_slot != item_slot) & (new_slot >= 0)
        new_epoch = jnp.where(took, epoch + jnp.asarray(1, iss.I32),
                              epoch)
        return new_state, new_slot, new_epoch, acc, stats

    def refill_dmr(state, item_slot, epoch, retries, quar, snap, acc,
                   staged_mems, staged_prog, staged_ms, staged_slot,
                   n_staged, out_addr):
        """DMR shadow-lane retire/refill (§9.14).

        Lanes pair up as (2p primary, 2p+1 shadow); both run the SAME
        item image but draw independent fault schedules (different
        physical lane keys). At every refill boundary the pair's
        architectural digests are compared: a mismatch means at least
        one lane was hit since the last boundary, so the pair rolls
        back to `snap` (its state at the previous boundary — the exact
        segment re-executes) with a bumped epoch (fresh draws; a
        transient won't recur, a stuck-at/dead defect will). A pair
        that mismatches `max_retries` times in a row is quarantined —
        parked forever, its item handed back to the host for
        re-admission on healthy lanes — at most one pair per shard per
        boundary, so the host's re-admission bookkeeping is one scalar
        per shard. Pairs whose digests agree retire/refill exactly as
        the base loop, at pair granularity (the shadow carries item
        row -1 and never scatters). The next boundary's snapshot is the
        op's OUTPUT state (for rolled-back pairs that IS the old snap)
        — the host keeps that reference while the segment executes,
        which is why the DMR segment runner does not donate its state.
        """
        lanes = state.lanes
        one = jnp.asarray(1, iss.I32)
        active = item_slot >= 0        # primaries only (shadows: -1)

        # ---- pair views: chunk % (2 * n_shards) == 0 (validated in
        # run_packed), so a pair never straddles a shard boundary
        d = flexifault.arch_digest(lanes.regs, lanes.pc, lanes.mem,
                                   lanes.halted, lanes.n_instr)
        d2 = d.reshape(-1, 2)
        pair_active = active.reshape(-1, 2)[:, 0]
        mismatch = pair_active & (d2[:, 0] != d2[:, 1])
        done_l = lanes.halted | (lanes.n_instr >= state.max_steps)
        pair_retire = (pair_active & done_l.reshape(-1, 2)[:, 0]
                       & ~mismatch)

        wants_q = mismatch & (retries >= max_retries)
        new_q = wants_q & (jnp.cumsum(wants_q.astype(iss.I32)) == 1)
        rollback = mismatch & ~new_q
        q_slot = jnp.max(jnp.where(
            new_q, item_slot.reshape(-1, 2)[:, 0], -1))

        # ---- accounting of the segment that just ran
        delta = jnp.max(lanes.n_instr - acc.prev_instr, initial=0)
        act_g = jnp.zeros((n_groups,), iss.I32).at[state.prog_id].add(
            active.astype(iss.I32))

        # ---- retire matching finished pairs (primary rows scatter)
        retired = iss.retire_mask(state, item_slot) \
            & jnp.repeat(pair_retire, 2)
        acc = scatter_retired(state, item_slot, acc, out_addr, retired)

        # ---- roll mismatching pairs back to the last good boundary,
        # park the quarantined pair
        rb_l = jnp.repeat(rollback, 2)
        q_l = jnp.repeat(new_q, 2)

        def rb(a, b):
            m = rb_l.reshape(rb_l.shape + (1,) * (b.ndim - 1))
            return jnp.where(m, a, b)

        lanes2 = jax.tree.map(rb, snap, lanes)
        lanes2 = lanes2._replace(
            halted=jnp.where(q_l, True, lanes2.halted))
        state = iss.PackedState(lanes=lanes2, prog_id=state.prog_id,
                                max_steps=state.max_steps)

        # ---- refill freed pairs; both lanes get the item image, only
        # the primary carries the accumulator row
        free_p = (pair_retire | ~pair_active) & ~(quar | new_q)
        take_p, src_p = iss.refill_take(free_p, n_staged[0])
        take_l = jnp.repeat(take_p, 2)
        src_l = jnp.repeat(src_p, 2)
        new_state = iss.refill_lanes(state, take_l, src_l,
                                     staged_mems[0], staged_prog[0],
                                     staged_ms[0])
        is_primary = (jnp.arange(item_slot.shape[0]) % 2) == 0
        new_slot = jnp.where(
            take_l & is_primary, staged_slot[0][src_l],
            jnp.where(retired | q_l, -1, item_slot))
        new_epoch = jnp.where(take_l | rb_l, epoch + one, epoch)
        # consecutive-mismatch counter: any clean boundary resets it
        # (a long-lived item accrues many independent transients over
        # its lifetime; only an unrecoverable streak should quarantine)
        new_retries = jnp.where(rollback, retries + one,
                                jnp.where(new_q, retries,
                                          jnp.zeros_like(retries)))
        acc = acc._replace(prev_instr=jnp.where(
            take_l, 0, new_state.lanes.n_instr))
        stats = jnp.concatenate([
            jnp.stack([pair_retire.sum().astype(iss.I32),
                       take_p.sum().astype(iss.I32),
                       delta.astype(iss.I32),
                       mismatch.sum().astype(iss.I32),
                       rollback.sum().astype(iss.I32),
                       q_slot.astype(iss.I32)]), act_g])[None]
        return (new_state, new_slot, new_epoch, new_retries,
                quar | new_q, acc, stats)

    if dmr:
        # snap (arg 5) is NOT donated: the new-state output already
        # reuses the state input's buffers, so snap's would go unused
        # (it is freed by refcount when the host drops the reference)
        fn, donate = refill_dmr, (0, 1, 2, 3, 4, 6)
    elif faults_on:
        fn, donate = refill_faults, (0, 1, 2, 3)
    else:
        fn, donate = refill, (0, 1, 2)
    if mesh is None:
        return jax.jit(fn, donate_argnums=donate)
    axes = tuple(mesh.axis_names)
    lane = P(axes)
    state_specs = _packed_state_specs(mesh, mem_words)
    acc_specs = dsharding.lane_specs(mesh, _abstract_acc(keep_state))
    st_specs = (P(axes, None, None), P(axes, None), P(axes, None),
                P(axes, None))
    if dmr:
        snap_specs = state_specs.lanes
        carry_in = (state_specs, lane, lane, lane, lane, snap_specs,
                    acc_specs)
        carry_out = (state_specs, lane, lane, lane, lane, acc_specs)
    elif faults_on:
        carry_in = (state_specs, lane, lane, acc_specs)
        carry_out = (state_specs, lane, lane, acc_specs)
    else:
        carry_in = (state_specs, lane, acc_specs)
        carry_out = (state_specs, lane, acc_specs)
    fn = shard_map(
        fn, mesh=mesh,
        in_specs=(*carry_in, *st_specs, lane, P()),
        out_specs=(*carry_out, P(axes, None)),
        check_rep=False)
    return jax.jit(fn, donate_argnums=donate)


def run_packed(groups, *, chunk: int = 256, seg_steps: int = 4096,
               keep_state: bool = False, mesh: Optional[Mesh] = None,
               stepper: str = "branchless",
               subset: Optional[frozenset] = None,
               prefetch: bool = True, refill: str = "device",
               adaptive: bool = False,
               checkpoint_dir: Optional[str] = None,
               checkpoint_every: int = 0,
               faults: Optional[flexifault.FaultSpec] = None,
               redundancy: str = "none", max_retries: int = 2,
               _crash_after_segments: Optional[int] = None):
    """Execute every `PackedGroup` through ONE packed stream.

    Returns `(results, stats)`: `results[g]` is a per-group `FleetResult`
    bit-exact with what `run_stream` would produce for group g alone —
    identical per-item instruction/timing/mix tallies and final state
    (`tests/test_packed.py` pins this three ways) — and `stats` is the
    whole-run `PackedStats`.

    The program bank holds one padded row per group; every stepper
    fetches through the per-program clamp (`iss.fetch_banked`), bounds
    each lane's data-memory ports at its group's own `mem_words` (so
    clamp-on-read / drop-on-write happen at the program's boundary even
    though the pool memory is padded to the largest group's), and the
    branchless/pallas steppers compile ONE graph specialized to the
    *union* opcode subset of the bank (a superset of every row's subset,
    so per-group bit-exactness is preserved). Lane admission backfills
    freed lanes from any pending group (`_apportion`); per-group sources
    prefetch concurrently, each double-buffered as in `run_stream`.

    Per-group accounting: `lane_steps`/`n_segments` count only segments
    slots where the group had active lanes; `wall_s` splits the measured
    whole-run wall clock proportionally to retired instructions (the
    sums over groups match the run, up to idle-lane slots, which belong
    to `stats`).

    `refill` picks the stream loop (DESIGN.md §9.9): "device" (the
    default) is the *resident* runtime — retire/refill happens in one
    donated on-device op against a staged batch that was uploaded
    asynchronously while the previous segment ran, and the only
    per-segment host read is one small stats vector fetched while the
    NEXT segment executes — while "host" keeps the PR-4 loop (blocking
    done-count read, host demux/rebuild, device_put) as the A/B
    baseline. Per-group results are bit-exact either way
    (tests/test_resident.py pins full-state parity). `adaptive` turns
    on the superstep controller (§9.9): each segment's step bound is
    picked from a bounded power-of-two ladder under `seg_steps` by the
    observed halt cadence — deterministic for a given plan, bit-exact
    with any fixed schedule.

    `checkpoint_dir` makes the resident stream durable (§9.12): every
    `checkpoint_every` segments the loop writes an atomic, canonical
    (mesh-independent) snapshot of the resident state — lane pool,
    accumulated/done results, pending item spans, controller state —
    through `distributed/checkpoint.py`; when `checkpoint_dir` already
    holds a checkpoint the run auto-resumes from it, bit-exact with an
    uninterrupted run, even onto a different mesh shape (the elastic
    path re-deals surviving lanes and pending spans to the new shards).
    `_crash_after_segments` is the fault-injection knob used by
    tests/test_fault_tolerance.py: raise `InjectedFault` once that many
    segments have retired.

    `faults` (a `flexibits.faults.FaultSpec`, DESIGN.md §9.14) turns on
    deterministic fault injection: every lane applies the post-commit
    fault transform under its own `fold_in`-derived key, bit-identically
    across all three steppers. `redundancy="dmr"` pairs lanes as
    primary+shadow running the same item under independent schedules,
    compares architectural digests at every segment boundary, rolls
    mismatching pairs back to the boundary's snapshot (re-executing the
    segment under fresh draws), and after `max_retries` consecutive
    mismatches quarantines the pair — parking the defective lanes and
    re-admitting the item on healthy ones. Both require the resident
    loop (`refill="device"`) and are incompatible with `checkpoint_dir`
    (the rollback snapshots are not part of the durable snapshot
    schema); `faults=None` with `redundancy="none"` is bit-exact with
    the pre-FlexiFault engine (pinned by tests/test_faults.py).
    """
    groups = list(groups)
    if not groups:
        raise ValueError("run_packed needs at least one group")
    if seg_steps < 1:
        raise ValueError("seg_steps must be >= 1")
    if chunk < 1:
        raise ValueError("chunk must be >= 1")
    if stepper not in STEPPERS:
        raise ValueError(f"stepper must be one of {STEPPERS}")
    if refill not in REFILLS:
        raise ValueError(f"refill must be one of {REFILLS}")
    if redundancy not in REDUNDANCY:
        raise ValueError(f"redundancy must be one of {REDUNDANCY} "
                         f"(tmr is priced by the carbon planner but "
                         f"not executed), got {redundancy!r}")
    if faults is not None and faults.off:
        faults = None              # rate 0 IS the fault-free graph
    resilient = faults is not None or redundancy == "dmr"
    if resilient:
        if refill != "device":
            raise ValueError(
                "fault injection / DMR needs the resident loop: the "
                "fault epoch and rollback snapshots live on device "
                "(pass refill='device')")
        if checkpoint_dir is not None:
            raise ValueError(
                "fault injection / DMR is incompatible with "
                "checkpoint_dir: epoch/retry/snapshot state is not "
                "part of the durable checkpoint schema")

    n_groups = len(groups)
    counts = np.array([g.n_items for g in groups], np.int64)
    total_items = int(counts.sum())
    if refill == "device" and groups:
        # resident-safety fallback: the on-device per-group mix
        # counters are int32 (a group's per-class retired count is
        # bounded by n_items x max_steps), and keep_state scatters full
        # final state into O(fleet) device rows — past either bound the
        # host loop (int64 collectors, host-RAM state) is the correct
        # runtime, so fall back rather than overflow/OOM silently; the
        # returned PackedStats.refill reports what actually ran.
        mix_bound = max(int(g.n_items) * int(g.max_steps)
                        for g in groups)
        ks_words = 0
        if keep_state:
            ks_words = total_items * (
                max(g.mem_words for g in groups) + 16 + 1
                + len(iss.MIX_CLASSES))
        if mix_bound > _RESIDENT_MIX_LIMIT \
                or ks_words > _RESIDENT_KEEP_STATE_WORDS:
            if resilient:
                raise ValueError(
                    "plan exceeds the resident-runtime safety bounds "
                    "(int32 mix counters / keep_state device rows) and "
                    "fault injection / DMR cannot fall back to the "
                    "host-refill loop — shrink the plan or drop the "
                    "fault/redundancy knobs")
            refill = "host"
    if checkpoint_dir is not None and refill != "device":
        raise ValueError(
            "checkpoint_dir requires the resident loop: refill='device' "
            "within the resident safety bounds (the host-refill loop "
            "keeps no durable on-device state)")
    if total_items == 0:
        empty = [FleetResult(
            n_items=0, n_instr=np.zeros(0, np.int64),
            n_two_stage=np.zeros(0, np.int64), halted=np.zeros(0, bool),
            out=np.zeros(0, np.int32),
            mix=np.zeros(len(iss.MIX_CLASSES), np.int64), lane_steps=0,
            n_segments=0, chunk=0, seg_steps=seg_steps, wall_s=0.0,
            stepper=stepper,
            n_cycles=None if g.cost is None else np.zeros(0, np.int64))
            for g in groups]
        return empty, PackedStats(
            n_groups=n_groups, n_progs=n_groups, bank_width=0,
            lane_steps=0, n_segments=0, chunk=0, seg_steps=seg_steps,
            wall_s=0.0, stepper=stepper, n_devices=1, refill=refill,
            adaptive=adaptive)
    mem_words = max(g.mem_words for g in groups)
    bank_np, code_len_np = iss.pack_programs([g.code for g in groups])
    if subset is None:
        subset = frozenset().union(
            *(g.subset if g.subset is not None
              else iss.opcode_subset(g.code) for g in groups))
    bank = jnp.asarray(bank_np)
    code_len = jnp.asarray(code_len_np)
    # per-program memory bounds: lanes of a small-memory group keep
    # clamp-on-read / drop-on-write at their OWN word count even though
    # the pool memory is padded to the largest group's
    mem_len = jnp.asarray([g.mem_words for g in groups], iss.I32)
    ms_of = np.array([g.max_steps for g in groups], np.int64)
    # per-program cycle-cost rows (§9.10): the timing layer is ON iff
    # any group carries a cost row. Cost-less groups in a mixed plan get
    # a zero row — their lanes share the timing-on graph but tally 0.
    timing = any(g.cost is not None for g in groups)
    cost_np = np.zeros((n_groups, N_COST), np.int32)
    for i, g in enumerate(groups):
        if g.cost is not None:
            cost_np[i] = np.asarray(g.cost, np.int32)
    cost = jnp.asarray(cost_np)

    dmr = redundancy == "dmr"
    # a DMR pair occupies two lanes per item, and a pair must never
    # straddle a shard: the pool rounds to 2 x n_dev
    chunk = min(chunk, max(total_items * (2 if dmr else 1), 1))
    n_dev = 1
    if mesh is not None:
        n_dev = int(np.prod(list(mesh.shape.values())))
    round_to = 2 * n_dev if dmr else n_dev
    if stepper == "pallas" and chunk > 128:
        # same wide-lane-tile rule as run_stream: pad the pool to a
        # 128-multiple (lcm'd with the mesh/pair alignment) instead of
        # tiling at a prime-ish chunk's largest small divisor
        round_to = int(np.lcm(128, round_to))
    if round_to > 1:
        chunk = -(-chunk // round_to) * round_to

    clock = _SyncClock()
    controller = _SuperstepController(seg_steps, chunk, adaptive)
    t0 = time.perf_counter()
    if refill == "device":
        # the resident loop owns per-(group, shard) prefetchers — the
        # item->shard partition decides what each one reads (§9.12)
        out = _stream_resident(
            groups, prefetch, counts, ms_of, bank, code_len, mem_len,
            cost, timing, bank_np, chunk, keep_state, mesh, stepper,
            subset, mem_words, controller, clock,
            checkpoint_dir=checkpoint_dir,
            checkpoint_every=checkpoint_every,
            faults=faults, redundancy=redundancy,
            max_retries=max_retries,
            crash_after=_crash_after_segments)
    else:
        prefs = [_Prefetcher(g.source, g.n_items,
                             block=max(1, min(chunk, g.n_items)),
                             background=prefetch)
                 for g in groups]
        try:
            out = _stream_host(groups, prefs, counts, ms_of, bank,
                               code_len, mem_len, cost, timing, bank_np,
                               chunk, keep_state, mesh, stepper, subset,
                               mem_words, controller, clock)
        finally:
            for p in prefs:
                p.close()

    wall_s = time.perf_counter() - t0
    busy = np.array([r.sum() for r in out["r_instr"]], np.float64)
    busy_share = busy / max(busy.sum(), 1.0)
    results = []
    for g, grp in enumerate(groups):
        results.append(FleetResult(
            n_items=grp.n_items, n_instr=out["r_instr"][g],
            n_two_stage=out["r_two"][g],
            halted=out["r_halt"][g], out=out["r_out"][g],
            mix=out["r_mix"][g],
            lane_steps=int(out["g_lane_steps"][g]),
            n_segments=int(out["g_segments"][g]),
            chunk=chunk, seg_steps=seg_steps,
            wall_s=wall_s * float(busy_share[g]),
            stepper=stepper, n_devices=n_dev,
            mems=out["r_mem"][g] if keep_state else None,
            regs=out["r_regs"][g] if keep_state else None,
            pc=out["r_pc"][g] if keep_state else None,
            mix_items=out["r_mix_items"][g] if keep_state else None,
            n_cycles=out["r_cycles"][g] if grp.cost is not None else None,
        ))
    stats = PackedStats(
        n_groups=n_groups, n_progs=bank_np.shape[0],
        bank_width=bank_np.shape[1], lane_steps=out["lane_steps"],
        n_segments=out["n_segments"], chunk=chunk, seg_steps=seg_steps,
        wall_s=wall_s, stepper=stepper, n_devices=n_dev, refill=refill,
        adaptive=adaptive, host_syncs=clock.host_syncs,
        sync_wait_s=clock.sync_wait_s, refill_wall_s=clock.refill_wall_s,
        device_busy_frac=clock.busy_frac(wall_s),
        seg_schedule=tuple(controller.schedule[:out["n_segments"]]),
        n_shards=int(out.get("n_shards", n_dev)),
        shard_retired=tuple(int(x)
                            for x in out.get("shard_retired", ())),
        shard_lane_steps=tuple(int(x)
                               for x in out.get("shard_lane_steps", ())),
        redundancy=redundancy,
        detected=int(out.get("detected", 0)),
        corrected=int(out.get("corrected", 0)),
        quarantined=int(out.get("quarantined", 0)))
    return results, stats


def _stream_host(groups, prefs, counts, ms_of, bank, code_len, mem_len,
                 cost, timing, bank_np, chunk, keep_state, mesh, stepper,
                 subset, mem_words, controller: _SuperstepController,
                 clock: _SyncClock):
    """The PR-4 host-refill stream loop (the `refill="host"` A/B path):
    blocking single-scalar done-count sync per segment, host-side
    demux + refill rebuild + device_put on finishing segments."""
    n_groups = len(groups)
    r_instr = [np.zeros(n, np.int64) for n in counts]
    r_two = [np.zeros(n, np.int64) for n in counts]
    r_cycles = [np.zeros(n, np.int64) for n in counts]
    r_halt = [np.zeros(n, bool) for n in counts]
    r_out = [np.zeros(n, np.int32) for n in counts]
    r_mix = [np.zeros(len(iss.MIX_CLASSES), np.int64) for _ in groups]
    g_lane_steps = np.zeros(n_groups, np.int64)
    g_segments = np.zeros(n_groups, np.int64)
    r_mem = r_regs = r_pc = r_mix_items = None
    if keep_state:
        r_mem = [np.zeros((n, g.mem_words), np.int32)
                 for n, g in zip(counts, groups)]
        r_regs = [np.zeros((n, 16), np.int32) for n in counts]
        r_pc = [np.zeros(n, np.int32) for n in counts]
        r_mix_items = [np.zeros((n, len(iss.MIX_CLASSES)), np.int32)
                       for n in counts]

    cursor = np.zeros(n_groups, np.int64)   # next item per group
    ids = np.full(chunk, -1, np.int64)      # item index within group
    lane_group = np.full(chunk, -1, np.int64)
    lane_ms = np.zeros(chunk, np.int64)     # host copy of budgets

    def admit(state, free_lanes):
        """Backfill `free_lanes` with items from any pending group."""
        take = _apportion(len(free_lanes), counts - cursor)
        n_new = int(take.sum())
        if n_new == 0:
            return state, 0
        new_mems = np.zeros((chunk, mem_words), np.int32)
        new_prog = np.zeros(chunk, np.int32)
        new_ms = np.zeros(chunk, np.int32)
        replace = np.zeros(chunk, bool)
        off = 0
        for g in np.nonzero(take)[0]:
            k = int(take[g])
            lanes = free_lanes[off:off + k]
            off += k
            new_mems[lanes, :groups[g].mem_words] = prefs[g].take(k)
            new_prog[lanes] = g
            new_ms[lanes] = ms_of[g]
            replace[lanes] = True
            ids[lanes] = np.arange(cursor[g], cursor[g] + k)
            lane_group[lanes] = g
            lane_ms[lanes] = ms_of[g]
            cursor[g] += k
        if state is None:
            return (new_mems, replace, new_prog, new_ms), n_new
        return _refill_packed(state, jnp.asarray(replace),
                              jnp.asarray(new_mems),
                              jnp.asarray(new_prog),
                              jnp.asarray(new_ms)), n_new

    # initial fill (admit into a fresh pool; padding lanes carry
    # budget 0 and stay parked forever)
    (first, active0, prog0, ms0), _ = admit(None, np.arange(chunk))
    state = _fresh_packed(first, active0, prog0, ms0)
    if mesh is not None:
        state = jax.tree.map(jax.device_put, state,
                             dsharding.lane_shardings(mesh, state))

    prev_instr = np.zeros(chunk, np.int64)
    lane_steps = 0
    n_segments = 0
    expected_done = chunk - int((ids >= 0).sum())

    while (ids >= 0).any():
        seg_steps = controller.next_seg()
        seg_fn = _packed_segment_runner(stepper, chunk, seg_steps,
                                        mem_words, n_groups,
                                        bank_np.shape[1], mesh, subset,
                                        timing)
        state = seg_fn(bank, code_len, mem_len, cost, state)
        n_segments += 1
        active = ids >= 0
        act_per_group = np.bincount(lane_group[active],
                                    minlength=n_groups)
        g_segments += act_per_group > 0

        # single-scalar sync, as in run_stream: if no lane finished,
        # every active lane ran exactly seg_steps
        if int(clock.fetch(_done_count_packed(state))) == expected_done:
            lane_steps += chunk * seg_steps
            g_lane_steps += act_per_group * seg_steps
            prev_instr[active] += seg_steps
            controller.record(0, seg_steps)
            continue

        t_harvest = time.perf_counter()
        wait_before = clock.sync_wait_s
        halted = clock.fetch(state.lanes.halted)
        n_instr = clock.fetch(state.lanes.n_instr).astype(np.int64)
        delta = int((n_instr - prev_instr).max(initial=0))
        lane_steps += chunk * delta
        g_lane_steps += act_per_group * delta
        prev_instr = n_instr

        done = active & (halted | (n_instr >= lane_ms))
        idx = np.nonzero(done)[0]
        if idx.size:
            jidx = jnp.asarray(idx)
            two = clock.fetch(state.lanes.n_two_stage).astype(np.int64)
            mix_rows = clock.fetch(state.lanes.mix[jidx]).astype(np.int64)
            if timing:   # one extra pull, only when the layer is on
                cyc = clock.fetch(state.lanes.n_cycles).astype(np.int64)
            # one O(done x mem_words) row gather serves every
            # group's out-word read (and the keep_state memories) —
            # not a full O(chunk) column pull per group
            need_mem = keep_state or any(
                g.out_addr is not None for g in groups)
            if need_mem:
                mem_rows = clock.fetch(state.lanes.mem[jidx])
            if keep_state:
                regs_rows = clock.fetch(state.lanes.regs[jidx])
                pc_rows = clock.fetch(state.lanes.pc)[idx]
            for g in np.unique(lane_group[idx]):
                sel = lane_group[idx] == g
                lg = idx[sel]
                items = ids[lg]
                r_instr[g][items] = n_instr[lg]
                r_two[g][items] = two[lg]
                if timing:
                    r_cycles[g][items] = cyc[lg]
                r_halt[g][items] = halted[lg]
                r_mix[g] += mix_rows[sel].sum(0)
                if groups[g].out_addr is not None:
                    r_out[g][items] = \
                        mem_rows[sel][:, groups[g].out_addr]
                if keep_state:
                    r_mem[g][items] = \
                        mem_rows[sel][:, :groups[g].mem_words]
                    r_regs[g][items] = regs_rows[sel]
                    r_pc[g][items] = pc_rows[sel]
                    r_mix_items[g][items] = mix_rows[sel]

            # retire done lanes, then backfill from any pending group
            ids[idx] = -1
            lane_group[idx] = -1
            lane_ms[idx] = 0
            state, _ = admit(state, idx)
            # refilled lanes restart at n_instr=0; retired-but-empty
            # lanes keep their frozen device counters
            prev_instr[idx] = np.where(ids[idx] >= 0, 0,
                                       prev_instr[idx])
        controller.record(int(idx.size), seg_steps)
        # the whole harvest+rebuild runs with the segment finished and
        # nothing dispatched: device-idle host work, minus the transfer
        # time already booked as sync wait
        dt = time.perf_counter() - t_harvest
        clock.refill_wall_s += dt
        clock.idle_s += max(0.0, dt - (clock.sync_wait_s - wait_before))
        expected_done = chunk - int((ids >= 0).sum())

    return {"r_instr": r_instr, "r_two": r_two, "r_halt": r_halt,
            "r_out": r_out, "r_mix": r_mix, "r_mem": r_mem,
            "r_regs": r_regs, "r_pc": r_pc, "r_mix_items": r_mix_items,
            "r_cycles": r_cycles,
            "g_lane_steps": g_lane_steps, "g_segments": g_segments,
            "lane_steps": lane_steps, "n_segments": n_segments}


_CKPT_VALS = ("n_instr", "n_two", "n_cycles", "halted", "out")
_CKPT_KEEP = ("mems", "regs", "pc", "mix_items")
_CKPT_LANES = ("regs", "pc", "mem", "halted", "n_instr", "n_two",
               "mix", "n_cycles", "prog", "ms")


def _resident_ckpt_skeleton(n_groups: int, keep_state: bool) -> dict:
    """Flat-dict skeleton of a resident checkpoint — `restore` only
    needs the key set; shapes come from the stored arrays."""
    keys = ["counts", "done_mask", "mix_g", "pending", "counters",
            "ctrl", "sched", "g_lane_steps", "g_segments",
            "lane_item", "lane_prev"]
    keys += ["val_" + k for k in _CKPT_VALS]
    if keep_state:
        keys += ["val_" + k for k in _CKPT_KEEP]
    keys += ["lane_" + k for k in _CKPT_LANES]
    return {k: np.zeros(0, np.int64) for k in keys}


def _stream_resident(groups, prefetch, counts, ms_of, bank, code_len,
                     mem_len, cost, timing, bank_np, chunk, keep_state,
                     mesh, stepper, subset, mem_words,
                     controller: _SuperstepController,
                     clock: _SyncClock, checkpoint_dir=None,
                     checkpoint_every: int = 0, faults=None,
                     redundancy: str = "none", max_retries: int = 2,
                     crash_after=None):
    """The resident stream loop (DESIGN.md §9.9, shard-local §9.12,
    `refill="device"`).

    Pipeline per iteration, in device-queue order:

        refill_i  — donated on-device op: retire finished lanes into
                    the `ResidentAcc` rows, swap in staged items —
                    per-shard under a mesh, zero collectives
        seg_i     — the segment, at the controller's step bound
        (host)    — async-fetch refill_i's stacked per-shard stats
                    block, which blocks only until refill_i is done —
                    seg_i is already executing behind it; then restock
                    each shard's staged slice for refill_{i+1}
                    (per-shard prefetcher take + async device_put), all
                    overlapped with seg_i

    The host therefore performs exactly ONE small read per segment
    regardless of the device count, and the device queue never drains
    while the stream has backlog. The loop exits after the refill that
    retires the last item; the final trailing segment dispatch sees an
    all-parked pool and its while_loop exits without stepping. Per-item
    results and final state are fetched ONCE, at drain, and merged
    through the host-side item->row table.
    """
    n_groups = len(groups)
    total = int(counts.sum())
    n_mix = len(iss.MIX_CLASSES)
    slot_base = np.zeros(n_groups, np.int64)
    np.cumsum(counts[:-1], out=slot_base[1:])
    out_addr_np = np.asarray(
        [-1 if g.out_addr is None else g.out_addr for g in groups],
        np.int32)
    dmr = redundancy == "dmr"
    # the banked Pallas swap is the single-device fused-stepper path;
    # under a mesh the (bit-identical) jnp swap partitions per shard
    # (and the DMR op always uses the jnp swap — pair semantics)
    use_pallas = stepper == "pallas" and mesh is None and not dmr
    n_shards = 1
    if mesh is not None:
        n_shards = int(np.prod(list(mesh.shape.values())))
    spc = chunk // n_shards          # lanes (and staged rows) per shard

    # ---- host-side merged results: items finished before a resume
    # live here and never get device rows again
    done_mask = np.zeros(total, bool)
    base = {"n_instr": np.zeros(total, np.int64),
            "n_two": np.zeros(total, np.int64),
            "n_cycles": np.zeros(total, np.int64),
            "halted": np.zeros(total, bool),
            "out": np.zeros(total, np.int32)}
    if keep_state:
        base.update(mems=np.zeros((total, mem_words), np.int32),
                    regs=np.zeros((total, 16), np.int32),
                    pc=np.zeros(total, np.int32),
                    mix_items=np.zeros((total, n_mix), np.int32))
    mix_base = np.zeros((n_groups, n_mix), np.int64)

    g_lane_steps = np.zeros(n_groups, np.int64)
    g_segments = np.zeros(n_groups, np.int64)
    shard_retired = np.zeros(n_shards, np.int64)
    shard_steps = np.zeros(n_shards, np.int64)
    lane_steps = 0
    n_segments = 0
    prev_seg = 0
    detected = corrected = quarantined = 0        # §9.14 counters
    n_quar = np.zeros(n_shards, np.int64)         # quarantined pairs

    # ---- resume? (canonical checkpoint — independent of the mesh and
    # chunk it was written under)
    resume = None
    if checkpoint_dir is not None \
            and dckpt.latest_step(checkpoint_dir) is not None:
        tree, _ = dckpt.restore(
            checkpoint_dir, _resident_ckpt_skeleton(n_groups, keep_state))
        resume = {k: np.asarray(v) for k, v in tree.items()}
        if not np.array_equal(resume["counts"], counts):
            raise ValueError(
                f"checkpoint in {checkpoint_dir} was written for group "
                f"sizes {resume['counts'].tolist()}, plan has "
                f"{counts.tolist()}")
        if int(resume["lane_mem"].shape[1]) != mem_words:
            raise ValueError("checkpoint lane memory width "
                             f"{resume['lane_mem'].shape[1]} != plan "
                             f"mem_words {mem_words}")
        done_mask = resume["done_mask"].astype(bool).copy()
        for k in base:
            base[k] = resume["val_" + k].astype(base[k].dtype).copy()
        mix_base = resume["mix_g"].astype(np.int64).copy()
        lane_steps = int(resume["counters"][0])
        n_segments = int(resume["counters"][1])
        controller.rate = float(resume["ctrl"][0])
        prev_seg = int(resume["ctrl"][1])
        controller.schedule = [int(x) for x in resume["sched"]]
        g_lane_steps = resume["g_lane_steps"].astype(np.int64).copy()
        g_segments = resume["g_segments"].astype(np.int64).copy()
    retired = int(done_mask.sum())

    # ---- static item->shard partition (§9.12): pending spans plus the
    # in-flight lanes a resume deals onto the new shards
    if resume is None:
        spans = shard_partition(counts, n_shards)
        live = np.zeros(0, np.int64)
        lane_shard = np.zeros(0, np.int64)
    else:
        lane_item = resume["lane_item"].astype(np.int64)
        live = np.nonzero(lane_item >= 0)[0]
        if live.size > chunk:
            raise ValueError(
                f"cannot resume {live.size} in-flight lanes onto a "
                f"{chunk}-lane pool ({n_shards} shards x {spc})")
        # contiguous balanced deal of surviving lanes to new shards
        lane_shard = (np.arange(live.size) * n_shards) // max(
            live.size, 1)
        pend = resume["pending"].astype(np.int64).reshape(-1, 3)
        spans = [_split_spans([(int(lo), int(hi))
                               for g2, lo, hi in pend if g2 == g],
                              n_shards) for g in range(n_groups)]
    infl_items = [resume["lane_item"].astype(np.int64)[
        live[lane_shard == s]] if resume is not None
        else np.zeros(0, np.int64) for s in range(n_shards)]

    # ---- shard-local accumulator layout: shard s owns rows
    # [s*cap, (s+1)*cap); rowmap[global item row] -> acc row
    pend_n = np.array([[sum(hi - lo for lo, hi in spans[g][s])
                        for s in range(n_shards)]
                       for g in range(n_groups)],
                      np.int64).reshape(n_groups, n_shards)
    infl_n = np.array([x.size for x in infl_items], np.int64)
    cap = int(max(int((infl_n + pend_n.sum(0)).max()), 1))
    rowmap = np.full(total, -1, np.int64)
    lbase = np.zeros((n_shards, n_groups), np.int64)
    for s in range(n_shards):
        rowmap[infl_items[s]] = s * cap + np.arange(infl_n[s])
        off = int(infl_n[s])
        for g in range(n_groups):
            lbase[s, g] = off
            items = slot_base[g] + _span_items(spans[g][s])
            rowmap[items] = s * cap + off + np.arange(items.size)
            off += items.size
    row_owner = np.full(n_shards * cap, -1, np.int64)
    have = np.nonzero(rowmap >= 0)[0]
    row_owner[rowmap[have]] = have

    # ---- per-(group, shard) prefetchers over the pending spans
    prefs = [[_Prefetcher(_span_source(groups[g].source, spans[g][s]),
                          int(pend_n[g, s]),
                          block=max(1, min(spc, int(pend_n[g, s]))),
                          background=prefetch)
              for s in range(n_shards)] for g in range(n_groups)]

    # ---- host mirror of the per-shard staged batches (FIFO per shard)
    st_mems = np.zeros((n_shards, spc, mem_words), np.int32)
    st_prog = np.zeros((n_shards, spc), np.int32)
    st_ms = np.zeros((n_shards, spc), np.int32)
    st_slot = np.zeros((n_shards, spc), np.int32)
    staged_n = np.zeros(n_shards, np.int64)
    staged_cursor = np.zeros((n_groups, n_shards), np.int64)
    staged = {"dirty": True, "dev": None}
    stage_sh = None
    if mesh is not None:
        stage_sh = dsharding.stage_shardings(
            mesh, (st_mems, st_prog, st_ms, st_slot))

    # quarantined pairs hand their item back here; restock re-stages it
    # (same accumulator row — the healthy pair that picks it up scatters
    # into the row the item always owned) ahead of fresh admissions
    requeue = [[] for _ in range(n_shards)]

    def restock():
        changed = False
        for s in range(n_shards):
            while requeue[s] and int(staged_n[s]) < spc:
                g, local, slot = requeue[s].pop(0)
                off = int(staged_n[s])
                st_mems[s, off] = 0
                st_mems[s, off, :groups[g].mem_words] = \
                    np.asarray(groups[g].source(local, 1), np.int32)[0]
                st_prog[s, off] = g
                st_ms[s, off] = ms_of[g]
                st_slot[s, off] = slot
                staged_n[s] = off + 1
                changed = True
        for s in range(n_shards):
            free = spc - int(staged_n[s])
            remaining = pend_n[:, s] - staged_cursor[:, s]
            if free <= 0 or int(remaining.sum()) == 0:
                continue
            take = _apportion(free, remaining)
            off = int(staged_n[s])
            for g in np.nonzero(take)[0]:
                k = int(take[g])
                st_mems[s, off:off + k] = 0
                st_mems[s, off:off + k, :groups[g].mem_words] = \
                    prefs[g][s].take(k)
                st_prog[s, off:off + k] = g
                st_ms[s, off:off + k] = ms_of[g]
                st_slot[s, off:off + k] = lbase[s, g] + np.arange(
                    staged_cursor[g, s], staged_cursor[g, s] + k)
                staged_cursor[g, s] += k
                off += k
            if off != staged_n[s]:
                staged_n[s] = off
                changed = True
        if changed:
            staged["dirty"] = True

    def consume(con):
        changed = False
        for s in range(n_shards):
            k = int(con[s])
            if k <= 0:
                continue
            keep = int(staged_n[s]) - k
            for buf in (st_mems, st_prog, st_ms, st_slot):
                buf[s, :keep] = buf[s, k:int(staged_n[s])].copy()
            staged_n[s] = keep
            changed = True
        if changed:
            staged["dirty"] = True

    def upload():
        """Async-stage the batches to device (device_put returns before
        the transfer completes, so this overlaps the running segment).
        Each device receives ONLY its own (spc, ...) slice — staging
        H2D bytes are O(chunk) total, not O(chunk x devices)."""
        if not staged["dirty"] and staged["dev"] is not None:
            return
        arrs = (st_mems.copy(), st_prog.copy(), st_ms.copy(),
                st_slot.copy())
        if mesh is None:
            staged["dev"] = tuple(jax.device_put(a) for a in arrs)
        else:
            staged["dev"] = tuple(jax.device_put(a, s)
                                  for a, s in zip(arrs, stage_sh))
        staged["dirty"] = False

    # ---- device state: the lane pool + result accumulators. Fresh
    # runs start all-parked; a resume re-seats surviving lanes at the
    # head of their new shard's lane block.
    regs_l = np.zeros((chunk, 16), np.int32)
    pc_l = np.zeros(chunk, np.int32)
    mem_l = np.zeros((chunk, mem_words), np.int32)
    halted_l = np.ones(chunk, bool)       # parked lanes never step
    instr_l = np.zeros(chunk, np.int32)
    two_l = np.zeros(chunk, np.int32)
    mix_l = np.zeros((chunk, n_mix), np.int32)
    cyc_l = np.zeros(chunk, np.int32)
    prog_l = np.zeros(chunk, np.int32)
    ms_l = np.zeros(chunk, np.int32)
    slot_l = np.full(chunk, -1, np.int32)
    prev_l = np.zeros(chunk, np.int32)
    if resume is not None:
        for s in range(n_shards):
            old = live[lane_shard == s]
            pos = s * spc + np.arange(old.size)
            regs_l[pos] = resume["lane_regs"][old]
            pc_l[pos] = resume["lane_pc"][old]
            mem_l[pos] = resume["lane_mem"][old]
            halted_l[pos] = resume["lane_halted"][old].astype(bool)
            instr_l[pos] = resume["lane_n_instr"][old]
            two_l[pos] = resume["lane_n_two"][old]
            mix_l[pos] = resume["lane_mix"][old]
            cyc_l[pos] = resume["lane_n_cycles"][old]
            prog_l[pos] = resume["lane_prog"][old]
            ms_l[pos] = resume["lane_ms"][old]
            slot_l[pos] = np.arange(old.size)   # the in-flight rows
            prev_l[pos] = resume["lane_prev"][old]
    state = iss.PackedState(
        lanes=iss.ISSState(
            regs=jnp.asarray(regs_l), pc=jnp.asarray(pc_l),
            mem=jnp.asarray(mem_l), halted=jnp.asarray(halted_l),
            n_instr=jnp.asarray(instr_l), n_two_stage=jnp.asarray(two_l),
            mix=jnp.asarray(mix_l), n_cycles=jnp.asarray(cyc_l)),
        prog_id=jnp.asarray(prog_l), max_steps=jnp.asarray(ms_l))
    item_slot = jnp.asarray(slot_l, iss.I32)
    # resilience state (§9.14): per-lane fault keys/epochs, per-pair
    # retry counters + quarantine flags, and the rollback snapshot
    lane_key = None
    if faults is not None:
        lane_key = jnp.asarray(flexifault.lane_keys(faults.seed, chunk))
    epoch = jnp.zeros(chunk, iss.I32) if (faults is not None or dmr) \
        else None
    retries = jnp.zeros(chunk // 2, iss.I32) if dmr else None
    quar_d = jnp.zeros(chunk // 2, bool) if dmr else None
    snap = jax.tree.map(lambda x: jnp.array(x, copy=True),
                        state.lanes) if dmr else None
    acc = ResidentAcc(
        n_instr=jnp.zeros(n_shards * cap, iss.I32),
        n_two=jnp.zeros(n_shards * cap, iss.I32),
        n_cycles=jnp.zeros(n_shards * cap, iss.I32),
        halted=jnp.zeros(n_shards * cap, bool),
        out=jnp.zeros(n_shards * cap, iss.I32),
        mix_g=jnp.zeros((n_shards, n_groups, n_mix), iss.I32),
        prev_instr=jnp.asarray(prev_l, iss.I32),
        mems=jnp.zeros((n_shards * cap, mem_words), iss.I32)
        if keep_state else None,
        regs=jnp.zeros((n_shards * cap, 16), iss.I32)
        if keep_state else None,
        pc=jnp.zeros(n_shards * cap, iss.I32) if keep_state else None,
        mix_items=jnp.zeros((n_shards * cap, n_mix), iss.I32)
        if keep_state else None)
    if mesh is not None:
        state = jax.tree.map(jax.device_put, state,
                             dsharding.lane_shardings(mesh, state))
        item_slot = jax.device_put(
            item_slot, dsharding.lane_shardings(mesh, item_slot))
        acc = jax.tree.map(jax.device_put, acc,
                           dsharding.lane_shardings(mesh, acc))

        def _lane_put(x):
            return None if x is None else jax.device_put(
                x, dsharding.lane_shardings(mesh, x))

        lane_key = _lane_put(lane_key)
        epoch = _lane_put(epoch)
        retries = _lane_put(retries)
        quar_d = _lane_put(quar_d)
        if snap is not None:
            snap = jax.tree.map(jax.device_put, snap,
                                dsharding.lane_shardings(mesh, snap))
    out_addr_dev = jnp.asarray(out_addr_np)
    # positional on purpose: test_shard_local.py wraps this factory
    # with a *args-only shim to audit the lowered HLO
    refill_fn = _resident_refill_runner(
        mesh, mem_words, n_groups, keep_state, use_pallas,
        faults is not None and not dmr, dmr, max_retries)

    def merged_vals(accv):
        """Per-item results: host `base` where done, else the item's
        accumulator row through the item->row table."""
        idx = np.clip(rowmap, 0, None)
        out = {}
        for k, b in base.items():
            v = accv[k][idx].astype(b.dtype)
            mask = done_mask if b.ndim == 1 else done_mask[:, None]
            out[k] = np.where(mask, b, v)
        return out

    def save_checkpoint():
        """Canonical snapshot at a refill boundary: (state, item_slot,
        acc) here are exactly the inputs the next refill would see, and
        staged-but-unconsumed items roll back into the pending spans
        (they were never stepped, so re-staging them after a resume is
        bit-exact)."""
        lanes = state.lanes
        accv = {k: clock.fetch(getattr(acc, k))
                for k in base}
        slot_h = clock.fetch(item_slot).astype(np.int64)
        prev_h = clock.fetch(acc.prev_instr)
        mix_now = mix_base + clock.fetch(acc.mix_g).astype(
            np.int64).sum(0)
        merged = merged_vals(accv)
        # global item of each in-flight lane, via the row table
        lane_rows = (np.arange(chunk) // spc) * cap + slot_h
        lane_item = np.where(
            slot_h >= 0,
            row_owner[np.clip(lane_rows, 0, n_shards * cap - 1)], -1)
        # pending = staged-but-unconsumed + not-yet-staged remainder
        pend_items = [[] for _ in range(n_groups)]
        for s in range(n_shards):
            k = int(staged_n[s])
            if k:
                srows = s * cap + st_slot[s, :k].astype(np.int64)
                sitems = row_owner[srows]
                for g in range(n_groups):
                    pend_items[g].append(
                        sitems[st_prog[s, :k] == g] - slot_base[g])
            for g in range(n_groups):
                rest = _span_items(spans[g][s])
                pend_items[g].append(rest[int(staged_cursor[g, s]):])
        prows = []
        for g in range(n_groups):
            items = np.sort(np.concatenate(
                [np.zeros(0, np.int64)] + pend_items[g]))
            prows += [(g, lo, hi) for lo, hi in _items_to_spans(items)]
        done_now = np.ones(total, bool)
        done_now[lane_item[lane_item >= 0]] = False
        for g, lo, hi in prows:
            done_now[slot_base[g] + lo:slot_base[g] + hi] = False
        tree = {"counts": counts.copy(), "done_mask": done_now,
                "mix_g": mix_now, "lane_item": lane_item,
                "lane_prev": prev_h,
                "pending": np.asarray(prows, np.int64).reshape(-1, 3),
                "counters": np.array([lane_steps, n_segments],
                                     np.int64),
                "ctrl": np.array([controller.rate, prev_seg],
                                 np.float64),
                "sched": np.array(controller.schedule, np.int64),
                "g_lane_steps": g_lane_steps.copy(),
                "g_segments": g_segments.copy()}
        tree.update({"val_" + k: v for k, v in merged.items()})
        tree.update(
            lane_regs=clock.fetch(lanes.regs),
            lane_pc=clock.fetch(lanes.pc),
            lane_mem=clock.fetch(lanes.mem),
            lane_halted=clock.fetch(lanes.halted),
            lane_n_instr=clock.fetch(lanes.n_instr),
            lane_n_two=clock.fetch(lanes.n_two_stage),
            lane_mix=clock.fetch(lanes.mix),
            lane_n_cycles=clock.fetch(lanes.n_cycles),
            lane_prog=clock.fetch(state.prog_id),
            lane_ms=clock.fetch(state.max_steps))
        dckpt.save(checkpoint_dir, n_segments, tree)

    last_saved = n_segments
    try:
        restock()
        while retired < total:
            if crash_after is not None and n_segments >= crash_after:
                raise InjectedFault(
                    f"injected fault after segment {n_segments}")
            if checkpoint_dir is not None and checkpoint_every > 0 \
                    and n_segments - last_saved >= checkpoint_every:
                save_checkpoint()
                last_saved = n_segments
            upload()
            staged_dev_n = jnp.asarray(staged_n, iss.I32)
            if dmr:
                (state, item_slot, epoch, retries, quar_d, acc,
                 stats) = refill_fn(
                    state, item_slot, epoch, retries, quar_d, snap,
                    acc, *staged["dev"], staged_dev_n, out_addr_dev)
                # the refreshed boundary state IS the next rollback
                # snapshot; holding it here (while the non-donating
                # segment runs) keeps its buffers alive
                snap = state.lanes
            elif faults is not None:
                state, item_slot, epoch, acc, stats = refill_fn(
                    state, item_slot, epoch, acc, *staged["dev"],
                    staged_dev_n, out_addr_dev)
            else:
                state, item_slot, acc, stats = refill_fn(
                    state, item_slot, acc, *staged["dev"],
                    staged_dev_n, out_addr_dev)
            seg_steps = controller.next_seg()
            # positional on purpose: test_shard_local.py wraps this
            # factory with a *args-only shim to audit the lowered HLO
            seg_fn = _packed_segment_runner(stepper, chunk, seg_steps,
                                            mem_words, n_groups,
                                            bank_np.shape[1], mesh,
                                            subset, timing, faults,
                                            not dmr)
            if faults is not None:
                state = seg_fn(bank, code_len, mem_len, cost,
                               lane_key, epoch, state)
            else:
                state = seg_fn(bank, code_len, mem_len, cost, state)
            if hasattr(stats, "copy_to_host_async"):
                stats.copy_to_host_async()
            # blocks until refill_i only — seg_i is already running;
            # one (n_shards, 3+G) read regardless of device count
            # ((n_shards, 6+G) under DMR: +detected/corrected/q_slot)
            sv = np.asarray(clock.fetch(stats), np.int64)
            n_ret = int(sv[:, 0].sum())
            if dmr:
                detected += int(sv[:, 3].sum())
                corrected += int(sv[:, 4].sum())
                for s in np.nonzero(sv[:, 5] >= 0)[0]:
                    # quarantined pair: map the acc row back to the
                    # item and hand it to restock for re-admission
                    row = int(s) * cap + int(sv[s, 5])
                    item = int(row_owner[row])
                    g = int(np.searchsorted(slot_base, item,
                                            side="right") - 1)
                    requeue[int(s)].append(
                        (g, item - int(slot_base[g]), int(sv[s, 5])))
                    quarantined += 1
                    n_quar[int(s)] += 1
                    if n_quar[int(s)] >= spc // 2:
                        raise RuntimeError(
                            f"DMR pool starved: all {spc // 2} lane "
                            f"pair(s) of shard {int(s)} are "
                            f"quarantined with items still pending — "
                            f"raise chunk, raise max_retries, or fix "
                            f"the fault rate")
                act_s = sv[:, 6:]
            else:
                act_s = sv[:, 3:]
            deltas = sv[:, 2]
            sh_act = act_s.sum(1) > 0
            if sh_act.any():
                n_segments += 1
                g_segments += act_s.sum(0) > 0
                g_lane_steps += (act_s * deltas[:, None]).sum(0)
                stepped = spc * deltas * sh_act
                lane_steps += int(stepped.sum())
                shard_steps += stepped
            controller.record(n_ret, prev_seg)
            prev_seg = seg_steps
            retired += n_ret
            shard_retired += sv[:, 0]
            t_refill = time.perf_counter()
            consume(sv[:, 1])
            restock()
            dt = time.perf_counter() - t_refill
            clock.refill_wall_s += dt
            try:
                if state.lanes.regs.is_ready():  # segment already done:
                    clock.idle_s += dt           # restock was idle time
            except AttributeError:
                pass
    finally:
        for row in prefs:
            for p in row:
                p.close()

    # ---- drain: ONE demux of the on-device accumulators, merged with
    # the host base through the item->row table
    accv = {"n_instr": clock.fetch(acc.n_instr),
            "n_two": clock.fetch(acc.n_two)}
    accv["n_cycles"] = clock.fetch(acc.n_cycles) if timing \
        else np.zeros(n_shards * cap, np.int64)
    accv["halted"] = clock.fetch(acc.halted)
    accv["out"] = clock.fetch(acc.out)
    res_mix_g = mix_base + clock.fetch(acc.mix_g).astype(
        np.int64).sum(0)
    if keep_state:
        accv["mems"] = clock.fetch(acc.mems)
        accv["regs"] = clock.fetch(acc.regs)
        accv["pc"] = clock.fetch(acc.pc)
        accv["mix_items"] = clock.fetch(acc.mix_items)
    merged = merged_vals(accv)

    r_instr, r_two, r_halt, r_out, r_mix = [], [], [], [], []
    r_cycles = []
    r_mem = r_regs = r_pc = r_mix_items = None
    if keep_state:
        r_mem, r_regs, r_pc, r_mix_items = [], [], [], []
    for g, grp in enumerate(groups):
        sl = slice(int(slot_base[g]), int(slot_base[g] + counts[g]))
        r_instr.append(merged["n_instr"][sl].astype(np.int64))
        r_two.append(merged["n_two"][sl].astype(np.int64))
        r_cycles.append(merged["n_cycles"][sl].astype(np.int64))
        r_halt.append(merged["halted"][sl])
        r_out.append(merged["out"][sl])
        r_mix.append(res_mix_g[g])
        if keep_state:
            r_mem.append(merged["mems"][sl, :grp.mem_words].copy())
            r_regs.append(merged["regs"][sl])
            r_pc.append(merged["pc"][sl])
            r_mix_items.append(merged["mix_items"][sl])

    return {"r_instr": r_instr, "r_two": r_two, "r_halt": r_halt,
            "r_out": r_out, "r_mix": r_mix, "r_mem": r_mem,
            "r_regs": r_regs, "r_pc": r_pc, "r_mix_items": r_mix_items,
            "r_cycles": r_cycles,
            "g_lane_steps": g_lane_steps, "g_segments": g_segments,
            "lane_steps": lane_steps, "n_segments": n_segments,
            "n_shards": n_shards,
            "shard_retired": shard_retired.tolist(),
            "shard_lane_steps": shard_steps.tolist(),
            "detected": detected, "corrected": corrected,
            "quarantined": quarantined}


def run_workload_stream(w: Workload, n_items: int, *, seed: int = 0,
                        chunk: int = 256, seg_steps: int = 4096,
                        max_steps: Optional[int] = None,
                        keep_state: bool = False,
                        mesh: Optional[Mesh] = None,
                        stepper: str = "branchless",
                        prefetch: bool = True, refill: str = "device",
                        adaptive: bool = False,
                        cost: Optional[np.ndarray] = None,
                        subset: Optional[frozenset] = None,
                        faults: Optional[flexifault.FaultSpec] = None,
                        redundancy: str = "none",
                        max_retries: int = 2) -> FleetResult:
    """Convenience wrapper: stream a FlexiBench workload end to end.

    The branchless/pallas steppers' opcode subset is derived from the
    workload's program text, so the compiled segment contains only the
    ISA subset this workload retires (the RISP specialization knob
    applied to the simulator). `subset` pins it explicitly instead —
    e.g. FlexiLint's reachable-only subset (DESIGN.md §9.11)."""
    return run_stream(
        w.program.code, workload_source(w, seed), n_items=n_items,
        mem_words=w.total_mem_words,
        max_steps=w.max_steps if max_steps is None else max_steps,
        chunk=chunk, subset=subset,
        seg_steps=seg_steps, out_addr=w.out_addr, keep_state=keep_state,
        mesh=mesh, stepper=stepper, prefetch=prefetch, refill=refill,
        adaptive=adaptive, cost=cost, faults=faults,
        redundancy=redundancy, max_retries=max_retries)
