"""FLEXIBITS bit-serial cycle + energy model (paper §4.2/§4.4, Table 7).

Timing: one-stage instructions take 32/w + a_w cycles, two-stage 64/w + b_w
(w = datapath width). (a_1,b_1)=(6,6) reproduces the paper's SERV numbers
exactly (38 / 70 cycles, §4.2 "70 cycles from initial fetch to retirement").
(a_4,b_4) and (a_8,b_8) are calibration constants fitted so the suite
geomean speedups land on the paper's 3.15x (QERV) and 4.93x (HERV)
(DESIGN.md §5). Powers/areas are the paper's measured values (Table 7), so
energy ratios 2.65x / 3.50x follow from the timing model.

Memory (Table 8): LPROM ~ area-only (negligible power); SRAM power/area
scale linearly with required KB, anchored to the paper's per-workload
Table 3 <-> Table 8 pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

CLOCK_HZ = 10_000.0          # 10 kHz operating point (paper §4.4)


@dataclasses.dataclass(frozen=True)
class Core:
    name: str
    width: int               # datapath bits
    area_mm2: float          # Table 7
    power_mw: float          # Table 7
    gates: int               # Table 4 (NAND2)
    a: float                 # one-stage fetch/decode overhead cycles
    b: float                 # two-stage overhead cycles

    def cycles_one_stage(self) -> float:
        return 32.0 / self.width + self.a

    def cycles_two_stage(self) -> float:
        return 64.0 / self.width + self.b

    def cycles(self, n_one: float, n_two: float) -> float:
        return (n_one * self.cycles_one_stage()
                + n_two * self.cycles_two_stage())

    def runtime_s(self, n_one: float, n_two: float,
                  clock_hz: float = CLOCK_HZ) -> float:
        return self.cycles(n_one, n_two) / clock_hz

    def energy_j(self, n_one: float, n_two: float,
                 extra_power_mw: float = 0.0,
                 clock_hz: float = CLOCK_HZ) -> float:
        """Energy per program execution (core + memory static power)."""
        t = self.runtime_s(n_one, n_two, clock_hz)
        return (self.power_mw + extra_power_mw) * 1e-3 * t


SERV = Core("SERV", 1, area_mm2=2.93, power_mw=17.75, gates=2546,
            a=6.0, b=6.0)
QERV = Core("QERV", 4, area_mm2=3.68, power_mw=21.07, gates=3198,
            a=4.0, b=6.0)
HERV = Core("HERV", 8, area_mm2=4.50, power_mw=24.99, gates=3903,
            a=3.65, b=6.2)

CORES: Dict[str, Core] = {"SERV": SERV, "QERV": QERV, "HERV": HERV}


# ------------------------------------------------------------------ memory
# Table 8 anchors: SRAM area/power scale with VM KB; LPROM area scales with
# NVM KB at negligible power. Linear coefficients fitted to the paper's
# (Table 3 KB, Table 8 area/power) pairs:
#   WQ: VM 0.01 KB -> SRAM 2.32 (area units), power 2.26 mW total
#   GR: VM 40.0 KB -> SRAM 661.85, power 642.58 mW
#   AP: NVM 63.38 KB -> LPROM 182.03 area units
SRAM_AREA_PER_KB = (661.85 - 2.32) / (40.0 - 0.01)      # ~16.49 /KB
SRAM_AREA_BASE = 2.32 - SRAM_AREA_PER_KB * 0.01
SRAM_MW_PER_KB = (642.58 - 2.26) / (40.0 - 0.01)        # ~16.01 mW/KB
SRAM_MW_BASE = 2.26 - SRAM_MW_PER_KB * 0.01
LPROM_AREA_PER_KB = 182.03 / 63.38                      # ~2.872 /KB
# Table-8 "area units" -> mm^2: Table 7 core areas are mm^2; Pragmatic's
# LPROM/SRAM macros are characterized per-KB. We treat Table 8 units as
# 0.01 mm^2 so a 40 KB SRAM ~ 6.6 mm^2 (consistent with FlexIC die sizes).
AREA_UNIT_MM2 = 0.01


def sram_power_mw(vm_kb: float) -> float:
    return max(SRAM_MW_BASE + SRAM_MW_PER_KB * vm_kb, 0.05)


def sram_area_mm2(vm_kb: float) -> float:
    return max(SRAM_AREA_BASE + SRAM_AREA_PER_KB * vm_kb, 0.1) \
        * AREA_UNIT_MM2


def lprom_area_mm2(nvm_kb: float) -> float:
    return LPROM_AREA_PER_KB * nvm_kb * AREA_UNIT_MM2


def system_area_mm2(core: Core, nvm_kb: float, vm_kb: float) -> float:
    return core.area_mm2 + sram_area_mm2(vm_kb) + lprom_area_mm2(nvm_kb)


def system_power_mw(core: Core, vm_kb: float) -> float:
    return core.power_mw + sram_power_mw(vm_kb)
