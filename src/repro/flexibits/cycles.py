"""FLEXIBITS bit-serial cycle + energy model (paper §4.2/§4.4, Table 7).

Timing: one-stage instructions take 32/w + a_w cycles, two-stage 64/w + b_w
(w = datapath width). (a_1,b_1)=(6,6) reproduces the paper's SERV numbers
exactly (38 / 70 cycles, §4.2 "70 cycles from initial fetch to retirement").
(a_4,b_4) and (a_8,b_8) are calibration constants fitted so the suite
geomean speedups land on the paper's 3.15x (QERV) and 4.93x (HERV)
(DESIGN.md §5). Powers/areas are the paper's measured values (Table 7), so
energy ratios 2.65x / 3.50x follow from the timing model.

Memory (Table 8): LPROM ~ area-only (negligible power); SRAM power/area
scale linearly with required KB, anchored to the paper's per-workload
Table 3 <-> Table 8 pairs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import numpy as np

CLOCK_HZ = 10_000.0          # 10 kHz operating point (paper §4.4)

# Fig. 2a instruction-mix categories — the canonical order for every mix
# vector in the codebase: iss.ISSState.mix, PyISS.events, and the
# per-(stage, class) blocks of `cost_row`. Lives here (not iss.py) so the
# pure-python oracle and the cost table need no jax import.
MIX_CLASSES = ("loads", "stores", "branches", "jumps", "shifts", "I-type",
               "R-type", "system")


@dataclasses.dataclass(frozen=True)
class Core:
    name: str
    width: int               # datapath bits
    area_mm2: float          # Table 7
    power_mw: float          # Table 7
    gates: int               # Table 4 (NAND2)
    a: float                 # one-stage fetch/decode overhead cycles
    b: float                 # two-stage overhead cycles

    def cycles_one_stage(self) -> float:
        return 32.0 / self.width + self.a

    def cycles_two_stage(self) -> float:
        return 64.0 / self.width + self.b

    def cycles(self, n_one: float, n_two: float) -> float:
        return (n_one * self.cycles_one_stage()
                + n_two * self.cycles_two_stage())

    def runtime_s(self, n_one: float, n_two: float,
                  clock_hz: float = CLOCK_HZ) -> float:
        return self.cycles(n_one, n_two) / clock_hz

    def energy_j(self, n_one: float, n_two: float,
                 extra_power_mw: float = 0.0,
                 clock_hz: float = CLOCK_HZ) -> float:
        """Energy per program execution (core + memory static power)."""
        t = self.runtime_s(n_one, n_two, clock_hz)
        return (self.power_mw + extra_power_mw) * 1e-3 * t


SERV = Core("SERV", 1, area_mm2=2.93, power_mw=17.75, gates=2546,
            a=6.0, b=6.0)
QERV = Core("QERV", 4, area_mm2=3.68, power_mw=21.07, gates=3198,
            a=4.0, b=6.0)
HERV = Core("HERV", 8, area_mm2=4.50, power_mw=24.99, gates=3903,
            a=3.65, b=6.2)

CORES: Dict[str, Core] = {"SERV": SERV, "QERV": QERV, "HERV": HERV}


# ----------------------------------------------------- cycle-cost table
# Per-lane timing layer (DESIGN.md §9.10). Integer fixed point: costs are
# expressed in TICKS (TICKS_PER_CYCLE ticks = 1 cycle) so every stepper
# accumulates exact int32 tallies — TICKS_PER_CYCLE is chosen so that
# 32/w, 64/w, and the Table-7 overheads a_w/b_w are all whole numbers of
# ticks for every core (20*a and 20*b are integral for SERV/QERV/HERV).
TICKS_PER_CYCLE = 20

# Flattened cost row consumed by iss.timing_ticks / PyISS.events:
#   [0:8]   one-stage base ticks per mix class (MIX_CLASSES order)
#   [8:16]  two-stage base ticks per mix class
#   [16]    taken-branch refetch          (dynamic)
#   [17]    per-shift-amount-bit serial shift cost (dynamic)
#   [18]    subword load/store read-modify-write   (dynamic)
N_COST = 2 * len(MIX_CLASSES) + 3
TAKEN_IDX = 2 * len(MIX_CLASSES)
SHIFT_IDX = TAKEN_IDX + 1
SUBWORD_IDX = TAKEN_IDX + 2


def base_ticks(core: Core) -> "tuple[int, int]":
    """(one-stage, two-stage) base cost in ticks.

    Exactly TICKS_PER_CYCLE * Core.cycles_one_stage()/cycles_two_stage()
    for every Table-7 core: 640/w and 1280/w are integral for w in
    {1, 4, 8} and so are 20*a_w / 20*b_w.
    """
    one = 640 // core.width + round(TICKS_PER_CYCLE * core.a)
    two = 1280 // core.width + round(TICKS_PER_CYCLE * core.b)
    return one, two


def cost_row(core: Core, dynamic: bool = False) -> np.ndarray:
    """(N_COST,) int32 cycle-cost row for `core`, in ticks.

    With dynamic=False (the table's BASE case) only the per-(stage, mix
    class) entries are populated, and accumulated ticks equal
    TICKS_PER_CYCLE * Core.cycles(n_one, n_two) exactly — the SERV 38/70
    pins and the Table-7 geomeans are preserved by construction.

    dynamic=True additionally prices the events the two-bucket model
    cannot see (ROADMAP "cycle-accurate core timing beyond 1 CPI"):
    a taken branch refetches (one extra 32-bit fetch pass, 32/w cycles),
    serial shifters pay one datapath pass per shift-amount bit (1/w
    cycles per bit), and subword loads/stores pay an extra word pass for
    the read-modify-write (32/w cycles).
    """
    one, two = base_ticks(core)
    row = np.zeros(N_COST, np.int32)
    row[:len(MIX_CLASSES)] = one
    row[len(MIX_CLASSES):2 * len(MIX_CLASSES)] = two
    if dynamic:
        row[TAKEN_IDX] = 640 // core.width
        row[SHIFT_IDX] = 20 // core.width
        row[SUBWORD_IDX] = 640 // core.width
    return row


def event_cycles(events, core: Core, dynamic: bool = False) -> float:
    """Cycles for an (N_COST,) timing-event vector priced on `core`.

    Events are core-independent (PyISS tracks them once per program);
    pricing is a dot product against the core's cost row, so one
    profiling run serves every candidate core. With dynamic=False this
    equals `Core.cycles(n_one, n_two)` exactly.
    """
    ev = np.asarray(events, np.float64)
    return float(ev @ cost_row(core, dynamic).astype(np.float64)) \
        / TICKS_PER_CYCLE


# ------------------------------------------------------------------ memory
# Table 8 anchors: SRAM area/power scale with VM KB; LPROM area scales with
# NVM KB at negligible power. Linear coefficients fitted to the paper's
# (Table 3 KB, Table 8 area/power) pairs:
#   WQ: VM 0.01 KB -> SRAM 2.32 (area units), power 2.26 mW total
#   GR: VM 40.0 KB -> SRAM 661.85, power 642.58 mW
#   AP: NVM 63.38 KB -> LPROM 182.03 area units
SRAM_AREA_PER_KB = (661.85 - 2.32) / (40.0 - 0.01)      # ~16.49 /KB
SRAM_AREA_BASE = 2.32 - SRAM_AREA_PER_KB * 0.01
SRAM_MW_PER_KB = (642.58 - 2.26) / (40.0 - 0.01)        # ~16.01 mW/KB
SRAM_MW_BASE = 2.26 - SRAM_MW_PER_KB * 0.01
LPROM_AREA_PER_KB = 182.03 / 63.38                      # ~2.872 /KB
# Table-8 "area units" -> mm^2: Table 7 core areas are mm^2; Pragmatic's
# LPROM/SRAM macros are characterized per-KB. We treat Table 8 units as
# 0.01 mm^2 so a 40 KB SRAM ~ 6.6 mm^2 (consistent with FlexIC die sizes).
AREA_UNIT_MM2 = 0.01


def sram_power_mw(vm_kb: float) -> float:
    return max(SRAM_MW_BASE + SRAM_MW_PER_KB * vm_kb, 0.05)


def sram_area_mm2(vm_kb: float) -> float:
    return max(SRAM_AREA_BASE + SRAM_AREA_PER_KB * vm_kb, 0.1) \
        * AREA_UNIT_MM2


def lprom_area_mm2(nvm_kb: float) -> float:
    return LPROM_AREA_PER_KB * nvm_kb * AREA_UNIT_MM2


def system_area_mm2(core: Core, nvm_kb: float, vm_kb: float) -> float:
    return core.area_mm2 + sram_area_mm2(vm_kb) + lprom_area_mm2(nvm_kb)


def system_power_mw(core: Core, vm_kb: float) -> float:
    return core.power_mw + sram_power_mw(vm_kb)
