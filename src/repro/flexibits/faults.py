"""FlexiFault — deterministic fault injection for the lane steppers
(DESIGN.md §9.14).

Flexible ICs run at far lower yield and far higher variability than
silicon; this module gives the fleet runtime an adversarial-state layer
with the same cannot-drift discipline as the steppers themselves. A
fault schedule is a pure function of

    (spec.seed, lane, epoch, n_instr)

with no sampler state to carry: per-lane base keys come from
`jax.random.fold_in` (host-side, cached), and every per-step draw is a
murmur3-finalizer hash (`mix32`) of the lane key, the lane's retry/refit
`epoch`, and the post-commit `n_instr` counter. The identical integer
arithmetic exists twice — shape-polymorphic jnp (used verbatim by the
switch, branchless, and Pallas steppers) and masked pure-Python (the
PyISS fault oracle) — so all four produce bit-identical faulty
trajectories for the same schedule (pinned by tests/test_faults.py).

Fault model (post-commit transform, applied after every *live* retired
instruction; the halting instruction itself is exempt — a flip in the
cycle the machine stops is architecturally unobservable):

- ``transient``: with probability `rate` per retired instruction, flip
  one bit in one enabled target — a register (x1..x15), a data-memory
  word (within the lane's own `mem_len`), or the pc (bits 2..11, so the
  pc stays word-aligned and the clamp-on-read fetch contract holds).
- ``stuck``: with probability `rate` per *lane*, one drawn register bit
  is forced to a drawn value after every live step (a manufacturing
  defect; epoch-independent, so retries cannot clear it).
- ``dead``: with probability `rate` per *lane*, the whole register file
  reads zero after every live step (a dead lane; epoch-independent).

The transform is elementwise one-hot arithmetic — no gather/scatter —
so the Pallas tile stepper runs it unchanged inside the fused kernel.
With `spec=None` (or a transient rate of exactly 0) the transform is
dropped from the traced graph entirely, keeping the fault-free graphs
byte-identical to the pre-FlexiFault steppers.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import FrozenSet, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

I32 = jnp.int32
U32 = jnp.uint32

_TARGETS = ("regs", "mem", "pc")
_MASK32 = 0xFFFFFFFF

# derivation salts (arbitrary odd constants, shared with the oracle)
_T1 = 0x9E3779B9      # fire draw -> index draw
_T2 = 0x632BE59B      # index draw -> bit draw
_STUCK = 0x27220A95   # per-lane stuck-at decision
_DEAD = 0x85157AF5    # per-lane dead-lane decision


def _u(v):
    return v.astype(U32)


def _c(v: int):
    """uint32 constant (python ints > 2**31 overflow weak int32)."""
    return jnp.asarray(v, U32)


def mix32(x):
    """murmur3 finalizer over uint32 (shape-polymorphic jnp).

    The one hash every draw is built from. Multiplications wrap mod
    2**32 (uint32 arithmetic); `mix32_py` is the bit-identical
    pure-Python mirror used by the PyISS fault oracle.
    """
    x = x ^ (x >> 16)
    x = x * jnp.asarray(0x85EBCA6B, U32)
    x = x ^ (x >> 13)
    x = x * jnp.asarray(0xC2B2AE35, U32)
    x = x ^ (x >> 16)
    return x


def mix32_py(x: int) -> int:
    """Pure-Python mirror of `mix32` (masked 32-bit arithmetic)."""
    x &= _MASK32
    x ^= x >> 16
    x = (x * 0x85EBCA6B) & _MASK32
    x ^= x >> 13
    x = (x * 0xC2B2AE35) & _MASK32
    x ^= x >> 16
    return x


def width_scaled_rate(rate: float, width: int) -> float:
    """Per-retired-instruction transient rate for a `width`-bit serial
    core: a narrower datapath holds each instruction in flight for more
    cycles (cycles/instr ~ 32/width, cycles.py), so its exposure window
    per retirement is proportionally longer."""
    return min(1.0, rate * (32.0 / float(width)))


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static description of a fault schedule (hashable — it keys the
    jitted-runner caches in fleet/engine.py, so two streams with the
    same spec share compiled graphs).

    `rate` is per retired instruction for ``transient`` and per lane
    for ``stuck``/``dead``. `targets` picks the transient flip targets
    (canonical order; ignored by stuck/dead, which are register-file
    defects). Use `for_core` to derive the width-scaled rate of a
    specific core from a technology base rate.
    """
    rate: float
    seed: int = 0
    targets: Tuple[str, ...] = ("regs",)
    mode: str = "transient"

    def __post_init__(self):
        if self.mode not in ("transient", "stuck", "dead"):
            raise ValueError(f"unknown fault mode {self.mode!r}")
        bad = set(self.targets) - set(_TARGETS)
        if bad or not self.targets:
            raise ValueError(f"targets must be a non-empty subset of "
                             f"{_TARGETS}, got {self.targets!r}")
        # canonicalize target order so equal specs hash equal
        object.__setattr__(self, "targets",
                           tuple(t for t in _TARGETS if t in self.targets))
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")

    @property
    def threshold(self) -> int:
        """uint32 fire threshold: draw < threshold fires."""
        return min(_MASK32, int(round(self.rate * 4294967296.0)))

    @property
    def always(self) -> bool:
        """rate >= 1: fire unconditionally (statically, no draw)."""
        return self.rate >= 1.0

    @property
    def off(self) -> bool:
        """A schedule that can never fire — the transform is dropped
        from the traced graph entirely (the fault-free graph)."""
        return self.threshold == 0 and not self.always

    def for_core(self, core) -> "FaultSpec":
        """Width-scaled copy of this spec for `core` (cycles.Core)."""
        return dataclasses.replace(
            self, rate=width_scaled_rate(self.rate, core.width))


@functools.lru_cache(maxsize=64)
def lane_keys(seed: int, n_lanes: int) -> np.ndarray:
    """Per-lane uint32 base keys: `fold_in(PRNGKey(seed), lane)`, both
    key words xored down to 32 bits. Host-side and cached — the engine
    derives them once per stream; the PyISS oracle calls the same
    function, so lane l's schedule is identical everywhere."""
    base = jax.random.PRNGKey(seed)
    kd = jax.vmap(lambda i: jax.random.fold_in(base, i))(
        jnp.arange(n_lanes, dtype=U32))
    kd = np.asarray(kd, np.uint32)
    out = kd[:, 0] ^ kd[:, 1]
    out.setflags(write=False)
    return out


# ---------------------------------------------------------------------------
# The post-commit transform (jnp, shape-polymorphic)
# ---------------------------------------------------------------------------


def apply_fault_arrays(spec: Optional[FaultSpec], lane_key, epoch,
                       regs, pc, mem, n_instr, gate, mem_len=None):
    """Post-commit fault transform over architectural arrays.

    Shape-polymorphic exactly like `iss.branchless_commits`: a scalar
    lane (`regs` (16,), `pc`/`n_instr`/`gate` (), `mem` (M,)) or a lane
    tile (leading lane axis on everything, `regs` (L, 16), `mem`
    (L, M)). `gate` must already exclude lanes that are halted *after*
    the commit; `mem_len` bounds the transient memory-word draw at the
    lane's own word count (None: the full pool width). All arithmetic
    is elementwise/one-hot — the Pallas kernel runs this unchanged.

    Returns (regs, pc, mem); with `spec=None` or an off schedule the
    inputs pass through untouched (nothing enters the traced graph).
    """
    if spec is None or spec.off:
        return regs, pc, mem
    key = _u(lane_key)
    thr = jnp.asarray(spec.threshold, U32)
    iota16 = jnp.arange(16, dtype=I32)

    if spec.mode == "dead":
        hit = mix32(key ^ _c(_DEAD)) < thr
        dead = gate if spec.always else (gate & hit)
        return jnp.where(dead[..., None], 0, regs), pc, mem

    if spec.mode == "stuck":
        sk = mix32(key ^ _c(_STUCK))
        hit = gate if spec.always else (gate & (sk < thr))
        s1 = mix32(sk ^ _c(_T1))
        reg = (1 + ((s1 >> 8) % 15)).astype(I32)
        mask = jnp.left_shift(jnp.asarray(1, U32), s1 % 32).astype(I32)
        sel = (iota16 == reg[..., None]) & hit[..., None]
        stuck_one = (s1 >> 5) & 1
        forced = jnp.where((stuck_one == 1)[..., None],
                           regs | mask[..., None],
                           regs & ~mask[..., None])
        return jnp.where(sel, forced, regs), pc, mem

    # ---- transient: one draw per retired instruction
    k = mix32(key ^ mix32(_u(epoch)))
    h0 = mix32(k ^ _u(n_instr))
    fire = gate if spec.always else (gate & (h0 < thr))
    h1 = mix32(h0 ^ _c(_T1))
    h2 = mix32(h1 ^ _c(_T2))
    t = h1 % len(spec.targets)
    bit = h2 % 32
    bmask = jnp.left_shift(jnp.asarray(1, U32), bit).astype(I32)

    if "regs" in spec.targets:
        f = fire & (t == spec.targets.index("regs"))
        reg = (1 + ((h1 >> 8) % 15)).astype(I32)
        sel = (iota16 == reg[..., None]) & f[..., None]
        regs = jnp.where(sel, regs ^ bmask[..., None], regs)
    if "mem" in spec.targets:
        f = fire & (t == spec.targets.index("mem"))
        mwords = mem.shape[-1]
        ml = jnp.asarray(mwords, U32) if mem_len is None else _u(mem_len)
        word = ((h1 >> 8) % ml).astype(I32)
        iota_mem = jnp.arange(mwords, dtype=I32)
        wsel = (iota_mem == word[..., None]) & f[..., None]
        mem = jnp.where(wsel, mem ^ bmask[..., None], mem)
    if "pc" in spec.targets:
        f = fire & (t == spec.targets.index("pc"))
        pmask = jnp.left_shift(jnp.asarray(1, U32),
                               2 + (h2 % 10)).astype(I32)
        pc = jnp.where(f, pc ^ pmask, pc)
    return regs, pc, mem


def apply_faults(spec: Optional[FaultSpec], lane_key, epoch, state,
                 live=None, mem_len=None):
    """ISSState-level wrapper over `apply_fault_arrays`.

    `state` is an `iss.ISSState` (scalar or lane-batched) *after* its
    commit; `live` is the pre-step active mask (None: all live). The
    gate excludes post-commit halted lanes — the halting instruction's
    own flip window is unobservable. Returns the state with regs/pc/mem
    possibly flipped; everything else passes through.
    """
    if spec is None or spec.off:
        return state
    gate = ~state.halted if live is None else (live & ~state.halted)
    regs, pc, mem = apply_fault_arrays(
        spec, lane_key, epoch, state.regs, state.pc, state.mem,
        state.n_instr, gate, mem_len=mem_len)
    return state._replace(regs=regs, pc=pc, mem=mem)


def arch_digest(regs, pc, mem, halted, n_instr):
    """Per-lane 32-bit digest of the architectural state.

    The DMR boundary compare (fleet/engine.py): two lanes that executed
    the same item fault-free have equal digests; any surviving state
    corruption shows up as an inequality. Position-mixed so permuted
    corruption cannot cancel; uint32 sums wrap, which is fine — the
    digest is a determinism check, not cryptography.
    """
    rpos = mix32(_u(jnp.arange(16, dtype=I32)) + 1)
    mpos = mix32(_u(jnp.arange(mem.shape[-1], dtype=I32)) + 17)
    d = jnp.sum(mix32(_u(regs) ^ rpos), axis=-1)
    d = d + jnp.sum(mix32(_u(mem) ^ mpos), axis=-1)
    d = d + mix32(_u(pc) ^ _c(0x7FB5D329))
    d = d + mix32(_u(n_instr) ^ _c(0x2B7E1516))
    return d + halted.astype(U32)


# ---------------------------------------------------------------------------
# PyISS fault oracle (pure Python, bit-identical draws)
# ---------------------------------------------------------------------------


def _s32(v: int) -> int:
    v &= _MASK32
    return v - 0x100000000 if v >= 0x80000000 else v


class FaultOracle:
    """Post-commit hook for `pyiss.PyISS` — the fault oracle.

    Attach as ``p.post_commit = FaultOracle(spec, lane_key)``; PyISS
    calls it after every non-halting retired instruction, exactly where
    the jnp steppers apply `apply_fault_arrays`, with bit-identical
    draws. `fired` counts transient fires (for stuck/dead it is 1 per
    application while the lane defect is active).
    """

    def __init__(self, spec: FaultSpec, lane_key: int, epoch: int = 0):
        self.spec = spec
        self.lane_key = int(lane_key) & _MASK32
        self.epoch = int(epoch) & _MASK32
        self.fired = 0
        # per-lane (epoch-independent) defect decisions
        sk = mix32_py(self.lane_key ^ _STUCK)
        self._stuck = spec.mode == "stuck" and \
            (spec.always or sk < spec.threshold)
        s1 = mix32_py(sk ^ _T1)
        self._stuck_reg = 1 + ((s1 >> 8) % 15)
        self._stuck_mask = 1 << (s1 % 32)
        self._stuck_one = (s1 >> 5) & 1
        dk = mix32_py(self.lane_key ^ _DEAD)
        self._dead = spec.mode == "dead" and \
            (spec.always or dk < spec.threshold)

    def __call__(self, iss):
        spec = self.spec
        if spec.off:
            return
        if spec.mode == "dead":
            if self._dead:
                iss.regs = [0] * 16
                self.fired += 1
            return
        if spec.mode == "stuck":
            if self._stuck:
                r = self._stuck_reg
                w = iss.regs[r] & _MASK32
                w = (w | self._stuck_mask) if self._stuck_one \
                    else (w & ~self._stuck_mask)
                iss.regs[r] = _s32(w)
                self.fired += 1
            return
        # ---- transient
        k = mix32_py(self.lane_key ^ mix32_py(self.epoch))
        h0 = mix32_py(k ^ (iss.n_instr & _MASK32))
        if not spec.always and h0 >= spec.threshold:
            return
        self.fired += 1
        h1 = mix32_py(h0 ^ _T1)
        h2 = mix32_py(h1 ^ _T2)
        t = spec.targets[h1 % len(spec.targets)]
        bmask = 1 << (h2 % 32)
        if t == "regs":
            r = 1 + ((h1 >> 8) % 15)
            iss.regs[r] = _s32((iss.regs[r] & _MASK32) ^ bmask)
        elif t == "mem":
            w = (h1 >> 8) % len(iss.mem)
            iss.mem[w] = _s32((int(iss.mem[w]) & _MASK32) ^ bmask)
        else:  # pc: flip a word-aligned bit (2..11)
            iss.pc = _s32((iss.pc & _MASK32) ^ (1 << (2 + (h2 % 10))))


# ---------------------------------------------------------------------------
# Measurement: SDC / derating vs the golden fault-free PyISS run
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FaultReport:
    """Per-workload resilience rates (AVF-style, DESIGN.md §9.14).

    Of `exposed` trials (>= 1 fault actually fired), each is one of:
    `masked` (architecturally invisible — final memory and every
    FlexiLint-live register match the golden run), `derated` (a
    detectable deviation: halt status or retirement count differ — what
    a watchdog/budget check catches), or `sdc` (silent data corruption:
    the run completes on time but the visible state is wrong). Flips
    that only land in provably-dead registers (never read by any
    CFG-reachable instruction) are masked by construction of the
    comparison, not counted as corruption.
    """
    n_trials: int
    exposed: int
    masked: int
    derated: int
    sdc: int
    live_regs: Tuple[int, ...]

    @property
    def sdc_rate(self) -> float:
        return self.sdc / self.exposed if self.exposed else 0.0

    @property
    def derate_rate(self) -> float:
        return self.derated / self.exposed if self.exposed else 0.0

    @property
    def avf(self) -> float:
        """Architectural vulnerability: visible failures / exposures."""
        return (self.sdc + self.derated) / self.exposed \
            if self.exposed else 0.0


def measure_rates(code, mems, *, max_steps: int, spec: FaultSpec,
                  analysis=None) -> FaultReport:
    """Golden-vs-faulty differential over a batch of items.

    Runs every item twice through PyISS — fault-free and with the
    item's lane schedule (`lane_keys(spec.seed, n_items)[i]`, epoch 0)
    — and classifies each exposed trial per `FaultReport`. Register
    comparison is masked by FlexiLint liveness: only registers read by
    some reachable instruction (`analyze.read_registers`) count; a CFG
    degrade falls back to all 15 (conservative — nothing masked).
    """
    from repro.flexibits import analyze, pyiss

    code = np.asarray(code)
    mems = np.asarray(mems)
    n_items, mem_words = mems.shape
    if analysis is None:
        analysis = analyze.analyze_code(code, mem_words)
    if analysis.degraded:
        live = tuple(range(1, 16))
    else:
        live = tuple(sorted(analyze.read_registers(analysis)))
    keys = lane_keys(spec.seed, n_items)

    exposed = masked = derated = sdc = 0
    for i in range(n_items):
        golden = pyiss.PyISS(code, mem_words, init_mem=mems[i])
        golden.run(max_steps)
        faulty = pyiss.PyISS(code, mem_words, init_mem=mems[i])
        oracle = FaultOracle(spec, int(keys[i]))
        faulty.post_commit = oracle
        faulty.run(max_steps)
        if oracle.fired == 0:
            continue
        exposed += 1
        if golden.halted != faulty.halted \
                or golden.n_instr != faulty.n_instr:
            derated += 1
        elif np.array_equal(golden.mem, faulty.mem) and all(
                golden.regs[r] == faulty.regs[r] for r in live):
            masked += 1
        else:
            sdc += 1
    return FaultReport(n_trials=n_items, exposed=exposed, masked=masked,
                       derated=derated, sdc=sdc, live_regs=live)
