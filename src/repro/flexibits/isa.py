"""RV32E instruction encoding/decoding tables.

RV32E = RV32I with 16 registers (x0..x15). We implement the full base
integer set the paper's workloads use (no M/F/D extensions — multiplies are
software shift-add routines, as in the paper §3.2.1).

Instruction classes for the bit-serial cycle model (paper §4.2):
  one-stage: R-type, most I-type ALU ops         (32/w + a_w cycles)
  two-stage: loads/stores/jumps/branches/shifts/slt (64/w + b_w cycles)
"""
from __future__ import annotations

from typing import Dict, Tuple

# opcode constants
OP_LUI = 0b0110111
OP_AUIPC = 0b0010111
OP_JAL = 0b1101111
OP_JALR = 0b1100111
OP_BRANCH = 0b1100011
OP_LOAD = 0b0000011
OP_STORE = 0b0100011
OP_IMM = 0b0010011
OP_REG = 0b0110011
OP_SYSTEM = 0b1110011

ABI = {
    "zero": 0, "ra": 1, "sp": 2, "gp": 3, "tp": 4,
    "t0": 5, "t1": 6, "t2": 7, "s0": 8, "fp": 8, "s1": 9,
    "a0": 10, "a1": 11, "a2": 12, "a3": 13, "a4": 14, "a5": 15,
}

# name -> (opcode, funct3, funct7 or None)
R_OPS = {
    "add": (OP_REG, 0b000, 0b0000000), "sub": (OP_REG, 0b000, 0b0100000),
    "sll": (OP_REG, 0b001, 0b0000000), "slt": (OP_REG, 0b010, 0b0000000),
    "sltu": (OP_REG, 0b011, 0b0000000), "xor": (OP_REG, 0b100, 0b0000000),
    "srl": (OP_REG, 0b101, 0b0000000), "sra": (OP_REG, 0b101, 0b0100000),
    "or": (OP_REG, 0b110, 0b0000000), "and": (OP_REG, 0b111, 0b0000000),
}
I_OPS = {
    "addi": (OP_IMM, 0b000), "slti": (OP_IMM, 0b010),
    "sltiu": (OP_IMM, 0b011), "xori": (OP_IMM, 0b100),
    "ori": (OP_IMM, 0b110), "andi": (OP_IMM, 0b111),
    "jalr": (OP_JALR, 0b000),
    "lb": (OP_LOAD, 0b000), "lh": (OP_LOAD, 0b001), "lw": (OP_LOAD, 0b010),
    "lbu": (OP_LOAD, 0b100), "lhu": (OP_LOAD, 0b101),
}
SHIFT_OPS = {
    "slli": (OP_IMM, 0b001, 0b0000000),
    "srli": (OP_IMM, 0b101, 0b0000000),
    "srai": (OP_IMM, 0b101, 0b0100000),
}
S_OPS = {"sb": (OP_STORE, 0b000), "sh": (OP_STORE, 0b001),
         "sw": (OP_STORE, 0b010)}
B_OPS = {"beq": (OP_BRANCH, 0b000), "bne": (OP_BRANCH, 0b001),
         "blt": (OP_BRANCH, 0b100), "bge": (OP_BRANCH, 0b101),
         "bltu": (OP_BRANCH, 0b110), "bgeu": (OP_BRANCH, 0b111)}

# two-stage instruction names (paper §4.2): loads, stores, jumps, branches,
# shifts, set-less-than.
TWO_STAGE = (set(S_OPS) | set(B_OPS) | set(SHIFT_OPS)
             | {"lb", "lh", "lw", "lbu", "lhu", "jal", "jalr",
                "slt", "sltu", "slti", "sltiu", "sll", "srl", "sra"})

# instruction-mix categories for the Fig. 2a reproduction
MIX_CATEGORY = {}
for _n in R_OPS:
    MIX_CATEGORY[_n] = "shifts" if _n in ("sll", "srl", "sra") else "R-type"
for _n in ("addi", "slti", "sltiu", "xori", "ori", "andi"):
    MIX_CATEGORY[_n] = "I-type"
for _n in SHIFT_OPS:
    MIX_CATEGORY[_n] = "shifts"
for _n in ("lb", "lh", "lw", "lbu", "lhu"):
    MIX_CATEGORY[_n] = "loads"
for _n in S_OPS:
    MIX_CATEGORY[_n] = "stores"
for _n in B_OPS:
    MIX_CATEGORY[_n] = "branches"
for _n in ("jal", "jalr"):
    MIX_CATEGORY[_n] = "jumps"
MIX_CATEGORY["lui"] = "I-type"
MIX_CATEGORY["auipc"] = "I-type"
MIX_CATEGORY["ecall"] = "system"


def _imm_i(v: int) -> int:
    return (v & 0xFFF) << 20


def encode(name: str, rd=0, rs1=0, rs2=0, imm=0) -> int:
    if name in R_OPS:
        op, f3, f7 = R_OPS[name]
        return (f7 << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) \
            | (rd << 7) | op
    if name in SHIFT_OPS:
        op, f3, f7 = SHIFT_OPS[name]
        return (f7 << 25) | ((imm & 0x1F) << 20) | (rs1 << 15) | (f3 << 12) \
            | (rd << 7) | op
    if name in I_OPS:
        op, f3 = I_OPS[name]
        return _imm_i(imm) | (rs1 << 15) | (f3 << 12) | (rd << 7) | op
    if name in S_OPS:
        op, f3 = S_OPS[name]
        lo = imm & 0x1F
        hi = (imm >> 5) & 0x7F
        return (hi << 25) | (rs2 << 20) | (rs1 << 15) | (f3 << 12) \
            | (lo << 7) | op
    if name in B_OPS:
        op, f3 = B_OPS[name]
        b12 = (imm >> 12) & 1
        b11 = (imm >> 11) & 1
        b10_5 = (imm >> 5) & 0x3F
        b4_1 = (imm >> 1) & 0xF
        return (b12 << 31) | (b10_5 << 25) | (rs2 << 20) | (rs1 << 15) \
            | (f3 << 12) | (b4_1 << 8) | (b11 << 7) | op
    if name == "lui":
        return ((imm & 0xFFFFF) << 12) | (rd << 7) | OP_LUI
    if name == "auipc":
        return ((imm & 0xFFFFF) << 12) | (rd << 7) | OP_AUIPC
    if name == "jal":
        b20 = (imm >> 20) & 1
        b10_1 = (imm >> 1) & 0x3FF
        b11 = (imm >> 11) & 1
        b19_12 = (imm >> 12) & 0xFF
        return (b20 << 31) | (b10_1 << 21) | (b11 << 20) | (b19_12 << 12) \
            | (rd << 7) | OP_JAL
    if name == "ecall":
        return OP_SYSTEM
    if name == "ebreak":
        return (1 << 20) | OP_SYSTEM
    raise ValueError(f"unknown instruction {name!r}")


ALL_OPS: Tuple[str, ...] = tuple(
    list(R_OPS) + list(I_OPS) + list(SHIFT_OPS) + list(S_OPS) + list(B_OPS)
    + ["lui", "auipc", "jal", "ecall", "ebreak"])
