"""RV32E assembler eDSL.

The paper compiles C with the RISC-V GNU toolchain; this container has no
offline toolchain, so FlexiBench workloads are written against this small
assembler instead (DESIGN.md §8.2). It provides labels, pseudo-ops and the
software multiply/divide routines (RV32E has no M extension — multiplies are
shift-add loops, exactly the behavior the paper characterizes in §3.2.2).

Memory map (word-addressed data RAM, byte addresses):
  0x0000.. : data RAM (inputs, globals, scratch)    [VM]
  ROM      : program words + constant words          [NVM]
Constants are placed in a read-only segment appended after the data image;
`Program.nvm_words`/`vm_bytes` feed the Table-3 memory profile.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.flexibits import isa

# Canonical RV32E register display names, indexed by register number.
REG_NAMES = ("zero", "ra", "sp", "gp", "tp", "t0", "t1", "t2",
             "s0", "s1", "a0", "a1", "a2", "a3", "a4", "a5")

# A decoded instruction word in *canonical operand form*: the exact
# (name, rd, rs1, rs2, imm) tuple that `isa.encode` accepts, chosen so
# `isa.encode(*d) == word` for every decodable word (register fields not
# used by the format are zeroed; immediates are sign-extended the way the
# steppers see them; shift immediates are the 5-bit shamt).
Decoded = collections.namedtuple("Decoded", "name rd rs1 rs2 imm")

_R_BY_KEY = {(f3, f7): n for n, (_, f3, f7) in isa.R_OPS.items()}
_SHIFT_BY_KEY = {(f3, f7): n for n, (_, f3, f7) in isa.SHIFT_OPS.items()}
_I_BY_KEY = {(op, f3): n for n, (op, f3) in isa.I_OPS.items()}
_S_BY_F3 = {f3: n for n, (_, f3) in isa.S_OPS.items()}
_B_BY_F3 = {f3: n for n, (_, f3) in isa.B_OPS.items()}
_LOAD_NAMES = frozenset(("lb", "lh", "lw", "lbu", "lhu"))


def _sx(v: int, bits: int) -> int:
    v &= (1 << bits) - 1
    return v - (1 << bits) if v >= (1 << (bits - 1)) else v


def decode(word: int) -> Optional[Decoded]:
    """Word -> canonical `Decoded`, or None for words outside the ISA
    (unknown opcode, non-canonical funct3/funct7). Round-trip property:
    `isa.encode(*decode(w)) == w` whenever decode(w) is not None."""
    w = int(word) & 0xFFFFFFFF
    op = w & 0x7F
    rd = (w >> 7) & 0x1F
    f3 = (w >> 12) & 0x7
    rs1 = (w >> 15) & 0x1F
    rs2 = (w >> 20) & 0x1F
    f7 = (w >> 25) & 0x7F
    if op == isa.OP_REG:
        name = _R_BY_KEY.get((f3, f7))
        return None if name is None else Decoded(name, rd, rs1, rs2, 0)
    if op == isa.OP_IMM and f3 in (1, 5):
        name = _SHIFT_BY_KEY.get((f3, f7))
        # shamt lives in the rs2 field
        return None if name is None else Decoded(name, rd, rs1, 0, rs2)
    if op in (isa.OP_IMM, isa.OP_JALR, isa.OP_LOAD):
        name = _I_BY_KEY.get((op, f3))
        return None if name is None \
            else Decoded(name, rd, rs1, 0, _sx(w >> 20, 12))
    if op == isa.OP_STORE:
        name = _S_BY_F3.get(f3)
        return None if name is None \
            else Decoded(name, 0, rs1, rs2, _sx(((w >> 25) << 5) | rd, 12))
    if op == isa.OP_BRANCH:
        name = _B_BY_F3.get(f3)
        if name is None:
            return None
        imm = _sx((((w >> 31) & 1) << 12) | (((w >> 7) & 1) << 11)
                  | (((w >> 25) & 0x3F) << 5) | (((w >> 8) & 0xF) << 1), 13)
        return Decoded(name, 0, rs1, rs2, imm)
    if op == isa.OP_LUI:
        return Decoded("lui", rd, 0, 0, (w >> 12) & 0xFFFFF)
    if op == isa.OP_AUIPC:
        return Decoded("auipc", rd, 0, 0, (w >> 12) & 0xFFFFF)
    if op == isa.OP_JAL:
        imm = _sx((((w >> 31) & 1) << 20) | (((w >> 12) & 0xFF) << 12)
                  | (((w >> 20) & 1) << 11) | (((w >> 21) & 0x3FF) << 1), 21)
        return Decoded("jal", rd, 0, 0, imm)
    if op == isa.OP_SYSTEM:
        if w == isa.encode("ecall"):
            return Decoded("ecall", 0, 0, 0, 0)
        if w == isa.encode("ebreak"):
            return Decoded("ebreak", 0, 0, 0, 0)
        return None
    return None


def _reg(r: int) -> str:
    return REG_NAMES[r] if r < len(REG_NAMES) else f"x{r}"


def disasm(word: int) -> str:
    """Word -> one-line mnemonic/operand string (FlexiLint diagnostics,
    PyISS trace dumps). Undecodable words render as `.word 0x........`."""
    d = decode(word)
    if d is None:
        return f".word 0x{int(word) & 0xFFFFFFFF:08x}"
    n = d.name
    if n in isa.R_OPS:
        return f"{n} {_reg(d.rd)}, {_reg(d.rs1)}, {_reg(d.rs2)}"
    if n in isa.SHIFT_OPS:
        return f"{n} {_reg(d.rd)}, {_reg(d.rs1)}, {d.imm}"
    if n in _LOAD_NAMES or n == "jalr":
        return f"{n} {_reg(d.rd)}, {d.imm}({_reg(d.rs1)})"
    if n in isa.I_OPS:
        return f"{n} {_reg(d.rd)}, {_reg(d.rs1)}, {d.imm}"
    if n in isa.S_OPS:
        return f"{n} {_reg(d.rs2)}, {d.imm}({_reg(d.rs1)})"
    if n in isa.B_OPS:
        return f"{n} {_reg(d.rs1)}, {_reg(d.rs2)}, pc{d.imm:+d}"
    if n in ("lui", "auipc"):
        return f"{n} {_reg(d.rd)}, 0x{d.imm:05x}"
    if n == "jal":
        return f"jal {_reg(d.rd)}, pc{d.imm:+d}"
    return n                                    # ecall / ebreak


@dataclasses.dataclass
class Program:
    code: np.ndarray            # uint32 instruction words
    names: List[str]            # mnemonic per instruction (for mix stats)
    ro_base: int                # byte address where constants start
    ro_words: np.ndarray        # int32 read-only constant words
    vm_reserved: int            # bytes of RAM reserved (inputs+globals)
    labels: Dict[str, int]
    # word index of a loop header -> max executions of that header per
    # program entry (FlexiLint WCET annotations, DESIGN.md §9.11)
    loop_bounds: Dict[int, int] = dataclasses.field(default_factory=dict)

    @property
    def nvm_bytes(self) -> int:
        """Program + constants (paper: .text + .rodata)."""
        return 4 * (len(self.code) + len(self.ro_words))

    def initial_memory(self, mem_words: int) -> np.ndarray:
        mem = np.zeros(mem_words, np.int32)
        ro = self.ro_base // 4
        assert ro + len(self.ro_words) <= mem_words, "constants overflow RAM"
        mem[ro:ro + len(self.ro_words)] = self.ro_words
        return mem


class Asm:
    """Builder: emit instructions, labels, and constant data."""

    def __init__(self, vm_reserved: int = 0):
        self._instrs: List[Tuple] = []       # (name, rd, rs1, rs2, imm|label)
        self._labels: Dict[str, int] = {}
        self._consts: List[int] = []
        self._vm_reserved = vm_reserved
        self._uniq = 0
        self._loop_bounds: Dict[str, int] = {}   # label -> max executions

    def loop_bound(self, label: str, max_iters: int):
        """Annotate `label` (a loop header) with its maximum number of
        executions per program entry. FlexiLint uses these bounds for
        loops whose trip count it cannot infer from counter idioms
        (DESIGN.md §9.11); unannotated uninferable loops make the WCET
        unbounded."""
        assert max_iters >= 1, max_iters
        self._loop_bounds[label] = int(max_iters)

    # ---- registers by ABI name
    def __getattr__(self, item):
        if item in isa.ABI:
            return isa.ABI[item]
        raise AttributeError(item)

    def uniq(self, prefix="L") -> str:
        self._uniq += 1
        return f"{prefix}_{self._uniq}"

    def label(self, name: str):
        self._labels[name] = len(self._instrs)

    def emit(self, name, rd=0, rs1=0, rs2=0, imm=0):
        self._instrs.append((name, rd, rs1, rs2, imm))

    # ---- raw instructions
    def add(self, rd, rs1, rs2):
        self.emit("add", rd, rs1, rs2)

    def sub(self, rd, rs1, rs2):
        self.emit("sub", rd, rs1, rs2)

    def sll(self, rd, rs1, rs2):
        self.emit("sll", rd, rs1, rs2)

    def srl(self, rd, rs1, rs2):
        self.emit("srl", rd, rs1, rs2)

    def sra(self, rd, rs1, rs2):
        self.emit("sra", rd, rs1, rs2)

    def slt(self, rd, rs1, rs2):
        self.emit("slt", rd, rs1, rs2)

    def sltu(self, rd, rs1, rs2):
        self.emit("sltu", rd, rs1, rs2)

    def xor(self, rd, rs1, rs2):
        self.emit("xor", rd, rs1, rs2)

    def or_(self, rd, rs1, rs2):
        self.emit("or", rd, rs1, rs2)

    def and_(self, rd, rs1, rs2):
        self.emit("and", rd, rs1, rs2)

    def addi(self, rd, rs1, imm):
        assert -2048 <= imm < 2048, imm
        self.emit("addi", rd, rs1, imm=imm)

    def slti(self, rd, rs1, imm):
        self.emit("slti", rd, rs1, imm=imm)

    def xori(self, rd, rs1, imm):
        self.emit("xori", rd, rs1, imm=imm)

    def ori(self, rd, rs1, imm):
        self.emit("ori", rd, rs1, imm=imm)

    def andi(self, rd, rs1, imm):
        self.emit("andi", rd, rs1, imm=imm)

    def slli(self, rd, rs1, imm):
        self.emit("slli", rd, rs1, imm=imm)

    def srli(self, rd, rs1, imm):
        self.emit("srli", rd, rs1, imm=imm)

    def srai(self, rd, rs1, imm):
        self.emit("srai", rd, rs1, imm=imm)

    def lw(self, rd, rs1, imm=0):
        self.emit("lw", rd, rs1, imm=imm)

    def sw(self, rs2, rs1, imm=0):
        self.emit("sw", 0, rs1, rs2, imm)

    def lui(self, rd, imm):
        self.emit("lui", rd, imm=imm)

    def beq(self, rs1, rs2, label):
        self.emit("beq", 0, rs1, rs2, label)

    def bne(self, rs1, rs2, label):
        self.emit("bne", 0, rs1, rs2, label)

    def blt(self, rs1, rs2, label):
        self.emit("blt", 0, rs1, rs2, label)

    def bge(self, rs1, rs2, label):
        self.emit("bge", 0, rs1, rs2, label)

    def bltu(self, rs1, rs2, label):
        self.emit("bltu", 0, rs1, rs2, label)

    def bgeu(self, rs1, rs2, label):
        self.emit("bgeu", 0, rs1, rs2, label)

    def jal(self, rd, label):
        self.emit("jal", rd, imm=label)

    def jalr(self, rd, rs1, imm=0):
        self.emit("jalr", rd, rs1, imm=imm)

    def ecall(self):
        self.emit("ecall")

    # ---- pseudo-ops
    def li(self, rd, value: int):
        value &= 0xFFFFFFFF
        if value >= 0x80000000:
            value -= 1 << 32
        if -2048 <= value < 2048:
            self.addi(rd, 0, value)
            return
        upper = (value + 0x800) >> 12
        lower = value - (upper << 12)
        self.lui(rd, upper & 0xFFFFF)
        if lower:
            self.addi(rd, rd, lower)

    def mv(self, rd, rs):
        self.addi(rd, rs, 0)

    def j(self, label):
        self.jal(0, label)

    def call(self, label):
        self.jal(1, label)          # ra = x1

    def ret(self):
        self.jalr(0, 1, 0)

    def halt(self):
        self.ecall()

    # ---- constant data segment
    def const_words(self, values) -> int:
        """Append int32 words to the read-only segment; returns word offset
        within the segment (byte address resolved at assembly)."""
        off = len(self._consts)
        self._consts.extend(int(v) for v in np.asarray(values, np.int64))
        return off

    def la_const(self, rd, word_offset: int):
        """Load address of constant segment + word offset (resolved late)."""
        self.emit("__la_const", rd, imm=word_offset)

    # ---- software multiply: a0 = a0 * a1 (signed, 32-bit wrap)
    # Registers t0..t2 clobbered. Shift-add, ~32 iterations.
    def emit_mul_routine(self):
        self.label("__mul")
        self.mv(self.t0, self.a0)       # multiplicand
        self.mv(self.t1, self.a1)       # multiplier
        self.li(self.a0, 0)
        loop = "__mul_loop"
        done = "__mul_done"
        skip = "__mul_skip"
        # 32 multiplier bits + the final zero-test pass
        self.loop_bound(loop, 33)
        self.label(loop)
        self.beq(self.t1, self.zero, done)
        self.andi(self.t2, self.t1, 1)
        self.beq(self.t2, self.zero, skip)
        self.add(self.a0, self.a0, self.t0)
        self.label(skip)
        self.slli(self.t0, self.t0, 1)
        self.srli(self.t1, self.t1, 1)
        self.j(loop)
        self.label(done)
        self.ret()

    def mul(self, rd, rs1, rs2):
        """Call the software multiply (must emit_mul_routine once)."""
        self.mv(self.a0, rs1)
        self.mv(self.a1, rs2)
        self.call("__mul")
        if rd != isa.ABI["a0"]:
            self.mv(rd, self.a0)

    # ---- assemble
    def assemble(self, ro_base: Optional[int] = None) -> Program:
        if ro_base is None:
            ro_base = self._vm_reserved
        ro_base = -(-ro_base // 4) * 4
        code = []
        names = []
        resolved: List[Tuple] = []
        # first expand __la_const into li (needs final addresses — two-pass
        # with fixed expansion size: li = lui+addi always (2 instrs))
        expanded: List[Tuple] = []
        label_pos: Dict[str, int] = {}
        # pass 1: compute positions with fixed sizes
        pos = 0
        pending = dict(self._labels)
        # labels were recorded by instruction index; recompute by walking
        idx2pos: List[int] = []
        for ins in self._instrs:
            idx2pos.append(pos)
            pos += 2 if ins[0] == "__la_const" else 1
        final_labels = {k: idx2pos[v] if v < len(idx2pos) else pos
                        for k, v in pending.items()}
        # pass 2: emit
        for name, rd, rs1, rs2, imm in self._instrs:
            if name == "__la_const":
                addr = ro_base + 4 * imm
                upper = ((addr + 0x800) >> 12) & 0xFFFFF
                lower = addr - ((addr + 0x800) >> 12 << 12)
                expanded.append(("lui", rd, 0, 0, upper))
                expanded.append(("addi", rd, rd, 0, lower))
            else:
                expanded.append((name, rd, rs1, rs2, imm))
        for i, (name, rd, rs1, rs2, imm) in enumerate(expanded):
            if isinstance(imm, str):
                target = final_labels[imm]
                offset = (target - i) * 4
                imm = offset
            if name in ("addi",) and not (-2048 <= imm < 2048):
                raise ValueError(f"addi imm out of range at {i}: {imm}")
            code.append(isa.encode(name, rd, rs1, rs2, imm))
            names.append(name)
        loop_bounds = {final_labels[lbl]: b
                       for lbl, b in self._loop_bounds.items()
                       if lbl in final_labels}
        return Program(
            code=np.asarray(code, np.uint32),
            names=names,
            ro_base=ro_base,
            ro_words=np.asarray(self._consts, np.int32),
            vm_reserved=self._vm_reserved,
            labels=final_labels,
            loop_bounds=loop_bounds,
        )
