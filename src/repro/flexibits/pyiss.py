"""Pure-Python RV32E instruction-set simulator — the oracle for the JAX ISS
property tests (spike-equivalent for our subset).

Also the *cycle* oracle for the timing layer (DESIGN.md §9.10): every
step records core-independent timing events (`events`, the dual of
`cycles.cost_row`) — per-(stage, mix-class) retirements, taken
branches, total serial shift amount, subword memory ops — so one
profiling run prices a program on any core via a dot product. With a
`cost` row the oracle additionally accumulates `n_cycles` exactly as
the JAX steppers do, int32 wrap included.

Memory follows the JAX steppers' out-of-range contract: reads clamp to
the last word, writes past the end drop (the jax gather/scatter
semantics every stepper reproduces). Word indices are computed through
the same int32 reinterpretation the steppers use, so the differential
tests can compare the two bit-for-bit on OOB-touching programs
(addresses with bit 31 set are outside the contract, as in iss.py).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np

from repro.flexibits import isa
from repro.flexibits.asm import disasm
from repro.flexibits.cycles import (MIX_CLASSES, N_COST, SHIFT_IDX,
                                    SUBWORD_IDX, TAKEN_IDX)

_MIX_IDX = {c: i for i, c in enumerate(MIX_CLASSES)}
_N_MIX = len(MIX_CLASSES)
_SUBWORD_NAMES = frozenset(("lb", "lh", "lbu", "lhu", "sb", "sh"))


def _sx(v: int, bits: int) -> int:
    v &= (1 << bits) - 1
    return v - (1 << bits) if v >= (1 << (bits - 1)) else v


def _u32(v: int) -> int:
    return v & 0xFFFFFFFF


def _s32(v: int) -> int:
    return _sx(v, 32)


class PyISS:
    def __init__(self, code: np.ndarray, mem_words: int = 4096,
                 init_mem: Optional[np.ndarray] = None,
                 cost: Optional[np.ndarray] = None,
                 trace_len: int = 0):
        self.code = np.asarray(code, np.uint32)
        self.mem = np.zeros(mem_words, np.int64)
        if init_mem is not None:
            self.mem[:len(init_mem)] = np.asarray(init_mem, np.int64)
        self.regs = [0] * 16
        self.pc = 0
        self.halted = False
        self.n_instr = 0
        self.mix: Dict[str, int] = {}
        self.n_two_stage = 0
        self.max_sp_used = None
        self.events = np.zeros(N_COST, np.int64)
        self.cost = None if cost is None else np.asarray(cost, np.int64)
        self.n_cycles = 0
        # FlexiLint cross-validation (DESIGN.md §9.11): every retired
        # word index, plus an optional ring of the last `trace_len`
        # (pc, word) pairs for disassembled trace dumps
        self.visited: set = set()
        self._trace_len = int(trace_len)
        self.trace: list = []
        # FlexiFault oracle hook (DESIGN.md §9.14): called with `self`
        # after every retired instruction that did not halt the machine
        # — the exact point the JAX steppers apply their post-commit
        # fault transform (faults.apply_fault_arrays)
        self.post_commit = None

    def _widx(self, addr: int) -> int:
        # the steppers' word index: uint32 address reinterpreted int32,
        # then arithmetic >> 2
        return _s32(addr) >> 2

    def _load_word(self, addr: int) -> int:
        widx = max(0, min(self._widx(addr), len(self.mem) - 1))
        return _s32(int(self.mem[widx]))

    def _store_word(self, addr: int, val: int):
        widx = self._widx(addr)
        if 0 <= widx < len(self.mem):
            self.mem[widx] = _s32(val)

    def _load_sub(self, addr: int, nbytes: int, signed: bool) -> int:
        w = _u32(self._load_word(addr & ~3))
        # halfword ports are aligned to addr & ~1, as in the steppers
        # (the serial cores have no misaligned-access machinery)
        sh = ((addr & 3) if nbytes == 1 else (addr & 2)) * 8
        v = (w >> sh) & ((1 << (nbytes * 8)) - 1)
        return _sx(v, nbytes * 8) if signed else v

    def _store_sub(self, addr: int, nbytes: int, val: int):
        w = _u32(self._load_word(addr & ~3))
        sh = ((addr & 3) if nbytes == 1 else (addr & 2)) * 8
        mask = ((1 << (nbytes * 8)) - 1) << sh
        w = (w & ~mask) | ((_u32(val) << sh) & mask)
        self._store_word(addr & ~3, w)

    def format_trace(self) -> str:
        """Disassembled dump of the retired-instruction ring (requires
        trace_len > 0 at construction)."""
        return "\n".join(f"pc={pc:#07x} word {pc >> 2:4d}: {disasm(w)}"
                         for pc, w in self.trace)

    def step(self):
        # clamp-on-read fetch, mirroring jax gather semantics in the jnp
        # steppers (only reachable with a faulted pc — §9.14; fault-free
        # programs never leave the code image)
        widx = self.pc >> 2
        widx = 0 if widx < 0 else min(widx, len(self.code) - 1)
        self.visited.add(widx)
        instr = int(self.code[widx])
        if self._trace_len:
            self.trace.append((self.pc, instr))
            if len(self.trace) > self._trace_len:
                del self.trace[0]
        op = instr & 0x7F
        rd = (instr >> 7) & 0x1F
        f3 = (instr >> 12) & 0x7
        rs1 = (instr >> 15) & 0x1F
        rs2 = (instr >> 20) & 0x1F
        f7 = (instr >> 25) & 0x7F
        imm_i = _sx(instr >> 20, 12)
        imm_s = _sx(((instr >> 25) << 5) | ((instr >> 7) & 0x1F), 12)
        imm_b = _sx((((instr >> 31) & 1) << 12) | (((instr >> 7) & 1) << 11)
                    | (((instr >> 25) & 0x3F) << 5)
                    | (((instr >> 8) & 0xF) << 1), 13)
        imm_u = _s32(instr & 0xFFFFF000)
        imm_j = _sx((((instr >> 31) & 1) << 20)
                    | (((instr >> 12) & 0xFF) << 12)
                    | (((instr >> 20) & 1) << 11)
                    | (((instr >> 21) & 0x3FF) << 1), 21)
        a = _s32(self.regs[rs1 & 0xF])
        b = _s32(self.regs[rs2 & 0xF])
        next_pc = self.pc + 4
        wr = None
        name = "?"
        taken = False          # branch condition held (dynamic timing)
        shamt = 0              # serial shift amount (dynamic timing)

        if op == isa.OP_LUI:
            wr, name = imm_u, "lui"
        elif op == isa.OP_AUIPC:
            wr, name = _s32(self.pc + imm_u), "auipc"
        elif op == isa.OP_JAL:
            wr, name = self.pc + 4, "jal"
            next_pc = self.pc + imm_j
        elif op == isa.OP_JALR:
            wr, name = self.pc + 4, "jalr"
            next_pc = _u32(a + imm_i) & ~1
        elif op == isa.OP_BRANCH:
            cond = {0: a == b, 1: a != b, 4: a < b, 5: a >= b,
                    6: _u32(a) < _u32(b), 7: _u32(a) >= _u32(b)}[f3]
            name = {0: "beq", 1: "bne", 4: "blt", 5: "bge", 6: "bltu",
                    7: "bgeu"}[f3]
            taken = bool(cond)
            if cond:
                next_pc = self.pc + imm_b
        elif op == isa.OP_LOAD:
            addr = _u32(a + imm_i)
            if f3 == 0:
                wr, name = self._load_sub(addr, 1, True), "lb"
            elif f3 == 1:
                wr, name = self._load_sub(addr, 2, True), "lh"
            elif f3 == 2:
                wr, name = self._load_word(addr), "lw"
            elif f3 == 4:
                wr, name = self._load_sub(addr, 1, False), "lbu"
            elif f3 == 5:
                wr, name = self._load_sub(addr, 2, False), "lhu"
        elif op == isa.OP_STORE:
            addr = _u32(a + imm_s)
            if f3 == 0:
                self._store_sub(addr, 1, b)
                name = "sb"
            elif f3 == 1:
                self._store_sub(addr, 2, b)
                name = "sh"
            else:
                self._store_word(addr, b)
                name = "sw"
        elif op == isa.OP_IMM:
            if f3 == 0:
                wr, name = _s32(a + imm_i), "addi"
            elif f3 == 1:
                shamt = imm_i & 31
                wr, name = _s32(a << shamt), "slli"
            elif f3 == 2:
                wr, name = int(a < imm_i), "slti"
            elif f3 == 3:
                wr, name = int(_u32(a) < _u32(imm_i)), "sltiu"
            elif f3 == 4:
                wr, name = _s32(a ^ imm_i), "xori"
            elif f3 == 5:
                shamt = imm_i & 31
                if f7 & 0x20:
                    wr, name = a >> shamt, "srai"
                else:
                    wr, name = _s32(_u32(a) >> shamt), "srli"
            elif f3 == 6:
                wr, name = _s32(a | imm_i), "ori"
            elif f3 == 7:
                wr, name = _s32(a & imm_i), "andi"
        elif op == isa.OP_REG:
            sub = bool(f7 & 0x20)
            if f3 == 0:
                wr, name = _s32(a - b if sub else a + b), \
                    ("sub" if sub else "add")
            elif f3 == 1:
                shamt = b & 31
                wr, name = _s32(a << shamt), "sll"
            elif f3 == 2:
                wr, name = int(a < b), "slt"
            elif f3 == 3:
                wr, name = int(_u32(a) < _u32(b)), "sltu"
            elif f3 == 4:
                wr, name = _s32(a ^ b), "xor"
            elif f3 == 5:
                shamt = b & 31
                if sub:
                    wr, name = a >> shamt, "sra"
                else:
                    wr, name = _s32(_u32(a) >> shamt), "srl"
            elif f3 == 6:
                wr, name = _s32(a | b), "or"
            elif f3 == 7:
                wr, name = _s32(a & b), "and"
        elif op == isa.OP_SYSTEM:
            name = "ecall"
            self.halted = True
        else:
            raise ValueError(f"bad opcode {op:#x} at pc={self.pc}")

        if wr is not None and (rd & 0xF) != 0:
            self.regs[rd & 0xF] = _s32(wr)
        self.pc = next_pc
        self.n_instr += 1
        self.mix[name] = self.mix.get(name, 0) + 1
        two = name in isa.TWO_STAGE
        if two:
            self.n_two_stage += 1

        # ---- timing events (mirror of iss.dynamic_terms/timing_ticks)
        subword = name in _SUBWORD_NAMES
        cls = (_N_MIX if two else 0) + _MIX_IDX[isa.MIX_CATEGORY[name]]
        self.events[cls] += 1
        if taken:
            self.events[TAKEN_IDX] += 1
        self.events[SHIFT_IDX] += shamt
        if subword:
            self.events[SUBWORD_IDX] += 1
        if self.cost is not None:
            ticks = int(self.cost[cls])
            if taken:
                ticks += int(self.cost[TAKEN_IDX])
            ticks += shamt * int(self.cost[SHIFT_IDX])
            if subword:
                ticks += int(self.cost[SUBWORD_IDX])
            # the steppers tally in int32; wrap identically
            self.n_cycles = _s32(self.n_cycles + ticks)

        if self.post_commit is not None and not self.halted:
            self.post_commit(self)

    def ticks(self, cost: np.ndarray) -> int:
        """Total ticks under `cost` from the recorded events (exact,
        no wrap) — prices one run on any core after the fact."""
        return int(np.asarray(cost, np.int64) @ self.events)

    def run(self, max_steps: int = 10_000_000):
        while not self.halted and self.n_instr < max_steps:
            self.step()
        return self
