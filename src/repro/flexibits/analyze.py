"""FlexiLint — static binary analysis of FlexiBits programs
(DESIGN.md §9.11).

Everything the runtime discovers dynamically about a program — which
opcode classes can retire (`iss.opcode_subset`), how many steps an item
needs (`max_steps` budgets), how many ticks an execution costs (the
§9.10 timing layer) — this module derives *statically* from the encoded
words, as proven properties instead of point measurements:

  * CFG recovery over the instruction words: word-level control-flow
    graph from decoded branch/JAL targets, interprocedural via a
    ra-discipline model of JALR returns, with explicit *degraded mode*
    (everything-reachable over-approximation) for programs the word
    model cannot represent exactly (indirect jumps, misaligned or
    out-of-code transfers, undecodable reachable words).
  * Dataflow diagnostics: definite-assignment (read-before-write =
    error), backward liveness (dead store = warning), unreachable code
    and unreachable-HALT checks.
  * Interval analysis proving load/store addresses against `mem_words`
    where they are affine in constants; the rest is flagged
    runtime-clamped (the steppers' clamp-on-read / drop-on-write
    contract makes every access architecturally defined either way).
  * Reachable opcode subset + static opcode-class mix, a sound input to
    the steppers' subset DCE (`step_branchless(subset=...)`, the packed
    engine's union subset): only reachable words can ever retire live —
    halted lanes keep fetching but every commit is `live`-masked.
  * WCET: per-function longest path with loop SCCs collapsed under
    trip-count bounds (annotated via `Asm.loop_bound` or inferred from
    `addi`-counter branch idioms), generic over a per-word weight — so
    the same machinery yields worst-case *instruction counts* (to
    validate/derive `max_steps`) and worst-case *ticks* under any
    §9.10 cost row (certified energy/carbon in `core/carbon.py`).

Soundness contract (pinned by tests/test_flexilint.py against PyISS):
every dynamically retired pc lies in `reachable`; every retired opcode
class lies in `subset`; every measured tick tally is <= `wcet_ticks`.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple

import numpy as np

from repro.flexibits import asm, isa
from repro.flexibits.cycles import (MIX_CLASSES, SHIFT_IDX, SUBWORD_IDX,
                                    TAKEN_IDX)

_MIX_IDX = {c: i for i, c in enumerate(MIX_CLASSES)}
_N_MIX = len(MIX_CLASSES)
_LOAD_NAMES = frozenset(("lb", "lh", "lw", "lbu", "lhu"))
_WIDEN_VISITS = 24          # interval worklist visits before widening
_TOP = None                 # interval lattice top (unknown int32)

ERROR, WARNING, INFO = "error", "warning", "info"


@dataclasses.dataclass(frozen=True)
class Diag:
    severity: str           # error | warning | info
    code: str               # stable diagnostic id, e.g. "dead-store"
    word: Optional[int]     # word index, or None for program-level
    message: str

    def format(self, code_words: Optional[np.ndarray] = None) -> str:
        loc = "program" if self.word is None else f"word {self.word:4d}"
        line = f"{self.severity.upper():7s} {loc}: {self.message}"
        if self.word is not None and code_words is not None:
            line += f"   [{asm.disasm(int(code_words[self.word]))}]"
        return line


def _sx(v: int, bits: int) -> int:
    v &= (1 << bits) - 1
    return v - (1 << bits) if v >= (1 << (bits - 1)) else v


def _s32(v: int) -> int:
    return _sx(v, 32)


# ---------------------------------------------------------------------------
# decoded-word helpers (operate on asm.Decoded in canonical form)

def _writes_rd(d: asm.Decoded) -> bool:
    return d.name not in isa.S_OPS and d.name not in isa.B_OPS \
        and d.name not in ("ecall", "ebreak")


def _uses(d: asm.Decoded) -> Tuple[int, ...]:
    n = d.name
    if n in isa.R_OPS or n in isa.S_OPS or n in isa.B_OPS:
        return (d.rs1 & 0xF, d.rs2 & 0xF)
    if n in isa.I_OPS or n in isa.SHIFT_OPS:
        return (d.rs1 & 0xF,)
    return ()                       # lui / auipc / jal / ecall / ebreak


def _def_reg(d: asm.Decoded) -> Optional[int]:
    if not _writes_rd(d):
        return None
    rd = d.rd & 0xF
    return rd if rd != 0 else None


def read_registers(analysis: "Analysis") -> FrozenSet[int]:
    """Registers read by at least one CFG-reachable instruction.

    The FlexiLint liveness mask of the FlexiFault measurement layer
    (DESIGN.md §9.14): a register outside this set is provably dead —
    no reachable instruction ever sources it — so a bit flip landing
    there cannot propagate to any architectural output and is not
    counted as corruption. Callers must treat a degraded analysis as
    all-registers-live; this helper only reports what the recovered
    CFG proves.
    """
    regs = set()
    for w in analysis.reachable:
        d = analysis._dec[w]
        if d is not None:
            regs.update(_uses(d))
    return frozenset(regs)


def _worst_ticks(d: asm.Decoded, cost: np.ndarray) -> int:
    """Worst-case ticks one retirement of `d` can cost under a §9.10
    cost row — `iss.classify` + `iss.dynamic_terms` with every dynamic
    term at its maximum (branches taken, register shifts by 31)."""
    two = d.name in isa.TWO_STAGE
    # ebreak has no MIX_CATEGORY entry; it retires as a system op
    mix = isa.MIX_CATEGORY.get(d.name, "system")
    base = int(cost[(_N_MIX if two else 0) + _MIX_IDX[mix]])
    if d.name in isa.B_OPS:
        base += int(cost[TAKEN_IDX])            # assume taken
    if d.name in isa.SHIFT_OPS:
        base += (d.imm & 31) * int(cost[SHIFT_IDX])
    elif d.name in ("sll", "srl", "sra"):
        base += 31 * int(cost[SHIFT_IDX])       # unknown register shamt
    if d.name in ("lb", "lh", "lbu", "lhu", "sb", "sh"):
        base += int(cost[SUBWORD_IDX])
    return base


# ---------------------------------------------------------------------------
# interval domain: (lo, hi) int pairs, or _TOP for unknown

def _ival_const(v: int):
    v = _s32(v)
    return (v, v)


def _ival_join(x, y):
    if x is _TOP or y is _TOP:
        return _TOP
    return (min(x[0], y[0]), max(x[1], y[1]))


def _ival_addc(x, c: int):
    if x is _TOP:
        return _TOP
    lo, hi = x[0] + c, x[1] + c
    if -(1 << 31) <= lo and hi < (1 << 31):
        return (lo, hi)
    return _TOP                                  # int32 wrap hazard


def _ival_add(x, y, sign=1):
    if x is _TOP or y is _TOP:
        return _TOP
    if sign > 0:
        lo, hi = x[0] + y[0], x[1] + y[1]
    else:
        lo, hi = x[0] - y[1], x[1] - y[0]
    if -(1 << 31) <= lo and hi < (1 << 31):
        return (lo, hi)
    return _TOP


class Uninferable(Exception):
    """Raised internally when a loop bound cannot be established."""


@dataclasses.dataclass
class Analysis:
    """Result of FlexiLint over one encoded program."""
    name: str
    code: np.ndarray                     # uint32 words
    mem_words: int
    degraded: Optional[str]              # over-approximation reason
    reachable: FrozenSet[int]            # word indices
    subset: FrozenSet[int]               # opcode classes (iss-compatible)
    reachable_names: FrozenSet[str]      # reachable mnemonics
    mix_sites: Dict[str, int]            # static site count per mix class
    diags: List[Diag]
    functions: Dict[int, FrozenSet[int]]  # entry word -> body words
    loop_headers: Dict[int, int]         # header word -> bound used
    min_steps: Optional[int]             # shortest instr path to HALT
    wcet_steps: Optional[int]            # longest bounded instr path
    # internal CFG state for on-demand wcet_ticks evaluation
    _dec: List[Optional[asm.Decoded]] = dataclasses.field(repr=False,
                                                          default=None)
    _fsucc: Dict[int, Dict[int, Tuple[int, ...]]] = \
        dataclasses.field(repr=False, default=None)
    _forder: List[int] = dataclasses.field(repr=False, default=None)
    _fcalls: Dict[int, Dict[int, int]] = dataclasses.field(repr=False,
                                                           default=None)
    _tick_cache: Dict[bytes, Optional[int]] = \
        dataclasses.field(repr=False, default_factory=dict)

    @property
    def n_words(self) -> int:
        return len(self.code)

    @property
    def errors(self) -> List[Diag]:
        return [d for d in self.diags if d.severity == ERROR]

    @property
    def warnings(self) -> List[Diag]:
        return [d for d in self.diags if d.severity == WARNING]

    # -- WCET under an arbitrary §9.10 cost row ---------------------------
    def wcet_ticks(self, cost) -> Optional[int]:
        """Worst-case total ticks of one execution under `cost`
        (cycles.cost_row), or None when no finite static bound exists
        (degraded CFG / unbounded loop)."""
        cost = np.asarray(cost, np.int64)
        key = cost.tobytes()
        if key not in self._tick_cache:
            # a word pulled into a function body by the cs+1 return
            # summary can be globally unreachable (callee never
            # returns); it never retires, so it prices at 0
            self._tick_cache[key] = self._wcet(
                lambda i: 0 if self._dec[i] is None
                else _worst_ticks(self._dec[i], cost))
        return self._tick_cache[key]

    def max_instr_ticks(self, cost) -> int:
        """Max worst-case ticks any single reachable retirement can
        cost — prices a `max_steps` budget into a tick bound even when
        the structural WCET is unavailable."""
        cost = np.asarray(cost, np.int64)
        if self.degraded is not None or not self.reachable:
            return max(_worst_ticks(asm.decode(isa.encode(n)), cost)
                       if asm.decode(isa.encode(n)) else 0
                       for n in isa.ALL_OPS)
        return max(_worst_ticks(self._dec[i], cost) for i in self.reachable)

    def bound_ticks(self, cost, max_steps: Optional[int] = None) \
            -> Optional[int]:
        """Certified tick bound: min(structural WCET, budget x costliest
        instruction). Budget-only when the CFG is degraded; None when
        neither bound exists."""
        w = self.wcet_ticks(cost)
        if max_steps is not None:
            b = int(max_steps) * self.max_instr_ticks(cost)
            w = b if w is None else min(w, b)
        return w

    # -- generic longest-path WCET ---------------------------------------
    def _wcet(self, weight: Callable[[int], int]) -> Optional[int]:
        if self.degraded is not None or self._forder is None:
            return None
        summaries: Dict[int, Optional[int]] = {}
        for f in self._forder:              # callees before callers
            body = self.functions[f]
            succ = self._fsucc[f]

            def node_weight(i, _f=f):
                w = weight(i)
                callee = self._fcalls[_f].get(i)
                if callee is not None:
                    cw = summaries.get(callee)
                    if cw is None:
                        return None
                    w += cw
                return w

            summaries[f] = _longest(frozenset(body), succ, f, node_weight,
                                    self.loop_headers)
            if summaries[f] is None and f == 0:
                return None
        return summaries.get(0)

    # -- report -----------------------------------------------------------
    def format_report(self, cost=None, measured_ticks: Optional[int] = None) \
            -> str:
        sub = sorted(self.subset)
        out = [f"FlexiLint: {self.name or '<program>'} — "
               f"{self.n_words} words, {len(self.reachable)} reachable, "
               f"{len(self.functions)} function(s), "
               f"opcode subset {len(sub)}/{len(_ALL_OPCODES)} "
               f"[{' '.join(f'{o:#04x}' for o in sub)}]"]
        if self.degraded is not None:
            out.append(f"  DEGRADED: {self.degraded} — "
                       "everything-reachable over-approximation")
        if self.loop_headers:
            bounds = ", ".join(f"w{h}<={b}"
                               for h, b in sorted(self.loop_headers.items()))
            out.append(f"  loop bounds: {bounds}")
        out.append(f"  min-steps-to-halt {self.min_steps}, "
                   f"wcet-steps {self.wcet_steps}")
        if cost is not None:
            line = f"  wcet-ticks {self.wcet_ticks(cost)}"
            if measured_ticks is not None:
                w = self.wcet_ticks(cost)
                ratio = (w / measured_ticks) if (w and measured_ticks) else None
                line += f", measured {measured_ticks}" + \
                    (f" (wcet/measured {ratio:.2f}x)" if ratio else "")
            out.append(line)
        for d in self.diags:
            out.append("  " + d.format(self.code))
        if not self.diags:
            out.append("  clean: no diagnostics")
        return "\n".join(out)


# ---------------------------------------------------------------------------
# longest path with SCC collapse under loop bounds

def _tarjan(nodes: FrozenSet[int], succ) -> List[List[int]]:
    """Iterative Tarjan SCC; returns SCCs in reverse topological order
    (callees of the condensation first)."""
    index: Dict[int, int] = {}
    low: Dict[int, int] = {}
    on_stack: Dict[int, bool] = {}
    stack: List[int] = []
    sccs: List[List[int]] = []
    counter = [0]
    for root in nodes:
        if root in index:
            continue
        work = [(root, iter(succ.get(root, ())))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack[root] = True
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in nodes:
                    continue
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack[w] = True
                    work.append((w, iter(succ.get(w, ()))))
                    advanced = True
                    break
                elif on_stack.get(w):
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
            if low[v] == index[v]:
                scc = []
                while True:
                    w = stack.pop()
                    on_stack[w] = False
                    scc.append(w)
                    if w == v:
                        break
                sccs.append(scc)
    return sccs


def _longest(nodes: FrozenSet[int], succ: Dict[int, Tuple[int, ...]],
             entry: int, weight: Callable[[int], Optional[int]],
             bounds: Dict[int, int]) -> Optional[int]:
    """Longest weighted path from `entry` over `nodes`; every nontrivial
    SCC must have a unique header with a bound in `bounds` and collapses
    to bound x (longest single-iteration path). None = no finite bound
    (or a node weight reported None, i.e. an unbounded callee)."""
    if entry not in nodes:
        return 0
    preds: Dict[int, List[int]] = {n: [] for n in nodes}
    for n in nodes:
        for t in succ.get(n, ()):
            if t in nodes:
                preds[t].append(n)
    sccs = _tarjan(nodes, succ)
    scc_id: Dict[int, int] = {}
    for k, scc in enumerate(sccs):
        for n in scc:
            scc_id[n] = k
    scc_weight: List[Optional[int]] = [None] * len(sccs)
    for k, scc in enumerate(sccs):
        members = frozenset(scc)
        trivial = len(scc) == 1 and scc[0] not in succ.get(scc[0], ())
        if trivial:
            scc_weight[k] = weight(scc[0])
            continue
        headers = {n for n in scc
                   if n == entry or any(p not in members for p in preds[n])}
        if len(headers) != 1:
            return None                     # irreducible loop
        h = next(iter(headers))
        bound = bounds.get(h)
        if bound is None:
            return None                     # unbounded loop
        # one iteration: the SCC subgraph with edges back into the
        # header removed (nested SCCs collapse recursively)
        isucc = {n: tuple(t for t in succ.get(n, ())
                          if t in members and t != h) for n in scc}
        inner = _longest(members, isucc, h, weight, bounds)
        if inner is None:
            return None
        scc_weight[k] = bound * inner
    # condensation longest path: sccs is reverse-topological, so walk it
    # backwards (sources first) accumulating max dist-through-node
    dist: List[Optional[int]] = [None] * len(sccs)
    best = None
    for k in range(len(sccs) - 1, -1, -1):
        if scc_id.get(entry) == k:
            dist[k] = 0
        incoming = dist[k]
        if incoming is None:
            continue
        w = scc_weight[k]
        if w is None:
            return None
        here = incoming + w
        best = here if best is None else max(best, here)
        for n in sccs[k]:
            for t in succ.get(n, ()):
                j = scc_id.get(t)
                if j is None or j == k:
                    continue
                if dist[j] is None or dist[j] < here:
                    dist[j] = here
    return best


# ---------------------------------------------------------------------------
# the analyzer

class _Analyzer:
    def __init__(self, code: np.ndarray, mem_words: int,
                 loop_bounds: Dict[int, int], name: str):
        self.code = np.asarray(code).astype(np.uint32, copy=False)
        self.n = len(self.code)
        self.mem_words = int(mem_words)
        self.annotations = dict(loop_bounds or {})
        self.name = name
        self.dec: List[Optional[asm.Decoded]] = \
            [asm.decode(int(w)) for w in self.code]
        self.diags: List[Diag] = []
        self.degraded: Optional[str] = None
        self.calls: set = set()              # word idx of jal ra calls
        self.rets: set = set()               # word idx of ret
        self.succ: Dict[int, Tuple[int, ...]] = {}
        self.reachable: set = set()
        self.in_iv: Dict[int, list] = {}     # word -> 16 intervals (IN)
        self.out_iv: Dict[int, list] = {}
        self.loop_headers: Dict[int, int] = {}

    def diag(self, severity, dcode, word, msg):
        self.diags.append(Diag(severity, dcode, word, msg))

    def degrade(self, reason: str, word: Optional[int]):
        if self.degraded is None:
            self.degraded = reason + ("" if word is None
                                      else f" at word {word}")
            self.diag(WARNING, "degraded", word, f"analysis degraded: "
                      f"{reason} — falling back to everything-reachable")

    # -- successor model (word-level, matches the steppers' fetch) -------
    def _target(self, i: int, imm: int) -> Optional[int]:
        byte = i * 4 + imm
        if imm % 4 != 0:
            self.degrade("misaligned control transfer", i)
            return None
        if byte < 0 or byte >= self.n * 4:
            self.degrade("control transfer outside code", i)
            return None
        return byte // 4

    def _classify_word(self, i: int):
        """-> (successors, kind) where kind in {fall, branch, jump,
        call, ret, halt}; degrades the analysis on anything the exact
        word model cannot represent."""
        d = self.dec[i]
        if d is None:
            self.degrade("undecodable reachable word", i)
            return (), "halt"
        rd = d.rd & 0xF
        if _writes_rd(d) and rd == 1 and d.name != "jal":
            self.degrade("ra written by non-call instruction "
                         f"({d.name})", i)
            return (), "halt"
        if d.name == "jal":
            t = self._target(i, d.imm)
            if t is None:
                return (), "halt"
            if rd == 1:
                self.calls.add(i)
                return (t,), "call"
            return (t,), "jump"
        if d.name == "jalr":
            if rd == 0 and (d.rs1 & 0xF) == 1 and d.imm == 0:
                self.rets.add(i)
                return (), "ret"
            self.degrade("indirect jump (non-return jalr)", i)
            return (), "halt"
        if d.name in isa.B_OPS:
            t = self._target(i, d.imm)
            if t is None:
                return (), "halt"
            if i + 1 >= self.n:
                self.degrade("control reaches end of code", i)
                return (), "halt"
            return (t, i + 1), "branch"
        if d.name in ("ecall", "ebreak"):
            return (), "halt"
        if i + 1 >= self.n:
            self.degrade("control reaches end of code", i)
            return (), "halt"
        return (i + 1,), "fall"

    # -- reachability with incremental ret-edge wiring --------------------
    def _explore(self):
        kind: Dict[int, str] = {}
        work = [0] if self.n else []
        self.reachable = {0} if self.n else set()
        ret_succ: Dict[int, set] = {}
        while work and self.degraded is None:
            i = work.pop()
            succ, k = self._classify_word(i)
            if self.degraded is not None:
                break
            kind[i] = k
            targets = set(succ)
            if k == "call":
                if i + 1 >= self.n:
                    self.degrade("call falls off end of code", i)
                    break
                # returns land after the call site: wire every known ret
                for r in self.rets:
                    ret_succ.setdefault(r, set()).add(i + 1)
                    if i + 1 not in self.reachable:
                        self.reachable.add(i + 1)
                        work.append(i + 1)
            if k == "ret":
                ret_succ[i] = {cs + 1 for cs in self.calls}
                targets |= ret_succ[i]
            self.succ[i] = tuple(sorted(targets))
            for t in targets:
                if t not in self.reachable:
                    self.reachable.add(t)
                    work.append(t)
        if self.degraded is not None:
            self.reachable = set(range(self.n))
            self.succ = {}
            return
        # late-bound ret successors (calls discovered after the ret)
        for r, targets in ret_succ.items():
            self.succ[r] = tuple(sorted(targets))
        self.kind = kind

    # -- dataflow ---------------------------------------------------------
    def _preds(self) -> Dict[int, List[int]]:
        preds: Dict[int, List[int]] = {i: [] for i in self.reachable}
        for i in self.reachable:
            for t in self.succ.get(i, ()):
                preds[t].append(i)
        return preds

    def _local_assign(self, f: int, entry_mask: int,
                      must_def: Dict[int, int]) -> Dict[int, int]:
        """Definite-assignment over one function body (forward must,
        bitmask-16, meet = AND). Calls transfer through the callee's
        must-def summary instead of the shared interprocedural return
        edges — context-sensitive, so registers live across a call are
        not spuriously dropped at other call sites' joins."""
        FULL = (1 << 16) - 1
        succ = self._fsucc[f]
        in_m: Dict[int, int] = {f: entry_mask | 1}
        work = [f]
        while work:
            i = work.pop()
            m = in_m[i]
            callee = self._fcalls[f].get(i)
            if callee is not None:
                out = m | 2 | must_def.get(callee, 0)   # jal wrote ra
            else:
                d = self.dec[i]
                r = _def_reg(d) if d is not None else None
                out = m | (1 << r) if r is not None else m
            for t in succ.get(i, ()):
                nm = out & in_m.get(t, FULL)
                if nm != in_m.get(t):
                    in_m[t] = nm
                    work.append(t)
        return in_m

    def _definite_assignment(self):
        """Context-sensitive forward must-analysis; flags reads of
        registers that are not definitely written on every path (they
        read the zero-initialized register file — legal on the core,
        but a lint error)."""
        FULL = (1 << 16) - 1
        # bottom-up (callees first): regs every return path assigns
        must_def: Dict[int, int] = {}
        for f in self._forder:
            in_m = self._local_assign(f, 0, must_def)
            md = FULL
            for r in self.functions[f]:
                if self.kind.get(r) == "ret":
                    md &= in_m.get(r, FULL)
            must_def[f] = md
        # top-down (callers first): entry state = meet over call sites
        entry_mask: Dict[int, int] = {f: FULL for f in self.functions}
        entry_mask[0] = 1                    # only x0 defined at boot
        reported = set()
        for f in reversed(self._forder):
            in_m = self._local_assign(f, entry_mask.get(f, FULL), must_def)
            for cs, callee in self._fcalls[f].items():
                if callee in entry_mask and cs in in_m:
                    entry_mask[callee] &= in_m[cs] | 2
            for i in sorted(self.functions[f]):
                d = self.dec[i]
                if d is None or i not in self.reachable:
                    continue
                m = in_m.get(i, FULL)
                for r in _uses(d):
                    if r != 0 and not (m & (1 << r)) \
                            and (i, r) not in reported:
                        reported.add((i, r))
                        self.diag(ERROR, "read-before-write", i,
                                  f"{asm.REG_NAMES[r]} may be read before "
                                  "any write (reads the zero-initialized "
                                  "register file)")

    def _liveness(self):
        """Backward may-analysis; flags pure defs whose value no path
        ever reads (dead stores)."""
        preds = self._preds()
        live_out: Dict[int, int] = {i: 0 for i in self.reachable}
        work = list(self.reachable)
        while work:
            i = work.pop()
            d = self.dec[i]
            r = _def_reg(d)
            live_in = live_out[i]
            if r is not None:
                live_in &= ~(1 << r)
            for u in _uses(d):
                live_in |= (1 << u)
            for p in preds.get(i, ()):
                if live_out[p] | live_in != live_out[p]:
                    live_out[p] |= live_in
                    work.append(p)
        for i in sorted(self.reachable):
            d = self.dec[i]
            r = _def_reg(d)
            if r is None or d.name in ("jal", "jalr"):
                continue                     # link writes are control
            if not (live_out[i] & (1 << r)):
                self.diag(WARNING, "dead-store", i,
                          f"result in {asm.REG_NAMES[r]} is never read")

    def _unreachable(self):
        dead = sorted(set(range(self.n)) - self.reachable)
        if dead:
            runs = []
            start = prev = dead[0]
            for i in dead[1:]:
                if i != prev + 1:
                    runs.append((start, prev))
                    start = i
                prev = i
            runs.append((start, prev))
            for a, b in runs:
                self.diag(WARNING, "unreachable-code", a,
                          f"words {a}..{b} are unreachable"
                          if b > a else "word is unreachable")
        if not any(self.dec[i] and self.dec[i].name in ("ecall", "ebreak")
                   for i in self.reachable):
            self.diag(ERROR, "unreachable-halt", None,
                      "no HALT (ecall/ebreak) is reachable — every item "
                      "retires budget-exhausted")

    # -- interval analysis + memory bounds --------------------------------
    def _transfer(self, i: int, iv: list) -> list:
        d = self.dec[i]
        out = list(iv)
        r = _def_reg(d)
        if r is None:
            return out
        n = d.name
        a = iv[d.rs1 & 0xF]
        b = iv[d.rs2 & 0xF]
        v = _TOP
        if n == "lui":
            v = _ival_const(d.imm << 12)
        elif n == "auipc":
            v = _ival_const(i * 4 + _s32(d.imm << 12))
        elif n == "addi":
            v = _ival_addc(a, d.imm)
        elif n == "add":
            v = _ival_add(a, b, 1)
        elif n == "sub":
            v = _ival_add(a, b, -1)
        elif n == "andi":
            if d.imm >= 0:
                v = (0, d.imm) if a is _TOP else \
                    (0, min(d.imm, max(a[1], 0)) if a[0] >= 0 else d.imm)
        elif n in ("slti", "sltiu", "slt", "sltu"):
            v = (0, 1)
        elif n == "slli":
            sh = d.imm & 31
            if a is not _TOP and a[0] >= 0 and (a[1] << sh) < (1 << 31):
                v = (a[0] << sh, a[1] << sh)
        elif n == "srli":
            sh = d.imm & 31
            if a is not _TOP and a[0] >= 0:
                v = (a[0] >> sh, a[1] >> sh)
            elif sh > 0:
                v = (0, ((1 << 32) - 1) >> sh)
        elif n == "srai":
            sh = d.imm & 31
            if a is not _TOP:
                v = (a[0] >> sh, a[1] >> sh)
        elif n in ("jal", "jalr"):
            v = _ival_const(i * 4 + 4)
        elif n in ("xori", "ori") and a is not _TOP and a[0] == a[1]:
            x = a[0]
            v = _ival_const(x ^ d.imm if n == "xori" else x | d.imm)
        # everything else (loads, xor/or/and, reg shifts): TOP
        out[r] = v
        out[0] = (0, 0)
        return out

    def _intervals(self):
        zero = [(0, 0)] * 16                 # the core zero-inits regs
        self.in_iv = {0: zero}
        visits: Dict[int, int] = {}
        work = [0]
        while work:
            i = work.pop(0)
            iv = self.in_iv[i]
            out = self._transfer(i, iv)
            prev = self.out_iv.get(i)
            if prev == out and i in visits:
                continue
            self.out_iv[i] = out
            visits[i] = visits.get(i, 0) + 1
            for t in self.succ.get(i, ()):
                cur = self.in_iv.get(t)
                if cur is None:
                    self.in_iv[t] = list(out)
                    work.append(t)
                    continue
                nxt = [_ival_join(x, y) for x, y in zip(cur, out)]
                if visits.get(t, 0) > _WIDEN_VISITS:
                    nxt = [x if x == y else _TOP
                           for x, y in zip(cur, nxt)]
                if nxt != cur:
                    self.in_iv[t] = nxt
                    work.append(t)

    def _check_bounds(self):
        limit = self.mem_words * 4
        for i in sorted(self.reachable):
            d = self.dec[i]
            if d is None:
                continue
            is_load = d.name in _LOAD_NAMES
            is_store = d.name in isa.S_OPS
            if not (is_load or is_store):
                continue
            base = self.in_iv.get(i, [_TOP] * 16)[d.rs1 & 0xF]
            addr = _ival_addc(base, d.imm)
            if addr is _TOP:
                self.diag(INFO, "runtime-clamped", i,
                          "address not affine in constants — runtime "
                          "clamp-on-read/drop-on-write applies")
            elif addr[1] < 0 or addr[0] >= limit:
                self.diag(ERROR, "oob-access", i,
                          f"address provably outside [0, {limit}) bytes: "
                          f"[{addr[0]}, {addr[1]}]")
            elif addr[0] < 0 or addr[1] >= limit:
                self.diag(WARNING, "partial-oob", i,
                          f"address range [{addr[0]}, {addr[1]}] may "
                          f"leave [0, {limit}) bytes")
            # in-range: proved — no diagnostic

    # -- loop bounds: annotations + counter-idiom inference ---------------
    def _infer_bound(self, header: int, scc: FrozenSet[int],
                     succ: Dict[int, Tuple[int, ...]],
                     preds: Dict[int, List[int]]) -> Optional[int]:
        back = [s for s in scc if header in succ.get(s, ())]
        if len(back) != 1:
            return None
        s = back[0]
        d = self.dec[s]
        if d is None or d.name not in isa.B_OPS:
            return None
        outs = [t for t in succ.get(s, ()) if t not in scc]
        ins = [t for t in succ.get(s, ()) if t == header]
        if len(outs) != 1 or len(ins) != 1:
            return None
        taken_tgt = self._target_quiet(s, d.imm)
        if taken_tgt is None:
            return None
        taken_to_header = (taken_tgt == header)
        for side in (1, 2):
            c = (d.rs1 if side == 1 else d.rs2) & 0xF
            o = (d.rs2 if side == 1 else d.rs1) & 0xF
            if c == 0:
                continue
            bound = self._try_counter(c, o, side, d.name, taken_to_header,
                                      header, s, scc, succ, preds)
            if bound is not None:
                return bound
        return None

    def _target_quiet(self, i: int, imm: int) -> Optional[int]:
        byte = i * 4 + imm
        if imm % 4 != 0 or byte < 0 or byte >= self.n * 4:
            return None
        return byte // 4

    def _try_counter(self, c, o, side, bname, taken_to_header,
                     header, s, scc, succ, preds) -> Optional[int]:
        # exactly one def of c inside the SCC: `addi c, c, k`
        defs = [i for i in scc
                if self.dec[i] is not None and _def_reg(self.dec[i]) == c]
        if len(defs) != 1:
            return None
        dw = defs[0]
        dd = self.dec[dw]
        if dd.name != "addi" or (dd.rs1 & 0xF) != c or dd.imm == 0:
            return None
        k = dd.imm
        # the def must lie on every path header -> back-edge source
        if dw != s and not self._cuts(header, s, dw, scc, succ):
            return None
        # other operand: x0 or interval-constant at the branch
        if o == 0:
            C = 0
        else:
            iv = self.in_iv.get(s, [_TOP] * 16)[o]
            if iv is _TOP or iv[0] != iv[1]:
                return None
            C = iv[0]
        # initial counter value: constant join over external preds
        v0iv = None
        for p in preds.get(header, ()):
            if p in scc:
                continue
            pv = self.out_iv.get(p, [_TOP] * 16)[c]
            v0iv = pv if v0iv is None else _ival_join(v0iv, pv)
        if v0iv is None or v0iv is _TOP or v0iv[0] != v0iv[1]:
            return None
        v0 = v0iv[0]
        if abs(v0) >= (1 << 30) or abs(C) >= (1 << 30) or abs(k) > 2048:
            return None
        # continue-predicate on the counter
        pred_by_cond = {"beq": "eq", "bne": "ne", "blt": "lt", "bge": "ge",
                        "bltu": "ltu", "bgeu": "geu"}[bname]
        if side == 2:                        # counter on rs2: mirror
            pred_by_cond = {"eq": "eq", "ne": "ne", "lt": "gt", "ge": "le",
                            "ltu": "gtu", "geu": "leu"}[pred_by_cond]
        if not taken_to_header:              # loop continues on fall
            pred_by_cond = {"eq": "ne", "ne": "eq", "lt": "ge", "ge": "lt",
                            "le": "gt", "gt": "le", "ltu": "geu",
                            "geu": "ltu", "gtu": "leu",
                            "leu": "gtu"}[pred_by_cond]
        return _counter_trips(pred_by_cond, v0, C, k)

    def _cuts(self, src, dst, via, scc, succ) -> bool:
        """True if every path src->dst inside `scc` passes through
        `via` (reachability check with `via` removed)."""
        if via == src or via == dst:
            return True
        seen = {src}
        work = [src]
        while work:
            v = work.pop()
            for t in succ.get(v, ()):
                if t == via or t not in scc or t in seen:
                    continue
                if t == dst:
                    return False
                seen.add(t)
                work.append(t)
        return True

    def _resolve_loop_bounds(self):
        """Find every loop header in every function body and attach a
        bound: annotation first, counter inference second."""
        for f in self.functions:
            self._resolve_in(frozenset(self.functions[f]),
                             self._fsucc[f], f)

    def _resolve_in(self, nodes, succ, entry):
        preds: Dict[int, List[int]] = {n: [] for n in nodes}
        for n in nodes:
            for t in succ.get(n, ()):
                if t in preds:
                    preds[t].append(n)
        for scc in _tarjan(nodes, succ):
            members = frozenset(scc)
            if len(scc) == 1 and scc[0] not in succ.get(scc[0], ()):
                continue
            headers = {n for n in scc if n == entry
                       or any(p not in members for p in preds[n])}
            if len(headers) != 1:
                self.diag(WARNING, "irreducible-loop", min(scc),
                          "loop with multiple entries — WCET unavailable")
                continue
            h = next(iter(headers))
            if h not in self.loop_headers:
                b = self.annotations.get(h)
                if b is None:
                    b = self._infer_bound(h, members, succ, preds)
                    if b is not None:
                        self.diag(INFO, "inferred-bound", h,
                                  f"counter idiom: header executes "
                                  f"<= {b} times per entry")
                if b is None:
                    self.diag(WARNING, "unbounded-loop", h,
                              "no annotation and no counter idiom — "
                              "WCET unavailable")
                else:
                    self.loop_headers[h] = max(1, int(b))
            # recurse into the loop body for nested loops
            isucc = {n: tuple(t for t in succ.get(n, ())
                              if t in members and t != h) for n in scc}
            self._resolve_in(members, isucc, h)

    # -- function partition ------------------------------------------------
    def _build_functions(self):
        entries = {0} | {self._target_quiet(cs, self.dec[cs].imm)
                         for cs in self.calls}
        entries.discard(None)
        self.functions = {}
        self._fsucc = {}
        self._fcalls = {}
        for f in sorted(entries):
            body = set()
            succ: Dict[int, Tuple[int, ...]] = {}
            calls: Dict[int, int] = {}
            work = [f]
            while work:
                i = work.pop()
                if i in body:
                    continue
                body.add(i)
                k = self.kind.get(i)
                if k == "call":
                    tgt = self._target_quiet(i, self.dec[i].imm)
                    calls[i] = tgt
                    succ[i] = (i + 1,)       # callee summarized
                elif k == "ret":
                    succ[i] = ()
                else:
                    succ[i] = tuple(t for t in self.succ.get(i, ()))
                for t in succ[i]:
                    if t not in body:
                        work.append(t)
            self.functions[f] = frozenset(body)
            self._fsucc[f] = succ
            self._fcalls[f] = calls
        # call-graph topological order, callees first; cycles -> those
        # functions get no WCET (recursion)
        order: List[int] = []
        state: Dict[int, int] = {}
        self._recursive: set = set()

        def visit(f):
            stack = [(f, iter(set(self._fcalls[f].values())))]
            state[f] = 1
            path = [f]
            while stack:
                g, it = stack[-1]
                advanced = False
                for h in it:
                    if h is None or h not in self.functions:
                        continue
                    st = state.get(h, 0)
                    if st == 1:
                        self._recursive.update(path)
                    elif st == 0:
                        state[h] = 1
                        path.append(h)
                        stack.append((h, iter(set(self._fcalls[h].values()))))
                        advanced = True
                        break
                if advanced:
                    continue
                stack.pop()
                state[g] = 2
                path.pop()
                order.append(g)

        for f in self.functions:
            if state.get(f, 0) == 0:
                visit(f)
        self._forder = order
        for f in sorted(self._recursive):
            self.diag(WARNING, "recursion", f,
                      "recursive call cycle — WCET unavailable")

    # -- min-steps-to-halt -------------------------------------------------
    def _min_steps(self) -> Optional[int]:
        from collections import deque
        if not self.n:
            return None
        dist = {0: 1}
        q = deque([0])
        while q:
            i = q.popleft()
            d = self.dec[i]
            if d is not None and d.name in ("ecall", "ebreak"):
                return dist[i]
            for t in self.succ.get(i, ()):
                if t not in dist:
                    dist[t] = dist[i] + 1
                    q.append(t)
        return None

    # -- main --------------------------------------------------------------
    def run(self) -> Analysis:
        if self.n == 0:
            self.diag(ERROR, "unreachable-halt", None, "empty program")
            return Analysis(
                name=self.name, code=self.code, mem_words=self.mem_words,
                degraded="empty program", reachable=frozenset(),
                subset=frozenset(), reachable_names=frozenset(),
                mix_sites={}, diags=self.diags, functions={},
                loop_headers={}, min_steps=None, wcet_steps=None,
                _dec=[], _fsucc=None, _forder=None, _fcalls=None)
        self._explore()
        if self.degraded is not None:
            from repro.flexibits import iss
            subset = iss.opcode_subset(self.code)
            return Analysis(
                name=self.name, code=self.code, mem_words=self.mem_words,
                degraded=self.degraded,
                reachable=frozenset(range(self.n)), subset=subset,
                reachable_names=frozenset(
                    d.name for d in self.dec if d is not None),
                mix_sites={}, diags=self.diags, functions={},
                loop_headers={}, min_steps=None, wcet_steps=None,
                _dec=self.dec, _fsucc=None, _forder=None, _fcalls=None)
        self._build_functions()
        self._definite_assignment()
        self._liveness()
        self._unreachable()
        self._intervals()
        self._check_bounds()
        self._resolve_loop_bounds()
        names = frozenset(self.dec[i].name for i in self.reachable)
        subset = frozenset(
            o for o in _ALL_OPCODES
            if o in {int(self.code[i]) & 0x7F for i in self.reachable})
        mix_sites: Dict[str, int] = {}
        for i in self.reachable:
            cat = isa.MIX_CATEGORY[self.dec[i].name]
            mix_sites[cat] = mix_sites.get(cat, 0) + 1
        res = Analysis(
            name=self.name, code=self.code, mem_words=self.mem_words,
            degraded=None, reachable=frozenset(self.reachable),
            subset=subset, reachable_names=names, mix_sites=mix_sites,
            diags=self.diags, functions=dict(self.functions),
            loop_headers=dict(self.loop_headers),
            min_steps=self._min_steps(), wcet_steps=None,
            _dec=self.dec, _fsucc=self._fsucc, _forder=self._forder,
            _fcalls=self._fcalls)
        res.wcet_steps = res._wcet(lambda i: 1)
        return res


def _counter_trips(pred: str, v0: int, C: int, k: int) -> Optional[int]:
    """Header executions H for a loop `for (r = v0; P(r); r += k)` where
    the continue-test sees r already advanced once. None = not provably
    bounded under predicate `pred`."""
    if pred == "lt":
        if k <= 0:
            return None
        return max(0, (C - 1 - v0) // k) + 1
    if pred == "le":
        if k <= 0:
            return None
        return max(0, (C - v0) // k) + 1
    if pred == "ge":
        if k >= 0:
            return None
        return max(0, (v0 - C) // (-k)) + 1
    if pred == "gt":
        if k >= 0:
            return None
        return max(0, (v0 - (C + 1)) // (-k)) + 1
    if pred == "ne":
        if k == 0 or (C - v0) % k != 0:
            return None
        h = (C - v0) // k
        return h if h >= 1 else None
    if pred == "ltu":
        if v0 < 0 or C < 0:
            return None
        return _counter_trips("lt", v0, C, k)
    if pred == "geu":
        if v0 < 0 or C < 0 or k >= 0 or -k > C:
            return None
        return _counter_trips("ge", v0, C, k)
    return None                              # eq / gtu / leu


# ---------------------------------------------------------------------------
# cached entry points

_ALL_OPCODES = (isa.OP_LUI, isa.OP_AUIPC, isa.OP_JAL, isa.OP_JALR,
                isa.OP_BRANCH, isa.OP_LOAD, isa.OP_STORE, isa.OP_IMM,
                isa.OP_REG, isa.OP_SYSTEM)

_CACHE: Dict[tuple, Analysis] = {}


def analyze_code(code, mem_words: int, *, loop_bounds=None,
                 name: str = "") -> Analysis:
    """Analyze raw encoded words. Results are cached on (code bytes,
    mem_words, bounds) — repeated plan validation/reporting re-uses one
    analysis per program."""
    words = np.asarray(code)
    words = words.view(np.uint32) if words.dtype.itemsize == 4 \
        else words.astype(np.uint32)
    bounds = tuple(sorted((loop_bounds or {}).items()))
    key = (words.tobytes(), int(mem_words), bounds)
    hit = _CACHE.get(key)
    if hit is None:
        hit = _Analyzer(words, mem_words, dict(bounds), name).run()
        if len(_CACHE) > 256:
            _CACHE.clear()
        _CACHE[key] = hit
    return hit


def analyze_program(program: asm.Program, mem_words: int,
                    name: str = "") -> Analysis:
    return analyze_code(program.code, mem_words,
                        loop_bounds=program.loop_bounds, name=name)


def analyze_workload(workload) -> Analysis:
    """Analyze a FlexiBench workload against its own memory footprint."""
    return analyze_program(workload.program, workload.total_mem_words,
                           name=workload.key)
