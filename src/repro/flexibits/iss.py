"""JAX RV32E instruction-set simulator — the paper's RTL characterization
loop re-thought for TPU: one lax.while_loop interpreter, vmap-able over
per-item memories (a *fleet* of devices with different sensor inputs), and
shard_map-able over the production mesh (flexibits/fleet.py).

State is a dict of jnp arrays; the step decodes with bit ops and dispatches
on opcode via lax.switch. Cycle accounting implements the paper's bit-serial
timing model (cycles.py): per retired instruction, one-stage or two-stage
cost for the configured datapath width.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.flexibits import isa

I32 = jnp.int32
U32 = jnp.uint32

# mix categories (Fig. 2a)
MIX_CLASSES = ("loads", "stores", "branches", "jumps", "shifts", "I-type",
               "R-type", "system")
_MIX_IDX = {c: i for i, c in enumerate(MIX_CLASSES)}

_OPCODES = (isa.OP_LUI, isa.OP_AUIPC, isa.OP_JAL, isa.OP_JALR,
            isa.OP_BRANCH, isa.OP_LOAD, isa.OP_STORE, isa.OP_IMM,
            isa.OP_REG, isa.OP_SYSTEM)


class ISSState(NamedTuple):
    regs: jax.Array        # (16,) int32
    pc: jax.Array          # () int32 (byte address)
    mem: jax.Array         # (M,) int32 word-addressed RAM
    halted: jax.Array      # () bool
    n_instr: jax.Array     # () int32
    n_two_stage: jax.Array  # () int32
    mix: jax.Array         # (8,) int32 per-category retired counts


def init_state(mem: jax.Array) -> ISSState:
    return ISSState(
        regs=jnp.zeros(16, I32),
        pc=jnp.zeros((), I32),
        mem=mem.astype(I32),
        halted=jnp.zeros((), bool),
        n_instr=jnp.zeros((), I32),
        n_two_stage=jnp.zeros((), I32),
        mix=jnp.zeros(len(MIX_CLASSES), I32),
    )


def _sx(v, bits):
    shift = 32 - bits
    return (v.astype(I32) << shift) >> shift


def _u(v):
    return v.astype(U32)


def step(code: jax.Array, s: ISSState) -> ISSState:
    instr = code[(_u(s.pc) >> 2).astype(I32)].astype(U32)
    ii = instr.astype(I32)
    op = (ii & 0x7F)
    rd = (ii >> 7) & 0xF
    f3 = (ii >> 12) & 0x7
    rs1 = (ii >> 15) & 0xF
    rs2 = (ii >> 20) & 0xF
    f7 = (ii >> 25) & 0x7F
    sub_bit = (ii >> 30) & 1

    imm_i = _sx(_u(instr) >> 20, 12)
    imm_s = _sx(((_u(instr) >> 25) << 5).astype(I32)
                | ((ii >> 7) & 0x1F), 12)
    imm_b = _sx(((ii >> 31) & 1) << 12 | ((ii >> 7) & 1) << 11
                | ((ii >> 25) & 0x3F) << 5 | ((ii >> 8) & 0xF) << 1, 13)
    imm_u = ii & jnp.asarray(-4096, I32)  # 0xFFFFF000 as a signed mask
    imm_j = _sx(((ii >> 31) & 1) << 20 | ((ii >> 12) & 0xFF) << 12
                | ((ii >> 20) & 1) << 11 | ((ii >> 21) & 0x3FF) << 1, 21)

    a = s.regs[rs1]
    b = s.regs[rs2]
    au = _u(a)
    bu = _u(b)
    pc4 = s.pc + 4

    def alu(x, y, f3v, is_sub, is_sra):
        sh = (y & 31).astype(U32)
        return lax.switch(f3v, [
            lambda: jnp.where(is_sub, x - y, x + y),
            lambda: (x.astype(U32) << sh).astype(I32),
            lambda: (x < y).astype(I32),
            lambda: (_u(x) < _u(y)).astype(I32),
            lambda: x ^ y,
            lambda: jnp.where(is_sra, x >> (y & 31),
                              (_u(x) >> sh).astype(I32)),
            lambda: x | y,
            lambda: x & y,
        ])

    # LOAD: word RMW for sub-word
    def do_load():
        addr = (a + imm_i).astype(I32)
        word = s.mem[_u(addr).astype(I32) >> 2]
        sh8 = ((addr & 3) * 8).astype(U32)
        byte = (_u(word) >> sh8).astype(I32) & 0xFF
        half_sh = ((addr & 2) * 8).astype(U32)
        half = (_u(word) >> half_sh).astype(I32) & 0xFFFF
        val = lax.switch(jnp.clip(f3, 0, 5), [
            lambda: _sx(byte, 8),            # lb
            lambda: _sx(half, 16),           # lh
            lambda: word,                    # lw
            lambda: word,                    # (unused f3=3)
            lambda: byte,                    # lbu
            lambda: half,                    # lhu
        ])
        return val, pc4, s.mem, False

    def do_store():
        addr = (a + imm_s).astype(I32)
        widx = _u(addr).astype(I32) >> 2
        word = s.mem[widx]
        sh8 = ((addr & 3) * 8).astype(U32)
        sh16 = ((addr & 2) * 8).astype(U32)
        bmask = (jnp.asarray(0xFF, U32) << sh8).astype(I32)
        hmask = (jnp.asarray(0xFFFF, U32) << sh16).astype(I32)
        neww = lax.switch(jnp.clip(f3, 0, 2), [
            lambda: (word & ~bmask) | (((b & 0xFF).astype(U32) << sh8
                                        ).astype(I32) & bmask),
            lambda: (word & ~hmask) | (((b & 0xFFFF).astype(U32) << sh16
                                        ).astype(I32) & hmask),
            lambda: b,
        ])
        return jnp.zeros((), I32), pc4, s.mem.at[widx].set(neww), False

    def do_branch():
        cond = lax.switch(f3, [
            lambda: a == b, lambda: a != b,
            lambda: jnp.zeros((), bool), lambda: jnp.zeros((), bool),
            lambda: a < b, lambda: a >= b,
            lambda: au < bu, lambda: au >= bu,
        ])
        return jnp.zeros((), I32), \
            jnp.where(cond, s.pc + imm_b, pc4), s.mem, False

    cases = [
        lambda: (imm_u, pc4, s.mem, False),                       # LUI
        lambda: (s.pc + imm_u, pc4, s.mem, False),                # AUIPC
        lambda: (pc4, s.pc + imm_j, s.mem, False),                # JAL
        lambda: (pc4, (a + imm_i) & ~1, s.mem, False),            # JALR
        do_branch,                                                # BRANCH
        do_load,                                                  # LOAD
        do_store,                                                 # STORE
        lambda: (alu(a, imm_i, f3,                                # OP-IMM
                     jnp.zeros((), bool),
                     (f3 == 5) & (sub_bit == 1)),
                 pc4, s.mem, False),
        lambda: (alu(a, b, f3, sub_bit == 1, sub_bit == 1),       # OP-REG
                 pc4, s.mem, False),
        lambda: (jnp.zeros((), I32), pc4, s.mem, True),           # SYSTEM
    ]
    case_idx = jnp.searchsorted(jnp.asarray(sorted(_OPCODES), I32), op)
    # map sorted position back to case order
    sorted_ops = sorted(_OPCODES)
    perm = [sorted_ops.index(o) for o in _OPCODES]
    inv = [0] * len(_OPCODES)
    for ci, po in enumerate(perm):
        inv[po] = ci
    wr, next_pc, mem, halt = lax.switch(case_idx,
                                        [cases[i] for i in inv])

    writes_rd = (op != isa.OP_BRANCH) & (op != isa.OP_STORE) \
        & (op != isa.OP_SYSTEM) & (rd != 0)
    regs = s.regs.at[rd].set(jnp.where(writes_rd, wr, s.regs[rd]))

    # ---- classification: two-stage + mix category
    is_shift_imm = (op == isa.OP_IMM) & ((f3 == 1) | (f3 == 5))
    is_shift_reg = (op == isa.OP_REG) & ((f3 == 1) | (f3 == 5))
    is_slt = ((op == isa.OP_IMM) | (op == isa.OP_REG)) \
        & ((f3 == 2) | (f3 == 3))
    two_stage = ((op == isa.OP_LOAD) | (op == isa.OP_STORE)
                 | (op == isa.OP_BRANCH) | (op == isa.OP_JAL)
                 | (op == isa.OP_JALR) | is_shift_imm | is_shift_reg
                 | is_slt)
    mix_idx = jnp.select(
        [op == isa.OP_LOAD, op == isa.OP_STORE, op == isa.OP_BRANCH,
         (op == isa.OP_JAL) | (op == isa.OP_JALR),
         is_shift_imm | is_shift_reg,
         (op == isa.OP_IMM) | (op == isa.OP_LUI) | (op == isa.OP_AUIPC),
         op == isa.OP_REG],
        [_MIX_IDX["loads"], _MIX_IDX["stores"], _MIX_IDX["branches"],
         _MIX_IDX["jumps"], _MIX_IDX["shifts"], _MIX_IDX["I-type"],
         _MIX_IDX["R-type"]],
        _MIX_IDX["system"])

    return ISSState(
        regs=regs,
        pc=next_pc.astype(I32),
        mem=mem,
        halted=s.halted | halt,
        n_instr=s.n_instr + 1,
        n_two_stage=s.n_two_stage + two_stage.astype(I32),
        mix=s.mix.at[mix_idx].add(1),
    )


@functools.partial(jax.jit, static_argnums=(2,))
def run(code: jax.Array, mem: jax.Array, max_steps: int) -> ISSState:
    """Run to ecall or max_steps. code: (P,) uint32; mem: (M,) int32."""
    s0 = init_state(mem)

    def cond(s):
        return (~s.halted) & (s.n_instr < max_steps)

    return lax.while_loop(cond, lambda s: step(code, s), s0)


def run_segment(code: jax.Array, s: ISSState, seg_steps: int,
                max_steps: int) -> ISSState:
    """Resume an ISSState for up to `seg_steps` further instructions.

    The segment primitive of the streaming fleet engine (DESIGN.md §9):
    running `run_segment` repeatedly until `halted` (or `n_instr` reaches
    `max_steps`) retires the exact same instruction sequence as a single
    `run` call, so segmented execution is bit-exact with the monolithic
    while_loop. Not jitted here — fleet/engine.py jits the vmapped form
    with buffer donation.
    """
    def cond(c):
        k, st = c
        return (~st.halted) & (k < seg_steps) & (st.n_instr < max_steps)

    def body(c):
        k, st = c
        return k + 1, step(code, st)

    _, out = lax.while_loop(cond, body, (jnp.zeros((), I32), s))
    return out


def run_fleet(code: jax.Array, mems: jax.Array, max_steps: int) -> ISSState:
    """vmap over a fleet of items with different memory images."""
    return jax.vmap(lambda m: run(code, m, max_steps))(mems)
