"""JAX RV32E instruction-set simulator — the paper's RTL characterization
loop re-thought for TPU: vmap-able over per-item memories (a *fleet* of
devices with different sensor inputs) and shard_map-able over the
production mesh (fleet/engine.py).

Two interpreters share the decode/commit semantics bit-exactly:

- `step` — scalar reference: decodes with bit ops, dispatches on opcode
  via lax.switch; `run`/`run_segment` wrap it in while_loops.
- `step_branchless`/`step_lanes` — the lane-parallel hot path
  (DESIGN.md §9.5): no switch, masked jnp.where/jnp.select commits, one
  shared memory port, one-hot register/mix updates, and a static
  opcode-subset mask for per-workload ISA specialization;
  `run_segment_lanes` steps a whole lane pool in one while_loop.

A third interpreter, the fused-segment Pallas stepper
(`kernels/iss_stepper.py`, DESIGN.md §9.7), ports the branchless commit
scheme into a single kernel per lane tile; any change to the commit
semantics here must be mirrored there (the instruction-soup tests in
tests/test_stepper.py pin all three against each other).

All three also run *banked* (DESIGN.md §9.8): lanes fetch from a padded
multi-program bank through `fetch_banked` (per-program pc clamp), carry
their program row and step budget in `PackedState`, and retire exactly
what a single-program pool running their program would — the packed
fleet runtime multiplexes a whole heterogeneous plan through one lane
pool on top of this.

Cycle accounting implements the paper's bit-serial timing model
(cycles.py): per retired instruction, one-stage or two-stage cost for the
configured datapath width. On top of the two-bucket counts every stepper
can carry a per-lane cycle tally (`ISSState.n_cycles`, DESIGN.md §9.10):
pass a `cost` row (cycles.cost_row) and each retired instruction adds its
(stage, mix-class) base ticks plus the dynamic terms the bucket model
cannot see — taken-branch refetch, per-bit serial shift, subword RMW.
With `cost=None` (the default) the timing layer is dropped from the
traced graph entirely and `n_cycles` passes through untouched.
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.flexibits import faults as flexifault
from repro.flexibits import isa
from repro.flexibits.cycles import (MIX_CLASSES, SHIFT_IDX, SUBWORD_IDX,
                                    TAKEN_IDX)

I32 = jnp.int32
U32 = jnp.uint32

# mix categories (Fig. 2a) — canonical order lives in cycles.MIX_CLASSES
_MIX_IDX = {c: i for i, c in enumerate(MIX_CLASSES)}

_OPCODES = (isa.OP_LUI, isa.OP_AUIPC, isa.OP_JAL, isa.OP_JALR,
            isa.OP_BRANCH, isa.OP_LOAD, isa.OP_STORE, isa.OP_IMM,
            isa.OP_REG, isa.OP_SYSTEM)


class ISSState(NamedTuple):
    regs: jax.Array        # (16,) int32
    pc: jax.Array          # () int32 (byte address)
    mem: jax.Array         # (M,) int32 word-addressed RAM
    halted: jax.Array      # () bool
    n_instr: jax.Array     # () int32
    n_two_stage: jax.Array  # () int32
    mix: jax.Array         # (8,) int32 per-category retired counts
    n_cycles: jax.Array    # () int32 accumulated timing ticks (§9.10)


class PackedState(NamedTuple):
    """Lane pool executing a *bank* of programs (DESIGN.md §9.8).

    The packed fleet runtime multiplexes every group of a heterogeneous
    `FleetPlan` through one lane pool: each lane carries the bank row of
    the program it is executing (`prog_id`) and its own retirement
    budget (`max_steps`, groups differ in step budget), both refilled
    alongside the architectural state when the admission scheduler
    assigns the lane a new item.
    """
    lanes: ISSState        # lane-batched architectural state
    prog_id: jax.Array     # (lanes,) int32 bank row per lane
    max_steps: jax.Array   # (lanes,) int32 per-lane step budget


def pack_programs(codes) -> "tuple[np.ndarray, np.ndarray]":
    """Pad programs into a (n_progs, max_len) int32 bank + length vector.

    Rows are zero-padded; the pad words are unreachable because every
    banked fetch clamps the pc to the row's own `code_len` (the same
    clamp-on-read semantics a single-program fetch gets from jax
    gathers, applied per program — see `fetch_banked`).
    """
    rows = [np.asarray(c) for c in codes]
    rows = [r.view(np.int32) if r.dtype.itemsize == 4 else
            r.astype(np.uint32).view(np.int32) for r in rows]
    max_len = max(len(r) for r in rows)
    bank = np.zeros((len(rows), max_len), np.int32)
    for i, r in enumerate(rows):
        bank[i, :len(r)] = r
    return bank, np.array([len(r) for r in rows], np.int32)


def fetch_banked(bank: jax.Array, code_len: jax.Array, prog_id: jax.Array,
                 pc: jax.Array) -> jax.Array:
    """Fetch instruction word(s) from a program bank (uint32 out).

    Bit-exact with the single-program fetch `code[pc >> 2]` run against
    each lane's own program: the word index clamps to that program's
    `code_len`, not the padded bank width, so a pc past a short
    program's end reads the program's *own* last word exactly as jax's
    clamp-on-read gather would. Shape-polymorphic over () and (lanes,).
    """
    pword = (_u(pc) >> 2).astype(I32)
    pword = jnp.clip(pword, 0, code_len[prog_id] - 1)
    return bank[prog_id, pword].astype(U32)


def init_state(mem: jax.Array) -> ISSState:
    return ISSState(
        regs=jnp.zeros(16, I32),
        pc=jnp.zeros((), I32),
        mem=mem.astype(I32),
        halted=jnp.zeros((), bool),
        n_instr=jnp.zeros((), I32),
        n_two_stage=jnp.zeros((), I32),
        mix=jnp.zeros(len(MIX_CLASSES), I32),
        n_cycles=jnp.zeros((), I32),
    )


def _sx(v, bits):
    shift = 32 - bits
    return (v.astype(I32) << shift) >> shift


def _u(v):
    return v.astype(U32)


def step(code: jax.Array, s: ISSState, *,
         instr: jax.Array = None, mem_len: jax.Array = None,
         cost: jax.Array = None, faults=None, lane_key: jax.Array = None,
         epoch: jax.Array = None) -> ISSState:
    # `instr` overrides the fetch (banked runtimes fetch from a program
    # bank via `fetch_banked`); `mem_len` bounds the data-memory ports at
    # the lane's OWN word count, so a lane in a pool padded to a larger
    # memory keeps jax's clamp-on-read / drop-on-write semantics at ITS
    # program's boundary; `cost` (an (N_COST,) cycles.cost_row) turns on
    # the per-lane timing tally; `faults` (a faults.FaultSpec, with the
    # lane's traced uint32 `lane_key` and int32 retry `epoch`) turns on
    # the post-commit fault transform (DESIGN.md §9.14) — None keeps it
    # out of the traced graph. Everything else is identical.
    if instr is None:
        instr = code[(_u(s.pc) >> 2).astype(I32)].astype(U32)
    ii = instr.astype(I32)
    op = (ii & 0x7F)
    rd = (ii >> 7) & 0xF
    f3 = (ii >> 12) & 0x7
    rs1 = (ii >> 15) & 0xF
    rs2 = (ii >> 20) & 0xF
    f7 = (ii >> 25) & 0x7F
    sub_bit = (ii >> 30) & 1

    imm_i = _sx(_u(instr) >> 20, 12)
    imm_s = _sx(((_u(instr) >> 25) << 5).astype(I32)
                | ((ii >> 7) & 0x1F), 12)
    imm_b = _sx(((ii >> 31) & 1) << 12 | ((ii >> 7) & 1) << 11
                | ((ii >> 25) & 0x3F) << 5 | ((ii >> 8) & 0xF) << 1, 13)
    imm_u = ii & jnp.asarray(-4096, I32)  # 0xFFFFF000 as a signed mask
    imm_j = _sx(((ii >> 31) & 1) << 20 | ((ii >> 12) & 0xFF) << 12
                | ((ii >> 20) & 1) << 11 | ((ii >> 21) & 0x3FF) << 1, 21)

    a = s.regs[rs1]
    b = s.regs[rs2]
    au = _u(a)
    bu = _u(b)
    pc4 = s.pc + 4

    def alu(x, y, f3v, is_sub, is_sra):
        sh = (y & 31).astype(U32)
        return lax.switch(f3v, [
            lambda: jnp.where(is_sub, x - y, x + y),
            lambda: (x.astype(U32) << sh).astype(I32),
            lambda: (x < y).astype(I32),
            lambda: (_u(x) < _u(y)).astype(I32),
            lambda: x ^ y,
            lambda: jnp.where(is_sra, x >> (y & 31),
                              (_u(x) >> sh).astype(I32)),
            lambda: x | y,
            lambda: x & y,
        ])

    # LOAD: word RMW for sub-word
    def do_load():
        addr = (a + imm_i).astype(I32)
        widx = _u(addr).astype(I32) >> 2
        if mem_len is not None:          # per-program clamp-on-read
            widx = jnp.clip(widx, 0, mem_len - 1)
        word = s.mem[widx]
        sh8 = ((addr & 3) * 8).astype(U32)
        byte = (_u(word) >> sh8).astype(I32) & 0xFF
        half_sh = ((addr & 2) * 8).astype(U32)
        half = (_u(word) >> half_sh).astype(I32) & 0xFFFF
        val = lax.switch(jnp.clip(f3, 0, 5), [
            lambda: _sx(byte, 8),            # lb
            lambda: _sx(half, 16),           # lh
            lambda: word,                    # lw
            lambda: word,                    # (unused f3=3)
            lambda: byte,                    # lbu
            lambda: half,                    # lhu
        ])
        return val, pc4, s.mem, False

    def do_store():
        addr = (a + imm_s).astype(I32)
        widx = _u(addr).astype(I32) >> 2
        ridx = widx if mem_len is None \
            else jnp.clip(widx, 0, mem_len - 1)
        word = s.mem[ridx]
        sh8 = ((addr & 3) * 8).astype(U32)
        sh16 = ((addr & 2) * 8).astype(U32)
        bmask = (jnp.asarray(0xFF, U32) << sh8).astype(I32)
        hmask = (jnp.asarray(0xFFFF, U32) << sh16).astype(I32)
        neww = lax.switch(jnp.clip(f3, 0, 2), [
            lambda: (word & ~bmask) | (((b & 0xFF).astype(U32) << sh8
                                        ).astype(I32) & bmask),
            lambda: (word & ~hmask) | (((b & 0xFFFF).astype(U32) << sh16
                                        ).astype(I32) & hmask),
            lambda: b,
        ])
        if mem_len is not None:          # per-program drop-on-write
            neww = jnp.where(widx < mem_len, neww, s.mem[widx])
        return jnp.zeros((), I32), pc4, s.mem.at[widx].set(neww), False

    def do_branch():
        cond = lax.switch(f3, [
            lambda: a == b, lambda: a != b,
            lambda: jnp.zeros((), bool), lambda: jnp.zeros((), bool),
            lambda: a < b, lambda: a >= b,
            lambda: au < bu, lambda: au >= bu,
        ])
        return jnp.zeros((), I32), \
            jnp.where(cond, s.pc + imm_b, pc4), s.mem, False

    cases = [
        lambda: (imm_u, pc4, s.mem, False),                       # LUI
        lambda: (s.pc + imm_u, pc4, s.mem, False),                # AUIPC
        lambda: (pc4, s.pc + imm_j, s.mem, False),                # JAL
        lambda: (pc4, (a + imm_i) & ~1, s.mem, False),            # JALR
        do_branch,                                                # BRANCH
        do_load,                                                  # LOAD
        do_store,                                                 # STORE
        lambda: (alu(a, imm_i, f3,                                # OP-IMM
                     jnp.zeros((), bool),
                     (f3 == 5) & (sub_bit == 1)),
                 pc4, s.mem, False),
        lambda: (alu(a, b, f3, sub_bit == 1, sub_bit == 1),       # OP-REG
                 pc4, s.mem, False),
        lambda: (jnp.zeros((), I32), pc4, s.mem, True),           # SYSTEM
    ]
    case_idx = jnp.searchsorted(jnp.asarray(sorted(_OPCODES), I32), op)
    # map sorted position back to case order
    sorted_ops = sorted(_OPCODES)
    perm = [sorted_ops.index(o) for o in _OPCODES]
    inv = [0] * len(_OPCODES)
    for ci, po in enumerate(perm):
        inv[po] = ci
    wr, next_pc, mem, halt = lax.switch(case_idx,
                                        [cases[i] for i in inv])

    writes_rd = (op != isa.OP_BRANCH) & (op != isa.OP_STORE) \
        & (op != isa.OP_SYSTEM) & (rd != 0)
    regs = s.regs.at[rd].set(jnp.where(writes_rd, wr, s.regs[rd]))

    # ---- classification: two-stage + mix category
    is_shift_imm = (op == isa.OP_IMM) & ((f3 == 1) | (f3 == 5))
    is_shift_reg = (op == isa.OP_REG) & ((f3 == 1) | (f3 == 5))
    is_slt = ((op == isa.OP_IMM) | (op == isa.OP_REG)) \
        & ((f3 == 2) | (f3 == 3))
    two_stage = ((op == isa.OP_LOAD) | (op == isa.OP_STORE)
                 | (op == isa.OP_BRANCH) | (op == isa.OP_JAL)
                 | (op == isa.OP_JALR) | is_shift_imm | is_shift_reg
                 | is_slt)
    mix_idx = jnp.select(
        [op == isa.OP_LOAD, op == isa.OP_STORE, op == isa.OP_BRANCH,
         (op == isa.OP_JAL) | (op == isa.OP_JALR),
         is_shift_imm | is_shift_reg,
         (op == isa.OP_IMM) | (op == isa.OP_LUI) | (op == isa.OP_AUIPC),
         op == isa.OP_REG],
        [_MIX_IDX["loads"], _MIX_IDX["stores"], _MIX_IDX["branches"],
         _MIX_IDX["jumps"], _MIX_IDX["shifts"], _MIX_IDX["I-type"],
         _MIX_IDX["R-type"]],
        _MIX_IDX["system"])

    n_cycles = s.n_cycles
    if cost is not None:
        taken, shamt, subword = dynamic_terms(op, f3, a, b, imm_i)
        n_cycles = n_cycles + timing_ticks(cost, two_stage, mix_idx,
                                           taken, shamt, subword)

    out = ISSState(
        regs=regs,
        pc=next_pc.astype(I32),
        mem=mem,
        halted=s.halted | halt,
        n_instr=s.n_instr + 1,
        n_two_stage=s.n_two_stage + two_stage.astype(I32),
        mix=s.mix.at[mix_idx].add(1),
        n_cycles=n_cycles,
    )
    if faults is not None:
        # post-commit fault transform: the switch stepper only runs a
        # step while live, so the gate is just post-commit ~halted
        out = flexifault.apply_faults(faults, lane_key, epoch, out,
                                      mem_len=mem_len)
    return out


# ---------------------------------------------------------------------------
# Lane-parallel branchless stepper (DESIGN.md §9.5)
#
# Under vmap, `step`'s lax.switch executes every opcode branch for every
# lane anyway (batched switch lowers to select-of-all-branches) — but each
# branch re-derives its own addresses and issues its own gather/scatter.
# The branchless stepper makes the all-branches cost explicit and amortized:
# one decode, ONE memory gather shared by loads and stores, ONE scatter,
# and masked jnp.where/jnp.select commits. A static opcode-subset mask
# (per-workload ISA subset, à la RISC-V instruction-subset processors)
# drops whole opcode classes from the graph at trace time, so XLA never
# even compiles classes a workload cannot retire.
# ---------------------------------------------------------------------------

FULL_SUBSET = frozenset(_OPCODES)


# Shape-polymorphic pieces of the branchless step, shared verbatim by the
# scalar `step_branchless` (vmapped by `step_lanes`) and the lane-tile
# vectorized Pallas kernel (kernels/iss_stepper.py): the arithmetic is
# elementwise, so one definition serves () and (lanes,) operands alike
# and the two steppers cannot drift.

class DecodedInstr(NamedTuple):
    op: jax.Array
    rd: jax.Array
    f3: jax.Array
    rs1: jax.Array
    rs2: jax.Array
    sub_bit: jax.Array
    imm_i: jax.Array
    imm_s: jax.Array
    imm_b: jax.Array
    imm_u: jax.Array
    imm_j: jax.Array


def decode_fields(instr: jax.Array) -> DecodedInstr:
    """Bit-op decode of fetched instruction word(s) (uint32 in)."""
    ii = instr.astype(I32)
    return DecodedInstr(
        op=ii & 0x7F,
        rd=(ii >> 7) & 0xF,
        f3=(ii >> 12) & 0x7,
        rs1=(ii >> 15) & 0xF,
        rs2=(ii >> 20) & 0xF,
        sub_bit=(ii >> 30) & 1,
        imm_i=_sx(_u(instr) >> 20, 12),
        imm_s=_sx(((_u(instr) >> 25) << 5).astype(I32)
                  | ((ii >> 7) & 0x1F), 12),
        imm_b=_sx(((ii >> 31) & 1) << 12 | ((ii >> 7) & 1) << 11
                  | ((ii >> 25) & 0x3F) << 5 | ((ii >> 8) & 0xF) << 1, 13),
        imm_u=ii & jnp.asarray(-4096, I32),
        imm_j=_sx(((ii >> 31) & 1) << 20 | ((ii >> 12) & 0xFF) << 12
                  | ((ii >> 20) & 1) << 11 | ((ii >> 21) & 0x3FF) << 1, 21),
    )


def alu_result(a, y, f3, is_sub, is_sra):
    """Shared OP-IMM/OP-REG ALU: f3-selected branchless result."""
    au = _u(a)
    sh = (y & 31).astype(U32)
    return jnp.select(
        [f3 == 0, f3 == 1, f3 == 2, f3 == 3, f3 == 4, f3 == 5, f3 == 6],
        [jnp.where(is_sub, a - y, a + y),
         (au << sh).astype(I32),
         (a < y).astype(I32),
         (au < _u(y)).astype(I32),
         a ^ y,
         jnp.where(is_sra, a >> (y & 31), (au >> sh).astype(I32)),
         a | y], a & y)


def branch_taken(a, b, f3):
    """BRANCH condition select (f3 in {2,3} never taken, as in `step`)."""
    false = jnp.zeros_like(a, bool)
    au, bu = _u(a), _u(b)
    return jnp.select(
        [f3 == 0, f3 == 1, f3 == 2, f3 == 3, f3 == 4, f3 == 5, f3 == 6],
        [a == b, a != b, false, false, a < b, a >= b, au < bu],
        au >= bu)


def load_value(word, addr, f3):
    """Sub-word load extraction from the fetched memory word."""
    sh8 = ((addr & 3) * 8).astype(U32)
    sh16 = ((addr & 2) * 8).astype(U32)
    byte = (_u(word) >> sh8).astype(I32) & 0xFF
    half = (_u(word) >> sh16).astype(I32) & 0xFFFF
    lf3 = jnp.clip(f3, 0, 5)       # matches step's clipped switch
    return jnp.select(
        [lf3 == 0, lf3 == 1, lf3 == 4, lf3 == 5],
        [_sx(byte, 8), _sx(half, 16), byte, half], word)


def store_word(word, addr, b, f3):
    """Read-modify-write merge of the store value into the memory word."""
    sh8 = ((addr & 3) * 8).astype(U32)
    sh16 = ((addr & 2) * 8).astype(U32)
    bmask = (jnp.asarray(0xFF, U32) << sh8).astype(I32)
    hmask = (jnp.asarray(0xFFFF, U32) << sh16).astype(I32)
    sf3 = jnp.clip(f3, 0, 2)
    return jnp.select(
        [sf3 == 0, sf3 == 1],
        [(word & ~bmask) | (((b & 0xFF).astype(U32) << sh8
                             ).astype(I32) & bmask),
         (word & ~hmask) | (((b & 0xFFFF).astype(U32) << sh16
                             ).astype(I32) & hmask)], b)


def branchless_commits(d: DecodedInstr, a, b, pc, subset, live, *,
                       read_word, write_word, cost=None):
    """Opcode-gated commit pipeline shared by `step_branchless` and the
    Pallas tile stepper (kernels/iss_stepper.py).

    Computes every commit value — next pc, rd write value/predicate,
    halt, timing class, mix category, and the updated memory — from the
    decoded fields and register operands. Only the memory *ports* are
    injected, because that is all that differs between the steppers
    (indexed gather/scatter vs masked one-hot):

      read_word(widx) -> word          fetched memory word per lane
      write_word(widx, word, neww, is_store) -> mem   committed memory

    `subset` (static) drops opcode classes from the traced graph;
    `live=False` freezes stores, rd writes, and counters. All arithmetic
    is shape-polymorphic over () and (lanes,) operands.

    Returns (next_pc, wr, writes_rd, mem, halt, two_stage, mix_idx,
    ticks); `mem` is None when the subset contains no stores, and
    `ticks` is None when `cost` is None (the timing layer contributes
    nothing to the traced graph when off — cycles-off is the unchanged
    PR-5 graph, not a zeroed tally).
    """
    sub = FULL_SUBSET if subset is None else frozenset(subset)

    def on(*ops):
        return any(o in sub for o in ops)

    op, rd, f3 = d.op, d.rd, d.f3
    pc4 = pc + 4
    false = jnp.zeros_like(live)
    zero = jnp.zeros_like(pc)

    is_load = (op == isa.OP_LOAD) if on(isa.OP_LOAD) else false
    is_store = ((op == isa.OP_STORE) & live) if on(isa.OP_STORE) else false

    # ---- shared memory word port: one read serves loads AND stores
    mem_val = zero
    mem = None
    if on(isa.OP_LOAD, isa.OP_STORE):
        addr = (a + jnp.where(is_store, d.imm_s, d.imm_i)).astype(I32)
        widx = jnp.where(is_load | is_store, _u(addr).astype(I32) >> 2, 0)
        word = read_word(widx)
        if on(isa.OP_LOAD):
            mem_val = load_value(word, addr, f3)
        if on(isa.OP_STORE):
            mem = write_word(widx, word, store_word(word, addr, b, f3),
                             is_store)

    # ---- shared ALU serves OP-IMM and OP-REG
    alu_res = zero
    if on(isa.OP_IMM, isa.OP_REG):
        is_reg = (op == isa.OP_REG) if on(isa.OP_REG) else false
        y = jnp.where(is_reg, b, d.imm_i)
        alu_res = alu_result(a, y, f3,
                             is_sub=is_reg & (d.sub_bit == 1),
                             is_sra=(f3 == 5) & (d.sub_bit == 1))

    # ---- next pc
    next_pc = pc4
    if on(isa.OP_BRANCH):
        next_pc = jnp.where(op == isa.OP_BRANCH,
                            jnp.where(branch_taken(a, b, f3),
                                      pc + d.imm_b, pc4), next_pc)
    if on(isa.OP_JAL):
        next_pc = jnp.where(op == isa.OP_JAL, pc + d.imm_j, next_pc)
    if on(isa.OP_JALR):
        next_pc = jnp.where(op == isa.OP_JALR, (a + d.imm_i) & ~1, next_pc)

    # ---- rd write value
    wr = zero
    if on(isa.OP_LUI):
        wr = jnp.where(op == isa.OP_LUI, d.imm_u, wr)
    if on(isa.OP_AUIPC):
        wr = jnp.where(op == isa.OP_AUIPC, pc + d.imm_u, wr)
    if on(isa.OP_JAL, isa.OP_JALR):
        wr = jnp.where((op == isa.OP_JAL) | (op == isa.OP_JALR), pc4, wr)
    if on(isa.OP_LOAD):
        wr = jnp.where(is_load, mem_val, wr)
    if on(isa.OP_IMM, isa.OP_REG):
        wr = jnp.where((op == isa.OP_IMM) | (op == isa.OP_REG),
                       alu_res, wr)

    writes_rd = (op != isa.OP_BRANCH) & (op != isa.OP_STORE) \
        & (op != isa.OP_SYSTEM) & (rd != 0) & live
    halt = (op == isa.OP_SYSTEM) if on(isa.OP_SYSTEM) else false
    two_stage, mix_idx = classify(op, f3)
    ticks = None
    if cost is not None:
        taken, shamt, subword = dynamic_terms(op, f3, a, b, d.imm_i,
                                              subset)
        ticks = timing_ticks(cost, two_stage, mix_idx, taken, shamt,
                             subword)
    return next_pc, wr, writes_rd, mem, halt, two_stage, mix_idx, ticks


def classify(op, f3):
    """(two_stage, mix_idx) per retired instruction — the paper's
    bit-serial timing classes and Fig. 2a mix categories. Identical
    arithmetic to the tail of `step`."""
    is_shift_imm = (op == isa.OP_IMM) & ((f3 == 1) | (f3 == 5))
    is_shift_reg = (op == isa.OP_REG) & ((f3 == 1) | (f3 == 5))
    is_slt = ((op == isa.OP_IMM) | (op == isa.OP_REG)) \
        & ((f3 == 2) | (f3 == 3))
    two_stage = ((op == isa.OP_LOAD) | (op == isa.OP_STORE)
                 | (op == isa.OP_BRANCH) | (op == isa.OP_JAL)
                 | (op == isa.OP_JALR) | is_shift_imm | is_shift_reg
                 | is_slt)
    mix_idx = jnp.select(
        [op == isa.OP_LOAD, op == isa.OP_STORE, op == isa.OP_BRANCH,
         (op == isa.OP_JAL) | (op == isa.OP_JALR),
         is_shift_imm | is_shift_reg,
         (op == isa.OP_IMM) | (op == isa.OP_LUI) | (op == isa.OP_AUIPC),
         op == isa.OP_REG],
        [_MIX_IDX["loads"], _MIX_IDX["stores"], _MIX_IDX["branches"],
         _MIX_IDX["jumps"], _MIX_IDX["shifts"], _MIX_IDX["I-type"],
         _MIX_IDX["R-type"]],
        _MIX_IDX["system"])
    return two_stage, mix_idx


def dynamic_terms(op, f3, a, b, imm_i, subset: frozenset = None):
    """Per-instruction dynamic timing events (DESIGN.md §9.10).

    The microarchitectural events the two-bucket model cannot see,
    mirrored verbatim by the PyISS oracle:

      taken   — a BRANCH whose condition held (refetch; jumps always
                redirect and are priced in their base class instead)
      shamt   — effective shift amount of a serial shift (0 otherwise)
      subword — lb/lh/lbu/lhu/sb/sh (read-modify-write word pass)

    `subset` drops the classes from the traced graph exactly like
    `branchless_commits` does. Shape-polymorphic over () and (lanes,).
    """
    sub = FULL_SUBSET if subset is None else frozenset(subset)

    def on(*ops):
        return any(o in sub for o in ops)

    false = jnp.zeros_like(op, bool)
    zero = jnp.zeros_like(op)

    taken = ((op == isa.OP_BRANCH) & branch_taken(a, b, f3)) \
        if on(isa.OP_BRANCH) else false

    shamt = zero
    if on(isa.OP_IMM, isa.OP_REG):
        is_shift = (((op == isa.OP_IMM) | (op == isa.OP_REG))
                    & ((f3 == 1) | (f3 == 5)))
        shamt = jnp.where(is_shift,
                          jnp.where(op == isa.OP_REG, b, imm_i) & 31, 0)

    subword = false
    if on(isa.OP_LOAD):
        lf3 = jnp.clip(f3, 0, 5)       # matches load_value's clip
        subword = subword | ((op == isa.OP_LOAD)
                             & (lf3 != 2) & (lf3 != 3))
    if on(isa.OP_STORE):
        sf3 = jnp.clip(f3, 0, 2)       # matches store_word's clip
        subword = subword | ((op == isa.OP_STORE) & (sf3 != 2))
    return taken, shamt, subword


def timing_ticks(cost, two_stage, mix_idx, taken, shamt, subword):
    """Ticks retired by one instruction under cost row(s) `cost`.

    `cost` is (..., N_COST): one shared row, or per-lane rows gathered
    from a per-program cost bank. The (stage, mix-class) base entry is
    selected with a one-hot reduction over the 8 classes (no gathers —
    the same trick as the register/mix commits, so the Pallas stepper
    runs it unchanged), then the dynamic entries are added in.
    """
    n = len(MIX_CLASSES)
    oh = jnp.arange(n, dtype=I32) == mix_idx[..., None]
    one_base = jnp.sum(jnp.where(oh, cost[..., :n], 0), axis=-1)
    two_base = jnp.sum(jnp.where(oh, cost[..., n:2 * n], 0), axis=-1)
    base = jnp.where(two_stage, two_base, one_base)
    return (base + taken.astype(I32) * cost[..., TAKEN_IDX]
            + shamt * cost[..., SHIFT_IDX]
            + subword.astype(I32) * cost[..., SUBWORD_IDX])


def opcode_subset(code, reachable_only: bool = False) -> frozenset:
    """Static host-side decode: the opcode classes present in a program.

    Only opcodes that appear in the program text can ever retire (the pc
    always fetches from `code`), so this is a sound per-workload ISA
    subset for `step_branchless`/`step_lanes`.

    `reachable_only=True` tightens the set to opcodes of CFG-reachable
    words via FlexiLint (DESIGN.md §9.11): dead code never retires
    *live* — halted lanes keep fetching the word after their ecall, but
    every commit (and tick tally) is `live`-masked, so dropping
    unreachable opcode classes stays bit-exact. Falls back to the text
    subset when the CFG degrades (indirect jumps etc.).
    """
    if reachable_only:
        from repro.flexibits import analyze
        return analyze.analyze_code(code, mem_words=1).subset
    words = np.asarray(code)
    words = words.view(np.uint32) if words.dtype.itemsize == 4 \
        else words.astype(np.uint32)
    present = {int(o) for o in np.unique(words & np.uint32(0x7F))}
    return frozenset(o for o in _OPCODES if o in present)


def step_branchless(code: jax.Array, s: ISSState,
                    subset: frozenset = None,
                    active: jax.Array = None, *,
                    instr: jax.Array = None,
                    mem_len: jax.Array = None,
                    cost: jax.Array = None, faults=None,
                    lane_key: jax.Array = None,
                    epoch: jax.Array = None) -> ISSState:
    """One branchless step: bit-exact with `step`, no lax.switch/cond.

    `subset` (static) keeps only those opcode classes in the traced graph;
    it must be a superset of `opcode_subset(code)` for bit-exactness.
    `active=False` freezes the state entirely (used by the segment loop to
    park halted lanes without a pytree-wide post-select). `instr`
    overrides the fetch (the packed runtime fetches from a program bank
    with `fetch_banked`) and `mem_len` bounds the memory ports at the
    lane's own word count (clamp-on-read / drop-on-write at the
    program's boundary even when the pool's memory is padded wider);
    the commit pipeline is shared either way.

    Bit-exactness is defined over programs whose fetched words decode to
    RV32E opcodes (everything asm.py / FlexiBench emit). For a word whose
    opcode is outside the ISA both interpreters are junk — `step`'s
    clamped searchsorted dispatches to an arbitrary neighboring class,
    this one retires a no-op — and neither behavior is contractual.
    """
    if instr is None:
        instr = code[(_u(s.pc) >> 2).astype(I32)].astype(U32)
    d = decode_fields(instr)
    a = s.regs[d.rs1]
    b = s.regs[d.rs2]
    live = jnp.ones((), bool) if active is None else active

    def read_word(widx):
        if mem_len is not None:
            widx = jnp.clip(widx, 0, mem_len - 1)
        return s.mem[widx]

    def write_word(widx, word, neww, is_store):
        # non-stores write word back to itself at index 0: a no-op,
        # so the scatter needs no predication beyond the value select.
        # With a per-lane mem bound, a store past the lane's OWN word
        # count also degrades to the no-op write-back (the padded pool
        # drop-on-write); the clamped-read `word` may land in the pad
        # region then, which nothing — port, fetch, or demux — ever
        # reads back.
        if mem_len is not None:
            is_store = is_store & (widx < mem_len)
        return s.mem.at[widx].set(jnp.where(is_store, neww, word))

    next_pc, wr, writes_rd, mem, halt, two_stage, mix_idx, ticks = \
        branchless_commits(d, a, b, s.pc, subset, live,
                           read_word=read_word, write_word=write_word,
                           cost=cost)
    mem = s.mem if mem is None else mem

    # one-hot commit instead of a scatter: an elementwise select over the
    # 16-entry register file fuses into the surrounding arithmetic, where
    # a 1-element scatter is a separate kernel per step on CPU/TPU
    regs = jnp.where((jnp.arange(16, dtype=I32) == d.rd) & writes_rd,
                     wr, s.regs)

    one = live.astype(I32)
    mix_onehot = (jnp.arange(len(MIX_CLASSES), dtype=I32)
                  == mix_idx).astype(I32) * one
    out = ISSState(
        regs=regs,
        pc=jnp.where(live, next_pc.astype(I32), s.pc),
        mem=mem,
        halted=s.halted | (halt & live),
        n_instr=s.n_instr + one,
        n_two_stage=s.n_two_stage + (two_stage & live).astype(I32),
        mix=s.mix + mix_onehot,
        n_cycles=s.n_cycles if ticks is None else s.n_cycles + ticks * one,
    )
    if faults is not None:
        # post-commit fault transform (DESIGN.md §9.14): gated on the
        # lane having retired live AND not halted on this very step —
        # parked lanes draw nothing, and a flip in the halting cycle is
        # architecturally unobservable (identical in every stepper and
        # in the PyISS oracle's post_commit hook)
        out = flexifault.apply_faults(faults, lane_key, epoch, out,
                                      live=live, mem_len=mem_len)
    return out


def step_lanes(code: jax.Array, states: ISSState,
               subset: frozenset = None,
               active: jax.Array = None,
               cost: jax.Array = None, faults=None,
               lane_key: jax.Array = None,
               epoch: jax.Array = None) -> ISSState:
    """Branchless step over a batch of lanes (leading lane axis).

    Decodes once per lane with pure bit ops; every opcode class commits
    via masked where/select, so vmap pays one shared gather + scatter
    instead of per-branch memory ports. Bit-exact with vmap(step).
    `cost` is one shared (N_COST,) row — homogeneous pools run one
    program on one core, so it closes over the vmap unbatched.
    `faults` turns on the per-lane post-commit fault transform
    (`lane_key`/`epoch` are (lanes,) arrays).
    """
    if faults is not None:
        act = jnp.ones(states.pc.shape, bool) if active is None else active
        return jax.vmap(
            lambda a, k, e, s: step_branchless(
                code, s, subset, active=a, cost=cost, faults=faults,
                lane_key=k, epoch=e))(act, lane_key, epoch, states)
    if active is None:
        return jax.vmap(
            lambda s: step_branchless(code, s, subset, cost=cost))(states)
    return jax.vmap(
        lambda a, s: step_branchless(code, s, subset, active=a, cost=cost)
    )(active, states)


def run_segment_lanes(code: jax.Array, states: ISSState, seg_steps: int,
                      max_steps: int, subset: frozenset = None,
                      unroll: int = 1,
                      cost: jax.Array = None, faults=None,
                      lane_key: jax.Array = None,
                      epoch: jax.Array = None) -> ISSState:
    """Lane-parallel segment: up to `seg_steps` branchless steps per lane.

    One while_loop over the whole lane pool (not vmap of scalar loops):
    each iteration advances every still-active lane; lanes that halt or
    exhaust `max_steps` are frozen in place by the `active` mask. The body
    can unroll `unroll` steps per loop trip (substeps past `seg_steps`
    are masked out, so segment boundaries stay exact); the default is 1 —
    on CPU the one-hot-commit step body fuses into few kernels and
    unrolling only bloats codegen, but accelerators with costlier loop
    turnaround can profit. Execution retires the same instruction
    sequence as vmapped `run_segment`, so segmented execution stays
    bit-exact with `iss.run`.
    """
    unroll = max(1, min(unroll, seg_steps))

    def active_of(st: ISSState) -> jax.Array:
        return (~st.halted) & (st.n_instr < max_steps)

    def cond(c):
        k, st = c
        return (k < seg_steps) & active_of(st).any()

    def body(c):
        k, st = c
        for j in range(unroll):
            act = active_of(st) & (k + j < seg_steps)
            st = step_lanes(code, st, subset, active=act, cost=cost,
                            faults=faults, lane_key=lane_key, epoch=epoch)
        return k + unroll, st

    _, out = lax.while_loop(cond, body, (jnp.zeros((), I32), states))
    return out


def step_lanes_banked(bank: jax.Array, code_len: jax.Array,
                      states: ISSState, prog_id: jax.Array,
                      subset: frozenset = None,
                      active: jax.Array = None,
                      mem_len: jax.Array = None,
                      cost: jax.Array = None, faults=None,
                      lane_key: jax.Array = None,
                      epoch: jax.Array = None) -> ISSState:
    """Branchless step over lanes executing *different* programs.

    One batched bank fetch (`fetch_banked`, per-program pc clamp), then
    the exact `step_branchless` commit pipeline per lane — so a lane
    retires precisely what it would retire in a single-program pool
    running its own program. `subset` must cover the union of the bank's
    opcode subsets for bit-exactness; `mem_len` (per-LANE word counts)
    bounds each lane's memory ports at its own program's size; `cost`
    (per-LANE (lanes, N_COST) rows — groups price on different cores)
    turns on the per-lane timing tally.
    """
    instr = fetch_banked(bank, code_len, prog_id, states.pc)
    act = jnp.ones(states.pc.shape, bool) if active is None else active
    if faults is not None:
        # per-lane fault keys/epochs batch; mem_len/cost stay optional
        # (None broadcasts through the vmap as an empty pytree)
        return jax.vmap(
            lambda i, a, m, c, k, e, s: step_branchless(
                bank, s, subset, active=a, instr=i, mem_len=m, cost=c,
                faults=faults, lane_key=k, epoch=e),
            in_axes=(0, 0, None if mem_len is None else 0,
                     None if cost is None else 0, 0, 0, 0),
        )(instr, act, mem_len, cost, lane_key, epoch, states)
    if mem_len is None and cost is None:
        return jax.vmap(
            lambda i, a, s: step_branchless(bank, s, subset, active=a,
                                            instr=i)
        )(instr, act, states)
    if cost is None:
        return jax.vmap(
            lambda i, a, m, s: step_branchless(bank, s, subset, active=a,
                                               instr=i, mem_len=m)
        )(instr, act, mem_len, states)
    if mem_len is None:
        return jax.vmap(
            lambda i, a, c, s: step_branchless(bank, s, subset, active=a,
                                               instr=i, cost=c)
        )(instr, act, cost, states)
    return jax.vmap(
        lambda i, a, m, c, s: step_branchless(bank, s, subset, active=a,
                                              instr=i, mem_len=m, cost=c)
    )(instr, act, mem_len, cost, states)


def run_segment_lanes_banked(bank: jax.Array, code_len: jax.Array,
                             ps: PackedState, seg_steps: int,
                             subset: frozenset = None,
                             mem_len: jax.Array = None,
                             cost: jax.Array = None, faults=None,
                             lane_key: jax.Array = None,
                             epoch: jax.Array = None) -> PackedState:
    """Packed segment: up to `seg_steps` banked steps for every lane.

    The packed-runtime counterpart of `run_segment_lanes`: one
    while_loop over the whole heterogeneous lane pool. Each lane runs
    its own program (`prog_id`) against its own retirement budget
    (`ps.max_steps`, a traced per-lane array rather than a static int,
    because groups in one pool have different budgets); lanes that halt
    or exhaust their budget are frozen by the `active` mask exactly as
    in the homogeneous segment loop. `mem_len` (per-PROGRAM word
    counts, like `code_len`) keeps each lane's memory semantics at its
    own program's boundary when the pool memory is padded wider; `cost`
    (per-PROGRAM (n_progs, N_COST) rows, like `mem_len`) prices each
    lane's retirements on its own program's core; `faults` (with
    per-LANE `lane_key`/`epoch` arrays — fault schedules belong to the
    physical lane, not the program) turns on the post-commit fault
    transform (DESIGN.md §9.14).
    """
    lane_mlen = None if mem_len is None else mem_len[ps.prog_id]
    lane_cost = None if cost is None else cost[ps.prog_id]

    def active_of(st: ISSState) -> jax.Array:
        return (~st.halted) & (st.n_instr < ps.max_steps)

    def cond(c):
        k, st = c
        return (k < seg_steps) & active_of(st).any()

    def body(c):
        k, st = c
        return k + 1, step_lanes_banked(bank, code_len, st, ps.prog_id,
                                        subset, active=active_of(st),
                                        mem_len=lane_mlen,
                                        cost=lane_cost, faults=faults,
                                        lane_key=lane_key, epoch=epoch)

    _, out = lax.while_loop(cond, body, (jnp.zeros((), I32), ps.lanes))
    return PackedState(lanes=out, prog_id=ps.prog_id,
                       max_steps=ps.max_steps)


# ---------------------------------------------------------------------------
# Device-side refill / compaction helpers (DESIGN.md §9.9)
#
# The resident packed runtime never ships lane state to the host between
# segments: retired lanes are detected, their tallies scattered into
# on-device result accumulators, and fresh items swapped in from a staged
# buffer — all inside one jitted, donated op (fleet/engine.py). The
# *semantics* of that swap live here, `branchless_commits`-style: one
# shape-polymorphic definition shared by every stepper, with a banked
# Pallas variant (`kernels/iss_stepper.py::iss_refill`) that reproduces
# the same swap through one-hot ports and must stay bit-identical
# (pinned by tests/test_resident.py).
# ---------------------------------------------------------------------------


def retire_mask(ps: PackedState, item_slot: jax.Array) -> jax.Array:
    """Lanes whose item just finished: occupied (`item_slot >= 0`) and
    halted or out of their OWN step budget. Parked lanes (slot -1) are
    free but have nothing to retire; padding lanes stay parked forever.
    """
    return (item_slot >= 0) & (ps.lanes.halted
                               | (ps.lanes.n_instr >= ps.max_steps))


def refill_take(free: jax.Array, n_staged: jax.Array):
    """Deterministic staged->lane assignment for an on-device refill.

    Free lanes are ranked in lane order (a cumsum compaction — the
    device-side analogue of the host path's `np.nonzero(done)` index
    walk); the first `n_staged` of them take staged rows 0..n_staged-1
    in order, so the host — which built the staged batch and will learn
    only the *count* consumed — always knows exactly which item went
    where it matters (into the stream) without reading any lane state.

    Returns `(take, src)`: `take[l]` marks lanes that swap in a fresh
    item, `src[l]` is the staged row a taking lane reads (clipped for
    non-taking lanes, whose gathers are discarded).
    """
    rank = jnp.cumsum(free.astype(I32)) - 1
    take = free & (rank < n_staged)
    src = jnp.clip(rank, 0, free.shape[0] - 1)
    return take, src


def refill_lanes(ps: PackedState, take: jax.Array, src: jax.Array,
                 staged_mems: jax.Array, staged_prog: jax.Array,
                 staged_ms: jax.Array) -> PackedState:
    """Swap fresh items into `take` lanes from staged rows `src`.

    The jnp form of the resident swap (gather staged rows, masked
    reset of the architectural state) — used by the branchless and
    switch steppers; the Pallas stepper's variant
    (`kernels/iss_stepper.py::iss_refill`) expresses the same gather
    as a one-hot reduction and is bit-identical.
    """
    t1 = take[:, None]
    lanes = ps.lanes
    return PackedState(
        lanes=ISSState(
            regs=jnp.where(t1, 0, lanes.regs),
            pc=jnp.where(take, 0, lanes.pc),
            mem=jnp.where(t1, staged_mems[src], lanes.mem),
            halted=jnp.where(take, False, lanes.halted),
            n_instr=jnp.where(take, 0, lanes.n_instr),
            n_two_stage=jnp.where(take, 0, lanes.n_two_stage),
            mix=jnp.where(t1, 0, lanes.mix),
            n_cycles=jnp.where(take, 0, lanes.n_cycles)),
        prog_id=jnp.where(take, staged_prog[src], ps.prog_id),
        max_steps=jnp.where(take, staged_ms[src], ps.max_steps))


@functools.partial(jax.jit, static_argnums=(2,))
def run(code: jax.Array, mem: jax.Array, max_steps: int,
        cost: jax.Array = None) -> ISSState:
    """Run to ecall or max_steps. code: (P,) uint32; mem: (M,) int32."""
    s0 = init_state(mem)

    def cond(s):
        return (~s.halted) & (s.n_instr < max_steps)

    return lax.while_loop(cond, lambda s: step(code, s, cost=cost), s0)


def run_segment(code: jax.Array, s: ISSState, seg_steps: int,
                max_steps: int, cost: jax.Array = None) -> ISSState:
    """Resume an ISSState for up to `seg_steps` further instructions.

    The segment primitive of the streaming fleet engine (DESIGN.md §9):
    running `run_segment` repeatedly until `halted` (or `n_instr` reaches
    `max_steps`) retires the exact same instruction sequence as a single
    `run` call, so segmented execution is bit-exact with the monolithic
    while_loop. Not jitted here — fleet/engine.py jits the vmapped form
    with buffer donation.
    """
    def cond(c):
        k, st = c
        return (~st.halted) & (k < seg_steps) & (st.n_instr < max_steps)

    def body(c):
        k, st = c
        return k + 1, step(code, st, cost=cost)

    _, out = lax.while_loop(cond, body, (jnp.zeros((), I32), s))
    return out


def run_segment_banked(bank: jax.Array, code_len: jax.Array,
                       prog_id: jax.Array, max_steps: jax.Array,
                       s: ISSState, seg_steps: int,
                       mem_len: jax.Array = None,
                       cost: jax.Array = None, faults=None,
                       lane_key: jax.Array = None,
                       epoch: jax.Array = None) -> ISSState:
    """Banked `run_segment`: the lax.switch interpreter fetching from a
    program bank (scalar state; the packed engine vmaps it per lane).
    `max_steps` is a traced scalar — each lane brings its own budget;
    `mem_len` (per-program word counts) bounds the lane's memory ports
    at its own program's size; `cost` (per-program rows) prices the
    lane's retirements on its own program's core; `faults` (with this
    lane's scalar `lane_key`/`epoch`) turns on the post-commit fault
    transform.
    """
    ml = None if mem_len is None else mem_len[prog_id]
    cr = None if cost is None else cost[prog_id]

    def cond(c):
        k, st = c
        return (~st.halted) & (k < seg_steps) & (st.n_instr < max_steps)

    def body(c):
        k, st = c
        instr = fetch_banked(bank, code_len, prog_id, st.pc)
        return k + 1, step(bank, st, instr=instr, mem_len=ml, cost=cr,
                           faults=faults, lane_key=lane_key, epoch=epoch)

    _, out = lax.while_loop(cond, body, (jnp.zeros((), I32), s))
    return out


def run_fleet(code: jax.Array, mems: jax.Array, max_steps: int,
              cost: jax.Array = None) -> ISSState:
    """vmap over a fleet of items with different memory images."""
    return jax.vmap(lambda m: run(code, m, max_steps, cost))(mems)
