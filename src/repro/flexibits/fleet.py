"""Fleet-scale ILI simulation: millions of items, each running the same
program on different sensor inputs, sharded across the production mesh.

This is the trillion-item adaptation of the paper's one-device RTL loop:
`vmap` over items within a shard, `shard_map` over the mesh's combined
(pod, data, model) axes (an ISS run has no cross-item communication, so
every mesh axis is pure data parallelism).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.flexibench.base import Workload
from repro.flexibits import iss
from repro.flexibits.cycles import Core


def fleet_inputs(w: Workload, n_items: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    xs = w.gen_inputs(rng, n_items)
    base = w.initial_memory(np.zeros(w.n_inputs, np.int32))
    mems = np.tile(base, (n_items, 1))
    mems[:, :xs.shape[1]] = xs
    return mems


def run_fleet_sharded(w: Workload, mems: np.ndarray, mesh: Mesh):
    """Run the fleet with items sharded over every mesh axis."""
    code = jnp.asarray(w.program.code.view(np.int32))
    axes = tuple(mesh.axis_names)
    spec = P(axes)

    @functools.partial(
        shard_map, mesh=mesh, in_specs=(spec,),
        out_specs=iss.ISSState(
            regs=spec, pc=spec, mem=spec, halted=spec, n_instr=spec,
            n_two_stage=spec, mix=spec),
        check_rep=False)
    def shard_run(mems_shard):
        return jax.vmap(lambda m: iss.run(code, m, w.max_steps))(mems_shard)

    return jax.jit(shard_run)(jnp.asarray(mems))


def fleet_energy_kwh(state: iss.ISSState, core: Core,
                     vm_kb: float, clock_hz: float = 10_000.0) -> float:
    """Total fleet energy for one execution per item."""
    from repro.flexibits.cycles import system_power_mw
    n_one = np.asarray(state.n_instr - state.n_two_stage, np.float64)
    n_two = np.asarray(state.n_two_stage, np.float64)
    cycles = (n_one * core.cycles_one_stage()
              + n_two * core.cycles_two_stage())
    seconds = cycles / clock_hz
    joules = system_power_mw(core, vm_kb) * 1e-3 * seconds
    return float(joules.sum()) / 3.6e6
