"""Fleet-scale ILI simulation: millions of items, each running the same
program on different sensor inputs.

Since the streaming engine landed (DESIGN.md §9) this module is a thin
compatibility wrapper: `run_fleet_sharded` keeps its historical signature
and bit-exact results, but executes through `repro.fleet.engine` —
chunked, segment-early-exit, buffer-donated — instead of one monolithic
vmap(while_loop) over the whole fleet. New code should use
`repro.fleet` directly (heterogeneous plans, O(chunk) host memory,
carbon reports); this wrapper materializes full per-item state and is
therefore O(fleet) on the host, exactly like the old path.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.flexibench.base import Workload
from repro.flexibits import iss
from repro.flexibits.cycles import Core
from repro.fleet import engine


def fleet_inputs(w: Workload, n_items: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    xs = w.gen_inputs(rng, n_items)
    base = w.initial_memory(np.zeros(w.n_inputs, np.int32))
    mems = np.tile(base, (n_items, 1))
    mems[:, :xs.shape[1]] = xs
    return mems


def run_fleet_sharded(w: Workload, mems: np.ndarray, mesh: Mesh,
                      seg_steps: int = 4096) -> iss.ISSState:
    """Run the fleet with items sharded over every mesh axis.

    Legacy API: returns the batched final ISSState for every item, in item
    order, bit-exact with the historical vmap(while_loop) implementation.
    """
    mems = np.asarray(mems, np.int32)
    n = mems.shape[0]
    res = engine.run_stream(
        w.program.code, engine.array_source(mems), n_items=n,
        mem_words=mems.shape[1], max_steps=w.max_steps, chunk=n,
        seg_steps=seg_steps, out_addr=w.out_addr, keep_state=True,
        mesh=mesh)
    return iss.ISSState(
        regs=jnp.asarray(res.regs),
        pc=jnp.asarray(res.pc),
        mem=jnp.asarray(res.mems),
        halted=jnp.asarray(res.halted),
        n_instr=jnp.asarray(res.n_instr, iss.I32),
        n_two_stage=jnp.asarray(res.n_two_stage, iss.I32),
        mix=jnp.asarray(res.mix_items, iss.I32),
        # legacy wrapper runs cycles-off; the counter exists but is 0
        n_cycles=jnp.zeros(n, iss.I32),
    )


def fleet_energy_kwh(state: iss.ISSState, core: Core,
                     vm_kb: float, clock_hz: float = 10_000.0) -> float:
    """Total fleet energy for one execution per item."""
    from repro.flexibits.cycles import system_power_mw
    n_one = np.asarray(state.n_instr - state.n_two_stage, np.float64)
    n_two = np.asarray(state.n_two_stage, np.float64)
    cycles = (n_one * core.cycles_one_stage()
              + n_two * core.cycles_two_stage())
    seconds = cycles / clock_hz
    joules = system_power_mw(core, vm_kb) * 1e-3 * seconds
    return float(joules.sum()) / 3.6e6
