"""FLEXIFLOW carbon model (paper §5.4).

  C_op  = Power x Runtime x Freq x Lifetime x CarbonIntensity
  C_emb = DieArea / (ActiveWaferArea x Yield) x WaferCO2e

Pragmatic's per-wafer LCA is proprietary; WAFER_KG is calibrated so the
fully-flexible food-spoilage system footprint reproduces Table 5's
0.01086 kg CO2e (DESIGN.md §5). Everything else is the paper's own data
(Tables 7/8 areas & powers, [109]/[118] energy intensities, [85] silicon
TinyML footprint, [37]/[58] battery LCAs).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.flexibits.cycles import (Core, event_cycles, sram_area_mm2,
                                    sram_power_mw, system_area_mm2,
                                    system_power_mw)
from repro.flexibits.faults import width_scaled_rate

# ---- energy sources, kg CO2e / kWh ([109] EIA 2023, [118] Wind Vision)
ENERGY_SOURCES: Dict[str, float] = {
    "coal": 1.048,
    "petroleum": 1.116,
    "us_grid": 0.367,
    "solar": 0.028,
    "wind": 0.012,
}

# ---- embodied-carbon calibration (DESIGN.md §5)
ACTIVE_WAFER_AREA_MM2 = 27_000.0     # 200 mm FlexIC wafer, active fraction
WAFER_YIELD = 0.9
WAFER_KG = 33.4                      # calibrated: flexible FS system 0.01086
KG_PER_MM2 = WAFER_KG / (ACTIVE_WAFER_AREA_MM2 * WAFER_YIELD)

# ---- non-compute components (§6.4 system models)
BATTERY_FLEX_KG = 0.0025             # Ilika solid-state [58] (est.)
BATTERY_ALKALINE_KG = 0.055          # AA alkaline [37] (est.)
SENSOR_SILICON_KG = 0.069            # silicon gas sensor (est., [85])
SILICON_TINYML_SYSTEM_KG = 2.66      # full silicon TinyML system [85]


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    """Per-(workload, core) numbers the carbon model consumes.

    `events` optionally carries the (N_COST,) timing-event vector the
    PyISS cycle oracle records (DESIGN.md §9.10). With it, runtime is
    priced per-event through `cycles.event_cycles` instead of the
    two-bucket analytic model; `dynamic=False` (the base case) is
    *exactly* the two-bucket number, `dynamic=True` additionally prices
    taken-branch refetch, serial shift, and subword read-modify-write.
    """
    n_one_stage: float               # one-stage instructions / execution
    n_two_stage: float
    vm_kb: float
    nvm_kb: float
    events: Optional[Tuple[float, ...]] = None   # mean per-exec events
    dynamic: bool = False            # price the dynamic timing terms


def embodied_kg(area_mm2: float) -> float:
    return area_mm2 * KG_PER_MM2


def soc_embodied_kg(core: Core, prof: DeviceProfile) -> float:
    return embodied_kg(system_area_mm2(core, prof.nvm_kb, prof.vm_kb))


def runtime_s(core: Core, prof: DeviceProfile, clock_hz=10_000.0) -> float:
    if prof.events is not None:
        return event_cycles(prof.events, core, prof.dynamic) / clock_hz
    return core.runtime_s(prof.n_one_stage, prof.n_two_stage, clock_hz)


def energy_per_exec_j(core: Core, prof: DeviceProfile,
                      clock_hz=10_000.0,
                      cycles: Optional[float] = None) -> float:
    """Energy of one execution. `cycles` overrides the profile's runtime
    with a *measured* per-execution cycle count (the fleet engine's
    per-lane `n_cycles` tally, §9.10)."""
    p_mw = system_power_mw(core, prof.vm_kb)
    t = cycles / clock_hz if cycles is not None \
        else runtime_s(core, prof, clock_hz)
    return p_mw * 1e-3 * t


def operational_kg(core: Core, prof: DeviceProfile, *, lifetime_s: float,
                   execs_per_day: float, intensity: float = 0.367,
                   clock_hz: float = 10_000.0,
                   cycles: Optional[float] = None) -> float:
    n_exec = execs_per_day * lifetime_s / 86_400.0
    kwh = energy_per_exec_j(core, prof, clock_hz, cycles) * n_exec / 3.6e6
    return kwh * intensity


def certified_energy_j(core: Core, prof: DeviceProfile, clock_hz: float,
                       wcet_cycles: float) -> float:
    """Certified worst-case energy of one execution (DESIGN.md §9.11):
    FlexiLint's statically proved WCET cycle bound priced through the
    same power model as the measured mean. An upper bound on
    `energy_per_exec_j` whenever the measurement used the dynamic cost
    row (pinned by tests/test_flexilint.py)."""
    return energy_per_exec_j(core, prof, clock_hz, cycles=wcet_cycles)


def certified_operational_kg(core: Core, prof: DeviceProfile, *,
                             lifetime_s: float, execs_per_day: float,
                             intensity: float = 0.367,
                             clock_hz: float = 10_000.0,
                             wcet_cycles: float) -> float:
    """Certified worst-case lifetime operational carbon (§9.11): every
    execution priced at the static WCET ceiling instead of the measured
    mean — the number a deployment can promise without profiling."""
    return operational_kg(core, prof, lifetime_s=lifetime_s,
                          execs_per_day=execs_per_day, intensity=intensity,
                          clock_hz=clock_hz, cycles=wcet_cycles)


def total_kg(core: Core, prof: DeviceProfile, *, lifetime_s: float,
             execs_per_day: float, intensity: float = 0.367,
             clock_hz: float = 10_000.0) -> float:
    return soc_embodied_kg(core, prof) + operational_kg(
        core, prof, lifetime_s=lifetime_s, execs_per_day=execs_per_day,
        intensity=intensity, clock_hz=clock_hz)


# ---- redundancy-aware pricing (DESIGN.md §9.14) ------------------------
#
# Spare-area embodied carbon vs re-execution operational carbon: a DMR
# pair doubles the core + VM SRAM (each copy keeps private architectural
# state) but shares the LPROM code store; TMR triples them. Operationally
# DMR runs 2 copies per attempt and re-executes on a digest mismatch
# (the fleet engine's segment-granular rollback), TMR runs 3 copies and
# votes with no retry. The unprotected mode pays differently: its faults
# escape silently (SDC), so delivering the same number of *trusted*
# results takes 1/(1-p) device-executions — a derating multiplier on
# embodied AND operational carbon. At fault rate 0 every factor is
# exactly 1.0 and the unprotected numbers are bitwise unchanged.

REDUNDANCY_MODES: Tuple[str, ...] = ("none", "dmr", "tmr")
_REDUNDANCY_COPIES: Dict[str, int] = {"none": 1, "dmr": 2, "tmr": 3}


def _copies(redundancy: str) -> int:
    try:
        return _REDUNDANCY_COPIES[redundancy]
    except KeyError:
        raise ValueError(
            f"redundancy must be one of {REDUNDANCY_MODES}, "
            f"got {redundancy!r}") from None


def fault_escape_p(fault_rate: float, n_instr: float,
                   width: int = 32) -> float:
    """Probability at least one fault fires during one execution of
    `n_instr` retired instructions at per-instruction rate `fault_rate`
    (width-scaled exactly as the injector: narrower datapaths expose
    proportionally fewer bits per cycle). Clamped below 1 so the DMR
    retry series stays summable."""
    r = width_scaled_rate(fault_rate, width)
    p = 1.0 - (1.0 - r) ** max(float(n_instr), 0.0)
    return min(p, 0.99)


def redundancy_energy_factor(redundancy: str = "none", *,
                             fault_rate: float = 0.0,
                             n_instr: float = 0.0,
                             width: int = 32) -> float:
    """Multiplier on per-execution energy under a redundancy mode.

    none -> exactly 1.0 (callers multiplying by it stay bit-identical).
    dmr  -> 2/(1-p): two copies per attempt; a detected divergence
            (probability ~ p per attempt, first order in the rate)
            re-executes the segment, a geometric series summing to
            1/(1-p) expected attempts.
    tmr  -> 3.0: three copies, majority vote, no retry.
    """
    n = _copies(redundancy)
    if redundancy != "dmr":
        return float(n)
    p = fault_escape_p(fault_rate, n_instr, width)
    return 2.0 / (1.0 - p)


def sdc_derating(redundancy: str = "none", *, fault_rate: float = 0.0,
                 n_instr: float = 0.0, width: int = 32) -> float:
    """Per-trusted-result derating multiplier on BOTH embodied and
    operational carbon. Unprotected executions that fault are silently
    wrong (SDC), so a fleet must provision 1/(1-p) device-executions
    per result it can trust. DMR detects and TMR masks single faults;
    their escape rate is O(p^2) and priced as exactly 1.0 (first
    order), as is everything at fault rate 0."""
    _copies(redundancy)                         # validate mode
    if redundancy != "none" or fault_rate == 0.0:
        return 1.0
    return 1.0 / (1.0 - fault_escape_p(fault_rate, n_instr, width))


def redundant_embodied_kg(core: Core, prof: DeviceProfile,
                          redundancy: str = "none") -> float:
    """SoC embodied carbon with (n-1) spare copies of the core + VM SRAM
    (the LPROM code store is shared — every copy executes one image).
    `none` is exactly `soc_embodied_kg`."""
    n = _copies(redundancy)
    if n == 1:
        return soc_embodied_kg(core, prof)
    spare = (n - 1) * (core.area_mm2 + sram_area_mm2(prof.vm_kb))
    return soc_embodied_kg(core, prof) + embodied_kg(spare)


def redundant_operational_kg(core: Core, prof: DeviceProfile, *,
                             lifetime_s: float, execs_per_day: float,
                             redundancy: str = "none",
                             fault_rate: float = 0.0,
                             intensity: float = 0.367,
                             clock_hz: float = 10_000.0,
                             cycles: Optional[float] = None) -> float:
    factor = redundancy_energy_factor(
        redundancy, fault_rate=fault_rate,
        n_instr=prof.n_one_stage + prof.n_two_stage, width=core.width)
    return operational_kg(core, prof, lifetime_s=lifetime_s,
                          execs_per_day=execs_per_day, intensity=intensity,
                          clock_hz=clock_hz, cycles=cycles) * factor


def redundant_total_kg(core: Core, prof: DeviceProfile, *,
                       lifetime_s: float, execs_per_day: float,
                       redundancy: str = "none", fault_rate: float = 0.0,
                       intensity: float = 0.367,
                       clock_hz: float = 10_000.0) -> float:
    """`total_kg` over the redundancy axis: (spare-area embodied +
    re-execution operational) x the SDC derating. `none` at fault rate
    0 is bitwise `total_kg` (spare area exactly 0, every factor exactly
    1.0)."""
    derate = sdc_derating(redundancy, fault_rate=fault_rate,
                          n_instr=prof.n_one_stage + prof.n_two_stage,
                          width=core.width)
    return (redundant_embodied_kg(core, prof, redundancy)
            + redundant_operational_kg(
                core, prof, lifetime_s=lifetime_s,
                execs_per_day=execs_per_day, redundancy=redundancy,
                fault_rate=fault_rate, intensity=intensity,
                clock_hz=clock_hz)) * derate


def flexible_system_kg(core: Core, prof: DeviceProfile, **kw) -> float:
    """Fully-flexible system: SoC + flexible sensor (~= SoC, §6.4 fn 2) +
    solid-state battery."""
    return (total_kg(core, prof, **kw) + soc_embodied_kg(core, prof)
            + BATTERY_FLEX_KG)


def hybrid_system_kg(core: Core, prof: DeviceProfile, **kw) -> float:
    return (total_kg(core, prof, **kw) + SENSOR_SILICON_KG
            + BATTERY_ALKALINE_KG)
