"""Lifetime-aware carbon-optimal core selection (paper §5.5, Fig. 5).

Vectorized over (lifetime x frequency) grids with numpy (the grids are
tiny); the *fleet-scale* vectorized variant (jnp over items with different
lifetimes) lives in flexibits/fleet.py.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.carbon import (REDUNDANCY_MODES, DeviceProfile,
                               operational_kg, redundancy_energy_factor,
                               redundant_embodied_kg, sdc_derating,
                               soc_embodied_kg)
from repro.flexibits.cycles import CORES, Core


def total_grid(core: Union[Core, Sequence[Core]], prof: DeviceProfile,
               lifetimes_s: np.ndarray, execs_per_day: np.ndarray,
               intensity: float = 0.367,
               clock_hz: float = 10_000.0,
               redundancy: str = "none",
               fault_rate: float = 0.0) -> np.ndarray:
    """Total carbon over a (lifetime x frequency) grid.

    One core -> (len(lifetimes), len(freqs)); a sequence of cores -> a
    stacked (len(cores), len(lifetimes), len(freqs)) grid in one
    broadcast (the embodied/operational anchors are per-core scalars;
    operational carbon scales linearly in lifetime x freq).

    `redundancy`/`fault_rate` price an N-modular-redundant variant of
    every core (DESIGN.md §9.14): spare core+SRAM embodied area, the
    expected re-execution energy factor, and — for unprotected cores at
    a nonzero rate — the per-trusted-result SDC derating on both
    embodied and operational carbon. The default (`"none"` at rate 0)
    is bitwise the unpriced grid: the spare area is exactly 0 and every
    factor exactly 1.0.
    """
    cores = [core] if isinstance(core, Core) else list(core)
    n_instr = prof.n_one_stage + prof.n_two_stage
    derate = np.array([
        sdc_derating(redundancy, fault_rate=fault_rate, n_instr=n_instr,
                     width=c.width) for c in cores])
    emb = np.array([redundant_embodied_kg(c, prof, redundancy)
                    for c in cores]) * derate
    rfac = np.array([
        redundancy_energy_factor(
            redundancy, fault_rate=fault_rate, n_instr=n_instr,
            width=c.width)
        for c in cores])
    base = np.array([
        operational_kg(c, prof, lifetime_s=86_400.0, execs_per_day=1.0,
                       intensity=intensity, clock_hz=clock_hz)
        for c in cores]) * rfac * derate
    life_days = np.asarray(lifetimes_s)[:, None] / 86_400.0
    grid = emb[:, None, None] + base[:, None, None] \
        * life_days[None, :, :] * np.asarray(execs_per_day)[None, None, :]
    return grid[0] if isinstance(core, Core) else grid


def redundancy_grid(prof: DeviceProfile, lifetimes_s: np.ndarray,
                    execs_per_day: np.ndarray, *, fault_rate: float,
                    intensity: float = 0.367,
                    cores: Optional[Sequence[Core]] = None,
                    redundancies: Sequence[str] = REDUNDANCY_MODES
                    ) -> np.ndarray:
    """Stacked (redundancy, core, lifetime, freq) total-carbon grid —
    the (R, C) leading axes are the joint design space the planner
    argmins over."""
    cores = list(cores or CORES.values())
    return np.stack([
        total_grid(cores, prof, lifetimes_s, execs_per_day, intensity,
                   redundancy=r, fault_rate=fault_rate)
        for r in redundancies])


def redundancy_selection_map(prof: DeviceProfile, lifetimes_s: np.ndarray,
                             execs_per_day: np.ndarray, *,
                             fault_rate: float, intensity: float = 0.367,
                             cores: Optional[Sequence[Core]] = None,
                             redundancies: Sequence[str] = REDUNDANCY_MODES
                             ) -> Tuple[np.ndarray, np.ndarray]:
    """argmin over the joint (redundancy x core) axis: returns a pair of
    index grids `(redundancy_idx, core_idx)`, each (lifetime, freq).
    At fault_rate 0 the `core_idx` grid reproduces `selection_map`
    exactly — spare copies only cost, never pay (pinned by tests)."""
    cores = list(cores or CORES.values())
    totals = redundancy_grid(prof, lifetimes_s, execs_per_day,
                             fault_rate=fault_rate, intensity=intensity,
                             cores=cores, redundancies=redundancies)
    flat = totals.reshape(-1, *totals.shape[2:])
    best = np.argmin(flat, axis=0)
    return best // len(cores), best % len(cores)


def selection_map(prof: DeviceProfile, lifetimes_s: np.ndarray,
                  execs_per_day: np.ndarray, intensity: float = 0.367,
                  cores: Optional[Sequence[Core]] = None) -> np.ndarray:
    """argmin-core index grid (paper Fig. 5). 0=SERV, 1=QERV, 2=HERV."""
    cores = list(cores or CORES.values())
    totals = total_grid(cores, prof, lifetimes_s, execs_per_day, intensity)
    return np.argmin(totals, axis=0)


def optimal_core(prof: DeviceProfile, *, lifetime_s: float,
                 execs_per_day: float, intensity: float = 0.367,
                 cores: Optional[Sequence[Core]] = None) -> Tuple[Core, Dict]:
    cores = list(cores or CORES.values())
    totals = total_grid(cores, prof, np.array([lifetime_s]),
                        np.array([execs_per_day]), intensity)[:, 0, 0]
    i = int(np.argmin(totals))
    return cores[i], {c.name: float(t) for c, t in zip(cores, totals)}


def crossover_lifetimes(prof: DeviceProfile, execs_per_day: float,
                        intensity: float = 0.367,
                        cores: Optional[Sequence[Core]] = None
                        ) -> np.ndarray:
    """Pairwise crossover-lifetime matrix over all core pairs.

    `out[a, b]` is the lifetime (seconds) where core b overtakes core a
    (solves emb_a + op_a*L = emb_b + op_b*L per pair in one broadcast);
    +inf where b never catches up (op_a <= op_b). The sweep's frontier
    annotation consumes whole rows of this at once.
    """
    cores = list(CORES.values()) if cores is None else list(cores)
    emb = np.array([soc_embodied_kg(c, prof) for c in cores])
    op = np.array([
        operational_kg(c, prof, lifetime_s=86_400.0,
                       execs_per_day=execs_per_day, intensity=intensity)
        for c in cores])
    demb = emb[None, :] - emb[:, None]          # emb_b - emb_a
    dop = op[:, None] - op[None, :]             # op_a - op_b
    out = np.full((len(cores), len(cores)), np.inf)
    np.divide(demb * 86_400.0, dop, out=out, where=dop > 0)
    return out


def crossover_lifetime_s(prof: DeviceProfile, core_a: Core, core_b: Core,
                         execs_per_day: float,
                         intensity: float = 0.367) -> float:
    """Lifetime where core_b (more efficient, larger) overtakes core_a.

    Scalar view of `crossover_lifetimes`. Returns +inf if never.
    """
    return float(crossover_lifetimes(
        prof, execs_per_day, intensity, cores=(core_a, core_b))[0, 1])
