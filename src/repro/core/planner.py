"""Beyond-paper: lifetime-aware carbon planner for LLM serving fleets.

Applies FLEXIFLOW's embodied-vs-operational structure to datacenter
inference: the paper's datapath-width knob (1/4/8-bit) becomes the weight
bit-width knob (W16/W8/W4 bit-plane serving, kernels/bitplane_matmul), and
"deployment lifetime x task frequency" becomes "deployment lifetime x QPS".

  embodied   = chips_needed x TPU_EMBODIED_KG   (ACT-style per-chip LCA)
  operational= energy/token x tokens(lifetime, qps) x intensity

tokens/s/chip for decode is memory-bound: HBM_BW / bytes_moved_per_token,
with bytes ~ (param_bytes(bits) + kv_bytes)/chips — exactly the roofline
memory term, so the planner consumes dry-run artifacts when available.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

from repro.launch.roofline import HBM_BW, PEAK_FLOPS

TPU_EMBODIED_KG = 150.0       # kg CO2e per TPU package+board (ACT-style)
CHIP_POWER_W = 250.0          # v5e chip + host/interconnect share
PUE = 1.1


@dataclasses.dataclass(frozen=True)
class ServeVariant:
    name: str                 # e.g. "W16", "W8", "W4"
    weight_bits: int
    quality_penalty: float    # relative quality loss (documented, not opt.)
    prep_kg: float            # ONE-TIME carbon to produce the variant
    #                           (PTQ calibration / QAT distillation) — the
    #                           direct analogue of the paper's embodied
    #                           area cost: paid once, amortized by lifetime.


# prep costs: W8 = PTQ calibration+eval (~100 chip-hours);
# W4 = QAT/distillation (~4000 chip-hours) at 250W, PUE 1.1, US grid.
def _prep_kg(chip_hours: float, intensity: float = 0.367) -> float:
    return chip_hours * CHIP_POWER_W / 1000.0 * PUE * intensity


VARIANTS = (ServeVariant("W16", 16, 0.0, 0.0),
            ServeVariant("W8", 8, 0.002, _prep_kg(100.0)),
            ServeVariant("W4", 4, 0.01, _prep_kg(4000.0)))


def tokens_per_s_per_chip(n_params: float, weight_bits: int,
                          kv_bytes_per_token: float, chips: int,
                          batch: int = 64) -> float:
    """Decode roofline: each step reads all weights + the batch's KV."""
    weight_bytes = n_params * weight_bits / 8.0 / chips
    kv_bytes = kv_bytes_per_token * batch / chips
    step_s = (weight_bytes + kv_bytes) / HBM_BW
    return batch / step_s / chips


def plan_grid(*, n_params: float, kv_bytes_per_token: float,
              lifetimes_days: np.ndarray, qps_grid: np.ndarray,
              chips_options: Sequence[int] = (8, 16, 32, 64, 128, 256),
              intensity: float = 0.367,
              variants: Sequence[ServeVariant] = VARIANTS) -> Dict:
    """For every (lifetime, qps) cell pick (variant, chips) minimizing total
    carbon subject to meeting qps. Returns argmin maps + totals.

    One (lifetime, qps, option) broadcast, like `selection.total_grid`:
    the per-option anchors (prep carbon, chips, tokens/s) are vectors,
    embodied carbon broadcasts over lifetimes, operational over
    lifetime x qps, infeasible options mask to +inf, and the option
    axis argmin takes the first minimum — the same tie-break as the
    strict `<` scan it replaces (tests/test_planner.py pins exact
    array equality against the loop form).
    """
    if not list(chips_options):
        raise ValueError("plan_grid: chips_options is empty — need at "
                         "least one fleet size to plan over")
    if not list(variants):
        raise ValueError("plan_grid: variants is empty — need at least "
                         "one serving variant to plan over")
    days = np.asarray(lifetimes_days, float)          # (nl,)
    qps = np.asarray(qps_grid, float)                 # (nq,)
    opt_vi, opt_chips, opt_tps = [], [], []
    for vi, v in enumerate(variants):
        for chips in chips_options:
            opt_vi.append(vi)
            opt_chips.append(chips)
            opt_tps.append(tokens_per_s_per_chip(
                n_params, v.weight_bits, kv_bytes_per_token, chips)
                * chips)
    opt_vi = np.asarray(opt_vi, np.int32)             # (K,)
    opt_chips = np.asarray(opt_chips, float)
    opt_tps = np.asarray(opt_tps, float)
    opt_prep = np.asarray([variants[v].prep_kg for v in opt_vi])

    feasible = opt_tps[None, None, :] >= qps[None, :, None]
    # amortize 3y chip life
    emb = (opt_chips[None, None, :] * TPU_EMBODIED_KG
           * np.minimum(days / (3 * 365.0), 1.0)[:, None, None])
    # energy: chips run at utilization qps/tps — divide only where the
    # option is feasible (masked divide keeps inf/NaN qps demands from
    # raising spurious warnings; infeasible cells mask to +inf below
    # regardless, so feasible cells are bit-identical to the plain form)
    util = np.zeros(feasible.shape)
    np.divide(np.broadcast_to(qps[None, :, None], feasible.shape),
              np.broadcast_to(opt_tps[None, None, :], feasible.shape),
              out=util, where=feasible)
    kwh = (opt_chips[None, None, :] * CHIP_POWER_W * PUE * util
           * days[:, None, None] * 24.0 / 1000.0)
    total = opt_prep[None, None, :] + emb + kwh * intensity
    total = np.where(feasible, total, np.inf)         # (nl, nq, K)

    k = np.argmin(total, axis=2)                      # first min wins
    best_kg = np.take_along_axis(total, k[..., None], axis=2)[..., 0]
    met = np.isfinite(best_kg)
    best = np.where(met, opt_vi[k], -1).astype(np.int32)
    best_chips = np.where(met, opt_chips[k], 0).astype(np.int32)
    return {"variant_idx": best, "chips": best_chips, "total_kg": best_kg,
            "variants": [v.name for v in variants]}
