"""Device-resident Monte Carlo carbon-planner sweep (DESIGN.md §9.13).

The paper's central claim is that the carbon-optimal architecture flips
with deployment lifetime (a 1000X spread) and scale (trillions of
items). `selection.py`/`planner.py` answer one modest point-estimate
grid per host call; this module answers *distributional* what-ifs at
interactive rates: scenario tensors over

    lifetime distribution x task frequency x grid carbon intensity x
    deployment volume x workload x timing model x fault rate
                                     (x core x redundancy, reduced)

evaluated as one fused jitted program, with Monte Carlo lifetime draws
(point / lognormal / Weibull mixtures) over the paper's 1000X lifetime
spread instead of point estimates.

Engine shape (the `fleet/engine.py` streaming discipline, applied to
scenarios instead of items):

- **Streamed tiles, bounded memory.** The flat cell space is walked in
  fixed tiles; per-tile device work is O(tile x draws x cores) and the
  host keeps only O(cells) scalar summaries plus two small global
  accumulators (histogram + Pareto bins) that are *donated* back to the
  jitted step every tile — arbitrarily large sweeps run in one
  chunk-sized device allocation.
- **Counter-based per-cell seeding.** Scenario (cell, draw) derives its
  uniforms from `fold_in(fold_in(key, cell), draw)` — a pure function
  of the *global* indices, so tiles are order-independent and the whole
  sweep is bit-identical at any tile size (tests/test_sweep.py).
- **On-device reduce.** Core argmin/selection, per-cell draw statistics,
  the log-binned total histogram and the embodied-vs-operational Pareto
  frontier all reduce per tile (`kernels/carbon_sweep.py`, Pallas path
  + bit-exact jnp baseline); the (cells x draws) tensor is never
  materialized.
- **Oracles kept.** The numpy `selection.total_grid` / `planner.plan_grid`
  grids stay as host oracles: on point-mass lifetime distributions the
  sweep's totals/argmin equal `total_grid`/`selection_map` bit-for-bit
  (float64 + `jax.experimental.enable_x64`), and `serving_plan_jnp`
  mirrors `plan_grid` exactly on shared grid points.

Timing models ride in as a scenario axis: "base" prices the two-bucket
analytic model (== the paper's Table-7 arithmetic), "dynamic" the §9.10
measured event vectors with dynamic cost rows, "wcet" FlexiLint's §9.11
static worst-case certificates, and "measured" caller-supplied mean
cycles from fleet runs — so one sweep prices measured, base, dynamic and
certified-worst-case carbon in a single pass.
"""
from __future__ import annotations

import dataclasses
import functools
import math
import time
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.carbon import (REDUNDANCY_MODES, DeviceProfile,
                               operational_kg, redundancy_energy_factor,
                               redundant_embodied_kg, sdc_derating,
                               soc_embodied_kg)
from repro.core.planner import (CHIP_POWER_W, PUE, TPU_EMBODIED_KG,
                                ServeVariant, VARIANTS,
                                tokens_per_s_per_chip)
from repro.flexibits.cycles import CLOCK_HZ, CORES, Core
from repro.kernels import carbon_sweep as csk

I32 = jnp.int32

# lifetime-distribution component kinds
POINT, LOGNORMAL, WEIBULL = 0, 1, 2
TIMING_MODES = ("base", "dynamic", "wcet", "measured")

DAY_S = 86_400.0
YEAR_S = 365.0 * DAY_S
_PCTS = (50, 90, 99)


# --------------------------------------------------------- distributions
@dataclasses.dataclass(frozen=True)
class LifetimeDist:
    """Mixture of point / lognormal / Weibull lifetime components.

    `comps` rows are (kind, p1, p2, weight): point -> (p1=seconds),
    lognormal -> (p1=ln median seconds, p2=sigma of ln), Weibull ->
    (p1=scale seconds, p2=shape k). Weights are normalized at
    construction. Draws use inverse-CDF transforms of counter-based
    uniforms, so a distribution is a pure function of (seed, cell,
    draw).
    """
    name: str
    comps: Tuple[Tuple[int, float, float, float], ...]

    @staticmethod
    def point(seconds: float, name: Optional[str] = None) -> "LifetimeDist":
        return LifetimeDist(name or f"point:{seconds:g}s",
                            ((POINT, float(seconds), 0.0, 1.0),))

    @staticmethod
    def lognormal(median_s: float, sigma: float,
                  name: Optional[str] = None) -> "LifetimeDist":
        """ln L ~ Normal(ln median, sigma). sigma ~ 1.8 spans the
        paper's 1000X lifetime spread at +/-2 sigma."""
        return LifetimeDist(
            name or f"lognormal:{median_s:g}s:{sigma:g}",
            ((LOGNORMAL, math.log(median_s), float(sigma), 1.0),))

    @staticmethod
    def weibull(scale_s: float, shape: float,
                name: Optional[str] = None) -> "LifetimeDist":
        """L ~ Weibull(scale, k): k<1 models infant-mortality-heavy
        deployments, k>1 wear-out-dominated ones."""
        return LifetimeDist(name or f"weibull:{scale_s:g}s:{shape:g}",
                            ((WEIBULL, float(scale_s), float(shape), 1.0),))

    @staticmethod
    def mixture(parts: Sequence[Tuple["LifetimeDist", float]],
                name: Optional[str] = None) -> "LifetimeDist":
        comps, names = [], []
        for d, w in parts:
            for kind, p1, p2, cw in d.comps:
                comps.append((kind, p1, p2, cw * float(w)))
            names.append(f"{d.name}@{w:g}")
        return LifetimeDist(name or "mix(" + "+".join(names) + ")",
                            tuple(comps))

    def normalized(self) -> Tuple[Tuple[int, float, float, float], ...]:
        tot = sum(c[3] for c in self.comps)
        if not (tot > 0):
            raise ValueError(f"distribution {self.name!r} has no weight")
        return tuple((k, p1, p2, w / tot) for k, p1, p2, w in self.comps)

    def support_max(self) -> float:
        """Reference upper lifetime for histogram sizing (draws beyond
        it clamp into the top bin)."""
        hi = 0.0
        for kind, p1, p2, _ in self.comps:
            if kind == POINT:
                hi = max(hi, p1)
            elif kind == LOGNORMAL:
                hi = max(hi, math.exp(p1 + 8.0 * p2))
            else:
                hi = max(hi, p1 * 30.0 ** (1.0 / p2))
        return hi


# ----------------------------------------------------------------- spec
@dataclasses.dataclass(frozen=True)
class SweepSpec:
    """One scenario-sweep request. Cell axes in linear-index order
    (slowest to fastest): dists, execs_per_day, intensities, volumes,
    workloads, timing, fault_rates. Everything is hashable so compiled
    sweep steps cache across calls (`fleet/engine.py`'s lru-cached
    runner idiom).

    `fault_rates` (§9.14) is a scenario axis like intensity: each cell
    prices its candidates under one per-instruction transient-fault
    rate. `redundancies` expands the *reduced candidate* axis instead —
    the kernel argmins over core x redundancy jointly, so each cell
    reports the carbon-optimal (core, redundancy) pair. The defaults
    (one rate of 0.0, `("none",)`) leave every table and reduction
    bitwise identical to a redundancy-free sweep."""
    workloads: Tuple[str, ...]
    profiles: Tuple[DeviceProfile, ...]          # parallel to workloads
    dists: Tuple[LifetimeDist, ...]
    execs_per_day: Tuple[float, ...]
    intensities: Tuple[float, ...]
    volumes: Tuple[float, ...] = (1.0,)
    cores: Tuple[Core, ...] = tuple(CORES.values())
    timing: Tuple[str, ...] = ("base",)
    fault_rates: Tuple[float, ...] = (0.0,)
    redundancies: Tuple[str, ...] = ("none",)
    draws: int = 64
    seed: int = 0
    clock_hz: float = CLOCK_HZ
    # per-(workload, core) cycle overrides, parallel to workloads/cores:
    # required by the "wcet" (FlexiLint certificates, §9.11) and
    # "measured" (fleet-run mean cycles, §9.10) timing modes
    wcet_cycles: Optional[Tuple[Tuple[float, ...], ...]] = None
    measured_cycles: Optional[Tuple[Tuple[float, ...], ...]] = None

    @property
    def axis_sizes(self) -> Tuple[int, int, int, int, int, int, int]:
        return (len(self.dists), len(self.execs_per_day),
                len(self.intensities), len(self.volumes),
                len(self.workloads), len(self.timing),
                len(self.fault_rates))

    @property
    def n_candidates(self) -> int:
        """Width of the reduced axis: core x redundancy pairs. Joint
        candidate j decodes as (redundancy j // C, core j % C)."""
        return len(self.cores) * len(self.redundancies)

    @property
    def n_cells(self) -> int:
        n = 1
        for s in self.axis_sizes:
            n *= s
        return n

    @property
    def n_scenarios(self) -> int:
        return self.n_cells * self.draws

    def validate(self) -> None:
        names = ("dists", "execs_per_day", "intensities", "volumes",
                 "workloads", "timing", "fault_rates")
        for name, size in zip(names, self.axis_sizes):
            if size == 0:
                raise ValueError(f"SweepSpec.{name} is empty")
        if not self.cores:
            raise ValueError("SweepSpec.cores is empty")
        if not self.redundancies:
            raise ValueError("SweepSpec.redundancies is empty")
        if len(self.profiles) != len(self.workloads):
            raise ValueError("profiles must parallel workloads")
        if self.draws < 1:
            raise ValueError("draws must be >= 1")
        for t in self.timing:
            if t not in TIMING_MODES:
                raise ValueError(f"unknown timing mode {t!r}; "
                                 f"expected one of {TIMING_MODES}")
        for r in self.redundancies:
            if r not in REDUNDANCY_MODES:
                raise ValueError(f"unknown redundancy mode {r!r}; "
                                 f"expected one of {REDUNDANCY_MODES}")
        for fr in self.fault_rates:
            if not (fr >= 0.0):
                raise ValueError(f"fault rates must be >= 0, got {fr!r}")
        if "wcet" in self.timing and self.wcet_cycles is None:
            raise ValueError("timing mode 'wcet' needs wcet_cycles "
                             "(see workload_spec)")
        if "measured" in self.timing and self.measured_cycles is None:
            raise ValueError("timing mode 'measured' needs "
                             "measured_cycles")

    def decode_cell(self, idx: int
                    ) -> Tuple[int, int, int, int, int, int, int]:
        D, F, I, V, W, T, FR = self.axis_sizes
        fri = idx % FR
        idx //= FR
        ti = idx % T
        idx //= T
        wi = idx % W
        idx //= W
        vi = idx % V
        idx //= V
        ii = idx % I
        idx //= I
        return (idx // F, idx % F, ii, vi, wi, ti, fri)


# --------------------------------------------------------------- tables
@dataclasses.dataclass(frozen=True)
class SweepTables:
    """Host-side float64 anchors the device sweep consumes.

    The reduced candidate axis is core x redundancy (width
    `spec.n_candidates`, joint index j = r * C + c). `emb[fr, w, j]` is
    `carbon.redundant_embodied_kg` times the SDC derating for
    (redundancy, fault rate); `kwh[t, fr, w, j]` is the intensity-1
    daily-exec operational anchor — literally `operational_kg(core,
    prof, lifetime_s=86400, execs_per_day=1, intensity=1.0)` per timing
    mode, times `carbon.redundancy_energy_factor` and the same derating
    — so the device total ``emb + ((kwh * I) * life_days) * freq``
    retraces the numpy oracle `selection.total_grid` op for op. At the
    default `("none",)` / rate-0 axes every factor is exactly 1.0 and
    the tables are bitwise the redundancy-free ones.
    """
    emb: np.ndarray            # (FR, W, C*R)
    kwh: np.ndarray            # (T, FR, W, C*R)
    kind: np.ndarray           # (D, K) int32
    p1: np.ndarray             # (D, K)
    p2: np.ndarray             # (D, K)
    cum_prev: np.ndarray       # (D, K-1) mixture CDF boundaries
    hist_lo: float
    hist_inv: float
    par_lo: float
    par_inv: float

    def hist_edges(self, n_hist: int) -> np.ndarray:
        return 10.0 ** (self.hist_lo
                        + np.arange(n_hist + 1) / self.hist_inv)


def _mode_kwh(mode: str, core: Core, prof: DeviceProfile,
              clock_hz: float, wcet: Optional[float],
              measured: Optional[float]) -> float:
    if mode == "base":
        prof = dataclasses.replace(prof, dynamic=False)
        cycles = None
    elif mode == "dynamic":
        prof = dataclasses.replace(prof, dynamic=True)
        cycles = None
    elif mode == "wcet":
        cycles = wcet
    else:                                                  # measured
        cycles = measured
    return operational_kg(core, prof, lifetime_s=DAY_S, execs_per_day=1.0,
                          intensity=1.0, clock_hz=clock_hz, cycles=cycles)


def build_tables(spec: SweepSpec, n_hist: int = 64,
                 n_pareto: int = 32) -> SweepTables:
    spec.validate()
    W, C = len(spec.workloads), len(spec.cores)
    T, FR, R = len(spec.timing), len(spec.fault_rates), \
        len(spec.redundancies)
    emb = np.empty((FR, W, C * R))
    kwh = np.empty((T, FR, W, C * R))
    for wi, prof in enumerate(spec.profiles):
        n_instr = prof.n_one_stage + prof.n_two_stage
        for ci, core in enumerate(spec.cores):
            base = np.empty(T)
            for ti, mode in enumerate(spec.timing):
                base[ti] = _mode_kwh(
                    mode, core, prof, spec.clock_hz,
                    spec.wcet_cycles[wi][ci] if spec.wcet_cycles else None,
                    spec.measured_cycles[wi][ci]
                    if spec.measured_cycles else None)
            for ri, red in enumerate(spec.redundancies):
                j = ri * C + ci
                remb = redundant_embodied_kg(core, prof, red)
                for fri, rate in enumerate(spec.fault_rates):
                    rfac = redundancy_energy_factor(
                        red, fault_rate=rate, n_instr=n_instr,
                        width=core.width)
                    derate = sdc_derating(red, fault_rate=rate,
                                          n_instr=n_instr,
                                          width=core.width)
                    # host float64 multiplies; 1.0 is exact identity
                    emb[fri, wi, j] = remb * derate
                    kwh[:, fri, wi, j] = base * rfac * derate

    K = max(len(d.comps) for d in spec.dists)
    D = len(spec.dists)
    kind = np.zeros((D, K), np.int32)
    p1 = np.ones((D, K))
    p2 = np.ones((D, K))
    cum = np.ones((D, K))
    for di, d in enumerate(spec.dists):
        comps = d.normalized()
        for k, (kd, a, b, w) in enumerate(comps):
            kind[di, k], p1[di, k], p2[di, k] = kd, a, b
        cum[di, :len(comps)] = np.cumsum([c[3] for c in comps])
        cum[di, len(comps):] = 1.0

    life_max = max(d.support_max() for d in spec.dists)
    tmin = float(emb.min())
    tmax = float(emb.max() + kwh.max() * max(spec.intensities)
                 * (life_max / DAY_S) * max(spec.execs_per_day))
    hist_lo = math.log10(tmin)
    span = max(math.log10(tmax) - hist_lo, 1e-9)
    par_lo = math.log10(float(emb.min()))
    par_span = max(math.log10(float(emb.max())) - par_lo, 1e-9)
    return SweepTables(emb=emb, kwh=kwh, kind=kind, p1=p1, p2=p2,
                       cum_prev=cum[:, :max(K - 1, 1)],
                       hist_lo=hist_lo, hist_inv=n_hist / span,
                       par_lo=par_lo, par_inv=n_pareto / par_span)


# ------------------------------------------------------- scenario draws
def _uniforms(key, cell: jax.Array, draws: int, dtype) -> jax.Array:
    """(tile, draws, 2) uniforms from counter-based per-cell keys:
    `fold_in(key, global_cell_index)` then a (draws, 2) shaped draw — a
    pure function of the GLOBAL cell index, never of tile boundaries,
    so any tiling replays the same scenarios bit-for-bit."""
    ck = jax.vmap(lambda i: jax.random.fold_in(key, i))(cell)
    return jax.vmap(
        lambda k: jax.random.uniform(k, (draws, 2), dtype))(ck)


def _lifetimes(kind, p1, p2, cum_prev, u) -> jax.Array:
    """Inverse-CDF mixture draw: u[..., 1] picks the component against
    the cumulative weights, u[..., 0] transforms through the component's
    quantile function."""
    dtype = u.dtype
    eps = 1e-12 if dtype == jnp.float64 else 1e-6
    uc = jnp.clip(u[..., 0], eps, 1.0 - eps)
    comp = jnp.sum((u[..., 1][..., None] >= cum_prev[:, None, :])
                   .astype(I32), axis=-1, dtype=I32)       # (tile, N)
    sel = comp[..., None] == jnp.arange(kind.shape[1], dtype=I32)

    def take(tab):
        return jnp.sum(jnp.where(sel, tab[:, None, :], 0), axis=-1,
                       dtype=tab.dtype)

    k = take(kind.astype(I32))
    a = take(p1.astype(dtype))
    b = take(p2.astype(dtype))
    z = jax.scipy.special.ndtri(uc)
    lognorm = jnp.exp(a + b * z)
    weibull = a * (-jnp.log1p(-uc)) ** (1.0 / b)
    return jnp.where(k == POINT, a,
                     jnp.where(k == LOGNORMAL, lognorm, weibull))


# ----------------------------------------------------------- sweep step
@functools.lru_cache(maxsize=8)
def _sweep_step(spec: SweepSpec, tile_cells: int, path: str,
                dtype_str: str, n_hist: int, n_pareto: int,
                interpret: Optional[bool]):
    """Compiled streaming step for (spec, tile, path, dtype) — cached
    like `fleet/engine.py`'s segment runners so repeated what-ifs on the
    same spec skip retracing. Returns (jitted step, tables)."""
    tables = build_tables(spec, n_hist, n_pareto)
    dtype = jnp.dtype(dtype_str)
    D, F, I, V, W, T, FR = spec.axis_sizes
    n_cells = spec.n_cells
    draws = spec.draws
    emb_d = jnp.asarray(tables.emb, dtype)
    kwh_d = jnp.asarray(tables.kwh, dtype)
    freq_d = jnp.asarray(np.asarray(spec.execs_per_day, np.float64), dtype)
    inten_d = jnp.asarray(np.asarray(spec.intensities, np.float64), dtype)
    vol_d = jnp.asarray(np.asarray(spec.volumes, np.float64), dtype)
    kind_d = jnp.asarray(tables.kind)
    p1_d = jnp.asarray(tables.p1, dtype)
    p2_d = jnp.asarray(tables.p2, dtype)
    cum_d = jnp.asarray(tables.cum_prev, dtype)
    key = jax.random.PRNGKey(spec.seed)
    qidx = tuple(min(draws - 1, max(0, math.ceil(q / 100 * draws) - 1))
                 for q in _PCTS)

    def step(acc: csk.SweepAcc, start):
        cell = start + jnp.arange(tile_cells, dtype=I32)
        valid = cell < n_cells
        c = jnp.where(valid, cell, n_cells - 1)
        fri = c % FR
        c = c // FR
        ti = c % T
        r = c // T
        wi = r % W
        r = r // W
        vi = r % V
        r = r // V
        ii = r % I
        r = r // I
        fi = r % F
        di = r // F
        u = _uniforms(key, cell, draws, dtype)
        life = _lifetimes(kind_d[di], p1_d[di], p2_d[di], cum_d[di], u)
        # seconds -> days ONCE, outside the A/B'd kernel. The barrier on
        # the divisor stops XLA:CPU's context-dependent f32 rewrite of
        # divide-by-constant into reciprocal-multiply, which otherwise
        # makes the jnp and Pallas paths diverge by 1 ulp.
        life_days = life / lax.optimization_barrier(
            jnp.asarray(DAY_S, dtype))
        out, acc = csk.sweep_tile(
            emb_d[fri, wi], kwh_d[ti, fri, wi], inten_d[ii], freq_d[fi],
            life_days,
            valid, cell, acc, hist_lo=tables.hist_lo,
            hist_inv=tables.hist_inv, par_lo=tables.par_lo,
            par_inv=tables.par_inv, path=path, interpret=interpret)
        by_draw = jnp.sort(out.best_total, axis=1)
        mean = out.sum_best / draws
        stats = {
            "mean": mean,
            "p50": by_draw[:, qidx[0]],
            "p90": by_draw[:, qidx[1]],
            "p99": by_draw[:, qidx[2]],
            "min": out.min_best,
            "max": out.max_best,
            "mean_emb": out.sum_emb / draws,
            "mean_op": out.sum_op / draws,
            "fleet_mean": mean * vol_d[vi],
            "counts": out.counts,
        }
        return acc, stats

    return jax.jit(step, donate_argnums=0), tables


# --------------------------------------------------------------- result
_PAR_FIELDS = ("op", "emb", "life", "cell", "draw", "core")


def _acc_to_host(acc: csk.SweepAcc) -> Dict[str, np.ndarray]:
    return {"op": np.asarray(acc.par_op), "emb": np.asarray(acc.par_emb),
            "life": np.asarray(acc.par_life),
            "cell": np.asarray(acc.par_cell),
            "draw": np.asarray(acc.par_draw),
            "core": np.asarray(acc.par_core)}


def _merge_pareto_host(a: Optional[Dict[str, np.ndarray]],
                       b: Dict[str, np.ndarray]) -> Dict[str, np.ndarray]:
    """Host-side flush merge — the same lexicographic-min rule as
    `carbon_sweep._pareto_merge`, so flush cadence cannot change the
    frontier."""
    if a is None:
        return b
    take_b = (b["op"] < a["op"]) \
        | ((b["op"] == a["op"]) & (b["cell"] < a["cell"])) \
        | ((b["op"] == a["op"]) & (b["cell"] == a["cell"])
           & (b["draw"] < a["draw"]))
    return {k: np.where(take_b, b[k], a[k]) for k in _PAR_FIELDS}


@dataclasses.dataclass
class SweepResult:
    """Streamed sweep summaries. Per-cell arrays have the spec's
    (D, F, I, V, W, T, FR) axis shape; `counts` appends the joint
    core x redundancy candidate axis."""
    spec: SweepSpec
    path: str
    mean: np.ndarray
    p50: np.ndarray
    p90: np.ndarray
    p99: np.ndarray
    min: np.ndarray
    max: np.ndarray
    mean_emb: np.ndarray
    mean_op: np.ndarray
    fleet_mean: np.ndarray
    counts: np.ndarray           # (..., C*R) chosen-candidate draws/cell
    hist: np.ndarray             # (B,) int64 best-total histogram
    hist_edges: np.ndarray       # (B+1,) kg CO2e bin edges
    pareto: Dict[str, np.ndarray]
    n_cells: int
    n_scenarios: int
    wall_s: float
    scenarios_per_s: float

    @property
    def core_share(self) -> np.ndarray:
        return self.counts / self.spec.draws

    @property
    def best_core(self) -> np.ndarray:
        """Modal chosen core per cell (first max on draw-count ties);
        with a redundancy axis, the core half of the joint winner."""
        return np.argmax(self.counts, axis=-1) % len(self.spec.cores)

    @property
    def best_redundancy(self) -> np.ndarray:
        """Redundancy half of the modal joint (core, redundancy) winner
        — index into `spec.redundancies` (all 0 for default specs)."""
        return np.argmax(self.counts, axis=-1) // len(self.spec.cores)

    def quantile(self, q: float) -> float:
        """Whole-sweep best-total quantile from the streamed histogram
        (upper bin edge — exact to bin resolution)."""
        cum = np.cumsum(self.hist)
        i = int(np.searchsorted(cum, q * cum[-1]))
        return float(self.hist_edges[min(i + 1, len(self.hist))])

    def frontier(self) -> List[Dict]:
        """Non-dominated embodied-vs-operational points, ascending in
        embodied kg, annotated with their scenario coordinates."""
        finite = np.isfinite(self.pareto["op"])
        order = np.argsort(self.pareto["emb"][finite], kind="stable")
        rows, best_op = [], np.inf
        for j in np.nonzero(finite)[0][order]:
            op = float(self.pareto["op"][j])
            if op >= best_op:
                continue                      # dominated by a smaller-emb bin
            best_op = op
            cell = int(self.pareto["cell"][j])
            di, fi, ii, vi, wi, ti, fri = self.spec.decode_cell(cell)
            cand = int(self.pareto["core"][j])
            n_cores = len(self.spec.cores)
            rows.append({
                "embodied_kg": float(self.pareto["emb"][j]),
                "operational_kg": op,
                "total_kg": float(self.pareto["emb"][j] + op),
                "lifetime_s": float(self.pareto["life"][j] * DAY_S),
                "core": self.spec.cores[cand % n_cores].name,
                "redundancy": self.spec.redundancies[cand // n_cores],
                "workload": self.spec.workloads[wi],
                "dist": self.spec.dists[di].name,
                "execs_per_day": self.spec.execs_per_day[fi],
                "intensity": self.spec.intensities[ii],
                "volume": self.spec.volumes[vi],
                "timing": self.spec.timing[ti],
                "fault_rate": self.spec.fault_rates[fri],
                "cell": cell,
                "draw": int(self.pareto["draw"][j]),
            })
        return rows


# ----------------------------------------------------------- run_sweep
def run_sweep(spec: SweepSpec, *, path: str = "jnp",
              tile_cells: int = 1024, dtype=np.float32,
              n_hist: int = 64, n_pareto: int = 32,
              interpret: Optional[bool] = None,
              flush_limit: int = 1 << 30) -> SweepResult:
    """Stream the whole scenario space through the fused evaluate-and-
    reduce step in `tile_cells`-cell tiles.

    Device memory is bounded by one tile regardless of sweep size; the
    global int32 histogram flushes into a host int64 tally (and the
    Pareto accumulator merges host-side) every `flush_limit` scenarios,
    so counts can never wrap. float64 sweeps (the oracle-parity mode)
    require `jax.experimental.enable_x64` around the call.
    """
    spec.validate()
    dtype = np.dtype(dtype)
    if dtype == np.float64 and not jax.config.jax_enable_x64:
        raise ValueError("float64 sweeps need jax.experimental."
                         "enable_x64() around run_sweep")
    n_cells = spec.n_cells
    tile = max(1, min(tile_cells, n_cells))
    step, tables = _sweep_step(spec, tile, path, dtype.name, n_hist,
                               n_pareto, interpret)
    C = spec.n_candidates
    fields = ("mean", "p50", "p90", "p99", "min", "max", "mean_emb",
              "mean_op", "fleet_mean")
    host = {f: np.empty(n_cells, dtype) for f in fields}
    host_counts = np.empty((n_cells, C), np.int32)
    hist64 = np.zeros(n_hist, np.int64)
    par_host: Optional[Dict[str, np.ndarray]] = None
    since_flush = 0

    t0 = time.perf_counter()
    acc = csk.init_acc(n_hist, n_pareto, jnp.dtype(dtype))
    for start in range(0, n_cells, tile):
        acc, stats = step(acc, np.int32(start))
        k = min(tile, n_cells - start)
        for f in fields:
            host[f][start:start + k] = np.asarray(stats[f])[:k]
        host_counts[start:start + k] = np.asarray(stats["counts"])[:k]
        since_flush += tile * spec.draws
        if since_flush >= flush_limit:
            hist64 += np.asarray(acc.hist, np.int64)
            par_host = _merge_pareto_host(par_host, _acc_to_host(acc))
            acc = csk.init_acc(n_hist, n_pareto, jnp.dtype(dtype))
            since_flush = 0
    hist64 += np.asarray(acc.hist, np.int64)
    par_host = _merge_pareto_host(par_host, _acc_to_host(acc))
    wall = time.perf_counter() - t0

    shape = spec.axis_sizes
    return SweepResult(
        spec=spec, path=path,
        **{f: host[f].reshape(shape) for f in fields},
        counts=host_counts.reshape(shape + (C,)),
        hist=hist64, hist_edges=tables.hist_edges(n_hist),
        pareto=par_host, n_cells=n_cells,
        n_scenarios=spec.n_scenarios, wall_s=wall,
        scenarios_per_s=spec.n_scenarios / max(wall, 1e-12))


# ------------------------------------------------- workload spec helper
def workload_spec(keys: Optional[Sequence[str]] = None, *,
                  dists: Sequence[LifetimeDist],
                  execs_per_day: Sequence[float],
                  intensities: Sequence[float],
                  volumes: Sequence[float] = (1.0,),
                  cores: Optional[Sequence[Core]] = None,
                  timing: Sequence[str] = ("base",),
                  fault_rates: Sequence[float] = (0.0,),
                  redundancies: Sequence[str] = ("none",),
                  draws: int = 64, seed: int = 0, n_profile: int = 3,
                  measured_cycles: Optional[Mapping[str, Mapping[
                      str, float]]] = None) -> SweepSpec:
    """Build a SweepSpec from FlexiBench workloads: PyISS-profiled
    DeviceProfiles (measured §9.10 event vectors) and, when the timing
    axis asks for it, FlexiLint WCET certificates (§9.11) priced per
    candidate core under the dynamic cost row."""
    from repro.flexibench.base import all_workloads, get
    from repro.flexibench.memory import profile_memory
    from repro.flexibits import analyze
    from repro.flexibits.cycles import TICKS_PER_CYCLE, cost_row
    from repro.flexibits.pyiss import PyISS

    keys = tuple(w.key for w in all_workloads()) if keys is None \
        else tuple(keys)
    cores = tuple(CORES.values()) if cores is None else tuple(cores)
    timing = tuple(timing)
    profiles, wcet_rows = [], []
    for k in keys:
        w = get(k)
        rng = np.random.default_rng(0)
        n1 = n2 = 0.0
        events = np.zeros_like(np.asarray(
            PyISS(w.program.code, w.total_mem_words,
                  w.initial_memory(w.gen_inputs(rng, 1)[0]))
            .run(w.max_steps).events, np.float64))
        rng = np.random.default_rng(0)
        xs = w.gen_inputs(rng, n_profile)
        for x in xs:
            sim = PyISS(w.program.code, w.total_mem_words,
                        w.initial_memory(x)).run(w.max_steps)
            n1 += sim.n_instr - sim.n_two_stage
            n2 += sim.n_two_stage
            events += np.asarray(sim.events, np.float64)
        mem = profile_memory(w)
        profiles.append(DeviceProfile(
            n_one_stage=n1 / n_profile, n_two_stage=n2 / n_profile,
            vm_kb=mem["vm_kb"], nvm_kb=mem["nvm_kb"],
            events=tuple(events / n_profile)))
        if "wcet" in timing:
            a = analyze.analyze_workload(w)
            row = []
            for core in cores:
                ticks = a.wcet_ticks(cost_row(core, dynamic=True))
                if ticks is None:
                    raise ValueError(f"workload {k!r} has no finite "
                                     f"WCET certificate")
                row.append(ticks / TICKS_PER_CYCLE)
            wcet_rows.append(tuple(row))
    meas = None
    if measured_cycles is not None:
        meas = tuple(tuple(float(measured_cycles[k][c.name])
                           for c in cores) for k in keys)
    return SweepSpec(
        workloads=keys, profiles=tuple(profiles), dists=tuple(dists),
        execs_per_day=tuple(float(f) for f in execs_per_day),
        intensities=tuple(float(i) for i in intensities),
        volumes=tuple(float(v) for v in volumes), cores=cores,
        timing=timing,
        fault_rates=tuple(float(f) for f in fault_rates),
        redundancies=tuple(redundancies),
        draws=draws, seed=seed,
        wcet_cycles=tuple(wcet_rows) if wcet_rows else None,
        measured_cycles=meas)


# ------------------------------------------- serving-planner jnp mirror
def serving_plan_jnp(*, n_params: float, kv_bytes_per_token: float,
                     lifetimes_days, qps_grid,
                     chips_options: Sequence[int] = (8, 16, 32, 64,
                                                     128, 256),
                     intensity: float = 0.367,
                     variants: Sequence[ServeVariant] = VARIANTS) -> Dict:
    """jnp mirror of `planner.plan_grid` — same option vectors, same op
    order, same first-min tie-break — exactly equal to the numpy
    oracle on shared grid points under float64/enable_x64
    (tests/test_sweep.py), and jit/vmap-compatible for distributional
    serving what-ifs (e.g. vmapped over an intensity axis)."""
    if not list(chips_options):
        raise ValueError("chips_options is empty")
    if not list(variants):
        raise ValueError("variants is empty")
    opt_vi, opt_chips, opt_tps = [], [], []
    for vi, v in enumerate(variants):
        for chips in chips_options:
            opt_vi.append(vi)
            opt_chips.append(chips)
            opt_tps.append(tokens_per_s_per_chip(
                n_params, v.weight_bits, kv_bytes_per_token, chips)
                * chips)
    opt_vi = jnp.asarray(np.asarray(opt_vi, np.int32))
    opt_chips = jnp.asarray(np.asarray(opt_chips, np.float64))
    opt_tps = jnp.asarray(np.asarray(opt_tps, np.float64))
    opt_prep = jnp.asarray(np.asarray(
        [variants[v].prep_kg for v in opt_vi], np.float64))

    days = jnp.asarray(lifetimes_days)
    qps = jnp.asarray(qps_grid)
    feasible = opt_tps[None, None, :] >= qps[None, :, None]
    emb = (opt_chips[None, None, :] * TPU_EMBODIED_KG
           * jnp.minimum(days / (3 * 365.0), 1.0)[:, None, None])
    util = jnp.where(feasible, qps[None, :, None] / opt_tps[None, None, :],
                     0.0)
    kwh = (opt_chips[None, None, :] * CHIP_POWER_W * PUE * util
           * days[:, None, None] * 24.0 / 1000.0)
    # both addends are >= 0; `abs` blocks XLA CPU's FMA contraction of
    # the mul-feeding-add so the mirror rounds exactly like numpy
    total = (opt_prep[None, None, :] + jnp.abs(emb)
             + jnp.abs(kwh * intensity))
    total = jnp.where(feasible, total, jnp.inf)
    k = jnp.argmin(total, axis=2)
    best_kg = jnp.take_along_axis(total, k[..., None], axis=2)[..., 0]
    met = jnp.isfinite(best_kg)
    best = jnp.where(met, opt_vi[k], -1).astype(jnp.int32)
    best_chips = jnp.where(met, opt_chips[k], 0).astype(jnp.int32)
    return {"variant_idx": best, "chips": best_chips,
            "total_kg": best_kg,
            "variants": [v.name for v in variants]}
