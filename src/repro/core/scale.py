"""At-scale computing-for-sustainability model (paper §6.4, Table 5).

US beef: 26.19 B lbs consumed/yr [103], 31% wasted [11],
14.5 kg CO2e per kg beef [79], typical car 4.6 t CO2e/yr [110].
"""
from __future__ import annotations

from typing import Dict

BEEF_LBS_PER_YEAR = 26.19e9
KG_PER_LB = 1 / 2.20462
BEEF_KG_PER_YEAR = BEEF_LBS_PER_YEAR * KG_PER_LB       # ~11.88e9 slabs (1kg)
WASTE_FRACTION = 0.31
CO2_PER_KG_BEEF = 14.5
CAR_KG_PER_YEAR = 4_600.0

SYSTEM_FOOTPRINTS_KG = {
    "flexible": 0.01086,
    "hybrid": 0.12829,
    "silicon": 2.66,
}


def savings_kg(device_kg: float, effectiveness: float) -> float:
    """Net annual kg CO2e saved when every 1-kg slab carries a device.

    effectiveness = fraction of to-be-wasted slabs actually saved.
    """
    saved = effectiveness * WASTE_FRACTION * BEEF_KG_PER_YEAR \
        * CO2_PER_KG_BEEF
    spent = BEEF_KG_PER_YEAR * device_kg
    return saved - spent


def savings_cars(device_kg: float, effectiveness: float) -> float:
    return savings_kg(device_kg, effectiveness) / CAR_KG_PER_YEAR


def breakeven_effectiveness(device_kg: float) -> float:
    """Fraction of wasted slabs that must be saved to break even
    (paper: flexible ~1/417, hybrid ~1/35, silicon ~1/2)."""
    return device_kg / (WASTE_FRACTION * CO2_PER_KG_BEEF)


def table5() -> Dict[str, Dict]:
    out = {}
    for name, fp in SYSTEM_FOOTPRINTS_KG.items():
        out[name] = {
            "device_kg": fp,
            "savings_kg": {e: savings_kg(fp, e)
                           for e in (1.0, 0.1, 0.01, 0.001)},
            "savings_cars": {e: savings_cars(fp, e)
                             for e in (1.0, 0.1, 0.01, 0.001)},
            "breakeven": breakeven_effectiveness(fp),
        }
    return out
