"""mamba2-1.3b: attention-free SSD (state-space duality) LM [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,                  # attention-free
    n_kv_heads=0,
    d_ff=0,                     # no FFN; mamba block contains the mixing MLP
    vocab=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=256),
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, vocab=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
    )
