"""gemma3-12b: dense LM with 5:1 local:global attention [hf:google/gemma-3]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    rope_theta=1e6,
    window=1024,        # sliding window for local layers
    global_every=6,     # every 6th layer is global (5 local : 1 global)
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=6, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16, window=16,
                          global_every=3)
