"""qwen2-moe-a2.7b: 4 shared + 60 routed top-4 MoE [hf:Qwen/Qwen1.5-MoE-A2.7B]."""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,                 # per-expert hidden dim (per the assigned spec)
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    moe=MoEConfig(
        n_experts=60,
        top_k=4,
        n_shared=4,
        d_ff_expert=1408,
        n_dense_layers=0,
        capacity_factor=1.25,
        # §Perf levers A+B: EP needs E % mesh == 0 (60 -> 64, padded
        # experts router-masked); hierarchical per-shard dispatch avoids
        # the replicated-buffer all-reduce (269s -> 14s collective term)
        n_experts_padded=64,
        dispatch="hierarchical",
    ),
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=64, vocab=256,
        head_dim=12,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=64),
    )
