"""llava-next-34b: VLM backbone with anyres patch-embedding stub.

Per the assignment spec the modality frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings which are prepended to the token
sequence. Total sequence length still equals the assigned shape's seq_len.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    rope_theta=5e6,
    n_patches=576,             # base-res anyres tile (stub frontend)
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                          d_ff=128, vocab=256, head_dim=16, n_patches=8)
