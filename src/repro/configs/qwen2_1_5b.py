"""qwen2-1.5b: dense GQA LM with QKV bias [arXiv:2407.10671]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab=151936,
    qkv_bias=True,
    rope_theta=1e6,
    tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=48, n_heads=4, n_kv_heads=2,
                          d_ff=96, vocab=256, head_dim=12)
