"""Registry of assigned architectures (``--arch <id>``) and smoke variants."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (SHAPES, SHAPES_BY_NAME, ModelConfig,
                                ShapeConfig, shape_applicable)

_MODULES = {
    "minitron-8b": "repro.configs.minitron_8b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "qwen2.5-14b": "repro.configs.qwen2_5_14b",
    "gemma3-12b": "repro.configs.gemma3_12b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "llava-next-34b": "repro.configs.llava_next_34b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def cells():
    """Yield every (arch, shape, applicable, why) dry-run cell — 40 total."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            yield arch, shape.name, ok, why
