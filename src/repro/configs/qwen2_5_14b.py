"""qwen2.5-14b: dense GQA LM with QKV bias [hf:Qwen/Qwen2.5 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=80, n_heads=4, n_kv_heads=2,
                          d_ff=160, vocab=256, head_dim=20)
