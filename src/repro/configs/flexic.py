"""The paper's own system configuration: FlexiBits cores on Pragmatic's
0.6um FlexIC process, plus the documented red-star deployment points
(paper Table 2 / Fig. 5) and all calibration constants (DESIGN.md §5).

The per-workload lifetime/frequency metadata itself lives on each
Workload (flexibench/workloads.py); this module centralizes the paper's
hardware operating points for reference and tests.
"""
from repro.flexibits.cycles import CORES, HERV, QERV, SERV  # noqa: F401

CLOCK_HZ = 10_000.0            # minimum viable ILI frequency (§4.4)
TAPEOUT_HZ = 30_900.0          # OpenROAD tape-out result (§6.5) — the
#                                hardware-gated part we do not reproduce
TESTED_HZ = 33_000.0           # fabricated dies' reliable maximum

# Fig. 5 red stars we validate claims at (within Table 2's stated ranges)
RED_STARS = {
    "FS": dict(lifetime_days=7, execs_per_day=24),      # produce patch
    "CT": dict(lifetime_days=270, execs_per_day=48),    # full-term patch
    "MC": dict(lifetime_days=4 * 365, execs_per_day=1),  # garment tag
    "AP": dict(lifetime_days=4 * 365, execs_per_day=24),  # urban monitor
}
