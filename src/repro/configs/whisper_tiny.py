"""whisper-tiny: enc-dec audio backbone, conv frontend stubbed [arXiv:2212.04356].

``input_specs()`` provides precomputed frame embeddings (B, n_frames, d_model)
in place of the conv-over-mel frontend, per the assignment spec.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,                 # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    n_audio_frames=1500,
    rope_theta=0.0,             # whisper uses learned positions; we use sinusoidal
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, n_enc_layers=2, d_model=48, n_heads=4,
                          n_kv_heads=4, d_ff=96, vocab=256, head_dim=12,
                          n_audio_frames=16)
