"""zamba2-7b: Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 mamba layers; after every 6 mamba layers one of 2 alternating *shared*
attention blocks is applied (13 invocations). LoRA adapters and the
original-embedding concat of the real Zamba2 are omitted (DESIGN.md §8.5).
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab=32000,
    head_dim=112,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, chunk=256),
    shared_attn_period=6,
    n_shared_blocks=2,
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=9, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        head_dim=16,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=32),
        shared_attn_period=3,
    )
