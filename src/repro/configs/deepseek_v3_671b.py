"""deepseek-v3-671b: MLA + 1 shared + 256 routed top-8 MoE + MTP [arXiv:2412.19437].

Adam moments for 671B params exceed v5e HBM at 512 chips; the config selects
adafactor (factored second moment) — see DESIGN.md §4.
"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,            # MLA: latent cache, head count for attention
    d_ff=18432,                # dense-layer FFN width (first 3 layers)
    vocab=129280,
    rope_theta=10000.0,
    moe=MoEConfig(
        n_experts=256,
        top_k=8,
        n_shared=1,
        d_ff_expert=2048,
        n_dense_layers=3,
        capacity_factor=1.25,
        # §Perf lever B: per-shard dispatch kills the token all-gathers
        # (collective term 680s -> 215s, useful-flops 0.065 -> 0.514)
        dispatch="hierarchical",
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    use_mtp=True,
    optimizer="adafactor",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
        moe=MoEConfig(n_experts=8, top_k=2, n_shared=1, d_ff_expert=32,
                      n_dense_layers=1),
        mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
    )
