"""Architecture config dataclasses for the assigned model pool.

Every architecture in the pool is expressed as a single ``ModelConfig``.
Families: dense | moe | vlm | hybrid | ssm | audio.

Shapes (assigned): train_4k, prefill_32k, decode_32k, long_500k.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts
    top_k: int = 0
    n_shared: int = 0               # always-on shared experts
    d_ff_expert: int = 0            # per-expert hidden dim
    n_dense_layers: int = 0         # leading dense layers (deepseek style)
    capacity_factor: float = 1.25
    router_aux_weight: float = 1e-3
    # 'dense_capacity' (flat scatter/gather) or 'hierarchical' (per-data-
    # shard dispatch with an explicit shard axis — §Perf levers A/B)
    dispatch: str = "dense_capacity"
    # pad experts so EP sharding divides the model axis (e.g. 60 -> 64);
    # padded experts are masked in the router. 0 = no padding.
    n_experts_padded: int = 0

    @property
    def e_padded(self) -> int:
        return self.n_experts_padded or self.n_experts


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-style multi-head latent attention."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba2 (SSD) block config."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk: int = 256


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|vlm|hybrid|ssm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    # local/global attention (gemma3): every `global_every`-th layer is global,
    # the rest use sliding window `window`.
    window: int = 0                 # 0 = full attention everywhere
    global_every: int = 0
    # MoE / MLA / SSM sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): shared attention block applied after every
    # `shared_attn_period` mamba layers, alternating between 2 shared blocks.
    shared_attn_period: int = 0
    n_shared_blocks: int = 2
    # vlm stub: number of image patch embeddings prepended to the sequence
    n_patches: int = 0
    # audio stub (whisper): encoder config
    n_enc_layers: int = 0
    n_audio_frames: int = 0
    # MTP (deepseek): extra next-next-token prediction head
    use_mtp: bool = False
    mtp_weight: float = 0.1
    # training
    optimizer: str = "adamw"        # adamw | adafactor
    remat: bool = True
    zero1: bool = False             # shard optimizer state over data axis
    # serving: weight bit-width for bit-plane/quantized serving (16|8|4)
    serve_bits: int = 16
    # attention implementation: 'chunked' (flash-style jnp) or 'plain'
    attn_impl: str = "chunked"
    attn_chunk: int = 1024
    # scan-over-layers toggle (always true for big models; smokes keep it on)
    scan_layers: bool = True
    # decode with a python loop over layers (static cache indices let XLA
    # elide the stacked-cache copies that dynamic ds/dus provoke — §Perf C3)
    decode_unroll: bool = False
    # prefill-only causal triangle skip (dynamic-trip KV loop). OFF by
    # default: the HLO-text analyzer cannot multiply unknown-trip loops, so
    # dry-run numbers with this lever under-count (EXPERIMENTS §Perf it. 7)
    prefill_triangle_skip: bool = False
    dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k only runs on sub-quadratic archs (ssm/hybrid); see
    DESIGN.md §3."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, ("skip: full-attention arch (quadratic prefill at 500k); "
                       "per-spec only SSM/hybrid run long_500k")
    return True, ""
