"""Decoder-only LM assembly: dense / MoE / local:global / VLM families.

Layers are *scanned* (params stacked on a leading layer axis) with optional
per-layer remat — this keeps HLO size O(1) in depth (fast multi-arch
compiles) and activation memory flat. Local:global archs (gemma3) stack
params as (n_groups, group, ...) and scan over groups with the intra-group
pattern unrolled, so window layers use the O(L*window) attention path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.distributed.meshctx import shard_act
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE

VOCAB_PAD = 256


def padded_vocab(v: int) -> int:
    return -(-v // VOCAB_PAD) * VOCAB_PAD


# ------------------------------------------------------------------- init

def _attn_dims(cfg: ModelConfig) -> L.AttnDims:
    return L.AttnDims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim, cfg.qkv_bias)


def init_dense_layer(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": (MLA.init_mla(k1, cfg.d_model, cfg.n_heads, cfg.mla, dtype)
                 if cfg.mla else L.init_attn(k1, _attn_dims(cfg), dtype)),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def init_moe_layer(key, cfg: ModelConfig, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "attn": (MLA.init_mla(k1, cfg.d_model, cfg.n_heads, cfg.mla, dtype)
                 if cfg.mla else L.init_attn(k1, _attn_dims(cfg), dtype)),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
        "moe": MOE.init_moe(k2, cfg.d_model, cfg.moe, dtype),
    }


def _stack_layers(init_one, key, n: int):
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


def init_decoder(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg.vocab)
    ks = jax.random.split(key, 6)
    params = {
        "embed": (jax.random.normal(ks[0], (vp, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(ks[1], (cfg.d_model, vp))
                             * cfg.d_model ** -0.5).astype(dtype)

    n_moe = 0
    n_dense = cfg.n_layers
    if cfg.moe is not None:
        n_dense = cfg.moe.n_dense_layers
        n_moe = cfg.n_layers - n_dense
    if n_dense:
        stacked = _stack_layers(
            lambda k: init_dense_layer(k, cfg, dtype), ks[2], n_dense)
        g = cfg.global_every or 1
        if g > 1:
            assert n_dense % g == 0, (n_dense, g)
            stacked = jax.tree.map(
                lambda x: x.reshape((n_dense // g, g) + x.shape[1:]), stacked)
        params["dense_layers"] = stacked
    if n_moe:
        params["moe_layers"] = _stack_layers(
            lambda k: init_moe_layer(k, cfg, dtype), ks[3], n_moe)
    if cfg.use_mtp:
        k1, k2 = jax.random.split(ks[4])
        params["mtp"] = {
            "proj": (jax.random.normal(k1, (2 * cfg.d_model, cfg.d_model))
                     * (2 * cfg.d_model) ** -0.5).astype(dtype),
            "layer": init_dense_layer(
                k2, cfg.replace(moe=None, d_ff=cfg.d_ff), dtype),
            "norm": jnp.zeros((cfg.d_model,), dtype),
        }
    return params


# ------------------------------------------------------------------- blocks

def attn_block(p, cfg: ModelConfig, h, *, window: int, positions,
               triangle_skip: bool = False):
    """triangle_skip: bound the KV scan at the causal diagonal (dynamic
    trip count, NOT differentiable) — prefill-only §Perf lever E that
    halves global-attention FLOPs vs the masked-scan train path."""
    x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
    if cfg.mla:
        o = MLA.mla_forward(p["attn"], x, cfg.mla, cfg.rope_theta,
                            chunk=cfg.attn_chunk,
                            triangle_skip=triangle_skip)
    else:
        q, k, v = L.attn_qkv(p["attn"], x, positions, cfg.rope_theta)
        if cfg.attn_impl == "plain":
            o = L.plain_attention(q, k, v, causal=True, window=window)
        else:
            o = L.chunked_attention(q, k, v, causal=True, window=window,
                                    chunk=cfg.attn_chunk,
                                    triangle_skip=triangle_skip)
        o = L.attn_out(p["attn"], o)
    return h + o


def ffn_block(p, cfg: ModelConfig, h):
    x = L.rms_norm(h, p["ln2"], cfg.rms_eps)
    if "moe" in p:
        o, aux = MOE.moe_ffn(p["moe"], x, cfg.moe)
    else:
        o, aux = L.mlp(p["mlp"], x), 0.0
    return h + o, aux


def layer_fwd(p, cfg: ModelConfig, h, *, window: int, positions):
    h = shard_act(h, "batch", None, None)
    h = attn_block(p, cfg, h, window=window, positions=positions)
    h, aux = ffn_block(p, cfg, h)
    return h, aux


def _maybe_remat(f, cfg: ModelConfig):
    return jax.checkpoint(f) if cfg.remat else f


def _window_for(cfg: ModelConfig, idx_in_group: int) -> int:
    """gemma3 pattern: positions 0..g-2 local, g-1 global."""
    g = cfg.global_every or 1
    if g == 1 or cfg.window == 0:
        return 0
    return cfg.window if idx_in_group < g - 1 else 0


# ------------------------------------------------------------------- forward

def decoder_hidden(params, cfg: ModelConfig, h, positions):
    """Run all layers over h: (B, L, D). Returns (h, aux_loss_sum)."""
    aux_total = 0.0

    if "dense_layers" in params:
        g = cfg.global_every or 1

        def group_body(h, p_group):
            aux = 0.0
            for i in range(g):
                p_i = jax.tree.map(lambda x: x[i], p_group) if g > 1 \
                    else p_group
                w = _window_for(cfg, i)
                body = _maybe_remat(
                    lambda p, hh, _w=w: layer_fwd(p, cfg, hh, window=_w,
                                                  positions=positions), cfg)
                h, a = body(p_i, h)
                aux = aux + a
            return h, aux

        h, auxs = lax.scan(lambda c, p: group_body(c, p), h,
                           params["dense_layers"])
        aux_total = aux_total + jnp.sum(jnp.asarray(auxs))

    if "moe_layers" in params:
        def moe_body(h, p):
            body = _maybe_remat(
                lambda pp, hh: layer_fwd(pp, cfg, hh, window=0,
                                         positions=positions), cfg)
            return body(p, h)

        h, auxs = lax.scan(moe_body, h, params["moe_layers"])
        aux_total = aux_total + jnp.sum(jnp.asarray(auxs))

    return h, aux_total


def embed_tokens(params, cfg: ModelConfig, tokens):
    h = jnp.take(params["embed"], tokens, axis=0)
    return shard_act(h, "batch", None, None)


def logits_fn(params, cfg: ModelConfig, h):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bld,dv->blv", h, w)
    logits = shard_act(logits, "batch", None, "model")
    vp = padded_vocab(cfg.vocab)
    if vp != cfg.vocab:
        mask = (jnp.arange(vp) < cfg.vocab)
        logits = jnp.where(mask[None, None, :], logits, L.NEG_INF)
    return logits


def decoder_forward(params, cfg: ModelConfig, tokens, patches=None):
    """tokens: (B, Lt); patches: (B, P, D) prepended (VLM stub)."""
    h = embed_tokens(params, cfg, tokens)
    if patches is not None:
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
    b, l, _ = h.shape
    positions = jnp.arange(l)[None, :]
    h, aux = decoder_hidden(params, cfg, h, positions)
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    return h, aux


def softmax_xent(logits, targets, mask):
    """logits (B,L,V) fp32-accumulated xent; mask (B,L) weights."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


def decoder_loss(params, cfg: ModelConfig, batch):
    tokens = batch["tokens"]
    targets = batch["targets"]
    patches = batch.get("patches")
    h, aux = decoder_forward(params, cfg, tokens, patches)
    if patches is not None:
        h = h[:, patches.shape[1]:]                      # text positions only
    logits = logits_fn(params, cfg, h)
    mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
    loss = softmax_xent(logits, targets, mask)
    metrics = {"xent": loss}
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
        metrics["aux"] = aux
    if cfg.use_mtp:
        mtp_loss = _mtp_loss(params, cfg, h, tokens, targets, mask)
        loss = loss + cfg.mtp_weight * mtp_loss
        metrics["mtp"] = mtp_loss
    return loss, metrics


def _mtp_loss(params, cfg: ModelConfig, h, tokens, targets, mask):
    """DeepSeek-style depth-1 multi-token prediction: predict t+2 from
    (h_t, emb(y_{t+1})) through one extra transformer layer."""
    p = params["mtp"]
    emb_next = embed_tokens(params, cfg, targets)        # y_{t+1} embeddings
    x = jnp.concatenate([L.rms_norm(h, p["norm"], cfg.rms_eps),
                         emb_next], axis=-1)
    x = jnp.einsum("ble,ed->bld", x, p["proj"])
    positions = jnp.arange(x.shape[1])[None, :]
    x, _ = layer_fwd(p["layer"], cfg.replace(moe=None), x, window=0,
                     positions=positions)
    logits = logits_fn(params, cfg, x[:, :-1])
    # target at position t is y_{t+2} = targets shifted left by one
    t2 = targets[:, 1:]
    m2 = mask[:, 1:]
    return softmax_xent(logits, t2, m2)


# ------------------------------------------------------------------- decode

def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    caches = {}
    n_moe = 0
    n_dense = cfg.n_layers
    if cfg.moe is not None:
        n_dense = cfg.moe.n_dense_layers
        n_moe = cfg.n_layers - n_dense

    def kv(n):
        return jnp.zeros((n, batch, seq_len, cfg.n_kv_heads, hd), dtype)

    if cfg.mla:
        m = cfg.mla
        if n_dense:
            caches["dense"] = {
                "c_kv": jnp.zeros((n_dense, batch, seq_len, m.kv_lora_rank),
                                  dtype),
                "k_rope": jnp.zeros(
                    (n_dense, batch, seq_len, m.qk_rope_head_dim), dtype)}
        if n_moe:
            caches["moe"] = {
                "c_kv": jnp.zeros((n_moe, batch, seq_len, m.kv_lora_rank),
                                  dtype),
                "k_rope": jnp.zeros(
                    (n_moe, batch, seq_len, m.qk_rope_head_dim), dtype)}
    else:
        if n_dense:
            g = cfg.global_every or 1
            shape = ((n_dense // g, g, batch, seq_len, cfg.n_kv_heads, hd)
                     if g > 1 else (n_dense, batch, seq_len, cfg.n_kv_heads,
                                    hd))
            caches["dense"] = {"k": jnp.zeros(shape, dtype),
                               "v": jnp.zeros(shape, dtype)}
        if n_moe:
            caches["moe"] = {"k": kv(n_moe), "v": kv(n_moe)}
    return caches


def _gqa_layer_decode(p, cfg, h, k_cache, v_cache, pos, window):
    x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
    positions = pos[None, None]
    q = jnp.einsum("bld,dhk->blhk", x, p["attn"]["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["attn"]["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["attn"]["wv"])
    if "bq" in p["attn"]:
        q, k, v = (q + p["attn"]["bq"], k + p["attn"]["bk"],
                   v + p["attn"]["bv"])
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    k_cache = lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), pos, axis=1)
    v_cache = lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), pos, axis=1)
    o = L.decode_attention(q, k_cache, v_cache, pos, window=window)
    h = h + L.attn_out(p["attn"], o)
    h, _ = ffn_block(p, cfg, h)
    return h, k_cache, v_cache


def _mla_layer_decode(p, cfg, h, cache_l, pos):
    x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
    o, cache_l = MLA.mla_decode_step(p["attn"], x, cache_l, pos, cfg.mla,
                                     cfg.rope_theta)
    h = h + o
    h, _ = ffn_block(p, cfg, h)
    return h, cache_l


def scan_layers_carry(body, h, params_stacked, state, n: int,
                      unroll: bool = False):
    """Iterate layers with the decode state carried so XLA updates the
    stacked buffers in place. Passing caches as scan xs/ys makes XLA copy
    the full stacked cache every layer (§Perf lever C2: 20 GB/layer of
    copies on minitron decode); `unroll=True` additionally uses *static*
    layer indices so copy-insertion can prove slice disjointness (§Perf C3).

    body(h, p_l, state_l) -> (h, new_state_l)
    """
    if unroll:
        for li in range(n):
            p_l = jax.tree.map(lambda x: x[li], params_stacked)
            state_l = jax.tree.map(lambda s: s[li], state)
            h, new_l = body(h, p_l, state_l)
            state = jax.tree.map(
                lambda s, ns: lax.dynamic_update_index_in_dim(
                    s, ns.astype(s.dtype), li, 0), state, new_l)
        return h, state

    def step(carry, xs):
        h, state = carry
        p_l, li = xs
        state_l = jax.tree.map(
            lambda s: lax.dynamic_index_in_dim(s, li, 0, keepdims=False),
            state)
        h, new_l = body(h, p_l, state_l)
        state = jax.tree.map(
            lambda s, ns: lax.dynamic_update_index_in_dim(
                s, ns.astype(s.dtype), li, 0), state, new_l)
        return (h, state), None

    (h, state), _ = lax.scan(step, (h, state),
                             (params_stacked, jnp.arange(n)))
    return h, state


def decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    """tokens: (B, 1); pos: scalar int32. Returns (logits (B,1,V), cache)."""
    h = embed_tokens(params, cfg, tokens)
    new_cache = {}

    if "dense_layers" in params:
        g = cfg.global_every or 1
        n_dense = jax.tree.leaves(params["dense_layers"])[0].shape[0]
        if cfg.mla:
            def body(h, p, c):
                return _mla_layer_decode(p, cfg, h, c, pos)
            h, c = scan_layers_carry(body, h, params["dense_layers"],
                                     cache["dense"], n_dense,
                                     unroll=cfg.decode_unroll)
            new_cache["dense"] = c
        else:
            def body(h, p_group, c):
                kc, vc = c["k"], c["v"]
                if g > 1:
                    kcs, vcs = [], []
                    for i in range(g):
                        p_i = jax.tree.map(lambda x: x[i], p_group)
                        h, k2, v2 = _gqa_layer_decode(
                            p_i, cfg, h, kc[i], vc[i], pos,
                            _window_for(cfg, i))
                        kcs.append(k2)
                        vcs.append(v2)
                    return h, {"k": jnp.stack(kcs), "v": jnp.stack(vcs)}
                h, k2, v2 = _gqa_layer_decode(p_group, cfg, h, kc, vc,
                                              pos, 0)
                return h, {"k": k2, "v": v2}
            h, c = scan_layers_carry(body, h, params["dense_layers"],
                                     cache["dense"], n_dense,
                                     unroll=cfg.decode_unroll)
            new_cache["dense"] = c

    if "moe_layers" in params:
        n_moe = jax.tree.leaves(params["moe_layers"])[0].shape[0]
        if cfg.mla:
            def body(h, p, c):
                return _mla_layer_decode(p, cfg, h, c, pos)
            h, c = scan_layers_carry(body, h, params["moe_layers"],
                                     cache["moe"], n_moe,
                                     unroll=cfg.decode_unroll)
            new_cache["moe"] = c
        else:
            def body(h, p, c):
                h, k2, v2 = _gqa_layer_decode(p, cfg, h, c["k"], c["v"],
                                              pos, 0)
                return h, {"k": k2, "v": v2}
            h, c = scan_layers_carry(body, h, params["moe_layers"],
                                     cache["moe"], n_moe,
                                     unroll=cfg.decode_unroll)
            new_cache["moe"] = c

    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, cfg, h), new_cache


def prefill(params, cfg: ModelConfig, tokens, seq_len: int, patches=None):
    """Forward the prompt, build a cache of capacity `seq_len`.

    Returns (last-position logits (B,1,V), cache). For simplicity the cache
    is rebuilt by a forward pass that also emits K/V (scan ys).
    """
    h = embed_tokens(params, cfg, tokens)
    if patches is not None:
        h = jnp.concatenate([patches.astype(h.dtype), h], axis=1)
    b, l, _ = h.shape
    positions = jnp.arange(l)[None, :]
    pad = seq_len - l
    cache = {}

    def gqa_kv(p, x):
        k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
        v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
        if "bk" in p:
            k, v = k + p["bk"], v + p["bv"]
        k = L.apply_rope(k, positions, cfg.rope_theta)
        return k, v

    if "dense_layers" in params:
        g = cfg.global_every or 1
        if cfg.mla:
            def body(h, p):
                x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
                c = MLA.mla_prefill_cache(p["attn"], x, cfg.mla,
                                          cfg.rope_theta, seq_len)
                o = MLA.mla_forward(p["attn"], x, cfg.mla, cfg.rope_theta,
                                    chunk=cfg.attn_chunk,
                                    triangle_skip=cfg.prefill_triangle_skip)
                h = h + o
                h, _ = ffn_block(p, cfg, h)
                return h, c
            h, c = lax.scan(body, h, params["dense_layers"])
            cache["dense"] = c
        else:
            def body(h, p_group):
                ks, vs = [], []
                for i in range(g):
                    p_i = jax.tree.map(lambda x: x[i], p_group) if g > 1 \
                        else p_group
                    x = L.rms_norm(h, p_i["ln1"], cfg.rms_eps)
                    k, v = gqa_kv(p_i["attn"], x)
                    ks.append(jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))))
                    vs.append(jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))))
                    h = attn_block(p_i, cfg, h,
                                   window=_window_for(cfg, i),
                                   positions=positions,
                                   triangle_skip=cfg.prefill_triangle_skip)
                    h, _ = ffn_block(p_i, cfg, h)
                if g > 1:
                    return h, (jnp.stack(ks), jnp.stack(vs))
                return h, (ks[0], vs[0])
            h, (kc, vc) = lax.scan(body, h, params["dense_layers"])
            cache["dense"] = {"k": kc, "v": vc}

    if "moe_layers" in params:
        if cfg.mla:
            def body(h, p):
                x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
                c = MLA.mla_prefill_cache(p["attn"], x, cfg.mla,
                                          cfg.rope_theta, seq_len)
                o = MLA.mla_forward(p["attn"], x, cfg.mla, cfg.rope_theta,
                                    chunk=cfg.attn_chunk,
                                    triangle_skip=cfg.prefill_triangle_skip)
                h = h + o
                h, _ = ffn_block(p, cfg, h)
                return h, c
            h, c = lax.scan(body, h, params["moe_layers"])
            cache["moe"] = c
        else:
            def body(h, p):
                x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
                k, v = gqa_kv(p["attn"], x)
                k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                h = attn_block(p, cfg, h, window=0, positions=positions,
                               triangle_skip=cfg.prefill_triangle_skip)
                h, _ = ffn_block(p, cfg, h)
                return h, (k, v)
            h, (kc, vc) = lax.scan(body, h, params["moe_layers"])
            cache["moe"] = {"k": kc, "v": vc}

    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = logits_fn(params, cfg, h[:, -1:])
    return logits, cache
