"""Unified model API: build_model(config) -> Model with init/loss/serve fns.

All functions are pure; params/caches are pytrees of jnp arrays so they can
be created abstractly via jax.eval_shape for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import encdec as ED
from repro.models import hybrid as HY
from repro.models import ssm as SM
from repro.models import transformer as TF


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Any]
    loss_fn: Callable[[Any, Dict[str, jax.Array]], Any]
    init_cache: Callable[[int, int], Any]
    prefill_fn: Callable[..., Any]
    decode_fn: Callable[..., Any]

    def abstract_params(self, seed: int = 0):
        return jax.eval_shape(self.init_params,
                              jax.random.key(seed))


def build_model(cfg: ModelConfig) -> Model:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def init_params(key):
            return TF.init_decoder(key, cfg)

        def loss_fn(params, batch):
            return TF.decoder_loss(params, cfg, batch)

        def init_cache(batch, seq_len):
            return TF.init_cache(cfg, batch, seq_len)

        def prefill_fn(params, batch, seq_len):
            return TF.prefill(params, cfg, batch["tokens"], seq_len,
                              patches=batch.get("patches"))

        def decode_fn(params, cache, tokens, pos):
            return TF.decode_step(params, cfg, cache, tokens, pos)

    elif fam == "hybrid":
        def init_params(key):
            return HY.init_hybrid(key, cfg)

        def loss_fn(params, batch):
            return HY.hybrid_loss(params, cfg, batch)

        def init_cache(batch, seq_len):
            return HY.hybrid_init_cache(cfg, batch, seq_len)

        def prefill_fn(params, batch, seq_len):
            return HY.hybrid_prefill(params, cfg, batch["tokens"], seq_len)

        def decode_fn(params, cache, tokens, pos):
            return HY.hybrid_decode_step(params, cfg, cache, tokens, pos)

    elif fam == "ssm":
        def init_params(key):
            return SM.init_ssm_lm(key, cfg)

        def loss_fn(params, batch):
            return SM.ssm_loss(params, cfg, batch)

        def init_cache(batch, seq_len):
            return SM.ssm_init_cache(cfg, batch, seq_len)

        def prefill_fn(params, batch, seq_len):
            return SM.ssm_prefill(params, cfg, batch["tokens"], seq_len)

        def decode_fn(params, cache, tokens, pos):
            return SM.ssm_decode_step(params, cfg, cache, tokens, pos)

    elif fam == "audio":
        def init_params(key):
            return ED.init_encdec(key, cfg)

        def loss_fn(params, batch):
            return ED.encdec_loss(params, cfg, batch)

        def init_cache(batch, seq_len):
            return ED.encdec_init_cache(cfg, batch, seq_len)

        def prefill_fn(params, batch, seq_len):
            return ED.encdec_prefill(params, cfg, batch["frames"],
                                     batch["tokens"], seq_len)

        def decode_fn(params, cache, tokens, pos):
            return ED.encdec_decode_step(params, cfg, cache, tokens, pos)

    else:
        raise ValueError(f"unknown family {fam!r}")

    return Model(cfg, init_params, loss_fn, init_cache, prefill_fn,
                 decode_fn)


# -------------------------------------------------------- input specs

def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                prompt_frac: float = 0.5) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a dry-run cell.

    train: token/target batch. prefill: prompt of seq_len. decode: one new
    token + the positions scalar (cache specs come from init_cache).
    """
    b, l = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((b, l), i32), "targets": sds((b, l), i32),
                 "mask": sds((b, l), jnp.float32)}
        if cfg.family == "vlm":
            lt = l - cfg.n_patches
            specs["tokens"] = sds((b, lt), i32)
            specs["targets"] = sds((b, lt), i32)
            specs["mask"] = sds((b, lt), jnp.float32)
            specs["patches"] = sds((b, cfg.n_patches, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            specs["frames"] = sds((b, cfg.n_audio_frames, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((b, l), i32)}
        if cfg.family == "vlm":
            specs["tokens"] = sds((b, l - cfg.n_patches), i32)
            specs["patches"] = sds((b, cfg.n_patches, cfg.d_model),
                                   jnp.dtype(cfg.dtype))
        if cfg.family == "audio":
            specs["frames"] = sds((b, cfg.n_audio_frames, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
        return specs
    # decode: one token against a cache of capacity seq_len
    return {"tokens": sds((b, 1), i32), "pos": sds((), i32)}


# -------------------------------------------------------- flops accounting

def count_params(params) -> int:
    return sum(int(jnp.size(x)) if hasattr(x, "size") else 0
               for x in jax.tree.leaves(params))


def count_params_abstract(model: Model) -> int:
    shapes = model.abstract_params()
    total = 0
    for leaf in jax.tree.leaves(shapes):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n
    return total


def active_params(cfg: ModelConfig, n_total: int) -> int:
    """Active params per token (MoE discounts inactive experts)."""
    if cfg.moe is None:
        return n_total
    m = cfg.moe
    n_moe_layers = cfg.n_layers - m.n_dense_layers
    per_expert = 3 * cfg.d_model * m.d_ff_expert
    inactive = n_moe_layers * (m.n_experts - m.top_k) * per_expert
    return n_total - inactive


def model_flops(cfg: ModelConfig, shape: ShapeConfig, n_params: int) -> float:
    """MODEL_FLOPS: 6*N*D (train) / 2*N*D (fwd) with N = active params."""
    n_act = active_params(cfg, n_params)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_act * tokens
