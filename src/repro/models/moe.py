"""Mixture-of-Experts layer: top-k router + capacity-based sorted dispatch.

Two dispatch modes (§Perf levers A/B — see EXPERIMENTS.md):

* ``dense_capacity`` — flat global sort/scatter into an (E, C, D) buffer.
  Simple, but on a sharded mesh XLA must gather all tokens to build the
  expert buffer (and when E doesn't divide the model axis the buffer is
  replicated and all-reduced: 13 TB/device/step on qwen2-moe train).
* ``hierarchical`` — per-data-shard dispatch with an explicit leading shard
  axis: each shard sorts and scatters only its local tokens into an
  (S, E, C_local, D) buffer sharded (S->data, E->model). The only cross-
  device movement is the buffer's data->expert resharding (an all-to-all of
  the actual token payloads), which is the textbook EP pattern.

Expert padding (``n_experts_padded``) rounds E up so EP divides the mesh;
padded experts are masked to -inf in the router.

FLOPs are `capacity_factor` x the ideal active FLOPs; slots over capacity
drop (standard capacity semantics).
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.distributed.meshctx import (batch_axes, current_mesh, shard_act)


def init_moe(key, d_model: int, mcfg: MoEConfig, dtype):
    ks = jax.random.split(key, 5)
    e, f = mcfg.e_padded, mcfg.d_ff_expert
    std_in = d_model ** -0.5
    std_out = f ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d_model, e)) * std_in
                   ).astype(jnp.float32),
        "wi": (jax.random.normal(ks[1], (e, d_model, f)) * std_in
               ).astype(dtype),
        "wg": (jax.random.normal(ks[2], (e, d_model, f)) * std_in
               ).astype(dtype),
        "wo": (jax.random.normal(ks[3], (e, f, d_model)) * std_out
               ).astype(dtype),
    }
    if mcfg.n_shared:
        from repro.models.layers import init_mlp
        p["shared"] = init_mlp(ks[4], d_model, mcfg.n_shared * f, dtype)
    return p


def router_topk(logits, mcfg: MoEConfig):
    """logits: (..., E_pad) fp32 -> (probs, idx, aux). Padded experts are
    masked out before softmax."""
    e, ep = mcfg.n_experts, mcfg.e_padded
    if ep != e:
        mask = jnp.arange(ep) < e
        logits = jnp.where(mask, logits, -1e30)
    probs_full = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(probs_full, mcfg.top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    density = jnp.mean(jax.nn.one_hot(idx[..., 0], ep),
                       axis=tuple(range(idx.ndim - 1)))
    mean_probs = probs_full.reshape(-1, ep).mean(0)
    aux = e * jnp.sum(density * mean_probs)
    return probs, idx, aux


def _capacity(t: int, mcfg: MoEConfig) -> int:
    c = int(-(-t * mcfg.top_k * mcfg.capacity_factor // mcfg.e_padded))
    return max(8, -(-c // 8) * 8)


def _dispatch_flat(xf, probs, idx, p, mcfg: MoEConfig, c: int):
    """One dispatch group: xf (T, D); returns combined output (T, D)."""
    t, d = xf.shape
    e, k = mcfg.e_padded, mcfg.top_k
    flat_e = idx.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * k) - seg_start[sorted_e]
    keep = pos_in_e < c
    tok_of_slot = order // k
    e_idx = jnp.where(keep, sorted_e, e)
    p_idx = jnp.where(keep, pos_in_e, 0)
    buf = jnp.zeros((e + 1, c, d), xf.dtype)
    buf = buf.at[e_idx, p_idx].set(xf[tok_of_slot], mode="drop")
    buf = buf[:e]

    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"])

    slot_out = out_buf[e_idx.clip(0, e - 1), p_idx]
    slot_probs = probs.reshape(-1)[order]
    slot_out = slot_out * (slot_probs * keep).astype(slot_out.dtype)[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[tok_of_slot].add(
        slot_out.astype(jnp.float32))
    return out


def moe_ffn(p, x, mcfg: MoEConfig, *, capacity: int | None = None):
    """x: (B, L, D) -> (B, L, D), aux_loss."""
    b, l, d = x.shape
    t = b * l
    mesh = current_mesh()
    hier = (mcfg.dispatch == "hierarchical" and mesh is not None)

    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), p["router"])
    probs, idx, aux = router_topk(logits, mcfg)

    if not hier:
        c = capacity or _capacity(t, mcfg)
        out = _dispatch_flat(xf, probs, idx, p, mcfg, c)
        out = out.reshape(b, l, d).astype(x.dtype)
    else:
        baxes = batch_axes()
        s = int(np.prod([mesh.shape[a] for a in baxes]))
        if t % s or b % s:
            s = 1
        t_loc = t // s
        c = capacity or _capacity(t_loc, mcfg)
        e, k = mcfg.e_padded, mcfg.top_k
        x3 = xf.reshape(s, t_loc, d)
        x3 = shard_act(x3, "batch", None, None)
        probs3 = probs.reshape(s, t_loc, k)
        idx3 = idx.reshape(s, t_loc, k)

        # per-shard local sort/scatter (vmapped over the shard axis)
        def local_dispatch(xs, ps, ix):
            flat_e = ix.reshape(-1)
            order = jnp.argsort(flat_e, stable=True)
            sorted_e = flat_e[order]
            seg = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
            pos = jnp.arange(t_loc * k) - seg[sorted_e]
            keep = pos < c
            tok = order // k
            e_i = jnp.where(keep, sorted_e, e)
            p_i = jnp.where(keep, pos, 0)
            buf = jnp.zeros((e + 1, c, d), xs.dtype)
            buf = buf.at[e_i, p_i].set(xs[tok], mode="drop")[:e]
            return buf, (e_i, p_i, tok, keep, order)

        buf, meta = jax.vmap(local_dispatch)(x3, probs3, idx3)
        # (S, E, C, D): S->data shards, E->experts; the constraint below
        # makes XLA materialize the data->expert all-to-all exactly once
        buf = shard_act(buf, "batch", "model", None, None)

        h = jnp.einsum("secd,edf->secf", buf, p["wi"])
        g = jnp.einsum("secd,edf->secf", buf, p["wg"])
        h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
        out_buf = jnp.einsum("secf,efd->secd", h, p["wo"])
        out_buf = shard_act(out_buf, "batch", "model", None, None)

        def local_combine(ob, xs, ps, m):
            e_i, p_i, tok, keep, order = m
            slot_out = ob[e_i.clip(0, e - 1), p_i]
            slot_probs = ps.reshape(-1)[order]
            slot_out = slot_out * (slot_probs * keep
                                   ).astype(slot_out.dtype)[:, None]
            return jnp.zeros((t_loc, d), jnp.float32).at[tok].add(
                slot_out.astype(jnp.float32))

        out = jax.vmap(local_combine)(out_buf, x3, probs3, meta)
        out = out.reshape(b, l, d).astype(x.dtype)
        out = shard_act(out, "batch", None, None)

    if mcfg.n_shared:
        from repro.models.layers import mlp
        out = out + mlp(p["shared"], x)
    return out, aux
