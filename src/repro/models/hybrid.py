"""Zamba2-style hybrid: Mamba2 backbone + alternating *shared* attention
blocks applied after every `shared_attn_period` mamba layers.

Layer layout for n_layers=81, period=6:
  13 groups of (6 mamba layers + shared attn block[i % 2]) + 3 tail mamba
Shared attention blocks have their own KV cache per *invocation* (13 of
them) even though weights are shared (2 unique blocks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models.transformer import (embed_tokens, logits_fn, padded_vocab,
                                      softmax_xent)


def split_counts(cfg: ModelConfig):
    period = cfg.shared_attn_period
    n_groups = cfg.n_layers // period
    n_tail = cfg.n_layers - n_groups * period
    return period, n_groups, n_tail


def init_hybrid(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    period, n_groups, n_tail = split_counts(cfg)
    ks = jax.random.split(key, 8)
    vp = padded_vocab(cfg.vocab)

    def init_mamba_layer(k):
        return {"ln": jnp.zeros((cfg.d_model,), dtype),
                "mamba": M.init_mamba(k, cfg.d_model, cfg.ssm, dtype)}

    def init_shared_block(k):
        from repro.models.transformer import init_dense_layer
        return init_dense_layer(k, cfg, dtype)

    group_keys = jax.random.split(ks[0], n_groups * period)
    groups = jax.vmap(init_mamba_layer)(group_keys)
    groups = jax.tree.map(
        lambda x: x.reshape((n_groups, period) + x.shape[1:]), groups)
    params = {
        "embed": (jax.random.normal(ks[1], (vp, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "mamba_groups": groups,
        "shared": jax.vmap(init_shared_block)(
            jax.random.split(ks[2], cfg.n_shared_blocks)),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": (jax.random.normal(ks[3], (cfg.d_model, vp))
                    * cfg.d_model ** -0.5).astype(dtype),
    }
    if n_tail:
        params["mamba_tail"] = jax.vmap(init_mamba_layer)(
            jax.random.split(ks[4], n_tail))
    return params


def _mamba_layer(p, cfg, h, *, return_state=False):
    x = L.rms_norm(h, p["ln"], cfg.rms_eps)
    if return_state:
        y, st = M.mamba_forward(p["mamba"], x, cfg.ssm, return_state=True)
        return h + y, st
    return h + M.mamba_forward(p["mamba"], x, cfg.ssm)


def _shared_block_fwd(p, cfg, h, positions):
    from repro.models.transformer import attn_block, ffn_block
    h = attn_block(p, cfg, h, window=0, positions=positions)
    h, _ = ffn_block(p, cfg, h)
    return h


def hybrid_hidden(params, cfg: ModelConfig, h, positions):
    period, n_groups, n_tail = split_counts(cfg)
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    def group_body(carry, xs):
        h = carry
        gi, p_group = xs

        def inner(h, p_l):
            f = remat(lambda p, hh: _mamba_layer(p, cfg, hh))
            return f(p_l, h), None

        h, _ = lax.scan(inner, h, p_group)
        shared_p = jax.tree.map(
            lambda x: x[gi % cfg.n_shared_blocks], params["shared"])
        f = remat(lambda p, hh: _shared_block_fwd(p, cfg, hh, positions))
        h = f(shared_p, h)
        return h, None

    h, _ = lax.scan(group_body, h,
                    (jnp.arange(n_groups), params["mamba_groups"]))
    if n_tail:
        def inner(h, p_l):
            f = remat(lambda p, hh: _mamba_layer(p, cfg, hh))
            return f(p_l, h), None
        h, _ = lax.scan(inner, h, params["mamba_tail"])
    return h


def hybrid_forward(params, cfg: ModelConfig, tokens):
    h = embed_tokens(params, cfg, tokens)
    positions = jnp.arange(h.shape[1])[None, :]
    h = hybrid_hidden(params, cfg, h, positions)
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    return h


def hybrid_loss(params, cfg: ModelConfig, batch):
    h = hybrid_forward(params, cfg, batch["tokens"])
    logits = logits_fn(params, cfg, h)
    mask = batch.get("mask", jnp.ones_like(batch["targets"], jnp.float32))
    loss = softmax_xent(logits, batch["targets"], mask)
    return loss, {"xent": loss}


# --------------------------------------------------------------- serving

def hybrid_init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    period, n_groups, n_tail = split_counts(cfg)
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    st = M.mamba_init_state(batch, cfg.d_model, cfg.ssm, dtype)
    stack = lambda x, n: jnp.broadcast_to(x[None], (n,) + x.shape)
    cache = {
        "group_states": jax.tree.map(
            lambda x: stack(x, n_groups * period).reshape(
                (n_groups, period) + x.shape), st),
        "attn_k": jnp.zeros((n_groups, batch, seq_len, cfg.n_kv_heads, hd),
                            dtype),
        "attn_v": jnp.zeros((n_groups, batch, seq_len, cfg.n_kv_heads, hd),
                            dtype),
    }
    if n_tail:
        cache["tail_states"] = jax.tree.map(lambda x: stack(x, n_tail), st)
    return cache


def _mamba_layer_decode(p, cfg, h, state):
    x = L.rms_norm(h, p["ln"], cfg.rms_eps)
    y, state = M.mamba_decode_step(p["mamba"], x, state, cfg.ssm)
    return h + y, state


def hybrid_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    period, n_groups, n_tail = split_counts(cfg)
    h = embed_tokens(params, cfg, tokens)
    from repro.models.transformer import _gqa_layer_decode, \
        scan_layers_carry

    def group_body(h, xs, state):
        gi, p_group = xs

        def inner(h, p_l, st):
            return _mamba_layer_decode(p_l, cfg, h, st)

        h, mstates = scan_layers_carry(inner, h, p_group,
                                       state["mamba"], period)
        shared_p = jax.tree.map(
            lambda x: x[gi % cfg.n_shared_blocks], params["shared"])
        h, kc, vc = _gqa_layer_decode(shared_p, cfg, h, state["k"],
                                      state["v"], pos, 0)
        return h, {"mamba": mstates, "k": kc, "v": vc}

    state0 = {"mamba": cache["group_states"], "k": cache["attn_k"],
              "v": cache["attn_v"]}
    h, state = scan_layers_carry(
        lambda h, xs, st: group_body(h, xs, st), h,
        (jnp.arange(n_groups), params["mamba_groups"]), state0, n_groups)
    new_cache = {"group_states": state["mamba"], "attn_k": state["k"],
                 "attn_v": state["v"]}

    if n_tail:
        def inner(h, p_l, st):
            return _mamba_layer_decode(p_l, cfg, h, st)
        h, tstates = scan_layers_carry(inner, h, params["mamba_tail"],
                                       cache["tail_states"], n_tail)
        new_cache["tail_states"] = tstates

    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, cfg, h), new_cache


def hybrid_prefill(params, cfg: ModelConfig, tokens, seq_len: int):
    """Prefill: full forward that also emits decode-ready caches — the SSD
    chunked scan's final carry is the SSM state; shared blocks emit K/V."""
    period, n_groups, n_tail = split_counts(cfg)
    h = embed_tokens(params, cfg, tokens)
    b, l, _ = h.shape
    positions = jnp.arange(l)[None, :]
    pad = seq_len - l

    def group_body(carry, xs):
        h = carry
        gi, p_group = xs

        def inner(h, p_l):
            return _mamba_layer(p_l, cfg, h, return_state=True)

        h, states = lax.scan(inner, h, p_group)
        shared_p = jax.tree.map(
            lambda x: x[gi % cfg.n_shared_blocks], params["shared"])
        x = L.rms_norm(h, shared_p["ln1"], cfg.rms_eps)
        k = jnp.einsum("bld,dhk->blhk", x, shared_p["attn"]["wk"])
        v = jnp.einsum("bld,dhk->blhk", x, shared_p["attn"]["wv"])
        k = L.apply_rope(k, positions, cfg.rope_theta)
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        h = _shared_block_fwd(shared_p, cfg, h, positions)
        return h, (states, k, v)

    h, (gstates, kc, vc) = lax.scan(
        group_body, h, (jnp.arange(n_groups), params["mamba_groups"]))
    cache = {"group_states": gstates, "attn_k": kc, "attn_v": vc}
    if n_tail:
        def inner(h, p_l):
            return _mamba_layer(p_l, cfg, h, return_state=True)
        h, tstates = lax.scan(inner, h, params["mamba_tail"])
        cache["tail_states"] = tstates
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, cfg, h[:, -1:]), cache
