"""Core layers: norms, RoPE, GQA/chunked/local attention, SwiGLU MLP.

All attention math accumulates in fp32; parameters and activations are bf16
by default. Attention avoids materializing repeated KV heads by computing in
grouped layout (B, Lq, Hkv, G, Dh).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.distributed.meshctx import shard_act

NEG_INF = -1e30


# ---------------------------------------------------------------- norms/rope

def rms_norm(x, w, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (..., L, H, D); positions: (..., L) int32."""
    if theta <= 0:
        return x
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, d/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., L, 1, d/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, dim: int):
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    i = jnp.arange(dim // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * i / dim))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------- attention

def _grouped(q, n_kv: int):
    """(B, L, H, D) -> (B, L, Hkv, G, D)."""
    b, l, h, d = q.shape
    return q.reshape(b, l, n_kv, h // n_kv, d)


def attention_scores_mask(qpos, kpos, window: int, causal: bool):
    """(Lq, Lk) additive mask."""
    ok = jnp.ones((qpos.shape[0], kpos.shape[0]), dtype=bool)
    if causal:
        ok &= kpos[None, :] <= qpos[:, None]
    if window:
        ok &= qpos[:, None] - kpos[None, :] < window
    return jnp.where(ok, 0.0, NEG_INF)


def plain_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    bidirectional=False):
    """Reference attention. q: (B,Lq,H,D), k/v: (B,Lk,Hkv,D)."""
    b, lq, h, d = q.shape
    n_kv = k.shape[2]
    qg = _grouped(q, n_kv).astype(jnp.float32)
    scale = d ** -0.5
    scores = jnp.einsum("blhgd,bmhd->bhglm", qg * scale,
                        k.astype(jnp.float32))
    qpos = q_offset + jnp.arange(lq)
    kpos = jnp.arange(k.shape[1])
    if not bidirectional:
        scores += attention_scores_mask(qpos, kpos, window, True)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhglm,bmhd->blhgd", p, v.astype(jnp.float32))
    return out.reshape(b, lq, h, d).astype(q.dtype)


def chunked_attention(q, k, v, *, causal=True, window=0, chunk=1024,
                      triangle_skip=False):
    """Flash-style online-softmax attention, O(chunk*Lk) live memory.

    Scans query chunks; for each, scans KV chunks with a running
    (max, denom, acc).

    - `window` (local attention): only the last ceil(window/chunk)+1 KV
      chunks are read per query chunk (structural skip) -> O(L*window) FLOPs.
      Differentiable.
    - global causal, default: masked scan over *all* KV chunks. This is
      differentiable but spends 2x the ideal causal FLOPs; the Pallas flash
      kernel and the `triangle_skip` path below avoid that.
    - `triangle_skip=True`: bound the KV scan at the query chunk's diagonal
      via fori_loop (dynamic trip count). NOT differentiable -> prefill only.
    """
    b, lq, h, d = q.shape
    n_kv = k.shape[2]
    lk = k.shape[1]
    chunk = min(chunk, lq)
    assert lq % chunk == 0 and lk % chunk == 0, (lq, lk, chunk)
    nq, nk = lq // chunk, lk // chunk
    scale = d ** -0.5
    g = h // n_kv
    qg = (_grouped(q, n_kv).astype(jnp.float32) * scale
          ).reshape(b, nq, chunk, n_kv, g, d)

    def q_chunk_body(qi, qc):
        """qc: (B,chunk,Hkv,G,D) fp32. Returns (B,chunk,Hkv,G,D)."""
        qpos = qi * chunk + jnp.arange(chunk)

        if window:
            nwin = min(nk, window // chunk + 1)
            first = jnp.maximum(qi - (nwin - 1), 0)
            ks = lax.dynamic_slice_in_dim(k, first * chunk, nwin * chunk, 1)
            vs = lax.dynamic_slice_in_dim(v, first * chunk, nwin * chunk, 1)
            kpos = first * chunk + jnp.arange(nwin * chunk)
            mask = attention_scores_mask(qpos, kpos, window, causal)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc,
                           ks.astype(jnp.float32)) + mask
            m = jnp.max(s, axis=-1, keepdims=True)
            p = jnp.exp(s - m)
            denom = jnp.sum(p, axis=-1, keepdims=True)
            o = jnp.einsum("bhgqk,bkhd->bqhgd", p / jnp.maximum(denom, 1e-30),
                           vs.astype(jnp.float32))
            return o.astype(q.dtype)

        def kv_step(carry, ki):
            m, den, acc = carry
            ks = lax.dynamic_slice_in_dim(k, ki * chunk, chunk, 1)
            vs = lax.dynamic_slice_in_dim(v, ki * chunk, chunk, 1)
            kpos = ki * chunk + jnp.arange(chunk)
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc, ks.astype(jnp.float32))
            if causal:
                s += attention_scores_mask(qpos, kpos, 0, True)
            m2 = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
            corr = jnp.exp(m - m2)
            p = jnp.exp(s - m2)
            den = den * corr + jnp.sum(p, axis=-1, keepdims=True)
            pv = jnp.einsum("bhgqk,bkhd->bqhgd", p, vs.astype(jnp.float32))
            acc = acc * jnp.moveaxis(corr, (1, 2, 3), (2, 3, 1)) + pv
            return (m2, den, acc)

        m0 = jnp.full((b, n_kv, g, chunk, 1), NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, n_kv, g, chunk, 1), jnp.float32)
        a0 = jnp.zeros((b, chunk, n_kv, g, d), jnp.float32)
        if causal and triangle_skip:
            m, den, acc = lax.fori_loop(
                0, qi + 1, lambda ki, c: kv_step(c, ki), (m0, d0, a0))
        else:
            (m, den, acc), _ = lax.scan(
                lambda c, ki: (kv_step(c, ki), None), (m0, d0, a0),
                jnp.arange(nk))
        den = jnp.moveaxis(den, (1, 2, 3), (2, 3, 1))
        return (acc / jnp.maximum(den, 1e-30)).astype(q.dtype)

    out = lax.map(lambda args: q_chunk_body(*args),
                  (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)))
    out = jnp.moveaxis(out, 0, 1).reshape(b, lq, h, d)
    return out


def decode_attention(q, k_cache, v_cache, pos, *, window=0):
    """Single-token attention against a cache.

    q: (B,1,H,D); caches: (B,S,Hkv,D); pos: scalar int32 (index of the new
    token). Entries at kpos > pos are masked out.
    """
    b, _, h, d = q.shape
    n_kv = k_cache.shape[2]
    qg = _grouped(q, n_kv).astype(jnp.float32) * (d ** -0.5)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(k_cache.shape[1])
    ok = kpos <= pos
    if window:
        ok &= kpos > pos - window
    s = jnp.where(ok[None, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, d).astype(q.dtype)


# ---------------------------------------------------------------- blocks

@dataclasses.dataclass
class AttnDims:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False


def init_attn(key, dims: AttnDims, dtype):
    d, h, hkv, hd = dims.d_model, dims.n_heads, dims.n_kv_heads, dims.head_dim
    ks = jax.random.split(key, 4)
    std = d ** -0.5
    p = {
        "wq": (jax.random.normal(ks[0], (d, h, hd)) * std).astype(dtype),
        "wk": (jax.random.normal(ks[1], (d, hkv, hd)) * std).astype(dtype),
        "wv": (jax.random.normal(ks[2], (d, hkv, hd)) * std).astype(dtype),
        "wo": (jax.random.normal(ks[3], (h, hd, d)) * std).astype(dtype),
    }
    if dims.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    return p


def attn_qkv(p, x, positions, theta):
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)
    q = shard_act(q, "batch", None, "model", None)
    k = shard_act(k, "batch", None, None, None)
    return q, k, v


def attn_out(p, o):
    return jnp.einsum("blhk,hkd->bld", o, p["wo"])


def init_mlp(key, d_model: int, d_ff: int, dtype):
    ks = jax.random.split(key, 3)
    return {
        "wi": (jax.random.normal(ks[0], (d_model, d_ff))
               * d_model ** -0.5).astype(dtype),
        "wg": (jax.random.normal(ks[1], (d_model, d_ff))
               * d_model ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[2], (d_ff, d_model))
               * d_ff ** -0.5).astype(dtype),
    }


def mlp(p, x):
    h = jnp.einsum("bld,df->blf", x, p["wi"])
    g = jnp.einsum("bld,df->blf", x, p["wg"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype) * h
    h = shard_act(h, "batch", None, "model")
    return jnp.einsum("blf,fd->bld", h, p["wo"])
