"""Mamba2 (SSD, state-space duality) block: chunked train scan + O(1) decode.

Follows the minimal SSD formulation (Dao & Gu, arXiv:2405.21060):
  h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t ;  y_t = C_t . h_t + D x_t
computed chunk-parallel: intra-chunk quadratic attention-like term +
inter-chunk state recurrence (lax.scan over chunks).

Projections are kept *separate* (wz/wx/wB/wC/wdt) rather than packed so the
x/z channels — and therefore the SSD heads — shard cleanly over the `model`
mesh axis (Megatron column->row pattern with one all-reduce at out_proj).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import SSMConfig
from repro.distributed.meshctx import shard_act
from repro.models.layers import rms_norm


def mamba_dims(d_model: int, s: SSMConfig):
    d_inner = s.expand * d_model
    n_heads = d_inner // s.head_dim
    return d_inner, n_heads


def init_mamba(key, d_model: int, s: SSMConfig, dtype):
    d_inner, n_heads = mamba_dims(d_model, s)
    gn = s.n_groups * s.d_state
    ks = jax.random.split(key, 8)
    std = d_model ** -0.5

    def mat(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    return {
        "wz": mat(ks[0], (d_model, d_inner), std),
        "wx": mat(ks[1], (d_model, d_inner), std),
        "wB": mat(ks[2], (d_model, gn), std),
        "wC": mat(ks[3], (d_model, gn), std),
        "wdt": mat(ks[4], (d_model, n_heads), std),
        "conv_x": mat(ks[5], (s.d_conv, d_inner), 0.2),
        "conv_B": mat(ks[6], (s.d_conv, gn), 0.2),
        "conv_C": mat(ks[7], (s.d_conv, gn), 0.2),
        "conv_bx": jnp.zeros((d_inner,), dtype),
        "conv_bB": jnp.zeros((gn,), dtype),
        "conv_bC": jnp.zeros((gn,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(
            jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "D": jnp.ones((n_heads,), jnp.float32),
        "norm_w": jnp.zeros((d_inner,), dtype),
        "out_proj": mat(ks[0], (d_inner, d_model), d_inner ** -0.5),
    }


def _causal_conv(u, w, bias):
    """Depthwise causal conv. u: (B, L, C); w: (K, C)."""
    k = w.shape[0]
    out = jnp.zeros(u.shape, jnp.float32)
    for i in range(k):
        shift = k - 1 - i
        pad = jnp.pad(u, ((0, 0), (shift, 0), (0, 0)))[:, :u.shape[1]]
        out = out + pad.astype(jnp.float32) * w[i].astype(jnp.float32)
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(u.dtype)


def ssd_chunked(x, dt, A, B, C, D, chunk: int, *, return_state=False):
    """SSD scan. x: (Bt,L,H,P); dt:(Bt,L,H); A:(H,); B,C:(Bt,L,G,N); D:(H,).

    Returns y: (Bt,L,H,P) (and the final SSM state (Bt,H,N,P) when
    `return_state`). G divides H (B/C broadcast over H//G heads).
    """
    bt, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    rep = h // g

    xf = x.astype(jnp.float32).reshape(bt, nc, chunk, h, p)
    dtf = dt.astype(jnp.float32).reshape(bt, nc, chunk, h)
    Bf = B.astype(jnp.float32).reshape(bt, nc, chunk, g, n)
    Cf = C.astype(jnp.float32).reshape(bt, nc, chunk, g, n)
    Bh = jnp.repeat(Bf, rep, axis=3)                    # (bt,nc,Q,h,n)
    Ch = jnp.repeat(Cf, rep, axis=3)

    dA = dtf * A                                        # (bt,nc,Q,h) negative
    cum = jnp.cumsum(dA, axis=2)                        # within-chunk cumsum
    seg_end = cum[:, :, -1:, :]                         # (bt,nc,1,h)

    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) dt_j (C_i.B_j) x_j
    # NOTE: mask decay BEFORE exp — exp of the (positive) masked entries
    # overflows and poisons the backward pass through jnp.where otherwise.
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (bt,nc,Qi,Qj,h)
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, -jnp.inf)
    lmat = jnp.exp(decay)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", Ch, Bh)       # (bt,nc,Qi,Qj,h)
    w = cb * lmat * dtf[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w, xf)

    # chunk summary states: S_c = sum_j exp(seg_end - cum_j) dt_j B_j x_j^T
    wstate = jnp.exp(seg_end - cum) * dtf               # (bt,nc,Q,h)
    s_chunk = jnp.einsum("bcjh,bcjhn,bcjhp->bchnp", wstate, Bh, xf)

    # inter-chunk recurrence over c: S <- S * exp(seg_end_c) + s_chunk_c
    seg = jnp.exp(seg_end[:, :, 0, :])                  # (bt,nc,h)

    def step(s, inp):
        seg_c, sc = inp
        y_state = s                                     # state BEFORE chunk c
        s = s * seg_c[:, :, None, None] + sc
        return s, y_state

    s0 = jnp.zeros((bt, h, n, p), jnp.float32)
    s_final, s_before = lax.scan(step, s0,
                                 (jnp.moveaxis(seg, 1, 0),
                                  jnp.moveaxis(s_chunk, 1, 0)))
    s_before = jnp.moveaxis(s_before, 0, 1)             # (bt,nc,h,n,p)

    # inter-chunk output: y_i += exp(cum_i) C_i . S_{before}
    y_inter = jnp.einsum("bcih,bcihn,bchnp->bcihp",
                         jnp.exp(cum), Ch, s_before)

    y = (y_intra + y_inter).reshape(bt, l, h, p)
    y = y + D[None, None, :, None] * x.astype(jnp.float32)
    y = y.astype(x.dtype)
    if return_state:
        return y, s_final
    return y


def mamba_forward(params, u, s: SSMConfig, *, return_state=False):
    """Train/prefill forward. u: (B, L, D) -> (B, L, D).

    With `return_state`, also returns the decode-ready state dict
    ({'ssm','conv_x','conv_B','conv_C'}) after the last position.
    """
    d_model = u.shape[-1]
    d_inner, n_heads = mamba_dims(d_model, s)
    z = jnp.einsum("bld,de->ble", u, params["wz"])
    x_raw = jnp.einsum("bld,de->ble", u, params["wx"])
    B_raw = jnp.einsum("bld,de->ble", u, params["wB"])
    C_raw = jnp.einsum("bld,de->ble", u, params["wC"])
    dt = jnp.einsum("bld,de->ble", u, params["wdt"])
    z = shard_act(z, "batch", None, "model")
    x_raw = shard_act(x_raw, "batch", None, "model")

    x = _causal_conv(x_raw, params["conv_x"], params["conv_bx"])
    B = _causal_conv(B_raw, params["conv_B"], params["conv_bB"])
    C = _causal_conv(C_raw, params["conv_C"], params["conv_bC"])

    bt, l, _ = x.shape
    xh = x.reshape(bt, l, n_heads, s.head_dim)
    Bh = B.reshape(bt, l, s.n_groups, s.d_state)
    Ch = C.reshape(bt, l, s.n_groups, s.d_state)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    out = ssd_chunked(xh, dtv, A, Bh, Ch, params["D"], s.chunk,
                      return_state=return_state)
    y, s_final = out if return_state else (out, None)
    y = y.reshape(bt, l, d_inner)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype),
                 params["norm_w"])
    y = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    if return_state:
        state = {"ssm": s_final,
                 "conv_x": x_raw[:, -(s.d_conv - 1):],
                 "conv_B": B_raw[:, -(s.d_conv - 1):],
                 "conv_C": C_raw[:, -(s.d_conv - 1):]}
        return y, state
    return y


def mamba_init_state(batch: int, d_model: int, s: SSMConfig, dtype):
    d_inner, n_heads = mamba_dims(d_model, s)
    gn = s.n_groups * s.d_state
    return {
        "ssm": jnp.zeros((batch, n_heads, s.d_state, s.head_dim),
                         jnp.float32),
        "conv_x": jnp.zeros((batch, s.d_conv - 1, d_inner), dtype),
        "conv_B": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
        "conv_C": jnp.zeros((batch, s.d_conv - 1, gn), dtype),
    }


def _conv_step(window, w, bias):
    """window: (B, K, C) raw inputs incl. current; returns (B, C)."""
    out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))
    return jax.nn.silu(out + bias.astype(jnp.float32))


def mamba_decode_step(params, u, state, s: SSMConfig):
    """u: (B, 1, D); returns (y (B,1,D), new state)."""
    d_model = u.shape[-1]
    d_inner, n_heads = mamba_dims(d_model, s)
    z = jnp.einsum("bld,de->ble", u, params["wz"])[:, 0]
    x_new = jnp.einsum("bld,de->ble", u, params["wx"])[:, 0]
    B_new = jnp.einsum("bld,de->ble", u, params["wB"])[:, 0]
    C_new = jnp.einsum("bld,de->ble", u, params["wC"])[:, 0]
    dt = jnp.einsum("bld,de->ble", u, params["wdt"])[:, 0]

    wx = jnp.concatenate([state["conv_x"], x_new[:, None]], 1)
    wB = jnp.concatenate([state["conv_B"], B_new[:, None]], 1)
    wC = jnp.concatenate([state["conv_C"], C_new[:, None]], 1)
    x = _conv_step(wx, params["conv_x"], params["conv_bx"])
    B = _conv_step(wB, params["conv_B"], params["conv_bB"])
    C = _conv_step(wC, params["conv_C"], params["conv_bC"])

    b = u.shape[0]
    xh = x.reshape(b, n_heads, s.head_dim)
    rep = n_heads // s.n_groups
    Bh = jnp.repeat(B.reshape(b, s.n_groups, s.d_state), rep, 1)
    Ch = jnp.repeat(C.reshape(b, s.n_groups, s.d_state), rep, 1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    da = jnp.exp(dtv * A)                               # (B,H)
    h = state["ssm"] * da[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhp->bhnp", dtv, Bh.astype(jnp.float32), xh)
    y = jnp.einsum("bhn,bhnp->bhp", Ch.astype(jnp.float32), h)
    y = y + params["D"][None, :, None] * xh
    y = y.reshape(b, 1, d_inner).astype(u.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)
                                 ).astype(y.dtype)[:, None],
                 params["norm_w"])
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"])
    new_state = {"ssm": h,
                 "conv_x": wx[:, 1:], "conv_B": wB[:, 1:],
                 "conv_C": wC[:, 1:]}
    return out, new_state
