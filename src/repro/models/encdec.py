"""Whisper-style encoder-decoder backbone.

The conv-over-mel frontend is a STUB per the assignment: the encoder input
is precomputed frame embeddings (B, n_frames, d_model). Positions are
sinusoidal. Decoder layers: causal self-attn + cross-attn + MLP.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models.transformer import (embed_tokens, logits_fn, padded_vocab,
                                      softmax_xent)


def _attn_dims(cfg):
    return L.AttnDims(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                      cfg.resolved_head_dim, cfg.qkv_bias)


def init_encdec(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg.vocab)
    ks = jax.random.split(key, 6)

    def init_enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "attn": L.init_attn(k1, _attn_dims(cfg), dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": L.init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)}

    def init_dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.zeros((cfg.d_model,), dtype),
                "self_attn": L.init_attn(k1, _attn_dims(cfg), dtype),
                "ln_x": jnp.zeros((cfg.d_model,), dtype),
                "cross_attn": L.init_attn(k2, _attn_dims(cfg), dtype),
                "ln2": jnp.zeros((cfg.d_model,), dtype),
                "mlp": L.init_mlp(k3, cfg.d_model, cfg.d_ff, dtype)}

    return {
        "embed": (jax.random.normal(ks[0], (vp, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "enc_layers": jax.vmap(init_enc_layer)(
            jax.random.split(ks[1], cfg.n_enc_layers)),
        "dec_layers": jax.vmap(init_dec_layer)(
            jax.random.split(ks[2], cfg.n_layers)),
        "enc_norm": jnp.zeros((cfg.d_model,), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
        "lm_head": (jax.random.normal(ks[3], (cfg.d_model, vp))
                    * cfg.d_model ** -0.5).astype(dtype),
    }


def _qkv(p, x):
    q = jnp.einsum("bld,dhk->blhk", x, p["wq"])
    k = jnp.einsum("bld,dhk->blhk", x, p["wk"])
    v = jnp.einsum("bld,dhk->blhk", x, p["wv"])
    return q, k, v


def encode(params, cfg: ModelConfig, frames):
    """frames: (B, T, D) precomputed (conv frontend stub)."""
    h = frames.astype(jnp.dtype(cfg.dtype))
    h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model
                                   ).astype(h.dtype)[None]
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    def body(h, p):
        def f(p, h):
            x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
            q, k, v = _qkv(p["attn"], x)
            o = L.plain_attention(q, k, v, bidirectional=True)
            h = h + L.attn_out(p["attn"], o)
            x = L.rms_norm(h, p["ln2"], cfg.rms_eps)
            return h + L.mlp(p["mlp"], x)
        return remat(f)(p, h), None

    h, _ = lax.scan(body, h, params["enc_layers"])
    return L.rms_norm(h, params["enc_norm"], cfg.rms_eps)


def _dec_layer(p, cfg, h, enc_out, positions):
    x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
    q, k, v = _qkv(p["self_attn"], x)
    o = L.chunked_attention(q, k, v, causal=True,
                            chunk=min(cfg.attn_chunk, q.shape[1]))
    h = h + L.attn_out(p["self_attn"], o)
    x = L.rms_norm(h, p["ln_x"], cfg.rms_eps)
    q = jnp.einsum("bld,dhk->blhk", x, p["cross_attn"]["wq"])
    ke = jnp.einsum("bld,dhk->blhk", enc_out, p["cross_attn"]["wk"])
    ve = jnp.einsum("bld,dhk->blhk", enc_out, p["cross_attn"]["wv"])
    o = L.plain_attention(q, ke, ve, bidirectional=True)
    h = h + L.attn_out(p["cross_attn"], o)
    x = L.rms_norm(h, p["ln2"], cfg.rms_eps)
    return h + L.mlp(p["mlp"], x)


def encdec_forward(params, cfg: ModelConfig, frames, tokens):
    enc_out = encode(params, cfg, frames)
    h = embed_tokens(params, cfg, tokens)
    h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model
                                   ).astype(h.dtype)[None]
    positions = jnp.arange(h.shape[1])[None, :]
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    def body(h, p):
        f = remat(lambda pp, hh: _dec_layer(pp, cfg, hh, enc_out, positions))
        return f(p, h), None

    h, _ = lax.scan(body, h, params["dec_layers"])
    return L.rms_norm(h, params["final_norm"], cfg.rms_eps)


def encdec_loss(params, cfg: ModelConfig, batch):
    h = encdec_forward(params, cfg, batch["frames"], batch["tokens"])
    logits = logits_fn(params, cfg, h)
    mask = batch.get("mask", jnp.ones_like(batch["targets"], jnp.float32))
    loss = softmax_xent(logits, batch["targets"], mask)
    return loss, {"xent": loss}


def encdec_init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    dtype = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    nl = cfg.n_layers
    t = cfg.n_audio_frames
    return {
        "self_k": jnp.zeros((nl, batch, seq_len, cfg.n_kv_heads, hd), dtype),
        "self_v": jnp.zeros((nl, batch, seq_len, cfg.n_kv_heads, hd), dtype),
        "cross_k": jnp.zeros((nl, batch, t, cfg.n_kv_heads, hd), dtype),
        "cross_v": jnp.zeros((nl, batch, t, cfg.n_kv_heads, hd), dtype),
    }


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, seq_len: int):
    """Encode audio + run decoder prefix; emit self/cross caches."""
    enc_out = encode(params, cfg, frames)
    h = embed_tokens(params, cfg, tokens)
    h = h + L.sinusoidal_positions(h.shape[1], cfg.d_model
                                   ).astype(h.dtype)[None]
    positions = jnp.arange(h.shape[1])[None, :]
    pad = seq_len - h.shape[1]

    def body(h, p):
        x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
        _, k, v = _qkv(p["self_attn"], x)
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        xk = jnp.einsum("bld,dhk->blhk", enc_out, p["cross_attn"]["wk"])
        xv = jnp.einsum("bld,dhk->blhk", enc_out, p["cross_attn"]["wv"])
        h = _dec_layer(p, cfg, h, enc_out, positions)
        return h, (kc, vc, xk, xv)

    h, (kc, vc, xk, xv) = lax.scan(body, h, params["dec_layers"])
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    logits = logits_fn(params, cfg, h[:, -1:])
    return logits, {"self_k": kc, "self_v": vc, "cross_k": xk,
                    "cross_v": xv}


def encdec_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    from repro.models.transformer import scan_layers_carry
    h = embed_tokens(params, cfg, tokens)
    pos_emb = L.sinusoidal_positions(cache["self_k"].shape[2] + 0,
                                     cfg.d_model)
    h = h + lax.dynamic_slice_in_dim(pos_emb, pos, 1, 0)[None].astype(h.dtype)

    def body(h, p, st):
        kc, vc, xk, xv = st["k"], st["v"], st["xk"], st["xv"]
        x = L.rms_norm(h, p["ln1"], cfg.rms_eps)
        q, k, v = _qkv(p["self_attn"], x)
        kc = lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), pos, 1)
        vc = lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), pos, 1)
        o = L.decode_attention(q, kc, vc, pos)
        h = h + L.attn_out(p["self_attn"], o)
        x = L.rms_norm(h, p["ln_x"], cfg.rms_eps)
        q = jnp.einsum("bld,dhk->blhk", x, p["cross_attn"]["wq"])
        o = L.plain_attention(q, xk, xv, bidirectional=True)
        h = h + L.attn_out(p["cross_attn"], o)
        x = L.rms_norm(h, p["ln2"], cfg.rms_eps)
        h = h + L.mlp(p["mlp"], x)
        return h, {"k": kc, "v": vc, "xk": xk, "xv": xv}

    state0 = {"k": cache["self_k"], "v": cache["self_v"],
              "xk": cache["cross_k"], "xv": cache["cross_v"]}
    h, st = scan_layers_carry(body, h, params["dec_layers"], state0,
                              cfg.n_layers)
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    cache = dict(cache, self_k=st["k"], self_v=st["v"])
    return logits_fn(params, cfg, h), cache
