"""Multi-head Latent Attention (DeepSeek-V2/V3).

Queries are low-rank projected (q_lora_rank); keys/values are compressed to a
`kv_lora_rank` latent plus a single shared rope key. The decode cache stores
only (c_kv, k_rope) — `kv_lora_rank + rope_dim` floats/token instead of
2*H*Dh — and the decode path *absorbs* W_uk / W_uv so attention runs in
latent space (the memory-roofline win that motivates MLA).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLAConfig
from repro.distributed.meshctx import shard_act
from repro.models.layers import (NEG_INF, apply_rope, chunked_attention,
                                 plain_attention, rms_norm)


def init_mla(key, d_model: int, n_heads: int, m: MLAConfig, dtype):
    ks = jax.random.split(key, 8)
    std = d_model ** -0.5
    qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
    p = {
        "w_dq": (jax.random.normal(ks[0], (d_model, m.q_lora_rank)) * std
                 ).astype(dtype),
        "q_norm": jnp.zeros((m.q_lora_rank,), dtype),
        "w_uq": (jax.random.normal(ks[1], (m.q_lora_rank, n_heads, qk_dim))
                 * m.q_lora_rank ** -0.5).astype(dtype),
        "w_dkv": (jax.random.normal(ks[2], (d_model, m.kv_lora_rank)) * std
                  ).astype(dtype),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), dtype),
        "w_kr": (jax.random.normal(ks[3], (d_model, m.qk_rope_head_dim))
                 * std).astype(dtype),
        "w_uk": (jax.random.normal(
            ks[4], (m.kv_lora_rank, n_heads, m.qk_nope_head_dim))
            * m.kv_lora_rank ** -0.5).astype(dtype),
        "w_uv": (jax.random.normal(
            ks[5], (m.kv_lora_rank, n_heads, m.v_head_dim))
            * m.kv_lora_rank ** -0.5).astype(dtype),
        "wo": (jax.random.normal(ks[6], (n_heads, m.v_head_dim, d_model))
               * (n_heads * m.v_head_dim) ** -0.5).astype(dtype),
    }
    return p


def _latents(p, x, m: MLAConfig, theta, positions):
    """Compute (c_kv normalized, k_rope roped) from x: (B,L,D)."""
    c_kv = rms_norm(jnp.einsum("bld,dr->blr", x, p["w_dkv"]), p["kv_norm"])
    k_r = jnp.einsum("bld,dr->blr", x, p["w_kr"])[:, :, None, :]  # (B,L,1,R)
    k_r = apply_rope(k_r, positions, theta)[:, :, 0, :]
    return c_kv, k_r


def _queries(p, x, m: MLAConfig, theta, positions):
    cq = rms_norm(jnp.einsum("bld,dr->blr", x, p["w_dq"]), p["q_norm"])
    q = jnp.einsum("blr,rhk->blhk", cq, p["w_uq"])
    q_nope = q[..., :m.qk_nope_head_dim]
    q_rope = apply_rope(q[..., m.qk_nope_head_dim:], positions, theta)
    return q_nope, q_rope


def mla_forward(p, x, m: MLAConfig, theta, *, chunk=1024,
                triangle_skip=False):
    """Training/prefill forward (naive materialized K/V; differentiable
    unless triangle_skip — prefill-only causal-diagonal bound)."""
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]
    q_nope, q_rope = _queries(p, x, m, theta, positions)
    c_kv, k_r = _latents(p, x, m, theta, positions)
    k_nope = jnp.einsum("blr,rhk->blhk", c_kv, p["w_uk"])
    v = jnp.einsum("blr,rhk->blhk", c_kv, p["w_uv"])
    h = q_nope.shape[2]
    k_rope = jnp.broadcast_to(k_r[:, :, None, :],
                              (b, l, h, m.qk_rope_head_dim))
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, k_rope], -1)
    q = shard_act(q, "batch", None, "model", None)
    k = shard_act(k, "batch", None, "model", None)
    # pad v to qk dim so we can reuse the attention primitive, then slice
    pad = q.shape[-1] - m.v_head_dim
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, pad)))
    o = chunked_attention(q, k, vp, causal=True, chunk=min(chunk, l),
                          triangle_skip=triangle_skip)
    o = o[..., :m.v_head_dim]
    return jnp.einsum("blhk,hkd->bld", o, p["wo"])


def mla_init_cache(batch: int, seq_len: int, m: MLAConfig, dtype):
    return {
        "c_kv": jnp.zeros((batch, seq_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, seq_len, m.qk_rope_head_dim), dtype),
    }


def mla_prefill_cache(p, x, m: MLAConfig, theta, seq_len: int):
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]
    c_kv, k_r = _latents(p, x, m, theta, positions)
    pad = seq_len - l
    return {
        "c_kv": jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        "k_rope": jnp.pad(k_r, ((0, 0), (0, pad), (0, 0))),
    }


def mla_decode_step(p, x, cache, pos, m: MLAConfig, theta):
    """x: (B,1,D). Absorbed attention in latent space.

    scores = q_nope^T W_uk c_kv  +  q_rope^T k_rope
    out    = softmax(scores) c_kv W_uv
    """
    b = x.shape[0]
    positions = pos[None, None] if pos.ndim == 0 else pos[:, None]
    q_nope, q_rope = _queries(p, x, m, theta, positions)   # (B,1,H,*)
    c_new, kr_new = _latents(p, x, m, theta, positions)    # (B,1,R),(B,1,Rr)
    cache = {
        "c_kv": jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_new.astype(cache["c_kv"].dtype), pos, axis=1),
        "k_rope": jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"], kr_new.astype(cache["k_rope"].dtype), pos,
            axis=1),
    }
    # absorb W_uk into q: (B,1,H,R)
    q_lat = jnp.einsum("blhk,rhk->blhr", q_nope, p["w_uk"])
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = (jnp.einsum("blhr,bmr->bhlm", q_lat.astype(jnp.float32),
                    cache["c_kv"].astype(jnp.float32))
         + jnp.einsum("blhk,bmk->bhlm", q_rope.astype(jnp.float32),
                      cache["k_rope"].astype(jnp.float32))) * scale
    kpos = jnp.arange(cache["c_kv"].shape[1])
    s = jnp.where(kpos[None, None, None, :] <= pos, s, NEG_INF)
    prob = jax.nn.softmax(s, axis=-1)
    o_lat = jnp.einsum("bhlm,bmr->blhr", prob,
                       cache["c_kv"].astype(jnp.float32))   # (B,1,H,R)
    o = jnp.einsum("blhr,rhk->blhk", o_lat.astype(x.dtype), p["w_uv"])
    return jnp.einsum("blhk,hkd->bld", o, p["wo"]), cache
