"""Pure Mamba2 LM (attention-free): scan over SSD layers."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba as M
from repro.models.transformer import (embed_tokens, logits_fn, padded_vocab,
                                      softmax_xent)


def init_ssm_lm(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.dtype)
    vp = padded_vocab(cfg.vocab)
    ks = jax.random.split(key, 4)

    def init_layer(k):
        return {"ln": jnp.zeros((cfg.d_model,), dtype),
                "mamba": M.init_mamba(k, cfg.d_model, cfg.ssm, dtype)}

    params = {
        "embed": (jax.random.normal(ks[0], (vp, cfg.d_model))
                  * cfg.d_model ** -0.5).astype(dtype),
        "layers": jax.vmap(init_layer)(jax.random.split(ks[1],
                                                        cfg.n_layers)),
        "final_norm": jnp.zeros((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(ks[2], (cfg.d_model, vp))
                             * cfg.d_model ** -0.5).astype(dtype)
    return params


def _layer(p, cfg, h, *, return_state=False):
    x = L.rms_norm(h, p["ln"], cfg.rms_eps)
    if return_state:
        y, st = M.mamba_forward(p["mamba"], x, cfg.ssm, return_state=True)
        return h + y, st
    return h + M.mamba_forward(p["mamba"], x, cfg.ssm)


def ssm_forward(params, cfg: ModelConfig, tokens):
    h = embed_tokens(params, cfg, tokens)
    remat = jax.checkpoint if cfg.remat else (lambda f: f)

    def body(h, p):
        f = remat(lambda pp, hh: _layer(pp, cfg, hh))
        return f(p, h), None

    h, _ = lax.scan(body, h, params["layers"])
    return L.rms_norm(h, params["final_norm"], cfg.rms_eps)


def ssm_loss(params, cfg: ModelConfig, batch):
    h = ssm_forward(params, cfg, batch["tokens"])
    logits = logits_fn(params, cfg, h)
    mask = batch.get("mask", jnp.ones_like(batch["targets"], jnp.float32))
    loss = softmax_xent(logits, batch["targets"], mask)
    return loss, {"xent": loss}


def ssm_init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    del seq_len  # O(1) decode state — the long-context win of SSMs
    st = M.mamba_init_state(batch, cfg.d_model, cfg.ssm,
                            jnp.dtype(cfg.dtype))
    return {"states": jax.tree.map(
        lambda x: jnp.broadcast_to(x[None], (cfg.n_layers,) + x.shape), st)}


def ssm_decode_step(params, cfg: ModelConfig, cache, tokens, pos):
    del pos  # stateful recurrence; position-free
    from repro.models.transformer import scan_layers_carry
    h = embed_tokens(params, cfg, tokens)

    def body(h, p, st):
        x = L.rms_norm(h, p["ln"], cfg.rms_eps)
        y, st = M.mamba_decode_step(p["mamba"], x, st, cfg.ssm)
        return h + y, st

    h, states = scan_layers_carry(body, h, params["layers"],
                                  cache["states"], cfg.n_layers)
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, cfg, h), {"states": states}


def ssm_prefill(params, cfg: ModelConfig, tokens, seq_len: int):
    del seq_len
    h = embed_tokens(params, cfg, tokens)

    def body(h, p):
        return _layer(p, cfg, h, return_state=True)

    h, states = lax.scan(body, h, params["layers"])
    h = L.rms_norm(h, params["final_norm"], cfg.rms_eps)
    return logits_fn(params, cfg, h[:, -1:]), {"states": states}
