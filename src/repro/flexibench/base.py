"""FlexiBench workload framework.

Each workload provides: an RV32E assembly program (built with the asm eDSL),
a bit-exact jnp functional reference, a synthetic dataset generator, and
deployment metadata (SDG, lifetime, task frequency) from the paper's
Table 2. The ISS output must equal the reference output on every input —
that equivalence is property-tested.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import numpy as np

from repro.flexibits.asm import Program

DAY_S = 86_400.0
WEEK_S = 7 * DAY_S
MONTH_S = 30 * DAY_S
YEAR_S = 365 * DAY_S


@dataclasses.dataclass(frozen=True)
class Workload:
    key: str                      # short id (WQ, FS, ...)
    name: str
    sdg: str
    algorithm: str
    lifetime_s: float             # example deployment lifetime (Table 2)
    execs_per_day: float          # example task frequency (red star)
    program: Program
    mem_words: int                # RAM words for the ISS
    n_inputs: int                 # input words written at RAM[0..]
    gen_inputs: Callable[[np.random.Generator, int], np.ndarray]
    ref: Callable[[np.ndarray], np.ndarray]   # (n, n_inputs) -> (n,) int32
    out_addr: int = 0             # RAM word index of the scalar output
    max_steps: int = 2_000_000
    feasible_note: str = ""

    @property
    def nvm_kb(self) -> float:
        return self.program.nvm_bytes / 1024.0

    def vm_kb(self, measured_stack_bytes: int = 64) -> float:
        """VM = inputs/globals (reserved) + measured peak stack."""
        return (self.program.vm_reserved + measured_stack_bytes) / 1024.0

    @property
    def total_mem_words(self) -> int:
        """RAM image size: declared VM + the ROM (constants) segment, which
        the ISS maps into the same address space."""
        need = self.program.ro_base // 4 + len(self.program.ro_words) + 16
        return max(self.mem_words, need)

    def initial_memory(self, inputs: np.ndarray) -> np.ndarray:
        mem = self.program.initial_memory(self.total_mem_words)
        mem = mem.copy()
        mem[:len(inputs)] = np.asarray(inputs, np.int32)
        return mem


_REGISTRY: Dict[str, Workload] = {}


def register(w: Workload) -> Workload:
    _REGISTRY[w.key] = w
    return w


def get(key: str) -> Workload:
    _ensure_loaded()
    return _REGISTRY[key]


def all_workloads() -> List[Workload]:
    _ensure_loaded()
    return [_REGISTRY[k] for k in sorted(_REGISTRY)]


def _ensure_loaded():
    if _REGISTRY:
        return
    from repro.flexibench import workloads  # noqa: F401  (registers all)
