"""The 11 FlexiBench workloads (paper Table 2), each as RV32E assembly +
bit-exact numpy reference + synthetic dataset generator.

Deployment metadata (lifetime, example task frequency) follows Table 2; the
red-star frequencies are documented per workload. Quantization is integer
fixed-point throughout (RV32E has no FPU).
"""
from __future__ import annotations

import numpy as np

from repro.flexibench import builders as B
from repro.flexibench.base import (DAY_S, MONTH_S, WEEK_S, YEAR_S, Workload,
                                   register)
from repro.flexibits.asm import Asm

RNG = np.random.default_rng  # all tables built with fixed seeds


# ===================================================================== WQ
def _build_wq():
    """Water Quality Monitoring: threshold checks (SDG #6)."""
    n_in, out = 3, 4
    a = Asm(vm_reserved=4 * (n_in + 2))
    # ok = (650<=ph<=850) & (do>=500) & (tds<=500)
    fail = a.uniq("fail")
    done = a.uniq("done")
    a.lw(a.a2, a.zero, 0)            # ph x100
    a.li(a.t0, 650)
    a.blt(a.a2, a.t0, fail)
    a.li(a.t0, 850)
    a.blt(a.t0, a.a2, fail)
    a.lw(a.a2, a.zero, 4)            # do x100
    a.li(a.t0, 500)
    a.blt(a.a2, a.t0, fail)
    a.lw(a.a2, a.zero, 8)            # tds
    a.li(a.t0, 500)
    a.blt(a.t0, a.a2, fail)
    a.li(a.a3, 1)
    a.j(done)
    a.label(fail)
    a.li(a.a3, 0)
    a.label(done)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    prog = a.assemble()

    def gen(rng, n):
        x = np.stack([rng.integers(500, 1000, n),
                      rng.integers(300, 900, n),
                      rng.integers(100, 900, n)], -1)
        return x.astype(np.int32)

    def ref(x):
        ok = ((x[:, 0] >= 650) & (x[:, 0] <= 850) & (x[:, 1] >= 500)
              & (x[:, 2] <= 500))
        return ok.astype(np.int32)

    return register(Workload(
        key="WQ", name="Water Quality Monitoring", sdg="#6 Clean Water",
        algorithm="Thresholds", lifetime_s=1 * DAY_S, execs_per_day=24,
        program=prog, mem_words=64, n_inputs=n_in, gen_inputs=gen, ref=ref,
        out_addr=out, max_steps=20_000))


# ===================================================================== MC
def _mc_trees():
    rng = RNG(7)
    # two depth-3 trees (male/female), 4 e-nose features in 0..31,
    # leaves = malodor score 0..4
    def tree():
        nodes = []
        # complete depth-3: nodes 0..6, leaves at depth 3
        th = sorted(rng.integers(4, 28, 7))
        leaves = rng.integers(0, 5, 8)
        # node i children: internal until idx 3..6 whose children are leaves
        nodes.append((0, int(th[3]), 1, 2))
        nodes.append((1, int(th[1]), 3, 4))
        nodes.append((2, int(th[5]), 5, 6))
        for k in range(4):
            nodes.append((3, int(th[k if k < 3 else 6]),
                          ~int(leaves[2 * k]), ~int(leaves[2 * k + 1])))
        return nodes
    return B.pack_tree(tree()), B.pack_tree(tree())


def _build_mc():
    """Malodor Classification: 2 decision trees (SDG #12)."""
    t_m, t_f = _mc_trees()
    n_in, out = 5, 8                  # [gender, s0..s3]
    a = Asm(vm_reserved=4 * (n_in + 2))
    off_m = a.const_words(t_m)
    off_f = a.const_words(t_f)
    female = a.uniq("female")
    done = a.uniq("done")
    a.lw(a.t0, a.zero, 0)
    a.bne(a.t0, a.zero, female)
    B.emit_tree_walk(a, table_off=off_m, x_addr=4)
    a.j(done)
    a.label(female)
    B.emit_tree_walk(a, table_off=off_f, x_addr=4)
    a.label(done)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    prog = a.assemble()

    def gen(rng, n):
        return np.concatenate([rng.integers(0, 2, (n, 1)),
                               rng.integers(0, 32, (n, 4))],
                              -1).astype(np.int32)

    def ref(x):
        out_v = np.zeros(len(x), np.int32)
        for i, row in enumerate(x):
            tab = t_f if row[0] else t_m
            out_v[i] = B.tree_walk_ref(tab, row[1:])
        return out_v

    return register(Workload(
        key="MC", name="Malodor Classification", sdg="#12 Responsible Cons.",
        algorithm="Decision Tree", lifetime_s=4 * YEAR_S, execs_per_day=1,
        program=prog, mem_words=128, n_inputs=n_in, gen_inputs=gen, ref=ref,
        out_addr=out, max_steps=20_000))


# ===================================================================== FS
def _fs_model():
    """Quantized logistic-regression beef-spoilage model, 'trained' on the
    synthetic e-nose generative model (class means), Q8 weights."""
    rng = RNG(11)
    n_feat, n_cls = 10, 4            # fresh / ok / stale / spoiled
    means = np.linspace(200, 1800, n_cls)[:, None] * \
        np.linspace(0.5, 1.5, n_feat)[None, :]
    W = np.round((means - means.mean(0)) / 8.0).astype(np.int32)
    # nearest-mean bias with the same 1/8 weight scale: b_c = -|mu_c|^2/16
    b = np.round(-(means * means).sum(1) / 16.0)
    return W, b.astype(np.int64).astype(np.int32), means


def _build_fs():
    W, b, means = _fs_model()
    n_in, y_addr_w = 10, 12
    out = y_addr_w + 4
    a = Asm(vm_reserved=4 * (n_in + 4 + 2))
    w_off = a.const_words(W.reshape(-1))
    b_off = a.const_words(b)
    B.emit_matvec(a, w_off=w_off, b_off=b_off, x_addr=0,
                  y_addr=4 * y_addr_w, rows=4, cols=10, shift=8, relu=False)
    B.emit_argmax(a, y_addr=4 * y_addr_w, n=4)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()
    prog = a.assemble()

    def gen(rng, n):
        cls = rng.integers(0, 4, n)
        x = means[cls] + rng.normal(0, 350, (n, 10))
        return np.clip(np.round(x), 0, 4000).astype(np.int32)

    def ref(x):
        y = B.matvec_ref(W, b, x, 8, False)
        return np.argmax(y, -1).astype(np.int32)

    return register(Workload(
        key="FS", name="Food Spoilage Detection", sdg="#2 Zero Hunger",
        algorithm="Logistic Regression", lifetime_s=1 * WEEK_S,
        execs_per_day=24, program=prog, mem_words=128, n_inputs=n_in,
        gen_inputs=gen, ref=ref, out_addr=out, max_steps=200_000))


# ===================================================================== SI
def _si_refs():
    rng = RNG(13)
    n_ref = 20
    temp = rng.integers(10, 40, n_ref)
    moist = rng.integers(0, 100, n_ref)
    label = (moist < 45).astype(np.int32)      # dry -> pump on
    return np.stack([temp, moist, label], -1).astype(np.int32)


def _build_si():
    refs = _si_refs()
    n_ref = len(refs)
    n_in = 2
    # globals: best3 dist (words 4..6), best3 label (7..9)
    out = 10
    a = Asm(vm_reserved=4 * 12)
    r_off = a.const_words(refs.reshape(-1))
    big = 0x7FFFFFFF
    for k in range(3):
        a.li(a.t0, big)
        a.sw(a.t0, a.zero, 4 * (4 + k))
        a.sw(a.zero, a.zero, 4 * (7 + k))
    loop = a.uniq("si")
    a.li(a.s0, 0)                     # ref index
    a.label(loop)
    a.la_const(a.s1, r_off)
    a.slli(a.t1, a.s0, 2)
    a.add(a.t1, a.t1, a.s0)           # s0*5? no: 3 words per ref -> s0*12
    # compute s1 += s0*12: t1 = s0*4; t2 = s0*8; s1 += t1+t2
    a.slli(a.t1, a.s0, 2)
    a.slli(a.t2, a.s0, 3)
    a.add(a.s1, a.s1, a.t1)
    a.add(a.s1, a.s1, a.t2)
    # dt = x0 - ref_t ; dm = x1 - ref_m
    a.lw(a.a0, a.zero, 0)
    a.lw(a.t0, a.s1, 0)
    a.sub(a.a0, a.a0, a.t0)
    a.mv(a.a1, a.a0)
    a.call("__mul")                   # a0 = dt*dt
    a.mv(a.a2, a.a0)
    a.lw(a.a0, a.zero, 4)
    a.lw(a.t0, a.s1, 4)
    a.sub(a.a0, a.a0, a.t0)
    a.mv(a.a1, a.a0)
    a.call("__mul")                   # a0 = dm*dm
    a.add(a.a2, a.a2, a.a0)           # dist
    a.lw(a.a3, a.s1, 8)               # label
    # insertion into best3 (registers: a2 dist, a3 label)
    for k in range(3):
        nxt = a.uniq(f"si_ins{k}")
        a.lw(a.t0, a.zero, 4 * (4 + k))
        a.bge(a.a2, a.t0, nxt)        # dist >= best[k] -> next slot
        # shift down slots > k, insert at k
        for j in range(2, k, -1):
            a.lw(a.t1, a.zero, 4 * (4 + j - 1))
            a.sw(a.t1, a.zero, 4 * (4 + j))
            a.lw(a.t1, a.zero, 4 * (7 + j - 1))
            a.sw(a.t1, a.zero, 4 * (7 + j))
        a.sw(a.a2, a.zero, 4 * (4 + k))
        a.sw(a.a3, a.zero, 4 * (7 + k))
        a.j(a.uniq("si_done_ins") if False else f"__si_inserted_{k}")
        a.label(nxt)
    for k in range(3):
        a.label(f"__si_inserted_{k}")
    a.addi(a.s0, a.s0, 1)
    a.li(a.t0, n_ref)
    a.blt(a.s0, a.t0, loop)
    # majority vote of labels
    a.lw(a.t0, a.zero, 4 * 7)
    a.lw(a.t1, a.zero, 4 * 8)
    a.add(a.t0, a.t0, a.t1)
    a.lw(a.t1, a.zero, 4 * 9)
    a.add(a.t0, a.t0, a.t1)
    a.li(a.t1, 2)
    a.slt(a.a3, a.t0, a.t1)           # sum<2 -> 1? no: vote = sum>=2
    a.xori(a.a3, a.a3, 1)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()
    prog = a.assemble()

    def gen(rng, n):
        return np.stack([rng.integers(10, 40, n),
                         rng.integers(0, 100, n)], -1).astype(np.int32)

    def ref(x):
        d = (B.mulw(x[:, None, 0] - refs[None, :, 0],
                    x[:, None, 0] - refs[None, :, 0]).astype(np.int64)
             + B.mulw(x[:, None, 1] - refs[None, :, 1],
                      x[:, None, 1] - refs[None, :, 1]))
        idx = np.argsort(d, axis=1, kind="stable")[:, :3]
        votes = refs[idx, 2].sum(1)
        return (votes >= 2).astype(np.int32)

    return register(Workload(
        key="SI", name="Smart Irrigation Control", sdg="#13 Climate Action",
        algorithm="KNN", lifetime_s=6 * MONTH_S, execs_per_day=1,
        program=prog, mem_words=128, n_inputs=n_in, gen_inputs=gen, ref=ref,
        out_addr=out, max_steps=200_000))


# ==================================================================== MLPs
def _quant_mlp(rng, dims, means):
    """Random-feature MLP 'trained' by class-mean projection; Q6 ints."""
    Ws, bs = [], []
    for i in range(len(dims) - 1):
        W = rng.normal(0, 1, (dims[i + 1], dims[i]))
        Ws.append(np.round(W * 8).astype(np.int32))
        bs.append(np.zeros(dims[i + 1], np.int32))
    return Ws, bs


def _build_mlp_workload(*, key, name, sdg, algorithm, lifetime_s,
                        execs_per_day, dims, in_range, seed, max_steps):
    rng = RNG(seed)
    Ws, bs = _quant_mlp(rng, dims, None)
    n_in = dims[0]
    # RAM layout: x (n_in), then ping/pong activation buffers
    buf0 = n_in
    buf1 = n_in + max(dims[1:])
    out = buf1 + max(dims[1:])
    a = Asm(vm_reserved=4 * (out + 2))
    offs = [(a.const_words(W.reshape(-1)), a.const_words(b))
            for W, b in zip(Ws, bs)]
    src = 0
    dst = buf0
    for li, ((w_off, b_off), W) in enumerate(zip(offs, Ws)):
        last = li == len(Ws) - 1
        B.emit_matvec(a, w_off=w_off, b_off=b_off, x_addr=4 * src,
                      y_addr=4 * dst, rows=W.shape[0], cols=W.shape[1],
                      shift=6, relu=not last)
        src, dst = dst, (buf1 if dst == buf0 else buf0)
    B.emit_argmax(a, y_addr=4 * src, n=dims[-1])
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()
    prog = a.assemble()

    def gen(rng2, n):
        return rng2.integers(-in_range, in_range,
                             (n, n_in)).astype(np.int32)

    def ref(x):
        h = x
        for li, (W, b) in enumerate(zip(Ws, bs)):
            h = B.matvec_ref(W, b, h, 6, li < len(Ws) - 1)
        return np.argmax(h, -1).astype(np.int32)

    return register(Workload(
        key=key, name=name, sdg=sdg, algorithm=algorithm,
        lifetime_s=lifetime_s, execs_per_day=execs_per_day, program=prog,
        mem_words=256, n_inputs=n_in, gen_inputs=gen, ref=ref,
        out_addr=out, max_steps=max_steps))


def _build_ct():
    """Cardiotocography: MLP 21-16-3 (SDG #3)."""
    return _build_mlp_workload(
        key="CT", name="Cardiotocography", sdg="#3 Good Health",
        algorithm="MLP", lifetime_s=9 * MONTH_S, execs_per_day=24 * 2,
        dims=(21, 16, 3), in_range=64, seed=17, max_steps=2_000_000)


def _build_pt():
    """Package Tracking: MLP 12-16-16-4 (SDG #9)."""
    return _build_mlp_workload(
        key="PT", name="Package Tracking", sdg="#9 Infrastructure",
        algorithm="MLP (2 hidden)", lifetime_s=3 * WEEK_S,
        execs_per_day=24 * 3, dims=(12, 16, 16, 4), in_range=64, seed=19,
        max_steps=2_000_000)


# ===================================================================== AD
def _ad_bloom():
    """Bloom filter populated with AF-like (rr, drr) pairs."""
    rng = RNG(23)
    table = np.zeros(8, np.int64)
    for _ in range(40):
        rr = int(rng.integers(20, 60))       # irregular RR (in samples)
        drr = int(rng.integers(-20, 20))
        for mul_a, mul_b in ((31, 7), (13, 3)):
            h = (rr * mul_a + drr * mul_b) & 255
            table[h >> 5] |= 1 << (h & 31)
    return np.int32(table & 0xFFFFFFFF).astype(np.int32), rng


def _build_ad():
    bloom, _ = _ad_bloom()
    n_samp = 80
    thr = 96
    n_in = n_samp
    out = n_samp + 8
    a = Asm(vm_reserved=4 * (out + 2))
    b_off = a.const_words(bloom)
    # globals: last_peak(word n+0), last_rr(n+1), af_count(n+2)
    gl = n_samp
    a.li(a.t0, -1)
    a.sw(a.t0, a.zero, 4 * (gl + 0))
    a.sw(a.zero, a.zero, 4 * (gl + 1))
    a.sw(a.zero, a.zero, 4 * (gl + 2))
    loop = a.uniq("ad")
    nxt = a.uniq("ad_n")
    a.li(a.s0, 1)                     # i = 1..n-2
    a.label(loop)
    a.slli(a.t0, a.s0, 2)
    a.lw(a.a2, a.t0, 0)               # x[i]
    a.li(a.t1, thr)
    a.blt(a.a2, a.t1, nxt)            # below threshold
    a.lw(a.t1, a.t0, -4)              # x[i-1]
    a.blt(a.a2, a.t1, nxt)
    a.lw(a.t1, a.t0, 4)               # x[i+1]
    a.blt(a.a2, a.t1, nxt)
    # peak at i: rr = i - last_peak
    a.lw(a.t1, a.zero, 4 * (gl + 0))
    a.sw(a.s0, a.zero, 4 * (gl + 0))
    a.li(a.t2, -1)
    a.beq(a.t1, a.t2, nxt)            # first peak: no rr yet
    a.sub(a.a2, a.s0, a.t1)           # rr
    a.lw(a.t1, a.zero, 4 * (gl + 1))  # last_rr
    a.sw(a.a2, a.zero, 4 * (gl + 1))
    a.beq(a.t1, a.zero, nxt)          # no previous rr
    a.sub(a.a4, a.a2, a.t1)           # drr
    # h1 = (rr*31 + drr*7) & 255 ; h2 = (rr*13 + drr*3) & 255
    checked = a.uniq("ad_chk")
    for mul_a, mul_b in ((31, 7), (13, 3)):
        a.li(a.a1, mul_a)
        a.mv(a.a0, a.a2)
        a.call("__mul")
        a.mv(a.a5, a.a0)
        a.li(a.a1, mul_b)
        a.mv(a.a0, a.a4)
        a.call("__mul")
        a.add(a.a5, a.a5, a.a0)
        a.andi(a.a5, a.a5, 255)
        # bit test
        a.srli(a.t1, a.a5, 5)
        a.slli(a.t1, a.t1, 2)
        a.la_const(a.t2, b_off)
        a.add(a.t1, a.t1, a.t2)
        a.lw(a.t1, a.t1, 0)
        a.andi(a.t2, a.a5, 31)
        a.srl(a.t1, a.t1, a.t2)
        a.andi(a.t1, a.t1, 1)
        a.beq(a.t1, a.zero, checked)  # bit clear -> not AF
    # both bits set -> af_count++
    a.lw(a.t1, a.zero, 4 * (gl + 2))
    a.addi(a.t1, a.t1, 1)
    a.sw(a.t1, a.zero, 4 * (gl + 2))
    a.label(checked)
    a.label(nxt)
    a.addi(a.s0, a.s0, 1)
    a.li(a.t0, n_samp - 1)
    a.blt(a.s0, a.t0, loop)
    a.lw(a.t0, a.zero, 4 * (gl + 2))
    a.sw(a.t0, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()
    prog = a.assemble()

    def gen(rng, n):
        # synthetic ECG: baseline noise + peaks at irregular intervals
        x = rng.integers(0, 40, (n, n_samp))
        for i in range(n):
            pos = 2
            while pos < n_samp - 2:
                x[i, pos] = rng.integers(100, 127)
                pos += int(rng.integers(15, 60))
        return x.astype(np.int32)

    def ref(x):
        outv = np.zeros(len(x), np.int32)
        for i, row in enumerate(x):
            last_peak, last_rr, count = -1, 0, 0
            for j in range(1, n_samp - 1):
                if row[j] >= thr and row[j] >= row[j - 1] \
                        and row[j] >= row[j + 1]:
                    if last_peak >= 0:
                        rr = j - last_peak
                        if last_rr != 0:
                            drr = rr - last_rr
                            h1 = (rr * 31 + drr * 7) & 255
                            h2 = (rr * 13 + drr * 3) & 255
                            if ((bloom[h1 >> 5] >> (h1 & 31)) & 1) and \
                               ((bloom[h2 >> 5] >> (h2 & 31)) & 1):
                                count += 1
                        last_rr = rr
                    last_peak = j
            outv[i] = count
        return outv

    return register(Workload(
        key="AD", name="Arrhythmia Detection", sdg="#3 Good Health",
        algorithm="Bloom Filter", lifetime_s=2 * WEEK_S,
        execs_per_day=24 * 60 * 6, program=prog, mem_words=256,
        n_inputs=n_in, gen_inputs=gen, ref=ref, out_addr=out,
        max_steps=2_000_000,
        feasible_note="paper: infeasible on all cores at real-time rates"))


# =================================================================== trees
def _forest(rng, n_trees, n_feat, feat_range, leaf_vals):
    tables = []
    for _ in range(n_trees):
        th = rng.integers(feat_range // 4, 3 * feat_range // 4, 7)
        fs = rng.integers(0, n_feat, 7)
        lv = rng.choice(leaf_vals, 8)
        nodes = [
            (int(fs[0]), int(th[0]), 1, 2),
            (int(fs[1]), int(th[1]), 3, 4),
            (int(fs[2]), int(th[2]), 5, 6),
            (int(fs[3]), int(th[3]), ~int(lv[0]), ~int(lv[1])),
            (int(fs[4]), int(th[4]), ~int(lv[2]), ~int(lv[3])),
            (int(fs[5]), int(th[5]), ~int(lv[4]), ~int(lv[5])),
            (int(fs[6]), int(th[6]), ~int(lv[6]), ~int(lv[7])),
        ]
        tables.append(B.pack_tree(nodes))
    return tables


def _build_forest_workload(*, key, name, sdg, algorithm, lifetime_s,
                           execs_per_day, n_trees, n_feat, feat_range,
                           leaf_vals, reduce_, seed, out_levels=None):
    rng = RNG(seed)
    tables = _forest(rng, n_trees, n_feat, feat_range, leaf_vals)
    n_in = n_feat
    acc_w = n_in          # accumulator word
    out = n_in + 1
    a = Asm(vm_reserved=4 * (out + 2))
    offs = [a.const_words(t) for t in tables]
    a.sw(a.zero, a.zero, 4 * acc_w)
    for off in offs:
        B.emit_tree_walk(a, table_off=off, x_addr=0)
        a.lw(a.t0, a.zero, 4 * acc_w)
        a.add(a.t0, a.t0, a.a3)
        a.sw(a.t0, a.zero, 4 * acc_w)
    a.lw(a.a2, a.zero, 4 * acc_w)
    if reduce_ == "majority":
        a.li(a.t0, n_trees // 2)
        a.slt(a.a3, a.t0, a.a2)       # sum > n/2
    else:                             # bucket by thresholds
        th = out_levels
        a.li(a.a3, 0)
        for t in th:
            a.li(a.t0, t)
            a.slt(a.t0, a.t0, a.a2)   # sum > t
            a.add(a.a3, a.a3, a.t0)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    prog = a.assemble()

    def gen(rng2, n):
        return rng2.integers(0, feat_range, (n, n_feat)).astype(np.int32)

    def ref(x):
        outv = np.zeros(len(x), np.int32)
        for i, row in enumerate(x):
            s = sum(int(B.tree_walk_ref(t, row)) for t in tables)
            if reduce_ == "majority":
                outv[i] = 1 if s > n_trees // 2 else 0
            else:
                outv[i] = sum(1 for t in out_levels if s > t)
        return outv

    return register(Workload(
        key=key, name=name, sdg=sdg, algorithm=algorithm,
        lifetime_s=lifetime_s, execs_per_day=execs_per_day, program=prog,
        mem_words=128, n_inputs=n_in, gen_inputs=gen, ref=ref,
        out_addr=out, max_steps=2_000_000))


def _build_hc():
    """HVAC Control: random forest, 100 trees (SDG #7)."""
    return _build_forest_workload(
        key="HC", name="HVAC Control", sdg="#7 Clean Energy",
        algorithm="Random Forest (100 trees)", lifetime_s=20 * YEAR_S,
        execs_per_day=24 * 4, n_trees=100, n_feat=5, feat_range=1024,
        leaf_vals=[0, 1], reduce_="majority", seed=29)


def _build_ap():
    """Air Pollution Monitoring: XGBoost-style additive trees (SDG #11)."""
    return _build_forest_workload(
        key="AP", name="Air Pollution Monitoring",
        sdg="#11 Sustainable Cities", algorithm="XGBoost (50 trees)",
        lifetime_s=4 * YEAR_S, execs_per_day=24, n_trees=50, n_feat=6,
        feat_range=1024, leaf_vals=[0, 1, 2, 3, 4], reduce_="bucket",
        seed=31, out_levels=[20, 40, 60, 80, 100])


# ===================================================================== GR
def _gr_refs():
    rng = RNG(37)
    return rng.integers(0, 2 ** 32, (5, 8), dtype=np.uint64
                        ).astype(np.int64).astype(np.int32) \
        if False else np.int32(rng.integers(-2**31, 2**31, (5, 8)))


def _build_gr():
    refs = _gr_refs()                 # 5 gestures x 8 words (256 bits)
    n_in = 8
    # globals: best_sim, best_idx
    out = n_in + 2
    a = Asm(vm_reserved=4 * (out + 2))
    r_off = a.const_words(refs.reshape(-1))
    a.li(a.s0, 0)                     # gesture g
    a.li(a.a4, -1)                    # best sim
    a.li(a.a5, 0)                     # best idx
    gloop = a.uniq("gr_g")
    wloop = a.uniq("gr_w")
    skip = a.uniq("gr_s")
    a.label(gloop)
    a.li(a.a2, 0)                     # sim accumulator -> use RAM? regs ok
    a.li(a.s1, 0)                     # word w
    a.label(wloop)
    # t0 = x[w] ^ ref[g*8+w]; popcount(~t0) = 32 - popcount(t0)
    a.slli(a.t0, a.s1, 2)
    a.lw(a.t1, a.t0, 0)               # x[w]
    a.la_const(a.t2, r_off)
    a.slli(a.a0, a.s0, 5)             # g*32 bytes
    a.add(a.t2, a.t2, a.a0)
    a.slli(a.a0, a.s1, 2)
    a.add(a.t2, a.t2, a.a0)
    a.lw(a.t2, a.t2, 0)               # ref word
    a.xor(a.a0, a.t1, a.t2)
    a.sw(a.a2, a.zero, 4 * (n_in + 0))   # save sim (popcnt clobbers)
    a.call("__popcnt")
    a.lw(a.a2, a.zero, 4 * (n_in + 0))
    a.li(a.t0, 32)
    a.sub(a.t0, a.t0, a.a0)           # matching bits
    a.add(a.a2, a.a2, a.t0)
    a.addi(a.s1, a.s1, 1)
    a.li(a.t0, 8)
    a.blt(a.s1, a.t0, wloop)
    # update best
    a.bge(a.a4, a.a2, skip)
    a.mv(a.a4, a.a2)
    a.mv(a.a5, a.s0)
    a.label(skip)
    a.addi(a.s0, a.s0, 1)
    a.li(a.t0, 5)
    a.blt(a.s0, a.t0, gloop)
    a.sw(a.a5, a.zero, 4 * out)
    a.halt()
    B.emit_popcount(a)
    prog = a.assemble()

    def gen(rng, n):
        # flip a few bits of a random reference gesture
        g = rng.integers(0, 5, n)
        x = refs[g].astype(np.int64)
        for i in range(n):
            for _ in range(int(rng.integers(0, 20))):
                w = int(rng.integers(0, 8))
                b = int(rng.integers(0, 32))
                x[i, w] = int(x[i, w]) ^ (1 << b)
        return B.wrap32(x)

    def ref(x):
        xo = np.asarray(x, np.int64) & 0xFFFFFFFF
        ro = refs.astype(np.int64) & 0xFFFFFFFF
        xor = xo[:, None, :].astype(np.int64) ^ ro[None, :, :]
        pc = np.zeros(xor.shape[:2], np.int64)
        for w in range(8):
            v = xor[:, :, w]
            cnt = np.zeros_like(v)
            for _ in range(32):
                cnt += v & 1
                v >>= 1
            pc += 32 - cnt
        return np.argmax(pc, -1).astype(np.int32)

    return register(Workload(
        key="GR", name="Gesture Recognition", sdg="#10 Reduced Inequality",
        algorithm="Cosine Similarity (binary)", lifetime_s=2 * YEAR_S,
        execs_per_day=24 * 60 * 60, program=prog, mem_words=128,
        n_inputs=n_in, gen_inputs=gen, ref=ref, out_addr=out,
        max_steps=2_000_000,
        feasible_note="paper: infeasible on all cores at sub-second rates"))


# ===================================================================== TT
def _tt_tables():
    n = 32
    k = 8
    ang = 2 * np.pi * np.outer(np.arange(k), np.arange(n)) / n
    cos = np.round(np.cos(ang) * 127).astype(np.int32)
    sin = np.round(-np.sin(ang) * 127).astype(np.int32)
    return cos, sin


def _build_tt():
    cos, sin = _tt_tables()
    n, k = 32, 8
    n_in = n
    # globals: re, im ; output byte
    out = n_in + 4
    a = Asm(vm_reserved=4 * (out + 2))
    c_off = a.const_words(cos.reshape(-1))
    s_off = a.const_words(sin.reshape(-1))
    thr_hi = 1 << 24
    a.sw(a.zero, a.zero, 4 * (n_in + 2))      # demod byte
    for kk in range(k):
        # re/im accumulate
        a.sw(a.zero, a.zero, 4 * (n_in + 0))
        a.sw(a.zero, a.zero, 4 * (n_in + 1))
        loop = a.uniq(f"tt{kk}")
        a.li(a.s0, 0)
        a.label(loop)
        a.slli(a.t0, a.s0, 2)
        a.lw(a.a2, a.t0, 0)                   # x[n]
        for tab_off, acc_w in ((c_off, n_in + 0), (s_off, n_in + 1)):
            a.la_const(a.t1, tab_off + kk * n)
            a.slli(a.t2, a.s0, 2)
            a.add(a.t1, a.t1, a.t2)
            a.lw(a.a1, a.t1, 0)
            a.mv(a.a0, a.a2)
            a.call("__mul")
            a.lw(a.t1, a.zero, 4 * acc_w)
            a.add(a.t1, a.t1, a.a0)
            a.sw(a.t1, a.zero, 4 * acc_w)
        a.addi(a.s0, a.s0, 1)
        a.li(a.t0, n)
        a.blt(a.s0, a.t0, loop)
        # mag2 = re*re + im*im
        a.lw(a.a0, a.zero, 4 * (n_in + 0))
        a.mv(a.a1, a.a0)
        a.call("__mul")
        a.mv(a.a2, a.a0)
        a.lw(a.a0, a.zero, 4 * (n_in + 1))
        a.mv(a.a1, a.a0)
        a.call("__mul")
        a.add(a.a2, a.a2, a.a0)
        # bit kk = mag2 > thr
        a.li(a.t0, thr_hi)
        a.slt(a.t0, a.t0, a.a2)
        a.slli(a.t0, a.t0, kk)
        a.lw(a.t1, a.zero, 4 * (n_in + 2))
        a.or_(a.t1, a.t1, a.t0)
        a.sw(a.t1, a.zero, 4 * (n_in + 2))
    a.lw(a.t0, a.zero, 4 * (n_in + 2))
    a.sw(a.t0, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()
    prog = a.assemble()

    def gen(rng, nn):
        # modulate a random byte: sum of carriers for set bits
        byte = rng.integers(0, 256, nn)
        t = np.arange(n)
        x = np.zeros((nn, n))
        for i in range(nn):
            for b in range(8):
                if (byte[i] >> b) & 1:
                    x[i] += 90 * np.cos(2 * np.pi * b * t / n)
        return np.round(x).astype(np.int32)

    def ref(x):
        outv = np.zeros(len(x), np.int32)
        for i, row in enumerate(x):
            byte = 0
            for kk in range(k):
                re = im = np.int64(0)
                for j in range(n):
                    re = np.int64(B.wrap32(re + B.mulw(row[j], cos[kk, j])))
                    im = np.int64(B.wrap32(im + B.mulw(row[j], sin[kk, j])))
                mag2 = B.wrap32(np.int64(B.mulw(re, re))
                                + np.int64(B.mulw(im, im)))
                if mag2 > (1 << 24):
                    byte |= 1 << kk
            outv[i] = byte
        return outv

    return register(Workload(
        key="TT", name="Tree Tracking", sdg="#15 Life on Land",
        algorithm="DFT demodulation", lifetime_s=10 * YEAR_S,
        execs_per_day=24 * 60 * 60 / 5, program=prog, mem_words=256,
        n_inputs=n_in, gen_inputs=gen, ref=ref, out_addr=out,
        max_steps=4_000_000,
        feasible_note="paper: infeasible (analytical model; reduced N=32 "
                      "DFT here, scaled analytically in benchmarks)"))


# ------------------------------------------------------------------ build
WQ = _build_wq()
MC = _build_mc()
FS = _build_fs()
SI = _build_si()
CT = _build_ct()
PT = _build_pt()
AD = _build_ad()
HC = _build_hc()
AP = _build_ap()
GR = _build_gr()
TT = _build_tt()
