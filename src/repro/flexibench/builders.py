"""Shared RV32E assembly macro-builders for FlexiBench workloads:
matvec (software-mul), decision-tree walk, argmax, popcount.

Register conventions (callers must respect):
  __mul clobbers a0, a1, t0, t1, t2.
  matvec uses s0, s1, a2, a3, a4, a5 (+ mul's).
  tree_walk uses t0, t1, t2, a2, a3, a4 and returns the leaf in a3.
"""
from __future__ import annotations

import numpy as np

from repro.flexibits.asm import Asm


def wrap32(v):
    """Wrap any integer array/scalar to int32 two's-complement."""
    return (np.asarray(v, np.int64) & 0xFFFFFFFF).astype(np.uint32) \
        .astype(np.int32)


def mulw(a, b):
    """int32 wrap-around multiply (matches the software mul routine)."""
    return wrap32(np.asarray(a, np.int64) * np.asarray(b, np.int64))


def emit_matvec(a: Asm, *, w_off: int, b_off: int, x_addr: int, y_addr: int,
                rows: int, cols: int, shift: int, relu: bool):
    """y[i] = max(0, (b[i] + sum_j W[i,j] x[j]) >> shift)   (relu optional)

    W row-major int32 words at const offset w_off; bias at b_off;
    x at byte address x_addr (RAM); y at byte address y_addr (RAM).
    """
    li, lab = a.li, a.uniq
    loop_i, loop_j, after_relu = lab("mv_i"), lab("mv_j"), lab("mv_r")
    a.li(a.s0, 0)                        # i
    a.la_const(a.a2, w_off)              # W ptr (advances)
    a.label(loop_i)
    # acc = bias[i]
    a.la_const(a.t0, b_off)
    a.slli(a.t1, a.s0, 2)
    a.add(a.t0, a.t0, a.t1)
    a.lw(a.a3, a.t0, 0)
    a.li(a.a4, x_addr)                   # x ptr
    a.li(a.s1, cols)                     # j counter
    a.label(loop_j)
    a.lw(a.a0, a.a4, 0)
    a.lw(a.a1, a.a2, 0)
    a.call("__mul")
    a.add(a.a3, a.a3, a.a0)
    a.addi(a.a4, a.a4, 4)
    a.addi(a.a2, a.a2, 4)
    a.addi(a.s1, a.s1, -1)
    a.bne(a.s1, a.zero, loop_j)
    if shift:
        a.srai(a.a3, a.a3, shift)
    if relu:
        a.bge(a.a3, a.zero, after_relu)
        a.li(a.a3, 0)
        a.label(after_relu)
    # y[i] = acc
    a.li(a.a5, y_addr)
    a.slli(a.t1, a.s0, 2)
    a.add(a.a5, a.a5, a.t1)
    a.sw(a.a3, a.a5, 0)
    a.addi(a.s0, a.s0, 1)
    a.li(a.t1, rows)
    a.blt(a.s0, a.t1, loop_i)


def matvec_ref(W, b, x, shift, relu):
    """Bit-exact reference for emit_matvec (int32 wrap + arithmetic shift).

    x may be (cols,) or (batch, cols); result broadcasts accordingly.
    """
    x = np.asarray(x)
    acc = np.broadcast_to(
        wrap32(b), x.shape[:-1] + (W.shape[0],)).astype(np.int64)
    for j in range(W.shape[1]):
        acc = wrap32(acc + mulw(W[:, j], x[..., j:j + 1])).astype(np.int64)
    acc = wrap32(acc) >> shift
    if relu:
        acc = np.maximum(acc, 0)
    return wrap32(acc)


def emit_argmax(a: Asm, *, y_addr: int, n: int):
    """a3 <- argmax(y[0..n-1]); ties -> first. Clobbers t0,t1,t2,a2,a4."""
    loop, skip = a.uniq("am"), a.uniq("am_s")
    a.li(a.a3, 0)                        # best idx
    a.li(a.a4, y_addr)
    a.lw(a.t2, a.a4, 0)                  # best val
    a.li(a.t0, 1)                        # i
    a.label(loop)
    a.slli(a.t1, a.t0, 2)
    a.add(a.t1, a.t1, a.a4)
    a.lw(a.a2, a.t1, 0)
    a.bge(a.t2, a.a2, skip)              # best >= y[i] -> keep
    a.mv(a.a3, a.t0)
    a.mv(a.t2, a.a2)
    a.label(skip)
    a.addi(a.t0, a.t0, 1)
    a.li(a.t1, n)
    a.blt(a.t0, a.t1, loop)


def pack_tree(nodes):
    """nodes: list of (feat, thresh, left, right); leaves are encoded as
    ~value (negative). Returns flat int32 table (4 words per node)."""
    flat = []
    for f, t, l, r in nodes:
        flat += [f, t, l, r]
    return np.asarray(flat, np.int32)


def emit_tree_walk(a: Asm, *, table_off: int, x_addr: int, depth: int = 3):
    """Walk one packed tree; leaf value (small int) left in a3.

    next = (x[feat] <= thresh) ? left : right; negative next = ~leaf.
    `depth` bounds the internal levels of the packed table (every
    FlexiBench tree is 3 deep) — the walk is data-dependent, so the
    FlexiLint WCET needs the bound as an annotation (DESIGN.md §9.11).
    """
    loop, right, done = a.uniq("tw"), a.uniq("tw_r"), a.uniq("tw_d")
    a.li(a.a3, 0)                        # node idx
    a.loop_bound(loop, depth)
    a.label(loop)
    a.la_const(a.t0, table_off)
    a.slli(a.t1, a.a3, 4)                # node * 16 bytes
    a.add(a.t0, a.t0, a.t1)
    a.lw(a.t1, a.t0, 0)                  # feat
    a.slli(a.t1, a.t1, 2)
    a.li(a.a4, x_addr)
    a.add(a.t1, a.t1, a.a4)
    a.lw(a.t2, a.t1, 0)                  # x[feat]
    a.lw(a.a2, a.t0, 4)                  # thresh
    a.blt(a.a2, a.t2, right)             # thresh < x -> right
    a.lw(a.a3, a.t0, 8)                  # left
    a.j(loop + "_chk")
    a.label(right)
    a.lw(a.a3, a.t0, 12)                 # right
    a.label(loop + "_chk")
    a.bge(a.a3, a.zero, loop)
    a.xori(a.a3, a.a3, -1)               # leaf = ~next
    a.label(done)


def tree_walk_ref(table, x):
    """Reference for emit_tree_walk. table: flat int32; x: (features,)."""
    node = 0
    while node >= 0:
        f, t, l, r = (int(table[4 * node + k]) for k in range(4))
        node = l if int(x[f]) <= t else r
    return np.int32(~node)


def emit_popcount(a: Asm):
    """Routine __popcnt: a0 <- popcount(a0). Clobbers t0, t1."""
    a.label("__popcnt")
    a.mv(a.t0, a.a0)
    a.li(a.a0, 0)
    loop, done = "__pc_loop", "__pc_done"
    # one iteration per set bit + the final zero test
    a.loop_bound(loop, 33)
    a.label(loop)
    a.beq(a.t0, a.zero, done)
    a.addi(a.t1, a.t0, -1)
    a.and_(a.t0, a.t0, a.t1)
    a.addi(a.a0, a.a0, 1)
    a.j(loop)
    a.label(done)
    a.ret()
