"""Food-spoilage algorithm variants for the accuracy-vs-carbon Pareto
(paper §6.3, Fig. 6): LR, DT-Small, DT-Large, KNN-Small, KNN-Large, MLP.

The synthetic e-nose generative model is heteroscedastic (per-class noise
scale), so the nearest-mean LR is *not* Bayes-optimal and a large KNN can
edge it out in accuracy at far higher compute — reproducing the paper's
"similar accuracy (98.9% vs 98.2%), 14.5x more carbon" trade-off.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List

import numpy as np

from repro.flexibench import builders as B
from repro.flexibench.workloads import _fs_model
from repro.flexibits.asm import Asm

N_FEAT, N_CLS = 10, 4
_, _, MEANS = _fs_model()
CLASS_SIGMA = np.array([260.0, 300.0, 340.0, 400.0])
MODE_BOOST = 900.0   # class-3 "spoiled": two disjoint spoilage pathways


def gen_dataset(rng: np.random.Generator, n: int):
    """Heteroscedastic + disjunctive e-nose model: class 3 is a two-mode
    mixture (early-VOC vs late-VOC spoilage pathway), which caps linear
    models at ~98.2% while local methods reach ~99% (paper Fig. 6)."""
    cls = rng.integers(0, N_CLS, n)
    x = MEANS[cls].copy()
    m3 = cls == 3
    x[m3] = MEANS[2][None, :].repeat(m3.sum(), 0)
    boost = np.zeros((int(m3.sum()), N_FEAT))
    sel = rng.integers(0, 2, int(m3.sum())) == 0
    boost[sel, :5] = MODE_BOOST
    boost[~sel, 5:] = MODE_BOOST
    x[m3] += boost
    x = x + rng.normal(0, 1, (n, N_FEAT)) * CLASS_SIGMA[cls][:, None]
    return np.clip(np.round(x), 0, 4000).astype(np.int32), cls.astype(
        np.int32)


def _train_sample():
    rng = np.random.default_rng(5)
    return gen_dataset(rng, 2000)


def _trained_lr():
    Xtr, ytr = _train_sample()
    mus = np.stack([Xtr[ytr == c].mean(0) for c in range(N_CLS)])
    W = np.round((mus - mus.mean(0)) / 8).astype(np.int32)
    b = np.round(-(mus * mus).sum(1) / 16).astype(np.int64).astype(np.int32)
    return W, b, mus


@dataclasses.dataclass
class Algo:
    name: str
    program: "object"
    ref: Callable[[np.ndarray], np.ndarray]
    out_addr: int
    mem_words: int
    max_steps: int
    vm_reserved_bytes: int


def _finish(a: Asm, name, ref, out, mem_words, max_steps):
    return Algo(name=name, program=a.assemble(), ref=ref, out_addr=out,
                mem_words=mem_words, max_steps=max_steps,
                vm_reserved_bytes=a._vm_reserved)


def build_lr() -> Algo:
    W, b, _ = _trained_lr()
    y_addr_w = N_FEAT + 2
    out = y_addr_w + N_CLS
    a = Asm(vm_reserved=4 * (out + 2))
    w_off = a.const_words(W.reshape(-1))
    b_off = a.const_words(b)
    B.emit_matvec(a, w_off=w_off, b_off=b_off, x_addr=0,
                  y_addr=4 * y_addr_w, rows=N_CLS, cols=N_FEAT, shift=8,
                  relu=False)
    B.emit_argmax(a, y_addr=4 * y_addr_w, n=N_CLS)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()

    def ref(x):
        y = B.matvec_ref(W, b, x, 8, False)
        return np.argmax(y, -1).astype(np.int32)

    return _finish(a, "LR", ref, out, 256, 500_000)


def _tree_for(feat_idx: int):
    """Depth-2 complete tree on one feature, thresholds at class midpoints."""
    _, _, mus = _trained_lr()
    mids = ((mus[:-1, feat_idx] + mus[1:, feat_idx]) / 2).astype(int)
    nodes = [
        (feat_idx, int(mids[1]), 1, 2),
        (feat_idx, int(mids[0]), ~0, ~1),
        (feat_idx, int(mids[2]), ~2, ~3),
    ]
    return B.pack_tree(nodes)


def build_dt(n_trees: int, name: str) -> Algo:
    feats = list(range(N_FEAT))[-n_trees:]       # highest-scale features
    tables = [_tree_for(f) for f in feats]
    votes_w = N_FEAT + 1                          # 4 vote counters
    out = votes_w + N_CLS
    a = Asm(vm_reserved=4 * (out + 2))
    offs = [a.const_words(t) for t in tables]
    for k in range(N_CLS):
        a.sw(a.zero, a.zero, 4 * (votes_w + k))
    for off in offs:
        B.emit_tree_walk(a, table_off=off, x_addr=0)
        # votes[leaf]++
        a.slli(a.t0, a.a3, 2)
        a.addi(a.t0, a.t0, 4 * votes_w)
        a.lw(a.t1, a.t0, 0)
        a.addi(a.t1, a.t1, 1)
        a.sw(a.t1, a.t0, 0)
    B.emit_argmax(a, y_addr=4 * votes_w, n=N_CLS)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()

    def ref(x):
        x = np.atleast_2d(x)
        votes = np.zeros((len(x), N_CLS), np.int32)
        for i, row in enumerate(x):
            for t in tables:
                votes[i, int(B.tree_walk_ref(t, row))] += 1
        return np.argmax(votes, -1).astype(np.int32)

    return _finish(a, name, ref, out, 256, 200_000)


def build_knn(n_refs: int, name: str, seed: int = 41) -> Algo:
    rng = np.random.default_rng(seed)
    rx, ry = gen_dataset(rng, n_refs)
    table = np.concatenate([rx, ry[:, None]], -1).astype(np.int32)  # (n,11)
    stride = N_FEAT + 1
    # globals: best3 dist (w), best3 label, vote counters
    g = N_FEAT + 1
    best_d, best_l = g, g + 3
    votes_w = g + 6
    out = votes_w + N_CLS
    a = Asm(vm_reserved=4 * (out + 2))
    r_off = a.const_words(table.reshape(-1))
    big = 0x7FFFFFFF
    for k in range(3):
        a.li(a.t0, big)
        a.sw(a.t0, a.zero, 4 * (best_d + k))
        a.sw(a.zero, a.zero, 4 * (best_l + k))
    loop = a.uniq("knn")
    a.li(a.s0, 0)
    a.label(loop)
    # s1 = &table[s0 * stride]
    a.la_const(a.s1, r_off)
    a.li(a.t0, 4 * stride)
    a.mv(a.a0, a.s0)
    a.mv(a.a1, a.t0)
    a.call("__mul")
    a.add(a.s1, a.s1, a.a0)
    # dist = sum_f (x[f]-ref[f])^2  -> accumulate in RAM scratch g-1? use a2
    a.li(a.a2, 0)
    for f in range(N_FEAT):
        a.lw(a.a0, a.zero, 4 * f)
        a.lw(a.t0, a.s1, 4 * f)
        a.sub(a.a0, a.a0, a.t0)
        a.mv(a.a1, a.a0)
        a.sw(a.a2, a.zero, 4 * (g - 1))      # save acc across __mul
        a.call("__mul")
        a.lw(a.a2, a.zero, 4 * (g - 1))
        a.add(a.a2, a.a2, a.a0)
    a.lw(a.a3, a.s1, 4 * N_FEAT)             # label
    for k in range(3):
        nxt = a.uniq(f"knn_i{k}")
        a.lw(a.t0, a.zero, 4 * (best_d + k))
        a.bge(a.a2, a.t0, nxt)
        for j in range(2, k, -1):
            a.lw(a.t1, a.zero, 4 * (best_d + j - 1))
            a.sw(a.t1, a.zero, 4 * (best_d + j))
            a.lw(a.t1, a.zero, 4 * (best_l + j - 1))
            a.sw(a.t1, a.zero, 4 * (best_l + j))
        a.sw(a.a2, a.zero, 4 * (best_d + k))
        a.sw(a.a3, a.zero, 4 * (best_l + k))
        a.j(f"__knn_ins_done_{k}_{name}")
        a.label(nxt)
    for k in range(3):
        a.label(f"__knn_ins_done_{k}_{name}")
    a.addi(a.s0, a.s0, 1)
    a.li(a.t0, n_refs)
    a.blt(a.s0, a.t0, loop)
    # vote
    for k in range(N_CLS):
        a.sw(a.zero, a.zero, 4 * (votes_w + k))
    for k in range(3):
        a.lw(a.t0, a.zero, 4 * (best_l + k))
        a.slli(a.t0, a.t0, 2)
        a.addi(a.t0, a.t0, 4 * votes_w)
        a.lw(a.t1, a.t0, 0)
        a.addi(a.t1, a.t1, 1)
        a.sw(a.t1, a.t0, 0)
    B.emit_argmax(a, y_addr=4 * votes_w, n=N_CLS)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()

    def ref(x):
        x = np.atleast_2d(x).astype(np.int64)
        d = ((x[:, None, :] - rx[None].astype(np.int64)) ** 2).sum(-1)
        idx = np.argsort(d, axis=1, kind="stable")[:, :3]
        lab = ry[idx]
        votes = np.zeros((len(x), N_CLS), np.int64)
        for k in range(3):
            np.add.at(votes, (np.arange(len(x)), lab[:, k]), 1)
        return np.argmax(votes, -1).astype(np.int32)

    return _finish(a, name, ref, out, 512, 30_000_000)


def build_mlp() -> Algo:
    rng = np.random.default_rng(43)
    Xtr, ytr = _train_sample()
    mus = np.stack([Xtr[ytr == c].mean(0) for c in range(N_CLS)])
    # hidden layer: 6 discriminative directions (class contrasts + the two
    # class-3 pathway directions) + 6 random features, Q3
    dirs = [mus[c] - mus.mean(0) for c in range(N_CLS)]
    d3a = np.zeros(N_FEAT); d3a[:5] = MODE_BOOST
    d3b = np.zeros(N_FEAT); d3b[5:] = MODE_BOOST
    dirs += [d3a, d3b]
    P = np.stack(dirs + [rng.normal(0, 300, N_FEAT) for _ in range(6)])
    P = np.round(P / 64.0).astype(np.int32)              # (12, 10)
    b1 = np.zeros(12, np.int32)
    htr = B.matvec_ref(P, b1, Xtr, 6, True)
    hmus = np.stack([htr[ytr == c].mean(0) for c in range(N_CLS)])
    Wc = hmus - hmus.mean(0)
    scale = 1.0 / max(1.0, np.abs(Wc).max() / 100.0)
    W2 = np.round(Wc * scale).astype(np.int32)
    # nearest-mean bias at the same scale: b_c = -s |hmu_c|^2 / 2
    b2 = np.round(-scale * (hmus * hmus).sum(1) / 2).astype(np.int64) \
        .astype(np.int32)
    buf = N_FEAT
    y_addr_w = buf + 12
    out = y_addr_w + N_CLS
    a = Asm(vm_reserved=4 * (out + 2))
    p_off = a.const_words(P.reshape(-1))
    pb_off = a.const_words(b1)
    w2_off = a.const_words(W2.reshape(-1))
    b2_off = a.const_words(b2)
    B.emit_matvec(a, w_off=p_off, b_off=pb_off, x_addr=0, y_addr=4 * buf,
                  rows=12, cols=N_FEAT, shift=6, relu=True)
    B.emit_matvec(a, w_off=w2_off, b_off=b2_off, x_addr=4 * buf,
                  y_addr=4 * y_addr_w, rows=N_CLS, cols=12, shift=6,
                  relu=False)
    B.emit_argmax(a, y_addr=4 * y_addr_w, n=N_CLS)
    a.sw(a.a3, a.zero, 4 * out)
    a.halt()
    a.emit_mul_routine()

    def ref(x):
        h = B.matvec_ref(P, b1, x, 6, True)
        y = B.matvec_ref(W2, b2, h, 6, False)
        return np.argmax(y, -1).astype(np.int32)

    return _finish(a, "MLP", ref, out, 256, 2_000_000)


def all_algos() -> List[Algo]:
    return [
        build_lr(),
        build_dt(1, "DT-Small"),
        build_dt(5, "DT-Large"),
        build_knn(60, "KNN-Small", seed=41),
        build_knn(1500, "KNN-Large", seed=42),
        build_mlp(),
    ]
