"""NVM/VM memory profiler (paper Table 3 + §A.2 methodology analogue).

NVM = program words + read-only constant words (the paper's .text +
.rodata). VM = reserved input/global RAM + measured peak stack. Our
workloads are stack-free (leaf routines use registers), so VM is the
reserved image + the high-water mark of RAM words the ISS actually wrote.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.flexibench.base import Workload
from repro.flexibits.pyiss import PyISS


def profile_memory(w: Workload, n_samples: int = 3,
                   seed: int = 0) -> Dict[str, float]:
    rng = np.random.default_rng(seed)
    xs = w.gen_inputs(rng, n_samples)
    ro_start = w.program.ro_base // 4
    hi_water = 0
    for x in xs:
        mem0 = w.initial_memory(x)
        sim = PyISS(w.program.code, w.total_mem_words, mem0)
        sim.run(w.max_steps)
        # VM high-water: highest RAM word (below the ROM segment) that
        # differs from the initial image or was an input/global
        writable = np.nonzero(
            (sim.mem[:ro_start] != mem0[:ro_start])
        )[0]
        hw = int(writable.max()) + 1 if len(writable) else w.n_inputs
        hi_water = max(hi_water, hw, w.n_inputs + 1)
    return {
        "nvm_kb": w.program.nvm_bytes / 1024.0,
        "vm_kb": 4.0 * hi_water / 1024.0,
        "code_words": int(len(w.program.code)),
        "const_words": int(len(w.program.ro_words)),
    }
