"""Mesh context: logical-axis activation sharding that no-ops off-mesh.

Models call ``shard_act(x, 'batch', None, 'model')`` with *logical* axis
names. When a mesh context is installed (by dryrun/train/serve), logical axes
resolve to physical mesh axes and a ``with_sharding_constraint`` is applied;
in single-device unit tests it is a no-op, so model code is mesh-agnostic.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _rules() -> dict:
    return getattr(_state, "rules", None)


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def mesh_context(mesh: Mesh):
    """Install `mesh` and a logical->physical axis mapping derived from it.

    - 'batch'  -> ('pod','data') if the mesh has a pod axis, else ('data',)
    - 'model'  -> ('model',)
    - 'data'   -> ('data',)
    """
    axis_names = mesh.axis_names
    rules = {"model": ("model",), "data": ("data",)}
    rules["batch"] = (("pod", "data") if "pod" in axis_names else ("data",))
    prev = (getattr(_state, "mesh", None), getattr(_state, "rules", None))
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def logical_to_spec(axes: Tuple[Optional[str], ...]) -> P:
    rules = _rules()
    out = []
    for a in axes:
        if a is None:
            out.append(None)
        else:
            phys = rules[a]
            out.append(phys[0] if len(phys) == 1 else phys)
    return P(*out)


def batch_axes() -> Tuple[str, ...]:
    """Physical axis names the batch dimension shards over."""
    rules = _rules()
    return rules["batch"] if rules else ("data",)


def shard_act(x, *axes):
    """Constrain activation sharding by logical axes; no-op without a mesh.

    Divisibility-aware: an axis whose dim doesn't divide by the mesh axes'
    product is dropped (replicated) instead of forcing padded sharding,
    which triggers XLA's 'involuntary full rematerialization' path.
    """
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = list(logical_to_spec(axes))
    spec += [None] * (x.ndim - len(spec))
    for i, a in enumerate(spec):
        if a is None:
            continue
        names = a if isinstance(a, tuple) else (a,)
        size = 1
        for nm in names:
            size *= mesh.shape[nm]
        if x.shape[i] % size != 0 or x.shape[i] == 0:
            spec[i] = None
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))
