"""Fault-tolerant checkpointing: atomic write-temp-then-rename, keep-N,
auto-resume. Pytrees are flattened to named .npy entries inside an .npz;
restore reshards onto whatever mesh/shardings the restart supplies (the
elastic path — see elastic.py and tests/test_fault_tolerance.py).

Integrity (DESIGN.md §9.14): every leaf's bytes are CRC32-summed at save
time into meta.json; restore verifies each leaf and raises
`CheckpointCorrupt` naming the file and leaf on any mismatch (npz members
are STORED uncompressed, so a silent bit-flip loads cleanly — only the
checksum catches it). Auto-resume (`step=None`) walks checkpoints newest
first and restores the newest *intact* one, so one torn or flipped write
never strands a resumable stream."""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import zlib
from typing import Any, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


class CheckpointCorrupt(RuntimeError):
    """A checkpoint failed integrity verification: truncated archive,
    unreadable metadata, or a leaf whose bytes no longer match the CRC32
    recorded at save time. Carries the offending `path` and, for
    leaf-level damage, the flattened `leaf` key."""

    def __init__(self, path: str, leaf: Optional[str] = None,
                 detail: str = ""):
        self.path = path
        self.leaf = leaf
        where = path + (f", leaf {leaf!r}" if leaf else "")
        super().__init__(f"corrupt checkpoint: {where}"
                         + (f" ({detail})" if detail else ""))


import ml_dtypes

# extended dtypes numpy can't serialize natively: store a bit-identical
# integer view + the dtype name in meta.json
_EXT_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
               "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
               "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _encode(flat: dict):
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        name = v.dtype.name
        if name in _EXT_DTYPES:
            arrays[k] = v.view(_EXT_DTYPES[name][1])
            dtypes[k] = name
        else:
            arrays[k] = v
    return arrays, dtypes


def _decode(arr: np.ndarray, key: str, dtypes: dict) -> np.ndarray:
    name = dtypes.get(key)
    if name:
        return arr.view(_EXT_DTYPES[name][0])
    return arr


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomic checkpoint save; prunes to the newest `keep` steps."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = _encode(flat)
    crcs = {k: zlib.crc32(np.ascontiguousarray(v).tobytes())
            for k, v in arrays.items()}
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_arrays": len(flat),
                       "ext_dtypes": dtypes, "crc32": crcs}, f)
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                 # atomic on same filesystem
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def verify(ckpt_dir: str, step: int) -> Tuple[dict, dict]:
    """Load one checkpoint fully into memory and verify every leaf's
    CRC32 against meta.json. Returns `(arrays, ext_dtypes)`; raises
    `CheckpointCorrupt` (naming file + leaf) on truncation, unreadable
    metadata, a missing leaf, or a byte-level mismatch. Checkpoints
    written before the checksum field restore unverified."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    meta_path = os.path.join(d, "meta.json")
    npz_path = os.path.join(d, "arrays.npz")
    try:
        with open(meta_path) as f:
            meta = json.load(f)
    except (OSError, ValueError) as e:
        raise CheckpointCorrupt(meta_path, detail=str(e)) from None
    try:
        data = np.load(npz_path)
    except Exception as e:       # zipfile/np errors on torn writes
        raise CheckpointCorrupt(npz_path, detail=str(e)) from None
    arrays = {}
    try:
        for k in list(data.files):
            try:                 # member-wise: zip-level CRC failures
                arrays[k] = data[k]     # get attributed to their leaf
            except Exception as e:
                raise CheckpointCorrupt(npz_path, leaf=k,
                                        detail=str(e)) from None
    finally:
        data.close()
    for key, want in meta.get("crc32", {}).items():
        if key not in arrays:
            raise CheckpointCorrupt(npz_path, leaf=key,
                                    detail="leaf missing from archive")
        got = zlib.crc32(np.ascontiguousarray(arrays[key]).tobytes())
        if got != want:
            raise CheckpointCorrupt(
                npz_path, leaf=key,
                detail=f"crc32 {got:#010x} != recorded {want:#010x}")
    return arrays, meta.get("ext_dtypes", {})


def restore(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of `tree_like`. With `shardings`
    (a matching pytree of NamedSharding), arrays are placed sharded —
    this is how an elastic restart reshards onto a different mesh.

    With `step=None` (auto-resume) the newest *intact* checkpoint wins:
    corrupt ones (failed `verify`) are skipped newest-first, and the
    last `CheckpointCorrupt` is re-raised only when every step is
    damaged. An explicit `step` never falls back — damage raises."""
    if step is None:
        steps = sorted(all_steps(ckpt_dir), reverse=True)
        if not steps:
            raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
        last_err: Optional[CheckpointCorrupt] = None
        for s in steps:
            try:
                data, dtypes = verify(ckpt_dir, s)
                step = s
                break
            except CheckpointCorrupt as e:
                last_err = e
        else:
            raise last_err
    else:
        data, dtypes = verify(ckpt_dir, step)
    flat_keys = list(_flatten(tree_like))
    assert set(flat_keys) == set(data), (
        "checkpoint/tree structure mismatch:",
        set(flat_keys) ^ set(data))
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    treedef = leaves_paths[1]
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves_paths[0]))
    new_leaves = []
    for (path, leaf), sh in zip(leaves_paths[0], sh_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = _decode(data[key], key, dtypes)
        if sh is not None:
            new_leaves.append(jax.device_put(arr, sh))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, new_leaves), step
