"""Fault-tolerant checkpointing: atomic write-temp-then-rename, keep-N,
auto-resume. Pytrees are flattened to named .npy entries inside an .npz;
restore reshards onto whatever mesh/shardings the restart supplies (the
elastic path — see elastic.py and tests/test_fault_tolerance.py)."""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


import ml_dtypes

# extended dtypes numpy can't serialize natively: store a bit-identical
# integer view + the dtype name in meta.json
_EXT_DTYPES = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
               "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
               "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _encode(flat: dict):
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        name = v.dtype.name
        if name in _EXT_DTYPES:
            arrays[k] = v.view(_EXT_DTYPES[name][1])
            dtypes[k] = name
        else:
            arrays[k] = v
    return arrays, dtypes


def _decode(arr: np.ndarray, key: str, dtypes: dict) -> np.ndarray:
    name = dtypes.get(key)
    if name:
        return arr.view(_EXT_DTYPES[name][0])
    return arr


def save(ckpt_dir: str, step: int, tree: Any, *, keep: int = 3) -> str:
    """Atomic checkpoint save; prunes to the newest `keep` steps."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    arrays, dtypes = _encode(flat)
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump({"step": step, "n_arrays": len(flat),
                       "ext_dtypes": dtypes}, f)
        final = os.path.join(ckpt_dir, f"step_{step}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                 # atomic on same filesystem
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = _STEP_RE.match(name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, int]:
    """Restore into the structure of `tree_like`. With `shardings`
    (a matching pytree of NamedSharding), arrays are placed sharded —
    this is how an elastic restart reshards onto a different mesh."""
    if step is None:
        step = latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    data = np.load(os.path.join(ckpt_dir, f"step_{step}", "arrays.npz"))
    with open(os.path.join(ckpt_dir, f"step_{step}", "meta.json")) as f:
        dtypes = json.load(f).get("ext_dtypes", {})
    flat_keys = list(_flatten(tree_like))
    assert set(flat_keys) == set(data.files), (
        "checkpoint/tree structure mismatch:",
        set(flat_keys) ^ set(data.files))
    leaves_paths = jax.tree_util.tree_flatten_with_path(tree_like)
    treedef = leaves_paths[1]
    sh_leaves = (jax.tree.leaves(shardings) if shardings is not None
                 else [None] * len(leaves_paths[0]))
    new_leaves = []
    for (path, leaf), sh in zip(leaves_paths[0], sh_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = _decode(data[key], key, dtypes)
        if sh is not None:
            new_leaves.append(jax.device_put(arr, sh))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, new_leaves), step
