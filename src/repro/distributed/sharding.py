"""Parameter/batch/cache sharding rules.

Rules are *name + shape* driven and divisibility-aware: the preferred dim is
sharded over `model` only when divisible by the mesh's model-axis size,
otherwise fallbacks apply (e.g. GQA with 2 KV heads on a 16-way model axis
shards the contracting d_model dim instead — Megatron row-parallel).

Batch dims shard over ('pod','data') when the mesh has a pod axis.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def abstract_mesh(axis_names: Tuple[str, ...],
                  axis_sizes: Tuple[int, ...]):
    """Device-free mesh for shape-only sharding checks.

    jax.sharding.AbstractMesh changed signature across jax releases
    ((name, size) pairs vs separate sizes/names tuples); accept both so
    the divisibility rules below can be exercised without real devices.
    """
    try:
        return jax.sharding.AbstractMesh(
            tuple(zip(axis_names, axis_sizes)))
    except TypeError:
        return jax.sharding.AbstractMesh(
            tuple(axis_sizes), tuple(axis_names))


def _model_axis_size(mesh: Mesh) -> int:
    return mesh.shape["model"]


def batch_axes(mesh: Mesh):
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def lane_specs(mesh: Mesh, state):
    """Fleet-lane layout: dim 0 of every leaf over EVERY mesh axis.

    The ISS fleet engine is pure data parallelism — each lane is an
    independent item — so the lane pool flattens the whole mesh
    (data x model x pod alike) into one device axis. Used both for
    device_put layouts and as shard_map in/out specs (fleet/engine.py)
    — the same specs serve every segment stepper, including the fused
    Pallas kernel, whose lane-tile grid runs inside each device's shard
    (DESIGN.md §9.7). The packed fleet runtime's per-lane fields
    (`iss.PackedState.prog_id` / `.max_steps`, §9.8) are ordinary lane
    leaves — dim 0 is the lane axis — so the same rule shards them with
    no special casing; only the program bank is different (see
    `bank_specs`).
    """
    axes = tuple(mesh.axis_names)

    def one(leaf):
        return P(axes, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(one, state)


def bank_specs(mesh: Mesh, tree):
    """Program-bank layout: replicate every leaf on every device.

    The packed runtime's bank (padded program rows) and per-program
    code-length vector are read by EVERY lane every step — sharding them
    would put a collective inside the segment while_loop, where the
    whole engine design is zero-collective data parallelism (DESIGN.md
    §9.6/§9.8). Banks are tiny (programs x words), so replication is
    free; used as shard_map in_specs alongside `lane_specs`.
    """
    return jax.tree.map(lambda _: P(), tree)


def stage_specs(mesh: Mesh, tree):
    """Staged-refill-buffer layout: dim 0 — the SHARD axis — over every
    mesh axis.

    The resident fleet runtime (DESIGN.md §9.9/§9.12) stages each
    shard's next refill batch as its own slice of a
    `(n_shards, spc, ...)` buffer: the item->shard partition
    (`engine.shard_partition`) fixes which shard admits which items, so
    the on-device refill assigns staged rows to freed lanes by
    SHARD-LOCAL rank and no lane ever consumes another shard's row.
    Each device therefore receives only its own `(spc, ...)` slice —
    staging H2D bytes stay O(chunk) total instead of O(chunk x devices)
    under the old replicated layout — and both the swap and the result
    scatter inside the refill op stay collective-free (pinned by
    tests/test_shard_local.py's HLO audit).
    """
    axes = tuple(mesh.axis_names)

    def one(leaf):
        return P(axes, *([None] * (leaf.ndim - 1)))
    return jax.tree.map(one, tree)


def stage_shardings(mesh: Mesh, tree):
    """NamedShardings for `stage_specs` (device_put-ready)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        stage_specs(mesh, tree))


def lane_shardings(mesh: Mesh, state):
    """NamedShardings for `lane_specs` (device_put-ready)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        lane_specs(mesh, state))


# Priority lists of (dim, description) per parameter name. Dims are python
# indices into the *unstacked* trailing shape (negative = from the end).
_RULES = {
    # embeddings / heads
    "embed": [-2],          # (V, D): shard vocab
    "lm_head": [-1],        # (D, V): shard vocab
    # attention
    "wq": [-2, -3],         # (D, H, Dh): heads, else contracting D
    "wk": [-2, -3],
    "wv": [-2, -3],
    "wo": [-3, -2],         # (H, Dh, D): heads, else Dh (both contracting)
    "bq": [-2], "bk": [-2], "bv": [-2],
    # dense mlp
    "wi": [-1], "wg": [-1],     # (D, F): shard F
    # MLA
    "w_dq": [-1], "w_uq": [-2, -3], "w_dkv": [], "w_kr": [],
    "w_uk": [-2, -3], "w_uv": [-2, -3],
    # moe (E, D, F) handled specially by name prefix 'moe/'
    "router": [],
    # mamba
    "wz": [-1], "wx": [-1], "wdt": [-1], "wB": [], "wC": [],
    "conv_x": [-1], "conv_bx": [-1],
    "conv_B": [], "conv_C": [], "conv_bB": [], "conv_bC": [],
    "A_log": [-1], "dt_bias": [-1], "D": [-1], "norm_w": [-1],
    "out_proj": [-2],       # (d_inner, D): contracting
    # mtp
    "proj": [],
    # adafactor factored moments (see opt_shardings)
    "r": [-2, -1], "c": [-2, -1],
}

# Names whose *parent* dict distinguishes semantics.
_MLP_WO = {"wo"}


def _leaf_name(path) -> Tuple[str, Tuple[str, ...]]:
    keys = tuple(p.key for p in path if hasattr(p, "key"))
    return keys[-1], keys


def spec_for_param(path, shape, mesh: Mesh) -> P:
    msize = _model_axis_size(mesh)
    name, keys = _leaf_name(path)
    ndim = len(shape)
    spec = [None] * ndim

    def try_dims(dims) -> Optional[int]:
        for d in dims:
            dd = d % ndim if d < 0 else d
            if 0 <= dd < ndim and shape[dd] % msize == 0 and shape[dd] > 1:
                return dd
        return None

    in_moe = any(k in ("moe", "wi_e", "wg_e", "wo_e") for k in keys) and \
        name in ("wi", "wg", "wo")
    in_mlp = "mlp" in keys or "shared" in keys

    if in_moe:
        # (L?, E, D, F) for wi/wg ; (L?, E, F, D) for wo — prefer EP on E
        e_dim = ndim - 3
        if shape[e_dim] % msize == 0:
            spec[e_dim] = "model"
            return P(*spec)
        f_dim = ndim - 1 if name in ("wi", "wg") else ndim - 2
        if shape[f_dim] % msize == 0:
            spec[f_dim] = "model"
        return P(*spec)

    if name == "wo" and in_mlp:
        # dense mlp wo: (F, D) — shard contracting F
        d = try_dims([-2])
        if d is not None:
            spec[d] = "model"
        return P(*spec)

    dims = _RULES.get(name)
    if dims is None:
        return P(*spec)             # replicate unknown/small params
    d = try_dims(dims)
    if d is not None:
        spec[d] = "model"
    return P(*spec)


def param_shardings(abstract_params, mesh: Mesh):
    """Pytree of NamedShardings matching `abstract_params`."""
    def one(path, leaf):
        return NamedSharding(mesh, spec_for_param(path, leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(one, abstract_params)


def opt_shardings(abstract_opt, mesh: Mesh, *, zero1: bool = False):
    """Shardings for optimizer state.

    m/v/master mirror their parameters (path-name rules apply since leaf
    names match). Adafactor r/c shard their largest divisible dim. With
    `zero1`, moment leaves additionally shard dim 0 (the stacked-layers dim)
    over 'data' — ZeRO-1 style optimizer-state partitioning.
    """
    dsize = mesh.shape["data"]

    def one(path, leaf):
        spec = spec_for_param(path, leaf.shape, mesh)
        keys = tuple(p.key for p in path if hasattr(p, "key"))
        if zero1 and keys and keys[0] in ("m", "v", "vs", "master"):
            lst = list(spec) + [None] * (len(leaf.shape) - len(spec))
            if (leaf.shape and lst[0] is None and leaf.shape[0] > 1
                    and leaf.shape[0] % dsize == 0):
                lst[0] = "data"
                spec = P(*lst)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, abstract_opt)


def batch_shardings(batch_specs, mesh: Mesh):
    """Shard dim 0 (batch) over ('pod','data'); replicate when indivisible
    (e.g. long_500k batch=1); scalars replicated."""
    baxes = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))

    def one(leaf):
        if not leaf.shape:
            return NamedSharding(mesh, P())
        if leaf.shape[0] % bsize == 0:
            return NamedSharding(mesh,
                                 P(baxes if len(baxes) > 1 else baxes[0],
                                   *([None] * (len(leaf.shape) - 1))))
        return NamedSharding(mesh, P(*([None] * len(leaf.shape))))
    return jax.tree.map(one, batch_specs)


def cache_shardings(cache_specs, mesh: Mesh):
    """Decode-state shardings, name-aware.

    Attention KV caches (L?, B, S, H, Dh): batch over data axes; heads over
    `model` when divisible, else the SEQUENCE dim (flash-decode style
    partial-softmax sharding). Never the contracting head_dim — that was
    the §Perf minitron-decode bug (35 GB of per-token all-gathers).
    MLA latent caches (L, B, S, R): sequence over model (R contracts).
    SSM states: heads/channels over model.
    """
    baxes = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in baxes]))
    msize = _model_axis_size(mesh)

    def one(path, leaf):
        name, _ = _leaf_name(path)
        shape = leaf.shape
        nd = len(shape)
        spec = [None] * nd
        if nd < 3:
            return NamedSharding(mesh, P(*spec))
        bdim = 1  # all our cache leaves are stacked (L, B, ...)
        if shape[bdim] % bsize == 0 and shape[bdim] > 1:
            spec[bdim] = baxes if len(baxes) > 1 else baxes[0]

        def try_model(dims):
            for d in dims:
                dd = d % nd
                if (spec[dd] is None and shape[dd] > 1
                        and shape[dd] % msize == 0):
                    spec[dd] = "model"
                    return True
            return False

        if name in ("c_kv", "k_rope"):
            try_model([2])                       # MLA: sequence dim
        elif name == "ssm":
            try_model([-3])                      # (L,B,H,N,P): heads
        elif name.startswith("conv"):
            try_model([-1])                      # channels
        else:                                    # attention k/v caches
            try_model([-2, 2])                   # heads, else sequence
        return NamedSharding(mesh, P(*spec))
    return jax.tree_util.tree_map_with_path(one, cache_specs)
