"""Elastic restart: resume a checkpoint on a *different* mesh.

The checkpoint stores host numpy arrays (mesh-agnostic); `resume_elastic`
rebuilds shardings for the new mesh from the same name/shape rules and
device_puts each leaf — so scaling from N to M pods (or degraded pods) is a
restore, not a migration. The data pipeline's (step, host)-deterministic
addressing keeps the global batch identical across topologies
(data/pipeline.py), which tests assert bit-for-bit.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import opt_shardings, param_shardings


def resume_elastic(ckpt_dir: str, model, opt_init, new_mesh, *,
                   zero1: bool = False,
                   step: Optional[int] = None) -> Tuple[Any, Any, int]:
    """Returns (params, opt_state, step) resharded onto `new_mesh`."""
    params_abs = model.abstract_params()
    opt_abs = jax.eval_shape(opt_init, params_abs)
    p_sh = param_shardings(params_abs, new_mesh)
    o_sh = opt_shardings(opt_abs, new_mesh, zero1=zero1)
    state, got_step = ckpt.restore(
        ckpt_dir, {"params": params_abs, "opt": opt_abs}, step=step,
        shardings={"params": p_sh, "opt": o_sh})
    return state["params"], state["opt"], got_step
