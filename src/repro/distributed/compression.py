"""int8 error-feedback gradient all-reduce (opt-in).

At 1000+ nodes the DP gradient all-reduce dominates the collective term for
small models; quantizing to int8 with per-tensor scales cuts its bytes 4x
vs fp32 (2x vs bf16). The residual (quantization error) is fed back into
the next step's gradient — the standard EF-SGD trick that restores exact
convergence in expectation.

Implemented with shard_map + psum so the quantized representation is what
actually crosses the mesh; `compressed_allreduce` is a drop-in for the
implicit pjit gradient reduction when the train step is shard_mapped.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def ef_quantize(grad, residual):
    """Error-feedback quantization: returns (q, scale, new_residual)."""
    g = grad.astype(jnp.float32) + residual
    q, scale = quantize_int8(g)
    new_residual = g - dequantize_int8(q, scale)
    return q, scale, new_residual


def compressed_allreduce(grads, residuals, mesh: Mesh, axis: str = "data"):
    """All-reduce `grads` over `axis` in int8 with error feedback.

    grads/residuals: pytrees of replicated-over-axis arrays (each device
    holds its local gradient). Returns (mean_grads, new_residuals).
    """
    def one(g, r):
        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(), P()), out_specs=(P(), P()),
            check_rep=False)
        def reduce_fn(g_local, r_local):
            q, scale, new_r = ef_quantize(g_local, r_local)
            # the int8 payload + fp32 scale are what cross the links
            summed = jax.lax.psum(q.astype(jnp.int32), axis)
            scale_sum = jax.lax.psum(scale, axis)
            n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
            # each participant contributed q*scale; with per-rank scales we
            # approximate by the mean scale (exactness restored by EF).
            mean = summed.astype(jnp.float32) * (scale_sum / n) / n
            return mean.astype(g_local.dtype), new_r
        return reduce_fn(g, r)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def init_residuals(grads_like):
    return jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
