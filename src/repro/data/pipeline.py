"""Deterministic sharded synthetic token pipeline.

Every (step, host) pair maps to a unique slice of an infinite deterministic
stream (hash-seeded), so (a) restarts resume exactly, (b) any host can
recompute any other host's shard (straggler/failure recovery), (c) the
global batch is identical regardless of host count — the elastic-restart
invariant tested in tests/test_fault_tolerance.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 1234


def _example(cfg: DataConfig, index: int) -> np.ndarray:
    """Deterministic pseudo-text: a seeded markov-ish integer stream."""
    rng = np.random.default_rng((cfg.seed, index))
    # zipf-ish marginal so the loss has structure
    z = rng.zipf(1.3, cfg.seq_len + 1) % cfg.vocab
    return z.astype(np.int32)


def global_batch_indices(cfg: DataConfig, step: int) -> np.ndarray:
    start = step * cfg.global_batch
    return np.arange(start, start + cfg.global_batch)


def host_batch(cfg: DataConfig, step: int, host_id: int = 0,
               n_hosts: int = 1) -> Dict[str, np.ndarray]:
    """The host's slice of the global batch for `step`."""
    idx = global_batch_indices(cfg, step)
    assert cfg.global_batch % n_hosts == 0
    per = cfg.global_batch // n_hosts
    mine = idx[host_id * per:(host_id + 1) * per]
    toks = np.stack([_example(cfg, int(i)) for i in mine])
    return {
        "tokens": toks[:, :-1],
        "targets": toks[:, 1:],
        "mask": np.ones((per, cfg.seq_len), np.float32),
    }


def stream(cfg: DataConfig, start_step: int = 0, host_id: int = 0,
           n_hosts: int = 1) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield host_batch(cfg, step, host_id, n_hosts)
        step += 1
