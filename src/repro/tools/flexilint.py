"""FlexiLint CLI: static analysis of FlexiBench programs (DESIGN.md §9.11).

Runs the `flexibits/analyze.py` binary analyzer over encoded FlexiBench
workloads — CFG recovery, def-use dataflow, memory-bounds proofs, and
WCET cycle certificates — and prints one lint report per program.

    PYTHONPATH=src python -m repro.tools.flexilint            # all 11
    PYTHONPATH=src python -m repro.tools.flexilint WQ HC      # a subset
    PYTHONPATH=src python -m repro.tools.flexilint --measure 3

Exit status is the CI contract: 0 when every analyzed program is free
of ERROR diagnostics, 1 otherwise (`--strict` also fails on warnings
and degraded CFGs). `--measure N` additionally executes each program
through the PyISS oracle on N generated inputs and cross-checks the
certificate: every retired word must lie in the static reachable set,
every retired mnemonic in the static subset, and measured ticks must
not exceed the WCET bound — a violation is a soundness bug and fails
the run regardless of flags.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

from repro.flexibench import base as fb
from repro.flexibits import analyze
from repro.flexibits.cycles import CORES, cost_row
from repro.flexibits.pyiss import PyISS


def _measure(w, a: analyze.Analysis, cost, n_inputs: int, seed: int):
    """PyISS cross-validation: returns (max_ticks, violations)."""
    rng = np.random.default_rng(seed)
    xs = w.gen_inputs(rng, n_inputs)
    max_ticks = 0
    violations = []
    for x in xs:
        sim = PyISS(w.program.code, mem_words=w.total_mem_words,
                    init_mem=w.initial_memory(x))
        sim.run(max_steps=w.max_steps)
        if not sim.halted:
            violations.append(f"did not halt within {w.max_steps} steps")
            continue
        stray = sim.visited - a.reachable
        if stray:
            violations.append(f"retired words outside static reachable "
                              f"set: {sorted(stray)[:8]}")
        names = set(sim.mix) - a.reachable_names
        if names:
            violations.append(f"retired mnemonics outside static "
                              f"subset: {sorted(names)}")
        if a.wcet_steps is not None and sim.n_instr > a.wcet_steps:
            violations.append(f"measured steps {sim.n_instr} > "
                              f"wcet-steps {a.wcet_steps}")
        if a.min_steps is not None and sim.n_instr < a.min_steps:
            violations.append(f"measured steps {sim.n_instr} < "
                              f"min-steps {a.min_steps}")
        ticks = sim.ticks(cost)
        w_ticks = a.wcet_ticks(cost)
        if w_ticks is not None and ticks > w_ticks:
            violations.append(f"measured ticks {ticks} > "
                              f"wcet-ticks {w_ticks}")
        max_ticks = max(max_ticks, ticks)
    return max_ticks, violations


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="flexilint",
        description="Static analysis & WCET certificates for FlexiBench "
                    "programs (DESIGN.md §9.11)")
    p.add_argument("workloads", nargs="*",
                   help="FlexiBench keys (default: all)")
    p.add_argument("--core", default="SERV", choices=sorted(CORES),
                   help="core whose cost row prices the WCET")
    p.add_argument("--timing", default="dynamic",
                   choices=("base", "dynamic"),
                   help="cost row flavor for the tick bound")
    p.add_argument("--measure", type=int, default=0, metavar="N",
                   help="cross-check via PyISS on N generated inputs")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--strict", action="store_true",
                   help="also fail on warnings and degraded CFGs")
    args = p.parse_args(argv)

    if args.workloads:
        try:
            wls = [fb.get(k) for k in args.workloads]
        except KeyError as e:
            p.error(f"unknown workload {e.args[0]!r}; known: "
                    + " ".join(w.key for w in fb.all_workloads()))
    else:
        wls = fb.all_workloads()

    cost = cost_row(CORES[args.core], dynamic=args.timing == "dynamic")
    failed = False
    for w in wls:
        t0 = time.perf_counter()
        a = analyze.analyze_workload(w)
        wall_ms = (time.perf_counter() - t0) * 1e3
        measured = None
        violations = []
        if args.measure > 0:
            measured, violations = _measure(w, a, cost, args.measure,
                                            args.seed)
        print(a.format_report(cost, measured_ticks=measured))
        for v in violations:
            print(f"  SOUNDNESS VIOLATION: {v}")
        print(f"  analysis wall time {wall_ms:.1f} ms "
              f"({args.core} {args.timing} cost row)")
        print()
        if a.errors or violations:
            failed = True
        if args.strict and (a.warnings or a.degraded is not None):
            failed = True

    n = len(wls)
    print(f"flexilint: {n} program(s) analyzed, "
          + ("FAIL" if failed else "ok"))
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
