"""Command-line tools built on the repro package (DESIGN.md §9.11)."""
