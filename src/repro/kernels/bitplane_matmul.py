"""Bit-plane matmul Pallas kernel — FLEXIBITS' bit-serial datapath adapted
to the TPU MXU (DESIGN.md §2.1).

SERV processes one bit per cycle on a 1-bit ALU; the MXU has no bit-serial
mode, so the TPU-native translation is *bit-plane decomposition*: weights
quantized to B bits are stored as B binary planes and the matmul runs
MXU-parallel within a plane, serial across planes:

    W_q in [-2^(B-1), 2^(B-1)-1]; U = W_q + 2^(B-1) = sum_b 2^b u_b
    x @ W = s * (sum_b 2^b (x @ u_b)  -  2^(B-1) * rowsum(x) * 1^T)

HBM traffic scales with B exactly as FLEXIBITS' energy scales with datapath
width — the knob the lifetime-aware planner selects on.

Grid: (M/TM, N/TN, K/TK), K innermost with an accumulator scratch in VMEM;
planes live in a (B, TK, TN) block. Tile defaults are MXU-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, planes_ref, scales_ref, o_ref, acc_ref, *, bits: int,
            n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...].astype(jnp.float32)              # (TM, TK)
    acc = jnp.zeros(acc_ref.shape, jnp.float32)
    for b in range(bits):
        plane = planes_ref[b, :, :].astype(jnp.float32)   # (TK, TN)
        acc += (2.0 ** b) * jax.lax.dot(
            x, plane, precision=jax.lax.Precision.DEFAULT,
            preferred_element_type=jnp.float32)
    # unsigned-offset correction: -2^(B-1) * rowsum(x) broadcast over N
    rowsum = jnp.sum(x, axis=1, keepdims=True)      # (TM, 1)
    acc -= (2.0 ** (bits - 1)) * rowsum
    acc_ref[...] += acc

    @pl.when(k_idx == n_k - 1)
    def _finish():
        scales = scales_ref[...].astype(jnp.float32)      # (TN,)
        o_ref[...] = (acc_ref[...] * scales[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bits", "tm", "tn", "tk", "interpret"))
def bitplane_matmul(x, planes, scales, *, bits: int, tm: int = 128,
                    tn: int = 128, tk: int = 128, interpret: bool = True):
    """x: (M, K) float; planes: (B, K, N) int8 of {0,1}; scales: (N,).

    Returns (M, N) in x.dtype. M/K/N must divide by the tile sizes.
    """
    m, k = x.shape
    bts, kk, n = planes.shape
    assert bts == bits and kk == k, (planes.shape, bits, k)
    assert m % tm == 0 and n % tn == 0 and k % tk == 0, (m, n, k)
    n_k = k // tk

    return pl.pallas_call(
        functools.partial(_kernel, bits=bits, n_k=n_k),
        grid=(m // tm, n // tn, n_k),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kq: (i, kq)),
            pl.BlockSpec((bits, tk, tn), lambda i, j, kq: (0, kq, j)),
            pl.BlockSpec((tn,), lambda i, j, kq: (j,)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kq: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((tm, tn), jnp.float32)],
        interpret=interpret,
    )(x, planes, scales)
