"""Flash attention Pallas kernel (causal, online softmax).

The jnp fallback (models/layers.chunked_attention) pays 2x FLOPs on the
causal triangle to stay differentiable; this kernel skips fully-masked KV
tiles via a dynamic fori bound — the §Perf "triangle skip" the roofline
iteration measures. Grid: (B*H, Lq/TQ); KV tiles streamed in a fori_loop
with VMEM-resident (m, l, acc) carry.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, tq: int, tk: int, causal: bool,
            scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (TQ, D)
    lk = k_ref.shape[1]
    n_kv = lk // tk
    d = q.shape[-1]

    def body(ki, carry):
        m, den, acc = carry
        k = lax.dynamic_slice_in_dim(k_ref[0], ki * tk, tk, 0) \
            .astype(jnp.float32)                      # (TK, D)
        v = lax.dynamic_slice_in_dim(v_ref[0], ki * tk, tk, 0) \
            .astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            qpos = qi * tq + lax.broadcasted_iota(jnp.int32, (tq, tk), 0)
            kpos = ki * tk + lax.broadcasted_iota(jnp.int32, (tq, tk), 1)
            s = jnp.where(kpos <= qpos, s, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        corr = jnp.exp(m - m2)
        p = jnp.exp(s - m2)
        den = den * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jax.lax.dot(p, v,
                                       preferred_element_type=jnp.float32)
        return m2, den, acc

    m0 = jnp.full((tq, 1), NEG_INF, jnp.float32)
    d0 = jnp.zeros((tq, 1), jnp.float32)
    a0 = jnp.zeros((tq, d), jnp.float32)
    upper = (qi + 1) * tq // tk if causal else n_kv
    upper = jnp.minimum(jnp.maximum(upper, 1), n_kv) \
        if causal else n_kv
    m, den, acc = lax.fori_loop(0, upper, body, (m0, d0, a0))
    o_ref[0] = (acc / jnp.maximum(den, 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "tq", "tk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, tq: int = 128,
                    tk: int = 128, interpret: bool = True):
    """q, k, v: (BH, L, D) — heads pre-flattened into the batch dim.

    Returns (BH, L, D). L must divide by tq/tk; MQA/GQA grouping is done by
    the ops.py wrapper before flattening.
    """
    bh, l, d = q.shape
    assert l % tq == 0 and l % tk == 0, (l, tq, tk)
    scale = d ** -0.5
    return pl.pallas_call(
        functools.partial(_kernel, tq=tq, tk=tk, causal=causal,
                          scale=scale),
        grid=(bh, l // tq),
        in_specs=[
            pl.BlockSpec((1, tq, d), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, l, d), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, l, d), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, d), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
