"""Fused carbon-sweep evaluate-and-reduce kernel (DESIGN.md §9.13).

The hot inner loop of the Monte Carlo carbon-planner sweep
(`core/sweep.py`): given one streamed tile of scenario cells — per-cell
(embodied, operational-anchor) rows over the candidate cores, the cell's
grid intensity and task frequency, and the tile's Monte Carlo lifetime
draws — evaluate the total-carbon surface over the core axis, select the
carbon-optimal core per scenario, and reduce everything the planner
reports *inside the tile*:

- per-cell over draws: sum/min/max of the best-core total, chosen-core
  counts, chosen embodied/operational sums (the percentile sort runs in
  the shared wrapper on the tile-sized best-total matrix — the full
  (cells x draws) tensor never exists);
- across the whole sweep: a log-binned histogram of best totals and a
  binned embodied-vs-operational Pareto frontier, both carried as small
  accumulator arrays that the engine streams through every tile.

Two interchangeable paths with ONE shared arithmetic pipeline
(`_totals` / `_cell_reduce` / `_hist_contrib` / `_pareto_candidate` /
`_pareto_merge`), following the `iss_stepper.py` contract that A/B paths
share their math so they cannot drift:

- `sweep_tile(..., path="jnp")`: pure-jnp broadcast over the whole tile
  (the bit-exact baseline);
- `sweep_tile(..., path="pallas")`: a `pl.pallas_call` gridded over row
  tiles of the cell axis, per-cell outputs block-mapped per row tile and
  the histogram/Pareto accumulators mapped to one shared block that
  every grid step revisits (initialized from the aliased running
  accumulator at step 0, then accumulated in place — the
  `input_output_aliases` idiom of `iss_stepper.py`). All accumulator
  updates are associative (int adds, lexicographic mins), so the
  sequential per-row-tile merges equal the jnp path's single whole-tile
  merge bit-for-bit, at any row-tile size.

Bit-exactness contract: for identical tile inputs, every output of the
two paths is bit-identical (pinned by tests/test_sweep.py); the totals
themselves are evaluated in exactly the numpy oracle's op order
(`core.selection.total_grid`: ``emb + (base * life_days) * freq`` with
``base = kwh * intensity``), so on point-mass lifetime draws the sweep
is bit-equal to the host planner grid as well.

CPU fallback follows the package convention (`iss_stepper.py`,
`bitplane_matmul.py`): off-TPU the kernel defaults to `interpret=True`.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

I32 = jnp.int32
_IMAX = jnp.iinfo(jnp.int32).max


def _pick_row_tile(n_rows: int, want: Optional[int]) -> int:
    """Largest divisor of `n_rows` <= the requested row tile (the
    `iss_stepper._pick_lane_tile` rule on the cell axis)."""
    want = n_rows if want is None else max(1, min(want, n_rows))
    for d in range(want, 0, -1):
        if n_rows % d == 0:
            return d
    return 1


class SweepAcc(NamedTuple):
    """Streamed cross-tile accumulators (device-resident, donated).

    `hist` counts best-core totals into fixed log10 bins; the `par_*`
    arrays hold, per embodied-axis log10 bin, the lexicographically
    minimal (operational, cell, draw) point seen so far with its
    payload — the streamed Pareto frontier. Empty bins carry
    (+inf, IMAX, IMAX) sentinels.
    """
    hist: jax.Array       # (B,)  int32
    par_op: jax.Array     # (Bp,) dtype — min operational kg in bin
    par_emb: jax.Array    # (Bp,) dtype — embodied kg of that point
    par_life: jax.Array   # (Bp,) dtype — lifetime draw (days) of point
    par_cell: jax.Array   # (Bp,) int32 — global cell index
    par_draw: jax.Array   # (Bp,) int32 — draw index
    par_core: jax.Array   # (Bp,) int32 — chosen core index


class TileOut(NamedTuple):
    """Per-cell reductions for one streamed tile of scenario cells."""
    best_total: jax.Array  # (Tc, N) chosen-core total kg per draw
    best_core: jax.Array   # (Tc, N) int32 argmin core index
    counts: jax.Array      # (Tc, C) int32 chosen-core histogram
    sum_best: jax.Array    # (Tc,) sum over draws of best totals
    min_best: jax.Array    # (Tc,)
    max_best: jax.Array    # (Tc,)
    sum_emb: jax.Array     # (Tc,) sum of chosen embodied kg
    sum_op: jax.Array      # (Tc,) sum of chosen operational kg


def init_acc(n_hist: int, n_pareto: int, dtype) -> SweepAcc:
    inf = jnp.array(jnp.inf, dtype)
    return SweepAcc(
        hist=jnp.zeros((n_hist,), I32),
        par_op=jnp.full((n_pareto,), inf),
        par_emb=jnp.full((n_pareto,), inf),
        par_life=jnp.full((n_pareto,), inf),
        par_cell=jnp.full((n_pareto,), _IMAX, I32),
        par_draw=jnp.full((n_pareto,), _IMAX, I32),
        par_core=jnp.full((n_pareto,), _IMAX, I32),
    )


# --------------------------------------------------- shared arithmetic
def _totals(emb, kwh, inten, freq, life_days):
    """Total/embodied/operational surfaces over (cells, draws, cores).

    EXACTLY the numpy oracle's op order (`selection.total_grid`):
    ``base = kwh * intensity``; ``total = emb + (base * life_days) *
    freq`` — so point-mass draws reproduce the host grid bit-for-bit.
    `life_days` arrives pre-divided from the engine (`core/sweep.py`
    guards that division against XLA's f32 divide-by-constant ->
    reciprocal-multiply rewrite) so both A/B paths consume identical
    bits; the remaining ops here are pure multiply chains and a
    contraction-blocked add, which XLA CPU leaves bit-stable.
    """
    base = kwh * inten[:, None]                       # (Tc, C)
    op = (base[:, None, :] * life_days[:, :, None]) * freq[:, None, None]
    # `abs` is a bitwise identity here (op >= 0 always) whose only job
    # is to break the fadd(fmul) pattern: XLA CPU otherwise contracts
    # `emb + op` into an FMA, which rounds differently from the numpy
    # oracle's separate multiply-then-add
    total = emb[:, None, :] + jnp.abs(op)
    return total, op


def _cell_reduce(total, op, emb, n_cores) -> TileOut:
    """argmin core selection + per-cell reductions over the draw axis."""
    best_core = jnp.argmin(total, axis=-1).astype(I32)   # first-min ties
    sel = best_core[..., None]
    best_total = jnp.take_along_axis(total, sel, axis=-1)[..., 0]
    best_op = jnp.take_along_axis(op, sel, axis=-1)[..., 0]
    best_emb = jnp.take_along_axis(
        jnp.broadcast_to(emb[:, None, :], total.shape), sel, axis=-1)[..., 0]
    onehot = (best_core[..., None]
              == jnp.arange(n_cores, dtype=I32)).astype(I32)
    return TileOut(
        best_total=best_total,
        best_core=best_core,
        counts=jnp.sum(onehot, axis=1, dtype=I32),
        sum_best=jnp.sum(best_total, axis=1),
        min_best=jnp.min(best_total, axis=1),
        max_best=jnp.max(best_total, axis=1),
        sum_emb=jnp.sum(best_emb, axis=1),
        sum_op=jnp.sum(best_op, axis=1),
    ), best_emb, best_op


def _log_bin(x, lo, inv, n_bins):
    """Fixed log10 binning; out-of-range values clamp to the end bins."""
    b = jnp.floor((jnp.log10(x) - lo) * inv).astype(I32)
    return jnp.clip(b, 0, n_bins - 1)


def _hist_contrib(best_total, valid, lo, inv, n_bins):
    """Scatter-add histogram of the tile's best totals.

    Integer adds are exact and order-free, so the scatter is
    bit-identical to a one-hot reduction at any tile size (and ~2.7x
    faster on CPU than materializing the (cells, draws, bins) one-hot).
    Runs under the interpret-mode Pallas path as plain XLA scatter.
    """
    bins = _log_bin(best_total, lo, inv, n_bins)        # (Tc, N)
    w = jnp.broadcast_to(valid[:, None], bins.shape).astype(I32)
    return jnp.zeros((n_bins,), I32).at[bins.reshape(-1)].add(
        w.reshape(-1))                                  # (B,)


def _pareto_candidate(emb, best_op, life_days, cell_idx, best_core,
                      valid, lo, inv, n_bins):
    """Per-bin lexicographic min over this tile's scenario points.

    Global key order is (operational, cell, draw); the chosen core is a
    pure function of (cell, draw), so the key is a strict total order
    and per-bin min is associative — any grouping of scenarios into row
    tiles merges to the same frontier.

    Reduced in two levels: all draws of one (cell, core) share the same
    embodied kg and therefore the same bin, so first each (cell, core)
    group elects its champion draw (min op, then min draw — over the
    draws that actually chose that core), then the per-bin min runs
    over the (cells x cores) champions instead of (cells x draws)
    scenarios. A lexicographic min over any partition equals the global
    min, so this is bit-identical to the flat reduction.
    """
    n_cells, n_draws = best_op.shape
    n_cores = emb.shape[1]
    inf = jnp.array(jnp.inf, best_op.dtype)
    # level 1: per-(cell, core) champion draw
    chose = best_core[..., None] == jnp.arange(n_cores, dtype=I32)
    opm = jnp.where(chose, best_op[..., None], inf)     # (Tc, N, C)
    op_cc = jnp.min(opm, axis=1)                        # (Tc, C)
    tie = chose & (opm == op_cc[:, None, :])
    drawm = jnp.where(tie, jnp.arange(n_draws, dtype=I32)[None, :, None],
                      _IMAX)
    draw_cc = jnp.min(drawm, axis=1)                    # (Tc, C)
    tie = tie & (drawm == draw_cc[:, None, :])          # exactly one draw
    life_cc = jnp.sum(jnp.where(tie, life_days[..., None], 0), axis=1,
                      dtype=life_days.dtype)
    alive = valid[:, None] & (op_cc < inf)              # (Tc, C)

    # level 2: per-bin lexicographic min over the champions
    bins = _log_bin(emb, lo, inv, n_bins)               # (Tc, C)
    cell = jnp.broadcast_to(cell_idx[:, None], bins.shape)
    mask = (bins[None] == jnp.arange(n_bins, dtype=I32)[:, None, None]) \
        & alive[None]                                   # (Bp, Tc, C)
    opb = jnp.where(mask, op_cc[None], inf)
    op_min = jnp.min(opb, axis=(1, 2))                  # (Bp,)
    # bins that are empty OR whose best point overflowed to +inf both
    # keep the (inf, IMAX, IMAX) sentinel record
    finite = op_min < inf
    tie2 = mask & (opb == op_min[:, None, None]) & finite[:, None, None]
    cellm = jnp.where(tie2, cell[None], _IMAX)
    cell_min = jnp.min(cellm, axis=(1, 2))
    tie2 = tie2 & (cellm == cell_min[:, None, None])
    drawb = jnp.where(tie2, draw_cc[None], _IMAX)
    draw_min = jnp.min(drawb, axis=(1, 2))
    tie2 = tie2 & (drawb == draw_min[:, None, None])

    def pick(vals, empty):
        # `tie2` selects exactly one champion per bin with a finite
        # best point; sentinel bins sum to 0 and take `empty`
        return jnp.sum(jnp.where(tie2, vals[None], 0), axis=(1, 2),
                       dtype=vals.dtype) \
            + jnp.where(finite, 0, empty).astype(vals.dtype)

    core_b = jnp.broadcast_to(jnp.arange(n_cores, dtype=I32)[None, :],
                              bins.shape)
    return (jnp.where(finite, op_min, inf), pick(emb, inf),
            pick(life_cc, inf),
            jnp.where(finite, cell_min, _IMAX),
            jnp.where(finite, draw_min, _IMAX),
            pick(core_b, _IMAX).astype(I32))


def _pareto_merge(a: Tuple, b: Tuple) -> Tuple:
    """Elementwise lexicographic-min merge of two per-bin frontiers."""
    a_op, a_emb, a_life, a_cell, a_draw, a_core = a
    b_op, b_emb, b_life, b_cell, b_draw, b_core = b
    take_b = (b_op < a_op) \
        | ((b_op == a_op) & (b_cell < a_cell)) \
        | ((b_op == a_op) & (b_cell == a_cell) & (b_draw < a_draw))
    w = jnp.where
    return (w(take_b, b_op, a_op), w(take_b, b_emb, a_emb),
            w(take_b, b_life, a_life), w(take_b, b_cell, a_cell),
            w(take_b, b_draw, a_draw), w(take_b, b_core, a_core))


def _eval_tile(emb, kwh, inten, freq, life_days, valid, cell_idx, *,
               hist_lo, hist_inv, par_lo, par_inv, n_hist, n_pareto):
    """Shared per-(sub)tile pipeline used verbatim by both paths."""
    n_cores = emb.shape[1]
    total, op = _totals(emb, kwh, inten, freq, life_days)
    out, best_emb, best_op = _cell_reduce(total, op, emb, n_cores)
    hist = _hist_contrib(out.best_total, valid, hist_lo, hist_inv, n_hist)
    cand = _pareto_candidate(emb, best_op, life_days, cell_idx,
                             out.best_core, valid, par_lo, par_inv,
                             n_pareto)
    return out, hist, cand


# ------------------------------------------------------------ jnp path
def _sweep_tile_jnp(emb, kwh, inten, freq, life_days, valid, cell_idx,
                    acc: SweepAcc, **kw):
    out, hist, cand = _eval_tile(emb, kwh, inten, freq, life_days,
                                 valid, cell_idx, **kw)
    par = _pareto_merge(tuple(acc[1:]), cand)
    return out, SweepAcc(acc.hist + hist, *par)


# --------------------------------------------------------- pallas path
def _sweep_kernel(emb_ref, kwh_ref, inten_ref, freq_ref, life_ref,
                  valid_ref, cell_ref, hist_in_ref, *par_refs, **kw):
    """One row tile of the cell axis; every grid step merges its
    histogram/Pareto contribution into the shared accumulator block."""
    (pop_in, pemb_in, plife_in, pcell_in, pdraw_in, pcore_in,
     bt_ref, bc_ref, cnt_ref, sb_ref, mn_ref, mx_ref, se_ref, so_ref,
     ohist_ref, oop_ref, oemb_ref, olife_ref, ocell_ref, odraw_ref,
     ocore_ref) = par_refs

    out, hist, cand = _eval_tile(
        emb_ref[...], kwh_ref[...], inten_ref[...], freq_ref[...],
        life_ref[...], valid_ref[...], cell_ref[...], **kw)
    bt_ref[...] = out.best_total
    bc_ref[...] = out.best_core
    cnt_ref[...] = out.counts
    sb_ref[...] = out.sum_best
    mn_ref[...] = out.min_best
    mx_ref[...] = out.max_best
    se_ref[...] = out.sum_emb
    so_ref[...] = out.sum_op

    @pl.when(pl.program_id(0) == 0)
    def _seed_accumulators():
        ohist_ref[...] = hist_in_ref[...]
        oop_ref[...] = pop_in[...]
        oemb_ref[...] = pemb_in[...]
        olife_ref[...] = plife_in[...]
        ocell_ref[...] = pcell_in[...]
        odraw_ref[...] = pdraw_in[...]
        ocore_ref[...] = pcore_in[...]

    ohist_ref[...] = ohist_ref[...] + hist
    cur = (oop_ref[...], oemb_ref[...], olife_ref[...], ocell_ref[...],
           odraw_ref[...], ocore_ref[...])
    mop, memb, mlife, mcell, mdraw, mcore = _pareto_merge(cur, cand)
    oop_ref[...] = mop
    oemb_ref[...] = memb
    olife_ref[...] = mlife
    ocell_ref[...] = mcell
    odraw_ref[...] = mdraw
    ocore_ref[...] = mcore


def _sweep_tile_pallas(emb, kwh, inten, freq, life_days, valid,
                       cell_idx, acc: SweepAcc, row_tile=None,
                       interpret=None, **kw):
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_cells, n_draws = life_days.shape
    n_cores = emb.shape[1]
    n_hist = acc.hist.shape[0]
    n_par = acc.par_op.shape[0]
    dtype = life_days.dtype
    rt = _pick_row_tile(n_cells, 128 if row_tile is None else row_tile)

    def row(i):
        return (i,)

    def row2(i):
        return (i, 0)

    def whole(i):
        return (0,)

    outs = pl.pallas_call(
        functools.partial(_sweep_kernel, **kw),
        grid=(n_cells // rt,),
        in_specs=[
            pl.BlockSpec((rt, n_cores), row2),     # emb
            pl.BlockSpec((rt, n_cores), row2),     # kwh
            pl.BlockSpec((rt,), row),              # intensity
            pl.BlockSpec((rt,), row),              # freq
            pl.BlockSpec((rt, n_draws), row2),     # lifetimes
            pl.BlockSpec((rt,), row),              # valid
            pl.BlockSpec((rt,), row),              # cell idx
            pl.BlockSpec((n_hist,), whole),        # running hist
            pl.BlockSpec((n_par,), whole),         # running pareto x6
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
        ],
        out_specs=[
            pl.BlockSpec((rt, n_draws), row2),     # best_total
            pl.BlockSpec((rt, n_draws), row2),     # best_core
            pl.BlockSpec((rt, n_cores), row2),     # counts
            pl.BlockSpec((rt,), row),              # sum_best
            pl.BlockSpec((rt,), row),              # min_best
            pl.BlockSpec((rt,), row),              # max_best
            pl.BlockSpec((rt,), row),              # sum_emb
            pl.BlockSpec((rt,), row),              # sum_op
            pl.BlockSpec((n_hist,), whole),        # hist out
            pl.BlockSpec((n_par,), whole),         # pareto out x6
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
            pl.BlockSpec((n_par,), whole),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_cells, n_draws), dtype),
            jax.ShapeDtypeStruct((n_cells, n_draws), I32),
            jax.ShapeDtypeStruct((n_cells, n_cores), I32),
            jax.ShapeDtypeStruct((n_cells,), dtype),
            jax.ShapeDtypeStruct((n_cells,), dtype),
            jax.ShapeDtypeStruct((n_cells,), dtype),
            jax.ShapeDtypeStruct((n_cells,), dtype),
            jax.ShapeDtypeStruct((n_cells,), dtype),
            jax.ShapeDtypeStruct((n_hist,), I32),
            jax.ShapeDtypeStruct((n_par,), dtype),
            jax.ShapeDtypeStruct((n_par,), dtype),
            jax.ShapeDtypeStruct((n_par,), dtype),
            jax.ShapeDtypeStruct((n_par,), I32),
            jax.ShapeDtypeStruct((n_par,), I32),
            jax.ShapeDtypeStruct((n_par,), I32),
        ],
        # running accumulators update in place (inputs 7-13 -> outputs
        # 8-14), the iss_stepper donation/aliasing idiom
        input_output_aliases={7: 8, 8: 9, 9: 10, 10: 11, 11: 12,
                              12: 13, 13: 14},
        interpret=interpret,
    )(emb, kwh, inten, freq, life_days, valid, cell_idx, acc.hist,
      acc.par_op, acc.par_emb, acc.par_life, acc.par_cell,
      acc.par_draw, acc.par_core)
    return TileOut(*outs[:8]), SweepAcc(*outs[8:])


def sweep_tile(emb, kwh, inten, freq, life_days, valid, cell_idx,
               acc: SweepAcc, *, hist_lo: float, hist_inv: float,
               par_lo: float, par_inv: float, path: str = "jnp",
               row_tile: Optional[int] = None,
               interpret: Optional[bool] = None):
    """Evaluate-and-reduce one streamed tile of scenario cells.

    Inputs are per-cell rows over the core axis (`emb`/`kwh`, kg CO2e
    and intensity-1 kWh-rate anchors), per-cell scalars (`inten` kg/kWh,
    `freq` execs/day), and the tile's Monte Carlo lifetime draws
    (`life_days`, days, (cells, draws) — pre-divided by the engine so
    both paths see identical bits). `valid` masks padded cells out of
    the global accumulators; `cell_idx` is the global cell index used as
    the deterministic Pareto tie-break key. Returns `(TileOut, SweepAcc)`
    — per-cell reductions plus the advanced running accumulators.

    `path="jnp"` is the pure-broadcast baseline; `path="pallas"` runs
    the same pipeline as one kernel gridded over row tiles. The paths
    are bit-identical for identical inputs (tests/test_sweep.py).
    """
    kw = dict(hist_lo=hist_lo, hist_inv=hist_inv, par_lo=par_lo,
              par_inv=par_inv, n_hist=acc.hist.shape[0],
              n_pareto=acc.par_op.shape[0])
    if path == "jnp":
        return _sweep_tile_jnp(emb, kwh, inten, freq, life_days, valid,
                               cell_idx, acc, **kw)
    if path == "pallas":
        return _sweep_tile_pallas(emb, kwh, inten, freq, life_days,
                                  valid, cell_idx, acc,
                                  row_tile=row_tile,
                                  interpret=interpret, **kw)
    raise ValueError(f"unknown sweep path {path!r} "
                     f"(expected 'jnp' or 'pallas')")
