"""Fused-segment Pallas ISS stepper (DESIGN.md §9.7, packed bank §9.8).

`iss.run_segment_lanes` is plain XLA: every architectural step of the
segment `while_loop` re-materializes the full lane-pool `ISSState`
(regs, pc, mem, halted, counters) through the memory system and
re-dispatches the step body as dozens of separate HLO ops. This kernel
executes ALL `seg_steps` architectural steps of a lane tile inside ONE
`pl.pallas_call` invocation:

- the program *bank* and the tile's regs/pc/mem/halted/counters are read
  from their refs once, live in kernel-resident values (VMEM on TPU) for
  the whole segment, and are written back once at the end;
- the step body is the branchless one-hot commit scheme ported from
  `iss.step_branchless`, with every memory port expressed as a masked
  one-hot reduce/select instead of gather/scatter — the kernel body is
  pure elementwise/reduction work over (lanes, words) tiles;
- the PR-2 opcode-subset DCE (`iss.opcode_subset`) is applied at kernel
  *build* time, so dead opcode classes are never emitted into the kernel
  for a given workload (the RISP specialization knob, one kernel per
  ISA subset);
- the grid runs over lane tiles; each tile's internal `while_loop`
  exits as soon as its own lanes are all halted, mirroring the per-device
  early exit of the shard_map path (§9.6) at tile granularity.

The packed fleet runtime (§9.8) generalizes the fetch: the kernel holds
the whole multi-program bank resident, every lane carries its `prog_id`
and its own `max_steps` budget, and the instruction fetch is a one-hot
reduction over the *flattened* bank at index `prog_id * bank_width +
clamp(pc >> 2, 0, code_len[prog_id] - 1)` — the per-program clamp of
`iss.fetch_banked`, so each lane retires exactly what it would retire in
a single-program pool running its own program. The single-program entry
point `iss_segment` is the 1-row special case of the same kernel, so the
two paths cannot drift.

Bit-exactness contract: identical to `step_branchless` (and therefore to
`iss.step`/`iss.run`) for programs whose fetched words decode to RV32E
opcodes — including the clamp-on-read / drop-on-write behavior of jax
gathers and scatters at out-of-range addresses, which the one-hot ports
reproduce explicitly (clipped match for the read port, unclipped match
for the write port). Pinned by the instruction-soup and segment-parity
tests in `tests/test_stepper.py` and the packed-parity tests in
`tests/test_packed.py`.

The CPU fallback follows the package convention (`bitplane_matmul.py`,
`ssd_scan.py`): off-TPU the kernel defaults to `interpret=True`, so it
runs anywhere jax runs and the fleet engine can A/B it against the XLA
steppers; on a TPU backend the default flips to the compiled Mosaic
path (explicit `interpret=` overrides either way).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.flexibits import faults as flexifault
from repro.flexibits import iss
from repro.flexibits.cycles import N_COST
from repro.flexibits.iss import I32, U32, ISSState, PackedState, _u


def _pick_lane_tile(n_lanes: int, want: Optional[int]) -> int:
    """Largest divisor of `n_lanes` that is <= the requested tile."""
    want = n_lanes if want is None else max(1, min(want, n_lanes))
    for d in range(want, 0, -1):
        if n_lanes % d == 0:
            return d
    return 1


def _step_tile(bank_flat, lane_base, lane_len, lane_mlen, lane_cost,
               regs, pc, mem, halted, n_instr, n_two, mix, n_cyc,
               active, subset, faults=None, lane_key=None, epoch=None):
    """One branchless architectural step over a (TL,)-lane tile.

    Lane-vectorized port of `iss.step_branchless`: the opcode-gated
    commit pipeline is the SAME code (`iss.branchless_commits`, with the
    shared decode/ALU/branch/load-store/classify helpers), so the
    semantics cannot drift. What this function owns is only the data
    movement: instruction fetch, register reads, and the memory word
    ports are masked one-hot reductions/selects, so the kernel body
    contains no gather/scatter at all. The fetch indexes the flattened
    program bank through each lane's `lane_base`/`lane_len` (both
    segment-constant), reproducing the per-program pc clamp of
    `iss.fetch_banked`; `lane_mlen` bounds the memory word ports at each
    lane's OWN word count, so clamp-on-read / drop-on-write happen at
    the lane's program boundary even when the pool memory is padded
    wider. `active=False` freezes a lane completely. `subset` is static
    — opcode classes outside it are dropped from the kernel at build
    time, and `lane_cost=None` (timing off) drops the whole cycle tally
    (the timing select in `iss.timing_ticks` is already a one-hot
    reduction, so with timing ON the kernel body still contains no
    gather/scatter).
    """
    n_lanes = pc.shape[0]
    n_bank = bank_flat.shape[0]
    mem_words = mem.shape[1]
    iota_bank = jnp.arange(n_bank, dtype=I32)
    iota_mem = jnp.arange(mem_words, dtype=I32)
    iota_reg = jnp.arange(16, dtype=I32)

    # ---- fetch: per-program clipped one-hot over the flattened bank ==
    # jax's clamp-on-read gather against each lane's own program
    pword = (_u(pc) >> 2).astype(I32)
    flat = lane_base + jnp.clip(pword, 0, lane_len - 1)
    fsel = flat[:, None] == iota_bank[None, :]
    ii = jnp.sum(jnp.where(fsel, bank_flat[None, :], 0), axis=1)
    d = iss.decode_fields(ii.astype(U32))

    # ---- register read port: one-hot over the 16-entry file
    def read_reg(idx):
        sel = idx[:, None] == iota_reg[None, :]
        return jnp.sum(jnp.where(sel, regs, 0), axis=1)

    a = read_reg(d.rs1)
    b = read_reg(d.rs2)
    live = jnp.ones(n_lanes, bool) if active is None else active

    # ---- memory word ports: a clipped one-hot read (clamp-on-read, as
    # jax gathers) and an UNCLIPPED one-hot write select (out-of-range
    # stores drop, as jax scatters)
    def read_word(widx):
        rsel = jnp.clip(widx, 0, lane_mlen - 1)[:, None] \
            == iota_mem[None, :]
        return jnp.sum(jnp.where(rsel, mem, 0), axis=1)

    def write_word(widx, word, neww, is_store):
        wsel = (widx[:, None] == iota_mem[None, :]) \
            & (is_store & (widx < lane_mlen))[:, None]
        return jnp.where(wsel, neww[:, None], mem)

    next_pc, wr, writes_rd, new_mem, halt, two_stage, mix_idx, ticks = \
        iss.branchless_commits(d, a, b, pc, subset, live,
                               read_word=read_word, write_word=write_word,
                               cost=lane_cost)
    mem = mem if new_mem is None else new_mem

    # ---- one-hot register-file commit (elementwise, no scatter)
    rdsel = (d.rd[:, None] == iota_reg[None, :]) & writes_rd[:, None]
    regs = jnp.where(rdsel, wr[:, None], regs)

    one = live.astype(I32)
    mix_onehot = (jnp.arange(len(iss.MIX_CLASSES), dtype=I32)[None, :]
                  == mix_idx[:, None]).astype(I32) * one[:, None]
    pc = jnp.where(live, next_pc.astype(I32), pc)
    halted = halted | (halt & live)
    n_instr = n_instr + one
    if faults is not None:
        # post-commit fault transform (DESIGN.md §9.14): the SAME
        # shape-polymorphic one-hot arithmetic as the XLA steppers
        # (faults.apply_fault_arrays contains no gather/scatter), gated
        # exactly like their commits — live this step and not halted by
        # it. `lane_key`/`epoch` are segment constants per lane.
        regs, pc, mem = flexifault.apply_fault_arrays(
            faults, lane_key, epoch, regs, pc, mem, n_instr,
            live & ~halted, mem_len=lane_mlen)
    return (regs,
            pc,
            mem,
            halted,
            n_instr,
            n_two + (two_stage & live).astype(I32),
            mix + mix_onehot,
            n_cyc if ticks is None else n_cyc + ticks * one)


def _segment_kernel(bank_ref, clen_ref, mlen_ref, pid_ref, ms_ref,
                    cost_ref, *refs,
                    seg_steps: int, subset, timing: bool, faults=None):
    """Mega-step: all `seg_steps` architectural steps of one lane tile.

    State is read from the refs ONCE, carried through the segment loop as
    kernel-resident values, and written back ONCE — the per-step state
    round-trip of the XLA steppers never leaves the kernel. The bank,
    each lane's flat fetch base/length, memory bound, cost row, and step
    budget are segment constants, hoisted out of the loop. `timing`
    (static) gates the cycle tally: off, the per-program cost bank is a
    dummy and `n_cycles` passes through untouched. `faults` (static)
    gates the post-commit fault transform: on, two extra per-lane refs
    (fault key, epoch) lead the state refs; off, they are not inputs at
    all and the kernel is byte-identical to the fault-free build.
    """
    lane_key = epoch = None
    if faults is not None:
        lane_key = refs[0][...]
        epoch = refs[1][...]
        refs = refs[2:]
    (regs_ref, pc_ref, mem_ref, halt_ref, ni_ref, n2_ref, mix_ref,
     ncyc_ref, oregs_ref, opc_ref, omem_ref, ohalt_ref, oni_ref,
     on2_ref, omix_ref, oncyc_ref) = refs
    bank = bank_ref[...]
    clen = clen_ref[...]
    mlen = mlen_ref[...]
    pid = pid_ref[...]
    max_steps = ms_ref[...]
    n_progs, bank_width = bank.shape
    psel = pid[:, None] == jnp.arange(n_progs, dtype=I32)[None, :]
    lane_len = jnp.sum(jnp.where(psel, clen[None, :], 0), axis=1)
    lane_mlen = jnp.sum(jnp.where(psel, mlen[None, :], 0), axis=1)
    lane_base = pid * bank_width
    bank_flat = bank.reshape(-1)
    lane_cost = None
    if timing:
        # per-lane cost rows: the same one-hot program select as
        # lane_len/lane_mlen, lifted over the cost axis
        cost = cost_ref[...]
        lane_cost = jnp.sum(jnp.where(psel[:, :, None], cost[None, :, :],
                                      0), axis=1)

    carry = (jnp.zeros((), I32), regs_ref[...], pc_ref[...], mem_ref[...],
             halt_ref[...], ni_ref[...], n2_ref[...], mix_ref[...],
             ncyc_ref[...])

    def active_of(halted, n_instr):
        return (~halted) & (n_instr < max_steps)

    def cond(c):
        k, _, _, _, halted, n_instr, _, _, _ = c
        return (k < seg_steps) & active_of(halted, n_instr).any()

    def body(c):
        k, regs, pc, mem, halted, n_instr, n2, mix, ncyc = c
        act = active_of(halted, n_instr)
        regs, pc, mem, halted, n_instr, n2, mix, ncyc = _step_tile(
            bank_flat, lane_base, lane_len, lane_mlen, lane_cost, regs,
            pc, mem, halted, n_instr, n2, mix, ncyc, act, subset,
            faults=faults, lane_key=lane_key, epoch=epoch)
        return k + 1, regs, pc, mem, halted, n_instr, n2, mix, ncyc

    _, regs, pc, mem, halted, n_instr, n2, mix, ncyc = \
        lax.while_loop(cond, body, carry)
    oregs_ref[...] = regs
    opc_ref[...] = pc
    omem_ref[...] = mem
    ohalt_ref[...] = halted
    oni_ref[...] = n_instr
    on2_ref[...] = n2
    omix_ref[...] = mix
    oncyc_ref[...] = ncyc


def iss_segment_banked(bank: jax.Array, code_len: jax.Array,
                       state: PackedState, *, seg_steps: int,
                       subset=None, mem_len: Optional[jax.Array] = None,
                       cost: Optional[jax.Array] = None, faults=None,
                       lane_key: Optional[jax.Array] = None,
                       epoch: Optional[jax.Array] = None,
                       lane_tile: Optional[int] = None,
                       interpret: Optional[bool] = None) -> PackedState:
    """Fused packed segment: every lane runs ITS OWN bank program.

    The packed-runtime counterpart of `iss_segment` (and the fused form
    of `iss.run_segment_lanes_banked`, bit-exact with it): the whole
    (n_progs, width) program bank is resident in the kernel, each lane
    tile carries its lanes' `prog_id` and per-lane `max_steps` budget,
    and the fetch is a per-program-clamped one-hot over the flattened
    bank. `mem_len` (per-program word counts, like `code_len`) bounds
    each lane's memory ports at its own program's size; None means the
    padded pool width is every program's true size. `cost` (per-program
    (n_progs, N_COST) rows, like `mem_len`) turns on the per-lane cycle
    tally — None keeps the timing layer out of the kernel entirely (a
    dummy zero bank holds the spec list static). `faults` (a
    faults.FaultSpec, with per-LANE `lane_key` uint32 keys and int32
    retry `epoch`s) turns on the post-commit fault transform
    (DESIGN.md §9.14) — None adds neither the inputs nor any kernel
    code, so the fault-free build is byte-identical to the pre-
    FlexiFault kernel. `subset` must cover
    the union of the bank's opcode subsets — either the text-derived
    `iss.opcode_subset` per program, or FlexiLint's tighter
    reachable-only subsets (`analyze.Analysis.subset`, DESIGN.md §9.11):
    unreachable words are fetched at most by halted lanes, whose commits
    and tick tallies this kernel `live`-masks exactly like
    `step_branchless`, so the DCE stays bit-exact. State buffers are aliased
    input->output; `prog_id`/`max_steps` are segment constants and pass
    through untouched.
    """
    if seg_steps < 1:
        raise ValueError("seg_steps must be >= 1")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lanes = state.lanes
    n_lanes, mem_words = lanes.mem.shape
    n_progs, bank_width = bank.shape
    if mem_len is None:
        mem_len = jnp.full((n_progs,), mem_words, I32)
    timing = cost is not None
    if cost is None:
        cost = jnp.zeros((n_progs, N_COST), I32)
    tile = _pick_lane_tile(n_lanes, 128 if lane_tile is None else lane_tile)
    n_mix = len(iss.MIX_CLASSES)
    sub = None if subset is None else frozenset(subset)

    def row(i):
        return (i,)

    def row2(i):
        return (i, 0)

    def whole(i):
        return (0,)

    # fault schedule inputs ride between the segment constants and the
    # aliased state buffers — only when faults are on, so the fault-free
    # pallas_call is byte-identical to the pre-FlexiFault build
    fault_specs = []
    fault_args = []
    n_fault = 0
    if faults is not None and not faults.off:
        fault_specs = [pl.BlockSpec((tile,), row),
                       pl.BlockSpec((tile,), row)]
        fault_args = [lane_key.astype(jnp.uint32), epoch.astype(I32)]
        n_fault = 2
    else:
        faults = None

    out = pl.pallas_call(
        functools.partial(_segment_kernel, seg_steps=seg_steps,
                          subset=sub, timing=timing, faults=faults),
        grid=(n_lanes // tile,),
        in_specs=[
            pl.BlockSpec((n_progs, bank_width), lambda i: (0, 0)),
            pl.BlockSpec((n_progs,), whole),
            pl.BlockSpec((n_progs,), whole),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((n_progs, N_COST), lambda i: (0, 0)),
        ] + fault_specs + [
            pl.BlockSpec((tile, 16), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, mem_words), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, n_mix), row2),
            pl.BlockSpec((tile,), row),
        ],
        out_specs=[
            pl.BlockSpec((tile, 16), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, mem_words), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, n_mix), row2),
            pl.BlockSpec((tile,), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_lanes, 16), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes, mem_words), I32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.bool_),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes, n_mix), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
        ],
        # state buffers update in place (bank/code_len/mem_len/prog_id/
        # max_steps/cost, inputs 0-5, plus the optional fault key/epoch
        # pair, are read-only segment constants)
        input_output_aliases={6 + n_fault: 0, 7 + n_fault: 1,
                              8 + n_fault: 2, 9 + n_fault: 3,
                              10 + n_fault: 4, 11 + n_fault: 5,
                              12 + n_fault: 6, 13 + n_fault: 7},
        interpret=interpret,
    )(bank, code_len, mem_len, state.prog_id, state.max_steps, cost,
      *fault_args,
      lanes.regs, lanes.pc, lanes.mem, lanes.halted,
      lanes.n_instr, lanes.n_two_stage, lanes.mix, lanes.n_cycles)
    return PackedState(lanes=ISSState(*out), prog_id=state.prog_id,
                       max_steps=state.max_steps)


def _refill_kernel(take_ref, src_ref, smem_ref, sprog_ref, sms_ref,
                   regs_ref, pc_ref, mem_ref, halt_ref, ni_ref, n2_ref,
                   mix_ref, ncyc_ref, pid_ref, ms_ref,
                   oregs_ref, opc_ref, omem_ref, ohalt_ref, oni_ref,
                   on2_ref, omix_ref, oncyc_ref, opid_ref, oms_ref):
    """One-hot staged->lane swap for a lane tile (DESIGN.md §9.9).

    The resident runtime's compaction/scatter expressed the way the
    fused stepper expresses its ports: each taking lane's staged row is
    selected by a masked one-hot reduction over the staged axis instead
    of a row gather, so the kernel body is pure elementwise/reduction
    work. The take/src assignment itself (`iss.refill_take`, a pool-wide
    cumsum) is computed outside — ranks cross lane tiles, exactly like
    the host path's pool-wide free-lane walk. Bit-identical to
    `iss.refill_lanes`.
    """
    take = take_ref[...]
    src = src_ref[...]
    smem = smem_ref[...]
    n_staged_rows = smem.shape[0]
    onehot = (src[:, None] == jnp.arange(n_staged_rows, dtype=I32)[None, :]) \
        & take[:, None]
    o32 = onehot.astype(I32)

    def pick(rows):
        return jnp.sum(jnp.where(onehot, rows[None, :], 0), axis=1)

    new_mem = jnp.sum(o32[:, :, None] * smem[None, :, :], axis=1)
    t1 = take[:, None]
    oregs_ref[...] = jnp.where(t1, 0, regs_ref[...])
    opc_ref[...] = jnp.where(take, 0, pc_ref[...])
    omem_ref[...] = jnp.where(t1, new_mem, mem_ref[...])
    ohalt_ref[...] = jnp.where(take, False, halt_ref[...])
    oni_ref[...] = jnp.where(take, 0, ni_ref[...])
    on2_ref[...] = jnp.where(take, 0, n2_ref[...])
    omix_ref[...] = jnp.where(t1, 0, mix_ref[...])
    oncyc_ref[...] = jnp.where(take, 0, ncyc_ref[...])
    opid_ref[...] = jnp.where(take, pick(sprog_ref[...]), pid_ref[...])
    oms_ref[...] = jnp.where(take, pick(sms_ref[...]), ms_ref[...])


def iss_refill(state: PackedState, take: jax.Array, src: jax.Array,
               staged_mems: jax.Array, staged_prog: jax.Array,
               staged_ms: jax.Array, *, lane_tile: Optional[int] = None,
               interpret: Optional[bool] = None) -> PackedState:
    """Banked Pallas variant of `iss.refill_lanes` — same swap, one-hot
    ports, gridded over lane tiles with state aliased input->output so
    the donated lane pool updates in place. The staged batch is small
    (<= chunk rows), so it is replicated to every tile like the program
    bank in `iss_segment_banked`."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    lanes = state.lanes
    n_lanes, mem_words = lanes.mem.shape
    n_rows = staged_mems.shape[0]
    tile = _pick_lane_tile(n_lanes, 128 if lane_tile is None else lane_tile)
    n_mix = len(iss.MIX_CLASSES)

    def row(i):
        return (i,)

    def row2(i):
        return (i, 0)

    def whole(i):
        return (0,)

    out = pl.pallas_call(
        _refill_kernel,
        grid=(n_lanes // tile,),
        in_specs=[
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((n_rows, mem_words), lambda i: (0, 0)),
            pl.BlockSpec((n_rows,), whole),
            pl.BlockSpec((n_rows,), whole),
            pl.BlockSpec((tile, 16), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, mem_words), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, n_mix), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
        ],
        out_specs=[
            pl.BlockSpec((tile, 16), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, mem_words), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile, n_mix), row2),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
            pl.BlockSpec((tile,), row),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_lanes, 16), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes, mem_words), I32),
            jax.ShapeDtypeStruct((n_lanes,), jnp.bool_),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes, n_mix), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
            jax.ShapeDtypeStruct((n_lanes,), I32),
        ],
        # lane-pool state updates in place (take/src/staged, inputs 0-4,
        # are read-only refill constants)
        input_output_aliases={5: 0, 6: 1, 7: 2, 8: 3, 9: 4, 10: 5,
                              11: 6, 12: 7, 13: 8, 14: 9},
        interpret=interpret,
    )(take, src, staged_mems, staged_prog, staged_ms,
      lanes.regs, lanes.pc, lanes.mem, lanes.halted, lanes.n_instr,
      lanes.n_two_stage, lanes.mix, lanes.n_cycles, state.prog_id,
      state.max_steps)
    return PackedState(lanes=ISSState(*out[:8]), prog_id=out[8],
                       max_steps=out[9])


def iss_segment(code: jax.Array, state: ISSState, *, seg_steps: int,
                max_steps: int, subset=None,
                cost: Optional[jax.Array] = None, faults=None,
                lane_key: Optional[jax.Array] = None,
                epoch: Optional[jax.Array] = None,
                lane_tile: Optional[int] = None,
                interpret: Optional[bool] = None) -> ISSState:
    """Fused-segment stepper: up to `seg_steps` steps for every lane.

    Drop-in replacement for `iss.run_segment_lanes` — bit-exact with it
    (and with `iss.run`) over RV32E programs. The grid runs over lane
    tiles of `lane_tile` lanes (default: largest divisor of the lane
    count <= 128); each tile's segment executes inside a single kernel
    invocation with state resident for the whole segment. State buffers
    are aliased input->output, so the caller's donated lane pool is
    updated in place rather than reallocated per segment.

    Implemented as the 1-row special case of the packed-bank kernel
    (`iss_segment_banked`): a singleton bank, every lane on row 0 with a
    uniform `max_steps` budget — the flat one-hot fetch then clamps to
    `n_code - 1` exactly as the dedicated single-program fetch did, so
    the single- and multi-program paths share one kernel and cannot
    drift.

    `subset` is the static opcode subset (`iss.opcode_subset`): classes
    outside it are never emitted into the kernel. `interpret=None`
    resolves by backend — the compiled Mosaic kernel on TPU, the
    run-anywhere interpreter fallback elsewhere (the package's CPU
    convention); pass an explicit bool to override. Not jitted here —
    the fleet engine jits (and donates through) the wrapped call.
    """
    n_lanes = state.pc.shape[0]
    packed = PackedState(
        lanes=state,
        prog_id=jnp.zeros((n_lanes,), I32),
        max_steps=jnp.full((n_lanes,), max_steps, I32))
    out = iss_segment_banked(
        code[None, :], jnp.asarray([code.shape[0]], I32), packed,
        seg_steps=seg_steps, subset=subset,
        cost=None if cost is None else cost[None, :],
        faults=faults, lane_key=lane_key, epoch=epoch,
        lane_tile=lane_tile, interpret=interpret)
    return out.lanes
