"""Pure-jnp oracles for every Pallas kernel (allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


# ------------------------------------------------------- bitplane matmul

def quantize_weights(w, bits: int):
    """Symmetric per-output-channel quantization. w: (K, N) float.

    Returns (planes (B, K, N) int8 of {0,1}, scales (N,), w_q (K, N) int)."""
    amax = jnp.max(jnp.abs(w), axis=0)
    qmax = max(2.0 ** (bits - 1) - 1, 1.0)   # bits=1: levels {-1, 0}
    scales = jnp.where(amax > 0, amax / qmax, 1.0)
    w_q = jnp.clip(jnp.round(w / scales), -(2 ** (bits - 1)),
                   2 ** (bits - 1) - 1).astype(jnp.int32)
    u = (w_q + 2 ** (bits - 1)).astype(jnp.uint32)
    planes = jnp.stack([((u >> b) & 1).astype(jnp.int8)
                        for b in range(bits)])
    return planes, scales.astype(jnp.float32), w_q


def bitplane_matmul_ref(x, planes, scales, *, bits: int):
    """Oracle: reassemble W_q from planes, dense matmul, scale."""
    weights = jnp.zeros(planes.shape[1:], jnp.float32)
    for b in range(bits):
        weights += (2.0 ** b) * planes[b].astype(jnp.float32)
    weights -= 2.0 ** (bits - 1)
    out = jnp.dot(x.astype(jnp.float32), weights) * scales[None, :]
    return out.astype(x.dtype)


# ------------------------------------------------------- flash attention

def attention_ref(q, k, v, *, causal: bool = True):
    """q,k,v: (B, H, L, D). fp32 softmax."""
    d = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (d ** -0.5)
    if causal:
        lq, lk = s.shape[-2], s.shape[-1]
        mask = jnp.tril(jnp.ones((lq, lk), bool))
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


# ------------------------------------------------------------- ssd scan

def ssd_ref(x, dt, A, B, C, *, chunk: int = None):
    """Sequential SSD recurrence oracle. x: (Bt, H, L, P); dt: (Bt, H, L);
    A: (H,); B, C: (Bt, H, L, N). Returns (y, final_state (Bt,H,N,P))."""
    del chunk
    bt, h, l, p = x.shape
    n = B.shape[-1]
    # straightforward sequential loop (clarity over speed — it's an oracle)
    s = jnp.zeros((bt, h, n, p), jnp.float32)
    ys = []
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B.astype(jnp.float32)
    Cf = C.astype(jnp.float32)
    for t in range(l):
        da = jnp.exp(dtf[:, :, t] * A[None, :])
        s = s * da[..., None, None] + jnp.einsum(
            "bh,bhn,bhp->bhnp", dtf[:, :, t], Bf[:, :, t], xf[:, :, t])
        ys.append(jnp.einsum("bhn,bhnp->bhp", Cf[:, :, t], s))
    y = jnp.stack(ys, axis=2)                     # (bt,h,l,p)
    return y.astype(x.dtype), s
