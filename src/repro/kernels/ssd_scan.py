"""Mamba2 SSD chunked-scan Pallas kernel.

Grid: (B*H_blocks, L/Q) with the chunk dim sequential; the inter-chunk SSM
state lives in a VMEM scratch carried across grid steps (reset at chunk 0).
Per chunk: intra-chunk quadratic term (decay-masked C B^T) + inter-chunk
contribution from the carried state — the same math as
models/mamba.ssd_chunked, tiled for VMEM.

Layout: x (BH, L, P); dt (BH, L); B, C (BH, L, N) — heads pre-flattened and
B/C pre-broadcast per head by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, state_ref, *,
            q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _reset():
        state_ref[...] = jnp.zeros_like(state_ref)

    a = a_ref[0]                                       # scalar A (negative)
    x = x_ref[0].astype(jnp.float32)                   # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                 # (Q,)
    bm = b_ref[0].astype(jnp.float32)                  # (Q, N)
    cm = c_ref[0].astype(jnp.float32)                  # (Q, N)

    da = dt * a                                        # (Q,)
    cum = jnp.cumsum(da)                               # (Q,)
    seg_end = cum[-1]

    # intra-chunk
    decay = cum[:, None] - cum[None, :]                # (Q, Q)
    causal = lax.broadcasted_iota(jnp.int32, (q, q), 0) >= \
        lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.exp(jnp.where(causal, decay, -jnp.inf))
    cb = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    w = cb * lmat * dt[None, :]
    y = jax.lax.dot(w, x, preferred_element_type=jnp.float32)

    # inter-chunk from carried state: y += exp(cum_i) C_i . S_prev
    s_prev = state_ref[...]                            # (N, P)
    y += jnp.exp(cum)[:, None] * jax.lax.dot(
        cm, s_prev, preferred_element_type=jnp.float32)

    # state update: S = S * exp(seg_end) + sum_j exp(seg_end-cum_j) dt_j
    #               B_j x_j^T
    wstate = jnp.exp(seg_end - cum) * dt               # (Q,)
    s_new = jax.lax.dot_general(bm * wstate[:, None], x,
                                (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (N,P)
    state_ref[...] = s_prev * jnp.exp(seg_end) + s_new
    y_ref[0] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def ssd_scan(a, x, dt, b, c, *, q: int = 64, interpret: bool = True):
    """a: (BH,) per-head A; x: (BH, L, P); dt: (BH, L); b, c: (BH, L, N).

    Returns y: (BH, L, P). The D-residual and gating stay outside.
    """
    bh, l, p = x.shape
    n = b.shape[-1]
    assert l % q == 0, (l, q)
    return pl.pallas_call(
        functools.partial(_kernel, q=q),
        grid=(bh, l // q),
        in_specs=[
            pl.BlockSpec((1,), lambda i, ci: (i,)),
            pl.BlockSpec((1, q, p), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, q), lambda i, ci: (i, ci)),
            pl.BlockSpec((1, q, n), lambda i, ci: (i, ci, 0)),
            pl.BlockSpec((1, q, n), lambda i, ci: (i, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda i, ci: (i, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(a, x, dt, b, c)
