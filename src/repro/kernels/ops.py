"""jit'd public wrappers around the Pallas kernels (shape plumbing,
GQA grouping, plane packing). interpret=True everywhere on CPU; on TPU the
same calls lower to Mosaic."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as R
from repro.kernels.bitplane_matmul import bitplane_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def quantized_linear(x, w, *, bits: int = 8, tm: int = 128, tn: int = 128,
                     tk: int = 128, interpret: bool = True):
    """x: (..., K) @ w: (K, N) through the bit-plane kernel."""
    planes, scales, _ = R.quantize_weights(w, bits)
    lead = x.shape[:-1]
    xm = x.reshape(-1, x.shape[-1])
    m = xm.shape[0]
    pad = (-m) % tm
    if pad:
        xm = jnp.pad(xm, ((0, pad), (0, 0)))
    out = bitplane_matmul(xm, planes, scales, bits=bits, tm=tm, tn=tn,
                          tk=tk, interpret=interpret)
    return out[:m].reshape(*lead, w.shape[1])


def gqa_flash_attention(q, k, v, *, causal: bool = True, tq: int = 128,
                        tk: int = 128, interpret: bool = True):
    """q: (B, L, H, D); k/v: (B, L, Hkv, D) -> (B, L, H, D)."""
    b, l, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    k = jnp.repeat(k, g, axis=2) if g > 1 else k
    v = jnp.repeat(v, g, axis=2) if g > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * h, l, d)
    o = flash_attention(qf, kf, vf, causal=causal, tq=min(tq, l),
                        tk=min(tk, l), interpret=interpret)
    return o.reshape(b, h, l, d).transpose(0, 2, 1, 3)


def ssd(x, dt, A, B, C, *, q: int = 64, interpret: bool = True):
    """x: (Bt, H, L, P); dt: (Bt, H, L); A: (H,); B/C: (Bt, G, L, N) with
    G dividing H. Returns y: (Bt, H, L, P)."""
    bt, h, l, p = x.shape
    g = B.shape[1]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=1) if rep > 1 else B
    Ch = jnp.repeat(C, rep, axis=1) if rep > 1 else C
    n = Bh.shape[-1]
    a_flat = jnp.tile(A, bt)
    y = ssd_scan(a_flat,
                 x.reshape(bt * h, l, p),
                 dt.reshape(bt * h, l),
                 Bh.reshape(bt * h, l, n),
                 Ch.reshape(bt * h, l, n),
                 q=min(q, l), interpret=interpret)
    return y.reshape(bt, h, l, p)
