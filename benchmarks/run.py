"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes
artifacts/benchmarks.json with the derived headline quantities.
"""
from __future__ import annotations

import json
import os
import sys

from benchmarks.common import Timer

BENCHES = [
    ("fig2a_instruction_mix", "benchmarks.paper_tables"),
    ("fig2b_dynamic_instructions", "benchmarks.paper_tables"),
    ("table3_memory", "benchmarks.paper_tables"),
    ("table7_fig9_ppa", "benchmarks.paper_tables"),
    ("table6_feasibility", "benchmarks.paper_tables"),
    ("table8_memory_power", "benchmarks.paper_tables"),
    ("fig11_embodied", "benchmarks.paper_tables"),
    ("fig5_selection_maps", "benchmarks.paper_tables"),
    ("fig6_pareto", "benchmarks.paper_tables"),
    ("table5_at_scale", "benchmarks.paper_tables"),
    ("fig12_sensitivity_mix", "benchmarks.paper_tables"),
    ("fig13_sensitivity_energy", "benchmarks.paper_tables"),
    ("planner_grid", "benchmarks.serving"),
    ("roofline_table", "benchmarks.rooflines"),
    ("fleet_streaming_vs_monolithic", "benchmarks.fleet"),
    ("fleet_stepper_ab", "benchmarks.fleet"),
]


def main() -> None:
    import importlib
    derived_all = {}
    failures = []
    for fn_name, mod_name in BENCHES:
        try:
            mod = importlib.import_module(mod_name)
            fn = getattr(mod, fn_name)
            with Timer() as t:
                rows, derived = fn()
            for name, a, b in rows:
                print(f"{name},{t.us / max(len(rows), 1):.1f},{a};{b}")
            derived_all[fn_name] = derived
            print(f"{fn_name},{t.us:.1f},{json.dumps(derived, default=str)}")
        except Exception as e:  # keep the harness running
            failures.append((fn_name, f"{type(e).__name__}: {e}"))
            print(f"{fn_name},0,ERROR:{type(e).__name__}:{e}")
    os.makedirs("artifacts", exist_ok=True)
    with open("artifacts/benchmarks.json", "w") as f:
        json.dump(derived_all, f, indent=1, default=str)
    if failures:
        print("FAILURES:", failures, file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
