"""Shared benchmark helpers: cached workload profiling (instruction counts,
mix, memory) via the ISS."""
from __future__ import annotations

import json
import os
import time
from typing import Dict

import numpy as np

from repro.core.carbon import DeviceProfile
from repro.flexibench.base import Workload, all_workloads, get
from repro.flexibench.memory import profile_memory
from repro.flexibits.pyiss import PyISS

CACHE_PATH = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                          "profile_cache.json")
_CACHE: Dict[str, dict] = {}


def _load_cache():
    global _CACHE
    if not _CACHE and os.path.exists(CACHE_PATH):
        with open(CACHE_PATH) as f:
            _CACHE = json.load(f)
    return _CACHE


def _save_cache():
    os.makedirs(os.path.dirname(CACHE_PATH), exist_ok=True)
    with open(CACHE_PATH, "w") as f:
        json.dump(_CACHE, f, indent=1)


def profile_program(code, mem0, mem_words, max_steps, out_addr=None):
    sim = PyISS(code, mem_words, mem0).run(max_steps)
    assert sim.halted, "program did not halt"
    return {
        "n_instr": sim.n_instr,
        "n_two_stage": sim.n_two_stage,
        "mix": sim.mix,
        "events": sim.events.tolist(),
        "out": int(np.int32(sim.mem[out_addr])) if out_addr is not None
        else None,
    }


def workload_profile(key: str, n_avg: int = 3) -> dict:
    """Averaged dynamic-instruction profile + memory for one workload.

    Cached entries predating the timing layer (no "events" vector,
    DESIGN.md §9.10) are treated as misses and re-profiled.
    """
    cache = _load_cache()
    if key in cache and "events" in cache[key]:
        return cache[key]
    w = get(key)
    rng = np.random.default_rng(0)
    xs = w.gen_inputs(rng, n_avg)
    counts, twos, events = [], [], []
    mix_total: Dict[str, int] = {}
    for x in xs:
        r = profile_program(w.program.code, w.initial_memory(x),
                            w.total_mem_words, w.max_steps)
        counts.append(r["n_instr"])
        twos.append(r["n_two_stage"])
        events.append(r["events"])
        for k, v in r["mix"].items():
            mix_total[k] = mix_total.get(k, 0) + v
    mem = profile_memory(w)
    prof = {
        "n_instr": float(np.mean(counts)),
        "n_two_stage": float(np.mean(twos)),
        "mix": mix_total,
        "events": np.mean(np.asarray(events, np.float64), axis=0).tolist(),
        **mem,
    }
    _CACHE[key] = prof
    _save_cache()
    return prof


def device_profile(key: str, dynamic: bool = False) -> DeviceProfile:
    """DeviceProfile for `key`, carrying the measured timing events.

    With dynamic=False (the default everywhere paper numbers are
    reproduced) event pricing equals the two-bucket analytic model
    exactly; dynamic=True prices the §9.10 dynamic terms as well.
    """
    p = workload_profile(key)
    return DeviceProfile(
        n_one_stage=p["n_instr"] - p["n_two_stage"],
        n_two_stage=p["n_two_stage"],
        vm_kb=p["vm_kb"],
        nvm_kb=p["nvm_kb"],
        events=tuple(p["events"]),
        dynamic=dynamic,
    )


def all_profiles() -> Dict[str, dict]:
    return {w.key: workload_profile(w.key) for w in all_workloads()}


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.us = (time.perf_counter() - self.t0) * 1e6
