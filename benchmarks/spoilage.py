"""Fig. 6 support: carbon + accuracy per spoilage algorithm variant.

Carbon is total (embodied + operational) over a 1-year deployment at the
FS task frequency (hourly), evaluated at each variant's carbon-optimal
core; accuracy on a held-out synthetic test set.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Tuple

import numpy as np

from repro.core import carbon as C
from repro.core.carbon import DeviceProfile
from repro.core.selection import optimal_core
from repro.flexibench.spoilage_algos import all_algos, gen_dataset
from repro.flexibits.pyiss import PyISS

CACHE = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                     "spoilage_cache.json")
LIFETIME_S = 365 * 86_400.0
EXECS_PER_DAY = 24.0


def _profile_algo(algo) -> dict:
    rng = np.random.default_rng(3)
    x, _ = gen_dataset(rng, 1)
    mem_words = (algo.program.ro_base // 4 + len(algo.program.ro_words)
                 + max(algo.mem_words, 64))
    mem = algo.program.initial_memory(mem_words).copy()
    mem[:x.shape[1]] = x[0]
    sim = PyISS(algo.program.code, mem_words, mem).run(algo.max_steps)
    assert sim.halted, algo.name
    return {"n_instr": sim.n_instr, "n_two_stage": sim.n_two_stage,
            "nvm_kb": algo.program.nvm_bytes / 1024.0,
            "vm_kb": algo.vm_reserved_bytes / 1024.0}


def algo_carbon_accuracy() -> Dict[str, Tuple[float, float, str]]:
    if os.path.exists(CACHE):
        with open(CACHE) as f:
            return {k: tuple(v) for k, v in json.load(f).items()}
    rng = np.random.default_rng(99)
    xte, yte = gen_dataset(rng, 4000)
    out = {}
    for algo in all_algos():
        acc = float((algo.ref(xte) == yte).mean())
        p = _profile_algo(algo)
        prof = DeviceProfile(p["n_instr"] - p["n_two_stage"],
                             p["n_two_stage"], p["vm_kb"], p["nvm_kb"])
        core, totals = optimal_core(prof, lifetime_s=LIFETIME_S,
                                    execs_per_day=EXECS_PER_DAY)
        out[algo.name] = (acc, float(min(totals.values())), core.name)
    os.makedirs(os.path.dirname(CACHE), exist_ok=True)
    with open(CACHE, "w") as f:
        json.dump(out, f, indent=1)
    return out
