"""Roofline table from dry-run artifacts (EXPERIMENTS.md §Roofline source).

Reads artifacts/dryrun/*.json (written by repro.launch.dryrun) and emits
per-cell terms + bottleneck + useful-flops ratio. No jax involvement — the
numbers were extracted at compile time.
"""
from __future__ import annotations

import glob
import json
import os

_BASE = os.path.join(os.path.dirname(__file__), "..", "artifacts")
ART_OPT = os.path.join(_BASE, "dryrun_opt")
ART = ART_OPT if os.path.isdir(ART_OPT) else os.path.join(_BASE, "dryrun")


def load_cells(pod: str = "pod1", art: str = None):
    cells = {}
    for path in sorted(glob.glob(os.path.join(art or ART,
                                              f"*__{pod}.json"))):
        with open(path) as f:
            d = json.load(f)
        cells[f"{d['arch']}__{d['shape']}"] = d
    return cells


def roofline_table():
    cells = load_cells("pod1")
    rows = []
    n_ok = n_skip = 0
    worst = (None, 1.0)
    for key, d in cells.items():
        if d["status"] == "skip":
            n_skip += 1
            rows.append((f"roofline/{key}", 0, "skip"))
            continue
        if d["status"] != "ok":
            rows.append((f"roofline/{key}", 0, f"error:{d.get('error')}"))
            continue
        n_ok += 1
        r = d["roofline"]
        rows.append((
            f"roofline/{key}",
            r["bound_step_s"],
            f"{r['bottleneck']}|frac={r['roofline_fraction']:.4f}"
            f"|useful={r['useful_ratio']:.3f}"))
        if r["roofline_fraction"] < worst[1] and d["shape"] == "train_4k":
            worst = (key, r["roofline_fraction"])
    return rows, {"cells_ok": n_ok, "cells_skip": n_skip,
                  "worst_train_cell": worst[0],
                  "worst_train_fraction": worst[1]}
